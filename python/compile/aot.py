"""AOT lowering: JAX model -> HLO *text* artifacts + JSON manifest.

The interchange format is HLO text, not ``.serialize()``: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

One artifact per (kind, m, d, B) configuration; the Rust runtime reads
``artifacts/manifest.json`` and compiles what each engine needs.  Python
runs exactly once (``make artifacts``) and never on the request path.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (m, d, batch) grid.  d=8 -> flight-like data, d=9 -> taxi-like data,
# d=4 -> quickstart/tests.  Batches are multiples of the Pallas tile (128).
GRAD_B = 1024
EVAL_B = 2048
CONFIGS = [
    # (m, d) pairs
    (50, 8), (100, 8), (200, 8),     # Tables 1-2, Figs 1-3, Appendix C/D
    (50, 9), (100, 9),               # Fig 4 (taxi)
    (16, 4),                         # quickstart / integration tests
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _param_specs(m, d):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((m,), f32),        # mu
        jax.ShapeDtypeStruct((m, m), f32),      # u
        jax.ShapeDtypeStruct((m, d), f32),      # z
        jax.ShapeDtypeStruct((m, m), f32),      # chol_l (host-computed)
        jax.ShapeDtypeStruct((), f32),          # log_a0
        jax.ShapeDtypeStruct((d,), f32),        # log_eta
        jax.ShapeDtypeStruct((), f32),          # log_sigma
    )


def lower_one(kind, m, d, b):
    f32 = jnp.float32
    params = _param_specs(m, d)
    xspec = jax.ShapeDtypeStruct((b, d), f32)
    yspec = jax.ShapeDtypeStruct((b,), f32)
    if kind == "grad":
        fn, args = model.grad_fn, params + (xspec, yspec, yspec)
    elif kind == "predict":
        fn, args = model.predict_fn, params + (xspec,)
    elif kind == "elbo":
        fn, args = model.elbo_fn, params + (xspec, yspec, yspec)
    else:
        raise ValueError(kind)
    return to_hlo_text(jax.jit(fn).lower(*args))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts directory")
    ap.add_argument("--configs", default=None,
                    help="comma list of m:d pairs, e.g. 50:8,100:8")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    configs = CONFIGS
    if args.configs:
        configs = [tuple(int(v) for v in c.split(":"))
                   for c in args.configs.split(",")]

    manifest = []
    for m, d in configs:
        for kind, b in (("grad", GRAD_B), ("predict", EVAL_B),
                        ("elbo", EVAL_B)):
            name = f"{kind}_m{m}_d{d}_b{b}"
            path = os.path.join(args.out, name + ".hlo.txt")
            text = lower_one(kind, m, d, b)
            with open(path, "w") as f:
                f.write(text)
            manifest.append(dict(kind=kind, m=m, d=d, b=b,
                                 file=name + ".hlo.txt",
                                 block_b=128, dtype="f32", abi="split-chol-v2"))
            print(f"  wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(dict(version=1, grad_b=GRAD_B, eval_b=EVAL_B,
                       artifacts=manifest), f, indent=1)
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
