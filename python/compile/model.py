"""L2: the ADVGP worker compute graph (JAX, build-time only).

Three functions get AOT-lowered to HLO text (see ``aot.py``) and executed
by the Rust coordinator through PJRT:

* ``grad_fn``     — value + full gradient of the local data term
                    ``G(theta; batch) = sum_i mask_i g_i`` (paper eq. 15/23).
                    This is what every worker runs per iteration.
* ``predict_fn``  — posterior predictive mean/variance for a batch
                    (evaluator thread: RMSE / MNLP traces).
* ``elbo_fn``     — the batch contribution ``sum_i mask_i g_i`` plus the
                    masked squared error, for the Appendix-C negative log
                    evidence traces (the convex KL term ``h`` is evaluated
                    on the Rust side: it only needs mu and U).

Artifact ABI (all float32), fixed positional order — the Rust runtime
packs literals in exactly this order:

    mu        [m]      variational mean of q(w)
    u         [m, m]   upper-tri Cholesky factor of Sigma (Sigma = U^T U)
    z         [m, d]   inducing inputs
    chol_l    [m, m]   lower-tri L with K_mm^{-1} = L L^T  (HOST-COMPUTED)
    log_a0    []       ARD signal amplitude (a0 = exp(log_a0))
    log_eta   [d]      ARD inverse squared lengthscales (eta = exp(log_eta))
    log_sigma []       observation noise (beta = exp(-2 log_sigma))

Batch inputs: x [B, d], y [B], mask [B] (1.0 for real rows, 0.0 padding).

**Why chol_l is an input**: jax's CPU linalg (cholesky/inv/solve) lowers
to typed-FFI custom-calls (API v4) that the deployment XLA
(xla_extension 0.5.1) cannot execute.  So the O(m^3) factorization runs
on the Rust host (it owns an SPD solver anyway), the artifact treats L
as a leaf, and ``grad_fn`` returns the cotangent dL so the host can
chain it through chol(inv(K_mm)) — see rust/src/grad/chain.rs.  The
per-sample O(B m^2) work (the actual hot path) stays in XLA/Pallas.

The gradient is taken by ``jax.value_and_grad`` through the Pallas fused
kernel (``kernels.ard_phi.fused_phi``) whose custom VJP is hand-written.
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.ard_phi import fused_phi

# Toggle for A/B tests: use the pure-jnp twin instead of the Pallas kernel.
_USE_PALLAS = True


def _phi(x, z, chol_l, log_a0, log_eta, use_pallas=None, block_b=128):
    use_pallas = _USE_PALLAS if use_pallas is None else use_pallas
    if use_pallas:
        return fused_phi(x, z, chol_l, log_a0, log_eta, block_b)
    return ref.fused_phi_ref(x, z, chol_l, log_a0, log_eta)


def objective(mu, u, z, chol_l, log_a0, log_eta, log_sigma, x, y, mask,
              use_pallas=None, block_b=128):
    """Masked local data term G (negative-ELBO part, eq. 23).

    ``chol_l`` is a leaf input (see module docstring); gradients w.r.t.
    it are the dL cotangent the Rust host chains through chol(inv(Kmm)).
    """
    u_tri = jnp.triu(u)
    _, phi, ktilde = _phi(x, z, chol_l, log_a0, log_eta,
                          use_pallas=use_pallas, block_b=block_b)
    beta = jnp.exp(-2.0 * log_sigma)
    e = phi @ mu - y
    phi_u = phi @ u_tri.T
    quad = jnp.sum(phi_u * phi_u, axis=-1)
    g = (0.5 * jnp.log(2.0 * jnp.pi) + log_sigma
         + 0.5 * beta * (e * e + quad + ktilde))
    return jnp.sum(mask * g)


def objective_full(mu, u, z, log_a0, log_eta, log_sigma, x, y, mask,
                   jitter=ref.DEFAULT_JITTER, use_pallas=None, block_b=128):
    """Objective with chol_l computed inside (eager/test use only —
    contains jnp.linalg, so it is never AOT-lowered)."""
    chol_l = ref.chol_inv_factor(z, log_a0, log_eta, jitter)
    return objective(mu, u, z, chol_l, log_a0, log_eta, log_sigma, x, y,
                     mask, use_pallas=use_pallas, block_b=block_b)


def grad_fn(mu, u, z, chol_l, log_a0, log_eta, log_sigma, x, y, mask):
    """(G, dmu, du, dz_direct, dchol_l, dlog_a0_direct, dlog_eta_direct,
    dlog_sigma) for one batch.  The *direct* gradients exclude the
    L-path, which the host adds by chaining dchol_l."""
    val, grads = jax.value_and_grad(
        objective, argnums=(0, 1, 2, 3, 4, 5, 6))(
            mu, u, z, chol_l, log_a0, log_eta, log_sigma, x, y, mask)
    dmu, du, dz, dchol_l, dla0, dleta, dls = grads
    # The strictly-lower part of u never enters the objective, so autodiff
    # already returns zeros there; triu is a no-op kept for clarity.
    return (val, dmu, jnp.triu(du), dz, jnp.tril(dchol_l), dla0, dleta, dls)


def predict_fn(mu, u, z, chol_l, log_a0, log_eta, log_sigma, x):
    """(mean, var_y) with var_y = ktilde + phi^T Sigma phi + sigma^2."""
    u_tri = jnp.triu(u)
    _, phi, ktilde = _phi(x, z, chol_l, log_a0, log_eta)
    mean = phi @ mu
    phi_u = phi @ u_tri.T
    var_f = ktilde + jnp.sum(phi_u * phi_u, axis=-1)
    return mean, var_f + jnp.exp(2.0 * log_sigma)


def elbo_fn(mu, u, z, chol_l, log_a0, log_eta, log_sigma, x, y, mask):
    """(sum_i mask_i g_i, sum_i mask_i (mean_i - y_i)^2) for one batch.

    -ELBO = sum-over-all-batches(g) + h(mu, U); h is computed in Rust.
    The squared-error output lets the evaluator reuse the same pass for
    training-RMSE diagnostics.
    """
    g = objective(mu, u, z, chol_l, log_a0, log_eta, log_sigma, x, y, mask)
    mean, _ = predict_fn(mu, u, z, chol_l, log_a0, log_eta, log_sigma, x)
    sse = jnp.sum(mask * (mean - y) ** 2)
    return g, sse


def init_params(m, d, key=None, z_init=None):
    """Paper §6.1 initialization: mu = 0, U = I, unit kernel scales."""
    if z_init is None:
        key = key if key is not None else jax.random.PRNGKey(0)
        z_init = jax.random.normal(key, (m, d), dtype=jnp.float32)
    return dict(
        mu=jnp.zeros((m,), jnp.float32),
        u=jnp.eye(m, dtype=jnp.float32),
        z=jnp.asarray(z_init, jnp.float32),
        log_a0=jnp.asarray(0.0, jnp.float32),
        # 1/d heuristic for standardized features (matches Theta::init
        # on the Rust side): keeps the kernel responsive for any d.
        log_eta=jnp.full((d,), -jnp.log(jnp.asarray(d, jnp.float32))),
        log_sigma=jnp.asarray(0.0, jnp.float32),
    )
