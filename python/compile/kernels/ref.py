"""Pure-jnp reference oracle for the ADVGP compute kernels.

Everything here is written with plain ``jax.numpy`` (no Pallas) and is
fully differentiable.  It serves three purposes:

1. Correctness oracle for the Pallas kernel (``ard_phi.py``): pytest
   asserts ``allclose`` between the two on swept shapes.
2. Autodiff oracle for the hand-written ``custom_vjp`` of the fused
   kernel: gradients of any scalar function of the kernel outputs must
   match ``jax.grad`` through this reference.
3. Readable statement of the math in the paper (eqs. 6, 10, 11, 15, 23).

Notation follows the paper: a batch ``X`` of shape [B, d], inducing
inputs ``Z`` of shape [m, d], ARD squared-exponential kernel

    k(x, z) = a0^2 * exp(-0.5 * sum_k eta_k (x_k - z_k)^2)

with ``eta = exp(log_eta)`` (so lengthscale a_k = eta_k^-1/2), and the
feature map of eq. (11): ``phi(x) = L^T k_m(x)`` where ``L`` is the
lower-triangular Cholesky factor of ``K_mm^{-1}`` (``K_mm^{-1} = L L^T``).
"""

import jax
import jax.numpy as jnp

# Jitter added to K_mm before inversion; scaled by a0^2 so it tracks the
# kernel's output scale.  f32-safe for m <= ~500.
DEFAULT_JITTER = 1e-4


def ard_cross(x, z, log_a0, log_eta):
    """ARD squared-exponential cross-covariance K[x, z] of shape [B, m]."""
    eta = jnp.exp(log_eta)  # [d]
    a0_sq = jnp.exp(2.0 * log_a0)
    # Pairwise scaled squared distances via broadcasting: [B, m].
    diff = x[:, None, :] - z[None, :, :]
    d2 = jnp.sum(diff * diff * eta, axis=-1)
    return a0_sq * jnp.exp(-0.5 * d2)


def kmm(z, log_a0, log_eta, jitter=DEFAULT_JITTER):
    """Inducing covariance K_mm with scaled jitter on the diagonal."""
    a0_sq = jnp.exp(2.0 * log_a0)
    k = ard_cross(z, z, log_a0, log_eta)
    return k + jitter * a0_sq * jnp.eye(z.shape[0], dtype=k.dtype)


def chol_inv_factor(z, log_a0, log_eta, jitter=DEFAULT_JITTER):
    """Lower-triangular L with K_mm^{-1} = L L^T (paper's convention).

    Computed as L = cholesky(inv(K_mm)) after symmetrizing; m is small
    (<= a few hundred) so the explicit inverse is cheap and matches the
    paper's appendix-A derivation exactly.
    """
    k = kmm(z, log_a0, log_eta, jitter)
    kinv = jnp.linalg.inv(k)
    kinv = 0.5 * (kinv + kinv.T)
    return jnp.linalg.cholesky(kinv)


def fused_phi_ref(x, z, chol_l, log_a0, log_eta):
    """Reference for the fused L1 kernel.

    Returns (K_bm, Phi, ktilde):
      K_bm   [B, m] — cross covariance k_m(x_i)^T rows
      Phi    [B, m] — feature map rows phi_i = L^T k_m(x_i)
      ktilde [B]    — diag of K_nn - Phi Phi^T restricted to the batch,
                      i.e. a0^2 - ||phi_i||^2 (eq. 8's k~_ii).
    """
    k_bm = ard_cross(x, z, log_a0, log_eta)
    phi = k_bm @ chol_l
    a0_sq = jnp.exp(2.0 * log_a0)
    ktilde = a0_sq - jnp.sum(phi * phi, axis=-1)
    return k_bm, phi, ktilde


def objective_ref(mu, u, z, log_a0, log_eta, log_sigma, x, y, mask,
                  jitter=DEFAULT_JITTER):
    """Batch data term G = sum_i mask_i * g_i of the negative ELBO (eq. 23).

    ``u`` is the upper-triangular Cholesky factor of Sigma (Sigma = U^T U);
    only its upper triangle is read.  ``h`` (the KL, eq. 24) is *not*
    included: in ADVGP it lives on the server inside the proximal
    operator, so workers only ever evaluate/differentiate G.
    """
    u_tri = jnp.triu(u)
    chol_l = chol_inv_factor(z, log_a0, log_eta, jitter)
    _, phi, ktilde = fused_phi_ref(x, z, chol_l, log_a0, log_eta)
    beta = jnp.exp(-2.0 * log_sigma)
    e = phi @ mu - y
    phi_u = phi @ u_tri.T            # rows: U phi_i  -> [B, m]
    quad = jnp.sum(phi_u * phi_u, axis=-1)  # phi_i^T Sigma phi_i
    g = (0.5 * jnp.log(2.0 * jnp.pi) + log_sigma
         + 0.5 * beta * (e * e + quad + ktilde))
    return jnp.sum(mask * g)


def kl_term(mu, u):
    """h = KL(q(w) || N(0, I)) of eq. (24), from the Cholesky factor U."""
    u_tri = jnp.triu(u)
    m = mu.shape[0]
    diag = jnp.diagonal(u_tri)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.abs(diag)))
    tr = jnp.sum(u_tri * u_tri)
    return 0.5 * (-logdet - m + tr + mu @ mu)


def predict_ref(mu, u, z, log_a0, log_eta, log_sigma, x,
                jitter=DEFAULT_JITTER):
    """Posterior predictive q(y*) = N(phi^T mu, ktilde + phi^T Sigma phi + sigma^2)."""
    u_tri = jnp.triu(u)
    chol_l = chol_inv_factor(z, log_a0, log_eta, jitter)
    _, phi, ktilde = fused_phi_ref(x, z, chol_l, log_a0, log_eta)
    mean = phi @ mu
    phi_u = phi @ u_tri.T
    var_f = ktilde + jnp.sum(phi_u * phi_u, axis=-1)
    noise = jnp.exp(2.0 * log_sigma)
    return mean, var_f + noise


def exact_log_evidence(x, y, log_a0, log_eta, log_sigma):
    """Exact GP log evidence log N(y | 0, K_nn + sigma^2 I) (eq. 2).

    O(n^3); used only in tests to check ELBO <= evidence and the m -> n
    tightness of the bound.
    """
    n = x.shape[0]
    knn = ard_cross(x, x, log_a0, log_eta)
    noise = jnp.exp(2.0 * log_sigma)
    c = knn + noise * jnp.eye(n, dtype=knn.dtype)
    chol = jnp.linalg.cholesky(c)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(chol)))
    return -0.5 * (n * jnp.log(2.0 * jnp.pi) + logdet + y @ alpha)
