"""L1 Pallas kernel: fused ARD cross-covariance + feature map.

This is the per-worker compute hot-spot of ADVGP: for a data block
``X_blk`` it produces, in one pass,

    K_bm   = k(X_blk, Z)                       [B, m]   (ARD SE kernel)
    Phi    = K_bm @ L                          [B, m]   (eq. 11 feature map)
    ktilde = a0^2 - rowsum(Phi * Phi)          [B]      (eq. 8 diag term)

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid runs over
batch tiles of size ``block_b``; each grid step keeps the X tile, the
whole inducing matrix ``Z`` (m×d, tiny) and the whole Cholesky factor
``L`` (m×m, <=160 KB at m=200) resident in VMEM.  The pairwise-distance
+ exp() part is VPU work, the ``K_bm @ L`` contraction is MXU work.
``interpret=True`` everywhere because the CPU PJRT plugin cannot execute
Mosaic custom-calls; the kernel still lowers into the same HLO module as
the surrounding jax program, which is what the Rust runtime loads.

Reverse-mode: interpret-mode ``pallas_call`` has no autodiff rule, so
``fused_phi`` is wrapped in a ``jax.custom_vjp`` whose backward pass is
hand-derived (and checked in pytest against ``jax.grad`` through the
pure-jnp oracle in ``ref.py``).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128


def _fused_kernel(x_ref, z_ref, l_ref, a0_ref, eta_ref,
                  k_ref, phi_ref, kt_ref):
    """One batch tile: [TB, d] x -> K [TB, m], Phi [TB, m], ktilde [TB]."""
    x = x_ref[...]                       # [TB, d]  (VMEM)
    z = z_ref[...]                       # [m, d]   (VMEM, replicated)
    chol_l = l_ref[...]                  # [m, m]   (VMEM, replicated)
    a0_sq = jnp.exp(2.0 * a0_ref[0])
    eta = jnp.exp(eta_ref[...])          # [d]

    # Scaled pairwise squared distances.  d is tiny (<= ~16) so the
    # broadcasted [TB, m, d] intermediate stays well inside VMEM.
    diff = x[:, None, :] - z[None, :, :]
    d2 = jnp.sum(diff * diff * eta[None, None, :], axis=-1)
    k_bm = a0_sq * jnp.exp(-0.5 * d2)    # VPU

    # Feature map: MXU contraction.
    phi = jnp.dot(k_bm, chol_l, preferred_element_type=jnp.float32)

    k_ref[...] = k_bm
    phi_ref[...] = phi
    kt_ref[...] = a0_sq - jnp.sum(phi * phi, axis=-1)


def _fused_phi_fwd_impl(x, z, chol_l, log_a0, log_eta, *, block_b):
    b, d = x.shape
    m = z.shape[0]
    if b % block_b != 0:
        raise ValueError(f"batch {b} not divisible by block_b {block_b}")
    grid = (b // block_b,)
    return pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),   # X: tiled
            pl.BlockSpec((m, d), lambda i: (0, 0)),         # Z: replicated
            pl.BlockSpec((m, m), lambda i: (0, 0)),         # L: replicated
            pl.BlockSpec((1,), lambda i: (0,)),             # log_a0
            pl.BlockSpec((d,), lambda i: (0,)),             # log_eta
        ],
        out_specs=[
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, m), x.dtype),
            jax.ShapeDtypeStruct((b, m), x.dtype),
            jax.ShapeDtypeStruct((b,), x.dtype),
        ],
        interpret=True,
    )(x, z, chol_l, jnp.reshape(log_a0, (1,)), log_eta)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def fused_phi(x, z, chol_l, log_a0, log_eta, block_b=DEFAULT_BLOCK_B):
    """Differentiable fused kernel: returns (K_bm, Phi, ktilde)."""
    k_bm, phi, ktilde = _fused_phi_fwd_impl(
        x, z, chol_l, log_a0, log_eta, block_b=block_b)
    return k_bm, phi, ktilde


def _fused_phi_fwd(x, z, chol_l, log_a0, log_eta, block_b):
    k_bm, phi, ktilde = _fused_phi_fwd_impl(
        x, z, chol_l, log_a0, log_eta, block_b=block_b)
    residuals = (x, z, chol_l, log_a0, log_eta, k_bm, phi)
    return (k_bm, phi, ktilde), residuals


def _fused_phi_bwd(block_b, residuals, cotangents):
    """Hand-derived VJP.

    Primal:  K = a0^2 * exp(-0.5 * sum_k eta_k (x_ik - z_jk)^2)
             Phi = K @ L
             ktilde_i = a0^2 - sum_j Phi_ij^2
    The cotangent paths into K are the direct one (dK) plus Phi's
    (dPhi_tot @ L^T) where dPhi_tot folds ktilde's -2*Phi*dkt term.
    """
    x, z, chol_l, log_a0, log_eta, k_bm, phi = residuals
    dk, dphi, dkt = cotangents
    eta = jnp.exp(log_eta)
    a0_sq = jnp.exp(2.0 * log_a0)

    dphi_tot = dphi - 2.0 * phi * dkt[:, None]
    dk_tot = dk + dphi_tot @ chol_l.T
    d_chol_l = k_bm.T @ dphi_tot

    g = dk_tot * k_bm                     # [B, m]
    g_row = jnp.sum(g, axis=1)            # [B]
    g_col = jnp.sum(g, axis=0)            # [m]

    # dK_ij/dx_ik = -K_ij * eta_k * (x_ik - z_jk); dK_ij/dz_jk is +.
    dx = -eta[None, :] * (g_row[:, None] * x - g @ z)
    dz = eta[None, :] * (g.T @ x - g_col[:, None] * z)

    # dK/dlog_a0 = 2K ; dktilde/dlog_a0 = 2 a0^2.
    dlog_a0 = 2.0 * jnp.sum(g) + 2.0 * a0_sq * jnp.sum(dkt)

    # dK_ij/dlog_eta_k = -0.5 * K_ij * eta_k * (x_ik - z_jk)^2, expanded
    # so no [B, m, d] tensor is materialized:
    #   sum_ij G_ij (x_ik - z_jk)^2
    #     = g_row . (x.^2)_k  - 2 sum_i x_ik (g @ z)_ik + g_col . (z.^2)_k
    quad = (g_row @ (x * x)
            - 2.0 * jnp.sum(x * (g @ z), axis=0)
            + g_col @ (z * z))
    dlog_eta = -0.5 * eta * quad

    return dx, dz, d_chol_l, dlog_a0, dlog_eta


fused_phi.defvjp(_fused_phi_fwd, _fused_phi_bwd)


def fused_phi_jnp_fallback(x, z, chol_l, log_a0, log_eta):
    """Pure-jnp twin of ``fused_phi`` (used to A/B the lowered HLO)."""
    from . import ref
    return ref.fused_phi_ref(x, z, chol_l, log_a0, log_eta)
