"""L2 correctness: objective/gradients/predictive vs paper math.

Checks (a) the Pallas-backed objective is bit-compatible with the pure
oracle, (b) the closed-form gradients of the paper (eqs. 16, 17, 26, 27)
agree with autodiff, (c) variational-bound properties against the exact
GP (eq. 2), (d) the predictive distribution behaves like a GP posterior.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def make_state(seed, b, m, d, y_from_gp=False):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    x = jax.random.normal(ks[0], (b, d))
    z = jax.random.normal(ks[1], (m, d)) * 0.8
    mu = jax.random.normal(ks[2], (m,)) * 0.3
    u = jnp.eye(m) * 0.8 + jnp.triu(jax.random.normal(ks[3], (m, m)) * 0.05)
    la0 = jnp.asarray(0.2)
    leta = jax.random.normal(ks[4], (d,)) * 0.2
    ls = jnp.asarray(-0.4)
    if y_from_gp:
        knn = ref.ard_cross(x, x, la0, leta) + 1e-4 * jnp.eye(b)
        f = jnp.linalg.cholesky(knn) @ jax.random.normal(ks[5], (b,))
        y = f + jnp.exp(ls) * jax.random.normal(ks[6], (b,))
    else:
        y = jax.random.normal(ks[5], (b,))
    return mu, u, z, la0, leta, ls, x, y


class TestObjective:
    @pytest.mark.parametrize("b,m,d", [(128, 20, 5), (256, 50, 8)])
    def test_pallas_equals_ref(self, b, m, d):
        mu, u, z, la0, leta, ls, x, y = make_state(1, b, m, d)
        mask = jnp.ones((b,))
        v_p = model.objective_full(mu, u, z, la0, leta, ls, x, y, mask,
                                   use_pallas=True)
        v_r = ref.objective_ref(mu, u, z, la0, leta, ls, x, y, mask)
        np.testing.assert_allclose(float(v_p), float(v_r), rtol=1e-5)

    def test_mask_drops_rows(self):
        """Padding rows must contribute exactly zero."""
        mu, u, z, la0, leta, ls, x, y = make_state(2, 128, 10, 4)
        mask = jnp.ones((128,)).at[100:].set(0.0)
        full = model.objective_full(mu, u, z, la0, leta, ls, x[:128], y, mask)
        # Same computation with garbage in the masked rows.
        x2 = x.at[100:].set(1e3)
        y2 = y.at[100:].set(-1e3)
        v2 = model.objective_full(mu, u, z, la0, leta, ls, x2, y2, mask)
        np.testing.assert_allclose(float(full), float(v2), rtol=1e-5)

    def test_additivity_over_shards(self):
        """G decomposes as a sum over data — the property that makes the
        ELBO fit ParameterServer's composite form (eq. 12/14)."""
        mu, u, z, la0, leta, ls, x, y = make_state(3, 256, 12, 4)
        ones = jnp.ones((256,))
        m1 = ones.at[128:].set(0.0)
        m2 = ones.at[:128].set(0.0)
        total = model.objective_full(mu, u, z, la0, leta, ls, x, y, ones)
        part = (model.objective_full(mu, u, z, la0, leta, ls, x, y, m1)
                + model.objective_full(mu, u, z, la0, leta, ls, x, y, m2))
        np.testing.assert_allclose(float(total), float(part), rtol=1e-5)

    def test_lower_triangle_of_u_ignored(self):
        mu, u, z, la0, leta, ls, x, y = make_state(4, 128, 10, 4)
        mask = jnp.ones((128,))
        v1 = model.objective_full(mu, u, z, la0, leta, ls, x, y, mask)
        u2 = u + jnp.tril(jnp.full((10, 10), 7.0), -1)
        v2 = model.objective_full(mu, u2, z, la0, leta, ls, x, y, mask)
        np.testing.assert_allclose(float(v1), float(v2), rtol=1e-6)


class TestPaperGradients:
    """Closed forms from the paper vs autodiff of the implementation.

    ``grad_fn`` uses the split-Cholesky ABI: chol_l is a leaf input and
    the (μ, U, lnσ) gradients plus the direct (Z, ln a0, lnη) paths come
    out; the dL̄-chained parts are host-side (tested in Rust).  The
    eq. 16/17/26 forms have no L-path so they must match exactly.
    """

    def setup_method(self, _):
        (self.mu, self.u, self.z, self.la0, self.leta, self.ls,
         self.x, self.y) = make_state(7, 256, 30, 6)
        self.mask = jnp.ones((256,))
        self.chol_l = ref.chol_inv_factor(self.z, self.la0, self.leta)
        _, self.phi, self.kt = ref.fused_phi_ref(
            self.x, self.z, self.chol_l, self.la0, self.leta)
        self.beta = jnp.exp(-2.0 * self.ls)
        self.grads = model.grad_fn(self.mu, self.u, self.z, self.chol_l,
                                   self.la0, self.leta, self.ls, self.x,
                                   self.y, self.mask)

    def test_eq16_dmu(self):
        want = self.beta * self.phi.T @ (self.phi @ self.mu - self.y)
        np.testing.assert_allclose(np.asarray(self.grads[1]),
                                   np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_eq17_du(self):
        wu = jnp.triu(self.u)
        want = self.beta * jnp.triu(wu @ (self.phi.T @ self.phi))
        np.testing.assert_allclose(np.asarray(self.grads[2]),
                                   np.asarray(want), rtol=1e-4, atol=1e-3)

    def test_eq26_dlog_sigma(self):
        u_tri = jnp.triu(self.u)
        e = self.phi @ self.mu - self.y
        phi_u = self.phi @ u_tri.T
        quad = jnp.sum(phi_u * phi_u, axis=-1)
        want = jnp.sum(1.0 - self.beta * (e ** 2 + quad + self.kt))
        np.testing.assert_allclose(float(self.grads[7]), float(want),
                                   rtol=1e-4)

    def test_eq27_dlog_a0_full_path(self):
        """Eq. (27)'s closed form is the FULL ln a0 gradient (Φ ∝ a0
        identically); compare against autodiff through chol_inv_factor."""
        u_tri = jnp.triu(self.u)
        sig_mu = u_tri.T @ u_tri + jnp.outer(self.mu, self.mu)
        t = (-self.y * (self.phi @ self.mu)
             + jnp.sum((self.phi @ sig_mu) * self.phi, axis=-1)
             + jnp.exp(2 * self.la0) - jnp.sum(self.phi ** 2, axis=-1))
        want = self.beta * jnp.sum(t)
        full = jax.grad(ref.objective_ref, argnums=3)(
            self.mu, self.u, self.z, self.la0, self.leta, self.ls,
            self.x, self.y, self.mask)
        np.testing.assert_allclose(float(full), float(want), rtol=2e-3)

    def test_value_is_masked_sum(self):
        want = ref.objective_ref(self.mu, self.u, self.z, self.la0,
                                 self.leta, self.ls, self.x, self.y,
                                 self.mask)
        np.testing.assert_allclose(float(self.grads[0]), float(want),
                                   rtol=1e-5)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 1000))
    def test_grad_fn_matches_split_ref_autodiff(self, seed):
        """grad_fn (Pallas custom-VJP path) vs autodiff of the pure-jnp
        oracle with the same chol_l leaf."""
        mu, u, z, la0, leta, ls, x, y = make_state(seed, 128, 12, 4)
        mask = jnp.ones((128,))
        chol_l = ref.chol_inv_factor(z, la0, leta)

        def ref_split(mu, u, z, chol_l, la0, leta, ls):
            u_tri = jnp.triu(u)
            _, phi, kt = ref.fused_phi_ref(x, z, chol_l, la0, leta)
            beta = jnp.exp(-2.0 * ls)
            e = phi @ mu - y
            phi_u = phi @ u_tri.T
            quad = jnp.sum(phi_u * phi_u, axis=-1)
            g = (0.5 * jnp.log(2.0 * jnp.pi) + ls
                 + 0.5 * beta * (e * e + quad + kt))
            return jnp.sum(mask * g)

        got = model.grad_fn(mu, u, z, chol_l, la0, leta, ls, x, y, mask)
        want = jax.grad(ref_split, argnums=(0, 1, 2, 3, 4, 5, 6))(
            mu, u, z, chol_l, la0, leta, ls)
        expect = (want[0], jnp.triu(want[1]), want[2], jnp.tril(want[3]),
                  want[4], want[5], want[6])
        for g, w in zip(got[1:], expect):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=5e-4, atol=5e-4)


class TestBoundProperties:
    def test_elbo_below_exact_evidence(self):
        """eq. 10: L <= log p(y) for any feature map with K-PhiPhi^T PSD."""
        for seed in range(3):
            mu, u, z, la0, leta, ls, x, y = make_state(
                seed, 64, 10, 3, y_from_gp=True)
            mask = jnp.ones((64,))
            # Optimal-ish q(w): a few natural-gradient style updates
            # aren't needed — the bound holds for ANY q.
            g = ref.objective_ref(mu, u, z, la0, leta, ls, x, y, mask)
            elbo = -(float(g) + float(ref.kl_term(mu, u)))
            exact = float(ref.exact_log_evidence(x, y, la0, leta, ls))
            assert elbo <= exact + 1e-3, (elbo, exact)

    def test_bound_tightens_with_optimal_q(self):
        """With q(w) set to the closed-form optimum the bound must beat
        the mu=0,U=I initialization."""
        mu0, u0, z, la0, leta, ls, x, y = make_state(
            11, 64, 16, 3, y_from_gp=True)
        mask = jnp.ones((64,))
        chol_l = ref.chol_inv_factor(z, la0, leta)
        _, phi, _ = ref.fused_phi_ref(x, z, chol_l, la0, leta)
        beta = float(jnp.exp(-2 * ls))
        m = 16
        # Optimal q(w): Sigma* = (I + beta Phi^T Phi)^-1, mu* = beta Sigma* Phi^T y
        prec = jnp.eye(m) + beta * phi.T @ phi
        sigma = jnp.linalg.inv(prec)
        mu_star = beta * sigma @ (phi.T @ y)
        u_star = jnp.linalg.cholesky(sigma).T  # upper
        def elbo(mu, u):
            g = ref.objective_ref(mu, u, z, la0, leta, ls, x, y, mask)
            return -(float(g) + float(ref.kl_term(mu, u)))
        init = elbo(jnp.zeros((m,)), jnp.eye(m))
        opt = elbo(mu_star, u_star)
        exact = float(ref.exact_log_evidence(x, y, la0, leta, ls))
        assert init <= opt + 1e-3
        assert opt <= exact + 1e-3

    def test_m_equals_n_recovers_titsias_tight_bound(self):
        """With Z = X (m = n) the augmentation is exact up to jitter:
        ktilde -> 0 and the optimal-q ELBO approaches log p(y)."""
        mu, u, z, la0, leta, ls, x, y = make_state(13, 64, 10, 3,
                                                   y_from_gp=True)
        chol_l = ref.chol_inv_factor(x, la0, leta, jitter=1e-6)
        _, phi, kt = ref.fused_phi_ref(x, x, chol_l, la0, leta)
        assert float(jnp.max(jnp.abs(kt))) < 1e-2
        beta = float(jnp.exp(-2 * ls))
        n = 64
        prec = jnp.eye(n) + beta * phi.T @ phi
        sigma = jnp.linalg.inv(prec)
        mu_star = beta * sigma @ (phi.T @ y)
        u_star = jnp.linalg.cholesky(sigma + 1e-8 * jnp.eye(n)).T
        mask = jnp.ones((n,))
        g = ref.objective_ref(mu_star, u_star, x, la0, leta, ls, x, y, mask,
                              jitter=1e-6)
        elbo = -(float(g) + float(ref.kl_term(mu_star, u_star)))
        exact = float(ref.exact_log_evidence(x, y, la0, leta, ls))
        assert abs(elbo - exact) < 0.05 * abs(exact) + 0.5


class TestPredict:
    def test_variance_positive_and_reverts_to_prior(self):
        mu, u, z, la0, leta, ls, x, _ = make_state(21, 128, 10, 4)
        chol_l = ref.chol_inv_factor(z, la0, leta)
        far = x + 100.0  # far from all inducing points
        mean, var = model.predict_fn(mu, u, z, chol_l, la0, leta, ls, far)
        prior_var = float(jnp.exp(2 * la0) + jnp.exp(2 * ls))
        assert float(jnp.min(var)) > 0
        np.testing.assert_allclose(np.asarray(mean), 0.0, atol=1e-3)
        np.testing.assert_allclose(np.asarray(var), prior_var, rtol=1e-3)

    def test_matches_ref(self):
        mu, u, z, la0, leta, ls, x, _ = make_state(22, 256, 30, 6)
        chol_l = ref.chol_inv_factor(z, la0, leta)
        got = model.predict_fn(mu, u, z, chol_l, la0, leta, ls, x)
        want = ref.predict_ref(mu, u, z, la0, leta, ls, x)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-4, atol=1e-5)

    def test_elbo_fn_outputs(self):
        mu, u, z, la0, leta, ls, x, y = make_state(23, 128, 10, 4)
        chol_l = ref.chol_inv_factor(z, la0, leta)
        mask = jnp.ones((128,))
        g, sse = model.elbo_fn(mu, u, z, chol_l, la0, leta, ls, x, y, mask)
        mean, _ = model.predict_fn(mu, u, z, chol_l, la0, leta, ls, x)
        np.testing.assert_allclose(
            float(sse), float(jnp.sum((mean - y) ** 2)), rtol=1e-4)
        want = ref.objective_ref(mu, u, z, la0, leta, ls, x, y, mask)
        np.testing.assert_allclose(float(g), float(want), rtol=1e-5)


class TestKlTerm:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000), m=st.integers(1, 30))
    def test_against_dense_formula(self, seed, m):
        ks = jax.random.split(jax.random.PRNGKey(seed), 2)
        mu = jax.random.normal(ks[0], (m,))
        u = jnp.eye(m) * 0.7 + jnp.triu(jax.random.normal(ks[1], (m, m)) * 0.1)
        sigma = jnp.triu(u).T @ jnp.triu(u)
        sign, logdet = jnp.linalg.slogdet(sigma)
        want = 0.5 * (-logdet - m + jnp.trace(sigma) + mu @ mu)
        np.testing.assert_allclose(float(ref.kl_term(mu, u)), float(want),
                                   rtol=1e-4, atol=1e-4)

    def test_kl_nonnegative_zero_at_prior(self):
        m = 12
        assert abs(float(ref.kl_term(jnp.zeros((m,)), jnp.eye(m)))) < 1e-6
        for seed in range(5):
            ks = jax.random.split(jax.random.PRNGKey(seed), 2)
            mu = jax.random.normal(ks[0], (m,))
            u = jnp.eye(m) + jnp.triu(jax.random.normal(ks[1], (m, m)) * 0.2)
            assert float(ref.kl_term(mu, u)) >= -1e-5
