"""L1 correctness: Pallas fused kernel vs the pure-jnp oracle.

This is the core correctness signal for the compute layer: the kernel
that ends up inside every AOT artifact must agree with ``ref.py`` in
values AND in gradients (through the hand-written custom VJP).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.ard_phi import fused_phi, DEFAULT_BLOCK_B


def make_problem(seed, b, m, d, scale=1.0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (b, d)) * scale
    z = jax.random.normal(ks[1], (m, d)) * 0.8 * scale
    log_a0 = jnp.asarray(float(jax.random.normal(ks[2], ()) * 0.3))
    log_eta = jax.random.normal(ks[3], (d,)) * 0.3
    chol_l = ref.chol_inv_factor(z, log_a0, log_eta)
    return x, z, chol_l, log_a0, log_eta


class TestForwardAgainstRef:
    @pytest.mark.parametrize("b,m,d,block",
                             [(128, 20, 5, 64), (256, 50, 8, 128),
                              (128, 100, 9, 128), (384, 7, 3, 128),
                              (128, 1, 1, 64), (512, 200, 8, 128)])
    def test_matches_ref(self, b, m, d, block):
        x, z, chol_l, la0, leta = make_problem(b * 7 + m, b, m, d)
        got = fused_phi(x, z, chol_l, la0, leta, block)
        want = ref.fused_phi_ref(x, z, chol_l, la0, leta)
        for g, w, name in zip(got, want, ("K_bm", "Phi", "ktilde")):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=2e-5, atol=2e-5, err_msg=name)

    def test_single_tile_grid(self):
        """B == block_b -> grid of 1."""
        x, z, chol_l, la0, leta = make_problem(3, 128, 10, 4)
        got = fused_phi(x, z, chol_l, la0, leta, 128)
        want = ref.fused_phi_ref(x, z, chol_l, la0, leta)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=2e-5, atol=2e-5)

    def test_indivisible_batch_rejected(self):
        x, z, chol_l, la0, leta = make_problem(0, 100, 5, 3)
        with pytest.raises(ValueError, match="not divisible"):
            fused_phi(x, z, chol_l, la0, leta, 64)

    def test_ktilde_nonnegative(self):
        """k~_ii = diag(K_nn - Phi Phi^T) >= 0 (Schur complement, §3)."""
        for seed in range(5):
            x, z, chol_l, la0, leta = make_problem(seed, 256, 30, 6)
            _, _, kt = fused_phi(x, z, chol_l, la0, leta, 128)
            assert float(jnp.min(kt)) > -1e-4 * float(jnp.exp(2 * la0))

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000),
           m=st.integers(1, 64),
           d=st.integers(1, 12),
           tiles=st.integers(1, 4),
           scale=st.floats(0.2, 3.0))
    def test_hypothesis_shape_sweep(self, seed, m, d, tiles, scale):
        """Property: Pallas == oracle over random shapes & input scales."""
        b = 64 * tiles
        x, z, chol_l, la0, leta = make_problem(seed, b, m, d, scale)
        got = fused_phi(x, z, chol_l, la0, leta, 64)
        want = ref.fused_phi_ref(x, z, chol_l, la0, leta)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=5e-5, atol=5e-5)


class TestCustomVjp:
    @staticmethod
    def scalar_of(kernel_fn):
        def s(x, z, chol_l, la0, leta):
            k, phi, kt = kernel_fn(x, z, chol_l, la0, leta)
            # Mix all three outputs so every cotangent path is exercised.
            return (jnp.sum(jnp.sin(k)) + jnp.sum(phi ** 2)
                    + jnp.sum(kt * 1.7) + jnp.sum(k * phi))
        return s

    @pytest.mark.parametrize("b,m,d", [(128, 20, 5), (256, 50, 8),
                                       (128, 3, 2), (128, 64, 9)])
    def test_vjp_matches_autodiff(self, b, m, d):
        x, z, chol_l, la0, leta = make_problem(b + m + d, b, m, d)
        s_pallas = self.scalar_of(
            lambda *a: fused_phi(*a, DEFAULT_BLOCK_B))
        s_ref = self.scalar_of(ref.fused_phi_ref)
        gp = jax.grad(s_pallas, argnums=(0, 1, 2, 3, 4))(
            x, z, chol_l, la0, leta)
        gr = jax.grad(s_ref, argnums=(0, 1, 2, 3, 4))(
            x, z, chol_l, la0, leta)
        for a, b_, name in zip(gp, gr, ("dx", "dz", "dL", "dla0", "dleta")):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=2e-4, atol=2e-4, err_msg=name)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), m=st.integers(2, 40),
           d=st.integers(1, 10))
    def test_vjp_hypothesis_sweep(self, seed, m, d):
        x, z, chol_l, la0, leta = make_problem(seed, 128, m, d)
        s_pallas = self.scalar_of(lambda *a: fused_phi(*a, 64))
        s_ref = self.scalar_of(ref.fused_phi_ref)
        gp = jax.grad(s_pallas, argnums=(1, 2, 3, 4))(x, z, chol_l, la0, leta)
        gr = jax.grad(s_ref, argnums=(1, 2, 3, 4))(x, z, chol_l, la0, leta)
        for a, b_ in zip(gp, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=5e-4, atol=5e-4)

    def test_vjp_finite_difference_spotcheck(self):
        """Independent of jax autodiff: central finite differences."""
        x, z, chol_l, la0, leta = make_problem(42, 64, 8, 3)
        s = self.scalar_of(lambda *a: fused_phi(*a, 64))
        g_la0 = float(jax.grad(s, argnums=3)(x, z, chol_l, la0, leta))
        eps = 1e-3
        fd = (float(s(x, z, chol_l, la0 + eps, leta))
              - float(s(x, z, chol_l, la0 - eps, leta))) / (2 * eps)
        assert abs(g_la0 - fd) < 1e-2 * max(1.0, abs(fd))
