"""AOT path: HLO-text emission and manifest consistency."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


class TestLowering:
    def test_grad_artifact_signature(self):
        text = aot.lower_one("grad", 16, 4, 128)
        assert text.startswith("HloModule")
        # 10 inputs (incl. chol_l), 8 tuple outputs (value + 7 grads).
        assert "f32[16,16]" in text          # u / chol_l / du / dchol_l
        assert "f32[128,4]" in text          # x batch
        assert "entry_computation_layout" in text

    def test_no_ffi_custom_calls(self):
        """The deployment XLA (0.5.1) cannot run typed-FFI custom-calls;
        the artifacts must not contain any (jnp.linalg is banned from
        lowered code — the split-Cholesky ABI exists for this)."""
        for kind in ("grad", "predict", "elbo"):
            text = aot.lower_one(kind, 16, 4, 128)
            assert "custom-call" not in text, f"{kind} has custom-call"
            assert "API_VERSION_TYPED_FFI" not in text

    def test_predict_artifact_signature(self):
        text = aot.lower_one("predict", 16, 4, 128)
        assert "f32[128,4]" in text and "f32[128]" in text

    def test_elbo_artifact_signature(self):
        text = aot.lower_one("elbo", 16, 4, 128)
        assert "f32[128]" in text

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError):
            aot.lower_one("nope", 16, 4, 128)

    def test_hlo_text_has_no_64bit_id_issue(self):
        """The interchange constraint: we must emit text, and the text
        must parse as an HloModule header (the Rust side re-parses it)."""
        text = aot.lower_one("predict", 8, 4, 128)
        assert text.splitlines()[0].startswith("HloModule")
        assert ".serialize" not in text


class TestManifest:
    def test_main_writes_manifest_and_files(self, monkeypatch):
        with tempfile.TemporaryDirectory() as td:
            monkeypatch.setattr(
                "sys.argv", ["aot", "--out", td, "--configs", "8:4"])
            aot.main()
            with open(os.path.join(td, "manifest.json")) as f:
                man = json.load(f)
            assert man["version"] == 1
            assert len(man["artifacts"]) == 3
            kinds = {a["kind"] for a in man["artifacts"]}
            assert kinds == {"grad", "predict", "elbo"}
            for a in man["artifacts"]:
                p = os.path.join(td, a["file"])
                assert os.path.exists(p) and os.path.getsize(p) > 1000
                assert a["m"] == 8 and a["d"] == 4
                assert a["b"] % a["block_b"] == 0


class TestLoweredNumerics:
    """Execute the lowered HLO via jax itself (CPU) and compare with the
    eager functions — catches lowering-order bugs in the positional ABI."""

    def test_grad_roundtrip_numerics(self):
        from compile.kernels import ref as kref
        m, d, b = 16, 4, 128
        params = model.init_params(m, d)
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        x = jax.random.normal(ks[0], (b, d))
        y = jax.random.normal(ks[1], (b,))
        mask = jnp.ones((b,))
        chol_l = kref.chol_inv_factor(params["z"], params["log_a0"],
                                      params["log_eta"])
        args = (params["mu"], params["u"], params["z"], chol_l,
                params["log_a0"], params["log_eta"], params["log_sigma"],
                x, y, mask)
        eager = model.grad_fn(*args)
        compiled = jax.jit(model.grad_fn).lower(*args).compile()(*args)
        for e, c in zip(eager, compiled):
            np.testing.assert_allclose(np.asarray(e), np.asarray(c),
                                       rtol=1e-5, atol=1e-6)
