//! Reproduces **Figure 1**: RMSE as a function of training time for
//! ADVGP, DistGP-GD, DistGP-LBFGS and SVIGP (m ∈ {100, 200} panels).
//!
//! Emits one CSV trace per (method, m) under target/bench_out/fig1/ and
//! prints RMSE at 25/50/75/100% of the budget.  The paper's claims to
//! reproduce: ADVGP reduces RMSE fastest; SVIGP tracks it early then
//! lags; DistGP-LBFGS converges early but to a worse point.

use advgp::experiments::methods::*;
use advgp::experiments::{flight_problem, out_dir, print_table, Scale};
use advgp::ps::metrics::write_trace_csv;

fn rmse_at_fraction(r: &advgp::baselines::BaselineResult, frac: f64, budget: f64) -> f64 {
    let cutoff = frac * budget;
    r.trace
        .iter()
        .filter(|t| t.t_secs <= cutoff)
        .map(|t| t.rmse)
        .fold(f64::INFINITY, f64::min)
}

fn main() {
    let scale = Scale::from_env();
    let n_train = scale.pick(4_000, 40_000, 700_000);
    let n_test = scale.pick(800, 8_000, 100_000);
    let ms: Vec<usize> = scale.pick(vec![25], vec![100, 200], vec![100, 200]);
    let budget = scale.pick(2.0, 15.0, 600.0);
    let dir = out_dir().join("fig1");

    for &m in &ms {
        let p = flight_problem(n_train, n_test, m, 7);
        let y_std = p.standardizer.y_std;
        let opts = MethodOpts { budget_secs: budget, tau: 32, ..Default::default() };
        let sync = MethodOpts { budget_secs: budget, tau: 0, ..Default::default() };
        let runs = vec![
            ("advgp", run_advgp(&p, &opts)),
            ("distgp_gd", run_distgp_gd_method(&p, &sync)),
            ("distgp_lbfgs", run_distgp_lbfgs_method(&p, &sync)),
            ("svigp", run_svigp_method(&p, &opts)),
        ];
        let mut rows = Vec::new();
        for (name, r) in &runs {
            write_trace_csv(&dir.join(format!("{name}_m{m}.csv")), &r.trace).unwrap();
            rows.push(vec![
                name.to_string(),
                format!("{:.4}", rmse_at_fraction(r, 0.25, budget) * y_std),
                format!("{:.4}", rmse_at_fraction(r, 0.50, budget) * y_std),
                format!("{:.4}", rmse_at_fraction(r, 0.75, budget) * y_std),
                format!("{:.4}", final_rmse(r) * y_std),
            ]);
        }
        print_table(
            &format!("Fig.1 panel m={m}: RMSE at fraction of {budget:.0}s budget"),
            &["Method", "25%", "50%", "75%", "100%"],
            &rows,
        );
    }
    println!("\ntraces in {}", dir.display());
}
