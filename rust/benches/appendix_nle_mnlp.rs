//! Reproduces **Appendix C** (Tables/Figs C.1–C.2: negative log
//! evidence, i.e. −ELBO) and **Appendix D** (Tables/Figs D.1–D.2: mean
//! negative log predictive likelihood) on the flight workload for
//! m ∈ {100, 200}.
//!
//! Claims to reproduce: ADVGP attains the lowest (best) −ELBO; MNLPs of
//! ADVGP / DistGP-GD are close with DistGP-LBFGS worst.

use advgp::experiments::methods::*;
use advgp::experiments::{flight_problem, out_dir, print_table, Scale};
use advgp::ps::metrics::write_trace_csv;

fn main() {
    let scale = Scale::from_env();
    let sizes = [
        ("C.1/D.1 (700K-equivalent)", scale.pick(3_000, 40_000, 700_000)),
        ("C.2/D.2 (2M-equivalent)", scale.pick(6_000, 120_000, 2_000_000)),
    ];
    let n_test = scale.pick(600, 8_000, 100_000);
    let ms: Vec<usize> = scale.pick(vec![25], vec![100, 200], vec![100, 200]);
    let budget = scale.pick(2.0, 12.0, 600.0);
    let dir = out_dir().join("appendix");
    let mut all = String::new();

    for (label, n_train) in sizes {
        let mut nle_rows: Vec<Vec<String>> = vec![
            vec!["ADVGP".into()],
            vec!["DistGP-GD".into()],
            vec!["DistGP-LBFGS".into()],
        ];
        let mut mnlp_rows: Vec<Vec<String>> = vec![
            vec!["ADVGP".into()],
            vec!["DistGP-GD".into()],
            vec!["DistGP-LBFGS".into()],
            vec!["SVIGP".into()],
        ];
        for &m in &ms {
            let p = flight_problem(n_train, n_test, m, 29);
            let opts = MethodOpts {
                budget_secs: budget,
                tau: 32,
                track_elbo: true,
                ..Default::default()
            };
            let sync = MethodOpts { budget_secs: budget, tau: 0, ..Default::default() };
            let advgp = run_advgp(&p, &opts);
            let gd = run_distgp_gd_method(&p, &sync);
            let lbfgs = run_distgp_lbfgs_method(&p, &sync);
            let svi = run_svigp_method(&p, &opts);
            for (name, r) in [("advgp", &advgp), ("gd", &gd), ("lbfgs", &lbfgs)] {
                write_trace_csv(
                    &dir.join(format!("{name}_m{m}_n{n_train}.csv")),
                    &r.trace,
                )
                .unwrap();
            }
            // −ELBO (ADVGP trace carries it over a probe subset; the
            // sync methods carry the full objective).
            for (row, r) in nle_rows.iter_mut().zip([&advgp, &gd, &lbfgs]) {
                row.push(match final_neg_elbo(r) {
                    Some(v) => format!("{v:.1}"),
                    None => "-".into(),
                });
            }
            for (row, r) in mnlp_rows.iter_mut().zip([&advgp, &gd, &lbfgs, &svi]) {
                row.push(format!("{:.4}", final_mnlp(r)));
            }
        }
        let m_labels: Vec<String> = ms.iter().map(|m| format!("m = {m}")).collect();
        let mut header = vec!["Method"];
        header.extend(m_labels.iter().map(|s| s.as_str()));
        all.push_str(&print_table(
            &format!("Appendix C — negative log evidence proxy (−ELBO), {label}"),
            &header,
            &nle_rows,
        ));
        all.push_str(&print_table(
            &format!("Appendix D — MNLP, {label}"),
            &header,
            &mnlp_rows,
        ));
    }
    std::fs::write(out_dir().join("appendix_nle_mnlp.md"), all).unwrap();
    println!("\ntraces in {}", dir.display());
}
