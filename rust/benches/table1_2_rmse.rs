//! Reproduces **Table 1** (700K flight) and **Table 2** (2M flight):
//! RMSE for m ∈ {50, 100, 200} across ADVGP (Prox GP), DistGP-GD,
//! DistGP-LBFGS and SVIGP, each given the same wall-clock budget.
//!
//! Scale via ADVGP_BENCH_SCALE = ci | small (default) | paper.
//! The paper's claim to reproduce: ADVGP's RMSE is comparable or better
//! in every column, and RMSE decreases with m for the prox methods.

use advgp::experiments::methods::*;
use advgp::experiments::{flight_problem, out_dir, print_table, Scale};

fn main() {
    let scale = Scale::from_env();
    let sizes: Vec<(&str, usize, usize)> = vec![
        ("table1-700K-equivalent", scale.pick(4_000, 40_000, 700_000),
         scale.pick(800, 8_000, 100_000)),
        ("table2-2M-equivalent", scale.pick(8_000, 120_000, 2_000_000),
         scale.pick(800, 8_000, 100_000)),
    ];
    let ms: Vec<usize> = scale.pick(vec![25], vec![50, 100, 200], vec![50, 100, 200]);
    let budget = scale.pick(2.0, 12.0, 600.0);

    let mut all = String::new();
    for (label, n_train, n_test) in sizes {
        let mut rows: Vec<Vec<String>> = vec![
            vec!["ADVGP (Prox GP)".into()],
            vec!["DistGP-GD".into()],
            vec!["DistGP-LBFGS".into()],
            vec!["SVIGP".into()],
        ];
        for &m in &ms {
            let p = flight_problem(n_train, n_test, m, 42);
            let y_std = p.standardizer.y_std;
            let opts = MethodOpts { budget_secs: budget, tau: 32, ..Default::default() };
            let sync = MethodOpts { budget_secs: budget, tau: 0, ..Default::default() };
            let advgp = run_advgp(&p, &opts);
            let gd = run_distgp_gd_method(&p, &sync);
            let lbfgs = run_distgp_lbfgs_method(&p, &sync);
            let svi = run_svigp_method(&p, &opts);
            // Report in original target units (delay minutes), like the paper.
            for (row, r) in rows.iter_mut().zip([&advgp, &gd, &lbfgs, &svi]) {
                row.push(format!("{:.4}", final_rmse(r) * y_std));
            }
        }
        let mut header = vec!["Method"];
        let m_labels: Vec<String> = ms.iter().map(|m| format!("m = {m}")).collect();
        header.extend(m_labels.iter().map(|s| s.as_str()));
        all.push_str(&print_table(
            &format!("{label} (n_train per scale, budget {budget:.0}s/cell)"),
            &header,
            &rows,
        ));
    }
    std::fs::write(out_dir().join("table1_2_rmse.md"), all).unwrap();
    println!("\nwrote {}", out_dir().join("table1_2_rmse.md").display());
}
