//! §Perf micro-benchmarks over the hot paths of all three layers'
//! Rust-side counterparts:
//!
//! * gradient engines (native vs XLA/Pallas artifact) per 1024-row block
//! * the fused feature-map forward (K_bm, Φ, ktilde)
//! * the server update (ADADELTA + prox), serial vs element-wise sharded
//! * K_mm factorization chain (chol + inverse + L⁻¹)
//! * k-means init, prediction path
//!
//! Prints the human-readable table AND dumps machine-readable results
//! to `BENCH_hotpath.json` (bench name → ns/iter plus the pool/thread
//! configuration), so the perf trajectory is tracked across PRs.
//! Thread count follows `ADVGP_THREADS` (default: all cores); rerun
//! with `ADVGP_THREADS=1` for the serial baseline.

use advgp::data::synth;
use advgp::experiments::harness::{bench, BenchReport};
use advgp::gp::featuremap::{FeatureMap, InducingChol, PhiBatch, PhiWorkspace};
use advgp::gp::{SparseGp, Theta, ThetaLayout};
use advgp::grad::chain::LChain;
use advgp::grad::{native::NativeEngine, GradEngine};
use advgp::opt::AdaDelta;
use advgp::ps::server::apply_update;
use advgp::runtime::{Manifest, XlaEngine};
use advgp::util::json::Json;
use advgp::util::pool;
use advgp::util::rng::Pcg64;

const OUT_PATH: &str = "BENCH_hotpath.json";

fn main() {
    let (m, d, b) = (100usize, 8usize, 1024usize);
    let layout = ThetaLayout::new(m, d);
    let ds = synth::flight_like(b, 3);
    let mut rng = Pcg64::seeded(5);
    let z = advgp::data::kmeans::kmeans(&ds.x, m, 10, &mut rng);
    let theta = Theta::init(layout, &z);
    let threads = pool::threads();
    println!("hot-path microbenches: m={m} d={d} block={b} threads={threads}\n");
    let mut reports: Vec<BenchReport> = Vec::new();

    // L3-side forward: fused feature map (the Pallas kernel's Rust twin),
    // workspace-reusing path (zero allocation in steady state).
    let map = InducingChol::build(&theta.ard(), theta.z_mat());
    let mut ws = PhiWorkspace::new();
    let mut pb = PhiBatch::empty();
    reports.push(bench("phi_forward (K_bm+Phi+ktilde, 1024x100)", 3, 1.0, || {
        map.phi_into(&theta.ard(), &ds.x, &mut ws, &mut pb);
        std::hint::black_box(pb.ktilde.len());
    }));

    // Native gradient engine per block.
    let mut nat = NativeEngine::new(layout);
    reports.push(bench("native_grad (1024 rows)", 2, 1.5, || {
        let r = nat.grad(&theta.data, &ds.x, &ds.y);
        std::hint::black_box(r.value);
    }));

    // XLA (JAX+Pallas artifact) engine per block, if artifacts exist.
    let man_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&man_dir).and_then(|man| XlaEngine::from_manifest(&man, m, d)) {
        Ok(mut xla) => {
            reports.push(bench("xla_grad (1024 rows, m=100 d=8 artifact)", 2, 1.5, || {
                let r = xla.grad(&theta.data, &ds.x, &ds.y);
                std::hint::black_box(r.value);
            }));
        }
        Err(e) => println!("(skipping xla_grad: {e:#})"),
    }

    // K_mm factorization chain (once per θ per worker iteration).
    reports.push(bench("lchain_build (chol+inv+Linv, m=100)", 3, 1.0, || {
        let c = LChain::build(theta.ard(), theta.z_mat());
        std::hint::black_box(c.chol_l.data.len());
    }));

    // Server update: ADADELTA + prox, serial vs sharded.
    let dim = layout.len();
    let grad: Vec<f64> = (0..dim).map(|i| (i as f64 * 0.37).sin()).collect();
    for shards in [1usize, 2, 4, 8] {
        let mut th = theta.data.clone();
        let mut ada = AdaDelta::default_for(dim);
        reports.push(bench(
            &format!("server_update dim={dim} shards={shards}"),
            3,
            0.5,
            || {
                apply_update(&layout, &mut th, &mut ada, &grad, 0.5, 0.1, shards);
                std::hint::black_box(th[0]);
            },
        ));
    }

    // Prediction path (evaluator cadence driver).
    let gp = SparseGp::new(theta.clone());
    reports.push(bench("predict (1024 rows)", 3, 1.0, || {
        let (mean, _var) = gp.predict(&ds.x);
        std::hint::black_box(mean.len());
    }));

    // k-means init (run once per experiment).
    let big = synth::flight_like(20_000, 9);
    reports.push(bench("kmeans m=100 on 20K rows (5 iters)", 1, 2.0, || {
        let mut r = Pcg64::seeded(11);
        let c = advgp::data::kmeans::kmeans(&big.x, m, 5, &mut r);
        std::hint::black_box(c.data.len());
    }));

    write_json(&reports, threads, m, d, b);
    println!("\nwrote {} ({} benches, threads={threads})", OUT_PATH, reports.len());
}

/// Dump `BENCH_hotpath.json`: schema versioned, one entry per bench
/// with ns/iter stats plus the configuration that produced them.
fn write_json(reports: &[BenchReport], threads: usize, m: usize, d: usize, b: usize) {
    let benches: Vec<Json> = reports
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::Str(r.name.clone())),
                ("mean_ns", Json::Num(r.stats.mean() * 1e9)),
                ("std_ns", Json::Num(r.stats.std() * 1e9)),
                ("min_ns", Json::Num(r.stats.min * 1e9)),
                ("iters", Json::Num(r.iters as f64)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("schema", Json::Num(1.0)),
        ("bench", Json::Str("perf_hotpath".into())),
        ("threads", Json::Num(threads as f64)),
        ("m", Json::Num(m as f64)),
        ("d", Json::Num(d as f64)),
        ("block", Json::Num(b as f64)),
        (
            "par_min_flops",
            Json::Num(advgp::linalg::par_min_flops() as f64),
        ),
        ("benches", Json::Arr(benches)),
    ]);
    if let Err(e) = std::fs::write(OUT_PATH, format!("{doc}\n")) {
        eprintln!("failed to write {OUT_PATH}: {e}");
    }
}
