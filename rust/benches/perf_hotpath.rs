//! §Perf micro-benchmarks over the hot paths of all three layers'
//! Rust-side counterparts:
//!
//! * gradient engines (native vs XLA/Pallas artifact) per 1024-row block
//! * the fused feature-map forward (K_bm, Φ, ktilde)
//! * the server update (ADADELTA + prox), serial vs element-wise sharded
//! * K_mm factorization chain (chol + inverse + L⁻¹)
//! * k-means init, prediction path
//!
//! The compute-bound benches (phi_forward, native_grad, predict) run
//! once per [`ComputeBackend`] — scalar vs simd (ISSUE 10) — so the
//! JSON carries a measured rows/sec per backend and
//! `scripts/bench_diff.py` tracks each (bench, backend) series
//! independently.  Prints the human-readable table AND dumps
//! machine-readable results to `BENCH_hotpath.json` (bench name →
//! ns/iter plus the pool/thread configuration), so the perf trajectory
//! is tracked across PRs.  Thread count follows `ADVGP_THREADS`
//! (default: all cores); rerun with `ADVGP_THREADS=1` for the serial
//! baseline.

use advgp::data::synth;
use advgp::experiments::harness::{bench, BenchReport};
use advgp::gp::featuremap::{FeatureMap, InducingChol, PhiBatch, PhiWorkspace};
use advgp::gp::{SparseGp, Theta, ThetaLayout};
use advgp::grad::chain::LChain;
use advgp::grad::{native::NativeEngine, GradEngine};
use advgp::linalg::simd;
use advgp::opt::AdaDelta;
use advgp::ps::server::apply_update;
use advgp::runtime::{Backend, ComputeBackend, Manifest, XlaEngine};
use advgp::util::json::Json;
use advgp::util::pool;
use advgp::util::rng::Pcg64;

const OUT_PATH: &str = "BENCH_hotpath.json";

struct Entry {
    report: BenchReport,
    /// Backend name for the per-backend benches; `None` for the
    /// backend-independent ones (factorization, server update, …).
    backend: Option<&'static str>,
    /// Rows processed per second, where the bench has a natural row
    /// count (the 1024-row block benches).
    rows_per_sec: Option<f64>,
}

impl Entry {
    fn plain(report: BenchReport) -> Self {
        Self { report, backend: None, rows_per_sec: None }
    }
}

/// The backend dimension for the compute-bound benches: the explicit
/// selectors, constructed via `with_backend` so each row is
/// self-contained (no process-global state involved).
fn backends() -> Vec<(&'static str, &'static dyn ComputeBackend)> {
    vec![
        ("scalar", Backend::Scalar.resolve().expect("scalar resolves")),
        ("simd", Backend::Simd.resolve().expect("simd resolves")),
    ]
}

fn main() {
    let (m, d, b) = (100usize, 8usize, 1024usize);
    let layout = ThetaLayout::new(m, d);
    let ds = synth::flight_like(b, 3);
    let mut rng = Pcg64::seeded(5);
    let z = advgp::data::kmeans::kmeans(&ds.x, m, 10, &mut rng);
    let theta = Theta::init(layout, &z);
    let threads = pool::threads();
    println!(
        "hot-path microbenches: m={m} d={d} block={b} threads={threads} \
         simd path={}\n",
        simd::active_path()
    );
    let mut entries: Vec<Entry> = Vec::new();

    // L3-side forward: fused feature map (the Pallas kernel's Rust twin),
    // workspace-reusing path (zero allocation in steady state), once per
    // backend.
    let map = InducingChol::build(&theta.ard(), theta.z_mat());
    for (bname, be) in backends() {
        let mut ws = PhiWorkspace::new();
        let mut pb = PhiBatch::empty();
        let report = bench(
            &format!("phi_forward (K_bm+Phi+ktilde, 1024x100) [{bname}]"),
            3,
            1.0,
            || {
                map.phi_into_be(be, &theta.ard(), &ds.x, &mut ws, &mut pb);
                std::hint::black_box(pb.ktilde.len());
            },
        );
        let rows_per_sec = b as f64 / report.stats.mean().max(1e-12);
        entries.push(Entry { report, backend: Some(bname), rows_per_sec: Some(rows_per_sec) });
    }

    // Native gradient engine per block, once per backend.
    for (bname, be) in backends() {
        let mut nat = NativeEngine::with_backend(layout, be);
        let report = bench(&format!("native_grad (1024 rows) [{bname}]"), 2, 1.5, || {
            let r = nat.grad(&theta.data, &ds.x, &ds.y);
            std::hint::black_box(r.value);
        });
        let rows_per_sec = b as f64 / report.stats.mean().max(1e-12);
        entries.push(Entry { report, backend: Some(bname), rows_per_sec: Some(rows_per_sec) });
    }

    // XLA (JAX+Pallas artifact) engine per block, if artifacts exist.
    let man_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&man_dir).and_then(|man| XlaEngine::from_manifest(&man, m, d)) {
        Ok(mut xla) => {
            entries.push(Entry::plain(bench(
                "xla_grad (1024 rows, m=100 d=8 artifact)",
                2,
                1.5,
                || {
                    let r = xla.grad(&theta.data, &ds.x, &ds.y);
                    std::hint::black_box(r.value);
                },
            )));
        }
        Err(e) => println!("(skipping xla_grad: {e:#})"),
    }

    // K_mm factorization chain (once per θ per worker iteration) —
    // stays scalar under every backend by design.
    entries.push(Entry::plain(bench(
        "lchain_build (chol+inv+Linv, m=100)",
        3,
        1.0,
        || {
            let c = LChain::build(theta.ard(), theta.z_mat());
            std::hint::black_box(c.chol_l.data.len());
        },
    )));

    // Server update: ADADELTA + prox, serial vs element-wise sharded.
    let dim = layout.len();
    let grad: Vec<f64> = (0..dim).map(|i| (i as f64 * 0.37).sin()).collect();
    for shards in [1usize, 2, 4, 8] {
        let mut th = theta.data.clone();
        let mut ada = AdaDelta::default_for(dim);
        entries.push(Entry::plain(bench(
            &format!("server_update dim={dim} shards={shards}"),
            3,
            0.5,
            || {
                apply_update(&layout, &mut th, &mut ada, &grad, 0.5, 0.1, shards);
                std::hint::black_box(th[0]);
            },
        )));
    }

    // Prediction path (evaluator cadence driver), once per backend.
    for (bname, be) in backends() {
        let gp = SparseGp::with_backend(theta.clone(), be);
        let report = bench(&format!("predict (1024 rows) [{bname}]"), 3, 1.0, || {
            let (mean, _var) = gp.predict(&ds.x);
            std::hint::black_box(mean.len());
        });
        let rows_per_sec = b as f64 / report.stats.mean().max(1e-12);
        entries.push(Entry { report, backend: Some(bname), rows_per_sec: Some(rows_per_sec) });
    }

    // k-means init (run once per experiment).
    let big = synth::flight_like(20_000, 9);
    entries.push(Entry::plain(bench("kmeans m=100 on 20K rows (5 iters)", 1, 2.0, || {
        let mut r = Pcg64::seeded(11);
        let c = advgp::data::kmeans::kmeans(&big.x, m, 5, &mut r);
        std::hint::black_box(c.data.len());
    })));

    write_json(&entries, threads, m, d, b);
    println!("\nwrote {} ({} benches, threads={threads})", OUT_PATH, entries.len());
}

/// Dump `BENCH_hotpath.json`: schema versioned (2 adds the per-entry
/// `backend` and `rows_per_sec` fields plus the dispatched `simd_path`),
/// one entry per bench with ns/iter stats plus the configuration that
/// produced them.
fn write_json(entries: &[Entry], threads: usize, m: usize, d: usize, b: usize) {
    let benches: Vec<Json> = entries
        .iter()
        .map(|e| {
            let mut fields = vec![
                ("name", Json::Str(e.report.name.clone())),
                ("mean_ns", Json::Num(e.report.stats.mean() * 1e9)),
                ("std_ns", Json::Num(e.report.stats.std() * 1e9)),
                ("min_ns", Json::Num(e.report.stats.min * 1e9)),
                ("iters", Json::Num(e.report.iters as f64)),
            ];
            if let Some(bname) = e.backend {
                fields.push(("backend", Json::Str(bname.into())));
            }
            if let Some(rps) = e.rows_per_sec {
                fields.push(("rows_per_sec", Json::Num(rps)));
            }
            Json::obj(fields)
        })
        .collect();
    let doc = Json::obj(vec![
        ("schema", Json::Num(2.0)),
        ("bench", Json::Str("perf_hotpath".into())),
        ("threads", Json::Num(threads as f64)),
        ("m", Json::Num(m as f64)),
        ("d", Json::Num(d as f64)),
        ("block", Json::Num(b as f64)),
        ("simd_path", Json::Str(simd::active_path().into())),
        (
            "par_min_flops",
            Json::Num(advgp::linalg::par_min_flops() as f64),
        ),
        ("benches", Json::Arr(benches)),
    ]);
    if let Err(e) = std::fs::write(OUT_PATH, format!("{doc}\n")) {
        eprintln!("failed to write {OUT_PATH}: {e}");
    }
}
