//! §Perf micro-benchmarks over the hot paths of all three layers'
//! Rust-side counterparts:
//!
//! * gradient engines (native vs XLA/Pallas artifact) per 1024-row block
//! * the fused feature-map forward (K_bm, Φ, ktilde)
//! * the server update (ADADELTA + prox), serial vs element-wise sharded
//! * K_mm factorization chain (chol + inverse + L⁻¹)
//! * k-means init, prediction path
//!
//! Used by the performance pass; results recorded in EXPERIMENTS.md §Perf.

use advgp::data::synth;
use advgp::experiments::harness::bench;
use advgp::gp::featuremap::{FeatureMap, InducingChol};
use advgp::gp::{SparseGp, Theta, ThetaLayout};
use advgp::grad::chain::LChain;
use advgp::grad::{native::NativeEngine, GradEngine};
use advgp::opt::AdaDelta;
use advgp::ps::server::apply_update;
use advgp::runtime::{Manifest, XlaEngine};
use advgp::util::rng::Pcg64;

fn main() {
    let (m, d, b) = (100usize, 8usize, 1024usize);
    let layout = ThetaLayout::new(m, d);
    let ds = synth::flight_like(b, 3);
    let mut rng = Pcg64::seeded(5);
    let z = advgp::data::kmeans::kmeans(&ds.x, m, 10, &mut rng);
    let theta = Theta::init(layout, &z);
    println!("hot-path microbenches: m={m} d={d} block={b}\n");

    // L3-side forward: fused feature map (the Pallas kernel's Rust twin).
    let map = InducingChol::build(&theta.ard(), theta.z_mat());
    bench("phi_forward (K_bm+Phi+ktilde, 1024x100)", 3, 1.0, || {
        let pb = map.phi(&theta.ard(), &ds.x);
        std::hint::black_box(pb.ktilde.len());
    });

    // Native gradient engine per block.
    let mut nat = NativeEngine::new(layout);
    bench("native_grad (1024 rows)", 2, 1.5, || {
        let r = nat.grad(&theta.data, &ds.x, &ds.y);
        std::hint::black_box(r.value);
    });

    // XLA (JAX+Pallas artifact) engine per block, if artifacts exist.
    let man_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&man_dir).and_then(|man| XlaEngine::from_manifest(&man, m, d)) {
        Ok(mut xla) => {
            bench("xla_grad (1024 rows, m=100 d=8 artifact)", 2, 1.5, || {
                let r = xla.grad(&theta.data, &ds.x, &ds.y);
                std::hint::black_box(r.value);
            });
        }
        Err(e) => println!("(skipping xla_grad: {e:#})"),
    }

    // K_mm factorization chain (once per θ per worker iteration).
    bench("lchain_build (chol+inv+Linv, m=100)", 3, 1.0, || {
        let c = LChain::build(theta.ard(), theta.z_mat());
        std::hint::black_box(c.chol_l.data.len());
    });

    // Server update: ADADELTA + prox, serial vs sharded.
    let dim = layout.len();
    let grad: Vec<f64> = (0..dim).map(|i| (i as f64 * 0.37).sin()).collect();
    for shards in [1usize, 2, 4, 8] {
        let mut th = theta.data.clone();
        let mut ada = AdaDelta::default_for(dim);
        bench(
            &format!("server_update dim={dim} shards={shards}"),
            3,
            0.5,
            || {
                apply_update(&layout, &mut th, &mut ada, &grad, 0.5, 0.1, shards);
                std::hint::black_box(th[0]);
            },
        );
    }

    // Prediction path (evaluator cadence driver).
    let gp = SparseGp::new(theta.clone());
    bench("predict (1024 rows)", 3, 1.0, || {
        let (mean, _var) = gp.predict(&ds.x);
        std::hint::black_box(mean.len());
    });

    // k-means init (run once per experiment).
    let big = synth::flight_like(20_000, 9);
    bench("kmeans m=100 on 20K rows (5 iters)", 1, 2.0, || {
        let mut r = Pcg64::seeded(11);
        let c = advgp::data::kmeans::kmeans(&big.x, m, 5, &mut r);
        std::hint::black_box(c.data.len());
    });
}
