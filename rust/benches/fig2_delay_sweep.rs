//! Reproduces **Figure 2**: RMSE-vs-time for delay limits
//! τ ∈ {0, 5, 10, 20, 40, 80, 160} with injected stragglers.
//!
//! The paper assigns workers random sleeps of 0/10/20 s per iteration;
//! we scale those to 0/10/20 ms (per-iteration compute is ~ms here, so
//! the *ratio* of sleep to compute matches the paper's regime).  Claims
//! to reproduce: τ=0 is far slower (sync barrier waits on the slowest
//! worker); moderate τ is best; very large τ degrades the optimization.

use advgp::experiments::methods::*;
use advgp::experiments::{flight_problem, out_dir, print_table, Scale};
use advgp::ps::metrics::write_trace_csv;

fn main() {
    let scale = Scale::from_env();
    let n_train = scale.pick(3_000, 24_000, 700_000);
    let n_test = scale.pick(600, 6_000, 100_000);
    let m = scale.pick(16, 50, 100);
    let budget = scale.pick(2.0, 10.0, 300.0);
    let taus: Vec<u64> = scale.pick(vec![0, 10, 160], vec![0, 5, 10, 20, 40, 80, 160],
                                    vec![0, 5, 10, 20, 40, 80, 160]);
    let dir = out_dir().join("fig2");

    let p = flight_problem(n_train, n_test, m, 13);
    let y_std = p.standardizer.y_std;
    let mut rows = Vec::new();
    for &tau in &taus {
        let opts = MethodOpts {
            budget_secs: budget,
            tau,
            workers: 6,
            straggle_ms: vec![0, 0, 10, 10, 20, 20], // paper's 0/10/20s scaled
            ..Default::default()
        };
        let r = run_advgp(&p, &opts);
        write_trace_csv(&dir.join(format!("tau{tau}.csv")), &r.trace).unwrap();
        let updates = r.trace.last().map(|t| t.version).unwrap_or(0);
        rows.push(vec![
            format!("τ = {tau}"),
            format!("{:.4}", final_rmse(&r) * y_std),
            format!("{updates}"),
        ]);
    }
    print_table(
        &format!("Fig.2: final RMSE per delay limit (budget {budget:.0}s, 6 workers w/ 0/10/20ms stragglers)"),
        &["Delay limit", "best RMSE", "server updates"],
        &rows,
    );
    println!("\ntraces in {}", dir.display());
}
