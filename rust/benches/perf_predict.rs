//! §Serving micro-benchmarks: the blocked prediction path (ISSUE 2).
//!
//! Measures `SparseGp::predict_into` rows/sec across batch sizes
//! {1, 64, 4096} at thread budgets {1, N} (N = the pool size) for each
//! [`ComputeBackend`] — scalar vs simd (ISSUE 10) — plus the blocked
//! `data_term_ws` and an end-to-end `serve::BatchServer` throughput
//! probe.  Prints the human-readable table AND dumps machine-readable
//! results to `BENCH_predict.json` — the serving twin of
//! `perf_hotpath`'s `BENCH_hotpath.json`; `scripts/bench_diff.py`
//! diffs either file against a previous run, keyed per
//! (bench, backend).
//!
//! Thread count follows `ADVGP_THREADS` (default: all cores); the
//! budget-1 rows emulate `ADVGP_THREADS=1` via `pool::with_budget`.

use advgp::data::synth;
use advgp::experiments::harness::{bench, BenchReport};
use advgp::gp::{PredictWorkspace, SparseGp, Theta, ThetaLayout};
use advgp::linalg::simd;
use advgp::runtime::{Backend, ComputeBackend};
use advgp::serve::{BatchConfig, BatchServer, PosteriorCache};
use advgp::util::json::Json;
use advgp::util::pool;
use advgp::util::rng::Pcg64;
use std::sync::Arc;

const OUT_PATH: &str = "BENCH_predict.json";
const BATCHES: [usize; 3] = [1, 64, 4096];

struct Entry {
    report: BenchReport,
    batch: usize,
    threads: usize,
    rows_per_sec: f64,
    /// Backend name for the per-backend benches; `None` for the
    /// end-to-end server probe (which runs on the process default).
    backend: Option<&'static str>,
}

/// The backend dimension: explicit selectors resolved via
/// `with_backend`, so each bench row is self-contained.
fn backends() -> Vec<(&'static str, &'static dyn ComputeBackend)> {
    vec![
        ("scalar", Backend::Scalar.resolve().expect("scalar resolves")),
        ("simd", Backend::Simd.resolve().expect("simd resolves")),
    ]
}

fn main() {
    let (m, d) = (100usize, 8usize);
    let layout = ThetaLayout::new(m, d);
    let ds = synth::flight_like(*BATCHES.iter().max().unwrap(), 3);
    let mut rng = Pcg64::seeded(17);
    let z = advgp::data::kmeans::kmeans(&ds.x, m, 10, &mut rng);
    let theta = Theta::init(layout, &z);
    let pool_threads = pool::threads();
    println!(
        "predict/serving microbenches: m={m} d={d} threads={pool_threads} \
         simd path={}\n",
        simd::active_path()
    );

    let mut budgets = vec![1usize, pool_threads];
    budgets.dedup();
    let mut entries: Vec<Entry> = Vec::new();

    // Blocked predict across backend × batch × thread budget.
    for (bname, be) in backends() {
        let gp = SparseGp::with_backend(theta.clone(), be);
        for &batch in &BATCHES {
            let xb = ds.head(batch).x;
            for &t in &budgets {
                let mut ws = PredictWorkspace::new();
                let mut mean = Vec::new();
                let mut var = Vec::new();
                let report = bench(
                    &format!("predict_into batch={batch} threads={t} [{bname}]"),
                    3,
                    0.6,
                    || {
                        pool::with_budget(t, || {
                            gp.predict_into(&xb, &mut ws, &mut mean, &mut var)
                        });
                        std::hint::black_box(var.len());
                    },
                );
                let rows_per_sec = batch as f64 / report.stats.mean().max(1e-12);
                entries.push(Entry {
                    report,
                    batch,
                    threads: t,
                    rows_per_sec,
                    backend: Some(bname),
                });
            }
        }

        // Blocked data term (the evaluator's −ELBO path) at the big batch.
        let big = BATCHES[BATCHES.len() - 1];
        for &t in &budgets {
            let mut ws = PredictWorkspace::new();
            let report = bench(
                &format!("data_term_ws batch={big} threads={t} [{bname}]"),
                3,
                0.6,
                || {
                    let g = pool::with_budget(t, || gp.data_term_ws(&ds.x, &ds.y, &mut ws));
                    std::hint::black_box(g);
                },
            );
            let rows_per_sec = big as f64 / report.stats.mean().max(1e-12);
            entries.push(Entry {
                report,
                batch: big,
                threads: t,
                rows_per_sec,
                backend: Some(bname),
            });
        }
    }

    // End-to-end microbatching server: one client firing single-row
    // requests back-to-back (latency-bound) — reported for context, not
    // diffed as a hot path.
    {
        let cache = Arc::new(PosteriorCache::new(layout));
        cache.install(1, &theta.data);
        // Zero delay: a lone client measures the pure round-trip cost
        // (channel + stage + blocked 1-row predict), not the deadline.
        let cfg = BatchConfig { max_rows: 512, latency_budget: std::time::Duration::ZERO };
        let (server, client) = BatchServer::start(cache, None, cfg);
        let row = ds.x.row(0).to_vec();
        let report = bench("batch_server single-row round-trip", 10, 0.6, || {
            let p = client.predict(&row).expect("server alive");
            std::hint::black_box(p.mean);
        });
        drop(client);
        let sr = server.join();
        println!("  server report: {}", sr.summary());
        let rows_per_sec = 1.0 / report.stats.mean().max(1e-12);
        entries.push(Entry {
            report,
            batch: 1,
            threads: pool_threads,
            rows_per_sec,
            backend: None,
        });
    }

    write_json(&entries, pool_threads, m, d);
    println!("\nwrote {} ({} entries, threads={pool_threads})", OUT_PATH, entries.len());
}

/// Dump `BENCH_predict.json`: schema-versioned (2 adds the per-entry
/// `backend` field and the dispatched `simd_path`), one entry per
/// (bench, backend, batch, threads) with ns/iter stats and rows/sec.
fn write_json(entries: &[Entry], threads: usize, m: usize, d: usize) {
    let benches: Vec<Json> = entries
        .iter()
        .map(|e| {
            let mut fields = vec![
                ("name", Json::Str(e.report.name.clone())),
                ("batch", Json::Num(e.batch as f64)),
                ("threads", Json::Num(e.threads as f64)),
                ("rows_per_sec", Json::Num(e.rows_per_sec)),
                ("mean_ns", Json::Num(e.report.stats.mean() * 1e9)),
                ("std_ns", Json::Num(e.report.stats.std() * 1e9)),
                ("min_ns", Json::Num(e.report.stats.min * 1e9)),
                ("iters", Json::Num(e.report.iters as f64)),
            ];
            if let Some(bname) = e.backend {
                fields.push(("backend", Json::Str(bname.into())));
            }
            Json::obj(fields)
        })
        .collect();
    let doc = Json::obj(vec![
        ("schema", Json::Num(2.0)),
        ("bench", Json::Str("perf_predict".into())),
        ("threads", Json::Num(threads as f64)),
        ("m", Json::Num(m as f64)),
        ("d", Json::Num(d as f64)),
        ("simd_path", Json::Str(simd::active_path().into())),
        (
            "par_min_flops",
            Json::Num(advgp::linalg::par_min_flops() as f64),
        ),
        ("benches", Json::Arr(benches)),
    ]);
    if let Err(e) = std::fs::write(OUT_PATH, format!("{doc}\n")) {
        eprintln!("failed to write {OUT_PATH}: {e}");
    }
}
