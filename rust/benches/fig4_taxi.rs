//! Reproduces **Figure 4**: NYC-taxi travel-time prediction — GP
//! regression (ADVGP) vs Vowpal-Wabbit-style linear regression vs the
//! mean predictor, RMSE as a function of training time.
//!
//! Panel (A): the paper's 100M-sample run (m=50, k-means init, τ=20).
//! Panel (B): the 1B-sample run (m=50, τ=100, more workers).
//! We run the taxi-like generator at single-box scale (DESIGN.md §4);
//! the claims to reproduce: the GP beats the linear model by a clear
//! double-digit-% margin and the mean predictor by a large margin, with
//! most of the improvement early in the run.

use advgp::experiments::methods::*;
use advgp::experiments::{out_dir, print_table, taxi_problem, Scale};
use advgp::ps::metrics::write_trace_csv;

fn main() {
    let scale = Scale::from_env();
    let dir = out_dir().join("fig4");
    let panels = [
        ("A-100M-equivalent", scale.pick(5_000, 200_000, 2_000_000),
         scale.pick(1_000, 20_000, 100_000), 20u64, 8usize),
        ("B-1B-equivalent", scale.pick(10_000, 500_000, 8_000_000),
         scale.pick(1_000, 40_000, 200_000), 100u64, 16usize),
    ];
    let budget = scale.pick(2.0, 25.0, 900.0);
    let mut all = String::new();

    for (label, n_train, n_test, tau, workers) in panels {
        let p = taxi_problem(n_train, n_test, 50.min(n_train / 100).max(8), 23);
        let y_std = p.standardizer.y_std;
        let opts = MethodOpts {
            budget_secs: budget,
            tau,
            workers,
            ..Default::default()
        };
        let advgp = run_advgp(&p, &opts);
        let linear = run_linear_method(&p, &opts);
        let mean = run_mean_method(&p);
        write_trace_csv(&dir.join(format!("{label}_advgp.csv")), &advgp.trace).unwrap();
        write_trace_csv(&dir.join(format!("{label}_linear.csv")), &linear.trace).unwrap();

        let gp = final_rmse(&advgp) * y_std;
        let lin = final_rmse(&linear) * y_std;
        let mn = final_rmse(&mean) * y_std;
        let rows = vec![
            vec!["ADVGP".into(), format!("{gp:.1}"), "-".into()],
            vec!["linear (VW-style)".into(), format!("{lin:.1}"),
                 format!("GP better by {:.0}%", 100.0 * (1.0 - gp / lin))],
            vec!["mean prediction".into(), format!("{mn:.1}"),
                 format!("GP better by {:.0}%", 100.0 * (1.0 - gp / mn))],
        ];
        all.push_str(&print_table(
            &format!("Fig.4({label}): taxi travel-time RMSE (seconds), n={n_train}, τ={tau}, {workers} workers, budget {budget:.0}s"),
            &["Method", "RMSE (s)", "vs ADVGP"],
            &rows,
        ));
        // Paper's shape: GP < linear < mean with double-digit GP margin.
        assert!(gp < lin && lin < mn, "ordering must hold: {gp} {lin} {mn}");
    }
    std::fs::write(out_dir().join("fig4_taxi.md"), all).unwrap();
    println!("\ntraces in {}", dir.display());
}
