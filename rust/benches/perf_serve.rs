//! §Read-path fleet benchmark (ADVGPSV1, ISSUE 8): replicas over
//! loopback TCP under open-loop load.
//!
//! One short τ=0 training run over the networked transport publishes θ
//! to subscribed replicas; after the trainer's clean SHUTDOWN the
//! replicas keep serving the final posterior (that is the contract),
//! and `serve::loadgen` offers a fixed request schedule against fleets
//! of 1 and 2 replicas, then once more through a [`Router`] fronting
//! both (ADVGPRT1, ISSUE 9) so the routed read path is tracked by the
//! same harness.  Results merge into `BENCH_serve.json` (schema 1 —
//! `scripts/bench_diff.py` diffs it like the other bench dumps):
//! rows/sec plus exact p50/p99/p999 per fleet size, and for the routed
//! entry the `route_*` counters (cache hits/misses, retries,
//! failovers, per-hop rejects).
//!
//! Open loop means latency is measured from each request's *scheduled*
//! send time, so a stalled replica makes subsequent requests late
//! instead of silently slowing the offered rate (no coordinated
//! omission).

use advgp::data::{kmeans, synth, Standardizer};
use advgp::gp::{Theta, ThetaLayout};
use advgp::grad::native_factory;
use advgp::ps::coordinator::{train_remote, TrainConfig};
use advgp::ps::net::{remote_worker_loop, NetServer};
use advgp::ps::worker::{WorkerProfile, WorkerSource};
use advgp::serve::{loadgen, LoadgenConfig, Replica, ReplicaConfig, Router, RouterConfig};
use advgp::util::rng::Pcg64;
use std::time::Duration;

const OUT_PATH: &str = "BENCH_serve.json";
const UPDATES: u64 = 12;

fn main() {
    // ---- a small standardized problem + θ₀ ----
    let mut ds = synth::friedman(1200, 4, 0.4, 7);
    let mut rng = Pcg64::seeded(7);
    ds.shuffle(&mut rng);
    let st = Standardizer::fit(&ds);
    st.apply(&mut ds);
    let (m, d) = (30usize, ds.d());
    let layout = ThetaLayout::new(m, d);
    let z = kmeans::kmeans(&ds.x, m, 10, &mut rng);
    let theta0 = Theta::init(layout, &z);

    // ---- train over loopback with replicas subscribed ----
    let net = NetServer::bind("127.0.0.1:0").expect("bind θ server");
    let addr = net.local_addr().to_string();
    let shards = ds.shard(2);
    // Trainer first: its accept loop answers the replica subscriptions.
    // Replicas before workers: training cannot finish (and tear the
    // publish stream down) until the workers join, so the subscriptions
    // are guaranteed to see the run.
    let trainer = {
        let theta0 = theta0.data.clone();
        std::thread::spawn(move || {
            let mut cfg = TrainConfig::new(layout);
            cfg.tau = 0;
            cfg.max_updates = UPDATES;
            cfg.eval_every_secs = 0.0;
            train_remote(&cfg, theta0, net, 2, None)
        })
    };
    let mk_replica = || {
        Replica::start(
            "127.0.0.1:0",
            std::slice::from_ref(&addr),
            ReplicaConfig::default(),
        )
        .expect("start replica")
    };
    let replicas = vec![mk_replica(), mk_replica()];
    let workers: Vec<_> = shards
        .into_iter()
        .enumerate()
        .map(|(k, shard)| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                remote_worker_loop(
                    &addr,
                    Some(k),
                    WorkerSource::Memory(shard),
                    native_factory(layout),
                    WorkerProfile { threads: 1, ..Default::default() },
                )
                .expect("worker run")
            })
        })
        .collect();
    let run = trainer.join().expect("trainer thread");
    for w in workers {
        w.join().expect("worker thread");
    }
    println!(
        "perf_serve: trained {} update(s) (m={m} d={d}); replicas converging…",
        run.stats.updates
    );
    for (i, r) in replicas.iter().enumerate() {
        assert!(
            r.wait_version(run.stats.updates, Duration::from_secs(30)),
            "replica {i} never reached θ v{}",
            run.stats.updates
        );
    }

    // ---- offered load against fleets of 1 and 2 replicas ----
    let addrs: Vec<String> =
        replicas.iter().map(|r| r.predict_addr().to_string()).collect();
    let cfg = LoadgenConfig {
        qps: 400.0,
        requests: 1200,
        rows_per_request: 8,
        seed: 42,
    };
    for n in [1usize, 2] {
        let fleet = &addrs[..n];
        let sb = loadgen::run(fleet, &cfg).expect("loadgen run");
        let name = format!("serve/replicas={n}");
        println!("  {name}: {}", sb.summary());
        assert_eq!(sb.total_rejects(), 0, "{name}: healthy fleet rejected traffic");
        sb.write_bench(OUT_PATH, &name, &cfg, n).expect("write bench JSON");
    }

    // ---- the same offered load through the routing tier (ADVGPRT1) ----
    // One router address in front of both replicas: P2C spreading plus
    // the per-leg answer cache.  The loadgen's repeated seeded row
    // stream gives the cache real hits, so the routed entry reports
    // both ends of the path (route_cache_hits / route_cache_misses)
    // alongside the same latency quantiles as the direct fleets.
    let router = Router::start("127.0.0.1:0", &addrs, RouterConfig::default())
        .expect("start router");
    let routed = vec![router.addr().to_string()];
    let mut sb = loadgen::run(&routed, &cfg).expect("routed loadgen run");
    let name = "serve/routed-replicas=2";
    assert_eq!(sb.total_rejects(), 0, "{name}: healthy routed fleet rejected traffic");
    sb.attach_route(router.shutdown());
    println!("  {name}: {}", sb.summary());
    sb.write_bench(OUT_PATH, name, &cfg, 2).expect("write bench JSON");

    for r in replicas {
        let report = r.shutdown();
        println!("  replica report: {}", report.summary());
    }
    println!("wrote {OUT_PATH}");
}
