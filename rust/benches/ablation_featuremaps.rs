//! Ablation over the §5 feature-map family — the design choice DESIGN.md
//! calls out.  For each map φ(·) (eq. 11 Cholesky, eq. 21 Nyström/EigenGP,
//! eq. 22 ensemble-Nyström, RVM-style) we compute, at fixed kernel
//! hyperparameters, the **optimal-q ELBO** (closed form: Σ* = (I+βΦᵀΦ)⁻¹,
//! μ* = βΣ*Φᵀy) and the held-out RMSE, as m grows — the bound-quality
//! ladder of the framework, with the exact GP evidence as the ceiling.
//!
//! Claims checked: (a) every map's ELBO lower-bounds the exact evidence;
//! (b) Cholesky and Nyström span the same subspace (identical ELBOs);
//! (c) bounds tighten monotonically-ish with m; (d) the clamped RVM map
//! is strictly weaker (its α-cap shrinks Φ).

use advgp::data::{kmeans, synth, Standardizer};
use advgp::gp::exact::ExactGp;
use advgp::gp::featuremap::*;
use advgp::kernel::ArdParams;
use advgp::linalg::{cholesky_lower, spd_inverse, Mat};
use advgp::util::rng::Pcg64;
use advgp::util::rmse;
use advgp::experiments::{out_dir, print_table, Scale};

/// Optimal-q negative ELBO and test RMSE for a feature map.
fn eval_map(
    map: &dyn FeatureMap,
    params: &ArdParams,
    beta: f64,
    train: &advgp::data::Dataset,
    test: &advgp::data::Dataset,
) -> (f64, f64) {
    let pb = map.phi(params, &train.x);
    let p = map.dim();
    let mut prec = pb.phi.gram();
    prec.scale(beta);
    for i in 0..p {
        prec[(i, i)] += 1.0;
    }
    let sigma = spd_inverse(&prec).expect("prec SPD");
    let mut mu = sigma.matvec(&pb.phi.tr_matvec(&train.y));
    for v in &mut mu {
        *v *= beta;
    }
    // Data term Σ g_i at (μ*, Σ*).
    let n = train.n();
    let mut g = 0.0;
    let u = cholesky_lower(&sigma).expect("Σ SPD").transpose(); // upper
    for i in 0..n {
        let phi_i = pb.phi.row(i);
        let e = advgp::linalg::dot(phi_i, &mu) - train.y[i];
        let uphi = u.matvec(phi_i);
        let quad: f64 = uphi.iter().map(|v| v * v).sum();
        g += 0.5 * (2.0 * std::f64::consts::PI).ln() - 0.5 * beta.ln()
            + 0.5 * beta * (e * e + quad + pb.ktilde[i]);
    }
    // KL(q||prior) with Σ = UᵀU.
    let logdet: f64 = u.diag().iter().map(|v| 2.0 * v.abs().ln()).sum();
    let tr: f64 = u.data.iter().map(|v| v * v).sum();
    let musq: f64 = mu.iter().map(|v| v * v).sum();
    let kl = 0.5 * (-logdet - p as f64 + tr + musq);
    let neg_elbo = g + kl;
    // Held-out RMSE with the optimal q.
    let pt = map.phi(params, &test.x);
    let mean = pt.phi.matvec(&mu);
    (-neg_elbo, rmse(&mean, &test.y))
}

fn main() {
    let scale = Scale::from_env();
    let n_train = scale.pick(800, 3_000, 20_000);
    let n_test = scale.pick(200, 600, 4_000);
    let ms: Vec<usize> = scale.pick(vec![10, 25], vec![10, 25, 50, 100],
                                    vec![25, 50, 100, 200]);

    let mut ds = synth::friedman(n_train + n_test, 4, 0.4, 77);
    let mut rng = Pcg64::seeded(77);
    ds.shuffle(&mut rng);
    let (mut train, mut test) = ds.split(n_test);
    let st = Standardizer::fit(&train);
    st.apply(&mut train);
    st.apply(&mut test);
    let d = train.d();
    let params = ArdParams { log_a0: 0.0, log_eta: vec![-(d as f64).ln(); d] };
    let log_sigma: f64 = -0.5;
    let beta = (-2.0 * log_sigma).exp();

    // Exact evidence ceiling (feasible at small/ci scales only).
    let exact = if n_train <= 4000 {
        Some(ExactGp::fit(params.clone(), log_sigma, train.x.clone(), &train.y)
            .log_evidence())
    } else {
        None
    };

    let mut rows = Vec::new();
    for &m in &ms {
        let z = kmeans::kmeans(&train.x, m, 20, &mut rng);
        let half = m / 2;
        let z1 = Mat::from_vec(half, d, z.data[..half * d].to_vec());
        let z2 = Mat::from_vec(m - half, d, z.data[half * d..].to_vec());
        let chol = InducingChol::build(&params, z.clone());
        let nys = Nystrom::build(&params, z.clone());
        let ens = EnsembleNystrom::build(&params, vec![z1, z2]);
        let rvm = Rvm::build(&params, z.clone(), &vec![1.0; m]);
        let maps: Vec<(&str, &dyn FeatureMap)> = vec![
            ("chol (eq.11)", &chol),
            ("nystrom (eq.21)", &nys),
            ("ensemble (eq.22)", &ens),
            ("rvm (§5)", &rvm),
        ];
        for (name, map) in maps {
            let (elbo, r) = eval_map(map, &params, beta, &train, &test);
            if let Some(ev) = exact {
                assert!(elbo <= ev + 1e-3, "{name} m={m}: ELBO {elbo} > evidence {ev}");
            }
            rows.push(vec![
                format!("{m}"),
                name.to_string(),
                format!("{elbo:.2}"),
                format!("{r:.4}"),
            ]);
        }
    }
    let mut table = print_table(
        &format!(
            "feature-map ablation: optimal-q ELBO and test RMSE (n={n_train}, exact evidence = {})",
            exact.map(|e| format!("{e:.2}")).unwrap_or_else(|| "n/a".into())
        ),
        &["m", "map", "ELBO (higher=better)", "test RMSE"],
        &rows,
    );
    if let Some(ev) = exact {
        table.push_str(&format!("\nexact GP log evidence: {ev:.2}\n"));
        println!("\nexact GP log evidence: {ev:.2} (every ELBO above is ≤ this)");
    }
    std::fs::write(out_dir().join("ablation_featuremaps.md"), table).unwrap();
}
