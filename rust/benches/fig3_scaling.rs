//! Reproduces **Figure 3**: scalability of asynchronous (ADVGP) vs
//! synchronous (DistGP-GD ≡ τ=0) inference.
//!
//! (A) strong scaling: fixed data, workers 2→N; per-update wall time.
//! (B) weak scaling: data grows with workers; per-update wall time.
//!
//! Workers get heterogeneous per-iteration jitter (real clusters are
//! never uniform); the synchronous barrier pays the max, the async gate
//! amortizes it.  Claims to reproduce: ADVGP's per-iteration time is
//! well below DistGP-GD's at every width, and stays ~flat in (B) while
//! the synchronous version grows.

use advgp::experiments::{flight_problem, out_dir, print_table, Scale};
use advgp::ps::worker::WorkerProfile;
use std::time::Duration;

fn per_update_secs(p: &advgp::experiments::Problem, workers: usize, tau: u64,
                   budget: f64) -> (f64, u64) {
    let mut cfg = advgp::ps::coordinator::TrainConfig::new(p.layout);
    cfg.tau = tau;
    cfg.max_updates = u64::MAX / 2;
    cfg.time_limit_secs = Some(budget);
    cfg.eval_every_secs = 0.0;
    // Heterogeneous jitter: worker k sleeps (k % 4) ms.
    cfg.profiles = (0..workers)
        .map(|k| WorkerProfile {
            straggle: Duration::from_millis((k % 4) as u64),
            ..Default::default()
        })
        .collect();
    let res = advgp::ps::coordinator::train(
        &cfg,
        p.theta0.data.clone(),
        p.train.shard(workers),
        advgp::grad::native_factory(p.layout),
        None,
    );
    (res.stats.iter_secs.mean(), res.stats.updates)
}

fn main() {
    let scale = Scale::from_env();
    let m = scale.pick(16, 50, 100);
    let budget = scale.pick(1.5, 6.0, 60.0);
    let widths: Vec<usize> = scale.pick(vec![2, 8], vec![2, 4, 8, 16, 32],
                                        vec![4, 8, 16, 32, 64, 128]);

    // ---- (A) strong scaling ----
    let n_fixed = scale.pick(3_000, 24_000, 700_000);
    let p = flight_problem(n_fixed, 500, m, 17);
    let mut rows_a = Vec::new();
    for &w in &widths {
        let (async_t, async_u) = per_update_secs(&p, w, 32, budget);
        let (sync_t, sync_u) = per_update_secs(&p, w, 0, budget);
        rows_a.push(vec![
            format!("{w}"),
            format!("{:.2}ms ({} upd)", async_t * 1e3, async_u),
            format!("{:.2}ms ({} upd)", sync_t * 1e3, sync_u),
            format!("{:.2}x", sync_t / async_t.max(1e-9)),
        ]);
    }
    let table_a = print_table(
        &format!("Fig.3(A): per-update time, fixed n={n_fixed}, budget {budget:.0}s"),
        &["workers", "ADVGP (τ=32)", "DistGP-GD (τ=0)", "sync/async"],
        &rows_a,
    );

    // ---- (B) weak scaling ----
    let base_rows = scale.pick(1_000, 6_000, 87_500);
    let mut rows_b = Vec::new();
    for &w in &widths {
        let n = base_rows * w / widths[0];
        let pb = flight_problem(n, 500, m, 19);
        let (async_t, _) = per_update_secs(&pb, w, 32, budget);
        let (sync_t, _) = per_update_secs(&pb, w, 0, budget);
        rows_b.push(vec![
            format!("{w} / {n}"),
            format!("{:.2}ms", async_t * 1e3),
            format!("{:.2}ms", sync_t * 1e3),
            format!("{:.2}x", sync_t / async_t.max(1e-9)),
        ]);
    }
    let table_b = print_table(
        "Fig.3(B): per-update time, data scaled with workers",
        &["workers / rows", "ADVGP (τ=32)", "DistGP-GD (τ=0)", "sync/async"],
        &rows_b,
    );
    std::fs::write(out_dir().join("fig3_scaling.md"), table_a + &table_b).unwrap();
}
