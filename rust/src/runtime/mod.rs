//! PJRT runtime: load AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the CPU PJRT client.
//!
//! Key constraints (see /opt/xla-example/README.md and DESIGN.md):
//! * Interchange is **HLO text** — xla_extension 0.5.1 rejects jax>=0.5's
//!   serialized protos (64-bit instruction ids); the text parser
//!   reassigns ids.
//! * `PjRtClient` is `Rc`-backed and **not `Send`**: every worker thread
//!   builds its own [`XlaEngine`] (clients/executables never migrate).
//! * Artifacts are shape-specialized `(kind, m, d, B)`; shards are
//!   streamed through in fixed `B`-row blocks with a 0/1 mask padding
//!   the tail, so padded rows contribute exactly zero.
//!
//! The `xla` crate is optional (cargo feature `xla`): without it the
//! crate still builds and every entry point here returns a descriptive
//! error, so the pure-Rust [`crate::grad::native`] path — and all of
//! tier-1 — works in environments where the PJRT toolchain is absent.

pub mod backend;
pub mod manifest;

#[cfg(feature = "xla")]
pub mod engine;
#[cfg(not(feature = "xla"))]
#[path = "engine_stub.rs"]
pub mod engine;

pub use backend::{Backend, BackendError, ComputeBackend};
pub use engine::{XlaEngine, XlaEvaluator};
pub use manifest::{ArtifactKind, ArtifactSpec, Manifest};

use crate::gp::ThetaLayout;
use crate::linalg::Mat;
use anyhow::Result;

/// The posterior-evaluation surface both `XlaEvaluator` variants (real
/// PJRT and stub) must implement.  Before ISSUE 10 the stub shadowed
/// the real evaluator's API *by convention only* — a signature drift
/// compiled fine until someone built with `--features xla`.  As a
/// trait, drift is a compile error on whichever side lags (the CI
/// `cargo check --features xla` step keeps the real side honest).
pub trait PosteriorEval {
    /// The θ layout the compiled artifacts were specialized for.
    fn layout(&self) -> ThetaLayout;
    /// Predictive `(mean, var_y)` for every row of `x`.
    fn predict(&self, theta: &[f64], x: &Mat) -> Result<(Vec<f64>, Vec<f64>)>;
    /// `(Σ_i g_i, Σ_i (mean_i − y_i)²)` over the dataset — the data
    /// term of −ELBO (add `Theta::kl()` for the full bound) and the
    /// SSE.
    fn elbo_data_term(&self, theta: &[f64], x: &Mat, y: &[f64]) -> Result<(f64, f64)>;
}

/// Smoke helper used by the `advgp smoke` subcommand: load an HLO text
/// file of the reference `fn(x, y) = (x @ y + 2,)` and execute it.
#[cfg(feature = "xla")]
pub fn smoke(path: &str) -> Result<Vec<f32>> {
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2])?;
    let y = xla::Literal::vec1(&[1f32, 1., 1., 1.]).reshape(&[2, 2])?;
    let r = exe.execute::<xla::Literal>(&[x, y])?[0][0].to_literal_sync()?;
    Ok(r.to_tuple1()?.to_vec::<f32>()?)
}

/// Smoke helper (stub): the build has no PJRT runtime.
#[cfg(not(feature = "xla"))]
pub fn smoke(_path: &str) -> Result<Vec<f32>> {
    anyhow::bail!("built without the `xla` cargo feature; PJRT smoke test unavailable")
}
