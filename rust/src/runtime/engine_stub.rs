//! Stub PJRT engines for builds without the optional `xla` crate.
//!
//! Mirrors the public surface of `runtime::engine` so callers compile
//! unchanged: fallible constructors return a descriptive error (the
//! same shape as "artifacts missing", which every caller already
//! handles by falling back to [`crate::grad::native::NativeEngine`] or
//! skipping); `xla_factory` — whose signature has no error channel —
//! panics immediately at the call site with the same message; the
//! remaining methods are unreachable because no value of these types
//! can ever be constructed.

use crate::gp::ThetaLayout;
use crate::grad::{EngineFactory, GradEngine, GradResult};
use crate::linalg::Mat;
use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::PosteriorEval;
use anyhow::Result;

fn unavailable() -> anyhow::Error {
    anyhow::anyhow!(
        "PJRT runtime unavailable: this binary was built without the `xla` cargo \
         feature (rebuild with `--features xla` to execute AOT artifacts)"
    )
}

/// Stub for `engine::XlaEngine`; cannot be constructed.
pub struct XlaEngine {
    never: std::convert::Infallible,
}

impl XlaEngine {
    pub fn from_manifest(_manifest: &Manifest, _m: usize, _d: usize) -> Result<Self> {
        Err(unavailable())
    }

    pub fn new(_spec: &ArtifactSpec) -> Result<Self> {
        Err(unavailable())
    }
}

impl GradEngine for XlaEngine {
    fn layout(&self) -> ThetaLayout {
        match self.never {}
    }

    fn name(&self) -> &'static str {
        match self.never {}
    }

    fn grad(&mut self, _theta: &[f64], _x: &Mat, _y: &[f64]) -> GradResult {
        match self.never {}
    }
}

/// Stub factory: fails fast on the *calling* thread (a caller reaches
/// this only after explicitly selecting the XLA engine), rather than
/// letting `train` spawn workers that each die mid-run.
pub fn xla_factory(_manifest: Manifest, _m: usize, _d: usize) -> EngineFactory {
    panic!("{:#}", unavailable())
}

/// Stub for `engine::XlaEvaluator`; cannot be constructed.
pub struct XlaEvaluator {
    never: std::convert::Infallible,
}

impl XlaEvaluator {
    pub fn from_manifest(_manifest: &Manifest, _m: usize, _d: usize) -> Result<Self> {
        Err(unavailable())
    }
}

/// The stub satisfies the same [`PosteriorEval`] trait as the real
/// PJRT evaluator — drift between the two surfaces is now a compile
/// error instead of a convention (ISSUE 10 satellite).
impl PosteriorEval for XlaEvaluator {
    fn layout(&self) -> ThetaLayout {
        match self.never {}
    }

    fn predict(&self, _theta: &[f64], _x: &Mat) -> Result<(Vec<f64>, Vec<f64>)> {
        match self.never {}
    }

    fn elbo_data_term(&self, _theta: &[f64], _x: &Mat, _y: &[f64]) -> Result<(f64, f64)> {
        match self.never {}
    }
}
