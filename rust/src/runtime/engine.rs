//! PJRT-backed gradient / evaluation engines.
//!
//! One `XlaEngine` per worker thread (clients are not `Send`).  The
//! engine compiles its artifact once, then streams shard data through it
//! in fixed-size blocks, padding the final block via the mask input.
//!
//! Split-Cholesky ABI (see python/compile/model.py): the host computes
//! `L = chol(K_mm^{-1})` with its own linalg, feeds it as an input, and
//! chains the returned cotangent dL̄ through [`LChain`] — jax's CPU
//! linalg custom-calls (typed FFI) are not executable under
//! xla_extension 0.5.1, and the O(m³) factor is cheap on the host.

use crate::gp::{Theta, ThetaLayout};
use crate::grad::chain::LChain;
use crate::grad::{EngineFactory, GradEngine, GradResult};
use crate::linalg::Mat;
use crate::runtime::manifest::{ArtifactKind, ArtifactSpec, Manifest};
use anyhow::{Context, Result};
use std::path::Path;
use xla::Literal;

fn to_f32(s: &[f64]) -> Vec<f32> {
    s.iter().map(|&v| v as f32).collect()
}

/// Pack the seven θ-side inputs in the artifact's positional ABI.
/// Returns the literals plus the `LChain` built for this θ.
fn theta_literals(
    layout: ThetaLayout,
    theta: &[f64],
) -> Result<(Vec<Literal>, LChain)> {
    let (m, d) = (layout.m, layout.d);
    let th = Theta { layout, data: theta.to_vec() };
    let chain = LChain::build(th.ard(), th.z_mat());
    let mu = Literal::vec1(&to_f32(&theta[layout.mu_range()]));
    let u = Literal::vec1(&to_f32(&theta[layout.u_range()]))
        .reshape(&[m as i64, m as i64])?;
    let z = Literal::vec1(&to_f32(&theta[layout.z_range()]))
        .reshape(&[m as i64, d as i64])?;
    let chol_l = Literal::vec1(&to_f32(&chain.chol_l.data))
        .reshape(&[m as i64, m as i64])?;
    let log_a0 = Literal::scalar(theta[layout.log_a0_idx()] as f32);
    let log_eta = Literal::vec1(&to_f32(&theta[layout.log_eta_range()]));
    let log_sigma = Literal::scalar(theta[layout.log_sigma_idx()] as f32);
    Ok((vec![mu, u, z, chol_l, log_a0, log_eta, log_sigma], chain))
}

/// Pack one padded data block: x [B, d] (f32) and optionally y, mask [B].
fn block_literals(
    b: usize,
    d: usize,
    x: &Mat,
    y: Option<&[f64]>,
    start: usize,
    len: usize,
) -> Result<Vec<Literal>> {
    let mut xbuf = vec![0.0f32; b * d];
    for r in 0..len {
        for c in 0..d {
            xbuf[r * d + c] = x[(start + r, c)] as f32;
        }
    }
    let xl = Literal::vec1(&xbuf).reshape(&[b as i64, d as i64])?;
    let mut out = vec![xl];
    if let Some(y) = y {
        let mut ybuf = vec![0.0f32; b];
        for r in 0..len {
            ybuf[r] = y[start + r] as f32;
        }
        let mut mbuf = vec![0.0f32; b];
        for v in mbuf.iter_mut().take(len) {
            *v = 1.0;
        }
        out.push(Literal::vec1(&ybuf));
        out.push(Literal::vec1(&mbuf));
    }
    Ok(out)
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("artifact path not utf-8")?,
    )
    .with_context(|| format!("parse HLO text {path:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

fn literal_to_f64(lit: &Literal) -> Result<Vec<f64>> {
    Ok(lit.to_vec::<f32>()?.into_iter().map(|v| v as f64).collect())
}

/// PJRT gradient engine implementing [`GradEngine`].
pub struct XlaEngine {
    layout: ThetaLayout,
    block: usize,
    _client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
}

impl XlaEngine {
    pub fn from_manifest(manifest: &Manifest, m: usize, d: usize) -> Result<Self> {
        let spec = manifest.find(ArtifactKind::Grad, m, d)?;
        Self::new(spec)
    }

    pub fn new(spec: &ArtifactSpec) -> Result<Self> {
        anyhow::ensure!(spec.kind == ArtifactKind::Grad, "grad artifact required");
        let client = xla::PjRtClient::cpu()?;
        let exe = compile(&client, &spec.path)?;
        Ok(Self {
            layout: ThetaLayout::new(spec.m, spec.d),
            block: spec.b,
            _client: client,
            exe,
        })
    }

    fn grad_inner(&self, theta: &[f64], x: &Mat, y: &[f64]) -> Result<GradResult> {
        let layout = self.layout;
        let (m, d) = (layout.m, layout.d);
        let mut value = 0.0f64;
        let mut grad = vec![0.0f64; layout.len()];
        let (theta_lits, chain) = theta_literals(layout, theta)?;
        let mut l_cot = Mat::zeros(m, m);
        let mut start = 0;
        while start < x.rows {
            let len = self.block.min(x.rows - start);
            let mut args: Vec<&Literal> = theta_lits.iter().collect();
            let blk = block_literals(self.block, d, x, Some(y), start, len)?;
            args.extend(blk.iter());
            let out = self.exe.execute::<&Literal>(&args)?[0][0]
                .to_literal_sync()?
                .to_tuple()?;
            anyhow::ensure!(out.len() == 8, "grad artifact returned {}", out.len());
            // (G, dmu, du, dz_direct, dchol_l, dla0, dleta, dls)
            value += literal_to_f64(&out[0])?[0];
            add_into(&mut grad[layout.mu_range()], &literal_to_f64(&out[1])?);
            add_into(&mut grad[layout.u_range()], &literal_to_f64(&out[2])?);
            add_into(&mut grad[layout.z_range()], &literal_to_f64(&out[3])?);
            let dl = literal_to_f64(&out[4])?;
            for (slot, v) in l_cot.data.iter_mut().zip(&dl) {
                *slot += v;
            }
            grad[layout.log_a0_idx()] += literal_to_f64(&out[5])?[0];
            add_into(&mut grad[layout.log_eta_range()], &literal_to_f64(&out[6])?);
            grad[layout.log_sigma_idx()] += literal_to_f64(&out[7])?[0];
            start += len;
        }
        // Chain the L cotangent through chol(inv(K_mm)) on the host.
        let lg = chain.chain(&l_cot);
        add_into(&mut grad[layout.z_range()], &lg.dz.data);
        add_into(&mut grad[layout.log_eta_range()], &lg.dlog_eta);
        grad[layout.log_a0_idx()] += lg.dlog_a0;
        Ok(GradResult { value, grad })
    }
}

fn add_into(dst: &mut [f64], src: &[f64]) {
    debug_assert_eq!(dst.len(), src.len());
    for (a, b) in dst.iter_mut().zip(src) {
        *a += b;
    }
}

impl GradEngine for XlaEngine {
    fn layout(&self) -> ThetaLayout {
        self.layout
    }

    fn name(&self) -> &'static str {
        "xla"
    }

    fn grad(&mut self, theta: &[f64], x: &Mat, y: &[f64]) -> GradResult {
        self.grad_inner(theta, x, y).expect("XLA grad execution failed")
    }
}

/// Engine factory for PJRT workers (one engine per worker thread).
pub fn xla_factory(manifest: Manifest, m: usize, d: usize) -> EngineFactory {
    std::sync::Arc::new(move |_worker| {
        Box::new(XlaEngine::from_manifest(&manifest, m, d).expect("build XlaEngine"))
    })
}

/// Evaluation-side PJRT engine: predictions (mean/var) and the data term
/// of the negative ELBO — the evaluator thread's workhorse.
pub struct XlaEvaluator {
    layout: ThetaLayout,
    block_pred: usize,
    block_elbo: usize,
    _client: xla::PjRtClient,
    exe_predict: xla::PjRtLoadedExecutable,
    exe_elbo: xla::PjRtLoadedExecutable,
}

impl XlaEvaluator {
    pub fn from_manifest(manifest: &Manifest, m: usize, d: usize) -> Result<Self> {
        let pspec = manifest.find(ArtifactKind::Predict, m, d)?;
        let espec = manifest.find(ArtifactKind::Elbo, m, d)?;
        let client = xla::PjRtClient::cpu()?;
        let exe_predict = compile(&client, &pspec.path)?;
        let exe_elbo = compile(&client, &espec.path)?;
        Ok(Self {
            layout: ThetaLayout::new(m, d),
            block_pred: pspec.b,
            block_elbo: espec.b,
            _client: client,
            exe_predict,
            exe_elbo,
        })
    }
}

/// The evaluation surface lives behind [`crate::runtime::PosteriorEval`]
/// so the feature-gated stub cannot drift from this real implementation
/// (ISSUE 10 satellite — drift is now a compile error on either side).
impl crate::runtime::PosteriorEval for XlaEvaluator {
    fn layout(&self) -> ThetaLayout {
        self.layout
    }

    /// Predictive (mean, var_y) for every row of x.
    fn predict(&self, theta: &[f64], x: &Mat) -> Result<(Vec<f64>, Vec<f64>)> {
        let (theta_lits, _chain) = theta_literals(self.layout, theta)?;
        let mut mean = Vec::with_capacity(x.rows);
        let mut var = Vec::with_capacity(x.rows);
        let mut start = 0;
        while start < x.rows {
            let len = self.block_pred.min(x.rows - start);
            let mut args: Vec<&Literal> = theta_lits.iter().collect();
            let blk = block_literals(self.block_pred, self.layout.d, x, None, start, len)?;
            args.extend(blk.iter());
            let out = self.exe_predict.execute::<&Literal>(&args)?[0][0]
                .to_literal_sync()?
                .to_tuple()?;
            anyhow::ensure!(out.len() == 2, "predict artifact returned {}", out.len());
            mean.extend(literal_to_f64(&out[0])?.into_iter().take(len));
            var.extend(literal_to_f64(&out[1])?.into_iter().take(len));
            start += len;
        }
        Ok((mean, var))
    }

    /// (Σ_i g_i, Σ_i (mean_i − y_i)²) over the dataset — the data term of
    /// −ELBO (add `Theta::kl()` for the full bound) and the SSE.
    fn elbo_data_term(&self, theta: &[f64], x: &Mat, y: &[f64]) -> Result<(f64, f64)> {
        let (theta_lits, _chain) = theta_literals(self.layout, theta)?;
        let mut g = 0.0;
        let mut sse = 0.0;
        let mut start = 0;
        while start < x.rows {
            let len = self.block_elbo.min(x.rows - start);
            let mut args: Vec<&Literal> = theta_lits.iter().collect();
            let blk =
                block_literals(self.block_elbo, self.layout.d, x, Some(y), start, len)?;
            args.extend(blk.iter());
            let out = self.exe_elbo.execute::<&Literal>(&args)?[0][0]
                .to_literal_sync()?
                .to_tuple()?;
            anyhow::ensure!(out.len() == 2, "elbo artifact returned {}", out.len());
            g += literal_to_f64(&out[0])?[0];
            sse += literal_to_f64(&out[1])?[0];
            start += len;
        }
        Ok((g, sse))
    }
}
