//! The compute-backend seam (ISSUE 10, ADVGPBE1): every hot-path
//! kernel the training/serving planes execute per row of data goes
//! through [`ComputeBackend`], so swapping the instruction set (or,
//! later, the device) never touches the layers above.
//!
//! Three implementations:
//!
//! * [`ScalarBackend`] — the reference semantics: delegates verbatim
//!   to the PR-1 kernels in [`crate::linalg`] / [`crate::kernel`].
//!   **Bitwise-pinned**: selecting it reproduces the seed θ trajectory
//!   and posterior outputs exactly, which is why it is the default.
//! * [`SimdBackend`] — the same operations through
//!   [`crate::linalg::simd`]: explicit 8-lane accumulators for the
//!   reduction kernels (results differ from scalar by reassociated
//!   rounding, bounded by the tolerance contract in
//!   `rust/tests/backend_contract.rs`) and AVX2-recompiled copies of
//!   the broadcast-chain kernels (bitwise-identical to scalar).  Both
//!   backends share [`crate::linalg`]'s serial/parallel dispatcher, so
//!   thread count still never changes results *within* a backend.
//! * `XlaBackend` (behind `--features xla`) — the PJRT slot.  XLA
//!   executes whole fused per-block graphs at the engine level
//!   ([`crate::runtime::XlaEngine`] / `PosteriorEval`), so its
//!   fine-grained host-side kernel obligations delegate to the scalar
//!   reference; the value of the slot is that the *selection plumbing*
//!   (`Backend::Xla` → config → engine) is exercised and typed.
//!
//! # Selection
//!
//! [`Backend`] is the user-facing knob: `TrainConfig::backend`, the
//! `--backend` CLI flag, or the `ADVGP_BACKEND` env var
//! (`scalar|simd|auto|xla`).  `auto` resolves to `simd` when
//! [`crate::linalg::simd::available`] says the host has a vector path,
//! else `scalar`.  Unknown values are a typed [`BackendError`], never a
//! panic; the env path warns and falls back to scalar (same contract
//! as `ADVGP_THREADS`).
//!
//! The resolved backend is installed process-wide ([`set_active`] /
//! [`active`]) by the training entry points; constructors that want a
//! specific backend regardless of global state take it explicitly
//! (`NativeEngine::with_backend`, `SparseGp::with_backend`).

use crate::kernel::{self, ArdParams, CrossScratch};
use crate::linalg::{self, simd, Mat};
use crate::log_warn;
use crate::util::pool;
use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};

/// The hot-path kernel set, promoted to a trait.  One method per
/// operation the per-row training/serving loops execute; everything
/// O(m³)-once-per-θ (Cholesky, `LChain`) deliberately stays outside —
/// it is not rows/sec and keeping it scalar pins its bitwise behavior
/// for every backend.
///
/// Implementations must be `Send + Sync` ZST-like statics: engines
/// hold `&'static dyn ComputeBackend` and fan it across worker lanes.
pub trait ComputeBackend: Send + Sync {
    /// Stable identifier (`"scalar"`, `"simd"`, `"xla"`) — used in
    /// bench JSON and logs.
    fn name(&self) -> &'static str;

    /// C = A·B.
    fn matmul_into(&self, a: &Mat, b: &Mat, out: &mut Mat);
    /// C = Aᵀ·B without materializing Aᵀ.
    fn tr_matmul_into(&self, a: &Mat, b: &Mat, out: &mut Mat);
    /// G = AᵀA (symmetry exploited: upper triangle + mirror).
    fn gram_into(&self, a: &Mat, out: &mut Mat);
    /// y = A·x.
    fn matvec_into(&self, a: &Mat, x: &[f64], out: &mut Vec<f64>);
    /// y = Aᵀ·x.
    fn tr_matvec_into(&self, a: &Mat, x: &[f64], out: &mut Vec<f64>);
    /// s_j = Σ_i A[i, j].
    fn col_sums_into(&self, a: &Mat, out: &mut Vec<f64>);
    /// C = U·B, U upper triangular.
    fn triu_matmul_into(&self, u: &Mat, b: &Mat, out: &mut Mat);
    /// C = A·L, L lower triangular.
    fn mul_tril_into(&self, a: &Mat, l: &Mat, out: &mut Mat);
    /// C = A·U, U upper triangular.
    fn mul_triu_into(&self, a: &Mat, u: &Mat, out: &mut Mat);
    /// C = A·Lᵀ, L lower triangular (prefix dots).
    fn mul_tril_t_into(&self, a: &Mat, l: &Mat, out: &mut Mat);
    /// C = A·Uᵀ, U upper triangular (suffix dots).
    fn mul_triu_t_into(&self, a: &Mat, u: &Mat, out: &mut Mat);
    /// ⟨a, b⟩.
    fn dot(&self, a: &[f64], b: &[f64]) -> f64;
    /// Σ aᵢ² — the row sum-of-squares of the blocked predict path
    /// (`V = ΦUᵀ` row norms for the predictive variance).
    fn sumsq(&self, a: &[f64]) -> f64;
    /// Cross-covariance K[X, Z] (fast dot-product form) into `out`,
    /// with the z-side preparation cached in `ws`.
    fn cross_into_ws(&self, p: &ArdParams, x: &Mat, z: &Mat, out: &mut Mat, ws: &mut CrossScratch);
    /// Exact per-pair K[X, Z] (used where `chol(inv(K_mm))` would
    /// amplify fast-form cancellation).
    fn cross_pairwise(&self, p: &ArdParams, x: &Mat, z: &Mat) -> Mat;
}

// ---------------------------------------------------------------------
// Scalar reference backend.
// ---------------------------------------------------------------------

/// The PR-1 scalar kernels, verbatim.  Every method delegates to the
/// exact code path the engines called before the trait existed, so
/// this backend is bitwise-pinned against seed behavior (asserted by
/// `rust/tests/backend_contract.rs`).
pub struct ScalarBackend;

/// The process-wide [`ScalarBackend`] instance.
pub static SCALAR: ScalarBackend = ScalarBackend;

impl ComputeBackend for ScalarBackend {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn matmul_into(&self, a: &Mat, b: &Mat, out: &mut Mat) {
        a.matmul_into(b, out);
    }

    fn tr_matmul_into(&self, a: &Mat, b: &Mat, out: &mut Mat) {
        a.tr_matmul_into(b, out);
    }

    fn gram_into(&self, a: &Mat, out: &mut Mat) {
        a.gram_into(out);
    }

    fn matvec_into(&self, a: &Mat, x: &[f64], out: &mut Vec<f64>) {
        a.matvec_into(x, out);
    }

    fn tr_matvec_into(&self, a: &Mat, x: &[f64], out: &mut Vec<f64>) {
        a.tr_matvec_into(x, out);
    }

    fn col_sums_into(&self, a: &Mat, out: &mut Vec<f64>) {
        a.col_sums_into(out);
    }

    fn triu_matmul_into(&self, u: &Mat, b: &Mat, out: &mut Mat) {
        u.triu_matmul_into(b, out);
    }

    fn mul_tril_into(&self, a: &Mat, l: &Mat, out: &mut Mat) {
        a.mul_tril_into(l, out);
    }

    fn mul_triu_into(&self, a: &Mat, u: &Mat, out: &mut Mat) {
        a.mul_triu_into(u, out);
    }

    fn mul_tril_t_into(&self, a: &Mat, l: &Mat, out: &mut Mat) {
        a.mul_tril_t_into(l, out);
    }

    fn mul_triu_t_into(&self, a: &Mat, u: &Mat, out: &mut Mat) {
        a.mul_triu_t_into(u, out);
    }

    fn dot(&self, a: &[f64], b: &[f64]) -> f64 {
        linalg::dot(a, b)
    }

    fn sumsq(&self, a: &[f64]) -> f64 {
        // dot(a, a), not a fresh loop: the blocked predict path
        // historically computed `dot(vi, vi)`, and bitwise-pinning the
        // scalar backend means reproducing that exact accumulation.
        linalg::dot(a, a)
    }

    fn cross_into_ws(&self, p: &ArdParams, x: &Mat, z: &Mat, out: &mut Mat, ws: &mut CrossScratch) {
        kernel::cross_into_ws(p, x, z, out, ws);
    }

    fn cross_pairwise(&self, p: &ArdParams, x: &Mat, z: &Mat) -> Mat {
        kernel::cross_pairwise(p, x, z)
    }
}

// ---------------------------------------------------------------------
// SIMD backend.
// ---------------------------------------------------------------------

/// The [`crate::linalg::simd`] kernels behind the same trait surface.
/// Shares `linalg::run_rows` (and the kernel-module flop model) with
/// the scalar backend, so the serial/parallel dispatch decision — and
/// therefore the thread-count-independence guarantee — is identical;
/// only the per-row arithmetic differs.
pub struct SimdBackend;

/// The process-wide [`SimdBackend`] instance.
pub static SIMD: SimdBackend = SimdBackend;

impl ComputeBackend for SimdBackend {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn matmul_into(&self, a: &Mat, b: &Mat, out: &mut Mat) {
        assert_eq!(
            a.cols, b.rows,
            "matmul dims {}x{} * {}x{}",
            a.rows, a.cols, b.rows, b.cols
        );
        out.resize(a.rows, b.cols);
        let flops = a.rows * a.cols * b.cols;
        linalg::run_rows(&mut out.data, b.cols, a.rows, flops, false, &|r0, rows, blk| {
            simd::matmul_rows(a, b, r0, rows, blk)
        });
    }

    fn tr_matmul_into(&self, a: &Mat, b: &Mat, out: &mut Mat) {
        assert_eq!(a.rows, b.rows, "tr_matmul dims");
        out.resize(a.cols, b.cols);
        let flops = a.rows * a.cols * b.cols;
        linalg::run_rows(&mut out.data, b.cols, a.cols, flops, true, &|i0, rows, blk| {
            simd::tr_matmul_rows(a, b, i0, rows, blk)
        });
    }

    fn gram_into(&self, a: &Mat, out: &mut Mat) {
        let n = a.cols;
        out.resize(n, n);
        let flops = a.rows * n * n / 2;
        linalg::run_rows(&mut out.data, n, n, flops, true, &|i0, rows, blk| {
            simd::gram_rows(a, i0, rows, blk)
        });
        for i in 0..n {
            for j in 0..i {
                out[(i, j)] = out[(j, i)];
            }
        }
    }

    fn matvec_into(&self, a: &Mat, x: &[f64], out: &mut Vec<f64>) {
        assert_eq!(a.cols, x.len());
        out.resize(a.rows, 0.0);
        let flops = a.rows * a.cols;
        linalg::run_rows(out, 1, a.rows, flops, false, &|r0, rows, blk| {
            simd::matvec_rows(a, x, r0, rows, blk)
        });
    }

    fn tr_matvec_into(&self, a: &Mat, x: &[f64], out: &mut Vec<f64>) {
        assert_eq!(a.rows, x.len());
        out.resize(a.cols, 0.0);
        let flops = a.rows * a.cols;
        linalg::run_rows(out, 1, a.cols, flops, true, &|c0, cols, blk| {
            simd::tr_matvec_cols(a, x, c0, cols, blk)
        });
    }

    fn col_sums_into(&self, a: &Mat, out: &mut Vec<f64>) {
        out.resize(a.cols, 0.0);
        let flops = a.rows * a.cols;
        linalg::run_rows(out, 1, a.cols, flops, true, &|c0, cols, blk| {
            simd::col_sums_cols(a, c0, cols, blk)
        });
    }

    fn triu_matmul_into(&self, u: &Mat, b: &Mat, out: &mut Mat) {
        assert_eq!(u.rows, u.cols, "triu operand must be square");
        assert_eq!(u.cols, b.rows, "triu_matmul dims");
        out.resize(u.rows, b.cols);
        let flops = u.rows * u.cols * b.cols / 2;
        linalg::run_rows(&mut out.data, b.cols, u.rows, flops, false, &|r0, rows, blk| {
            simd::triu_matmul_rows(u, b, r0, rows, blk)
        });
    }

    fn mul_tril_into(&self, a: &Mat, l: &Mat, out: &mut Mat) {
        assert_eq!(l.rows, l.cols, "tril operand must be square");
        assert_eq!(a.cols, l.rows, "mul_tril dims");
        out.resize(a.rows, l.cols);
        let flops = a.rows * l.rows * l.cols / 2;
        linalg::run_rows(&mut out.data, l.cols, a.rows, flops, false, &|r0, rows, blk| {
            simd::mul_tril_rows(a, l, r0, rows, blk)
        });
    }

    fn mul_triu_into(&self, a: &Mat, u: &Mat, out: &mut Mat) {
        assert_eq!(u.rows, u.cols, "triu operand must be square");
        assert_eq!(a.cols, u.rows, "mul_triu dims");
        out.resize(a.rows, u.cols);
        let flops = a.rows * u.rows * u.cols / 2;
        linalg::run_rows(&mut out.data, u.cols, a.rows, flops, false, &|r0, rows, blk| {
            simd::mul_triu_rows(a, u, r0, rows, blk)
        });
    }

    fn mul_tril_t_into(&self, a: &Mat, l: &Mat, out: &mut Mat) {
        assert_eq!(l.rows, l.cols, "tril operand must be square");
        assert_eq!(a.cols, l.rows, "mul_tril_t dims");
        out.resize(a.rows, l.rows);
        let flops = a.rows * l.rows * l.cols / 2;
        linalg::run_rows(&mut out.data, l.rows, a.rows, flops, false, &|r0, rows, blk| {
            simd::mul_tril_t_rows(a, l, r0, rows, blk)
        });
    }

    fn mul_triu_t_into(&self, a: &Mat, u: &Mat, out: &mut Mat) {
        assert_eq!(u.rows, u.cols, "triu operand must be square");
        assert_eq!(a.cols, u.rows, "mul_triu_t dims");
        out.resize(a.rows, u.rows);
        let flops = a.rows * u.rows * u.cols / 2;
        linalg::run_rows(&mut out.data, u.rows, a.rows, flops, false, &|r0, rows, blk| {
            simd::mul_triu_t_rows(a, u, r0, rows, blk)
        });
    }

    fn dot(&self, a: &[f64], b: &[f64]) -> f64 {
        simd::dot(a, b)
    }

    fn sumsq(&self, a: &[f64]) -> f64 {
        simd::sumsq(a)
    }

    fn cross_into_ws(&self, p: &ArdParams, x: &Mat, z: &Mat, out: &mut Mat, ws: &mut CrossScratch) {
        assert_eq!(x.cols, z.cols);
        assert_eq!(x.cols, p.dim());
        let eta = p.eta();
        let a0_sq = p.a0_sq();
        let m = z.rows;
        out.resize(x.rows, m);
        if x.rows == 0 || m == 0 {
            return;
        }
        ws.prepare(&eta, z);
        let (ze, zn, eta) = (&ws.ze, &ws.zn, &eta);
        let kern =
            |r0: usize, blk: &mut [f64]| simd::cross_rows(a0_sq, eta, x, ze, zn, r0, blk);
        if linalg::should_par(kernel::cross_flops(x.rows, m, eta.len())) {
            pool::parallel_rows_mut(
                &mut out.data,
                m,
                x.rows,
                pool::block_size(x.rows),
                &|r0, blk| kern(r0, blk),
            );
        } else {
            kern(0, &mut out.data);
        }
    }

    fn cross_pairwise(&self, p: &ArdParams, x: &Mat, z: &Mat) -> Mat {
        assert_eq!(x.cols, z.cols);
        assert_eq!(x.cols, p.dim());
        let eta = p.eta();
        let a0_sq = p.a0_sq();
        let m = z.rows;
        let mut k = Mat::zeros(x.rows, m);
        if x.rows == 0 || m == 0 {
            return k;
        }
        let eta = &eta;
        let kern =
            |r0: usize, blk: &mut [f64]| simd::cross_pairwise_rows(a0_sq, eta, x, z, r0, blk);
        if linalg::should_par(kernel::cross_flops(x.rows, m, eta.len())) {
            pool::parallel_rows_mut(
                &mut k.data,
                m,
                x.rows,
                pool::block_size(x.rows),
                &|r0, blk| kern(r0, blk),
            );
        } else {
            kern(0, &mut k.data);
        }
        k
    }
}

// ---------------------------------------------------------------------
// XLA backend (feature-gated third slot).
// ---------------------------------------------------------------------

/// The PJRT slot behind the trait.  XLA runs whole fused per-block
/// graphs at the engine layer (`GradEngine` / `PosteriorEval`), not
/// individual host kernels, so the fine-grained obligations here
/// delegate to the scalar reference — the slot exists so backend
/// selection (`Backend::Xla` → engine factory) is typed and cannot
/// rot to a parallel convention-only code path.
#[cfg(feature = "xla")]
pub struct XlaBackend;

/// The process-wide `XlaBackend` instance.
#[cfg(feature = "xla")]
pub static XLA: XlaBackend = XlaBackend;

#[cfg(feature = "xla")]
impl ComputeBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn matmul_into(&self, a: &Mat, b: &Mat, out: &mut Mat) {
        SCALAR.matmul_into(a, b, out);
    }

    fn tr_matmul_into(&self, a: &Mat, b: &Mat, out: &mut Mat) {
        SCALAR.tr_matmul_into(a, b, out);
    }

    fn gram_into(&self, a: &Mat, out: &mut Mat) {
        SCALAR.gram_into(a, out);
    }

    fn matvec_into(&self, a: &Mat, x: &[f64], out: &mut Vec<f64>) {
        SCALAR.matvec_into(a, x, out);
    }

    fn tr_matvec_into(&self, a: &Mat, x: &[f64], out: &mut Vec<f64>) {
        SCALAR.tr_matvec_into(a, x, out);
    }

    fn col_sums_into(&self, a: &Mat, out: &mut Vec<f64>) {
        SCALAR.col_sums_into(a, out);
    }

    fn triu_matmul_into(&self, u: &Mat, b: &Mat, out: &mut Mat) {
        SCALAR.triu_matmul_into(u, b, out);
    }

    fn mul_tril_into(&self, a: &Mat, l: &Mat, out: &mut Mat) {
        SCALAR.mul_tril_into(a, l, out);
    }

    fn mul_triu_into(&self, a: &Mat, u: &Mat, out: &mut Mat) {
        SCALAR.mul_triu_into(a, u, out);
    }

    fn mul_tril_t_into(&self, a: &Mat, l: &Mat, out: &mut Mat) {
        SCALAR.mul_tril_t_into(a, l, out);
    }

    fn mul_triu_t_into(&self, a: &Mat, u: &Mat, out: &mut Mat) {
        SCALAR.mul_triu_t_into(a, u, out);
    }

    fn dot(&self, a: &[f64], b: &[f64]) -> f64 {
        SCALAR.dot(a, b)
    }

    fn sumsq(&self, a: &[f64]) -> f64 {
        SCALAR.sumsq(a)
    }

    fn cross_into_ws(&self, p: &ArdParams, x: &Mat, z: &Mat, out: &mut Mat, ws: &mut CrossScratch) {
        SCALAR.cross_into_ws(p, x, z, out, ws);
    }

    fn cross_pairwise(&self, p: &ArdParams, x: &Mat, z: &Mat) -> Mat {
        SCALAR.cross_pairwise(p, x, z)
    }
}

// ---------------------------------------------------------------------
// Selection plumbing.
// ---------------------------------------------------------------------

/// User-facing backend selector (`TrainConfig::backend`, `--backend`,
/// `ADVGP_BACKEND`).  `Auto` is resolved at activation time, so a
/// config recorded as `auto` stays portable across hosts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Reference scalar kernels — bitwise-pinned default.
    Scalar,
    /// Runtime-dispatched SIMD kernels ([`crate::linalg::simd`]).
    Simd,
    /// `Simd` when [`crate::linalg::simd::available`], else `Scalar`.
    Auto,
    /// PJRT slot; requires a binary built with `--features xla`.
    Xla,
}

/// Typed selection failure: unknown name, or a slot this binary was
/// not built with.  Never a panic — CLI and config paths surface it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BackendError(pub String);

impl fmt::Display for BackendError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for BackendError {}

impl fmt::Display for Backend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Backend::Scalar => "scalar",
            Backend::Simd => "simd",
            Backend::Auto => "auto",
            Backend::Xla => "xla",
        })
    }
}

/// The valid `--backend` / `ADVGP_BACKEND` values, for error messages
/// and usage text.
pub const BACKEND_CHOICES: &str = "scalar|simd|auto|xla";

impl Backend {
    /// Parse a selector name (case-insensitive, surrounding whitespace
    /// ignored).  Unknown names are a typed error listing the valid
    /// set.
    pub fn parse(s: &str) -> Result<Self, BackendError> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Ok(Self::Scalar),
            "simd" => Ok(Self::Simd),
            "auto" => Ok(Self::Auto),
            "xla" => Ok(Self::Xla),
            other => Err(BackendError(format!(
                "unknown compute backend {other:?} (expected {BACKEND_CHOICES})"
            ))),
        }
    }

    /// [`Backend::from_env`] on an explicit value — the testable core:
    /// `None`/empty ⇒ the scalar default; invalid ⇒ warn + scalar
    /// (mirroring the `ADVGP_THREADS` contract: a bad env var must not
    /// take down a worker fleet).
    pub fn from_env_value(v: Option<&str>) -> Self {
        match v {
            None => Self::Scalar,
            Some(s) if s.trim().is_empty() => Self::Scalar,
            Some(s) => Self::parse(s).unwrap_or_else(|e| {
                log_warn!("ADVGP_BACKEND: {e}; using the scalar backend");
                Self::Scalar
            }),
        }
    }

    /// Default backend from `ADVGP_BACKEND` (scalar when unset).
    pub fn from_env() -> Self {
        Self::from_env_value(std::env::var("ADVGP_BACKEND").ok().as_deref())
    }

    /// Resolve to a concrete kernel set.  `Auto` inspects the host;
    /// `Xla` errors unless the binary carries the feature.
    pub fn resolve(self) -> Result<&'static dyn ComputeBackend, BackendError> {
        code_of(self).map(backend_of)
    }
}

const B_SCALAR: u8 = 0;
const B_SIMD: u8 = 1;
#[cfg(feature = "xla")]
const B_XLA: u8 = 2;

fn code_of(b: Backend) -> Result<u8, BackendError> {
    match b {
        Backend::Scalar => Ok(B_SCALAR),
        Backend::Simd => Ok(B_SIMD),
        Backend::Auto => Ok(if simd::available() { B_SIMD } else { B_SCALAR }),
        Backend::Xla => xla_code(),
    }
}

#[cfg(feature = "xla")]
fn xla_code() -> Result<u8, BackendError> {
    Ok(B_XLA)
}

#[cfg(not(feature = "xla"))]
fn xla_code() -> Result<u8, BackendError> {
    Err(BackendError(
        "backend `xla` requires a binary built with `--features xla`".into(),
    ))
}

fn backend_of(code: u8) -> &'static dyn ComputeBackend {
    match code {
        B_SIMD => &SIMD,
        #[cfg(feature = "xla")]
        B_XLA => &XLA,
        _ => &SCALAR,
    }
}

/// Process-wide active backend (what [`active`] returns).  Scalar by
/// default: every pre-existing bitwise test and the seed θ trajectory
/// depend on the default being the reference kernels.
static ACTIVE: AtomicU8 = AtomicU8::new(B_SCALAR);

/// The process-wide backend used by constructors that don't take one
/// explicitly (`NativeEngine::new`, `SparseGp::new` — and therefore
/// the serving stack's `PosteriorCache` builds).
pub fn active() -> &'static dyn ComputeBackend {
    backend_of(ACTIVE.load(Ordering::Relaxed))
}

/// Install `b` as the process-wide backend.  Typed error if it cannot
/// resolve; on success returns the concrete backend.
pub fn set_active(b: Backend) -> Result<&'static dyn ComputeBackend, BackendError> {
    let code = code_of(b)?;
    ACTIVE.store(code, Ordering::Relaxed);
    Ok(backend_of(code))
}

/// [`set_active`] with the warn-and-fall-back contract used by the
/// training entry points (which have no error channel to the caller):
/// an unresolvable selection logs and pins scalar rather than
/// aborting a fleet.
pub fn activate(b: Backend) -> &'static dyn ComputeBackend {
    set_active(b).unwrap_or_else(|e| {
        log_warn!("backend {b}: {e}; using the scalar backend");
        set_active(Backend::Scalar).expect("scalar backend always resolves")
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_known_names_case_insensitive() {
        assert_eq!(Backend::parse("scalar").unwrap(), Backend::Scalar);
        assert_eq!(Backend::parse("SIMD").unwrap(), Backend::Simd);
        assert_eq!(Backend::parse(" Auto ").unwrap(), Backend::Auto);
        assert_eq!(Backend::parse("xla").unwrap(), Backend::Xla);
    }

    #[test]
    fn parse_unknown_is_typed_error_not_panic() {
        let err = Backend::parse("cuda").unwrap_err();
        assert!(err.0.contains("cuda"), "error names the bad value: {err}");
        assert!(
            err.0.contains(BACKEND_CHOICES),
            "error lists valid values: {err}"
        );
    }

    #[test]
    fn env_value_defaults_and_falls_back() {
        // Unset and empty ⇒ scalar default; garbage warns + scalar
        // (tested through the value-shaped core so no test mutates
        // process env out from under parallel tests).
        assert_eq!(Backend::from_env_value(None), Backend::Scalar);
        assert_eq!(Backend::from_env_value(Some("")), Backend::Scalar);
        assert_eq!(Backend::from_env_value(Some("  ")), Backend::Scalar);
        assert_eq!(Backend::from_env_value(Some("simd")), Backend::Simd);
        assert_eq!(Backend::from_env_value(Some("bogus")), Backend::Scalar);
    }

    #[test]
    fn auto_resolves_by_host_capability() {
        let resolved = Backend::Auto.resolve().unwrap();
        if simd::available() {
            assert_eq!(resolved.name(), "simd");
        } else {
            assert_eq!(resolved.name(), "scalar");
        }
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn xla_without_feature_is_typed_error() {
        let err = Backend::Xla.resolve().unwrap_err();
        assert!(err.0.contains("--features xla"), "{err}");
    }

    #[test]
    fn resolution_names_are_stable() {
        // Bench JSON and logs key on these exact names; asserting
        // resolution identity (not global `active()` state, which
        // parallel tests may legitimately set) keeps this race-free.
        assert_eq!(Backend::Scalar.resolve().unwrap().name(), "scalar");
        assert_eq!(Backend::Simd.resolve().unwrap().name(), "simd");
    }
}
