//! `artifacts/manifest.json` parsing and artifact lookup.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    Grad,
    Predict,
    Elbo,
}

impl ArtifactKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "grad" => Self::Grad,
            "predict" => Self::Predict,
            "elbo" => Self::Elbo,
            other => bail!("unknown artifact kind {other:?}"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub kind: ArtifactKind,
    pub m: usize,
    pub d: usize,
    pub b: usize,
    pub path: PathBuf,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let arts = v
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .context("manifest missing 'artifacts'")?;
        let mut artifacts = Vec::new();
        for a in arts {
            let get_usize = |k: &str| {
                a.get(k)
                    .and_then(|x| x.as_usize())
                    .with_context(|| format!("artifact missing {k}"))
            };
            artifacts.push(ArtifactSpec {
                kind: ArtifactKind::parse(
                    a.get("kind").and_then(|x| x.as_str()).context("kind")?,
                )?,
                m: get_usize("m")?,
                d: get_usize("d")?,
                b: get_usize("b")?,
                path: dir.join(a.get("file").and_then(|x| x.as_str()).context("file")?),
            });
        }
        Ok(Self { dir: dir.to_path_buf(), artifacts })
    }

    /// Find the artifact for (kind, m, d).
    pub fn find(&self, kind: ArtifactKind, m: usize, d: usize) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.kind == kind && a.m == m && a.d == d)
            .with_context(|| {
                format!(
                    "no {kind:?} artifact for m={m}, d={d}; available: {:?}",
                    self.artifacts
                        .iter()
                        .map(|a| (a.kind, a.m, a.d))
                        .collect::<Vec<_>>()
                )
            })
    }

    /// All (m, d) pairs with a full (grad, predict, elbo) triple.
    pub fn complete_configs(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for a in &self.artifacts {
            if a.kind == ArtifactKind::Grad
                && self.find(ArtifactKind::Predict, a.m, a.d).is_ok()
                && self.find(ArtifactKind::Elbo, a.m, a.d).is_ok()
            {
                out.push((a.m, a.d));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest_dir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("advgp_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"artifacts":[
                {"kind":"grad","m":16,"d":4,"b":1024,"file":"g.hlo.txt","block_b":128},
                {"kind":"predict","m":16,"d":4,"b":2048,"file":"p.hlo.txt","block_b":128},
                {"kind":"elbo","m":16,"d":4,"b":2048,"file":"e.hlo.txt","block_b":128}
            ]}"#,
        )
        .unwrap();
        dir
    }

    #[test]
    fn load_and_find() {
        let dir = fake_manifest_dir();
        let man = Manifest::load(&dir).unwrap();
        assert_eq!(man.artifacts.len(), 3);
        let g = man.find(ArtifactKind::Grad, 16, 4).unwrap();
        assert_eq!(g.b, 1024);
        assert!(man.find(ArtifactKind::Grad, 50, 8).is_err());
        assert_eq!(man.complete_configs(), vec![(16, 4)]);
    }

    #[test]
    fn missing_dir_is_actionable() {
        let err = Manifest::load(Path::new("/nonexistent/advgp")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
