//! L-BFGS with backtracking Armijo line search — the optimizer behind
//! the DistGP-LBFGS baseline (Gal et al. 2014 drive the collapsed bound
//! with L-BFGS on the master).

/// Limited-memory BFGS state (two-loop recursion).
pub struct Lbfgs {
    mem: usize,
    s: Vec<Vec<f64>>,
    y: Vec<Vec<f64>>,
    rho: Vec<f64>,
    prev_x: Option<Vec<f64>>,
    prev_g: Option<Vec<f64>>,
}

impl Lbfgs {
    pub fn new(mem: usize) -> Self {
        Self { mem, s: vec![], y: vec![], rho: vec![], prev_x: None, prev_g: None }
    }

    /// Two-loop recursion: returns the descent direction −H·g.
    pub fn direction(&self, g: &[f64]) -> Vec<f64> {
        let k = self.s.len();
        let mut q = g.to_vec();
        let mut alpha = vec![0.0; k];
        for i in (0..k).rev() {
            alpha[i] = self.rho[i] * dot(&self.s[i], &q);
            axpy(&mut q, -alpha[i], &self.y[i]);
        }
        // Initial Hessian scaling γ = s·y / y·y.
        if let (Some(s), Some(y)) = (self.s.last(), self.y.last()) {
            let gamma = dot(s, y) / dot(y, y).max(1e-300);
            for v in &mut q {
                *v *= gamma;
            }
        }
        for i in 0..k {
            let beta = self.rho[i] * dot(&self.y[i], &q);
            axpy(&mut q, alpha[i] - beta, &self.s[i]);
        }
        for v in &mut q {
            *v = -*v;
        }
        q
    }

    /// Record the accepted step (x_{t+1}, g_{t+1}).
    pub fn update(&mut self, x: &[f64], g: &[f64]) {
        if let (Some(px), Some(pg)) = (&self.prev_x, &self.prev_g) {
            let s: Vec<f64> = x.iter().zip(px).map(|(a, b)| a - b).collect();
            let y: Vec<f64> = g.iter().zip(pg).map(|(a, b)| a - b).collect();
            let sy = dot(&s, &y);
            if sy > 1e-10 * norm(&s) * norm(&y) {
                // Curvature condition holds: keep the pair.
                self.s.push(s);
                self.y.push(y);
                self.rho.push(1.0 / sy);
                if self.s.len() > self.mem {
                    self.s.remove(0);
                    self.y.remove(0);
                    self.rho.remove(0);
                }
            }
        }
        self.prev_x = Some(x.to_vec());
        self.prev_g = Some(g.to_vec());
    }

    pub fn reset(&mut self) {
        self.s.clear();
        self.y.clear();
        self.rho.clear();
        self.prev_x = None;
        self.prev_g = None;
    }
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// One L-BFGS step with backtracking Armijo line search.
/// `f` evaluates (value, gradient).  Returns (new_x, new_value, evals).
pub fn lbfgs_step<F>(
    opt: &mut Lbfgs,
    x: &[f64],
    fx: f64,
    gx: &[f64],
    mut f: F,
) -> (Vec<f64>, f64, usize)
where
    F: FnMut(&[f64]) -> (f64, Vec<f64>),
{
    opt.update(x, gx);
    let dir = opt.direction(gx);
    let slope = dot(&dir, gx);
    // Fall back to steepest descent if the direction isn't a descent dir.
    let (dir, slope) = if slope < 0.0 {
        (dir, slope)
    } else {
        let d: Vec<f64> = gx.iter().map(|g| -g).collect();
        let s = dot(&d, gx);
        (d, s)
    };
    let mut step = 1.0;
    let c1 = 1e-4;
    let mut evals = 0;
    for _ in 0..30 {
        let cand: Vec<f64> = x.iter().zip(&dir).map(|(xi, di)| xi + step * di).collect();
        let (val, _g) = f(&cand);
        evals += 1;
        if val.is_finite() && val <= fx + c1 * step * slope {
            return (cand, val, evals);
        }
        step *= 0.5;
    }
    // Line search failed: stay put (caller may reset the memory).
    (x.to_vec(), fx, evals)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rosenbrock(x: &[f64]) -> (f64, Vec<f64>) {
        let (a, b) = (1.0, 100.0);
        let f = (a - x[0]).powi(2) + b * (x[1] - x[0] * x[0]).powi(2);
        let g = vec![
            -2.0 * (a - x[0]) - 4.0 * b * x[0] * (x[1] - x[0] * x[0]),
            2.0 * b * (x[1] - x[0] * x[0]),
        ];
        (f, g)
    }

    #[test]
    fn solves_rosenbrock() {
        let mut opt = Lbfgs::new(8);
        let mut x = vec![-1.2, 1.0];
        let (mut fx, mut gx) = rosenbrock(&x);
        for _ in 0..1000 {
            let (nx, nf, _) = lbfgs_step(&mut opt, &x, fx, &gx, rosenbrock);
            x = nx;
            let (f2, g2) = rosenbrock(&x);
            fx = f2;
            gx = g2;
            if nf < 1e-12 {
                break;
            }
        }
        assert!((x[0] - 1.0).abs() < 1e-4 && (x[1] - 1.0).abs() < 1e-4,
                "x={x:?} f={fx}");
    }

    #[test]
    fn quadratic_converges_fast() {
        // f = 0.5 x^T diag(c) x: L-BFGS should crush this in few iters.
        let c = [10.0, 1.0, 0.1, 100.0];
        let f = |x: &[f64]| {
            let v = 0.5 * x.iter().zip(&c).map(|(xi, ci)| ci * xi * xi).sum::<f64>();
            let g: Vec<f64> = x.iter().zip(&c).map(|(xi, ci)| ci * xi).collect();
            (v, g)
        };
        let mut opt = Lbfgs::new(6);
        let mut x = vec![1.0; 4];
        let (mut fx, mut gx) = f(&x);
        for _ in 0..40 {
            let (nx, _, _) = lbfgs_step(&mut opt, &x, fx, &gx, f);
            x = nx;
            let r = f(&x);
            fx = r.0;
            gx = r.1;
        }
        assert!(fx < 1e-10, "f={fx}");
    }

    #[test]
    fn monotone_nonincreasing() {
        let f = |x: &[f64]| {
            let v = (x[0] - 3.0).powi(4) + x[1] * x[1];
            (v, vec![4.0 * (x[0] - 3.0).powi(3), 2.0 * x[1]])
        };
        let mut opt = Lbfgs::new(5);
        let mut x = vec![0.0, 5.0];
        let (mut fx, mut gx) = f(&x);
        for _ in 0..50 {
            let (nx, nf, _) = lbfgs_step(&mut opt, &x, fx, &gx, f);
            assert!(nf <= fx + 1e-12, "went uphill: {nf} > {fx}");
            x = nx;
            let r = f(&x);
            fx = r.0;
            gx = r.1;
        }
        assert!(fx < 1e-3);
    }
}
