//! Optimizers: ADADELTA (paper §6.1), the ADVGP proximal operator
//! (paper eqs. 18–20), plain SGD, and L-BFGS (for the DistGP-LBFGS
//! baseline).
//!
//! Key invariants:
//! * The proximal projection keeps diag(U) strictly positive (eq. 20's
//!   closed form), so Σ = UᵀU stays SPD at every server update.
//! * [`AdaDelta`] state is checkpointable: `params`/`state` +
//!   `from_state` round-trip bitwise, which is what makes
//!   `ps::checkpoint` resumes exact.

pub mod adadelta;
pub mod lbfgs;
pub mod prox;

pub use adadelta::AdaDelta;
pub use lbfgs::Lbfgs;
pub use prox::prox_update;

/// Theorem 4.1-style decaying global scale: γ_t = c / (1 + t / t0).
/// Composed with ADADELTA's per-coordinate adaptation (§6.1), this keeps
/// γ_t ≤ ((1+τ)C + ε)^{-1} eventually, for any Lipschitz constant C.
#[derive(Clone, Copy, Debug)]
pub struct StepSchedule {
    pub c: f64,
    pub t0: f64,
}

impl StepSchedule {
    pub fn new(c: f64, t0: f64) -> Self {
        Self { c, t0 }
    }

    pub fn at(&self, t: u64) -> f64 {
        self.c / (1.0 + t as f64 / self.t0)
    }
}

/// Plain SGD step (used by the linear baseline).
pub fn sgd_step(w: &mut [f64], grad: &[f64], lr: f64) {
    for (wi, gi) in w.iter_mut().zip(grad) {
        *wi -= lr * gi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_monotone_decreasing() {
        let s = StepSchedule::new(0.5, 100.0);
        assert_eq!(s.at(0), 0.5);
        let mut prev = f64::INFINITY;
        for t in [0, 10, 100, 1000, 100_000] {
            let g = s.at(t);
            assert!(g <= prev);
            assert!(g > 0.0);
            prev = g;
        }
    }

    #[test]
    fn sgd_minimizes_quadratic() {
        // f(w) = ||w - a||^2 / 2
        let a = [1.0, -2.0, 3.0];
        let mut w = [0.0; 3];
        for _ in 0..200 {
            let g: Vec<f64> = w.iter().zip(&a).map(|(wi, ai)| wi - ai).collect();
            sgd_step(&mut w, &g, 0.1);
        }
        for (wi, ai) in w.iter().zip(&a) {
            assert!((wi - ai).abs() < 1e-6);
        }
    }
}
