//! The ADVGP proximal operator (paper eqs. 13, 18–20).
//!
//! After the delayed gradient step produces θ′, the server projects the
//! variational block toward the minimum of the convex KL term h:
//!
//!   Prox_γ[θ′] = argmin_θ  h(θ) + ‖θ − θ′‖² / (2γ)
//!
//! With h of eq. (24) this is closed-form and **element-wise**:
//!   μ_i   = μ′_i / (1 + γ)                                   (18)
//!   U_ij  = U′_ij / (1 + γ)            (i ≠ j)               (19)
//!   U_ii  = (U′_ii + √(U′_ii² + 4(1+γ)γ)) / (2(1+γ))         (20)
//!
//! Eq. (20) keeps diag(U) > 0 for any input, i.e. Σ = UᵀU stays SPD by
//! construction — the property the whole asynchronous scheme leans on.

use crate::gp::ThetaLayout;

/// Apply the proximal projection to the variational block of θ′ in
/// place.  Non-variational coordinates (Z, kernel, noise) are left
/// untouched: for them h is constant, so Prox is the identity
/// (Algorithm 1 line 4).
pub fn prox_update(layout: &ThetaLayout, theta: &mut [f64], gamma: f64) {
    assert!(gamma >= 0.0, "negative step {gamma}");
    let scale = 1.0 / (1.0 + gamma);
    for v in &mut theta[layout.mu_range()] {
        *v *= scale; // eq. (18)
    }
    let m = layout.m;
    let ur = layout.u_range();
    let u = &mut theta[ur];
    for i in 0..m {
        for j in 0..m {
            let idx = i * m + j;
            if i == j {
                // eq. (20)
                let up = u[idx];
                u[idx] = (up + (up * up + 4.0 * (1.0 + gamma) * gamma).sqrt())
                    / (2.0 * (1.0 + gamma));
            } else {
                u[idx] *= scale; // eq. (19)
            }
        }
    }
}

/// Numeric check helper: the prox objective for a single diagonal entry.
#[cfg(test)]
fn diag_objective(u: f64, up: f64, gamma: f64) -> f64 {
    // h contribution of one diagonal entry: ½(−2 ln u + u²) (from eq. 24);
    // plus the proximal quadratic.
    0.5 * (-2.0 * u.ln() + u * u) + (u - up) * (u - up) / (2.0 * gamma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::Theta;
    use crate::linalg::Mat;
    use crate::util::rng::Pcg64;

    #[test]
    fn gamma_zero_is_identity() {
        let layout = ThetaLayout::new(4, 2);
        let mut rng = Pcg64::seeded(1);
        let mut theta: Vec<f64> = (0..layout.len()).map(|_| rng.normal()).collect();
        let before = theta.clone();
        prox_update(&layout, &mut theta, 0.0);
        // μ and off-diag unchanged; diag maps u ↦ (u + |u|)/2 only when
        // γ = 0: (u + sqrt(u²))/2 = max(u, 0) — for positive diag it's id.
        for i in 0..layout.len() {
            if layout.is_u_diag(i) {
                assert!((theta[i] - before[i].max(0.0)).abs() < 1e-12);
            } else {
                assert_eq!(theta[i], before[i]);
            }
        }
    }

    #[test]
    fn diag_stays_positive_for_any_input() {
        let layout = ThetaLayout::new(3, 1);
        for seed in 0..20 {
            let mut rng = Pcg64::seeded(seed);
            let mut theta: Vec<f64> =
                (0..layout.len()).map(|_| rng.normal() * 10.0).collect();
            let gamma = 0.01 + rng.next_f64();
            prox_update(&layout, &mut theta, gamma);
            for i in 0..layout.len() {
                if layout.is_u_diag(i) {
                    assert!(theta[i] > 0.0, "diag went nonpositive: {}", theta[i]);
                }
            }
        }
    }

    #[test]
    fn diag_update_is_argmin_of_prox_objective() {
        // eq. (20) must minimize ½(−2 ln u + u²) + (u−u′)²/(2γ) over u>0.
        for &(up, gamma) in
            &[(1.0, 0.5), (-2.0, 0.3), (0.1, 2.0), (5.0, 0.01), (-0.5, 1.0)]
        {
            let layout = ThetaLayout::new(1, 1);
            let mut theta = vec![0.0; layout.len()];
            theta[layout.u_range().start] = up;
            prox_update(&layout, &mut theta, gamma);
            let star = theta[layout.u_range().start];
            let f_star = diag_objective(star, up, gamma);
            // Grid around the solution.
            for delta in [-1e-3, -1e-4, 1e-4, 1e-3] {
                let u = (star + delta).max(1e-9);
                assert!(
                    diag_objective(u, up, gamma) >= f_star - 1e-12,
                    "up={up} gamma={gamma}: not a minimum"
                );
            }
        }
    }

    #[test]
    fn mu_and_offdiag_shrink_toward_prior() {
        // The prox pulls q(w) toward N(0, I): μ shrinks, off-diag shrinks,
        // and a unit diagonal is a fixed point (KL gradient zero there).
        let layout = ThetaLayout::new(3, 2);
        let z = Mat::zeros(3, 2);
        let mut th = Theta::init(layout, &z);
        th.mu_mut().copy_from_slice(&[1.0, -2.0, 0.5]);
        let mut u = Mat::eye(3);
        u[(0, 1)] = 0.4;
        th.set_u_mat(&u);
        let kl_before = th.kl();
        prox_update(&layout, &mut th.data, 0.5);
        let kl_after = th.kl();
        assert!(kl_after < kl_before);
        // Unit diagonal ~ fixed point of eq. (20):
        // (1 + sqrt(1 + 4(1+γ)γ)) / (2(1+γ)) with γ=0.5 →
        let want = (1.0 + (1.0f64 + 4.0 * 1.5 * 0.5).sqrt()) / 3.0;
        let got = th.u_mat()[(1, 1)];
        assert!((got - want).abs() < 1e-12);
        assert!((want - 1.0).abs() < 0.01, "unit diag moves little: {want}");
    }

    #[test]
    fn hyperparameters_untouched() {
        let layout = ThetaLayout::new(2, 3);
        let mut rng = Pcg64::seeded(5);
        let mut theta: Vec<f64> = (0..layout.len()).map(|_| rng.normal()).collect();
        let before = theta.clone();
        prox_update(&layout, &mut theta, 0.7);
        for i in layout.z_range().start..layout.len() {
            assert_eq!(theta[i], before[i], "hyper {i} changed");
        }
    }
}
