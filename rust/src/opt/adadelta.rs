//! ADADELTA (Zeiler, 2012) — the paper's §6.1 choice for adapting the
//! gradient-descent step before the proximal projection.
//!
//! Per coordinate i:
//!   E[g²]_i ← ρ E[g²]_i + (1−ρ) g_i²
//!   Δ_i     = −√(E[Δ²]_i + ε) / √(E[g²]_i + ε) · g_i
//!   E[Δ²]_i ← ρ E[Δ²]_i + (1−ρ) Δ_i²

#[derive(Clone, Debug)]
pub struct AdaDelta {
    rho: f64,
    eps: f64,
    eg2: Vec<f64>,
    ed2: Vec<f64>,
}

impl AdaDelta {
    pub fn new(dim: usize, rho: f64, eps: f64) -> Self {
        Self { rho, eps, eg2: vec![0.0; dim], ed2: vec![0.0; dim] }
    }

    /// Zeiler's defaults.  (eps=1e-3 was tried during the perf pass:
    /// the warmer start overshoots on full-batch gradients and stalls —
    /// see EXPERIMENTS.md §Perf tuning log.)
    pub fn default_for(dim: usize) -> Self {
        Self::new(dim, 0.95, 1e-6)
    }

    /// Compute the (negative) update Δ for `grad` and roll the state.
    /// Returns the step to *add* to the parameters.
    pub fn step(&mut self, grad: &[f64]) -> Vec<f64> {
        assert_eq!(grad.len(), self.eg2.len());
        let mut delta = vec![0.0; grad.len()];
        for i in 0..grad.len() {
            let g = grad[i];
            self.eg2[i] = self.rho * self.eg2[i] + (1.0 - self.rho) * g * g;
            let d = -((self.ed2[i] + self.eps).sqrt()
                / (self.eg2[i] + self.eps).sqrt())
                * g;
            self.ed2[i] = self.rho * self.ed2[i] + (1.0 - self.rho) * d * d;
            delta[i] = d;
        }
        delta
    }

    /// `(ρ, ε)` hyperparameters — for checkpointing.
    pub fn params(&self) -> (f64, f64) {
        (self.rho, self.eps)
    }

    /// Accumulator state `(E[g²], E[Δ²])` — for checkpointing.
    pub fn state(&self) -> (&[f64], &[f64]) {
        (&self.eg2, &self.ed2)
    }

    /// Rebuild an optimizer from checkpointed state (the inverse of
    /// [`AdaDelta::params`] + [`AdaDelta::state`]): the next `step` is
    /// bitwise-identical to what the checkpointed instance would have
    /// produced.
    pub fn from_state(rho: f64, eps: f64, eg2: Vec<f64>, ed2: Vec<f64>) -> Self {
        assert_eq!(eg2.len(), ed2.len(), "accumulator length mismatch");
        Self { rho, eps, eg2, ed2 }
    }

    /// Apply in place: θ ← θ + scale·Δ(grad).
    pub fn apply(&mut self, theta: &mut [f64], grad: &[f64], scale: f64) {
        let delta = self.step(grad);
        for (t, d) in theta.iter_mut().zip(delta) {
            *t += scale * d;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descends_a_quadratic() {
        // f(x) = 0.5 * sum c_i x_i^2 with wildly different curvatures —
        // the case ADADELTA's per-coordinate scaling is built for.
        let c = [100.0, 1.0, 0.01];
        let mut x = [1.0, 1.0, 1.0];
        let mut opt = AdaDelta::default_for(3);
        let f = |x: &[f64; 3]| 0.5 * (c[0] * x[0] * x[0] + c[1] * x[1] * x[1] + c[2] * x[2] * x[2]);
        let f0 = f(&x);
        for _ in 0..3000 {
            let g = [c[0] * x[0], c[1] * x[1], c[2] * x[2]];
            opt.apply(&mut x, &g, 1.0);
        }
        assert!(f(&x) < 1e-3 * f0, "f={} from {}", f(&x), f0);
    }

    #[test]
    fn first_step_is_sqrt_eps_scaled() {
        let mut opt = AdaDelta::new(1, 0.95, 1e-6);
        let d = opt.step(&[10.0]);
        // E[g²] = 0.05*100 = 5 ; Δ = -sqrt(1e-6)/sqrt(5+1e-6)*10
        let want = -(1e-6f64).sqrt() / (5.0f64 + 1e-6).sqrt() * 10.0;
        assert!((d[0] - want).abs() < 1e-12);
        // Scale invariance: 100x gradient, (almost) identical step.
        let mut a = AdaDelta::new(1, 0.95, 1e-12);
        let mut b = AdaDelta::new(1, 0.95, 1e-12);
        let da = a.step(&[3.0]);
        let db = b.step(&[300.0]);
        assert!((da[0] - db[0]).abs() < 1e-9, "{} vs {}", da[0], db[0]);
    }

    /// Checkpoint fidelity: an optimizer rebuilt via `from_state` must
    /// continue the original trajectory bitwise.
    #[test]
    fn state_roundtrip_continues_bitwise() {
        let mut a = AdaDelta::default_for(3);
        for i in 0..10 {
            a.step(&[1.0 + i as f64, -2.0, 0.5]);
        }
        let (rho, eps) = a.params();
        let (eg2, ed2) = a.state();
        let mut b = AdaDelta::from_state(rho, eps, eg2.to_vec(), ed2.to_vec());
        let da = a.step(&[0.3, 0.7, -1.1]);
        let db = b.step(&[0.3, 0.7, -1.1]);
        for (x, y) in da.iter().zip(&db) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn zero_grad_is_fixed_point() {
        let mut opt = AdaDelta::default_for(4);
        let mut x = [1.0, 2.0, 3.0, 4.0];
        let before = x;
        opt.apply(&mut x, &[0.0; 4], 1.0);
        assert_eq!(x, before);
    }
}
