//! Cyclic Jacobi eigendecomposition for symmetric matrices.
//!
//! Used by the Nyström / EigenGP feature maps (paper eqs. 21–22), which
//! need eigenvectors/eigenvalues of the m×m inducing covariance.
//! O(m^3) per sweep with quadratic convergence; m ≤ a few hundred here.

use super::Mat;

/// Returns (eigenvalues desc, eigenvectors as columns), A = V diag(w) V^T.
pub fn sym_eig(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut m = a.clone();
    // Symmetrize defensively.
    for i in 0..n {
        for j in 0..i {
            let s = 0.5 * (m[(i, j)] + m[(j, i)]);
            m[(i, j)] = s;
            m[(j, i)] = s;
        }
    }
    let mut v = Mat::eye(n);
    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-12 * (1.0 + m.frob_norm()) {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q of M.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut w = m.diag();
    // Sort descending, permuting eigenvector columns to match.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| w[j].partial_cmp(&w[i]).unwrap());
    let w_sorted: Vec<f64> = order.iter().map(|&i| w[i]).collect();
    let mut v_sorted = Mat::zeros(n, n);
    for (new_c, &old_c) in order.iter().enumerate() {
        for r in 0..n {
            v_sorted[(r, new_c)] = v[(r, old_c)];
        }
    }
    w = w_sorted;
    (w, v_sorted)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn reconstructs_and_orthonormal() {
        let mut rng = Pcg64::seeded(21);
        for n in [1, 2, 5, 30] {
            let a = Mat::from_vec(n, n, (0..n * n).map(|_| rng.normal()).collect());
            let s = {
                let mut s = a.transpose().matmul(&a);
                s.scale(1.0 / n as f64);
                s
            };
            let (w, v) = sym_eig(&s);
            // V diag(w) V^T == S
            let mut dw = Mat::zeros(n, n);
            for i in 0..n {
                dw[(i, i)] = w[i];
            }
            let back = v.matmul(&dw).matmul(&v.transpose());
            assert!(back.max_abs_diff(&s) < 1e-8, "n={n}");
            // V orthonormal
            let vtv = v.transpose().matmul(&v);
            assert!(vtv.max_abs_diff(&Mat::eye(n)) < 1e-9);
            // Sorted descending
            for i in 1..n {
                assert!(w[i - 1] >= w[i] - 1e-12);
            }
            // PSD input -> nonnegative eigenvalues
            assert!(w.iter().all(|&x| x > -1e-9));
        }
    }

    #[test]
    fn known_2x2() {
        let a = Mat::from_rows(vec![vec![2.0, 1.0], vec![1.0, 2.0]]);
        let (w, _) = sym_eig(&a);
        assert!((w[0] - 3.0).abs() < 1e-12);
        assert!((w[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn diagonal_is_fixed_point() {
        let mut d = Mat::zeros(4, 4);
        for (i, x) in [4.0, 3.0, 2.0, 1.0].iter().enumerate() {
            d[(i, i)] = *x;
        }
        let (w, v) = sym_eig(&d);
        assert_eq!(w, vec![4.0, 3.0, 2.0, 1.0]);
        assert!(v.max_abs_diff(&Mat::eye(4)) < 1e-12);
    }
}
