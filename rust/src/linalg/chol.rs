//! Cholesky factorization, triangular solves, SPD inverse.

use super::Mat;

#[derive(Debug, Clone, PartialEq)]
pub enum CholError {
    /// Leading minor `i` is not positive definite.
    NotPositiveDefinite(usize),
}

impl std::fmt::Display for CholError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CholError::NotPositiveDefinite(i) => {
                write!(f, "matrix not positive definite at pivot {i}")
            }
        }
    }
}

impl std::error::Error for CholError {}

/// Lower Cholesky factor L with A = L L^T.  `A` must be symmetric.
pub fn cholesky_lower(a: &Mat) -> Result<Mat, CholError> {
    assert_eq!(a.rows, a.cols, "cholesky wants square");
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // s = A[i][j] - sum_k L[i][k] L[j][k]
            let mut s = a[(i, j)];
            let (li, lj) = (l.row(i), l.row(j));
            for k in 0..j {
                s -= li[k] * lj[k];
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    return Err(CholError::NotPositiveDefinite(i));
                }
                l[(i, i)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Solve L x = b for lower-triangular L (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        let row = l.row(i);
        for k in 0..i {
            s -= row[k] * x[k];
        }
        x[i] = s / row[i];
    }
    x
}

/// Solve U x = b for upper-triangular U (back substitution).
pub fn solve_upper(u: &Mat, b: &[f64]) -> Vec<f64> {
    let n = u.rows;
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        let row = u.row(i);
        for k in i + 1..n {
            s -= row[k] * x[k];
        }
        x[i] = s / row[i];
    }
    x
}

/// Inverse of an SPD matrix via Cholesky: A^{-1} = L^{-T} L^{-1}.
pub fn spd_inverse(a: &Mat) -> Result<Mat, CholError> {
    let n = a.rows;
    let l = cholesky_lower(a)?;
    // Invert L by forward-substituting the identity columns, building
    // Linv (lower-triangular).
    let mut linv = Mat::zeros(n, n);
    for col in 0..n {
        let mut e = vec![0.0; n];
        e[col] = 1.0;
        let x = solve_lower(&l, &e);
        for r in col..n {
            linv[(r, col)] = x[r];
        }
    }
    // A^{-1} = Linv^T Linv; exploit symmetry.
    let mut inv = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let mut s = 0.0;
            for k in i.max(j)..n {
                s += linv[(k, i)] * linv[(k, j)];
            }
            inv[(i, j)] = s;
            inv[(j, i)] = s;
        }
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_spd(rng: &mut Pcg64, n: usize) -> Mat {
        let a = Mat::from_vec(n, n, (0..n * n).map(|_| rng.normal()).collect());
        let mut s = a.transpose().matmul(&a);
        for i in 0..n {
            s[(i, i)] += n as f64 * 0.1;
        }
        s
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Pcg64::seeded(10);
        for n in [1, 2, 5, 20, 64] {
            let a = random_spd(&mut rng, n);
            let l = cholesky_lower(&a).unwrap();
            let back = l.matmul(&l.transpose());
            assert!(back.max_abs_diff(&a) < 1e-9 * n as f64, "n={n}");
            // L is lower-triangular with positive diagonal.
            for i in 0..n {
                assert!(l[(i, i)] > 0.0);
                for j in i + 1..n {
                    assert_eq!(l[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(matches!(
            cholesky_lower(&a),
            Err(CholError::NotPositiveDefinite(1))
        ));
    }

    #[test]
    fn triangular_solves() {
        let mut rng = Pcg64::seeded(11);
        let a = random_spd(&mut rng, 12);
        let l = cholesky_lower(&a).unwrap();
        let x_true: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let b = l.matvec(&x_true);
        let x = solve_lower(&l, &b);
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-10);
        }
        let u_mat = l.transpose();
        let b2 = u_mat.matvec(&x_true);
        let x2 = solve_upper(&u_mat, &b2);
        for (u, v) in x2.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-10);
        }
    }

    #[test]
    fn spd_inverse_identity() {
        let mut rng = Pcg64::seeded(12);
        for n in [1, 3, 10, 40] {
            let a = random_spd(&mut rng, n);
            let inv = spd_inverse(&a).unwrap();
            let prod = a.matmul(&inv);
            assert!(prod.max_abs_diff(&Mat::eye(n)) < 1e-8, "n={n}");
            // Symmetric.
            assert!(inv.max_abs_diff(&inv.transpose()) < 1e-12);
        }
    }
}
