//! Explicit-width SIMD twins of the hot-path row kernels (ISSUE 10).
//!
//! Std-only: no packed intrinsics are written by hand.  Instead every
//! kernel here is an ordinary safe Rust function shaped so LLVM's
//! vectorizer maps it onto full-width vector code, and each one is
//! compiled **twice**:
//!
//! * a **generic** copy at the crate's baseline target features (the
//!   portable fallback — on aarch64 the baseline already includes
//!   NEON, so this copy *is* the SIMD path there), and
//! * on x86_64, an **AVX2** copy behind `#[target_feature(enable =
//!   "avx2")]`, selected at runtime via `is_x86_feature_detected!`
//!   (cached after the first query).
//!
//! The only `unsafe` in this module is the call into the
//! `#[target_feature]` clone, guarded by that runtime detection.
//!
//! # Two kernel families, two determinism contracts
//!
//! **Reduction kernels** ([`dot`], [`sumsq`], [`matvec_rows`],
//! [`mul_tril_t_rows`], [`mul_triu_t_rows`], [`cross_rows`],
//! [`cross_pairwise_rows`]) accumulate into a `LANES`-wide array with
//! a fixed pairwise reduction tree.  This *reassociates* the sum
//! relative to the scalar kernels in [`super`] (which unroll 4-way),
//! so results differ from the scalar backend by rounding only — the
//! per-backend tolerance contract (`rust/tests/backend_contract.rs`)
//! bounds the element-wise relative error.  Across *this module's own*
//! dispatch paths the accumulation order is identical, so AVX2 vs
//! generic is bitwise (pinned by [`self_check`]).
//!
//! **Broadcast-chain kernels** (`matmul_rows`, `gram_rows`, … — every
//! kernel where each output element owns an independent `+=` chain)
//! are not re-implemented at all: the scalar row kernels from
//! [`super`] are inlined into the AVX2 wrapper and re-vectorized at
//! the wider ISA.  Vectorizing independent accumulator chains is
//! semantics-preserving, and we deliberately do **not** enable `fma`
//! (contraction would change results), so these kernels stay bitwise
//! identical to the scalar backend on every path.
//!
//! # Forcing the fallback
//!
//! `ADVGP_SIMD_FALLBACK=1` pins dispatch to the generic copies even on
//! AVX2-capable hardware (read once, cached).  It pins the *dispatch
//! path*, not backend selection — [`available`] ignores it — so CI can
//! run the whole SIMD contract suite down the no-intrinsics path.

use super::Mat;
#[cfg(target_arch = "x86_64")]
use std::sync::atomic::{AtomicU8, Ordering};

/// Accumulator width for the reduction kernels: 8 f64 lanes = two
/// 256-bit AVX2 registers (or four 128-bit NEON registers), enough to
/// hide FP add latency without spilling on either ISA.
pub const LANES: usize = 8;

/// Fixed pairwise reduction of the lane accumulators.  The tree shape
/// is part of the numeric contract: it must not depend on the dispatch
/// path, or [`self_check`] would fail.
#[inline(always)]
fn reduce(acc: [f64; LANES]) -> f64 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

// ---------------------------------------------------------------------
// Generic kernel bodies.  `#[inline(always)]` is load-bearing: the
// `#[target_feature]` wrappers below must inline these so the AVX2
// codegen actually applies to the loops.
// ---------------------------------------------------------------------

#[inline(always)]
fn dot_impl(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        for l in 0..LANES {
            acc[l] += xa[l] * xb[l];
        }
    }
    let mut s = reduce(acc);
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s += x * y;
    }
    s
}

#[inline(always)]
fn sumsq_impl(a: &[f64]) -> f64 {
    let mut acc = [0.0f64; LANES];
    let mut ca = a.chunks_exact(LANES);
    for xa in ca.by_ref() {
        for l in 0..LANES {
            acc[l] += xa[l] * xa[l];
        }
    }
    let mut s = reduce(acc);
    for x in ca.remainder() {
        s += x * x;
    }
    s
}

#[inline(always)]
fn matvec_rows_impl(a: &Mat, x: &[f64], r0: usize, rows: usize, out: &mut [f64]) {
    for (i, v) in out.iter_mut().enumerate().take(rows) {
        *v = dot_impl(a.row(r0 + i), x);
    }
}

#[inline(always)]
fn mul_tril_t_rows_impl(a: &Mat, l: &Mat, r0: usize, rows: usize, out: &mut [f64]) {
    let n = l.rows;
    debug_assert_eq!(out.len(), rows * n);
    for i in 0..rows {
        let arow = a.row(r0 + i);
        let crow = &mut out[i * n..(i + 1) * n];
        for (j, slot) in crow.iter_mut().enumerate() {
            *slot = dot_impl(&arow[..=j], &l.row(j)[..=j]);
        }
    }
}

#[inline(always)]
fn mul_triu_t_rows_impl(a: &Mat, u: &Mat, r0: usize, rows: usize, out: &mut [f64]) {
    let n = u.rows;
    debug_assert_eq!(out.len(), rows * n);
    for i in 0..rows {
        let arow = a.row(r0 + i);
        let crow = &mut out[i * n..(i + 1) * n];
        for (j, slot) in crow.iter_mut().enumerate() {
            *slot = dot_impl(&arow[j..], &u.row(j)[j..]);
        }
    }
}

/// SIMD twin of the fast-form cross-covariance row kernel in
/// [`crate::kernel::cross_into_ws`]: `ze`/`zn` are the η-scaled
/// inducing rows and η-norms prepared by `CrossScratch`.
#[inline(always)]
fn cross_rows_impl(
    a0_sq: f64,
    eta: &[f64],
    x: &Mat,
    ze: &Mat,
    zn: &[f64],
    r0: usize,
    blk: &mut [f64],
) {
    let m = ze.rows;
    for (i, orow) in blk.chunks_mut(m).enumerate() {
        let xrow = x.row(r0 + i);
        let mut xn = 0.0;
        for (c, &e) in eta.iter().enumerate() {
            xn += e * xrow[c] * xrow[c];
        }
        for (j, v) in orow.iter_mut().enumerate() {
            let d2 = (xn + zn[j] - 2.0 * dot_impl(xrow, ze.row(j))).max(0.0);
            *v = a0_sq * (-0.5 * d2).exp();
        }
    }
}

/// SIMD twin of the exact per-pair row kernel in
/// [`crate::kernel::cross_pairwise`] (lane-array accumulation of the
/// η-weighted squared distance).
#[inline(always)]
fn cross_pairwise_rows_impl(
    a0_sq: f64,
    eta: &[f64],
    x: &Mat,
    z: &Mat,
    r0: usize,
    blk: &mut [f64],
) {
    let m = z.rows;
    for (i, krow) in blk.chunks_mut(m).enumerate() {
        let xi = x.row(r0 + i);
        for (j, slot) in krow.iter_mut().enumerate() {
            let zj = z.row(j);
            let mut acc = [0.0f64; LANES];
            let mut cx = xi.chunks_exact(LANES);
            let mut cz = zj.chunks_exact(LANES);
            let mut ce = eta.chunks_exact(LANES);
            for ((xa, za), ea) in cx.by_ref().zip(cz.by_ref()).zip(ce.by_ref()) {
                for l in 0..LANES {
                    let diff = xa[l] - za[l];
                    acc[l] += diff * diff * ea[l];
                }
            }
            let mut d2 = reduce(acc);
            for ((xv, zv), ev) in cx
                .remainder()
                .iter()
                .zip(cz.remainder())
                .zip(ce.remainder())
            {
                let diff = xv - zv;
                d2 += diff * diff * ev;
            }
            *slot = a0_sq * (-0.5 * d2).exp();
        }
    }
}

// ---------------------------------------------------------------------
// Runtime dispatch.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
const PATH_UNKNOWN: u8 = 0;
#[cfg(target_arch = "x86_64")]
const PATH_ACCEL: u8 = 1;
#[cfg(target_arch = "x86_64")]
const PATH_GENERIC: u8 = 2;

/// Cached dispatch decision (feature detection + env override are read
/// once; `Relaxed` is fine — worst case two threads both detect).
#[cfg(target_arch = "x86_64")]
static PATH: AtomicU8 = AtomicU8::new(PATH_UNKNOWN);

#[cfg(target_arch = "x86_64")]
fn fallback_forced() -> bool {
    std::env::var_os("ADVGP_SIMD_FALLBACK").is_some_and(|v| v == "1")
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn avx2_enabled() -> bool {
    match PATH.load(Ordering::Relaxed) {
        PATH_ACCEL => true,
        PATH_GENERIC => false,
        _ => {
            let on = !fallback_forced() && std::is_x86_feature_detected!("avx2");
            PATH.store(
                if on { PATH_ACCEL } else { PATH_GENERIC },
                Ordering::Relaxed,
            );
            on
        }
    }
}

/// Whether this build/host has a SIMD path worth selecting via
/// `Backend::Auto`: AVX2 on x86_64, always on aarch64 (NEON is
/// baseline, so the generic copies are already vector code).  Ignores
/// `ADVGP_SIMD_FALLBACK`, which pins the dispatch *path*, not backend
/// choice.
pub fn available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::is_x86_feature_detected!("avx2")
    }
    #[cfg(target_arch = "aarch64")]
    {
        true
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        false
    }
}

/// Which copy of the kernels calls through this module run: for logs,
/// bench JSON, and the CI forced-fallback run.
pub fn active_path() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_enabled() {
            "x86_64-avx2"
        } else {
            "generic"
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        "aarch64-neon"
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        "generic"
    }
}

/// Compile `$imp` twice (generic + AVX2 on x86_64) and emit `$name` as
/// the runtime-dispatched entry point.  The `unsafe` block is sound
/// because the AVX2 clone is only reachable after
/// `is_x86_feature_detected!("avx2")` returned true.
macro_rules! dispatch {
    ($(#[$meta:meta])* $vis:vis fn $name:ident(
        $($arg:ident: $ty:ty),* $(,)?
    ) $(-> $ret:ty)? = $imp:path;) => {
        $(#[$meta])*
        #[inline]
        $vis fn $name($($arg: $ty),*) $(-> $ret)? {
            #[cfg(target_arch = "x86_64")]
            {
                #[target_feature(enable = "avx2")]
                unsafe fn avx2($($arg: $ty),*) $(-> $ret)? {
                    $imp($($arg),*)
                }
                if avx2_enabled() {
                    // SAFETY: guarded by runtime AVX2 detection above.
                    return unsafe { avx2($($arg),*) };
                }
            }
            $imp($($arg),*)
        }
    };
}

// Reduction kernels (lane-array accumulators; tolerance-bounded vs the
// scalar backend, bitwise across dispatch paths).
dispatch! {
    /// Lane-accumulated dot product (reassociated vs [`super::dot`]).
    pub fn dot(a: &[f64], b: &[f64]) -> f64 = dot_impl;
}
dispatch! {
    /// Lane-accumulated Σ aᵢ² (the blocked-predict row sum-of-squares).
    pub fn sumsq(a: &[f64]) -> f64 = sumsq_impl;
}
dispatch! {
    /// Rows [r0, r0+rows) of y = A·x via [`dot`].
    pub fn matvec_rows(a: &Mat, x: &[f64], r0: usize, rows: usize, out: &mut [f64]) =
        matvec_rows_impl;
}
dispatch! {
    /// Rows of C = A·Lᵀ (prefix dots) via [`dot`].
    pub fn mul_tril_t_rows(a: &Mat, l: &Mat, r0: usize, rows: usize, out: &mut [f64]) =
        mul_tril_t_rows_impl;
}
dispatch! {
    /// Rows of C = A·Uᵀ (suffix dots) via [`dot`].
    pub fn mul_triu_t_rows(a: &Mat, u: &Mat, r0: usize, rows: usize, out: &mut [f64]) =
        mul_triu_t_rows_impl;
}
dispatch! {
    /// Fast-form K[X, Z] row block (see [`crate::kernel::cross_into_ws`]).
    pub fn cross_rows(
        a0_sq: f64,
        eta: &[f64],
        x: &Mat,
        ze: &Mat,
        zn: &[f64],
        r0: usize,
        blk: &mut [f64],
    ) = cross_rows_impl;
}
dispatch! {
    /// Exact per-pair K[X, Z] row block (see [`crate::kernel::cross_pairwise`]).
    pub fn cross_pairwise_rows(
        a0_sq: f64,
        eta: &[f64],
        x: &Mat,
        z: &Mat,
        r0: usize,
        blk: &mut [f64],
    ) = cross_pairwise_rows_impl;
}

// Broadcast-chain kernels: the scalar row kernels recompiled at AVX2.
// Bitwise identical to the scalar backend on every dispatch path (no
// reassociation, no fma).
dispatch! {
    /// Rows of C = A·B — `super::matmul_rows` at the wider ISA.
    pub fn matmul_rows(a: &Mat, b: &Mat, r0: usize, rows: usize, out: &mut [f64]) =
        super::matmul_rows;
}
dispatch! {
    /// Rows of C = Aᵀ·B — `super::tr_matmul_rows` at the wider ISA.
    pub fn tr_matmul_rows(a: &Mat, b: &Mat, i0: usize, rows: usize, out: &mut [f64]) =
        super::tr_matmul_rows;
}
dispatch! {
    /// Upper-triangle rows of G = AᵀA — `super::gram_rows` at the wider ISA.
    pub fn gram_rows(a: &Mat, i0: usize, rows: usize, out: &mut [f64]) = super::gram_rows;
}
dispatch! {
    /// Columns of y = Aᵀ·x — `super::tr_matvec_cols` at the wider ISA.
    pub fn tr_matvec_cols(a: &Mat, x: &[f64], c0: usize, cols: usize, out: &mut [f64]) =
        super::tr_matvec_cols;
}
dispatch! {
    /// Column sums — `super::col_sums_cols` at the wider ISA.
    pub fn col_sums_cols(a: &Mat, c0: usize, cols: usize, out: &mut [f64]) =
        super::col_sums_cols;
}
dispatch! {
    /// Rows of C = U·B — `super::triu_matmul_rows` at the wider ISA.
    pub fn triu_matmul_rows(u: &Mat, b: &Mat, r0: usize, rows: usize, out: &mut [f64]) =
        super::triu_matmul_rows;
}
dispatch! {
    /// Rows of C = A·L — `super::mul_tril_rows` at the wider ISA.
    pub fn mul_tril_rows(a: &Mat, l: &Mat, r0: usize, rows: usize, out: &mut [f64]) =
        super::mul_tril_rows;
}
dispatch! {
    /// Rows of C = A·U — `super::mul_triu_rows` at the wider ISA.
    pub fn mul_triu_rows(a: &Mat, u: &Mat, r0: usize, rows: usize, out: &mut [f64]) =
        super::mul_triu_rows;
}

/// Compare every dispatched kernel against its generic copy on seeded
/// data and report the first bitwise mismatch.  On AVX2 hardware this
/// pins the "bitwise across dispatch paths" half of the SIMD numeric
/// contract; on other paths it degenerates to a self-comparison (still
/// useful as a smoke test of every wrapper).
pub fn self_check() -> Result<(), String> {
    use crate::util::rng::Pcg64;
    let mut rng = Pcg64::seeded(0x51D0_C4EC);
    let rand_mat = |rng: &mut Pcg64, r: usize, c: usize| {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    };
    let n = 37; // deliberately not a lane multiple
    let a = rand_mat(&mut rng, n, n);
    let b = rand_mat(&mut rng, n, n);
    let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let eta: Vec<f64> = (0..n).map(|_| rng.normal().abs() + 0.1).collect();
    let mut got = vec![0.0; n * n];
    let mut want = vec![0.0; n * n];
    let check = |name: &str, got: &[f64], want: &[f64]| -> Result<(), String> {
        if got != want {
            return Err(format!(
                "simd::self_check: `{name}` dispatched path diverges from generic copy \
                 (path {})",
                active_path()
            ));
        }
        Ok(())
    };

    check("dot", &[dot(&a.data[..n], &x)], &[dot_impl(&a.data[..n], &x)])?;
    check("sumsq", &[sumsq(&a.data[..n])], &[sumsq_impl(&a.data[..n])])?;
    matvec_rows(&a, &x, 0, n, &mut got[..n]);
    matvec_rows_impl(&a, &x, 0, n, &mut want[..n]);
    check("matvec_rows", &got[..n], &want[..n])?;
    mul_tril_t_rows(&a, &b, 0, n, &mut got);
    mul_tril_t_rows_impl(&a, &b, 0, n, &mut want);
    check("mul_tril_t_rows", &got, &want)?;
    mul_triu_t_rows(&a, &b, 0, n, &mut got);
    mul_triu_t_rows_impl(&a, &b, 0, n, &mut want);
    check("mul_triu_t_rows", &got, &want)?;
    cross_rows(1.3, &eta, &a, &b, &x, 0, &mut got);
    cross_rows_impl(1.3, &eta, &a, &b, &x, 0, &mut want);
    check("cross_rows", &got, &want)?;
    cross_pairwise_rows(1.3, &eta, &a, &b, 0, &mut got);
    cross_pairwise_rows_impl(1.3, &eta, &a, &b, 0, &mut want);
    check("cross_pairwise_rows", &got, &want)?;
    matmul_rows(&a, &b, 0, n, &mut got);
    super::matmul_rows(&a, &b, 0, n, &mut want);
    check("matmul_rows", &got, &want)?;
    tr_matmul_rows(&a, &b, 0, n, &mut got);
    super::tr_matmul_rows(&a, &b, 0, n, &mut want);
    check("tr_matmul_rows", &got, &want)?;
    gram_rows(&a, 0, n, &mut got);
    super::gram_rows(&a, 0, n, &mut want);
    check("gram_rows", &got, &want)?;
    tr_matvec_cols(&a, &x, 0, n, &mut got[..n]);
    super::tr_matvec_cols(&a, &x, 0, n, &mut want[..n]);
    check("tr_matvec_cols", &got[..n], &want[..n])?;
    col_sums_cols(&a, 0, n, &mut got[..n]);
    super::col_sums_cols(&a, 0, n, &mut want[..n]);
    check("col_sums_cols", &got[..n], &want[..n])?;
    triu_matmul_rows(&a, &b, 0, n, &mut got);
    super::triu_matmul_rows(&a, &b, 0, n, &mut want);
    check("triu_matmul_rows", &got, &want)?;
    mul_tril_rows(&a, &b, 0, n, &mut got);
    super::mul_tril_rows(&a, &b, 0, n, &mut want);
    check("mul_tril_rows", &got, &want)?;
    mul_triu_rows(&a, &b, 0, n, &mut got);
    super::mul_triu_rows(&a, &b, 0, n, &mut want);
    check("mul_triu_rows", &got, &want)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_vec(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Reassociation moves the sum by rounding only: pin the relative
    /// error on adversarial (non-lane-multiple, tiny, empty) lengths.
    #[test]
    fn lane_dot_is_close_to_scalar_dot() {
        let mut rng = Pcg64::seeded(90);
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 63, 64, 65, 1000] {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let simd = dot(&a, &b);
            let scalar = super::super::dot(&a, &b);
            let scale = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum::<f64>();
            assert!(
                (simd - scalar).abs() <= 1e-12 * scale.max(1.0),
                "dot n={n}: simd={simd} scalar={scalar}"
            );
            let sq = sumsq(&a);
            let sq_ref = super::super::dot(&a, &a);
            assert!(
                (sq - sq_ref).abs() <= 1e-12 * sq_ref.abs().max(1.0),
                "sumsq n={n}"
            );
        }
    }

    #[test]
    fn lane_dot_exact_cases() {
        // Exactly representable inputs: any path must be exact.
        let a: Vec<f64> = (0..23).map(|i| i as f64).collect();
        let ones = vec![1.0; 23];
        assert_eq!(dot(&a, &ones), (0..23).sum::<usize>() as f64);
        assert_eq!(sumsq(&[3.0]), 9.0);
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(sumsq(&[]), 0.0);
    }

    #[test]
    fn dispatched_kernels_match_generic_bitwise() {
        self_check().unwrap();
    }

    #[test]
    fn path_introspection_is_coherent() {
        // available() describes hardware, active_path() the dispatch
        // decision; on non-x86_64 they cannot disagree, on x86_64 the
        // accel path requires availability.
        if active_path() == "x86_64-avx2" {
            assert!(available());
        }
    }
}
