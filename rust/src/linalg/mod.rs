//! Dense linear-algebra substrate (offline build: no BLAS/nalgebra).
//!
//! Row-major `f64` matrices sized for the GP working set (m ≤ a few
//! hundred): blocked matmul, Cholesky, triangular solves, inverses and a
//! Jacobi symmetric eigendecomposition (for the Nyström/EigenGP feature
//! maps, paper eq. 21–22).

mod chol;
mod eig;

pub use chol::{cholesky_lower, solve_lower, solve_upper, spd_inverse, CholError};
pub use eig::sym_eig;

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for r in 0..self.rows.min(6) {
            write!(f, "  ")?;
            for c in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(r, c)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 6 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Self { rows: r, cols: c, data: rows.into_iter().flatten().collect() }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t[(c, r)] = self[(r, c)];
            }
        }
        t
    }

    /// C = A * B (ikj loop order: streams B's rows, vector-friendly).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul dims {}x{} * {}x{}",
                   self.rows, self.cols, b.rows, b.cols);
        let mut c = Mat::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            let arow = self.row(i);
            let crow = c.row_mut(i);
            for (k, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = b.row(k);
                for (j, &bkj) in brow.iter().enumerate() {
                    crow[j] += aik * bkj;
                }
            }
        }
        c
    }

    /// C = A^T * B without materializing A^T (kij order streams both
    /// operands row-wise; beats `self.transpose().matmul(b)` by the
    /// transpose copy plus its cache misses on tall matrices).
    pub fn tr_matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows, "tr_matmul dims");
        let mut c = Mat::zeros(self.cols, b.cols);
        for k in 0..self.rows {
            let arow = self.row(k);
            let brow = b.row(k);
            for (i, &aki) in arow.iter().enumerate() {
                if aki == 0.0 {
                    continue;
                }
                let crow = c.row_mut(i);
                for (j, &bkj) in brow.iter().enumerate() {
                    crow[j] += aki * bkj;
                }
            }
        }
        c
    }

    /// C = A^T * A (Gram matrix), exploiting symmetry.
    pub fn gram(&self) -> Mat {
        let n = self.cols;
        let mut g = Mat::zeros(n, n);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..n {
                let xi = row[i];
                if xi == 0.0 {
                    continue;
                }
                for j in i..n {
                    g[(i, j)] += xi * row[j];
                }
            }
        }
        for i in 0..n {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        g
    }

    /// y = A * x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|r| dot(self.row(r), x))
            .collect()
    }

    /// y = A^T * x.
    pub fn tr_matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, x.len());
        let mut y = vec![0.0; self.cols];
        for r in 0..self.rows {
            let xr = x[r];
            if xr == 0.0 {
                continue;
            }
            for (c, &v) in self.row(r).iter().enumerate() {
                y[c] += xr * v;
            }
        }
        y
    }

    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// self += s * other.
    pub fn axpy(&mut self, s: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    /// Keep the upper triangle (incl. diagonal), zero the rest — the
    /// paper's `triu[·]` operator (eq. 17).
    pub fn triu_inplace(&mut self) {
        for r in 0..self.rows {
            for c in 0..r.min(self.cols) {
                self[(r, c)] = 0.0;
            }
        }
    }

    pub fn diag(&self) -> Vec<f64> {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).collect()
    }

    pub fn trace(&self) -> f64 {
        self.diag().iter().sum()
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: lets LLVM vectorize without
    // re-association concerns dominating.
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut s3 = 0.0;
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let k = i * 4;
        s0 += a[k] * b[k];
        s1 += a[k + 1] * b[k + 1];
        s2 += a[k + 2] * b[k + 2];
        s3 += a[k + 3] * b[k + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Elementwise a·b summed with a mask.
#[inline]
pub fn dot3(a: &[f64], b: &[f64], mask: &[f64]) -> f64 {
    a.iter().zip(b).zip(mask).map(|((x, y), m)| x * y * m).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_mat(rng: &mut Pcg64, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    #[test]
    fn matmul_identity_and_assoc() {
        let mut rng = Pcg64::seeded(1);
        let a = random_mat(&mut rng, 7, 5);
        let i5 = Mat::eye(5);
        assert!(a.matmul(&i5).max_abs_diff(&a) < 1e-14);
        let b = random_mat(&mut rng, 5, 6);
        let c = random_mat(&mut rng, 6, 4);
        let left = a.matmul(&b).matmul(&c);
        let right = a.matmul(&b.matmul(&c));
        assert!(left.max_abs_diff(&right) < 1e-10);
    }

    #[test]
    fn matmul_known_values() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(vec![vec![1.0, 1.0], vec![1.0, 1.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn gram_matches_explicit() {
        let mut rng = Pcg64::seeded(2);
        let a = random_mat(&mut rng, 9, 6);
        let g = a.gram();
        let g2 = a.transpose().matmul(&a);
        assert!(g.max_abs_diff(&g2) < 1e-12);
    }

    #[test]
    fn matvec_and_transpose() {
        let mut rng = Pcg64::seeded(3);
        let a = random_mat(&mut rng, 8, 5);
        let x: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let y = a.matvec(&x);
        let yt = a.transpose().tr_matvec(&x);
        // A x == (A^T)^T x
        for (u, v) in y.iter().zip(&yt) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn triu_zeroes_strict_lower() {
        let mut a = Mat::from_rows(vec![
            vec![1.0, 2.0, 3.0],
            vec![4.0, 5.0, 6.0],
            vec![7.0, 8.0, 9.0],
        ]);
        a.triu_inplace();
        assert_eq!(a.data, vec![1.0, 2.0, 3.0, 0.0, 5.0, 6.0, 0.0, 0.0, 9.0]);
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        let mut rng = Pcg64::seeded(4);
        for n in [0, 1, 3, 4, 7, 64, 129] {
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-10);
        }
    }
}
