//! Dense linear-algebra substrate (offline build: no BLAS/nalgebra).
//!
//! Row-major `f64` matrices sized for the GP working set (m ≤ a few
//! hundred, batches up to a few thousand rows): cache-blocked,
//! row-parallel matmul family, Cholesky, triangular solves, inverses
//! and a Jacobi symmetric eigendecomposition (for the Nyström/EigenGP
//! feature maps, paper eq. 21–22).
//!
//! # Execution model
//!
//! Every product has an allocation-free `*_into` form plus a
//! convenience allocating wrapper.  Ops whose multiply count reaches
//! [`par_min_flops`] are dispatched over the global thread pool
//! ([`crate::util::pool`]) in contiguous *output-row blocks*; smaller
//! ops run inline on the caller.  Both paths execute the **same
//! kernel** over row ranges, and each output row's accumulation order
//! is fixed (ascending k, tiled), so results are bitwise identical at
//! any thread count or budget.
//!
//! Dense kernels carry no `== 0.0` skip guards (they were branch
//! mispredict fodder on dense GP matrices); structural sparsity is
//! exploited instead by the dedicated triangular kernels
//! ([`triu_matmul_into`], [`Mat::mul_tril_into`], …) used for the
//! paper's `triu[U]` and Cholesky-factor products.

mod chol;
mod eig;
pub mod simd;

pub use chol::{cholesky_lower, solve_lower, solve_upper, spd_inverse, CholError};
pub use eig::sym_eig;

use crate::util::pool;
use std::fmt;
use std::ops::{Index, IndexMut};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Default serial-fallback threshold: ops below this many multiplies
/// are not worth a pool dispatch (~20 µs of serial work).
pub const DEFAULT_PAR_MIN_FLOPS: usize = 1 << 16;

static PAR_MIN_FLOPS: AtomicUsize = AtomicUsize::new(DEFAULT_PAR_MIN_FLOPS);

/// Current serial-fallback threshold (multiply count).
pub fn par_min_flops() -> usize {
    PAR_MIN_FLOPS.load(Ordering::Relaxed)
}

/// Override the serial-fallback threshold (1 forces parallel dispatch
/// for every op — used by the equivalence tests and benches).
pub fn set_par_min_flops(n: usize) {
    PAR_MIN_FLOPS.store(n.max(1), Ordering::Relaxed);
}

/// The crate-wide serial/parallel dispatch gate: parallelize only when
/// the op's multiply count clears the threshold AND this thread may
/// actually fan out.  Shared by `kernel` and `data::kmeans` so the
/// gating policy lives in one place.
#[inline]
pub(crate) fn should_par(flops: usize) -> bool {
    flops >= par_min_flops() && pool::effective_parallelism() > 1
}

/// K-dimension tile: keeps the streamed operand's tile resident in L1/L2
/// across an output-row block without changing accumulation order.
const KC_TILE: usize = 64;

/// Dense row-major matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for r in 0..self.rows.min(6) {
            write!(f, "  ")?;
            for c in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(r, c)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 6 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

// ---------------------------------------------------------------------
// Row-range kernels.  Each computes a contiguous block of OUTPUT rows;
// the serial path runs them over the full range, the parallel path over
// disjoint blocks.  Per-element accumulation order (ascending k) is
// identical either way.
//
// `pub(crate)` + `#[inline(always)]`: the [`simd`] module recompiles
// the broadcast-chain kernels under wider target features (see
// `simd::dispatch!`) — inlining into the `#[target_feature]` wrapper is
// what lets that codegen actually apply.
// ---------------------------------------------------------------------

/// Rows [r0, r0+rows) of C = A·B (ikj, k-tiled).
#[inline(always)]
pub(crate) fn matmul_rows(a: &Mat, b: &Mat, r0: usize, rows: usize, out: &mut [f64]) {
    let n = b.cols;
    debug_assert_eq!(out.len(), rows * n);
    for v in out.iter_mut() {
        *v = 0.0;
    }
    let mut k0 = 0;
    while k0 < a.cols {
        let k1 = (k0 + KC_TILE).min(a.cols);
        for i in 0..rows {
            let arow = &a.row(r0 + i)[k0..k1];
            let crow = &mut out[i * n..(i + 1) * n];
            for (k, &aik) in arow.iter().enumerate() {
                let brow = b.row(k0 + k);
                for (j, &bkj) in brow.iter().enumerate() {
                    crow[j] += aik * bkj;
                }
            }
        }
        k0 = k1;
    }
}

/// Rows [i0, i0+rows) of C = Aᵀ·B (k-outer; streams both operands).
#[inline(always)]
pub(crate) fn tr_matmul_rows(a: &Mat, b: &Mat, i0: usize, rows: usize, out: &mut [f64]) {
    let n = b.cols;
    debug_assert_eq!(out.len(), rows * n);
    for v in out.iter_mut() {
        *v = 0.0;
    }
    for k in 0..a.rows {
        let arow = a.row(k);
        let brow = b.row(k);
        for i in 0..rows {
            let aki = arow[i0 + i];
            let crow = &mut out[i * n..(i + 1) * n];
            for (j, &bkj) in brow.iter().enumerate() {
                crow[j] += aki * bkj;
            }
        }
    }
}

/// Rows [i0, i0+rows) of G = AᵀA, upper triangle only (j ≥ global i).
#[inline(always)]
pub(crate) fn gram_rows(a: &Mat, i0: usize, rows: usize, out: &mut [f64]) {
    let n = a.cols;
    debug_assert_eq!(out.len(), rows * n);
    for v in out.iter_mut() {
        *v = 0.0;
    }
    for r in 0..a.rows {
        let row = a.row(r);
        for i in 0..rows {
            let gi = i0 + i;
            let xi = row[gi];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in gi..n {
                orow[j] += xi * row[j];
            }
        }
    }
}

/// Rows [r0, r0+rows) of y = A·x.
#[inline(always)]
pub(crate) fn matvec_rows(a: &Mat, x: &[f64], r0: usize, rows: usize, out: &mut [f64]) {
    for (i, v) in out.iter_mut().enumerate().take(rows) {
        *v = dot(a.row(r0 + i), x);
    }
}

/// Columns [c0, c0+cols) of y = Aᵀ·x.
#[inline(always)]
pub(crate) fn tr_matvec_cols(a: &Mat, x: &[f64], c0: usize, cols: usize, out: &mut [f64]) {
    for v in out.iter_mut() {
        *v = 0.0;
    }
    for r in 0..a.rows {
        let xr = x[r];
        let arow = &a.row(r)[c0..c0 + cols];
        for (c, &v) in arow.iter().enumerate() {
            out[c] += xr * v;
        }
    }
}

/// Columns [c0, c0+cols) of s_j = Σ_i A[i, j].
#[inline(always)]
pub(crate) fn col_sums_cols(a: &Mat, c0: usize, cols: usize, out: &mut [f64]) {
    for v in out.iter_mut() {
        *v = 0.0;
    }
    for r in 0..a.rows {
        let arow = &a.row(r)[c0..c0 + cols];
        for (c, &v) in arow.iter().enumerate() {
            out[c] += v;
        }
    }
}

/// Rows [r0, r0+rows) of C = U·B with U upper triangular (k ≥ i).
#[inline(always)]
pub(crate) fn triu_matmul_rows(u: &Mat, b: &Mat, r0: usize, rows: usize, out: &mut [f64]) {
    let n = b.cols;
    debug_assert_eq!(out.len(), rows * n);
    for v in out.iter_mut() {
        *v = 0.0;
    }
    for i in 0..rows {
        let gi = r0 + i;
        let urow = u.row(gi);
        let crow = &mut out[i * n..(i + 1) * n];
        for k in gi..u.cols {
            let uik = urow[k];
            let brow = b.row(k);
            for (j, &bkj) in brow.iter().enumerate() {
                crow[j] += uik * bkj;
            }
        }
    }
}

/// Rows [r0, r0+rows) of C = A·L with L lower triangular (j ≤ k).
#[inline(always)]
pub(crate) fn mul_tril_rows(a: &Mat, l: &Mat, r0: usize, rows: usize, out: &mut [f64]) {
    let n = l.cols;
    debug_assert_eq!(out.len(), rows * n);
    for v in out.iter_mut() {
        *v = 0.0;
    }
    for i in 0..rows {
        let arow = a.row(r0 + i);
        let crow = &mut out[i * n..(i + 1) * n];
        for (k, &aik) in arow.iter().enumerate() {
            let lrow = &l.row(k)[..=k];
            for (j, &lkj) in lrow.iter().enumerate() {
                crow[j] += aik * lkj;
            }
        }
    }
}

/// Rows [r0, r0+rows) of C = A·U with U upper triangular (j ≥ k).
#[inline(always)]
pub(crate) fn mul_triu_rows(a: &Mat, u: &Mat, r0: usize, rows: usize, out: &mut [f64]) {
    let n = u.cols;
    debug_assert_eq!(out.len(), rows * n);
    for v in out.iter_mut() {
        *v = 0.0;
    }
    for i in 0..rows {
        let arow = a.row(r0 + i);
        let crow = &mut out[i * n..(i + 1) * n];
        for (k, &aik) in arow.iter().enumerate() {
            let urow = &u.row(k)[k..];
            for (j, &ukj) in urow.iter().enumerate() {
                crow[k + j] += aik * ukj;
            }
        }
    }
}

/// Rows [r0, r0+rows) of C = A·Lᵀ with L lower triangular:
/// C[i, j] = ⟨A[i, ..=j], L[j, ..=j]⟩ (prefix dot).
#[inline(always)]
pub(crate) fn mul_tril_t_rows(a: &Mat, l: &Mat, r0: usize, rows: usize, out: &mut [f64]) {
    let n = l.rows;
    debug_assert_eq!(out.len(), rows * n);
    for i in 0..rows {
        let arow = a.row(r0 + i);
        let crow = &mut out[i * n..(i + 1) * n];
        for (j, slot) in crow.iter_mut().enumerate() {
            *slot = dot(&arow[..=j], &l.row(j)[..=j]);
        }
    }
}

/// Rows [r0, r0+rows) of C = A·Uᵀ with U upper triangular:
/// C[i, j] = ⟨A[i, j..], U[j, j..]⟩ (suffix dot).
#[inline(always)]
pub(crate) fn mul_triu_t_rows(a: &Mat, u: &Mat, r0: usize, rows: usize, out: &mut [f64]) {
    let n = u.rows;
    debug_assert_eq!(out.len(), rows * n);
    for i in 0..rows {
        let arow = a.row(r0 + i);
        let crow = &mut out[i * n..(i + 1) * n];
        for (j, slot) in crow.iter_mut().enumerate() {
            *slot = dot(&arow[j..], &u.row(j)[j..]);
        }
    }
}

/// Dispatch a row-blocked kernel: inline below the flop threshold,
/// otherwise over the pool in disjoint output-row blocks.
///
/// `full_pass` marks transpose-side kernels whose every block streams
/// the *whole* input operand (tr_matmul/gram/tr_matvec/col_sums): they
/// get exactly one block per lane, since extra blocks multiply memory
/// traffic instead of improving balance.
///
/// `pub(crate)`: [`crate::runtime::backend::SimdBackend`] reuses this
/// dispatcher so both backends share one serial/parallel policy.
pub(crate) fn run_rows(
    out: &mut [f64],
    row_len: usize,
    rows: usize,
    flops: usize,
    full_pass: bool,
    kernel: &(dyn Fn(usize, usize, &mut [f64]) + Sync),
) {
    if rows == 0 || row_len == 0 {
        return;
    }
    if should_par(flops) {
        let block = if full_pass {
            pool::block_size_full_pass(rows)
        } else {
            pool::block_size(rows)
        };
        pool::parallel_rows_mut(out, row_len, rows, block, &|r0, blk| {
            kernel(r0, blk.len() / row_len, blk)
        });
    } else {
        kernel(0, rows, out);
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Empty matrix placeholder for `*_into` targets (no allocation).
    pub fn empty() -> Self {
        Self { rows: 0, cols: 0, data: Vec::new() }
    }

    /// Reshape to [rows, cols] reusing the allocation.  Contents are
    /// unspecified afterwards; every `*_into` kernel overwrites fully.
    pub fn resize(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Self { rows: r, cols: c, data: rows.into_iter().flatten().collect() }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t[(c, r)] = self[(r, c)];
            }
        }
        t
    }

    /// C = A * B into a caller-owned buffer (no allocation once `out`
    /// has capacity).
    pub fn matmul_into(&self, b: &Mat, out: &mut Mat) {
        assert_eq!(
            self.cols, b.rows,
            "matmul dims {}x{} * {}x{}",
            self.rows, self.cols, b.rows, b.cols
        );
        out.resize(self.rows, b.cols);
        let flops = self.rows * self.cols * b.cols;
        run_rows(&mut out.data, b.cols, self.rows, flops, false, &|r0, rows, blk| {
            matmul_rows(self, b, r0, rows, blk)
        });
    }

    /// C = A * B.
    pub fn matmul(&self, b: &Mat) -> Mat {
        let mut out = Mat::empty();
        self.matmul_into(b, &mut out);
        out
    }

    /// C = Aᵀ * B into a caller-owned buffer, without materializing Aᵀ.
    pub fn tr_matmul_into(&self, b: &Mat, out: &mut Mat) {
        assert_eq!(self.rows, b.rows, "tr_matmul dims");
        out.resize(self.cols, b.cols);
        let flops = self.rows * self.cols * b.cols;
        run_rows(&mut out.data, b.cols, self.cols, flops, true, &|i0, rows, blk| {
            tr_matmul_rows(self, b, i0, rows, blk)
        });
    }

    /// C = Aᵀ * B without materializing Aᵀ.
    pub fn tr_matmul(&self, b: &Mat) -> Mat {
        let mut out = Mat::empty();
        self.tr_matmul_into(b, &mut out);
        out
    }

    /// G = Aᵀ * A (Gram matrix) into a caller-owned buffer, exploiting
    /// symmetry (upper triangle computed, lower mirrored).
    pub fn gram_into(&self, out: &mut Mat) {
        let n = self.cols;
        out.resize(n, n);
        let flops = self.rows * n * n / 2;
        run_rows(&mut out.data, n, n, flops, true, &|i0, rows, blk| {
            gram_rows(self, i0, rows, blk)
        });
        for i in 0..n {
            for j in 0..i {
                out[(i, j)] = out[(j, i)];
            }
        }
    }

    /// G = Aᵀ * A (Gram matrix), exploiting symmetry.
    pub fn gram(&self) -> Mat {
        let mut out = Mat::empty();
        self.gram_into(&mut out);
        out
    }

    /// C = U * B with U = self **upper triangular** (structural skip of
    /// the strictly-lower zeros; the paper's `triu[·]` factor).
    pub fn triu_matmul_into(&self, b: &Mat, out: &mut Mat) {
        assert_eq!(self.rows, self.cols, "triu operand must be square");
        assert_eq!(self.cols, b.rows, "triu_matmul dims");
        out.resize(self.rows, b.cols);
        let flops = self.rows * self.cols * b.cols / 2;
        run_rows(&mut out.data, b.cols, self.rows, flops, false, &|r0, rows, blk| {
            triu_matmul_rows(self, b, r0, rows, blk)
        });
    }

    /// C = A * L with `l` **lower triangular** (half the multiplies of
    /// a dense matmul).
    pub fn mul_tril_into(&self, l: &Mat, out: &mut Mat) {
        assert_eq!(l.rows, l.cols, "tril operand must be square");
        assert_eq!(self.cols, l.rows, "mul_tril dims");
        out.resize(self.rows, l.cols);
        let flops = self.rows * l.rows * l.cols / 2;
        run_rows(&mut out.data, l.cols, self.rows, flops, false, &|r0, rows, blk| {
            mul_tril_rows(self, l, r0, rows, blk)
        });
    }

    /// C = A * U with `u` **upper triangular**.
    pub fn mul_triu_into(&self, u: &Mat, out: &mut Mat) {
        assert_eq!(u.rows, u.cols, "triu operand must be square");
        assert_eq!(self.cols, u.rows, "mul_triu dims");
        out.resize(self.rows, u.cols);
        let flops = self.rows * u.rows * u.cols / 2;
        run_rows(&mut out.data, u.cols, self.rows, flops, false, &|r0, rows, blk| {
            mul_triu_rows(self, u, r0, rows, blk)
        });
    }

    /// C = A * Lᵀ with `l` **lower triangular**, without materializing
    /// the transpose.
    pub fn mul_tril_t_into(&self, l: &Mat, out: &mut Mat) {
        assert_eq!(l.rows, l.cols, "tril operand must be square");
        assert_eq!(self.cols, l.rows, "mul_tril_t dims");
        out.resize(self.rows, l.rows);
        let flops = self.rows * l.rows * l.cols / 2;
        run_rows(&mut out.data, l.rows, self.rows, flops, false, &|r0, rows, blk| {
            mul_tril_t_rows(self, l, r0, rows, blk)
        });
    }

    /// C = A * Uᵀ with `u` **upper triangular**, without materializing
    /// the transpose.
    pub fn mul_triu_t_into(&self, u: &Mat, out: &mut Mat) {
        assert_eq!(u.rows, u.cols, "triu operand must be square");
        assert_eq!(self.cols, u.rows, "mul_triu_t dims");
        out.resize(self.rows, u.rows);
        let flops = self.rows * u.rows * u.cols / 2;
        run_rows(&mut out.data, u.rows, self.rows, flops, false, &|r0, rows, blk| {
            mul_triu_t_rows(self, u, r0, rows, blk)
        });
    }

    /// y = A * x into a caller-owned buffer.
    pub fn matvec_into(&self, x: &[f64], out: &mut Vec<f64>) {
        assert_eq!(self.cols, x.len());
        out.resize(self.rows, 0.0);
        let flops = self.rows * self.cols;
        run_rows(out, 1, self.rows, flops, false, &|r0, rows, blk| {
            matvec_rows(self, x, r0, rows, blk)
        });
    }

    /// y = A * x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = Vec::new();
        self.matvec_into(x, &mut out);
        out
    }

    /// y = Aᵀ * x into a caller-owned buffer.
    pub fn tr_matvec_into(&self, x: &[f64], out: &mut Vec<f64>) {
        assert_eq!(self.rows, x.len());
        out.resize(self.cols, 0.0);
        let flops = self.rows * self.cols;
        run_rows(out, 1, self.cols, flops, true, &|c0, cols, blk| {
            tr_matvec_cols(self, x, c0, cols, blk)
        });
    }

    /// y = Aᵀ * x.
    pub fn tr_matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = Vec::new();
        self.tr_matvec_into(x, &mut out);
        out
    }

    /// s_j = Σ_i A[i, j] (column sums).
    pub fn col_sums(&self) -> Vec<f64> {
        let mut out = Vec::new();
        self.col_sums_into(&mut out);
        out
    }

    /// s_j = Σ_i A[i, j] (column sums) into a caller-owned buffer.
    pub fn col_sums_into(&self, out: &mut Vec<f64>) {
        out.resize(self.cols, 0.0);
        let flops = self.rows * self.cols;
        run_rows(out, 1, self.cols, flops, true, &|c0, cols, blk| {
            col_sums_cols(self, c0, cols, blk)
        });
    }

    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// self += s * other.
    pub fn axpy(&mut self, s: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    /// Keep the upper triangle (incl. diagonal), zero the rest — the
    /// paper's `triu[·]` operator (eq. 17).
    pub fn triu_inplace(&mut self) {
        for r in 0..self.rows {
            for c in 0..r.min(self.cols) {
                self[(r, c)] = 0.0;
            }
        }
    }

    pub fn diag(&self) -> Vec<f64> {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).collect()
    }

    pub fn trace(&self) -> f64 {
        self.diag().iter().sum()
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: lets LLVM vectorize without
    // re-association concerns dominating.
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut s3 = 0.0;
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let k = i * 4;
        s0 += a[k] * b[k];
        s1 += a[k + 1] * b[k + 1];
        s2 += a[k + 2] * b[k + 2];
        s3 += a[k + 3] * b[k + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Elementwise a·b summed with a mask.
#[inline]
pub fn dot3(a: &[f64], b: &[f64], mask: &[f64]) -> f64 {
    a.iter().zip(b).zip(mask).map(|((x, y), m)| x * y * m).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_mat(rng: &mut Pcg64, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    #[test]
    fn matmul_identity_and_assoc() {
        let mut rng = Pcg64::seeded(1);
        let a = random_mat(&mut rng, 7, 5);
        let i5 = Mat::eye(5);
        assert!(a.matmul(&i5).max_abs_diff(&a) < 1e-14);
        let b = random_mat(&mut rng, 5, 6);
        let c = random_mat(&mut rng, 6, 4);
        let left = a.matmul(&b).matmul(&c);
        let right = a.matmul(&b.matmul(&c));
        assert!(left.max_abs_diff(&right) < 1e-10);
    }

    #[test]
    fn matmul_known_values() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(vec![vec![1.0, 1.0], vec![1.0, 1.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn gram_matches_explicit() {
        let mut rng = Pcg64::seeded(2);
        let a = random_mat(&mut rng, 9, 6);
        let g = a.gram();
        let g2 = a.transpose().matmul(&a);
        assert!(g.max_abs_diff(&g2) < 1e-12);
    }

    #[test]
    fn matvec_and_transpose() {
        let mut rng = Pcg64::seeded(3);
        let a = random_mat(&mut rng, 8, 5);
        let x: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let y = a.matvec(&x);
        let yt = a.transpose().tr_matvec(&x);
        // A x == (A^T)^T x
        for (u, v) in y.iter().zip(&yt) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn triu_zeroes_strict_lower() {
        let mut a = Mat::from_rows(vec![
            vec![1.0, 2.0, 3.0],
            vec![4.0, 5.0, 6.0],
            vec![7.0, 8.0, 9.0],
        ]);
        a.triu_inplace();
        assert_eq!(a.data, vec![1.0, 2.0, 3.0, 0.0, 5.0, 6.0, 0.0, 0.0, 9.0]);
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        let mut rng = Pcg64::seeded(4);
        for n in [0, 1, 3, 4, 7, 64, 129] {
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-10);
        }
    }

    #[test]
    fn into_variants_reuse_buffers() {
        let mut rng = Pcg64::seeded(5);
        let a = random_mat(&mut rng, 6, 4);
        let b = random_mat(&mut rng, 4, 3);
        let mut out = Mat::empty();
        a.matmul_into(&b, &mut out);
        let want = a.matmul(&b);
        assert_eq!(out.data, want.data);
        let cap = out.data.capacity();
        // Second call with the same shapes must not reallocate.
        a.matmul_into(&b, &mut out);
        assert_eq!(out.data.capacity(), cap);
        assert_eq!(out.data, want.data);
    }

    #[test]
    fn degenerate_shapes() {
        let a = Mat::zeros(0, 4);
        let b = Mat::zeros(4, 3);
        let c = a.matmul(&b);
        assert_eq!((c.rows, c.cols), (0, 3));
        let d = a.tr_matmul(&Mat::zeros(0, 2));
        assert_eq!((d.rows, d.cols), (4, 2));
        assert!(d.data.iter().all(|&v| v == 0.0));
        let e = Mat::zeros(3, 0).gram();
        assert_eq!((e.rows, e.cols), (0, 0));
        assert_eq!(Mat::zeros(0, 3).matvec(&[1.0, 2.0, 3.0]).len(), 0);
    }

    fn random_lower(rng: &mut Pcg64, n: usize) -> Mat {
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                l[(i, j)] = rng.normal();
            }
        }
        l
    }

    fn random_upper(rng: &mut Pcg64, n: usize) -> Mat {
        let mut u = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                u[(i, j)] = rng.normal();
            }
        }
        u
    }

    #[test]
    fn triangular_kernels_match_dense() {
        let mut rng = Pcg64::seeded(6);
        for n in [1usize, 2, 5, 9] {
            let a = random_mat(&mut rng, 7, n);
            let l = random_lower(&mut rng, n);
            let u = random_upper(&mut rng, n);
            let b = random_mat(&mut rng, n, 4);

            let mut got = Mat::empty();
            a.mul_tril_into(&l, &mut got);
            assert!(got.max_abs_diff(&a.matmul(&l)) < 1e-12, "mul_tril n={n}");

            a.mul_triu_into(&u, &mut got);
            assert!(got.max_abs_diff(&a.matmul(&u)) < 1e-12, "mul_triu n={n}");

            a.mul_tril_t_into(&l, &mut got);
            assert!(
                got.max_abs_diff(&a.matmul(&l.transpose())) < 1e-12,
                "mul_tril_t n={n}"
            );

            a.mul_triu_t_into(&u, &mut got);
            assert!(
                got.max_abs_diff(&a.matmul(&u.transpose())) < 1e-12,
                "mul_triu_t n={n}"
            );

            u.triu_matmul_into(&b, &mut got);
            assert!(got.max_abs_diff(&u.matmul(&b)) < 1e-12, "triu_matmul n={n}");
        }
    }

    #[test]
    fn col_sums_match_tr_matvec_ones() {
        let mut rng = Pcg64::seeded(7);
        let a = random_mat(&mut rng, 11, 6);
        let mut s = Vec::new();
        a.col_sums_into(&mut s);
        let want = a.tr_matvec(&vec![1.0; 11]);
        for (x, y) in s.iter().zip(&want) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn parallel_dispatch_is_bitwise_identical() {
        // Force pool dispatch for everything and compare against the
        // budget-1 (inline) path: identical bits, not just close.
        // Restore the global threshold even if an assertion fails, so
        // a failure here can't change how other lib tests dispatch.
        struct Restore(usize);
        impl Drop for Restore {
            fn drop(&mut self) {
                set_par_min_flops(self.0);
            }
        }
        let _restore = Restore(par_min_flops());
        set_par_min_flops(1);
        let mut rng = Pcg64::seeded(8);
        for (r, k, c) in [(1usize, 1usize, 1usize), (3, 5, 2), (33, 17, 9), (64, 8, 100)] {
            let a = random_mat(&mut rng, r, k);
            let b = random_mat(&mut rng, k, c);
            let serial = crate::util::pool::with_budget(1, || a.matmul(&b));
            let par = a.matmul(&b);
            assert_eq!(serial.data, par.data, "matmul {r}x{k}x{c}");
            let serial = crate::util::pool::with_budget(1, || b.tr_matmul(&a.transpose()));
            let par = b.tr_matmul(&a.transpose());
            assert_eq!(serial.data, par.data, "tr_matmul {r}x{k}x{c}");
            let serial = crate::util::pool::with_budget(1, || a.gram());
            let par = a.gram();
            assert_eq!(serial.data, par.data, "gram {r}x{k}");
        }
    }
}
