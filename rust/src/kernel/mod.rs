//! ARD squared-exponential covariance (paper §2 / appendix A.2):
//!
//! k(x, z) = a0^2 exp(-0.5 Σ_k η_k (x_k - z_k)^2),  η_k = exp(log_eta_k).
//!
//! Mirrors `python/compile/kernels/ref.py` (the f32 JAX oracle) in f64.

use crate::linalg::{dot, Mat};
use crate::util::pool;

/// Hyperparameters of the ARD kernel, stored in log space.
#[derive(Clone, Debug, PartialEq)]
pub struct ArdParams {
    pub log_a0: f64,
    pub log_eta: Vec<f64>,
}

impl ArdParams {
    pub fn unit(d: usize) -> Self {
        Self { log_a0: 0.0, log_eta: vec![0.0; d] }
    }

    pub fn a0_sq(&self) -> f64 {
        (2.0 * self.log_a0).exp()
    }

    pub fn eta(&self) -> Vec<f64> {
        self.log_eta.iter().map(|x| x.exp()).collect()
    }

    pub fn dim(&self) -> usize {
        self.log_eta.len()
    }
}

/// Scalar kernel evaluation.
pub fn k_pair(p: &ArdParams, x: &[f64], z: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), z.len());
    let mut d2 = 0.0;
    for ((xi, zi), le) in x.iter().zip(z).zip(&p.log_eta) {
        let diff = xi - zi;
        d2 += diff * diff * le.exp();
    }
    p.a0_sq() * (-0.5 * d2).exp()
}

/// Reusable scratch for [`cross_into_ws`]: η-scaled inducing rows and
/// their η-norms.  Holding one per engine/worker makes the batched
/// kernel evaluation allocation-free in steady state.
#[derive(Clone, Debug)]
pub struct CrossScratch {
    /// `ze[j, k] = η_k z[j, k]`.  `pub(crate)`: the SIMD backend's
    /// cross kernel ([`crate::runtime::backend::SimdBackend`]) shares
    /// this scratch so both backends reuse one z-side preparation.
    pub(crate) ze: Mat,
    /// `zn[j] = Σ_k η_k z[j, k]²`.
    pub(crate) zn: Vec<f64>,
}

impl CrossScratch {
    pub fn new() -> Self {
        Self { ze: Mat::empty(), zn: Vec::new() }
    }

    /// Fill `ze`/`zn` for inducing set `z` under lengthscales `eta`
    /// (m×d work, small next to the [n, m] output it enables).  Shared
    /// by the scalar and SIMD cross kernels — identical preparation is
    /// part of why the two backends differ only by reduction order.
    pub(crate) fn prepare(&mut self, eta: &[f64], z: &Mat) {
        let (m, d) = (z.rows, eta.len());
        self.ze.resize(m, d);
        self.zn.resize(m, 0.0);
        for j in 0..m {
            let zrow = z.row(j);
            let erow = self.ze.row_mut(j);
            let mut n2 = 0.0;
            for c in 0..d {
                erow[c] = eta[c] * zrow[c];
                n2 += eta[c] * zrow[c] * zrow[c];
            }
            self.zn[j] = n2;
        }
    }
}

impl Default for CrossScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Rough cost model for one K[X, Z] evaluation: d multiply-adds plus an
/// exp (~16 flops) per pair.  Drives the serial/parallel dispatch
/// (shared with the SIMD backend so both dispatch identically).
pub(crate) fn cross_flops(rows: usize, m: usize, d: usize) -> usize {
    rows * m * (d + 16)
}

/// Cross-covariance K[X, Z] of shape [n, m] into a caller-owned buffer;
/// rows of `x`/`z` are points.
///
/// Uses the dot-product expansion `‖x−z‖²_η = ‖x‖²_η + ‖z‖²_η − 2⟨x,z⟩_η`
/// with the z-side η-scaling hoisted into `ws` — ~2× faster than the
/// naive per-pair loop (the inner product vectorizes) at identical
/// math; tiny negative d² from cancellation is clamped to 0.  Rows of
/// the output are computed in parallel blocks above the linalg flop
/// threshold; each row's arithmetic is independent of the thread count.
pub fn cross_into_ws(p: &ArdParams, x: &Mat, z: &Mat, out: &mut Mat, ws: &mut CrossScratch) {
    assert_eq!(x.cols, z.cols);
    assert_eq!(x.cols, p.dim());
    let eta = p.eta();
    let a0_sq = p.a0_sq();
    let d = eta.len();
    let m = z.rows;
    out.resize(x.rows, m);
    if x.rows == 0 || m == 0 {
        return;
    }
    ws.prepare(&eta, z);
    let ze = &ws.ze;
    let zn = &ws.zn;
    let eta = &eta;
    let kernel = |r0: usize, blk: &mut [f64]| {
        for (i, orow) in blk.chunks_mut(m).enumerate() {
            let xrow = x.row(r0 + i);
            let mut xn = 0.0;
            for c in 0..d {
                xn += eta[c] * xrow[c] * xrow[c];
            }
            for (j, v) in orow.iter_mut().enumerate() {
                // dot(x, η∘z) = ⟨x, z⟩_η.
                let d2 = (xn + zn[j] - 2.0 * dot(xrow, ze.row(j))).max(0.0);
                *v = a0_sq * (-0.5 * d2).exp();
            }
        }
    };
    if crate::linalg::should_par(cross_flops(x.rows, m, d)) {
        pool::parallel_rows_mut(&mut out.data, m, x.rows, pool::block_size(x.rows), &|r0, blk| {
            kernel(r0, blk)
        });
    } else {
        kernel(0, &mut out.data);
    }
}

/// Cross-covariance K[X, Z] into a caller-owned buffer (temporary
/// scratch allocated internally).
pub fn cross_into(p: &ArdParams, x: &Mat, z: &Mat, out: &mut Mat) {
    let mut ws = CrossScratch::new();
    cross_into_ws(p, x, z, out, &mut ws);
}

/// Cross-covariance K[X, Z] of shape [n, m]; rows of `x`/`z` are points.
pub fn cross(p: &ArdParams, x: &Mat, z: &Mat) -> Mat {
    let mut out = Mat::empty();
    cross_into(p, x, z, &mut out);
    out
}

/// Exact per-pair evaluation (no dot-product expansion).  Used for the
/// small m×m inducing covariance, where `chol(inv(K_mm))` amplifies the
/// cancellation error of the fast form by K_mm's condition number.
/// Parallel over row blocks of `x` above the flop threshold.
pub fn cross_pairwise(p: &ArdParams, x: &Mat, z: &Mat) -> Mat {
    assert_eq!(x.cols, z.cols);
    assert_eq!(x.cols, p.dim());
    let eta = p.eta();
    let a0_sq = p.a0_sq();
    let m = z.rows;
    let mut k = Mat::zeros(x.rows, m);
    if x.rows == 0 || m == 0 {
        return k;
    }
    let eta = &eta;
    let kernel = |r0: usize, blk: &mut [f64]| {
        for (i, krow) in blk.chunks_mut(m).enumerate() {
            let xi = x.row(r0 + i);
            for (j, slot) in krow.iter_mut().enumerate() {
                let zj = z.row(j);
                let mut d2 = 0.0;
                for c in 0..eta.len() {
                    let diff = xi[c] - zj[c];
                    d2 += diff * diff * eta[c];
                }
                *slot = a0_sq * (-0.5 * d2).exp();
            }
        }
    };
    if crate::linalg::should_par(cross_flops(x.rows, m, eta.len())) {
        pool::parallel_rows_mut(&mut k.data, m, x.rows, pool::block_size(x.rows), &|r0, blk| {
            kernel(r0, blk)
        });
    } else {
        kernel(0, &mut k.data);
    }
    k
}

/// Inducing covariance K_mm with `jitter * a0^2` on the diagonal (same
/// scaled-jitter convention as ref.py's DEFAULT_JITTER).
pub fn kmm(p: &ArdParams, z: &Mat, jitter: f64) -> Mat {
    let mut k = cross_pairwise(p, z, z);
    let ridge = jitter * p.a0_sq();
    for i in 0..z.rows {
        k[(i, i)] += ridge;
    }
    k
}

/// Same jitter value used by the Python oracle (ref.DEFAULT_JITTER).
pub const DEFAULT_JITTER: f64 = 1e-4;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    #[test]
    fn diagonal_is_amplitude() {
        let p = ArdParams { log_a0: 0.3, log_eta: vec![0.1, -0.2, 0.0] };
        let x = vec![0.5, -1.0, 2.0];
        assert!((k_pair(&p, &x, &x) - p.a0_sq()).abs() < 1e-14);
    }

    #[test]
    fn symmetry_and_bounds() {
        let mut rng = Pcg64::seeded(31);
        let p = ArdParams { log_a0: 0.2, log_eta: vec![0.3, -0.1] };
        for _ in 0..100 {
            let x: Vec<f64> = (0..2).map(|_| rng.normal()).collect();
            let z: Vec<f64> = (0..2).map(|_| rng.normal()).collect();
            let kxz = k_pair(&p, &x, &z);
            let kzx = k_pair(&p, &z, &x);
            assert!((kxz - kzx).abs() < 1e-14);
            assert!(kxz > 0.0 && kxz <= p.a0_sq() + 1e-14);
        }
    }

    #[test]
    fn cross_matches_pairwise() {
        let mut rng = Pcg64::seeded(32);
        let p = ArdParams { log_a0: -0.1, log_eta: vec![0.2, 0.0, -0.3, 0.1] };
        let x = rand_mat(&mut rng, 6, 4);
        let z = rand_mat(&mut rng, 5, 4);
        let k = cross(&p, &x, &z);
        for i in 0..6 {
            for j in 0..5 {
                assert!((k[(i, j)] - k_pair(&p, x.row(i), z.row(j))).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn kmm_is_spd() {
        let mut rng = Pcg64::seeded(33);
        let p = ArdParams::unit(3);
        let z = rand_mat(&mut rng, 30, 3);
        let k = kmm(&p, &z, DEFAULT_JITTER);
        assert!(crate::linalg::cholesky_lower(&k).is_ok());
    }

    #[test]
    fn lengthscale_pruning_effect() {
        // eta -> 0 makes a dimension irrelevant (ARD pruning, appendix A.2).
        let p = ArdParams { log_a0: 0.0, log_eta: vec![0.0, -40.0] };
        let x = vec![0.0, 0.0];
        let z = vec![0.0, 100.0];
        assert!((k_pair(&p, &x, &z) - 1.0).abs() < 1e-6);
    }
}
