//! DistGP (Gal et al., 2014) substitutes: bulk-synchronous distributed
//! optimization of the full negative ELBO −L = Σ_k G_k + h.
//!
//! * `DistGP-GD` — synchronous (τ=0) distributed ADADELTA gradient
//!   descent on **all** parameters (variational + hypers), with the KL
//!   gradient (eqs. 35–36) added explicitly instead of the prox step.
//! * `DistGP-LBFGS` — master-side L-BFGS: every function/gradient
//!   evaluation is one synchronous map-reduce over the shards (which is
//!   exactly why its wall-clock per iteration is large — the effect the
//!   paper's Fig. 1 shows).
//!
//! Both run the workers as scoped threads with a full barrier per
//! evaluation — the MapReduce behaviour the paper compares against.

use super::BaselineResult;
use crate::data::Dataset;
use crate::gp::{SparseGp, Theta, ThetaLayout};
use crate::grad::EngineFactory;
use crate::linalg::Mat;
use crate::opt::{lbfgs::lbfgs_step, AdaDelta, Lbfgs};
use crate::ps::metrics::TraceRow;
use crate::util::{mnlp, rmse, Stopwatch};

/// ∇h (eqs. 35–36): dμ = μ; dU = U − diag(1/U_ii), upper triangle only.
fn kl_grad(layout: &ThetaLayout, theta: &[f64], out: &mut [f64]) {
    let m = layout.m;
    for (o, v) in out[layout.mu_range()].iter_mut().zip(&theta[layout.mu_range()]) {
        *o += v;
    }
    let ur = layout.u_range();
    let u = &theta[ur.clone()];
    let go = &mut out[ur];
    for i in 0..m {
        for j in i..m {
            let idx = i * m + j;
            go[idx] += u[idx];
            if i == j {
                let d = u[idx];
                let safe = if d.abs() < 1e-8 { 1e-8f64.copysign(d) } else { d };
                go[idx] -= 1.0 / safe;
            }
        }
    }
}

/// One synchronous map-reduce pass: f(θ) = Σ_k G_k + h, ∇f likewise.
/// Workers run in scoped threads (a full barrier, as in MapReduce).
fn full_eval(
    layout: &ThetaLayout,
    theta: &[f64],
    shards: &[Dataset],
    factory: &EngineFactory,
) -> (f64, Vec<f64>) {
    let dim = layout.len();
    let partials: Vec<(f64, Vec<f64>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = shards
            .iter()
            .enumerate()
            .map(|(k, shard)| {
                let factory = factory.clone();
                scope.spawn(move || {
                    let mut engine = factory(k);
                    let r = engine.grad(theta, &shard.x, &shard.y);
                    (r.value, r.grad)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut value = 0.0;
    let mut grad = vec![0.0; dim];
    for (v, g) in partials {
        value += v;
        for (a, b) in grad.iter_mut().zip(&g) {
            *a += b;
        }
    }
    // Add the convex KL term h(μ, U).
    let th = Theta { layout: *layout, data: theta.to_vec() };
    value += th.kl();
    kl_grad(layout, theta, &mut grad);
    (value, grad)
}

pub struct DistGpConfig {
    pub iters: u64,
    pub lr: f64,
    pub eval_every: u64,
    pub time_limit_secs: Option<f64>,
    /// L-BFGS memory (LBFGS mode only).
    pub lbfgs_mem: usize,
}

impl Default for DistGpConfig {
    fn default() -> Self {
        Self { iters: 300, lr: 1.0, eval_every: 10, time_limit_secs: None, lbfgs_mem: 10 }
    }
}

fn snapshot(
    layout: ThetaLayout,
    theta: &[f64],
    test: &Dataset,
    t: u64,
    clock: &Stopwatch,
    neg_elbo: f64,
    trace: &mut Vec<TraceRow>,
) {
    let gp = SparseGp::new(Theta { layout, data: theta.to_vec() });
    let (mean, var) = gp.predict(&test.x);
    trace.push(TraceRow {
        t_secs: clock.secs(),
        version: t,
        rmse: rmse(&mean, &test.y),
        mnlp: mnlp(&mean, &var, &test.y),
        neg_elbo: Some(neg_elbo),
    });
}

/// DistGP-GD: synchronous distributed ADADELTA descent on −L.
pub fn run_distgp_gd(
    cfg: &DistGpConfig,
    theta0: Theta,
    shards: &[Dataset],
    test: &Dataset,
    factory: EngineFactory,
) -> BaselineResult {
    let layout = theta0.layout;
    let clock = Stopwatch::start();
    let mut theta = theta0.data;
    let mut ada = AdaDelta::default_for(theta.len());
    let mut trace = Vec::new();
    for t in 0..cfg.iters {
        if let Some(limit) = cfg.time_limit_secs {
            if clock.secs() > limit {
                break;
            }
        }
        let (value, grad) = full_eval(&layout, &theta, shards, &factory);
        ada.apply(&mut theta, &grad, cfg.lr);
        // Keep U structurally upper-triangular.
        let mut th = Theta { layout, data: theta };
        th.enforce_triu();
        theta = th.data;
        if t % cfg.eval_every == 0 || t + 1 == cfg.iters {
            snapshot(layout, &theta, test, t, &clock, value, &mut trace);
        }
    }
    BaselineResult { theta, trace, wall_secs: clock.secs() }
}

/// DistGP-LBFGS: master-side L-BFGS over synchronous map-reduce evals.
pub fn run_distgp_lbfgs(
    cfg: &DistGpConfig,
    theta0: Theta,
    shards: &[Dataset],
    test: &Dataset,
    factory: EngineFactory,
) -> BaselineResult {
    let layout = theta0.layout;
    let clock = Stopwatch::start();
    let mut theta = theta0.data;
    let mut opt = Lbfgs::new(cfg.lbfgs_mem);
    let mut trace = Vec::new();
    let (mut fx, mut gx) = full_eval(&layout, &theta, shards, &factory);
    for t in 0..cfg.iters {
        if let Some(limit) = cfg.time_limit_secs {
            if clock.secs() > limit {
                break;
            }
        }
        let (nx, nf, _evals) = lbfgs_step(&mut opt, &theta, fx, &gx, |cand| {
            full_eval(&layout, cand, shards, &factory)
        });
        let stalled = (fx - nf).abs() < 1e-10 * fx.abs().max(1.0);
        theta = nx;
        let mut th = Theta { layout, data: theta };
        th.enforce_triu();
        theta = th.data;
        let r = full_eval(&layout, &theta, shards, &factory);
        fx = r.0;
        gx = r.1;
        if t % cfg.eval_every == 0 || t + 1 == cfg.iters || stalled {
            snapshot(layout, &theta, test, t, &clock, fx, &mut trace);
        }
        if stalled {
            break; // converged (possibly to the suboptimal point §6.1 sees)
        }
    }
    BaselineResult { theta, trace, wall_secs: clock.secs() }
}

/// Expose the KL gradient for tests.
pub fn kl_grad_for_test(layout: &ThetaLayout, theta: &[f64]) -> Vec<f64> {
    let mut g = vec![0.0; layout.len()];
    kl_grad(layout, theta, &mut g);
    g
}

#[allow(dead_code)]
fn unused(_: &Mat) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{kmeans, synth, Standardizer};
    use crate::grad::native_factory;
    use crate::util::rng::Pcg64;

    fn setup(seed: u64) -> (Dataset, Dataset, Theta, ThetaLayout) {
        let mut ds = synth::friedman(1200, 4, 0.4, seed);
        let mut rng = Pcg64::seeded(seed);
        ds.shuffle(&mut rng);
        let (mut tr, mut te) = ds.split(250);
        let st = Standardizer::fit(&tr);
        st.apply(&mut tr);
        st.apply(&mut te);
        let layout = ThetaLayout::new(10, 4);
        let z = kmeans::kmeans(&tr.x, 10, 10, &mut rng);
        (tr, te, Theta::init(layout, &z), layout)
    }

    #[test]
    fn kl_grad_matches_fd() {
        let layout = ThetaLayout::new(4, 2);
        let mut rng = Pcg64::seeded(9);
        let z = Mat::from_vec(4, 2, (0..8).map(|_| rng.normal()).collect());
        let mut th = Theta::init(layout, &z);
        for v in th.mu_mut() {
            *v = rng.normal();
        }
        let mut u = Mat::eye(4);
        for i in 0..4 {
            u[(i, i)] = 0.5 + rng.next_f64();
            for j in i + 1..4 {
                u[(i, j)] = rng.normal() * 0.2;
            }
        }
        th.set_u_mat(&u);
        let g = kl_grad_for_test(&layout, &th.data);
        let eps = 1e-6;
        for i in 0..layout.len() {
            let mut tp = th.clone();
            tp.data[i] += eps;
            let mut tm = th.clone();
            tm.data[i] -= eps;
            let fd = (tp.kl() - tm.kl()) / (2.0 * eps);
            assert!(
                (fd - g[i]).abs() < 1e-5 * fd.abs().max(1.0).max(g[i].abs()),
                "coord {i}: {fd} vs {}",
                g[i]
            );
        }
    }

    #[test]
    fn distgp_gd_learns() {
        let (tr, te, th, layout) = setup(21);
        let shards = tr.shard(3);
        let cfg = DistGpConfig { iters: 150, eval_every: 25, ..Default::default() };
        let res = run_distgp_gd(&cfg, th, &shards, &te, native_factory(layout));
        let last = res.trace.last().unwrap();
        let base = rmse(&vec![0.0; te.n()], &te.y);
        assert!(last.rmse < 0.65 * base, "{} vs {}", last.rmse, base);
        // -ELBO decreased.
        let first = res.trace.first().unwrap().neg_elbo.unwrap();
        assert!(last.neg_elbo.unwrap() < first);
    }

    #[test]
    fn distgp_lbfgs_decreases_objective_monotonically() {
        let (tr, te, th, layout) = setup(23);
        let shards = tr.shard(2);
        let cfg = DistGpConfig { iters: 30, eval_every: 1, ..Default::default() };
        let res = run_distgp_lbfgs(&cfg, th, &shards, &te, native_factory(layout));
        let elbos: Vec<f64> = res.trace.iter().filter_map(|r| r.neg_elbo).collect();
        assert!(elbos.len() >= 2);
        for w in elbos.windows(2) {
            assert!(w[1] <= w[0] + 1e-6 * w[0].abs(), "not monotone: {w:?}");
        }
        // LBFGS converges quickly to a decent (possibly suboptimal) fit.
        let base = rmse(&vec![0.0; te.n()], &te.y);
        assert!(res.trace.last().unwrap().rmse < base);
    }
}
