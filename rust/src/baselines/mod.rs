//! Baselines reproduced from the paper's evaluation (§6):
//!
//! * [`svigp`] — SVIGP (Hensman et al., 2013): single-machine stochastic
//!   variational inference with closed-form natural-gradient updates of
//!   q(w) and ADADELTA on the hyperparameters.
//! * [`distgp`] — DistGP (Gal et al., 2014) substitutes: bulk-synchronous
//!   distributed optimization of the same ELBO with plain gradient
//!   descent (`DistGP-GD`) or master-side L-BFGS (`DistGP-LBFGS`).
//!   See DESIGN.md §4 for the substitution rationale.
//! * [`linear`] — SGD linear regression (the Vowpal-Wabbit stand-in of
//!   §6.3).
//! * [`mean`] — the mean predictor.

pub mod distgp;
pub mod linear;
pub mod mean;
pub mod svigp;

use crate::ps::metrics::TraceRow;

/// Common result shape so benches can treat all methods uniformly.
pub struct BaselineResult {
    /// Final parameters (method-specific meaning; empty for mean/linear).
    pub theta: Vec<f64>,
    pub trace: Vec<TraceRow>,
    pub wall_secs: f64,
}
