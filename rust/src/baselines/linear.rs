//! SGD linear regression — the Vowpal-Wabbit stand-in of §6.3.
//!
//! Linear-in-features model with bias, trained by SGD with an inverse
//! decay schedule over shuffled epochs (VW's default regime: online
//! least squares).  Features/targets are expected standardized by the
//! caller (as for every other method).

use super::BaselineResult;
use crate::data::Dataset;
use crate::ps::metrics::TraceRow;
use crate::util::rng::Pcg64;
use crate::util::{rmse, Stopwatch};

pub struct LinearConfig {
    pub epochs: usize,
    pub lr0: f64,
    pub decay: f64,
    pub l2: f64,
    pub eval_every_rows: usize,
    pub seed: u64,
    pub time_limit_secs: Option<f64>,
}

impl Default for LinearConfig {
    fn default() -> Self {
        Self {
            epochs: 3,
            lr0: 0.05,
            decay: 1e-5,
            l2: 1e-8,
            eval_every_rows: 50_000,
            seed: 0,
            time_limit_secs: None,
        }
    }
}

pub struct LinearModel {
    pub w: Vec<f64>,
    pub b: f64,
}

impl LinearModel {
    pub fn predict_row(&self, x: &[f64]) -> f64 {
        self.b + crate::linalg::dot(&self.w, x)
    }

    pub fn predict(&self, x: &crate::linalg::Mat) -> Vec<f64> {
        (0..x.rows).map(|r| self.predict_row(x.row(r))).collect()
    }
}

pub fn run_linear(
    cfg: &LinearConfig,
    data: &Dataset,
    test: &Dataset,
) -> (LinearModel, BaselineResult) {
    let d = data.d();
    let n = data.n();
    let clock = Stopwatch::start();
    let mut model = LinearModel { w: vec![0.0; d], b: 0.0 };
    let mut rng = Pcg64::new(cfg.seed, 17);
    let mut order: Vec<usize> = (0..n).collect();
    let mut trace = Vec::new();
    let mut seen: u64 = 0;
    'outer: for _epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        for &i in &order {
            let x = data.x.row(i);
            let err = model.predict_row(x) - data.y[i];
            let lr = cfg.lr0 / (1.0 + cfg.decay * seen as f64);
            for (wj, xj) in model.w.iter_mut().zip(x) {
                *wj -= lr * (err * xj + cfg.l2 * *wj);
            }
            model.b -= lr * err;
            seen += 1;
            if seen as usize % cfg.eval_every_rows == 0 {
                let pred = model.predict(&test.x);
                trace.push(TraceRow {
                    t_secs: clock.secs(),
                    version: seen,
                    rmse: rmse(&pred, &test.y),
                    mnlp: f64::NAN, // point predictor: no likelihood
                    neg_elbo: None,
                });
                if let Some(limit) = cfg.time_limit_secs {
                    if clock.secs() > limit {
                        break 'outer;
                    }
                }
            }
        }
    }
    let pred = model.predict(&test.x);
    trace.push(TraceRow {
        t_secs: clock.secs(),
        version: seen,
        rmse: rmse(&pred, &test.y),
        mnlp: f64::NAN,
        neg_elbo: None,
    });
    let wall = clock.secs();
    (model, BaselineResult { theta: vec![], trace, wall_secs: wall })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, Standardizer};
    use crate::util::rng::Pcg64;

    #[test]
    fn recovers_linear_ground_truth() {
        // Purely linear data: SGD must reach near-OLS accuracy.
        let mut ds = synth::friedman(4000, 4, 0.0, 31);
        for r in 0..ds.n() {
            let x = ds.x.row(r);
            ds.y[r] = 2.0 * x[0] - 1.0 * x[1] + 0.5 * x[2] + 3.0;
        }
        let mut rng = Pcg64::seeded(31);
        ds.shuffle(&mut rng);
        let (mut tr, mut te) = ds.split(500);
        let st = Standardizer::fit(&tr);
        st.apply(&mut tr);
        st.apply(&mut te);
        let (model, res) = run_linear(&LinearConfig::default(), &tr, &te);
        let pred = model.predict(&te.x);
        assert!(rmse(&pred, &te.y) < 0.05, "rmse {}", rmse(&pred, &te.y));
        assert!(!res.trace.is_empty());
    }

    #[test]
    fn underfits_nonlinear_data() {
        // On friedman it must beat the mean but stay well above the
        // noise floor — the gap the GP closes (the §6.3 comparison).
        let mut ds = synth::friedman(4000, 4, 0.3, 33);
        let mut rng = Pcg64::seeded(33);
        ds.shuffle(&mut rng);
        let (mut tr, mut te) = ds.split(500);
        let st = Standardizer::fit(&tr);
        st.apply(&mut tr);
        st.apply(&mut te);
        let (model, _) = run_linear(&LinearConfig::default(), &tr, &te);
        let pred = model.predict(&te.x);
        let lin = rmse(&pred, &te.y);
        let mean_rmse = rmse(&vec![0.0; te.n()], &te.y);
        let noise_floor = 0.3 / st.y_std;
        assert!(lin < 0.95 * mean_rmse, "beats mean: {lin} vs {mean_rmse}");
        assert!(lin > 2.0 * noise_floor, "must underfit: {lin} vs {noise_floor}");
    }
}
