//! SVIGP (Hensman et al., 2013) in the weight-space parameterization.
//!
//! Sequential single-machine stochastic variational inference:
//! * q(w) natural-gradient updates in expectation parameters — in the
//!   weight space the ELBO is conjugate-quadratic in (μ, Σ), so a step
//!   of size ρ_t on a minibatch of size B out of n is closed-form:
//!
//!     Λ   ← (1−ρ) Λ   + ρ (I + β (n/B) Φ_bᵀ Φ_b)        (Λ = Σ⁻¹)
//!     Λμ  ← (1−ρ) Λμ  + ρ β (n/B) Φ_bᵀ y_b
//!
//! * hyperparameters (Z, ln a₀, ln η, ln σ) by ADADELTA on the n/B-scaled
//!   minibatch gradient of the data term (the KL is hyper-free).
//!
//! The paper runs SVIGP with minibatch 5000 on one CPU core; we keep the
//! same structure with a configurable batch.

use super::BaselineResult;
use crate::data::Dataset;
use crate::gp::featuremap::{FeatureMap, InducingChol};
use crate::gp::{SparseGp, Theta};
#[cfg(test)]
use crate::gp::ThetaLayout;
use crate::grad::{native::NativeEngine, GradEngine};
use crate::linalg::{cholesky_lower, spd_inverse, Mat};
use crate::opt::AdaDelta;
use crate::ps::metrics::TraceRow;
use crate::util::rng::Pcg64;
use crate::util::{mnlp, rmse, Stopwatch};

pub struct SvigpConfig {
    pub batch: usize,
    pub steps: u64,
    /// Natural-gradient rate schedule ρ_t = r0 / (1 + t/t0)^κ.
    pub r0: f64,
    pub t0: f64,
    pub kappa: f64,
    /// ADADELTA scale for the hyperparameter steps.
    pub hyper_lr: f64,
    /// Update hypers every this many natural-gradient steps.
    pub hyper_every: u64,
    pub eval_every: u64,
    pub seed: u64,
    pub time_limit_secs: Option<f64>,
}

impl Default for SvigpConfig {
    fn default() -> Self {
        Self {
            batch: 1000,
            steps: 500,
            r0: 0.8,
            t0: 50.0,
            kappa: 0.8,
            hyper_lr: 0.3,
            hyper_every: 1,
            eval_every: 10,
            seed: 0,
            time_limit_secs: None,
        }
    }
}

pub fn run_svigp(
    cfg: &SvigpConfig,
    mut theta: Theta,
    data: &Dataset,
    test: &Dataset,
) -> BaselineResult {
    let layout = theta.layout;
    let m = layout.m;
    let n = data.n();
    let clock = Stopwatch::start();
    let mut rng = Pcg64::new(cfg.seed, 7);
    let mut engine = NativeEngine::new(layout);
    // Hyper block = everything after (μ, U).
    let hyper_dim = layout.len() - layout.z_range().start;
    let mut ada = AdaDelta::default_for(hyper_dim);
    // Natural parameters of q(w).
    let mut prec = Mat::eye(m); // Σ⁻¹ (init q = prior)
    let mut prec_mu = vec![0.0; m]; // Σ⁻¹ μ
    let mut trace = Vec::new();

    for t in 0..cfg.steps {
        if let Some(limit) = cfg.time_limit_secs {
            if clock.secs() > limit {
                break;
            }
        }
        // ---- sample a minibatch ----
        let idx = rng.sample_indices(n, cfg.batch.min(n));
        let mut xb = Mat::zeros(idx.len(), layout.d);
        let mut yb = vec![0.0; idx.len()];
        for (r, &i) in idx.iter().enumerate() {
            xb.row_mut(r).copy_from_slice(data.x.row(i));
            yb[r] = data.y[i];
        }
        let scale = n as f64 / idx.len() as f64;

        // ---- natural-gradient update of q(w) ----
        let map = InducingChol::build(&theta.ard(), theta.z_mat());
        let pb = map.phi(&theta.ard(), &xb);
        let beta = theta.beta();
        let rho = cfg.r0 / (1.0 + t as f64 / cfg.t0).powf(cfg.kappa);
        let mut gram = pb.phi.gram();
        gram.scale(beta * scale);
        for i in 0..m {
            gram[(i, i)] += 1.0;
        }
        for i in 0..m * m {
            prec.data[i] = (1.0 - rho) * prec.data[i] + rho * gram.data[i];
        }
        let phity = pb.phi.tr_matvec(&yb);
        for i in 0..m {
            prec_mu[i] = (1.0 - rho) * prec_mu[i] + rho * beta * scale * phity[i];
        }
        // Materialize (μ, U) into θ.
        let sigma = spd_inverse(&prec).expect("Λ SPD");
        let mu = sigma.matvec(&prec_mu);
        theta.mu_mut().copy_from_slice(&mu);
        let l = cholesky_lower(&sigma).expect("Σ SPD");
        theta.set_u_mat(&l.transpose());

        // ---- hyperparameter step (scaled minibatch gradient) ----
        if t % cfg.hyper_every == 0 {
            let res = engine.grad(&theta.data, &xb, &yb);
            let start = layout.z_range().start;
            let hg: Vec<f64> =
                res.grad[start..].iter().map(|g| g * scale).collect();
            let hyper = &mut theta.data[start..];
            ada.apply(hyper, &hg, cfg.hyper_lr);
        }

        if t % cfg.eval_every == 0 || t + 1 == cfg.steps {
            let gp = SparseGp::new(theta.clone());
            let (mean, var) = gp.predict(&test.x);
            trace.push(TraceRow {
                t_secs: clock.secs(),
                version: t,
                rmse: rmse(&mean, &test.y),
                mnlp: mnlp(&mean, &var, &test.y),
                neg_elbo: None,
            });
        }
    }
    BaselineResult { theta: theta.data, trace, wall_secs: clock.secs() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{kmeans, synth, Standardizer};

    #[test]
    fn svigp_learns_friedman() {
        let mut ds = synth::friedman(1500, 4, 0.4, 3);
        let mut rng = Pcg64::seeded(3);
        ds.shuffle(&mut rng);
        let (mut tr, mut te) = ds.split(300);
        let st = Standardizer::fit(&tr);
        st.apply(&mut tr);
        st.apply(&mut te);
        let layout = ThetaLayout::new(12, 4);
        let z = kmeans::kmeans(&tr.x, 12, 10, &mut rng);
        let theta = Theta::init(layout, &z);
        let cfg = SvigpConfig { steps: 150, batch: 256, ..Default::default() };
        let res = run_svigp(&cfg, theta, &tr, &te);
        let last = res.trace.last().unwrap();
        let mean_rmse = rmse(&vec![0.0; te.n()], &te.y);
        assert!(last.rmse < 0.6 * mean_rmse, "{} vs {}", last.rmse, mean_rmse);
        // RMSE improved over the run.
        assert!(last.rmse < res.trace.first().unwrap().rmse);
    }

    #[test]
    fn natural_gradient_full_batch_rho1_is_exact_optimum() {
        // With ρ=1 and B=n the update lands exactly on the conjugate
        // optimum Σ=(I+βΦᵀΦ)⁻¹, μ=βΣΦᵀy.
        let mut ds = synth::friedman(300, 4, 0.3, 5);
        let mut rng = Pcg64::seeded(5);
        ds.shuffle(&mut rng);
        let st = Standardizer::fit(&ds);
        st.apply(&mut ds);
        let layout = ThetaLayout::new(8, 4);
        let z = kmeans::kmeans(&ds.x, 8, 10, &mut rng);
        let theta = Theta::init(layout, &z);
        let cfg = SvigpConfig {
            steps: 1,
            batch: 300,
            r0: 1.0,
            t0: 1e12,
            hyper_lr: 0.0,
            ..Default::default()
        };
        let res = run_svigp(&cfg, theta.clone(), &ds, &ds);
        // Compare against the closed form.
        let map = InducingChol::build(&theta.ard(), theta.z_mat());
        let pb = map.phi(&theta.ard(), &ds.x);
        let mut prec = pb.phi.gram();
        prec.scale(theta.beta());
        for i in 0..8 {
            prec[(i, i)] += 1.0;
        }
        let sigma = spd_inverse(&prec).unwrap();
        let mut mu_star = sigma.matvec(&pb.phi.tr_matvec(&ds.y));
        for v in &mut mu_star {
            *v *= theta.beta();
        }
        let got = Theta { layout, data: res.theta };
        for (a, b) in got.mu().iter().zip(&mu_star) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
    }
}
