//! The mean predictor (§6.3's weakest baseline).

use crate::data::Dataset;
use crate::util::rmse;

pub struct MeanPredictor {
    pub mean: f64,
}

impl MeanPredictor {
    pub fn fit(data: &Dataset) -> Self {
        Self { mean: data.y.iter().sum::<f64>() / data.n().max(1) as f64 }
    }

    pub fn rmse_on(&self, test: &Dataset) -> f64 {
        rmse(&vec![self.mean; test.n()], &test.y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn mean_is_fit_and_rmse_is_std() {
        let ds = synth::friedman(2000, 4, 0.1, 41);
        let mp = MeanPredictor::fit(&ds);
        let want_mean = ds.y.iter().sum::<f64>() / 2000.0;
        assert!((mp.mean - want_mean).abs() < 1e-12);
        // RMSE of the mean predictor on the training set == the std.
        let std = (ds.y.iter().map(|v| (v - want_mean).powi(2)).sum::<f64>()
            / 2000.0)
            .sqrt();
        assert!((mp.rmse_on(&ds) - std).abs() < 1e-9);
    }
}
