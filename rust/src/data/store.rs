//! Out-of-core shard store (ISSUE 3, reworked in ISSUE 7): the disk
//! layer that lets a worker train on a shard far larger than its RAM —
//! the paper's §1 regime ("billions of samples") needs data locality to
//! be a property of the *store*, not of process memory (cf. Gal et al.,
//! 2014, on distributed data placement in sparse-GP inference).
//!
//! Two on-disk formats coexist:
//!
//! # Legacy flat format `ADVGPSH1` (read + migrate only)
//!
//! ```text
//! [ 0.. 8)  magic   b"ADVGPSH1"
//! [ 8..16)  n       u64 row count        (≥ 1)
//! [16..24)  d       u64 feature count    (≥ 1)
//! [24.. )   rows    n × (d features + 1 target) f64, row-major
//! ```
//!
//! SH1 carries **no checksums**: a flipped bit on disk reaches the
//! gradient path undetected.  [`migrate_store`] upgrades an SH1 store
//! in place (bitwise row parity pinned by tests).
//!
//! # Verifiable chunk-columnar format `ADVGPSH2` (ISSUE 7)
//!
//! All values little-endian:
//!
//! ```text
//! [ 0.. 8)  magic        b"ADVGPSH2"
//! [ 8..16)  n            u64 row count           (≥ 1)
//! [16..24)  d            u64 feature count       (≥ 1)
//! [24..32)  chunk_rows   u64 rows per chunk      (≥ 1; last chunk short)
//! [32..40)  n_chunks     u64 = ⌈n / chunk_rows⌉
//! [40..48)  dir_off      u64 file offset of the chunk directory
//! [48..  )  payloads     n_chunks chunk payloads, back to back
//! [dir_off) directory    n_chunks × 40-byte entries:
//!             offset u64 | len u64 | raw_len u64 | enc u64 | sum u64
//! [ .. +8)  dir_sum      u64 FNV-1a over header ‖ directory entries
//! ```
//!
//! A chunk's *raw* payload is **columnar**: for the `r` rows it holds,
//! the f64 bit patterns of feature column 0, then column 1, …, then the
//! `r` targets (`raw_len = r·(d+1)·8`).  Columnar layout puts values of
//! like magnitude next to each other, which is what the optional
//! std-only compression (`enc = 1`) exploits: XOR-delta over
//! consecutive u64 words, then a zero-run-length byte code.  The writer
//! keeps the compressed form only when it is strictly smaller.
//!
//! `sum` is the same FNV-1a 64 used by the `ps/wire` frame checksums,
//! computed over the payload bytes **as stored** (post-compression), so
//! verification never has to decompress a corrupt chunk.  Every read
//! path recomputes it; a mismatch surfaces as a typed
//! [`StoreFault::ChunkCorrupt`] — corrupt bytes never reach the
//! gradient path.
//!
//! # Quarantine & degraded mode
//!
//! A [`ShardReader`] given a [`QuarantinePolicy`] (training paths
//! install one; standalone opens stay strict) reacts to a corrupt chunk
//! by *quarantining* it — the chunk is skipped for the rest of the
//! session, a shared counter is bumped, and one token is drawn from the
//! session-wide [`CorruptionBudget`] (refilled by every verified read,
//! mirroring the transport layer's `OutageBudget`).  Training continues
//! on the surviving rows; only a dry budget (or a shard with nothing
//! left) ends the run, typed ([`StoreFault::BudgetDry`] /
//! [`StoreFault::ShardDead`]).
//!
//! # Logical repartitioning
//!
//! The v2 manifest maps **global chunk ranges** to logical workers, so
//! [`ShardSet::repartition`] retargets a store from W to W′ workers by
//! rewriting ~100 bytes of JSON — no shard bytes move.  A worker's
//! readers are restricted to its assigned chunk ranges
//! ([`ShardReader::restrict_chunks`]).
//!
//! # Key invariants
//!
//! * **Zero steady-state allocation**: windows stream through reusable
//!   byte buffers (stored + decompressed) and one caller-owned
//!   [`Dataset`] buffer; all are grown once and recycled forever after.
//! * **Traversal parity**: the cyclic window at `(start, k)` decodes
//!   bitwise-identically to [`Dataset::copy_cyclic_window`] on the
//!   in-memory shard, for both formats.
//! * **Partition parity**: [`ShardSet::create`] writes the same
//!   contiguous near-equal partition as [`Dataset::shard`] (and
//!   enforces the same `1 ≤ r ≤ n` contract).
//! * **Detection before use**: every SH2 byte consumed by training was
//!   checksum-verified in the same read that fetched it.

use super::Dataset;
use crate::util::json::Json;
use anyhow::{ensure, Context, Result};
use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;

/// Magic bytes opening every legacy (v1) shard file.
pub const SHARD_MAGIC: [u8; 8] = *b"ADVGPSH1";
/// Magic bytes opening every chunk-columnar (v2) shard file.
pub const SHARD_MAGIC_V2: [u8; 8] = *b"ADVGPSH2";
/// Legacy shard header length in bytes (magic + n + d).
pub const SHARD_HEADER_LEN: u64 = 24;
/// v2 shard header length (magic + n + d + chunk_rows + n_chunks + dir_off).
pub const SH2_HEADER_LEN: u64 = 48;
/// Bytes per v2 chunk-directory entry (offset, len, raw_len, enc, sum).
pub const SH2_DIR_ENTRY_LEN: u64 = 40;
/// Default minibatch chunk (rows per physical chunk and streamed window).
pub const DEFAULT_CHUNK_ROWS: usize = 4096;
/// Default session-wide corruption budget: consecutive quarantines a
/// run absorbs before failing typed (verified reads refill it).
pub const DEFAULT_CORRUPTION_BUDGET: u32 = 8;
/// Name of the [`ShardSet`] manifest inside its directory.
pub const STORE_MANIFEST: &str = "store.json";

/// Typed storage faults (ISSUE 7).  Carried through `anyhow` like the
/// checkpoint layer's `TopologyConflict`: downcast with
/// `err.downcast_ref::<StoreFault>()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StoreFault {
    /// A chunk failed checksum verification (or could not be
    /// decompressed / fully read).  Strict readers return this
    /// directly; degraded readers quarantine instead.
    ChunkCorrupt { path: PathBuf, chunk: usize, detail: String },
    /// The session's [`CorruptionBudget`] ran dry at this quarantine.
    BudgetDry { path: PathBuf, chunk: usize, max: u32 },
    /// Every chunk this reader may serve is quarantined.
    ShardDead { path: PathBuf, quarantined: usize },
}

impl std::fmt::Display for StoreFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreFault::ChunkCorrupt { path, chunk, detail } => write!(
                f,
                "store: chunk {chunk} of {} corrupt: {detail}",
                path.display()
            ),
            StoreFault::BudgetDry { path, chunk, max } => write!(
                f,
                "store: corruption budget ({max}) dry quarantining chunk {chunk} of {}",
                path.display()
            ),
            StoreFault::ShardDead { path, quarantined } => write!(
                f,
                "store: every readable chunk of {} is quarantined ({quarantined})",
                path.display()
            ),
        }
    }
}

impl std::error::Error for StoreFault {}

/// Session-wide corruption budget: how many *consecutive* chunk
/// quarantines a run absorbs before failing typed.  Mirrors the
/// transport layer's `OutageBudget` refill-on-success discipline: every
/// verified chunk read calls [`CorruptionBudget::refill`], so the
/// budget bounds corruption *density*, not lifetime total.
pub struct CorruptionBudget {
    max: u32,
    used: AtomicU32,
}

impl CorruptionBudget {
    pub fn new(max: u32) -> Self {
        Self { max, used: AtomicU32::new(0) }
    }

    /// Draw one token; `false` means the budget is dry.
    pub fn take(&self) -> bool {
        self.used
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |u| {
                (u < self.max).then_some(u + 1)
            })
            .is_ok()
    }

    /// A verified read proves the device still serves good bytes:
    /// restore the full budget.
    pub fn refill(&self) {
        self.used.store(0, Ordering::SeqCst);
    }

    pub fn used(&self) -> u32 {
        self.used.load(Ordering::SeqCst)
    }

    pub fn max(&self) -> u32 {
        self.max
    }
}

/// What a degraded-mode reader shares with the rest of the session: the
/// corruption budget and the run-wide quarantine counter surfaced in
/// `ServerStats.store_quarantines`.
#[derive(Clone)]
pub struct QuarantinePolicy {
    pub budget: Arc<CorruptionBudget>,
    pub counter: Arc<AtomicU64>,
}

impl QuarantinePolicy {
    /// Fresh policy with the default budget (convenience for tests and
    /// single-reader tools).
    pub fn new_default() -> Self {
        Self {
            budget: Arc::new(CorruptionBudget::new(DEFAULT_CORRUPTION_BUDGET)),
            counter: Arc::new(AtomicU64::new(0)),
        }
    }
}

// ---------------------------------------------------------------------
// Std-only chunk compression (enc = 1): XOR-delta over consecutive u64
// words, then a byte-level zero-run-length code.  Deterministic, exact,
// and dependency-free; columnar chunks make consecutive words close in
// magnitude, so their XOR is mostly leading-zero bytes.
//
// Token stream: control byte `c`:
//   c in 0..=127   → the next c+1 bytes are literals
//   c in 128..=255 → a run of (c - 126) zero bytes (2..=129)
// ---------------------------------------------------------------------

/// Compress `raw` (length a multiple of 8).  Returns the token stream;
/// callers keep it only if it is strictly smaller than `raw`.
fn sh2_compress(raw: &[u8]) -> Vec<u8> {
    debug_assert!(raw.len() % 8 == 0);
    // XOR-delta pass.
    let mut delta = Vec::with_capacity(raw.len());
    let mut prev = 0u64;
    for w in raw.chunks_exact(8) {
        let cur = u64::from_le_bytes(w.try_into().unwrap());
        delta.extend_from_slice(&(cur ^ prev).to_le_bytes());
        prev = cur;
    }
    // Zero-RLE pass.
    let mut out = Vec::with_capacity(raw.len() / 2);
    let mut i = 0usize;
    while i < delta.len() {
        if delta[i] == 0 {
            let mut run = 1usize;
            while i + run < delta.len() && delta[i + run] == 0 && run < 129 {
                run += 1;
            }
            if run >= 2 {
                out.push((run as u8 - 2) + 128);
                i += run;
                continue;
            }
        }
        // Literal run: up to 128 bytes, stopping before a zero pair.
        let start = i;
        let mut len = 0usize;
        while i < delta.len() && len < 128 {
            if delta[i] == 0 && i + 1 < delta.len() && delta[i + 1] == 0 {
                break;
            }
            i += 1;
            len += 1;
        }
        out.push(len as u8 - 1);
        out.extend_from_slice(&delta[start..i]);
    }
    out
}

/// Invert [`sh2_compress`] into `out` (cleared first).  Any structural
/// mismatch (overrun, wrong final length) is an error — with the
/// checksum already verified it would mean a writer bug, but the reader
/// still refuses to fabricate rows.
fn sh2_decompress(enc: &[u8], raw_len: usize, out: &mut Vec<u8>) -> Result<()> {
    out.clear();
    out.reserve(raw_len);
    let mut i = 0usize;
    while i < enc.len() {
        let c = enc[i];
        i += 1;
        if c < 128 {
            let len = c as usize + 1;
            ensure!(i + len <= enc.len(), "compressed chunk: literal overruns payload");
            out.extend_from_slice(&enc[i..i + len]);
            i += len;
        } else {
            let run = c as usize - 126;
            out.extend(std::iter::repeat(0u8).take(run));
        }
        ensure!(out.len() <= raw_len, "compressed chunk: inflates past raw_len");
    }
    ensure!(
        out.len() == raw_len,
        "compressed chunk: decoded {} bytes, expected {raw_len}",
        out.len()
    );
    // Undo the XOR-delta in place.
    let mut prev = 0u64;
    for w in out.chunks_exact_mut(8) {
        let cur = u64::from_le_bytes((&*w).try_into().unwrap()) ^ prev;
        w.copy_from_slice(&cur.to_le_bytes());
        prev = cur;
    }
    Ok(())
}

/// One v2 chunk-directory entry, as stored on disk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkDesc {
    /// Absolute file offset of the stored payload.
    pub offset: u64,
    /// Stored payload length (compressed length when `enc == 1`).
    pub len: u64,
    /// Uncompressed payload length = rows·(d+1)·8.
    pub raw_len: u64,
    /// 0 = raw columnar bytes, 1 = delta/RLE compressed.
    pub enc: u64,
    /// FNV-1a 64 over the stored payload bytes.
    pub sum: u64,
}

impl ChunkDesc {
    fn to_bytes(self) -> [u8; SH2_DIR_ENTRY_LEN as usize] {
        let mut b = [0u8; SH2_DIR_ENTRY_LEN as usize];
        b[0..8].copy_from_slice(&self.offset.to_le_bytes());
        b[8..16].copy_from_slice(&self.len.to_le_bytes());
        b[16..24].copy_from_slice(&self.raw_len.to_le_bytes());
        b[24..32].copy_from_slice(&self.enc.to_le_bytes());
        b[32..40].copy_from_slice(&self.sum.to_le_bytes());
        b
    }

    fn from_bytes(b: &[u8]) -> Self {
        let u = |r: Range<usize>| u64::from_le_bytes(b[r].try_into().unwrap());
        Self {
            offset: u(0..8),
            len: u(8..16),
            raw_len: u(16..24),
            enc: u(24..32),
            sum: u(32..40),
        }
    }
}

fn sh2_header_bytes(n: u64, d: u64, chunk_rows: u64, n_chunks: u64, dir_off: u64) -> [u8; 48] {
    let mut h = [0u8; SH2_HEADER_LEN as usize];
    h[0..8].copy_from_slice(&SHARD_MAGIC_V2);
    h[8..16].copy_from_slice(&n.to_le_bytes());
    h[16..24].copy_from_slice(&d.to_le_bytes());
    h[24..32].copy_from_slice(&chunk_rows.to_le_bytes());
    h[32..40].copy_from_slice(&n_chunks.to_le_bytes());
    h[40..48].copy_from_slice(&dir_off.to_le_bytes());
    h
}

/// Streaming writer for one ADVGPSH2 shard file.
///
/// Rows are buffered into physical chunks of `chunk_rows`; each full
/// chunk is transposed to columnar order, optionally compressed,
/// checksummed, and appended to `<path>.tmp`.  [`ShardWriter::finish`]
/// writes the chunk directory + directory checksum, patches the header,
/// fsyncs, and atomically renames the file into place.  An abandoned
/// writer removes its temp file, so aborted writes leave nothing
/// behind.
pub struct ShardWriter {
    /// `None` once `finish` has consumed the stream.
    w: Option<BufWriter<File>>,
    path: PathBuf,
    tmp: PathBuf,
    d: usize,
    n: u64,
    chunk_rows: usize,
    /// Row-major staging for the chunk being filled.
    pending: Vec<f64>,
    pending_rows: usize,
    descs: Vec<ChunkDesc>,
    /// Next payload write offset.
    pos: u64,
    /// Reusable columnar / compressed scratch.
    raw: Vec<u8>,
    comp: Vec<u8>,
}

impl ShardWriter {
    /// Start a shard at `path` for `d`-feature rows with the default
    /// physical chunk size.
    pub fn create(path: &Path, d: usize) -> Result<Self> {
        Self::create_with(path, d, DEFAULT_CHUNK_ROWS)
    }

    /// Start a shard at `path` with `chunk_rows` rows per physical
    /// chunk.
    pub fn create_with(path: &Path, d: usize, chunk_rows: usize) -> Result<Self> {
        ensure!(d >= 1, "shard store needs d >= 1 features (got {d})");
        ensure!(chunk_rows >= 1, "shard store needs chunk_rows >= 1");
        let tmp = tmp_path(path);
        let f = File::create(&tmp)
            .with_context(|| format!("create shard temp {}", tmp.display()))?;
        let mut w = BufWriter::new(f);
        // Header placeholder — every field patched by finish().
        w.write_all(&[0u8; SH2_HEADER_LEN as usize])?;
        Ok(Self {
            w: Some(w),
            path: path.to_path_buf(),
            tmp,
            d,
            n: 0,
            chunk_rows,
            pending: Vec::new(),
            pending_rows: 0,
            descs: Vec::new(),
            pos: SH2_HEADER_LEN,
            raw: Vec::new(),
            comp: Vec::new(),
        })
    }

    /// Append one row (`x` must have exactly `d` features).
    pub fn push_row(&mut self, x: &[f64], y: f64) -> Result<()> {
        ensure!(
            x.len() == self.d,
            "row has {} features, shard expects {}",
            x.len(),
            self.d
        );
        self.pending.extend_from_slice(x);
        self.pending.push(y);
        self.pending_rows += 1;
        self.n += 1;
        if self.pending_rows == self.chunk_rows {
            self.flush_chunk()?;
        }
        Ok(())
    }

    /// Append every row of `ds`.
    pub fn push_dataset(&mut self, ds: &Dataset) -> Result<()> {
        for r in 0..ds.n() {
            self.push_row(ds.x.row(r), ds.y[r])?;
        }
        Ok(())
    }

    /// Transpose the pending rows to columnar order, compress if that
    /// helps, checksum, and append as one chunk.
    fn flush_chunk(&mut self) -> Result<()> {
        let rows = self.pending_rows;
        if rows == 0 {
            return Ok(());
        }
        let d = self.d;
        let stride = d + 1;
        self.raw.clear();
        self.raw.reserve(rows * stride * 8);
        for c in 0..stride {
            for r in 0..rows {
                self.raw.extend_from_slice(&self.pending[r * stride + c].to_le_bytes());
            }
        }
        self.comp = sh2_compress(&self.raw);
        let (stored, enc): (&[u8], u64) = if self.comp.len() < self.raw.len() {
            (&self.comp, 1)
        } else {
            (&self.raw, 0)
        };
        let sum = crate::util::fnv1a64(crate::util::FNV1A64_INIT, stored);
        let w = self.w.as_mut().expect("writer already finished");
        w.write_all(stored)?;
        self.descs.push(ChunkDesc {
            offset: self.pos,
            len: stored.len() as u64,
            raw_len: self.raw.len() as u64,
            enc,
            sum,
        });
        self.pos += stored.len() as u64;
        self.pending.clear();
        self.pending_rows = 0;
        Ok(())
    }

    /// Seal the shard: flush the tail chunk, write the directory and
    /// its checksum, patch the header, fsync, and rename the temp file
    /// to its final path.  Returns the row count; on error the temp
    /// file is removed.
    pub fn finish(mut self) -> Result<u64> {
        let res = self.finish_inner();
        if res.is_err() {
            let _ = std::fs::remove_file(&self.tmp);
        }
        res
    }

    fn finish_inner(&mut self) -> Result<u64> {
        ensure!(self.n >= 1, "refusing to seal an empty shard (0 rows)");
        self.flush_chunk()?;
        let dir_off = self.pos;
        let header = sh2_header_bytes(
            self.n,
            self.d as u64,
            self.chunk_rows as u64,
            self.descs.len() as u64,
            dir_off,
        );
        let mut dir_sum = crate::util::fnv1a64(crate::util::FNV1A64_INIT, &header);
        let mut w = self.w.take().expect("writer already finished");
        for desc in &self.descs {
            let b = desc.to_bytes();
            dir_sum = crate::util::fnv1a64(dir_sum, &b);
            w.write_all(&b)?;
        }
        w.write_all(&dir_sum.to_le_bytes())?;
        w.flush()?;
        w.seek(SeekFrom::Start(0))?;
        w.write_all(&header)?;
        w.flush()?;
        let f = w.into_inner().context("flush shard writer")?;
        f.sync_all().context("fsync shard")?;
        drop(f);
        std::fs::rename(&self.tmp, &self.path).with_context(|| {
            format!("rename {} -> {}", self.tmp.display(), self.path.display())
        })?;
        // Durability contract (ISSUE 6): fsync(file) + rename + fsync
        // (parent dir).  The file sync makes the *contents* durable, the
        // rename makes the sealed name appear atomically, and the
        // directory sync makes the rename itself survive a crash — on
        // ext4/xfs an unsynced directory entry can vanish on power loss,
        // leaving a complete shard nobody can find.  Directory fsync is
        // unsupported on some filesystems (and on Windows), so failure
        // here is best-effort by design: the rename already succeeded
        // and readers of a live process see the sealed file either way.
        if let Some(parent) = self.path.parent() {
            if let Ok(dir) = File::open(parent) {
                let _ = dir.sync_all();
            }
        }
        Ok(self.n)
    }
}

impl Drop for ShardWriter {
    fn drop(&mut self) {
        // Unfinished writer: close the stream, then discard the temp
        // file so aborted writes don't accumulate.  (`finish` takes the
        // stream out first, so a sealed shard is never touched.)
        if self.w.take().is_some() {
            let _ = std::fs::remove_file(&self.tmp);
        }
    }
}

/// Write `ds` as a single v2 shard file at `path` (atomic; see
/// [`ShardWriter`]).
pub fn write_shard(path: &Path, ds: &Dataset) -> Result<()> {
    let mut w = ShardWriter::create(path, ds.d())?;
    w.push_dataset(ds)?;
    w.finish()?;
    Ok(())
}

/// Write `ds` in the legacy flat ADVGPSH1 format (migration sources,
/// compatibility tests).  Atomic like the v2 writer.
pub fn write_shard_v1(path: &Path, ds: &Dataset) -> Result<()> {
    ensure!(ds.n() >= 1 && ds.d() >= 1, "refusing to write a degenerate v1 shard");
    let tmp = tmp_path(path);
    let mut bytes = Vec::with_capacity(SHARD_HEADER_LEN as usize + ds.n() * (ds.d() + 1) * 8);
    bytes.extend_from_slice(&SHARD_MAGIC);
    bytes.extend_from_slice(&(ds.n() as u64).to_le_bytes());
    bytes.extend_from_slice(&(ds.d() as u64).to_le_bytes());
    for r in 0..ds.n() {
        for v in ds.x.row(r) {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.extend_from_slice(&ds.y[r].to_le_bytes());
    }
    std::fs::write(&tmp, &bytes)
        .with_context(|| format!("write v1 shard temp {}", tmp.display()))?;
    let f = File::open(&tmp)?;
    f.sync_all().context("fsync v1 shard")?;
    drop(f);
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))?;
    if let Some(parent) = path.parent() {
        if let Ok(dir) = File::open(parent) {
            let _ = dir.sync_all();
        }
    }
    Ok(())
}

/// Order-sensitive FNV-1a fingerprint over a dataset's exact f64 bit
/// patterns (features row-major, then targets).  Stored in the
/// [`ShardSet`] manifest so a reused store can be tied to its *source
/// data*, not just its shape — two datasets with equal `(n, d)` but
/// different contents (another seed, a regenerated CSV) fingerprint
/// differently.
pub fn dataset_fingerprint(ds: &Dataset) -> u64 {
    let mut h = crate::util::FNV1A64_INIT;
    for v in ds.x.data.iter().chain(&ds.y) {
        h = crate::util::fnv1a64(h, &v.to_le_bytes());
    }
    h
}

/// v2-specific reader state.
struct Sh2 {
    /// Rows per physical chunk (last chunk may be short).
    phys_rows: usize,
    dir: Vec<ChunkDesc>,
    quarantined: Vec<bool>,
    /// Quarantine events in discovery order (the replayable trace).
    trace: Vec<usize>,
    /// Reusable decompressed-payload scratch.
    raw: Vec<u8>,
}

/// Streams fixed-size minibatch windows out of one shard file (either
/// format; v2 chunks are checksum-verified on every read).
///
/// The reader holds a cursor for [`ShardReader::next_window`] and
/// reusable byte buffers; windows wrap cyclically so offsets
/// `start, start + k, start + 2k, …` (mod n) tile the whole shard
/// within ⌈n/k⌉ reads from any starting offset — the same coverage
/// guarantee as [`Dataset::copy_cyclic_window`].
///
/// ```
/// use advgp::data::store::{write_shard, ShardReader};
/// use advgp::data::Dataset;
/// use advgp::linalg::Mat;
///
/// let dir = std::env::temp_dir().join("advgp_doc_shard_reader");
/// std::fs::create_dir_all(&dir).unwrap();
/// let path = dir.join("toy.shard");
/// let ds = Dataset {
///     x: Mat::from_vec(5, 2, (0..10).map(|i| i as f64).collect()),
///     y: (0..5).map(|i| 10.0 * i as f64).collect(),
/// };
/// write_shard(&path, &ds).unwrap();
///
/// let mut reader = ShardReader::open(&path).unwrap();
/// reader.set_chunk_rows(2);
/// let mut window = Dataset { x: Mat::empty(), y: Vec::new() };
/// reader.next_window(&mut window).unwrap(); // rows 0, 1
/// assert_eq!(window.y, vec![0.0, 10.0]);
/// reader.next_window(&mut window).unwrap(); // rows 2, 3
/// reader.next_window(&mut window).unwrap(); // rows 4, 0 (wraps)
/// assert_eq!(window.y, vec![40.0, 0.0]);
/// assert_eq!((reader.n(), reader.d()), (5, 2));
/// ```
pub struct ShardReader {
    f: File,
    path: PathBuf,
    /// Absolute row count of the file.
    n: usize,
    d: usize,
    /// Window rows per `next_window` (logical, independent of the
    /// physical chunk size).
    chunk_rows: usize,
    /// Streaming cursor, relative to the restriction window.
    offset: usize,
    /// Reusable raw block buffer (grown once, recycled per read).
    buf: Vec<u8>,
    /// `None` for legacy SH1 files.
    v2: Option<Sh2>,
    /// Restriction window `[row_lo, row_hi)` in absolute rows — the
    /// logical-repartitioning hook.  Defaults to the whole file.
    row_lo: usize,
    row_hi: usize,
    /// Installed by training paths; turns corrupt chunks into
    /// quarantines instead of hard errors.
    policy: Option<QuarantinePolicy>,
}

impl ShardReader {
    /// Open and validate a shard file (either format).  For v2 the
    /// chunk directory is read and its checksum verified here; chunk
    /// payloads are verified lazily, on each read.
    pub fn open(path: &Path) -> Result<Self> {
        let mut f = File::open(path)
            .with_context(|| format!("open shard {}", path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)
            .with_context(|| format!("shard {} shorter than its magic", path.display()))?;
        if magic == SHARD_MAGIC_V2 {
            return Self::open_v2(f, path);
        }
        ensure!(
            magic == SHARD_MAGIC,
            "shard {}: bad magic {:?} (want {:?} or {:?})",
            path.display(),
            &magic,
            SHARD_MAGIC,
            SHARD_MAGIC_V2
        );
        let mut rest = [0u8; 16];
        f.read_exact(&mut rest).with_context(|| {
            format!("shard {} shorter than its header", path.display())
        })?;
        let n = u64::from_le_bytes(rest[0..8].try_into().unwrap());
        let d = u64::from_le_bytes(rest[8..16].try_into().unwrap());
        ensure!(n >= 1 && d >= 1, "shard {}: degenerate n={n} d={d}", path.display());
        let want = SHARD_HEADER_LEN as u128 + n as u128 * (d + 1) as u128 * 8;
        let have = f.metadata()?.len() as u128;
        ensure!(
            have == want,
            "shard {}: {have} bytes on disk, header declares {want} \
             (truncated or corrupt)",
            path.display()
        );
        Ok(Self {
            f,
            path: path.to_path_buf(),
            n: n as usize,
            d: d as usize,
            chunk_rows: DEFAULT_CHUNK_ROWS,
            offset: 0,
            buf: Vec::new(),
            v2: None,
            row_lo: 0,
            row_hi: n as usize,
            policy: None,
        })
    }

    fn open_v2(mut f: File, path: &Path) -> Result<Self> {
        let mut header = [0u8; SH2_HEADER_LEN as usize];
        header[..8].copy_from_slice(&SHARD_MAGIC_V2);
        f.read_exact(&mut header[8..]).with_context(|| {
            format!("shard {} shorter than its v2 header", path.display())
        })?;
        let u = |r: Range<usize>| u64::from_le_bytes(header[r].try_into().unwrap());
        let (n, d, phys, n_chunks, dir_off) =
            (u(8..16), u(16..24), u(24..32), u(32..40), u(40..48));
        ensure!(n >= 1 && d >= 1 && phys >= 1, "shard {}: degenerate header", path.display());
        ensure!(
            n_chunks == n.div_ceil(phys),
            "shard {}: header declares {n_chunks} chunks, {n} rows / {phys} \
             per chunk implies {}",
            path.display(),
            n.div_ceil(phys)
        );
        let want = dir_off as u128 + n_chunks as u128 * SH2_DIR_ENTRY_LEN as u128 + 8;
        let have = f.metadata()?.len() as u128;
        ensure!(
            dir_off >= SH2_HEADER_LEN && have == want,
            "shard {}: {have} bytes on disk, directory layout implies {want} \
             (truncated or corrupt)",
            path.display()
        );
        f.seek(SeekFrom::Start(dir_off))?;
        let dir_bytes = n_chunks as usize * SH2_DIR_ENTRY_LEN as usize;
        let mut block = vec![0u8; dir_bytes + 8];
        f.read_exact(&mut block).with_context(|| {
            format!("shard {}: short read of chunk directory", path.display())
        })?;
        let stored_sum = u64::from_le_bytes(block[dir_bytes..].try_into().unwrap());
        let mut sum = crate::util::fnv1a64(crate::util::FNV1A64_INIT, &header);
        sum = crate::util::fnv1a64(sum, &block[..dir_bytes]);
        ensure!(
            sum == stored_sum,
            "shard {}: chunk directory checksum mismatch \
             (stored {stored_sum:016x}, computed {sum:016x})",
            path.display()
        );
        let mut dir = Vec::with_capacity(n_chunks as usize);
        let mut pos = SH2_HEADER_LEN;
        for c in 0..n_chunks as usize {
            let e = ChunkDesc::from_bytes(
                &block[c * SH2_DIR_ENTRY_LEN as usize..(c + 1) * SH2_DIR_ENTRY_LEN as usize],
            );
            let rows = if c as u64 + 1 == n_chunks { n - c as u64 * phys } else { phys };
            ensure!(
                e.offset == pos
                    && e.offset + e.len <= dir_off
                    && e.raw_len == rows * (d + 1) * 8
                    && e.enc <= 1
                    && (e.enc == 1 || e.len == e.raw_len),
                "shard {}: chunk {c} directory entry inconsistent",
                path.display()
            );
            pos = e.offset + e.len;
            dir.push(e);
        }
        ensure!(
            pos == dir_off,
            "shard {}: chunk payloads do not tile the data region",
            path.display()
        );
        Ok(Self {
            f,
            path: path.to_path_buf(),
            n: n as usize,
            d: d as usize,
            chunk_rows: phys as usize,
            offset: 0,
            buf: Vec::new(),
            v2: Some(Sh2 {
                phys_rows: phys as usize,
                quarantined: vec![false; n_chunks as usize],
                trace: Vec::new(),
                raw: Vec::new(),
                dir,
            }),
            row_lo: 0,
            row_hi: n as usize,
            policy: None,
        })
    }

    /// Rows this reader serves (the restriction window when one is
    /// installed, else the whole file).
    pub fn n(&self) -> usize {
        self.row_hi - self.row_lo
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Is this a chunk-columnar (checksummed) v2 shard?
    pub fn is_v2(&self) -> bool {
        self.v2.is_some()
    }

    /// Physical chunks in the file (1 for legacy SH1).
    pub fn n_chunks(&self) -> usize {
        self.v2.as_ref().map_or(1, |v| v.dir.len())
    }

    /// Rows per physical chunk (v2 only).
    pub fn phys_chunk_rows(&self) -> Option<usize> {
        self.v2.as_ref().map(|v| v.phys_rows)
    }

    /// Rows per [`ShardReader::next_window`] call (clamped to n).
    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows.min(self.n())
    }

    pub fn set_chunk_rows(&mut self, rows: usize) {
        self.chunk_rows = rows.max(1);
    }

    /// Move the streaming cursor (wraps mod the served row count).
    pub fn seek_to(&mut self, offset: usize) {
        self.offset = offset % self.n();
    }

    /// Current streaming cursor (relative to the restriction window).
    pub fn cursor(&self) -> usize {
        self.offset
    }

    /// Advance the cursor as `windows` strict `next_window` calls would
    /// (arithmetic only — no I/O, no verification).  Used to replay a
    /// persisted `(offset, local_iter)` checkpoint cursor; exact for
    /// intact stores, approximate once quarantines have perturbed the
    /// walk (degraded runs don't promise bitwise resume).
    pub fn fast_forward(&mut self, windows: u64) {
        let ln = self.n() as u128;
        if ln == 0 {
            return;
        }
        let k = self.chunk_rows() as u128;
        self.offset = ((self.offset as u128 + (windows as u128 % ln) * k % ln) % ln) as usize;
    }

    /// Install the session's degraded-mode policy: corrupt chunks are
    /// quarantined (counted against `policy.counter` and
    /// `policy.budget`) instead of failing the read.
    pub fn set_fault_policy(&mut self, policy: QuarantinePolicy) {
        self.policy = Some(policy);
    }

    /// Restrict the reader to physical chunks `[lo, hi)` — the reader
    /// then serves only those rows, cyclically (logical repartitioning;
    /// v2 only).  Resets the cursor.
    pub fn restrict_chunks(&mut self, lo: usize, hi: usize) -> Result<()> {
        let v2 = self
            .v2
            .as_ref()
            .with_context(|| format!("{}: chunk restriction needs a v2 shard", self.path.display()))?;
        ensure!(
            lo < hi && hi <= v2.dir.len(),
            "{}: chunk range {lo}..{hi} out of 0..{}",
            self.path.display(),
            v2.dir.len()
        );
        self.row_lo = lo * v2.phys_rows;
        self.row_hi = (hi * v2.phys_rows).min(self.n);
        self.offset = 0;
        Ok(())
    }

    /// Quarantine events so far, in discovery order (v2 only).
    pub fn quarantine_trace(&self) -> Vec<usize> {
        self.v2.as_ref().map_or_else(Vec::new, |v| v.trace.clone())
    }

    /// Capacity of the internal byte buffer — exposed so tests can pin
    /// the zero-steady-state-allocation guarantee.
    pub fn buf_capacity(&self) -> usize {
        self.buf.capacity()
    }

    /// Verify one physical chunk's checksum (and decompressibility)
    /// without touching quarantine state.  SH1 files have no chunk
    /// checksums; their single pseudo-chunk trivially passes (the open
    /// already validated the length).
    pub fn verify_chunk(&mut self, c: usize) -> Result<()> {
        if self.v2.is_none() {
            ensure!(c == 0, "{}: SH1 shard has one pseudo-chunk", self.path.display());
            return Ok(());
        }
        self.load_chunk(c)
    }

    /// Read `k` consecutive rows starting at `start` (absolute file
    /// rows, wrapping around the end) into `out` — the on-disk twin of
    /// [`Dataset::copy_cyclic_window`], bitwise-identical to it on the
    /// same data.  **Strict**: a corrupt v2 chunk fails the read typed,
    /// regardless of any installed policy.  Allocation-free once `out`
    /// and the internal buffers are warm.
    pub fn read_window(&mut self, start: usize, k: usize, out: &mut Dataset) -> Result<()> {
        let n = self.n;
        let d = self.d;
        let k = k.min(n);
        out.x.resize(k, d);
        out.y.resize(k, 0.0);
        if k == 0 {
            return Ok(());
        }
        let start = start % n;
        let first = k.min(n - start);
        self.fetch_rows(start, first, 0, out)?;
        if first < k {
            self.fetch_rows(0, k - first, first, out)?; // wrapped prefix
        }
        Ok(())
    }

    /// Stream the next `chunk_rows()` window at the cursor and advance
    /// it, wrapping cyclically within the (possibly restricted) row
    /// range.  Returns the rows read.
    ///
    /// With a [`QuarantinePolicy`] installed on a v2 shard this is the
    /// **degraded-mode** entry point: corrupt chunks are quarantined
    /// and skipped, the window is filled from surviving rows (possibly
    /// fewer than requested), and only a dry budget or a fully
    /// quarantined shard errors (typed).
    pub fn next_window(&mut self, out: &mut Dataset) -> Result<usize> {
        let ln = self.n();
        let k = self.chunk_rows.min(ln);
        if self.v2.is_some() && self.policy.is_some() {
            return self.next_window_degraded(k, out);
        }
        out.x.resize(k, self.d);
        out.y.resize(k, 0.0);
        let first = k.min(ln - self.offset);
        self.fetch_rows(self.row_lo + self.offset, first, 0, out)?;
        if first < k {
            self.fetch_rows(self.row_lo, k - first, first, out)?;
        }
        self.offset = (self.offset + k) % ln;
        Ok(k)
    }

    /// Materialize the whole shard (tests / small-data convenience —
    /// defeats the point of the store for real runs).  Strict.
    pub fn read_all(&mut self) -> Result<Dataset> {
        let mut out = Dataset { x: crate::linalg::Mat::empty(), y: Vec::new() };
        let n = self.n;
        self.read_window(0, n, &mut out)?;
        Ok(out)
    }

    // -- internals ----------------------------------------------------

    fn next_window_degraded(&mut self, k: usize, out: &mut Dataset) -> Result<usize> {
        let d = self.d;
        out.x.resize(k, d);
        out.y.resize(k, 0.0);
        let ln = self.n();
        let phys = self.v2.as_ref().unwrap().phys_rows;
        let (mut got, mut pos, mut scanned) = (0usize, self.offset, 0usize);
        while got < k && scanned < ln {
            let abs = self.row_lo + pos;
            let c = abs / phys;
            let seg_end = ((c + 1) * phys).min(self.row_hi);
            let seg = seg_end - abs;
            if self.v2.as_ref().unwrap().quarantined[c] {
                pos = (pos + seg) % ln;
                scanned += seg;
                continue;
            }
            let take = seg.min(k - got);
            match self.copy_from_chunk(c, abs - c * phys, take, got, out) {
                Ok(()) => {
                    got += take;
                    pos = (pos + take) % ln;
                    scanned += take;
                    // A verified read proves the device is still
                    // serving good bytes (OutageBudget discipline).
                    self.policy.as_ref().unwrap().budget.refill();
                }
                Err(e) => {
                    self.quarantine(c, e)?;
                    pos = (pos + seg) % ln;
                    scanned += seg;
                }
            }
        }
        if got == 0 {
            let quarantined =
                self.v2.as_ref().unwrap().quarantined.iter().filter(|q| **q).count();
            return Err(StoreFault::ShardDead { path: self.path.clone(), quarantined }.into());
        }
        self.offset = pos;
        out.x.resize(got, d);
        out.y.resize(got, 0.0);
        Ok(got)
    }

    /// Record a fresh quarantine: mark the chunk, append to the trace,
    /// bump the shared counter, and draw one budget token (typed
    /// failure when dry).
    fn quarantine(&mut self, c: usize, cause: anyhow::Error) -> Result<()> {
        let policy = self.policy.clone().expect("quarantine without a policy");
        let v2 = self.v2.as_mut().expect("quarantine on a v1 shard");
        debug_assert!(!v2.quarantined[c]);
        v2.quarantined[c] = true;
        v2.trace.push(c);
        policy.counter.fetch_add(1, Ordering::Relaxed);
        crate::log_warn!(
            "store: quarantined chunk {c} of {} ({cause:#}); {} of budget {} used",
            self.path.display(),
            policy.budget.used() + 1,
            policy.budget.max()
        );
        if !policy.budget.take() {
            return Err(StoreFault::BudgetDry {
                path: self.path.clone(),
                chunk: c,
                max: policy.budget.max(),
            }
            .into());
        }
        Ok(())
    }

    /// Ranged read of `rows` absolute rows at `row0` into `out` rows
    /// `out_row0..`, dispatching on format.  Strict (errors propagate).
    fn fetch_rows(
        &mut self,
        row0: usize,
        rows: usize,
        out_row0: usize,
        out: &mut Dataset,
    ) -> Result<()> {
        if self.v2.is_none() {
            return self.read_rows_v1(row0, rows, out_row0, out);
        }
        let phys = self.v2.as_ref().unwrap().phys_rows;
        let (mut row0, mut rows, mut out_row0) = (row0, rows, out_row0);
        while rows > 0 {
            let c = row0 / phys;
            let in_chunk = row0 - c * phys;
            let chunk_rows = self.rows_in_chunk(c);
            let take = rows.min(chunk_rows - in_chunk);
            self.copy_from_chunk(c, in_chunk, take, out_row0, out)?;
            row0 += take;
            rows -= take;
            out_row0 += take;
        }
        Ok(())
    }

    fn rows_in_chunk(&self, c: usize) -> usize {
        let v2 = self.v2.as_ref().unwrap();
        if c + 1 == v2.dir.len() {
            self.n - c * v2.phys_rows
        } else {
            v2.phys_rows
        }
    }

    /// Fetch + verify chunk `c` and de-interleave rows
    /// `[r0, r0 + rows)` of it (chunk-relative) into `out` at
    /// `out_row0`.
    fn copy_from_chunk(
        &mut self,
        c: usize,
        r0: usize,
        rows: usize,
        out_row0: usize,
        out: &mut Dataset,
    ) -> Result<()> {
        self.load_chunk(c)?;
        let d = self.d;
        let chunk_rows = self.rows_in_chunk(c);
        let v2 = self.v2.as_ref().unwrap();
        let words = if v2.dir[c].enc == 1 { &v2.raw } else { &self.buf };
        for r in 0..rows {
            let rr = r0 + r;
            let xrow = out.x.row_mut(out_row0 + r);
            for col in 0..d {
                let o = (col * chunk_rows + rr) * 8;
                xrow[col] = f64::from_le_bytes(words[o..o + 8].try_into().unwrap());
            }
            let o = (d * chunk_rows + rr) * 8;
            out.y[out_row0 + r] = f64::from_le_bytes(words[o..o + 8].try_into().unwrap());
        }
        Ok(())
    }

    /// Read chunk `c`'s stored payload into `buf`, verify its FNV-1a
    /// checksum, and (when compressed) decompress into the v2 scratch.
    /// Every read re-verifies — corrupt bytes never reach a caller.
    fn load_chunk(&mut self, c: usize) -> Result<()> {
        let desc = self.v2.as_ref().unwrap().dir[c];
        let path = self.path.clone();
        let corrupt = move |detail: String| -> anyhow::Error {
            StoreFault::ChunkCorrupt { path: path.clone(), chunk: c, detail }.into()
        };
        let len = desc.len as usize;
        self.buf.resize(len, 0);
        self.f.seek(SeekFrom::Start(desc.offset))?;
        if let Err(e) = self.f.read_exact(&mut self.buf[..len]) {
            return Err(corrupt(format!("short read ({e})")));
        }
        let sum = crate::util::fnv1a64(crate::util::FNV1A64_INIT, &self.buf[..len]);
        if sum != desc.sum {
            return Err(corrupt(format!(
                "checksum mismatch (stored {:016x}, computed {sum:016x})",
                desc.sum
            )));
        }
        if desc.enc == 1 {
            let buf = std::mem::take(&mut self.buf);
            let v2 = self.v2.as_mut().unwrap();
            let res = sh2_decompress(&buf[..len], desc.raw_len as usize, &mut v2.raw);
            self.buf = buf;
            if let Err(e) = res {
                return Err(corrupt(format!("{e:#}")));
            }
        }
        Ok(())
    }

    /// Legacy flat-format ranged read, de-interleaving features and
    /// target.
    fn read_rows_v1(
        &mut self,
        row0: usize,
        rows: usize,
        out_row0: usize,
        out: &mut Dataset,
    ) -> Result<()> {
        let d = self.d;
        let stride = (d + 1) * 8;
        let bytes = rows * stride;
        self.buf.resize(bytes, 0);
        self.f
            .seek(SeekFrom::Start(SHARD_HEADER_LEN + (row0 * stride) as u64))?;
        self.f.read_exact(&mut self.buf[..bytes]).with_context(|| {
            format!("shard {}: short read at row {row0}", self.path.display())
        })?;
        for r in 0..rows {
            let base = r * stride;
            let xrow = out.x.row_mut(out_row0 + r);
            for c in 0..d {
                let o = base + c * 8;
                xrow[c] = f64::from_le_bytes(self.buf[o..o + 8].try_into().unwrap());
            }
            let o = base + d * 8;
            out.y[out_row0 + r] =
                f64::from_le_bytes(self.buf[o..o + 8].try_into().unwrap());
        }
        Ok(())
    }
}

/// The `(offset, len)` file locations of every chunk payload in a v2
/// shard — the hook the seeded storage fault layer (`ps/fault.rs`)
/// uses to corrupt specific chunk indices deterministically.
pub fn chunk_locations(path: &Path) -> Result<Vec<(u64, u64)>> {
    let r = ShardReader::open(path)?;
    let v2 = r
        .v2
        .as_ref()
        .with_context(|| format!("{}: chunk locations need a v2 shard", path.display()))?;
    Ok(v2.dir.iter().map(|e| (e.offset, e.len)).collect())
}

/// A directory of shard files plus a JSON manifest: the on-disk form of
/// `Dataset::shard(r)`.  Created once, then each worker opens its own
/// [`ShardReader`]s — nothing is cloned into worker memory.
///
/// The v2 manifest additionally carries a **logical repartition map**:
/// chunks are numbered globally (file 0's chunks, then file 1's, …) and
/// `assign[w]` is the contiguous global chunk range logical worker `w`
/// trains on.  [`ShardSet::repartition`] rewrites only this map.
pub struct ShardSet {
    dir: PathBuf,
    n: usize,
    d: usize,
    chunk_rows: usize,
    fingerprint: u64,
    files: Vec<PathBuf>,
    /// Physical chunks per file (1 per file for SH1 stores).
    file_chunks: Vec<usize>,
    /// Global chunk range per logical worker.
    assign: Vec<Range<usize>>,
    /// Manifest/shard format generation (1 = SH1 flat, 2 = SH2).
    version: u32,
}

impl ShardSet {
    /// Partition `ds` into `r` v2 shard files under `dir` (created if
    /// missing) with the manifest last, so a crash mid-create never
    /// leaves an openable-but-incomplete store.  Refuses to write over
    /// an existing store: re-partitioning in place could leave a stale
    /// manifest pointing at a mix of old and new shard files, so delete
    /// the directory (or its manifest) first — or use
    /// [`ShardSet::repartition`], which never rewrites shard bytes.
    /// The partition is the same [`crate::data::shard_spans`] split as
    /// [`Dataset::shard`] and shares its `1 ≤ r ≤ ds.n()` panic
    /// contract.  `chunk_rows` is both the physical chunk size and the
    /// default streaming window.
    pub fn create(dir: &Path, ds: &Dataset, r: usize, chunk_rows: usize) -> Result<Self> {
        let n = ds.n();
        let d = ds.d();
        let chunk_rows = chunk_rows.max(1);
        ensure!(
            !Self::exists(dir),
            "store already exists at {} — delete it (or its {STORE_MANIFEST}) \
             before re-partitioning",
            dir.display()
        );
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create store dir {}", dir.display()))?;
        let mut files = Vec::with_capacity(r);
        let mut file_chunks = Vec::with_capacity(r);
        let mut write_all = || -> Result<()> {
            for (k, span) in crate::data::shard_spans(n, r).enumerate() {
                let path = dir.join(format!("shard_{k:03}.bin"));
                let rows = span.end - span.start;
                let mut w = ShardWriter::create_with(&path, d, chunk_rows)?;
                for row in span {
                    w.push_row(ds.x.row(row), ds.y[row])?;
                }
                w.finish()?;
                files.push(path);
                file_chunks.push(rows.div_ceil(chunk_rows));
            }
            Ok(())
        };
        if let Err(e) = write_all() {
            // Don't strand a partial partition (disk full mid-create…):
            // no manifest was written, so the dir must stay reusable.
            for f in &files {
                let _ = std::fs::remove_file(f);
            }
            return Err(e);
        }
        let assign = per_file_assign(&file_chunks);
        let set = Self {
            dir: dir.to_path_buf(),
            n,
            d,
            chunk_rows,
            fingerprint: dataset_fingerprint(ds),
            files,
            file_chunks,
            assign,
            version: 2,
        };
        set.write_manifest()?;
        Ok(set)
    }

    /// Open an existing store from its manifest (either generation),
    /// cross-checking every shard header against it (feature count,
    /// total row count, and — for v2 — per-file chunk counts and the
    /// repartition map's coverage), so a manifest desynchronized from
    /// its shard files is rejected here rather than silently training
    /// on the wrong partition.
    pub fn open(dir: &Path) -> Result<Self> {
        let mpath = dir.join(STORE_MANIFEST);
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("read store manifest {}", mpath.display()))?;
        let v = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", mpath.display()))?;
        let format = v.get("format").and_then(Json::as_str).unwrap_or("");
        let version = match format {
            "advgp-store-v1" => 1,
            "advgp-store-v2" => 2,
            _ => anyhow::bail!("{}: unknown store format {format:?}", mpath.display()),
        };
        let n = v.get("n").and_then(Json::as_usize).context("manifest: n")?;
        let d = v.get("d").and_then(Json::as_usize).context("manifest: d")?;
        let chunk_rows = v
            .get("chunk_rows")
            .and_then(Json::as_usize)
            .unwrap_or(DEFAULT_CHUNK_ROWS);
        let fingerprint = v
            .get("fingerprint")
            .and_then(Json::as_str)
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .with_context(|| format!("{}: missing/bad fingerprint", mpath.display()))?;
        let names = v.get("files").and_then(Json::as_arr).context("manifest: files")?;
        let mut files = Vec::with_capacity(names.len());
        let mut file_chunks = Vec::with_capacity(names.len());
        let mut rows = 0usize;
        for name in names {
            let name = name.as_str().context("manifest: file name")?;
            let path = dir.join(name);
            let reader = ShardReader::open(&path)
                .with_context(|| format!("store shard {}", path.display()))?;
            ensure!(
                reader.d() == d,
                "{}: shard has d={} but manifest says {d}",
                path.display(),
                reader.d()
            );
            rows += reader.n();
            file_chunks.push(reader.n_chunks());
            files.push(path);
        }
        ensure!(!files.is_empty(), "{}: empty store", mpath.display());
        ensure!(
            rows == n,
            "{}: shards hold {rows} rows but manifest says {n} — store and \
             manifest are out of sync (recreate the store)",
            mpath.display()
        );
        let assign = match v.get("assign").and_then(Json::as_arr) {
            // v1 manifests (and v2 ones from before a repartition was
            // ever run) default to the physical per-file split.
            None => per_file_assign(&file_chunks),
            Some(arr) => {
                let total: usize = file_chunks.iter().sum();
                let mut assign = Vec::with_capacity(arr.len());
                let mut cursor = 0usize;
                for pair in arr {
                    let pair = pair.as_arr().context("manifest: assign entry")?;
                    ensure!(pair.len() == 2, "{}: assign entry arity", mpath.display());
                    let lo = pair[0].as_usize().context("manifest: assign lo")?;
                    let hi = pair[1].as_usize().context("manifest: assign hi")?;
                    ensure!(
                        lo == cursor && lo < hi && hi <= total,
                        "{}: assign map does not tile chunks 0..{total}",
                        mpath.display()
                    );
                    cursor = hi;
                    assign.push(lo..hi);
                }
                ensure!(
                    cursor == total && !assign.is_empty(),
                    "{}: assign map does not tile chunks 0..{total}",
                    mpath.display()
                );
                assign
            }
        };
        if let Some(fc) = v.get("file_chunks").and_then(Json::as_arr) {
            let declared: Option<Vec<usize>> = fc.iter().map(Json::as_usize).collect();
            ensure!(
                declared.as_deref() == Some(&file_chunks[..]),
                "{}: manifest chunk counts disagree with shard headers — store \
                 and manifest are out of sync (recreate the store)",
                mpath.display()
            );
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            n,
            d,
            chunk_rows: chunk_rows.max(1),
            fingerprint,
            files,
            file_chunks,
            assign,
            version,
        })
    }

    /// Does `dir` already hold a store manifest?
    pub fn exists(dir: &Path) -> bool {
        dir.join(STORE_MANIFEST).is_file()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Total rows across all shards.
    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of shard *files* (the physical partition).
    pub fn r(&self) -> usize {
        self.files.len()
    }

    /// On-disk path of shard file `k` (for the fault layer and tools;
    /// panics on an out-of-range index like any slice access).
    pub fn file_path(&self, k: usize) -> &Path {
        &self.files[k]
    }

    /// Number of *logical* workers the repartition map currently
    /// targets (= `r()` until a repartition changes it).
    pub fn logical_workers(&self) -> usize {
        self.assign.len()
    }

    /// Manifest/shard format generation (1 = legacy flat, 2 = ADVGPSH2).
    pub fn version(&self) -> u32 {
        self.version
    }

    /// Total physical chunks across all files.
    pub fn total_chunks(&self) -> usize {
        self.file_chunks.iter().sum()
    }

    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    /// [`dataset_fingerprint`] of the source data this store was
    /// partitioned from — compare before reusing a store for a run
    /// whose data was (re)generated independently.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Open a validating reader on shard *file* `k`, preconfigured with
    /// the store's chunk size.
    pub fn reader(&self, k: usize) -> Result<ShardReader> {
        ensure!(k < self.files.len(), "shard index {k} out of {}", self.files.len());
        let mut r = ShardReader::open(&self.files[k])?;
        ensure!(
            r.d() == self.d,
            "{}: shard d={} but manifest says {}",
            self.files[k].display(),
            r.d(),
            self.d
        );
        r.set_chunk_rows(self.chunk_rows);
        Ok(r)
    }

    /// One reader per shard file, in file order (the physical view).
    pub fn readers(&self) -> Result<Vec<ShardReader>> {
        (0..self.r()).map(|k| self.reader(k)).collect()
    }

    /// The readers logical worker `w` trains on under the current
    /// repartition map: one per file its global chunk range touches,
    /// each restricted to the assigned chunks.  Equals
    /// `vec![self.reader(w)?]` until a repartition decouples workers
    /// from files.
    pub fn reader_group(&self, w: usize) -> Result<Vec<ShardReader>> {
        ensure!(
            w < self.assign.len(),
            "logical worker {w} out of {}",
            self.assign.len()
        );
        let want = self.assign[w].clone();
        let mut out = Vec::new();
        let mut base = 0usize; // global index of file k's first chunk
        for (k, &fc) in self.file_chunks.iter().enumerate() {
            let lo = want.start.max(base);
            let hi = want.end.min(base + fc);
            if lo < hi {
                let mut r = self.reader(k)?;
                if r.is_v2() {
                    r.restrict_chunks(lo - base, hi - base)?;
                } else {
                    // SH1 files are one pseudo-chunk; a map that cuts
                    // one can only come from a hand-edited manifest.
                    ensure!(
                        lo == base && hi == base + fc,
                        "{}: repartition map splits an SH1 file — migrate the \
                         store to ADVGPSH2 first",
                        self.files[k].display()
                    );
                }
                out.push(r);
            }
            base += fc;
        }
        ensure!(!out.is_empty(), "logical worker {w} has no chunks assigned");
        Ok(out)
    }

    /// Reader groups for every logical worker, in worker order.
    pub fn reader_groups(&self) -> Result<Vec<Vec<ShardReader>>> {
        (0..self.logical_workers()).map(|w| self.reader_group(w)).collect()
    }

    /// Retarget the store from its current worker count to `workers`
    /// by rewriting the manifest's chunk→worker map — shard bytes are
    /// untouched.  Requires an ADVGPSH2 store (migrate first) and
    /// `1 ≤ workers ≤ total_chunks()`.
    pub fn repartition(&mut self, workers: usize) -> Result<()> {
        ensure!(
            self.version >= 2,
            "store at {} is ADVGPSH1 — run `advgp store migrate` before \
             repartitioning",
            self.dir.display()
        );
        let total = self.total_chunks();
        ensure!(
            workers >= 1 && workers <= total,
            "cannot split {total} chunks across {workers} workers"
        );
        self.assign = crate::data::shard_spans(total, workers).collect();
        self.write_manifest()
    }

    fn write_manifest(&self) -> Result<()> {
        let names: Vec<Json> = self
            .files
            .iter()
            .map(|p| Json::Str(p.file_name().unwrap().to_string_lossy().into_owned()))
            .collect();
        let assign: Vec<Json> = self
            .assign
            .iter()
            .map(|r| {
                Json::Arr(vec![Json::Num(r.start as f64), Json::Num(r.end as f64)])
            })
            .collect();
        let file_chunks: Vec<Json> =
            self.file_chunks.iter().map(|&c| Json::Num(c as f64)).collect();
        let doc = Json::obj(vec![
            ("format", Json::Str("advgp-store-v2".into())),
            ("n", Json::Num(self.n as f64)),
            ("d", Json::Num(self.d as f64)),
            ("r", Json::Num(self.r() as f64)),
            ("workers", Json::Num(self.logical_workers() as f64)),
            ("chunk_rows", Json::Num(self.chunk_rows as f64)),
            ("fingerprint", Json::Str(format!("{:016x}", self.fingerprint))),
            ("files", Json::Arr(names)),
            ("file_chunks", Json::Arr(file_chunks)),
            ("assign", Json::Arr(assign)),
        ]);
        let path = self.dir.join(STORE_MANIFEST);
        crate::util::atomic_write(&path, format!("{doc}\n").as_bytes())
            .context("write store manifest")?;
        Ok(())
    }
}

/// The identity repartition map: worker k owns exactly file k's chunks.
fn per_file_assign(file_chunks: &[usize]) -> Vec<Range<usize>> {
    let mut assign = Vec::with_capacity(file_chunks.len());
    let mut base = 0usize;
    for &fc in file_chunks {
        assign.push(base..base + fc);
        base += fc;
    }
    assign
}

/// One file's scrub outcome in a [`VerifyReport`].
#[derive(Debug, Clone)]
pub struct FileVerify {
    pub file: String,
    /// "sh1" or "sh2".
    pub format: &'static str,
    pub rows: usize,
    pub chunks: usize,
    /// Chunk indices that failed verification, with details.
    pub corrupt: Vec<(usize, String)>,
    /// File-level failure (unopenable: bad header, corrupt directory…).
    pub error: Option<String>,
}

/// Full-store scrub report from [`verify_store`].
#[derive(Debug, Clone, Default)]
pub struct VerifyReport {
    pub files: Vec<FileVerify>,
}

impl VerifyReport {
    /// No file-level errors and no corrupt chunks anywhere.
    pub fn clean(&self) -> bool {
        self.files.iter().all(|f| f.error.is_none() && f.corrupt.is_empty())
    }

    /// Total corrupt chunks across all files (unopenable files count
    /// all their declared-unknown chunks as 1).
    pub fn total_corrupt(&self) -> usize {
        self.files
            .iter()
            .map(|f| f.corrupt.len() + usize::from(f.error.is_some()))
            .sum()
    }
}

impl std::fmt::Display for VerifyReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for file in &self.files {
            match &file.error {
                Some(e) => writeln!(f, "{}: UNREADABLE — {e}", file.file)?,
                None => {
                    let bad = file.corrupt.len();
                    writeln!(
                        f,
                        "{}: {} — {} rows, {}/{} chunks intact",
                        file.file,
                        if bad == 0 { "ok" } else { "CORRUPT" },
                        file.rows,
                        file.chunks - bad,
                        file.chunks
                    )?;
                    for (c, detail) in &file.corrupt {
                        writeln!(f, "  chunk {c}: {detail}")?;
                    }
                }
            }
        }
        write!(
            f,
            "verify: {} file(s), {} fault(s){}",
            self.files.len(),
            self.total_corrupt(),
            if self.clean() { " — store is clean" } else { "" }
        )
    }
}

/// Full scrub: read + verify every chunk of every shard named by the
/// manifest, never failing on corruption — faults land in the report
/// (the `advgp store verify` CLI).  Only a missing/unparseable manifest
/// is a hard error.
pub fn verify_store(dir: &Path) -> Result<VerifyReport> {
    let mpath = dir.join(STORE_MANIFEST);
    let text = std::fs::read_to_string(&mpath)
        .with_context(|| format!("read store manifest {}", mpath.display()))?;
    let v = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", mpath.display()))?;
    let names = v.get("files").and_then(Json::as_arr).context("manifest: files")?;
    let mut report = VerifyReport::default();
    for name in names {
        let name = name.as_str().context("manifest: file name")?.to_string();
        let path = dir.join(&name);
        match ShardReader::open(&path) {
            Err(e) => report.files.push(FileVerify {
                file: name,
                format: "?",
                rows: 0,
                chunks: 0,
                corrupt: Vec::new(),
                error: Some(format!("{e:#}")),
            }),
            Ok(mut r) => {
                let mut corrupt = Vec::new();
                for c in 0..r.n_chunks() {
                    if let Err(e) = r.verify_chunk(c) {
                        corrupt.push((c, format!("{e:#}")));
                    }
                }
                report.files.push(FileVerify {
                    file: name,
                    format: if r.is_v2() { "sh2" } else { "sh1" },
                    rows: r.n(),
                    chunks: r.n_chunks(),
                    corrupt,
                    error: None,
                });
            }
        }
    }
    Ok(report)
}

/// Upgrade every ADVGPSH1 shard of the store at `dir` to ADVGPSH2 in
/// place and rewrite the manifest as v2.  Row parity is verified
/// *before* each rewritten file replaces its original (bitwise, via
/// [`dataset_fingerprint`]), so a migration can never corrupt data it
/// was asked to protect.  Returns the number of files migrated (0 when
/// the store is already fully v2).
pub fn migrate_store(dir: &Path) -> Result<usize> {
    let set = ShardSet::open(dir)?;
    let mut migrated = 0usize;
    let mut file_chunks = Vec::with_capacity(set.files.len());
    for path in &set.files {
        let mut old = ShardReader::open(path)?;
        if old.is_v2() {
            file_chunks.push(old.n_chunks());
            continue;
        }
        let rows = old.read_all()?;
        let side = {
            let mut os = path.as_os_str().to_os_string();
            os.push(".migrate");
            PathBuf::from(os)
        };
        let mut w = ShardWriter::create_with(&side, set.d, set.chunk_rows)?;
        w.push_dataset(&rows)?;
        w.finish()?;
        // Bitwise row-parity gate before the original is replaced.
        let back = ShardReader::open(&side)?.read_all()?;
        let parity = back.n() == rows.n()
            && dataset_fingerprint(&back) == dataset_fingerprint(&rows);
        if !parity {
            let _ = std::fs::remove_file(&side);
            anyhow::bail!(
                "migrate: rewritten {} fails bitwise row parity — original left \
                 untouched",
                path.display()
            );
        }
        file_chunks.push(ShardReader::open(&side)?.n_chunks());
        std::fs::rename(&side, path).with_context(|| {
            format!("rename {} -> {}", side.display(), path.display())
        })?;
        if let Some(parent) = path.parent() {
            if let Ok(dirf) = File::open(parent) {
                let _ = dirf.sync_all();
            }
        }
        migrated += 1;
    }
    if migrated > 0 || set.version < 2 {
        let set = ShardSet {
            assign: per_file_assign(&file_chunks),
            file_chunks,
            version: 2,
            ..set
        };
        set.write_manifest()?;
    }
    Ok(migrated)
}

/// Rewrite the manifest's chunk→worker map for `workers` logical
/// workers (the `advgp store repartition` CLI).  Shard bytes are
/// untouched.
pub fn repartition_store(dir: &Path, workers: usize) -> Result<()> {
    let mut set = ShardSet::open(dir)?;
    set.repartition(workers)
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::linalg::Mat;

    fn tdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("advgp_store_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// Build a legacy SH1 store (flat shards + v1 manifest) the way
    /// PR 3 wrote them — the migration source fixture.
    fn create_v1_store(dir: &Path, ds: &Dataset, r: usize, chunk_rows: usize) {
        std::fs::create_dir_all(dir).unwrap();
        let mut names = Vec::new();
        for (k, span) in crate::data::shard_spans(ds.n(), r).enumerate() {
            let path = dir.join(format!("shard_{k:03}.bin"));
            let part = Dataset {
                x: Mat::from_vec(
                    span.end - span.start,
                    ds.d(),
                    span.clone().flat_map(|row| ds.x.row(row).to_vec()).collect(),
                ),
                y: span.clone().map(|row| ds.y[row]).collect(),
            };
            write_shard_v1(&path, &part).unwrap();
            names.push(Json::Str(format!("shard_{k:03}.bin")));
        }
        let doc = Json::obj(vec![
            ("format", Json::Str("advgp-store-v1".into())),
            ("n", Json::Num(ds.n() as f64)),
            ("d", Json::Num(ds.d() as f64)),
            ("r", Json::Num(r as f64)),
            ("chunk_rows", Json::Num(chunk_rows as f64)),
            ("fingerprint", Json::Str(format!("{:016x}", dataset_fingerprint(ds)))),
            ("files", Json::Arr(names)),
        ]);
        crate::util::atomic_write(
            &dir.join(STORE_MANIFEST),
            format!("{doc}\n").as_bytes(),
        )
        .unwrap();
    }

    fn assert_bitwise(a: &Dataset, b: &Dataset) {
        assert_eq!((a.n(), a.d()), (b.n(), b.d()));
        for i in 0..a.n() {
            assert_eq!(a.y[i].to_bits(), b.y[i].to_bits(), "row {i} target");
            for c in 0..a.d() {
                assert_eq!(a.x[(i, c)].to_bits(), b.x[(i, c)].to_bits(), "row {i} col {c}");
            }
        }
    }

    #[test]
    fn compression_roundtrips_exactly() {
        let mut rng = crate::util::rng::Pcg64::seeded(11);
        for case in 0..4 {
            let words: Vec<u64> = match case {
                0 => vec![0u64; 257],
                1 => (0..300).map(|i| 1000 + i as u64).collect(),
                2 => (0..128).map(|_| rng.next_u64()).collect(),
                _ => (0..99)
                    .map(|i| if i % 7 == 0 { rng.next_u64() } else { 42 })
                    .collect(),
            };
            let raw: Vec<u8> = words.iter().flat_map(|w| w.to_le_bytes()).collect();
            let enc = sh2_compress(&raw);
            let mut back = Vec::new();
            sh2_decompress(&enc, raw.len(), &mut back).unwrap();
            assert_eq!(back, raw, "case {case}");
        }
        // Repetitive data must actually shrink (enc=1 is reachable).
        let raw: Vec<u8> = std::iter::repeat(7.5f64.to_le_bytes())
            .take(512)
            .flatten()
            .collect();
        assert!(sh2_compress(&raw).len() < raw.len());
    }

    #[test]
    fn roundtrip_bitwise_both_formats() {
        let dir = tdir("roundtrip");
        let ds = synth::friedman(37, 4, 0.3, 9);
        for (name, v1) in [("a2.shard", false), ("a1.shard", true)] {
            let path = dir.join(name);
            if v1 {
                write_shard_v1(&path, &ds).unwrap();
            } else {
                write_shard(&path, &ds).unwrap();
            }
            let mut r = ShardReader::open(&path).unwrap();
            assert_eq!((r.n(), r.d(), r.is_v2()), (37, 4, !v1));
            assert_bitwise(&r.read_all().unwrap(), &ds);
        }
        // Multi-chunk v2 (chunks of 5 over 37 rows → 8, last short).
        let path = dir.join("chunked.shard");
        let mut w = ShardWriter::create_with(&path, 4, 5).unwrap();
        w.push_dataset(&ds).unwrap();
        w.finish().unwrap();
        let mut r = ShardReader::open(&path).unwrap();
        assert_eq!((r.n_chunks(), r.phys_chunk_rows()), (8, Some(5)));
        assert_bitwise(&r.read_all().unwrap(), &ds);
    }

    #[test]
    fn window_matches_in_memory_cyclic_window() {
        let dir = tdir("window");
        let ds = synth::friedman(23, 3, 0.2, 4);
        let path = dir.join("w.shard");
        let mut w = ShardWriter::create_with(&path, 3, 4).unwrap(); // 6 chunks
        w.push_dataset(&ds).unwrap();
        w.finish().unwrap();
        let mut r = ShardReader::open(&path).unwrap();
        let mut disk = Dataset { x: Mat::empty(), y: Vec::new() };
        let mut mem = Dataset { x: Mat::empty(), y: Vec::new() };
        for (start, k) in [(0usize, 7usize), (20, 7), (22, 23), (5, 40), (11, 1)] {
            r.read_window(start, k, &mut disk).unwrap();
            ds.copy_cyclic_window(start, k, &mut mem);
            assert_bitwise(&disk, &mem);
        }
    }

    #[test]
    fn open_rejects_corruption_v1() {
        let dir = tdir("corrupt_v1");
        let ds = synth::friedman(10, 2, 0.1, 1);
        let good = dir.join("good.shard");
        write_shard_v1(&good, &ds).unwrap();
        let pristine = std::fs::read(&good).unwrap();
        // Bad magic.
        let mut bytes = pristine.clone();
        bytes[0] ^= 0xFF;
        std::fs::write(dir.join("bad_magic.shard"), &bytes).unwrap();
        assert!(ShardReader::open(&dir.join("bad_magic.shard")).is_err());
        // Truncated data region.
        std::fs::write(dir.join("trunc.shard"), &pristine[..pristine.len() - 8]).unwrap();
        assert!(ShardReader::open(&dir.join("trunc.shard")).is_err());
        // Truncated header.
        std::fs::write(dir.join("short.shard"), &pristine[..12]).unwrap();
        assert!(ShardReader::open(&dir.join("short.shard")).is_err());
        // Trailing garbage.
        let mut bytes = pristine.clone();
        bytes.extend_from_slice(&[0u8; 8]);
        std::fs::write(dir.join("long.shard"), &bytes).unwrap();
        assert!(ShardReader::open(&dir.join("long.shard")).is_err());
        // The pristine file still opens.
        assert!(ShardReader::open(&good).is_ok());
    }

    #[test]
    fn v2_detects_chunk_corruption_at_read_time() {
        let dir = tdir("corrupt_v2");
        let ds = synth::friedman(23, 3, 0.2, 4);
        let path = dir.join("c.shard");
        let mut w = ShardWriter::create_with(&path, 3, 4).unwrap(); // 6 chunks
        w.push_dataset(&ds).unwrap();
        w.finish().unwrap();
        let locs = chunk_locations(&path).unwrap();
        assert_eq!(locs.len(), 6);
        // Flip one payload byte in chunk 2: open still succeeds (the
        // directory is intact) but any strict read of that chunk fails
        // typed, and the fault names the chunk.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[locs[2].0 as usize + 1] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let mut r = ShardReader::open(&path).unwrap();
        let err = r.read_all().unwrap_err();
        match err.downcast_ref::<StoreFault>() {
            Some(StoreFault::ChunkCorrupt { chunk, .. }) => assert_eq!(*chunk, 2),
            other => panic!("expected ChunkCorrupt, got {other:?} ({err:#})"),
        }
        // Chunks outside the blast radius still verify.
        assert!(r.verify_chunk(1).is_ok());
        assert!(r.verify_chunk(2).is_err());
        // Directory corruption is caught at open.
        let mut bytes = std::fs::read(&path).unwrap();
        let dlen = bytes.len();
        bytes[dlen - 12] ^= 0xFF; // inside the directory block
        std::fs::write(dir.join("dir.shard"), &bytes).unwrap();
        let err = ShardReader::open(&dir.join("dir.shard")).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err:#}");
    }

    #[test]
    fn degraded_mode_quarantines_and_respects_budget() {
        let dir = tdir("degraded");
        let ds = synth::friedman(24, 3, 0.2, 4);
        let path = dir.join("d.shard");
        let mut w = ShardWriter::create_with(&path, 3, 4).unwrap(); // 6 chunks
        w.push_dataset(&ds).unwrap();
        w.finish().unwrap();
        let locs = chunk_locations(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[locs[1].0 as usize] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        // Degraded streaming: chunk 1's rows vanish, everything else
        // arrives, exactly once per cycle, and the quarantine trace and
        // counter record the single event.
        let policy = QuarantinePolicy::new_default();
        let mut r = ShardReader::open(&path).unwrap();
        r.set_fault_policy(policy.clone());
        r.set_chunk_rows(4);
        let mut win = Dataset { x: Mat::empty(), y: Vec::new() };
        let mut got_y = Vec::new();
        let mut rows = 0;
        while rows < 20 {
            let k = r.next_window(&mut win).unwrap();
            assert!(k > 0);
            got_y.extend_from_slice(&win.y[..k]);
            rows += k;
        }
        assert_eq!(rows, 20, "one full cycle minus the quarantined chunk");
        let want_y: Vec<f64> =
            (0..24usize).filter(|i| !(4..8).contains(i)).map(|i| ds.y[i]).collect();
        assert_eq!(got_y, want_y);
        assert_eq!(r.quarantine_trace(), vec![1]);
        assert_eq!(policy.counter.load(Ordering::Relaxed), 1);
        // Budget of 1: two adjacent corrupt chunks with no verified
        // read between them runs it dry, typed.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[locs[2].0 as usize] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let tight = QuarantinePolicy {
            budget: Arc::new(CorruptionBudget::new(1)),
            counter: Arc::new(AtomicU64::new(0)),
        };
        let mut r = ShardReader::open(&path).unwrap();
        r.set_fault_policy(tight.clone());
        r.set_chunk_rows(24);
        let err = r.next_window(&mut win).unwrap_err();
        match err.downcast_ref::<StoreFault>() {
            Some(StoreFault::BudgetDry { chunk, max, .. }) => {
                assert_eq!((*chunk, *max), (2, 1));
            }
            other => panic!("expected BudgetDry, got {other:?} ({err:#})"),
        }
        // All chunks corrupt → ShardDead (budget permitting).
        let mut bytes = std::fs::read(&path).unwrap();
        for (off, _) in &locs {
            bytes[*off as usize] ^= 0xFF;
        }
        std::fs::write(&path, &bytes).unwrap();
        let mut r = ShardReader::open(&path).unwrap();
        r.set_fault_policy(QuarantinePolicy::new_default());
        let err = r.next_window(&mut win).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<StoreFault>(), Some(StoreFault::ShardDead { .. })),
            "{err:#}"
        );
    }

    #[test]
    fn shard_set_matches_dataset_shard() {
        let dir = tdir("set");
        let ds = synth::friedman(25, 4, 0.2, 7);
        let set = ShardSet::create(&dir, &ds, 3, 8).unwrap();
        assert_eq!((set.n(), set.d(), set.r()), (25, 4, 3));
        assert_eq!(set.logical_workers(), 3);
        let mem = ds.shard(3);
        let reopened = ShardSet::open(&dir).unwrap();
        assert_eq!(reopened.chunk_rows(), 8);
        assert_eq!(reopened.version(), 2);
        // The fingerprint survives the manifest roundtrip and ties the
        // store to this exact data: a same-shape other dataset differs.
        assert_eq!(reopened.fingerprint(), dataset_fingerprint(&ds));
        let other = synth::friedman(25, 4, 0.2, 8);
        assert_ne!(reopened.fingerprint(), dataset_fingerprint(&other));
        for k in 0..3 {
            let got = reopened.reader(k).unwrap().read_all().unwrap();
            assert_bitwise(&got, &mem[k]);
        }
    }

    #[test]
    fn repartition_remaps_chunks_without_moving_bytes() {
        let dir = tdir("repartition");
        let ds = synth::friedman(25, 3, 0.2, 7);
        // r=2 files (13 + 12 rows), chunks of 4 → 4 + 3 = 7 chunks.
        ShardSet::create(&dir, &ds, 2, 4).unwrap();
        let before: Vec<Vec<u8>> = (0..2)
            .map(|k| std::fs::read(dir.join(format!("shard_{k:03}.bin"))).unwrap())
            .collect();
        repartition_store(&dir, 3).unwrap();
        let set = ShardSet::open(&dir).unwrap();
        assert_eq!((set.r(), set.logical_workers(), set.total_chunks()), (2, 3, 7));
        // Shard bytes are untouched — only the manifest moved.
        for (k, bytes) in before.iter().enumerate() {
            let after = std::fs::read(dir.join(format!("shard_{k:03}.bin"))).unwrap();
            assert_eq!(&after, bytes, "file {k} rewritten");
        }
        // The three reader groups tile the dataset exactly, in order,
        // and the middle group spans the file boundary.
        let groups = set.reader_groups().unwrap();
        assert_eq!(groups.iter().map(Vec::len).collect::<Vec<_>>(), vec![1, 2, 1]);
        let mut all = Dataset { x: Mat::empty(), y: Vec::new() };
        let mut win = Dataset { x: Mat::empty(), y: Vec::new() };
        for mut group in groups {
            for r in &mut group {
                let ln = r.n();
                r.set_chunk_rows(ln);
                let k = r.next_window(&mut win).unwrap();
                assert_eq!(k, ln);
                for i in 0..k {
                    all.x.data.extend_from_slice(win.x.row(i));
                    all.y.push(win.y[i]);
                }
            }
        }
        let all = Dataset { x: Mat::from_vec(ds.n(), ds.d(), all.x.data), y: all.y };
        assert_bitwise(&all, &ds);
        // Degenerate targets are refused; W' = total chunks is the max.
        assert!(repartition_store(&dir, 8).is_err());
        repartition_store(&dir, 7).unwrap();
        assert_eq!(ShardSet::open(&dir).unwrap().logical_workers(), 7);
    }

    #[test]
    fn migrate_upgrades_sh1_in_place_with_row_parity() {
        let dir = tdir("migrate");
        let ds = synth::friedman(25, 4, 0.2, 7);
        create_v1_store(&dir, &ds, 3, 8);
        let v1 = ShardSet::open(&dir).unwrap();
        assert_eq!(v1.version(), 1);
        // SH1 stores cannot repartition (one pseudo-chunk per file).
        assert!(ShardSet::open(&dir).unwrap().repartition(2).is_err());
        assert_eq!(migrate_store(&dir).unwrap(), 3);
        let v2 = ShardSet::open(&dir).unwrap();
        assert_eq!(v2.version(), 2);
        assert_eq!(v2.fingerprint(), dataset_fingerprint(&ds));
        // Bitwise row parity, shard by shard, against the in-memory
        // partition SH1 was written from.
        let mem = ds.shard(3);
        for k in 0..3 {
            let got = v2.reader(k).unwrap().read_all().unwrap();
            assert_bitwise(&got, &mem[k]);
            assert!(v2.reader(k).unwrap().is_v2());
        }
        // Idempotent.
        assert_eq!(migrate_store(&dir).unwrap(), 0);
        // And now repartition works.
        repartition_store(&dir, 2).unwrap();
        assert_eq!(ShardSet::open(&dir).unwrap().logical_workers(), 2);
    }

    #[test]
    fn verify_store_reports_per_chunk() {
        let dir = tdir("verify");
        let ds = synth::friedman(24, 3, 0.2, 4);
        ShardSet::create(&dir, &ds, 2, 4).unwrap();
        let report = verify_store(&dir).unwrap();
        assert!(report.clean(), "{report}");
        assert_eq!(report.files.len(), 2);
        // Corrupt one chunk of file 1 → exactly one fault, named.
        let path = dir.join("shard_001.bin");
        let locs = chunk_locations(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[locs[1].0 as usize] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let report = verify_store(&dir).unwrap();
        assert!(!report.clean());
        assert_eq!(report.total_corrupt(), 1);
        assert_eq!(report.files[1].corrupt.len(), 1);
        assert_eq!(report.files[1].corrupt[0].0, 1);
        assert!(report.files[0].corrupt.is_empty());
    }

    #[test]
    fn create_refuses_existing_store_and_open_rejects_desync() {
        let dir = tdir("recreate");
        let ds = synth::friedman(20, 3, 0.1, 5);
        ShardSet::create(&dir, &ds, 2, 4).unwrap();
        // Re-partitioning in place is refused (stale-manifest hazard).
        assert!(ShardSet::create(&dir, &ds, 4, 4).is_err());
        // Simulate the hazard anyway: a shard file from a different
        // partition under a surviving manifest → open() must reject.
        write_shard(&dir.join("shard_000.bin"), &ds.head(3)).unwrap();
        let err = ShardSet::open(&dir).unwrap_err();
        assert!(err.to_string().contains("out of sync"), "{err:#}");
    }

    #[test]
    fn steady_state_reads_do_not_allocate() {
        let dir = tdir("zeroalloc");
        let ds = synth::friedman(64, 5, 0.2, 3);
        let path = dir.join("z.shard");
        let mut w = ShardWriter::create_with(&path, 5, 16).unwrap(); // 4 chunks
        w.push_dataset(&ds).unwrap();
        w.finish().unwrap();
        let mut r = ShardReader::open(&path).unwrap();
        r.set_chunk_rows(10);
        let mut win = Dataset { x: Mat::empty(), y: Vec::new() };
        // Warm-up: one full cycle (includes a wrapped read).
        for _ in 0..7 {
            r.next_window(&mut win).unwrap();
        }
        let (cb, cx, cy) = (r.buf_capacity(), win.x.data.capacity(), win.y.capacity());
        for _ in 0..50 {
            r.next_window(&mut win).unwrap();
        }
        assert_eq!(r.buf_capacity(), cb, "reader byte buffer reallocated");
        assert_eq!(win.x.data.capacity(), cx, "window x reallocated");
        assert_eq!(win.y.capacity(), cy, "window y reallocated");
    }

    #[test]
    fn writer_rejects_bad_rows_and_cleans_up_temp_files() {
        let dir = tdir("writer");
        let mut w = ShardWriter::create(&dir.join("w.shard"), 3).unwrap();
        assert!(w.push_row(&[1.0, 2.0], 0.0).is_err(), "wrong arity accepted");
        drop(w);
        let w2 = ShardWriter::create(&dir.join("e.shard"), 2).unwrap();
        assert!(w2.finish().is_err(), "empty shard sealed");
        // Neither the final paths nor any temp files survive an abort.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name())
            .collect();
        assert!(leftovers.is_empty(), "aborted writers left {leftovers:?}");
    }

    #[test]
    fn fast_forward_matches_strict_streaming() {
        let dir = tdir("ff");
        let ds = synth::friedman(23, 3, 0.2, 4);
        let path = dir.join("f.shard");
        let mut w = ShardWriter::create_with(&path, 3, 4).unwrap();
        w.push_dataset(&ds).unwrap();
        w.finish().unwrap();
        let mut a = ShardReader::open(&path).unwrap();
        let mut b = ShardReader::open(&path).unwrap();
        for r in [&mut a, &mut b] {
            r.set_chunk_rows(5);
            r.seek_to(7);
        }
        let mut win = Dataset { x: Mat::empty(), y: Vec::new() };
        for _ in 0..11 {
            a.next_window(&mut win).unwrap();
        }
        b.fast_forward(11);
        assert_eq!(a.cursor(), b.cursor());
        // And the next windows agree bitwise.
        let mut wa = Dataset { x: Mat::empty(), y: Vec::new() };
        a.next_window(&mut wa).unwrap();
        b.next_window(&mut win).unwrap();
        assert_bitwise(&wa, &win);
    }
}
