//! Out-of-core shard store (ISSUE 3): the disk layer that lets a worker
//! train on a shard far larger than its RAM — the paper's §1 regime
//! ("billions of samples") needs data locality to be a property of the
//! *store*, not of process memory (cf. Gal et al., 2014, on distributed
//! data placement in sparse-GP inference).
//!
//! # Shard file format `ADVGPSH1`
//!
//! All values little-endian:
//!
//! ```text
//! [ 0.. 8)  magic   b"ADVGPSH1"
//! [ 8..16)  n       u64 row count        (≥ 1)
//! [16..24)  d       u64 feature count    (≥ 1)
//! [24.. )   rows    n × (d features + 1 target) f64, row-major
//! ```
//!
//! A row is contiguous (`x[0..d]` then `y`), so any window of rows is a
//! single ranged read.  The file is sealed by write-to-temp + atomic
//! rename: a crash mid-write can never leave a half-valid shard at the
//! final path, and [`ShardReader::open`] rejects bad magic, short
//! headers, and length mismatches (truncation or trailing garbage).
//!
//! # Key invariants
//!
//! * **Zero steady-state allocation**: [`ShardReader`] streams windows
//!   through one internal byte buffer and one caller-owned [`Dataset`]
//!   buffer; both are grown once and recycled forever after (pinned by
//!   `tests/store_checkpoint.rs`).  Peak resident data per worker is
//!   one chunk, not the shard.
//! * **Traversal parity**: the cyclic window at `(start, k)` decodes
//!   bitwise-identically to [`Dataset::copy_cyclic_window`] on the
//!   in-memory shard, so an out-of-core worker visits exactly the rows
//!   its resident twin would, in the same order.
//! * **Partition parity**: [`ShardSet::create`] writes the same
//!   contiguous near-equal partition as [`Dataset::shard`] (and
//!   enforces the same `1 ≤ r ≤ n` contract).

use super::Dataset;
use crate::util::json::Json;
use anyhow::{ensure, Context, Result};
use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// Magic bytes opening every shard file.
pub const SHARD_MAGIC: [u8; 8] = *b"ADVGPSH1";
/// Shard header length in bytes (magic + n + d).
pub const SHARD_HEADER_LEN: u64 = 24;
/// Default minibatch chunk (rows per streamed window).
pub const DEFAULT_CHUNK_ROWS: usize = 4096;
/// Name of the [`ShardSet`] manifest inside its directory.
pub const STORE_MANIFEST: &str = "store.json";

/// Streaming writer for one shard file.
///
/// Rows are appended to `<path>.tmp`; [`ShardWriter::finish`] patches
/// the row count into the header, fsyncs, and atomically renames the
/// file into place.  An abandoned writer (dropped unfinished, or a
/// failed `finish`) removes its temp file, so aborted writes leave
/// nothing behind.
pub struct ShardWriter {
    /// `None` once `finish` has consumed the stream.
    w: Option<BufWriter<File>>,
    path: PathBuf,
    tmp: PathBuf,
    d: usize,
    n: u64,
}

impl ShardWriter {
    /// Start a shard at `path` for `d`-feature rows.
    pub fn create(path: &Path, d: usize) -> Result<Self> {
        ensure!(d >= 1, "shard store needs d >= 1 features (got {d})");
        let tmp = tmp_path(path);
        let f = File::create(&tmp)
            .with_context(|| format!("create shard temp {}", tmp.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(&SHARD_MAGIC)?;
        w.write_all(&0u64.to_le_bytes())?; // n, patched by finish()
        w.write_all(&(d as u64).to_le_bytes())?;
        Ok(Self { w: Some(w), path: path.to_path_buf(), tmp, d, n: 0 })
    }

    /// Append one row (`x` must have exactly `d` features).
    pub fn push_row(&mut self, x: &[f64], y: f64) -> Result<()> {
        ensure!(
            x.len() == self.d,
            "row has {} features, shard expects {}",
            x.len(),
            self.d
        );
        let w = self.w.as_mut().expect("writer already finished");
        for v in x {
            w.write_all(&v.to_le_bytes())?;
        }
        w.write_all(&y.to_le_bytes())?;
        self.n += 1;
        Ok(())
    }

    /// Append every row of `ds`.
    pub fn push_dataset(&mut self, ds: &Dataset) -> Result<()> {
        for r in 0..ds.n() {
            self.push_row(ds.x.row(r), ds.y[r])?;
        }
        Ok(())
    }

    /// Seal the shard: patch the header row count, fsync, and rename
    /// the temp file to its final path.  Returns the row count; on
    /// error the temp file is removed.
    pub fn finish(mut self) -> Result<u64> {
        let res = self.finish_inner();
        if res.is_err() {
            let _ = std::fs::remove_file(&self.tmp);
        }
        res
    }

    fn finish_inner(&mut self) -> Result<u64> {
        ensure!(self.n >= 1, "refusing to seal an empty shard (0 rows)");
        let mut w = self.w.take().expect("writer already finished");
        w.flush()?;
        w.seek(SeekFrom::Start(8))?;
        w.write_all(&self.n.to_le_bytes())?;
        w.flush()?;
        let f = w.into_inner().context("flush shard writer")?;
        f.sync_all().context("fsync shard")?;
        drop(f);
        std::fs::rename(&self.tmp, &self.path).with_context(|| {
            format!("rename {} -> {}", self.tmp.display(), self.path.display())
        })?;
        // Durability contract (ISSUE 6): fsync(file) + rename + fsync
        // (parent dir).  The file sync makes the *contents* durable, the
        // rename makes the sealed name appear atomically, and the
        // directory sync makes the rename itself survive a crash — on
        // ext4/xfs an unsynced directory entry can vanish on power loss,
        // leaving a complete shard nobody can find.  Directory fsync is
        // unsupported on some filesystems (and on Windows), so failure
        // here is best-effort by design: the rename already succeeded
        // and readers of a live process see the sealed file either way.
        if let Some(parent) = self.path.parent() {
            if let Ok(dir) = File::open(parent) {
                let _ = dir.sync_all();
            }
        }
        Ok(self.n)
    }
}

impl Drop for ShardWriter {
    fn drop(&mut self) {
        // Unfinished writer: close the stream, then discard the temp
        // file so aborted writes don't accumulate.  (`finish` takes the
        // stream out first, so a sealed shard is never touched.)
        if self.w.take().is_some() {
            let _ = std::fs::remove_file(&self.tmp);
        }
    }
}

/// Write `ds` as a single shard file at `path` (atomic; see
/// [`ShardWriter`]).
pub fn write_shard(path: &Path, ds: &Dataset) -> Result<()> {
    let mut w = ShardWriter::create(path, ds.d())?;
    w.push_dataset(ds)?;
    w.finish()?;
    Ok(())
}

/// Order-sensitive FNV-1a fingerprint over a dataset's exact f64 bit
/// patterns (features row-major, then targets).  Stored in the
/// [`ShardSet`] manifest so a reused store can be tied to its *source
/// data*, not just its shape — two datasets with equal `(n, d)` but
/// different contents (another seed, a regenerated CSV) fingerprint
/// differently.
pub fn dataset_fingerprint(ds: &Dataset) -> u64 {
    let mut h = crate::util::FNV1A64_INIT;
    for v in ds.x.data.iter().chain(&ds.y) {
        h = crate::util::fnv1a64(h, &v.to_le_bytes());
    }
    h
}

/// Streams fixed-size minibatch windows out of one shard file.
///
/// The reader holds a cursor for [`ShardReader::next_window`] and a
/// reusable byte buffer; windows wrap cyclically so offsets
/// `start, start + k, start + 2k, …` (mod n) tile the whole shard
/// within ⌈n/k⌉ reads from any starting offset — the same coverage
/// guarantee as [`Dataset::copy_cyclic_window`].
///
/// ```
/// use advgp::data::store::{write_shard, ShardReader};
/// use advgp::data::Dataset;
/// use advgp::linalg::Mat;
///
/// let dir = std::env::temp_dir().join("advgp_doc_shard_reader");
/// std::fs::create_dir_all(&dir).unwrap();
/// let path = dir.join("toy.shard");
/// let ds = Dataset {
///     x: Mat::from_vec(5, 2, (0..10).map(|i| i as f64).collect()),
///     y: (0..5).map(|i| 10.0 * i as f64).collect(),
/// };
/// write_shard(&path, &ds).unwrap();
///
/// let mut reader = ShardReader::open(&path).unwrap();
/// reader.set_chunk_rows(2);
/// let mut window = Dataset { x: Mat::empty(), y: Vec::new() };
/// reader.next_window(&mut window).unwrap(); // rows 0, 1
/// assert_eq!(window.y, vec![0.0, 10.0]);
/// reader.next_window(&mut window).unwrap(); // rows 2, 3
/// reader.next_window(&mut window).unwrap(); // rows 4, 0 (wraps)
/// assert_eq!(window.y, vec![40.0, 0.0]);
/// assert_eq!((reader.n(), reader.d()), (5, 2));
/// ```
pub struct ShardReader {
    f: File,
    path: PathBuf,
    n: usize,
    d: usize,
    chunk_rows: usize,
    offset: usize,
    /// Reusable raw block buffer (grown once, recycled per window).
    buf: Vec<u8>,
}

impl ShardReader {
    /// Open and validate a shard file.
    pub fn open(path: &Path) -> Result<Self> {
        let mut f = File::open(path)
            .with_context(|| format!("open shard {}", path.display()))?;
        let mut header = [0u8; SHARD_HEADER_LEN as usize];
        f.read_exact(&mut header).with_context(|| {
            format!("shard {} shorter than its header", path.display())
        })?;
        ensure!(
            header[..8] == SHARD_MAGIC,
            "shard {}: bad magic {:?} (want {:?})",
            path.display(),
            &header[..8],
            SHARD_MAGIC
        );
        let n = u64::from_le_bytes(header[8..16].try_into().unwrap());
        let d = u64::from_le_bytes(header[16..24].try_into().unwrap());
        ensure!(n >= 1 && d >= 1, "shard {}: degenerate n={n} d={d}", path.display());
        let want = SHARD_HEADER_LEN as u128 + n as u128 * (d + 1) as u128 * 8;
        let have = f.metadata()?.len() as u128;
        ensure!(
            have == want,
            "shard {}: {have} bytes on disk, header declares {want} \
             (truncated or corrupt)",
            path.display()
        );
        Ok(Self {
            f,
            path: path.to_path_buf(),
            n: n as usize,
            d: d as usize,
            chunk_rows: DEFAULT_CHUNK_ROWS,
            offset: 0,
            buf: Vec::new(),
        })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Rows per [`ShardReader::next_window`] call (clamped to n).
    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows.min(self.n)
    }

    pub fn set_chunk_rows(&mut self, rows: usize) {
        self.chunk_rows = rows.max(1);
    }

    /// Move the streaming cursor (wraps mod n).
    pub fn seek_to(&mut self, offset: usize) {
        self.offset = offset % self.n;
    }

    /// Current streaming cursor.
    pub fn cursor(&self) -> usize {
        self.offset
    }

    /// Capacity of the internal byte buffer — exposed so tests can pin
    /// the zero-steady-state-allocation guarantee.
    pub fn buf_capacity(&self) -> usize {
        self.buf.capacity()
    }

    /// Read `k` consecutive rows starting at `start` (wrapping around
    /// the end) into `out` — the on-disk twin of
    /// [`Dataset::copy_cyclic_window`], bitwise-identical to it on the
    /// same data.  Allocation-free once `out` and the internal buffer
    /// are warm.
    pub fn read_window(&mut self, start: usize, k: usize, out: &mut Dataset) -> Result<()> {
        let n = self.n;
        let d = self.d;
        let k = k.min(n);
        out.x.resize(k, d);
        out.y.resize(k, 0.0);
        if k == 0 {
            return Ok(());
        }
        let start = start % n;
        let first = k.min(n - start);
        self.read_rows(start, first, 0, out)?;
        if first < k {
            self.read_rows(0, k - first, first, out)?; // wrapped prefix
        }
        Ok(())
    }

    /// Stream the next `chunk_rows()` window at the cursor and advance
    /// it, wrapping cyclically.  Returns the rows read.
    pub fn next_window(&mut self, out: &mut Dataset) -> Result<usize> {
        let k = self.chunk_rows();
        self.read_window(self.offset, k, out)?;
        self.offset = (self.offset + k) % self.n;
        Ok(k)
    }

    /// Materialize the whole shard (tests / small-data convenience —
    /// defeats the point of the store for real runs).
    pub fn read_all(&mut self) -> Result<Dataset> {
        let mut out = Dataset { x: crate::linalg::Mat::empty(), y: Vec::new() };
        let n = self.n;
        self.read_window(0, n, &mut out)?;
        Ok(out)
    }

    /// Ranged read of `rows` rows at file row `row0` into `out` rows
    /// `out_row0..`, de-interleaving features and target.
    fn read_rows(
        &mut self,
        row0: usize,
        rows: usize,
        out_row0: usize,
        out: &mut Dataset,
    ) -> Result<()> {
        let d = self.d;
        let stride = (d + 1) * 8;
        let bytes = rows * stride;
        self.buf.resize(bytes, 0);
        self.f
            .seek(SeekFrom::Start(SHARD_HEADER_LEN + (row0 * stride) as u64))?;
        self.f.read_exact(&mut self.buf[..bytes]).with_context(|| {
            format!("shard {}: short read at row {row0}", self.path.display())
        })?;
        for r in 0..rows {
            let base = r * stride;
            let xrow = out.x.row_mut(out_row0 + r);
            for c in 0..d {
                let o = base + c * 8;
                xrow[c] = f64::from_le_bytes(self.buf[o..o + 8].try_into().unwrap());
            }
            let o = base + d * 8;
            out.y[out_row0 + r] =
                f64::from_le_bytes(self.buf[o..o + 8].try_into().unwrap());
        }
        Ok(())
    }
}

/// A directory of shard files plus a JSON manifest: the on-disk form of
/// `Dataset::shard(r)`.  Created once, then each worker opens its own
/// [`ShardReader`] — nothing is cloned into worker memory.
pub struct ShardSet {
    dir: PathBuf,
    n: usize,
    d: usize,
    chunk_rows: usize,
    fingerprint: u64,
    files: Vec<PathBuf>,
}

impl ShardSet {
    /// Partition `ds` into `r` shard files under `dir` (created if
    /// missing) with the manifest last, so a crash mid-create never
    /// leaves an openable-but-incomplete store.  Refuses to write over
    /// an existing store: re-partitioning in place could leave a stale
    /// manifest pointing at a mix of old and new shard files, so delete
    /// the directory (or its manifest) first.  The partition is the
    /// same [`crate::data::shard_spans`] split as [`Dataset::shard`]
    /// and shares its `1 ≤ r ≤ ds.n()` panic contract.
    pub fn create(dir: &Path, ds: &Dataset, r: usize, chunk_rows: usize) -> Result<Self> {
        let n = ds.n();
        let d = ds.d();
        ensure!(
            !Self::exists(dir),
            "store already exists at {} — delete it (or its {STORE_MANIFEST}) \
             before re-partitioning",
            dir.display()
        );
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create store dir {}", dir.display()))?;
        let mut files = Vec::with_capacity(r);
        let mut write_all = || -> Result<()> {
            for (k, span) in crate::data::shard_spans(n, r).enumerate() {
                let path = dir.join(format!("shard_{k:03}.bin"));
                let mut w = ShardWriter::create(&path, d)?;
                for row in span {
                    w.push_row(ds.x.row(row), ds.y[row])?;
                }
                w.finish()?;
                files.push(path);
            }
            Ok(())
        };
        if let Err(e) = write_all() {
            // Don't strand a partial partition (disk full mid-create…):
            // no manifest was written, so the dir must stay reusable.
            for f in &files {
                let _ = std::fs::remove_file(f);
            }
            return Err(e);
        }
        let set = Self {
            dir: dir.to_path_buf(),
            n,
            d,
            chunk_rows: chunk_rows.max(1),
            fingerprint: dataset_fingerprint(ds),
            files,
        };
        set.write_manifest()?;
        Ok(set)
    }

    /// Open an existing store from its manifest, cross-checking every
    /// shard header against it (feature count and total row count), so
    /// a manifest desynchronized from its shard files is rejected here
    /// rather than silently training on the wrong partition.
    pub fn open(dir: &Path) -> Result<Self> {
        let mpath = dir.join(STORE_MANIFEST);
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("read store manifest {}", mpath.display()))?;
        let v = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", mpath.display()))?;
        let format = v.get("format").and_then(Json::as_str).unwrap_or("");
        ensure!(
            format == "advgp-store-v1",
            "{}: unknown store format {format:?}",
            mpath.display()
        );
        let n = v.get("n").and_then(Json::as_usize).context("manifest: n")?;
        let d = v.get("d").and_then(Json::as_usize).context("manifest: d")?;
        let chunk_rows = v
            .get("chunk_rows")
            .and_then(Json::as_usize)
            .unwrap_or(DEFAULT_CHUNK_ROWS);
        let fingerprint = v
            .get("fingerprint")
            .and_then(Json::as_str)
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .with_context(|| format!("{}: missing/bad fingerprint", mpath.display()))?;
        let names = v.get("files").and_then(Json::as_arr).context("manifest: files")?;
        let mut files = Vec::with_capacity(names.len());
        let mut rows = 0usize;
        for name in names {
            let name = name.as_str().context("manifest: file name")?;
            let path = dir.join(name);
            let reader = ShardReader::open(&path)
                .with_context(|| format!("store shard {}", path.display()))?;
            ensure!(
                reader.d() == d,
                "{}: shard has d={} but manifest says {d}",
                path.display(),
                reader.d()
            );
            rows += reader.n();
            files.push(path);
        }
        ensure!(!files.is_empty(), "{}: empty store", mpath.display());
        ensure!(
            rows == n,
            "{}: shards hold {rows} rows but manifest says {n} — store and \
             manifest are out of sync (recreate the store)",
            mpath.display()
        );
        Ok(Self {
            dir: dir.to_path_buf(),
            n,
            d,
            chunk_rows: chunk_rows.max(1),
            fingerprint,
            files,
        })
    }

    /// Does `dir` already hold a store manifest?
    pub fn exists(dir: &Path) -> bool {
        dir.join(STORE_MANIFEST).is_file()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Total rows across all shards.
    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of shards (= workers the store was partitioned for).
    pub fn r(&self) -> usize {
        self.files.len()
    }

    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    /// [`dataset_fingerprint`] of the source data this store was
    /// partitioned from — compare before reusing a store for a run
    /// whose data was (re)generated independently.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Open a validating reader on shard `k`, preconfigured with the
    /// store's chunk size.
    pub fn reader(&self, k: usize) -> Result<ShardReader> {
        ensure!(k < self.files.len(), "shard index {k} out of {}", self.files.len());
        let mut r = ShardReader::open(&self.files[k])?;
        ensure!(
            r.d() == self.d,
            "{}: shard d={} but manifest says {}",
            self.files[k].display(),
            r.d(),
            self.d
        );
        r.set_chunk_rows(self.chunk_rows);
        Ok(r)
    }

    /// One reader per shard, in shard order.
    pub fn readers(&self) -> Result<Vec<ShardReader>> {
        (0..self.r()).map(|k| self.reader(k)).collect()
    }

    fn write_manifest(&self) -> Result<()> {
        let names: Vec<Json> = self
            .files
            .iter()
            .map(|p| Json::Str(p.file_name().unwrap().to_string_lossy().into_owned()))
            .collect();
        let doc = Json::obj(vec![
            ("format", Json::Str("advgp-store-v1".into())),
            ("n", Json::Num(self.n as f64)),
            ("d", Json::Num(self.d as f64)),
            ("r", Json::Num(self.r() as f64)),
            ("chunk_rows", Json::Num(self.chunk_rows as f64)),
            ("fingerprint", Json::Str(format!("{:016x}", self.fingerprint))),
            ("files", Json::Arr(names)),
        ]);
        let path = self.dir.join(STORE_MANIFEST);
        crate::util::atomic_write(&path, format!("{doc}\n").as_bytes())
            .context("write store manifest")?;
        Ok(())
    }
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::linalg::Mat;

    fn tdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("advgp_store_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip_bitwise() {
        let dir = tdir("roundtrip");
        let ds = synth::friedman(37, 4, 0.3, 9);
        let path = dir.join("a.shard");
        write_shard(&path, &ds).unwrap();
        let mut r = ShardReader::open(&path).unwrap();
        assert_eq!((r.n(), r.d()), (37, 4));
        let back = r.read_all().unwrap();
        for i in 0..ds.n() {
            assert_eq!(back.y[i].to_bits(), ds.y[i].to_bits());
            for c in 0..ds.d() {
                assert_eq!(back.x[(i, c)].to_bits(), ds.x[(i, c)].to_bits());
            }
        }
    }

    #[test]
    fn window_matches_in_memory_cyclic_window() {
        let dir = tdir("window");
        let ds = synth::friedman(23, 3, 0.2, 4);
        let path = dir.join("w.shard");
        write_shard(&path, &ds).unwrap();
        let mut r = ShardReader::open(&path).unwrap();
        let mut disk = Dataset { x: Mat::empty(), y: Vec::new() };
        let mut mem = Dataset { x: Mat::empty(), y: Vec::new() };
        for (start, k) in [(0usize, 7usize), (20, 7), (22, 23), (5, 40), (11, 1)] {
            r.read_window(start, k, &mut disk).unwrap();
            ds.copy_cyclic_window(start, k, &mut mem);
            assert_eq!(disk.n(), mem.n(), "start={start} k={k}");
            for i in 0..mem.n() {
                assert_eq!(disk.y[i].to_bits(), mem.y[i].to_bits());
                for c in 0..mem.d() {
                    assert_eq!(disk.x[(i, c)].to_bits(), mem.x[(i, c)].to_bits());
                }
            }
        }
    }

    #[test]
    fn open_rejects_corruption() {
        let dir = tdir("corrupt");
        let ds = synth::friedman(10, 2, 0.1, 1);
        let good = dir.join("good.shard");
        write_shard(&good, &ds).unwrap();
        // Bad magic.
        let mut bytes = std::fs::read(&good).unwrap();
        bytes[0] ^= 0xFF;
        let bad = dir.join("bad_magic.shard");
        std::fs::write(&bad, &bytes).unwrap();
        assert!(ShardReader::open(&bad).is_err());
        // Truncated data region.
        let bytes = std::fs::read(&good).unwrap();
        let trunc = dir.join("trunc.shard");
        std::fs::write(&trunc, &bytes[..bytes.len() - 8]).unwrap();
        assert!(ShardReader::open(&trunc).is_err());
        // Truncated header.
        let short = dir.join("short.shard");
        std::fs::write(&short, &bytes[..12]).unwrap();
        assert!(ShardReader::open(&short).is_err());
        // Trailing garbage.
        let mut bytes = std::fs::read(&good).unwrap();
        bytes.extend_from_slice(&[0u8; 8]);
        let long = dir.join("long.shard");
        std::fs::write(&long, &bytes).unwrap();
        assert!(ShardReader::open(&long).is_err());
        // The pristine file still opens.
        assert!(ShardReader::open(&good).is_ok());
    }

    #[test]
    fn shard_set_matches_dataset_shard() {
        let dir = tdir("set");
        let ds = synth::friedman(25, 4, 0.2, 7);
        let set = ShardSet::create(&dir, &ds, 3, 8).unwrap();
        assert_eq!((set.n(), set.d(), set.r()), (25, 4, 3));
        let mem = ds.shard(3);
        let reopened = ShardSet::open(&dir).unwrap();
        assert_eq!(reopened.chunk_rows(), 8);
        // The fingerprint survives the manifest roundtrip and ties the
        // store to this exact data: a same-shape other dataset differs.
        assert_eq!(reopened.fingerprint(), dataset_fingerprint(&ds));
        let other = synth::friedman(25, 4, 0.2, 8);
        assert_ne!(reopened.fingerprint(), dataset_fingerprint(&other));
        for k in 0..3 {
            let got = reopened.reader(k).unwrap().read_all().unwrap();
            assert_eq!(got.n(), mem[k].n(), "shard {k} size");
            for i in 0..got.n() {
                assert_eq!(got.y[i].to_bits(), mem[k].y[i].to_bits());
                for c in 0..got.d() {
                    assert_eq!(got.x[(i, c)].to_bits(), mem[k].x[(i, c)].to_bits());
                }
            }
        }
    }

    #[test]
    fn create_refuses_existing_store_and_open_rejects_desync() {
        let dir = tdir("recreate");
        let ds = synth::friedman(20, 3, 0.1, 5);
        ShardSet::create(&dir, &ds, 2, 4).unwrap();
        // Re-partitioning in place is refused (stale-manifest hazard).
        assert!(ShardSet::create(&dir, &ds, 4, 4).is_err());
        // Simulate the hazard anyway: a shard file from a different
        // partition under a surviving manifest → open() must reject.
        write_shard(&dir.join("shard_000.bin"), &ds.head(3)).unwrap();
        let err = ShardSet::open(&dir).unwrap_err();
        assert!(err.to_string().contains("out of sync"), "{err:#}");
    }

    #[test]
    fn steady_state_reads_do_not_allocate() {
        let dir = tdir("zeroalloc");
        let ds = synth::friedman(64, 5, 0.2, 3);
        let path = dir.join("z.shard");
        write_shard(&path, &ds).unwrap();
        let mut r = ShardReader::open(&path).unwrap();
        r.set_chunk_rows(10);
        let mut win = Dataset { x: Mat::empty(), y: Vec::new() };
        // Warm-up: one full cycle (includes a wrapped read).
        for _ in 0..7 {
            r.next_window(&mut win).unwrap();
        }
        let (cb, cx, cy) = (r.buf_capacity(), win.x.data.capacity(), win.y.capacity());
        for _ in 0..50 {
            r.next_window(&mut win).unwrap();
        }
        assert_eq!(r.buf_capacity(), cb, "reader byte buffer reallocated");
        assert_eq!(win.x.data.capacity(), cx, "window x reallocated");
        assert_eq!(win.y.capacity(), cy, "window y reallocated");
    }

    #[test]
    fn writer_rejects_bad_rows_and_cleans_up_temp_files() {
        let dir = tdir("writer");
        let mut w = ShardWriter::create(&dir.join("w.shard"), 3).unwrap();
        assert!(w.push_row(&[1.0, 2.0], 0.0).is_err(), "wrong arity accepted");
        drop(w);
        let w2 = ShardWriter::create(&dir.join("e.shard"), 2).unwrap();
        assert!(w2.finish().is_err(), "empty shard sealed");
        // Neither the final paths nor any temp files survive an abort.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name())
            .collect();
        assert!(leftovers.is_empty(), "aborted writers left {leftovers:?}");
    }
}
