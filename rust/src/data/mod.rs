//! Datasets: container, standardization, sharding, CSV I/O, synthetic
//! generators, k-means++ inducing-point initialization, and the
//! out-of-core shard [`store`].
//!
//! This is the data layer under the paper's §4 topology: [`Dataset::shard`]
//! produces the per-worker partition D = ∪ D_k (one contiguous,
//! near-equal shard per worker), and [`store::ShardSet`] is its on-disk
//! twin for runs where a shard must not be resident in worker memory.
//!
//! Key invariants:
//! * Partitions are exact: shards are disjoint, cover every row once,
//!   and sizes differ by at most one.
//! * Degenerate partitions are rejected loudly — see the contracts on
//!   [`Dataset::split`] and [`Dataset::shard`].
//! * [`Standardizer`] statistics are fit on training data only and are
//!   invertible (`unscale_y`), so reported RMSE is in original units.

pub mod csv;
pub mod kmeans;
pub mod store;
pub mod synth;

use crate::linalg::Mat;
use crate::util::rng::Pcg64;

/// A regression dataset: features `x` `[n, d]` and targets `y` `[n]`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Mat,
    pub y: Vec<f64>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.y.len()
    }

    pub fn d(&self) -> usize {
        self.x.cols
    }

    /// Split off the last `n_test` rows (callers shuffle first).
    ///
    /// # Contract
    ///
    /// Panics unless `0 < n_test < n`: both sides of the split must be
    /// non-empty (an empty train or test set silently poisons every
    /// downstream statistic, so it is rejected here instead).
    pub fn split(mut self, n_test: usize) -> (Dataset, Dataset) {
        assert!(
            n_test > 0 && n_test < self.n(),
            "Dataset::split: n_test={n_test} must satisfy 0 < n_test < n={} \
             (both partitions must be non-empty)",
            self.n()
        );
        let n_train = self.n() - n_test;
        let d = self.d();
        let test_x = Mat::from_vec(
            n_test,
            d,
            self.x.data.split_off(n_train * d),
        );
        let test_y = self.y.split_off(n_train);
        self.x.rows = n_train;
        (
            Dataset { x: self.x, y: self.y },
            Dataset { x: test_x, y: test_y },
        )
    }

    /// In-place row shuffle (features and targets together).
    pub fn shuffle(&mut self, rng: &mut Pcg64) {
        let n = self.n();
        let d = self.d();
        for i in (1..n).rev() {
            let j = rng.next_below((i + 1) as u64) as usize;
            if i != j {
                self.y.swap(i, j);
                for c in 0..d {
                    self.x.data.swap(i * d + c, j * d + c);
                }
            }
        }
    }

    /// Contiguous shards of near-equal size (one per worker, §4).
    ///
    /// # Contract
    ///
    /// Panics unless `1 ≤ r ≤ n`: every worker must receive at least
    /// one row (an empty shard would deadlock the bounded-staleness
    /// gate, which waits for a gradient from every worker).
    ///
    /// ```
    /// use advgp::data::Dataset;
    /// use advgp::linalg::Mat;
    ///
    /// let ds = Dataset {
    ///     x: Mat::from_vec(10, 1, (0..10).map(|i| i as f64).collect()),
    ///     y: vec![0.0; 10],
    /// };
    /// let shards = ds.shard(3); // sizes 4 + 3 + 3
    /// assert_eq!(shards.iter().map(|s| s.n()).sum::<usize>(), 10);
    /// assert_eq!(shards[0].n(), 4);
    /// assert_eq!(shards[2].x.row(0)[0], 7.0); // contiguous partition
    /// ```
    pub fn shard(&self, r: usize) -> Vec<Dataset> {
        let d = self.d();
        shard_spans(self.n(), r)
            .map(|span| {
                let x = Mat::from_vec(
                    span.len(),
                    d,
                    self.x.data[span.start * d..span.end * d].to_vec(),
                );
                let y = self.y[span].to_vec();
                Dataset { x, y }
            })
            .collect()
    }

    /// Take the first `k` rows (for subsampling).
    pub fn head(&self, k: usize) -> Dataset {
        let k = k.min(self.n());
        Dataset {
            x: Mat::from_vec(k, self.d(), self.x.data[..k * self.d()].to_vec()),
            y: self.y[..k].to_vec(),
        }
    }

    /// Copy `k` consecutive rows starting at `start` (wrapping around
    /// the end) into a caller-owned buffer — allocation-free once `out`
    /// is warm.  Used by capped workers to *rotate* through their shard
    /// instead of resampling the same head every iteration: windows at
    /// offsets `start, start + k, start + 2k, …` (mod n) tile the whole
    /// shard within ⌈n/k⌉ steps from any starting offset.
    pub fn copy_cyclic_window(&self, start: usize, k: usize, out: &mut Dataset) {
        let n = self.n();
        let d = self.d();
        let k = k.min(n);
        out.x.resize(k, d);
        out.y.resize(k, 0.0);
        if k == 0 {
            return;
        }
        let start = start % n;
        let first = k.min(n - start);
        out.x.data[..first * d]
            .copy_from_slice(&self.x.data[start * d..(start + first) * d]);
        out.y[..first].copy_from_slice(&self.y[start..start + first]);
        if first < k {
            let rest = k - first; // wrapped prefix
            out.x.data[first * d..].copy_from_slice(&self.x.data[..rest * d]);
            out.y[first..].copy_from_slice(&self.y[..rest]);
        }
    }
}

/// The §4 partition arithmetic, shared by [`Dataset::shard`] and the
/// on-disk [`store::ShardSet`]: `r` contiguous row spans of near-equal
/// size (first `n % r` spans get one extra row) covering `0..n` exactly
/// once.  Panics unless `1 ≤ r ≤ n` — see [`Dataset::shard`].
pub fn shard_spans(n: usize, r: usize) -> impl Iterator<Item = std::ops::Range<usize>> {
    assert!(
        r >= 1 && r <= n,
        "shard: cannot partition n={n} rows into r={r} non-empty shards \
         (need 1 <= r <= n)"
    );
    let base = n / r;
    let extra = n % r;
    let mut start = 0;
    (0..r).map(move |k| {
        let len = base + usize::from(k < extra);
        let span = start..start + len;
        start += len;
        span
    })
}

/// Per-feature/target standardization statistics (fit on train only).
#[derive(Clone, Debug)]
pub struct Standardizer {
    pub x_mean: Vec<f64>,
    pub x_std: Vec<f64>,
    pub y_mean: f64,
    pub y_std: f64,
}

impl Standardizer {
    pub fn fit(data: &Dataset) -> Self {
        let n = data.n() as f64;
        let d = data.d();
        let mut x_mean = vec![0.0; d];
        for r in 0..data.n() {
            for (c, v) in data.x.row(r).iter().enumerate() {
                x_mean[c] += v;
            }
        }
        for m in &mut x_mean {
            *m /= n;
        }
        let mut x_std = vec![0.0; d];
        for r in 0..data.n() {
            for (c, v) in data.x.row(r).iter().enumerate() {
                x_std[c] += (v - x_mean[c]) * (v - x_mean[c]);
            }
        }
        for s in &mut x_std {
            *s = (*s / n).sqrt().max(1e-8);
        }
        let y_mean = data.y.iter().sum::<f64>() / n;
        let y_std = (data.y.iter().map(|v| (v - y_mean) * (v - y_mean)).sum::<f64>() / n)
            .sqrt()
            .max(1e-8);
        Self { x_mean, x_std, y_mean, y_std }
    }

    pub fn apply(&self, data: &mut Dataset) {
        let d = data.d();
        for r in 0..data.n() {
            let row = data.x.row_mut(r);
            for c in 0..d {
                row[c] = (row[c] - self.x_mean[c]) / self.x_std[c];
            }
        }
        for y in &mut data.y {
            *y = (*y - self.y_mean) / self.y_std;
        }
    }

    /// Undo the target scaling on a prediction (for reporting RMSE in
    /// original units).
    pub fn unscale_y(&self, y: f64) -> f64 {
        y * self.y_std + self.y_mean
    }

    /// RMSE in standardized space -> original units.
    pub fn unscale_rmse(&self, rmse_std: f64) -> f64 {
        rmse_std * self.y_std
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize, d: usize) -> Dataset {
        let x = Mat::from_vec(n, d, (0..n * d).map(|i| i as f64).collect());
        let y = (0..n).map(|i| 10.0 * i as f64).collect();
        Dataset { x, y }
    }

    #[test]
    fn split_preserves_rows() {
        let ds = toy(10, 3);
        let (tr, te) = ds.split(4);
        assert_eq!(tr.n(), 6);
        assert_eq!(te.n(), 4);
        assert_eq!(te.x.row(0)[0], 18.0); // row 6 starts at 6*3=18
        assert_eq!(te.y[0], 60.0);
    }

    #[test]
    fn shard_covers_everything_once() {
        let ds = toy(10, 2);
        let shards = ds.shard(3);
        assert_eq!(shards.iter().map(|s| s.n()).sum::<usize>(), 10);
        assert_eq!(shards[0].n(), 4); // 10 = 4+3+3
        let mut ys: Vec<f64> = shards.iter().flat_map(|s| s.y.clone()).collect();
        ys.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(ys, ds.y);
    }

    #[test]
    #[should_panic(expected = "0 < n_test < n")]
    fn split_rejects_test_set_as_big_as_data() {
        toy(5, 2).split(5);
    }

    #[test]
    #[should_panic(expected = "0 < n_test < n")]
    fn split_rejects_empty_test_set() {
        toy(5, 2).split(0);
    }

    #[test]
    #[should_panic(expected = "non-empty")]
    fn shard_rejects_more_workers_than_rows() {
        toy(3, 2).shard(4);
    }

    #[test]
    #[should_panic(expected = "non-empty")]
    fn shard_rejects_zero_workers() {
        toy(3, 2).shard(0);
    }

    #[test]
    fn shuffle_keeps_pairs_together() {
        let mut ds = toy(50, 2);
        let mut rng = Pcg64::seeded(5);
        ds.shuffle(&mut rng);
        for r in 0..50 {
            // y = 10 * (x[0] / 2) by construction (x row i = [2i, 2i+1])
            assert_eq!(ds.y[r], 10.0 * ds.x.row(r)[0] / 2.0);
            assert_eq!(ds.x.row(r)[1], ds.x.row(r)[0] + 1.0);
        }
    }

    /// Rotating windows must (a) keep (x, y) rows paired, (b) wrap
    /// correctly, and (c) cover every shard row within ⌈n/k⌉ steps from
    /// any starting offset — the capped-worker coverage guarantee.
    #[test]
    fn cyclic_windows_cover_shard_from_any_offset() {
        for (n, k) in [(10usize, 4usize), (10, 3), (7, 7), (9, 1), (5, 8)] {
            let ds = toy(n, 2);
            for start0 in [0usize, 2, n - 1] {
                let mut seen = vec![false; n];
                let mut win = Dataset { x: Mat::empty(), y: Vec::new() };
                let mut off = start0;
                let kk = k.min(n);
                let steps = n.div_ceil(kk);
                for _ in 0..steps {
                    ds.copy_cyclic_window(off, k, &mut win);
                    assert_eq!(win.n(), kk);
                    for r in 0..win.n() {
                        // Row identity from construction: y = 10·i,
                        // x row i = [2i, 2i+1].
                        let i = (win.y[r] / 10.0) as usize;
                        assert_eq!(win.x.row(r)[0], (2 * i) as f64, "x/y pairing");
                        assert_eq!(win.x.row(r)[1], (2 * i + 1) as f64);
                        seen[i] = true;
                    }
                    off = (off + kk) % n;
                }
                assert!(
                    seen.iter().all(|&s| s),
                    "n={n} k={k} start={start0}: rows missed: {seen:?}"
                );
            }
        }
    }

    #[test]
    fn cyclic_window_reuses_buffers() {
        let ds = toy(12, 3);
        let mut win = Dataset { x: Mat::empty(), y: Vec::new() };
        ds.copy_cyclic_window(0, 5, &mut win);
        let (cx, cy) = (win.x.data.capacity(), win.y.capacity());
        for off in [5usize, 10, 3, 8] {
            ds.copy_cyclic_window(off, 5, &mut win);
        }
        assert_eq!(win.x.data.capacity(), cx, "window x reallocated");
        assert_eq!(win.y.capacity(), cy, "window y reallocated");
    }

    #[test]
    fn standardizer_roundtrip() {
        let mut ds = toy(20, 2);
        let st = Standardizer::fit(&ds);
        st.apply(&mut ds);
        let refit = Standardizer::fit(&ds);
        assert!(refit.y_mean.abs() < 1e-10);
        assert!((refit.y_std - 1.0).abs() < 1e-10);
        for c in 0..2 {
            assert!(refit.x_mean[c].abs() < 1e-10);
            assert!((refit.x_std[c] - 1.0).abs() < 1e-10);
        }
        // unscale inverts
        let y0 = st.unscale_y(ds.y[0]);
        assert!((y0 - 0.0).abs() < 1e-9);
    }
}
