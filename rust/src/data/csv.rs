//! CSV I/O for datasets and metric traces, plus a tiny least-squares
//! helper used by tests and the linear baseline's closed-form check.

use super::Dataset;
use crate::linalg::{spd_inverse, Mat};
use anyhow::{Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Write a dataset as CSV with header `f0,...,fD,y`.
pub fn write_dataset(path: &Path, ds: &Dataset) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    let header: Vec<String> = (0..ds.d()).map(|i| format!("f{i}")).collect();
    writeln!(w, "{},y", header.join(","))?;
    for r in 0..ds.n() {
        for v in ds.x.row(r) {
            write!(w, "{v},")?;
        }
        writeln!(w, "{}", ds.y[r])?;
    }
    Ok(())
}

/// Read a dataset written by `write_dataset` (last column is the target).
pub fn read_dataset(path: &Path) -> Result<Dataset> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut lines = std::io::BufReader::new(f).lines();
    let header = lines.next().context("empty csv")??;
    let d = header.split(',').count() - 1;
    let mut xdata = Vec::new();
    let mut y = Vec::new();
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let vals: Vec<f64> = line
            .split(',')
            .map(|s| s.trim().parse::<f64>())
            .collect::<Result<_, _>>()
            .with_context(|| format!("line {}", lineno + 2))?;
        anyhow::ensure!(vals.len() == d + 1, "line {}: want {} cols", lineno + 2, d + 1);
        xdata.extend_from_slice(&vals[..d]);
        y.push(vals[d]);
    }
    let n = y.len();
    Ok(Dataset { x: Mat::from_vec(n, d, xdata), y })
}

/// Append rows of `(t, iter, metric...)` traces as CSV.
pub fn write_trace(path: &Path, header: &str, rows: &[Vec<f64>]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "{header}")?;
    for row in rows {
        let s: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        writeln!(w, "{}", s.join(","))?;
    }
    Ok(())
}

/// Residual RMSE of an ordinary-least-squares fit (with intercept).
/// Used to verify generators are genuinely nonlinear.
pub fn linear_fit_residual_rmse(ds: &Dataset) -> f64 {
    let n = ds.n();
    let d = ds.d();
    // Design matrix with intercept.
    let mut a = Mat::zeros(n, d + 1);
    for r in 0..n {
        a.row_mut(r)[..d].copy_from_slice(ds.x.row(r));
        a.row_mut(r)[d] = 1.0;
    }
    let mut ata = a.gram();
    for i in 0..=d {
        ata[(i, i)] += 1e-8 * n as f64;
    }
    let aty = a.tr_matvec(&ds.y);
    let w = spd_inverse(&ata).expect("ridge ATA SPD").matvec(&aty);
    let pred = a.matvec(&w);
    crate::util::rmse(&pred, &ds.y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn dataset_roundtrip() {
        let ds = synth::friedman(50, 4, 0.1, 1);
        let dir = std::env::temp_dir().join("advgp_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("toy.csv");
        write_dataset(&p, &ds).unwrap();
        let back = read_dataset(&p).unwrap();
        assert_eq!(back.n(), 50);
        assert_eq!(back.d(), 4);
        for r in 0..50 {
            assert!((back.y[r] - ds.y[r]).abs() < 1e-9);
            for c in 0..4 {
                assert!((back.x[(r, c)] - ds.x[(r, c)]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn ols_exact_on_linear_data() {
        // y = 3 x0 - 2 x1 + 1 exactly -> residual ~ 0.
        let mut ds = synth::friedman(200, 4, 0.0, 2);
        for r in 0..ds.n() {
            ds.y[r] = 3.0 * ds.x[(r, 0)] - 2.0 * ds.x[(r, 1)] + 1.0;
        }
        assert!(linear_fit_residual_rmse(&ds) < 1e-5);
    }

    #[test]
    fn read_rejects_ragged() {
        let dir = std::env::temp_dir().join("advgp_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.csv");
        std::fs::write(&p, "f0,f1,y\n1,2,3\n4,5\n").unwrap();
        assert!(read_dataset(&p).is_err());
    }
}
