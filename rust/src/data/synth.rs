//! Synthetic workload generators standing in for the paper's datasets
//! (DESIGN.md §4 records the substitution rationale).
//!
//! * `flight_like`  — 8 features mirroring the US-flight-delay schema
//!   (Hensman et al. 2013): month, day-of-month, day-of-week, departure
//!   time, arrival time, air time, distance, aircraft age.  Delay is a
//!   smooth nonlinear function (rush-hour bumps, distance interaction,
//!   weekday effects) plus heavy-ish noise — linear models underfit it,
//!   GPs don't, which is the property Tables 1–2 / Fig. 1 exercise.
//! * `taxi_like` — 9 features mirroring the NYC-taxi schema (§6.3):
//!   time-of-day, day-of-week, day-of-month, month, pickup lat/lon,
//!   dropoff lat/lon, trip distance.  Travel time = distance / speed
//!   where speed depends nonlinearly on time-of-day and location
//!   (Manhattan congestion bowl), plus lognormal-ish noise.
//! * `friedman` — the classic Friedman #1 benchmark, for quickstart and
//!   tests (d = 4 used by the tiny artifacts: first 4 of 5 active dims).

use super::Dataset;
use crate::linalg::Mat;
use crate::util::rng::Pcg64;

/// US-flight-delay-like generator.  Target is "delay minutes".
pub fn flight_like(n: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed, 101);
    let d = 8;
    let mut x = Mat::zeros(n, d);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let month = rng.uniform(1.0, 13.0).floor(); // 1..12
        let dom = rng.uniform(1.0, 29.0).floor();
        let dow = rng.uniform(0.0, 7.0).floor();
        let dep = rng.uniform(5.0, 24.0); // departure hour
        let air = rng.uniform(0.5, 6.5); // air time hours
        let arr = (dep + air) % 24.0;
        let dist = air * rng.uniform(350.0, 520.0); // miles
        let age = rng.uniform(0.0, 25.0); // aircraft age years

        // Nonlinear "true" delay surface.
        let rush = 18.0 * (-0.5 * ((dep - 8.0) / 1.5).powi(2)).exp()
            + 25.0 * (-0.5 * ((dep - 17.5) / 2.0).powi(2)).exp();
        let weekend = if dow >= 5.0 { -6.0 } else { 2.0 * (dow - 2.0).abs() };
        let seasonal = 10.0 * (std::f64::consts::PI * (month - 6.5) / 6.0).cos().powi(2);
        let congestion = 12.0 / (1.0 + (-0.8 * (dist / 400.0 - 2.0)).exp());
        let age_eff = 0.25 * age * (1.0 + 0.3 * (age / 10.0).sin());
        let interaction = 6.0 * ((dep / 24.0) * (dist / 2500.0) * 8.0).sin();
        let f = rush + weekend + seasonal + congestion + age_eff + interaction;

        // Heavy-ish noise: mixture of N(0, 9^2) and occasional big delays.
        let noise = if rng.next_f64() < 0.08 {
            rng.normal_scaled(35.0, 30.0).max(0.0)
        } else {
            rng.normal_scaled(0.0, 9.0)
        };
        y[i] = f + noise;
        let row = x.row_mut(i);
        row.copy_from_slice(&[month, dom, dow, dep, arr, air, dist, age]);
    }
    Dataset { x, y }
}

/// NYC-taxi-like generator.  Target is "travel seconds".
pub fn taxi_like(n: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed, 202);
    let d = 9;
    let mut x = Mat::zeros(n, d);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let tod = rng.uniform(0.0, 24.0);
        let dow = rng.uniform(0.0, 7.0).floor();
        let dom = rng.uniform(1.0, 32.0).floor();
        let month = rng.uniform(1.0, 13.0).floor();
        // Manhattan-ish bounding box.
        let p_lat = rng.uniform(40.70, 40.83);
        let p_lon = rng.uniform(-74.02, -73.93);
        let d_lat = (p_lat + rng.normal_scaled(0.0, 0.03)).clamp(40.60, 40.90);
        let d_lon = (p_lon + rng.normal_scaled(0.0, 0.03)).clamp(-74.05, -73.90);
        // Haversine-ish planar distance in km, plus route wiggle.
        let dy = (d_lat - p_lat) * 111.0;
        let dx = (d_lon - p_lon) * 84.3;
        let dist = (dx * dx + dy * dy).sqrt() * rng.uniform(1.15, 1.45) + 0.2;

        // Speed surface (km/h): congestion bowl by time-of-day, worse
        // midtown, better weekends — the nonlinearity the GP must find.
        let rush = 1.0
            + 0.9 * (-0.5 * ((tod - 8.5) / 1.8).powi(2)).exp()
            + 1.2 * (-0.5 * ((tod - 17.5) / 2.2).powi(2)).exp();
        let midtown = (-(((p_lat - 40.755) / 0.02).powi(2)
            + ((p_lon + 73.985) / 0.02).powi(2))
            / 2.0)
            .exp();
        let weekend = if dow >= 5.0 { 1.25 } else { 1.0 };
        let night = if !(6.0..22.0).contains(&tod) { 1.35 } else { 1.0 };
        let speed = 24.0 * weekend * night / (rush * (1.0 + 0.8 * midtown));

        let base = dist / speed * 3600.0; // seconds
        let overhead = 90.0 + 25.0 * midtown + 4.0 * (month - 6.0).abs();
        let noise = (rng.normal_scaled(0.0, 0.18)).exp(); // lognormal factor
        y[i] = ((base + overhead) * noise).clamp(30.0, 5.0 * 3600.0);
        let row = x.row_mut(i);
        row.copy_from_slice(&[tod, dow, dom, month, p_lat, p_lon, d_lat, d_lon, dist]);
    }
    Dataset { x, y }
}

/// Friedman #1 (d = 4 variant used by the tiny m=16 artifacts):
/// y = 10 sin(pi x1 x2) + 20 (x3 - .5)^2 + 10 x4 + noise.
pub fn friedman(n: usize, d: usize, noise_std: f64, seed: u64) -> Dataset {
    assert!(d >= 4);
    let mut rng = Pcg64::new(seed, 303);
    let mut x = Mat::zeros(n, d);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let row = x.row_mut(i);
        for v in row.iter_mut() {
            *v = rng.next_f64();
        }
        let f = 10.0 * (std::f64::consts::PI * row[0] * row[1]).sin()
            + 20.0 * (row[2] - 0.5).powi(2)
            + 10.0 * row[3];
        y[i] = f + rng.normal_scaled(0.0, noise_std);
    }
    Dataset { x, y }
}

/// Draw from an actual GP prior (ARD kernel) — for exact-GP validation.
pub fn gp_draw(n: usize, d: usize, noise_std: f64, seed: u64) -> Dataset {
    use crate::kernel::{kmm, ArdParams};
    use crate::linalg::cholesky_lower;
    let mut rng = Pcg64::new(seed, 404);
    let x = Mat::from_vec(n, d, (0..n * d).map(|_| rng.normal()).collect());
    let params = ArdParams::unit(d);
    let k = kmm(&params, &x, 1e-8);
    let l = cholesky_lower(&k).expect("prior covariance SPD");
    let eps: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let f = l.matvec(&eps);
    let y = f
        .iter()
        .map(|fi| fi + rng.normal_scaled(0.0, noise_std))
        .collect();
    Dataset { x, y }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flight_shapes_and_ranges() {
        let ds = flight_like(500, 1);
        assert_eq!(ds.n(), 500);
        assert_eq!(ds.d(), 8);
        for r in 0..ds.n() {
            let row = ds.x.row(r);
            assert!((1.0..=12.0).contains(&row[0]), "month");
            assert!((0.0..7.0).contains(&row[2]), "dow");
            assert!(row[6] > 0.0, "distance positive");
        }
    }

    #[test]
    fn flight_is_nonlinear() {
        // A linear fit on the true features must leave substantially more
        // residual than the structural noise floor — the property that
        // makes the GP-vs-linear comparison meaningful.
        let ds = flight_like(4000, 2);
        let resid = super::super::csv::linear_fit_residual_rmse(&ds);
        let var = {
            let m = ds.y.iter().sum::<f64>() / ds.n() as f64;
            (ds.y.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / ds.n() as f64).sqrt()
        };
        assert!(resid > 0.5 * var * 0.5, "resid={resid} var={var}");
        assert!(resid < var, "linear must still beat the mean");
    }

    #[test]
    fn taxi_shapes_and_positivity() {
        let ds = taxi_like(500, 3);
        assert_eq!(ds.d(), 9);
        assert!(ds.y.iter().all(|&t| (30.0..=18_000.0).contains(&t)));
        // Average around the paper's ~764s scale (same order).
        let mean = ds.y.iter().sum::<f64>() / ds.n() as f64;
        assert!((200.0..2500.0).contains(&mean), "mean={mean}");
    }

    #[test]
    fn determinism_by_seed() {
        let a = taxi_like(100, 7);
        let b = taxi_like(100, 7);
        let c = taxi_like(100, 8);
        assert_eq!(a.y, b.y);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn friedman_signal_dominates() {
        let ds = friedman(2000, 4, 0.5, 9);
        let m = ds.y.iter().sum::<f64>() / ds.n() as f64;
        let std = (ds.y.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / ds.n() as f64).sqrt();
        assert!(std > 3.0, "signal variance should dominate noise");
    }

    #[test]
    fn gp_draw_matches_prior_scale() {
        let ds = gp_draw(200, 3, 0.1, 11);
        let m = ds.y.iter().sum::<f64>() / 200.0;
        let var = ds.y.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / 200.0;
        // Prior variance is a0^2 + noise = 1.01; allow wide slack.
        assert!((0.3..3.0).contains(&var), "var={var}");
    }
}
