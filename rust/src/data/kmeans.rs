//! k-means++ clustering for inducing-point initialization (paper §6.3
//! initializes Z from k-means centers of a training subsample).
//!
//! The O(n·k·d) assignment step (and the k-means++ distance refresh)
//! runs in parallel row blocks on the global pool above the linalg flop
//! threshold; each point's nearest-center computation is independent,
//! so results are identical at any thread count.  Seeding draws and the
//! O(n·d) center accumulation stay serial (RNG order must be stable).

use crate::linalg::{should_par, Mat};
use crate::util::pool;
use crate::util::rng::Pcg64;

fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// k-means++ seeding followed by Lloyd iterations.
/// `x` is [n, d]; returns centers [k, d].
pub fn kmeans(x: &Mat, k: usize, iters: usize, rng: &mut Pcg64) -> Mat {
    let n = x.rows;
    let d = x.cols;
    assert!(k >= 1 && k <= n, "k={k} n={n}");

    // ---- k-means++ seeding ----
    let mut centers = Mat::zeros(k, d);
    let first = rng.next_below(n as u64) as usize;
    centers.row_mut(0).copy_from_slice(x.row(first));
    let mut d2: Vec<f64> = (0..n).map(|i| sq_dist(x.row(i), centers.row(0))).collect();
    for c in 1..k {
        let total: f64 = d2.iter().sum();
        let mut pick = n - 1;
        if total > 0.0 {
            let target = rng.next_f64() * total;
            let mut acc = 0.0;
            for (i, w) in d2.iter().enumerate() {
                acc += w;
                if acc >= target {
                    pick = i;
                    break;
                }
            }
        } else {
            pick = rng.next_below(n as u64) as usize;
        }
        centers.row_mut(c).copy_from_slice(x.row(pick));
        let crow = centers.row(c);
        if should_par(n * d) {
            pool::parallel_rows_mut(&mut d2, 1, n, pool::block_size(n), &|r0, blk| {
                for (i, v) in blk.iter_mut().enumerate() {
                    *v = v.min(sq_dist(x.row(r0 + i), crow));
                }
            });
        } else {
            for (i, v) in d2.iter_mut().enumerate() {
                *v = v.min(sq_dist(x.row(i), crow));
            }
        }
    }

    // ---- Lloyd iterations ----
    let mut assign = vec![0usize; n];
    for _ in 0..iters {
        // Assignment: each point independently finds its nearest center
        // (the O(n·k·d) bulk of an iteration) — parallel row blocks.
        let changed = std::sync::atomic::AtomicBool::new(false);
        let assign_point = |i: usize, slot: &mut usize| {
            let xi = x.row(i);
            let mut best = 0;
            let mut best_d = f64::INFINITY;
            for c in 0..k {
                let dd = sq_dist(xi, centers.row(c));
                if dd < best_d {
                    best_d = dd;
                    best = c;
                }
            }
            if *slot != best {
                *slot = best;
                changed.store(true, std::sync::atomic::Ordering::Relaxed);
            }
        };
        if should_par(n * k * d) {
            pool::parallel_rows_mut(&mut assign, 1, n, pool::block_size(n), &|r0, blk| {
                for (i, slot) in blk.iter_mut().enumerate() {
                    assign_point(r0 + i, slot);
                }
            });
        } else {
            for (i, slot) in assign.iter_mut().enumerate() {
                assign_point(i, slot);
            }
        }
        let changed = changed.into_inner();
        let mut sums = Mat::zeros(k, d);
        let mut counts = vec![0usize; k];
        for i in 0..n {
            counts[assign[i]] += 1;
            let xi = x.row(i);
            let s = sums.row_mut(assign[i]);
            for c in 0..d {
                s[c] += xi[c];
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Re-seed empty cluster at a random point.
                let j = rng.next_below(n as u64) as usize;
                centers.row_mut(c).copy_from_slice(x.row(j));
            } else {
                let s = sums.row(c).to_vec();
                let cm = centers.row_mut(c);
                for (t, v) in cm.iter_mut().zip(s) {
                    *t = v / counts[c] as f64;
                }
            }
        }
        if !changed {
            break;
        }
    }
    centers
}

/// Total within-cluster sum of squares (for testing monotonicity).
pub fn inertia(x: &Mat, centers: &Mat) -> f64 {
    (0..x.rows)
        .map(|i| {
            (0..centers.rows)
                .map(|c| sq_dist(x.row(i), centers.row(c)))
                .fold(f64::INFINITY, f64::min)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn three_blobs(n_per: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seeded(seed);
        let centers = [[0.0, 0.0], [10.0, 0.0], [0.0, 10.0]];
        let mut data = Vec::new();
        for c in centers {
            for _ in 0..n_per {
                data.push(c[0] + rng.normal() * 0.3);
                data.push(c[1] + rng.normal() * 0.3);
            }
        }
        Mat::from_vec(3 * n_per, 2, data)
    }

    #[test]
    fn recovers_blobs() {
        let x = three_blobs(100, 1);
        let mut rng = Pcg64::seeded(2);
        let centers = kmeans(&x, 3, 50, &mut rng);
        let mut found = [false; 3];
        for c in 0..3 {
            let row = centers.row(c);
            for (t, truth) in [[0.0, 0.0], [10.0, 0.0], [0.0, 10.0]].iter().enumerate() {
                if sq_dist(row, truth) < 0.5 {
                    found[t] = true;
                }
            }
        }
        assert_eq!(found, [true, true, true], "{centers:?}");
    }

    #[test]
    fn inertia_improves_over_seeding_only() {
        let x = three_blobs(60, 3);
        let mut rng1 = Pcg64::seeded(4);
        let seeded = kmeans(&x, 5, 0, &mut rng1);
        let mut rng2 = Pcg64::seeded(4);
        let trained = kmeans(&x, 5, 30, &mut rng2);
        assert!(inertia(&x, &trained) <= inertia(&x, &seeded) + 1e-9);
    }

    #[test]
    fn k_equals_n_zero_inertia() {
        let x = three_blobs(3, 5); // 9 points
        let mut rng = Pcg64::seeded(6);
        let centers = kmeans(&x, 9, 20, &mut rng);
        assert!(inertia(&x, &centers) < 1e-6);
    }
}
