//! Open-loop load generator + scoreboard for the replica read path
//! (ADVGPSV1, ISSUE 8) — the measurement half of `advgp loadgen`.
//!
//! **Open loop**: the k-th request is *scheduled* at `t0 + k/qps` and
//! its latency is measured from that scheduled instant, not from the
//! moment the socket write happened.  A closed-loop generator (send,
//! wait, send) silently stops offering load whenever the server stalls,
//! so its tail quantiles flatter exactly the behaviour a tail quantile
//! exists to expose (coordinated omission).  Here a stall makes the
//! *next* requests late too — and their latencies say so.
//!
//! Requests round-robin across the replica fleet, one pipelined
//! session per replica split into a sender and a receiver thread
//! ([`crate::serve::replica::PredictClient::into_split`]); answers
//! correlate back to their scheduled instants by request id.  Latencies
//! are kept **exactly** (one `u64` of nanoseconds per request, sorted
//! once at the end), so p50/p99/p999 are true order statistics, not
//! reservoir estimates — a loadgen knows its n up front and can afford
//! the memory.
//!
//! [`Scoreboard::write_bench`] merge-writes `BENCH_serve.json` in the
//! same schema-1 shape as `perf_hotpath`/`perf_predict`, so
//! `scripts/bench_diff.py` diffs serving runs unchanged and the
//! replicas=1 / replicas=2 rows accumulate into one file.
//!
//! **Routed-fleet mode** (ADVGPRT1, ISSUE 9): point [`run`] at a
//! [`super::Router`] address instead of the replicas — the wire is
//! identical (the receiver halves absorb the extra ROUTE-STATUS frame)
//! — then [`Scoreboard::attach_route`] the router's final
//! [`RouteStats`] so the bench entry carries per-hop reject, retry,
//! and cache-hit accounting next to the client-visible numbers.
//! Throughput stays honest either way: the `rows_per_sec` numerator
//! counts **accepted rows only** (a REJECT contributes zero rows, and
//! is reported per-code instead), so a routed run that absorbs
//! overload rejects on retries cannot inflate its own throughput.

use super::replica::{PredictAnswer, PredictClient};
use super::router::RouteStats;
use crate::ps::wire::{REJ_BAD_DIM, REJ_BAD_SCOPE, REJ_NOT_READY, REJ_OVERLOAD, REJ_STALE};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use anyhow::{ensure, Context, Result};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

/// What to offer, at what rate.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Offered request rate (requests/sec) across the whole fleet.
    pub qps: f64,
    /// Total requests to offer.
    pub requests: usize,
    /// Rows per PREDICT request.
    pub rows_per_request: usize,
    /// Seed for the synthetic input rows (deterministic per seed).
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self { qps: 500.0, requests: 2000, rows_per_request: 8, seed: 42 }
    }
}

/// What came back: exact latencies plus admission/throughput tallies.
#[derive(Clone, Debug)]
pub struct Scoreboard {
    /// Requests answered with a PREDICTION.
    pub answered: usize,
    /// Rows in those answers.
    pub rows: usize,
    /// Requests answered with a typed REJECT, by code.
    pub rejects: Vec<(u16, u64)>,
    /// Sessions that died before all their answers arrived.
    pub broken_sessions: usize,
    /// Offered-to-drained wall clock.
    pub wall_secs: f64,
    /// Answered rows per wall-clock second.
    pub rows_per_sec: f64,
    /// Per-request latency (scheduled → answered), sorted ascending.
    pub latencies_ns: Vec<u64>,
    /// θ versions observed in answers (freshness evidence).
    pub min_version: u64,
    pub max_version: u64,
    /// Router-side counters for a routed run (see
    /// [`Scoreboard::attach_route`]); `None` for direct-replica runs.
    pub route: Option<RouteStats>,
}

/// Stable field-name suffix for a REJECT code.
fn reject_code_name(code: u16) -> &'static str {
    match code {
        REJ_NOT_READY => "not_ready",
        REJ_STALE => "stale",
        REJ_OVERLOAD => "overload",
        REJ_BAD_DIM => "bad_dim",
        REJ_BAD_SCOPE => "bad_scope",
        _ => "other",
    }
}

impl Scoreboard {
    /// Exact order-statistic quantile over the answered requests
    /// (`q` in [0, 1]); 0 when nothing was answered.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.latencies_ns.is_empty() {
            return 0;
        }
        let idx = ((self.latencies_ns.len() - 1) as f64 * q.clamp(0.0, 1.0)).round();
        self.latencies_ns[idx as usize]
    }

    pub fn mean_ns(&self) -> f64 {
        if self.latencies_ns.is_empty() {
            return 0.0;
        }
        self.latencies_ns.iter().map(|&n| n as f64).sum::<f64>()
            / self.latencies_ns.len() as f64
    }

    pub fn total_rejects(&self) -> u64 {
        self.rejects.iter().map(|&(_, n)| n).sum()
    }

    /// Fold a router's final counters into this board, so the bench
    /// entry for a routed run reports per-hop rejects, sibling retries,
    /// failovers, and answer-cache traffic alongside the
    /// client-visible numbers.
    pub fn attach_route(&mut self, stats: RouteStats) {
        self.route = Some(stats);
    }

    /// One human line for the console.
    pub fn summary(&self) -> String {
        let mut line = format!(
            "{} answered ({} rows, {} rejects, {} broken) in {:.2}s — \
             {:.0} rows/s, p50 {:.2}ms p99 {:.2}ms p999 {:.2}ms (θ v{}..v{})",
            self.answered,
            self.rows,
            self.total_rejects(),
            self.broken_sessions,
            self.wall_secs,
            self.rows_per_sec,
            self.quantile_ns(0.50) as f64 / 1e6,
            self.quantile_ns(0.99) as f64 / 1e6,
            self.quantile_ns(0.999) as f64 / 1e6,
            self.min_version,
            self.max_version,
        );
        if let Some(r) = &self.route {
            let hop_rejects: u64 = r.hop_rejects.iter().map(|&(_, n)| n).sum();
            line.push_str(&format!(
                " [routed: {} cache hits / {} misses, {} retries, {} failovers, \
                 {hop_rejects} hop rejects]",
                r.cache_hits, r.cache_misses, r.retries, r.failovers,
            ));
        }
        line
    }

    /// The schema-1 bench entry for this run.  `rejects` is the total;
    /// every nonzero code also lands as its own `rejects_<code>` field,
    /// and a routed run ([`Scoreboard::attach_route`]) adds `route_*`
    /// per-hop accounting.
    pub fn to_bench_entry(&self, name: &str, cfg: &LoadgenConfig, replicas: usize) -> Json {
        let base = Json::obj(vec![
            ("name", Json::Str(name.to_string())),
            ("replicas", Json::Num(replicas as f64)),
            ("qps_target", Json::Num(cfg.qps)),
            ("requests", Json::Num(cfg.requests as f64)),
            ("rows_per_request", Json::Num(cfg.rows_per_request as f64)),
            ("rows_per_sec", Json::Num(self.rows_per_sec)),
            ("mean_ns", Json::Num(self.mean_ns())),
            ("p50_ns", Json::Num(self.quantile_ns(0.50) as f64)),
            ("p99_ns", Json::Num(self.quantile_ns(0.99) as f64)),
            ("p999_ns", Json::Num(self.quantile_ns(0.999) as f64)),
            ("rejects", Json::Num(self.total_rejects() as f64)),
            ("iters", Json::Num(self.answered as f64)),
        ]);
        let Json::Obj(mut entry) = base else { unreachable!() };
        let mut add = |key: String, n: u64| {
            if n > 0 {
                let prev = entry.get(&key).and_then(Json::as_f64).unwrap_or(0.0);
                entry.insert(key, Json::Num(prev + n as f64));
            }
        };
        for &(code, n) in &self.rejects {
            add(format!("rejects_{}", reject_code_name(code)), n);
        }
        if let Some(r) = &self.route {
            add("route_cache_hits".into(), r.cache_hits);
            add("route_cache_misses".into(), r.cache_misses);
            add("route_retries".into(), r.retries);
            add("route_failovers".into(), r.failovers);
            for &(code, n) in &r.hop_rejects {
                add(format!("route_hop_rejects_{}", reject_code_name(code)), n);
            }
        }
        Json::Obj(entry)
    }

    /// Merge this run into `path` (`BENCH_serve.json` shape: schema 1,
    /// bench "serve").  An existing entry with the same `name` is
    /// replaced; everything else in the file survives, so sequential
    /// `replicas=1` / `replicas=2` runs accumulate into one document.
    pub fn write_bench(
        &self,
        path: &str,
        name: &str,
        cfg: &LoadgenConfig,
        replicas: usize,
    ) -> Result<()> {
        let mut benches: Vec<Json> = match std::fs::read_to_string(path) {
            Ok(text) => Json::parse(&text)
                .ok()
                .and_then(|doc| doc.get("benches").and_then(|b| b.as_arr().map(<[Json]>::to_vec)))
                .unwrap_or_default(),
            Err(_) => Vec::new(),
        };
        benches.retain(|b| b.get("name").and_then(Json::as_str) != Some(name));
        benches.push(self.to_bench_entry(name, cfg, replicas));
        let doc = Json::obj(vec![
            ("schema", Json::Num(1.0)),
            ("bench", Json::Str("serve".into())),
            ("threads", Json::Num(crate::util::pool::threads() as f64)),
            ("benches", Json::Arr(benches)),
        ]);
        crate::util::atomic_write(std::path::Path::new(path), format!("{doc}\n").as_bytes())
            .with_context(|| format!("write {path}"))
    }
}

/// What a receiver thread tallies for its session.
struct SessionTally {
    latencies_ns: Vec<u64>,
    rows: usize,
    rejects: Vec<(u16, u64)>,
    broken: bool,
    min_version: u64,
    max_version: u64,
    last_answer: Option<Instant>,
}

/// Offer `cfg.requests` requests at `cfg.qps` across `replicas`
/// (round-robin), wait for every answer, and score the run.
pub fn run(replicas: &[String], cfg: &LoadgenConfig) -> Result<Scoreboard> {
    ensure!(!replicas.is_empty(), "no replica addresses");
    ensure!(cfg.qps > 0.0, "qps must be positive");
    ensure!(cfg.requests > 0, "nothing to offer");
    ensure!(cfg.rows_per_request > 0, "empty requests");

    // One pipelined session per replica.
    let mut senders = Vec::new();
    let mut receivers = Vec::new();
    let mut d = 0usize;
    for addr in replicas {
        let client = PredictClient::connect(addr)
            .with_context(|| format!("open predict session to {addr}"))?;
        ensure!(
            d == 0 || d == client.d,
            "replicas disagree on the feature dimension ({d} vs {})",
            client.d
        );
        d = client.d;
        let (tx, rx) = client.into_split();
        senders.push(tx);
        receivers.push(rx);
    }
    let interval = Duration::from_secs_f64(1.0 / cfg.qps);
    let n_sessions = senders.len();

    // Receiver threads: drain answers, correlating each to its
    // scheduled instant through an in-order side channel (a session
    // answers in request order).
    let mut rx_threads = Vec::new();
    let mut sched_txs: Vec<Sender<Instant>> = Vec::new();
    for mut rx in receivers {
        let (stx, srx): (Sender<Instant>, Receiver<Instant>) = channel();
        sched_txs.push(stx);
        rx_threads.push(std::thread::spawn(move || {
            let mut t = SessionTally {
                latencies_ns: Vec::new(),
                rows: 0,
                rejects: Vec::new(),
                broken: false,
                min_version: u64::MAX,
                max_version: 0,
                last_answer: None,
            };
            loop {
                let answer = match rx.recv() {
                    Ok(Some((_id, a))) => a,
                    Ok(None) => break,
                    Err(_) => {
                        t.broken = true;
                        break;
                    }
                };
                let now = Instant::now();
                let Ok(scheduled) = srx.recv() else {
                    t.broken = true;
                    break;
                };
                t.last_answer = Some(now);
                match answer {
                    PredictAnswer::Prediction { version, mean, .. } => {
                        // Only answered predictions enter the latency
                        // distribution — a fast REJECT would flatter
                        // the quantiles of work the replica refused.
                        t.latencies_ns.push(
                            now.saturating_duration_since(scheduled).as_nanos() as u64,
                        );
                        t.rows += mean.len();
                        t.min_version = t.min_version.min(version);
                        t.max_version = t.max_version.max(version);
                    }
                    PredictAnswer::Rejected { code, .. } => {
                        match t.rejects.iter_mut().find(|(c, _)| *c == code) {
                            Some((_, n)) => *n += 1,
                            None => t.rejects.push((code, 1)),
                        }
                    }
                }
            }
            t
        }));
    }

    // The single pacing loop: schedule, stamp, send, round-robin.
    // (One sender thread is enough — frame writes are microseconds at
    // these rates; the receivers carry the waiting.)
    let t0 = Instant::now();
    let mut rng = Pcg64::seeded(cfg.seed);
    let mut rows = vec![0.0f64; cfg.rows_per_request * d];
    for k in 0..cfg.requests {
        let scheduled = t0 + interval.mul_f64(k as f64);
        if let Some(wait) = scheduled.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        } // behind schedule: send immediately, the lateness is the point
        for v in rows.iter_mut() {
            *v = rng.next_f64() * 2.0 - 1.0;
        }
        let s = k % n_sessions;
        // Stamp before the write so socket back-pressure counts.
        let _ = sched_txs[s].send(scheduled);
        if senders[s].send(&rows).is_err() {
            // Session gone; its receiver will tally the break.  Keep
            // offering to the surviving sessions.
            continue;
        }
    }
    // Half-close every session: receivers see a clean end after the
    // in-flight answers drain.
    drop(sched_txs);
    for s in senders {
        s.finish();
    }

    let mut sb = Scoreboard {
        answered: 0,
        rows: 0,
        rejects: Vec::new(),
        broken_sessions: 0,
        wall_secs: 0.0,
        rows_per_sec: 0.0,
        latencies_ns: Vec::new(),
        min_version: u64::MAX,
        max_version: 0,
        route: None,
    };
    let mut t_end = t0;
    for h in rx_threads {
        let t = h.join().expect("receiver thread panicked");
        sb.answered += t.latencies_ns.len();
        sb.rows += t.rows;
        sb.latencies_ns.extend(t.latencies_ns);
        for (code, n) in t.rejects {
            match sb.rejects.iter_mut().find(|(c, _)| *c == code) {
                Some((_, m)) => *m += n,
                None => sb.rejects.push((code, n)),
            }
        }
        sb.broken_sessions += t.broken as usize;
        sb.min_version = sb.min_version.min(t.min_version);
        sb.max_version = sb.max_version.max(t.max_version);
        if let Some(last) = t.last_answer {
            t_end = t_end.max(last);
        }
    }
    if sb.min_version == u64::MAX {
        sb.min_version = 0;
    }
    sb.latencies_ns.sort_unstable();
    sb.wall_secs = t_end.saturating_duration_since(t0).as_secs_f64();
    sb.rows_per_sec = if sb.wall_secs > 0.0 { sb.rows as f64 / sb.wall_secs } else { 0.0 };
    Ok(sb)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn board(lat: Vec<u64>) -> Scoreboard {
        let mut latencies_ns = lat;
        latencies_ns.sort_unstable();
        Scoreboard {
            answered: latencies_ns.len(),
            rows: latencies_ns.len(),
            rejects: vec![],
            broken_sessions: 0,
            wall_secs: 1.0,
            rows_per_sec: latencies_ns.len() as f64,
            latencies_ns,
            min_version: 1,
            max_version: 1,
            route: None,
        }
    }

    /// Quantiles are exact order statistics over the latency vector.
    #[test]
    fn quantiles_are_exact_order_statistics() {
        let sb = board((1..=1000).collect());
        assert_eq!(sb.quantile_ns(0.0), 1);
        assert_eq!(sb.quantile_ns(1.0), 1000);
        // index round((n-1)·q): round(999·0.5) = 500 (0-based) → 501.
        assert_eq!(sb.quantile_ns(0.5), 501);
        assert_eq!(sb.quantile_ns(0.99), 990);
        assert_eq!(sb.quantile_ns(0.999), 999);
        assert!((sb.mean_ns() - 500.5).abs() < 1e-9);
    }

    /// Degenerate boards don't panic or divide by zero.
    #[test]
    fn empty_board_is_all_zeros() {
        let sb = board(vec![]);
        assert_eq!(sb.quantile_ns(0.5), 0);
        assert_eq!(sb.mean_ns(), 0.0);
    }

    /// `write_bench` accumulates entries by name: a re-run replaces its
    /// own row and leaves the other replica count's row alone.
    #[test]
    fn bench_file_merges_by_name() {
        let dir = std::env::temp_dir().join(format!("advgp_loadgen_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_serve.json");
        let path = path.to_str().unwrap();
        let cfg = LoadgenConfig::default();
        board(vec![10, 20]).write_bench(path, "serve/replicas=1", &cfg, 1).unwrap();
        board(vec![30, 40]).write_bench(path, "serve/replicas=2", &cfg, 2).unwrap();
        board(vec![50, 60]).write_bench(path, "serve/replicas=1", &cfg, 1).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("serve"));
        let benches = doc.get("benches").unwrap().as_arr().unwrap();
        assert_eq!(benches.len(), 2, "same-name rerun replaced, not appended");
        let r1 = benches
            .iter()
            .find(|b| b.get("name").unwrap().as_str() == Some("serve/replicas=1"))
            .unwrap();
        // The replacement carries the rerun's latencies (mean 55ns).
        assert!((r1.get("mean_ns").unwrap().as_f64().unwrap() - 55.0).abs() < 1e-9);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Regression (ISSUE 9 satellite): the throughput numerator counts
    /// **accepted rows only** — a REJECTed request contributes zero
    /// rows to `rows_per_sec` however many times a routed retry
    /// absorbed it — and every reject code is reported as its own
    /// bench field instead of hiding in the total.
    #[test]
    fn rows_per_sec_counts_only_accepted_rows() {
        let mut sb = board(vec![10, 20, 30, 40]); // 4 accepted rows, 1s wall
        sb.rejects = vec![(REJ_OVERLOAD, 5), (REJ_STALE, 2)];
        // the run() accounting: rows only ever comes from PREDICTION
        // answers, so rejects leave the numerator untouched
        sb.rows_per_sec = sb.rows as f64 / sb.wall_secs;
        assert_eq!(sb.rows_per_sec, 4.0);
        let entry = sb.to_bench_entry("serve/test", &LoadgenConfig::default(), 1);
        assert_eq!(entry.get("rows_per_sec").unwrap().as_f64(), Some(4.0));
        assert_eq!(entry.get("rejects").unwrap().as_f64(), Some(7.0));
        assert_eq!(entry.get("rejects_overload").unwrap().as_f64(), Some(5.0));
        assert_eq!(entry.get("rejects_stale").unwrap().as_f64(), Some(2.0));
        assert!(entry.get("rejects_not_ready").is_none(), "zero counts are elided");
    }

    /// A routed run's attached [`RouteStats`] lands as `route_*` fields
    /// in the bench entry — the per-hop accounting `bench_diff.py`
    /// tables for the routed-fleet config.
    #[test]
    fn routed_stats_land_in_the_bench_entry() {
        let mut sb = board(vec![10]);
        let rs = RouteStats {
            cache_hits: 3,
            cache_misses: 4,
            retries: 2,
            failovers: 1,
            hop_rejects: vec![(REJ_OVERLOAD, 2), (REJ_STALE, 0)],
            ..RouteStats::default()
        };
        sb.attach_route(rs);
        assert!(sb.summary().contains("3 cache hits"));
        let entry = sb.to_bench_entry("serve/routed-replicas=2", &LoadgenConfig::default(), 2);
        assert_eq!(entry.get("route_cache_hits").unwrap().as_f64(), Some(3.0));
        assert_eq!(entry.get("route_cache_misses").unwrap().as_f64(), Some(4.0));
        assert_eq!(entry.get("route_retries").unwrap().as_f64(), Some(2.0));
        assert_eq!(entry.get("route_failovers").unwrap().as_f64(), Some(1.0));
        assert_eq!(entry.get("route_hop_rejects_overload").unwrap().as_f64(), Some(2.0));
        assert!(entry.get("route_hop_rejects_stale").is_none(), "zero counts are elided");
    }
}
