//! The stateless serving replica (ADVGPSV1, ISSUE 8): horizontal read
//! scale-out decoupled from training.
//!
//! A [`Replica`] dials every θ-slice server of a running fleet with a
//! SUBSCRIBE handshake (read-only — no worker id, no gate clock),
//! assembles the per-slice POSTERIOR-SYNC streams into one full-θ view
//! with exactly the version-vector-floor machinery
//! [`crate::ps::ShardedWorkerHandle`] uses, rebuilds the posterior
//! locally in a [`PosteriorCache`], and answers PREDICT traffic on its
//! own listener through a [`BatchServer`].  Because
//! [`crate::gp::SparseGp`] is a deterministic function of (layout, θ),
//! a replica's posterior at θ version v is **bitwise-equal** to the
//! in-process cache at v — pinned by `rust/tests/serve_replica.rs`.
//!
//! Failure semantics:
//! * A **clean SHUTDOWN** from the trainer freezes the final θ; the
//!   replica serves it indefinitely (a finished model is not stale).
//! * A **lost subscription link** degrades typed: the replica serves
//!   its last posterior while a per-link supervisor reconnects with
//!   jittered backoff (resuming at the *newest* θ version the server
//!   holds); once the outage outlives
//!   [`ReplicaConfig::staleness_budget`], PREDICTs draw
//!   `REJECT(REJ_STALE)` until a link repair clears the clock.
//! * **Admission control** answers per-request REJECTs (`REJ_*` codes)
//!   without dropping the session — overload, staleness, and dimension
//!   errors are workload verdicts, not protocol faults.
//!
//! [`PredictClient`] is the client half (used by `advgp loadgen`, the
//! chaos suite, and any external caller): one SUBSCRIBE(predict)
//! handshake, then pipelined PREDICT/PREDICTION exchanges.  The same
//! client speaks to a [`super::Router`] (ADVGPRT1) unchanged — routers
//! additionally push ROUTE-STATUS frames, absorbed into
//! [`PredictClient::route_status`].

use super::{BatchConfig, BatchServer, PosteriorCache, ServeClient, ServeReport};
use crate::gp::ThetaLayout;
use crate::ps::net::{RetryPolicy, Rejected};
use crate::ps::sharded::{run_assembler_draining, ShardedPublished, Topology};
use crate::ps::wire::{
    self, Frame, ReadEvent, ERR_MALFORMED, ERR_PROTO, MAX_FRAME_LEN,
    MAX_HANDSHAKE_FRAME_LEN, PROTO_NT2, PROTO_VERSION, REJ_BAD_DIM, REJ_BAD_SCOPE,
    REJ_NOT_READY, REJ_OVERLOAD, REJ_STALE, SUBSCRIBE_POSTERIOR, SUBSCRIBE_PREDICT,
};
use crate::ps::{Published, PublishMeta};
use crate::util::rng::Pcg64;
use crate::util::{fnv1a64, FNV1A64_INIT};
use crate::{log_info, log_warn};
use anyhow::{bail, ensure, Context, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Replica policy knobs.
#[derive(Clone, Debug)]
pub struct ReplicaConfig {
    /// Microbatching policy for the local [`BatchServer`].
    pub batch: BatchConfig,
    /// How long the replica may serve a *stale* posterior while its
    /// subscription is down before PREDICTs draw `REJECT(REJ_STALE)`.
    /// A clean trainer SHUTDOWN never starts this clock.
    pub staleness_budget: Duration,
    /// Subscription timeouts and the per-outage reconnect budget.
    pub retry: RetryPolicy,
    /// Admission ceiling: PREDICT rows in flight (staged or being
    /// computed) beyond this draw `REJECT(REJ_OVERLOAD)`.
    pub max_inflight_rows: usize,
}

impl Default for ReplicaConfig {
    fn default() -> Self {
        Self {
            batch: BatchConfig::default(),
            staleness_budget: Duration::from_secs(10),
            retry: RetryPolicy::default(),
            max_inflight_rows: 4096,
        }
    }
}

/// Per-code REJECT tallies — the typed-degradation evidence the chaos
/// suite asserts on.
#[derive(Default)]
pub struct RejectCounters {
    pub not_ready: AtomicU64,
    pub stale: AtomicU64,
    pub overload: AtomicU64,
    pub bad_dim: AtomicU64,
}

impl RejectCounters {
    pub(crate) fn bump(&self, code: u16) {
        match code {
            REJ_NOT_READY => &self.not_ready,
            REJ_STALE => &self.stale,
            REJ_OVERLOAD => &self.overload,
            REJ_BAD_DIM => &self.bad_dim,
            _ => return,
        }
        .fetch_add(1, Ordering::Relaxed);
    }

    pub fn total(&self) -> u64 {
        self.not_ready.load(Ordering::Relaxed)
            + self.stale.load(Ordering::Relaxed)
            + self.overload.load(Ordering::Relaxed)
            + self.bad_dim.load(Ordering::Relaxed)
    }

    /// Per-code snapshot as `(code, count)` pairs, all four codes —
    /// the shape `BENCH_serve.json` and [`super::RouteStats`] report.
    pub fn by_code(&self) -> [(u16, u64); 4] {
        [
            (REJ_NOT_READY, self.not_ready.load(Ordering::Relaxed)),
            (REJ_STALE, self.stale.load(Ordering::Relaxed)),
            (REJ_OVERLOAD, self.overload.load(Ordering::Relaxed)),
            (REJ_BAD_DIM, self.bad_dim.load(Ordering::Relaxed)),
        ]
    }
}

/// Subscription-link staleness clock: which links are down, since when,
/// and whether the trainer ended cleanly (in which case the final θ is
/// *final*, not stale).
struct LinkHealth {
    inner: Mutex<HealthInner>,
}

struct HealthInner {
    down: Vec<bool>,
    down_since: Option<Instant>,
    clean: bool,
}

impl LinkHealth {
    fn new(n: usize) -> Self {
        Self {
            inner: Mutex::new(HealthInner {
                down: vec![false; n],
                down_since: None,
                clean: false,
            }),
        }
    }

    fn mark_down(&self, i: usize) {
        let mut g = self.inner.lock().unwrap();
        g.down[i] = true;
        g.down_since.get_or_insert_with(Instant::now);
    }

    fn mark_up(&self, i: usize) {
        let mut g = self.inner.lock().unwrap();
        g.down[i] = false;
        if !g.down.iter().any(|&d| d) {
            g.down_since = None;
        }
    }

    /// A clean trainer SHUTDOWN: the posterior is final from here on;
    /// any staleness clock (and future link losses — the servers are
    /// gone on purpose) stops mattering.
    fn mark_clean(&self) {
        let mut g = self.inner.lock().unwrap();
        g.clean = true;
        g.down_since = None;
    }

    /// How long the posterior has been stale (some link down, no clean
    /// shutdown); `None` while healthy or after a clean end.
    fn stale_for(&self) -> Option<Duration> {
        let g = self.inner.lock().unwrap();
        if g.clean {
            return None;
        }
        g.down_since.map(|t| t.elapsed())
    }
}

/// One validated posterior subscription (the client side of the
/// SUBSCRIBE → POSTERIOR-SYNC handshake against a θ-slice server).
struct Subscription {
    stream: TcpStream,
    m: u64,
    d: u64,
    slice_id: u64,
    n_slices: u64,
    start: u64,
    end: u64,
    version: u64,
    meta: PublishMeta,
    theta: Vec<f64>,
}

impl Subscription {
    /// The agreement key a reconnected link must reproduce exactly.
    fn shape(&self) -> (u64, u64, u64, u64, u64, u64) {
        (self.m, self.d, self.slice_id, self.n_slices, self.start, self.end)
    }
}

/// Dial `addr`, SUBSCRIBE (posterior scope), and validate the sync
/// reply.  The reply must carry the slice's full θ — a header-only sync
/// is a predict-session artifact and is rejected here.
fn connect_subscribe(addr: &str, retry: &RetryPolicy) -> Result<Subscription> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connect posterior subscription to {addr}"))?;
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(retry.write_timeout));
    let _ = stream.set_read_timeout(Some(retry.handshake_timeout));
    wire::write_frame(
        &mut stream,
        &Frame::Subscribe { proto: PROTO_VERSION, scope: SUBSCRIBE_POSTERIOR },
    )
    .context("send SUBSCRIBE")?;
    let mut scratch = Vec::new();
    // The sync reply carries θ, so it reads under the full frame cap
    // (unlike HELLO-side handshakes the server is the trusted party
    // here — the client dialed it).
    let frame = wire::read_frame(&mut stream, &mut scratch)
        .with_context(|| format!("read POSTERIOR-SYNC from {addr}"))?;
    match frame {
        Frame::PosteriorSync { m, d, slice_id, n_slices, start, end, version, meta, theta } => {
            ensure!(
                !theta.is_empty(),
                "{addr}: header-only sync on a posterior subscription"
            );
            Ok(Subscription {
                stream,
                m,
                d,
                slice_id,
                n_slices,
                start,
                end,
                version,
                meta,
                theta,
            })
        }
        Frame::Error { code, message } => Err(Rejected { code, message })
            .with_context(|| format!("{addr} rejected the subscription")),
        Frame::Reject { code, message, .. } => {
            bail!("{addr} rejected the subscription (code {code}: {message})")
        }
        f => bail!("{addr}: expected POSTERIOR-SYNC, got kind {:#04x}", f.kind()),
    }
}

/// How one subscription pump ended.
enum SubEnd {
    /// The trainer announced SHUTDOWN — the posterior is final.
    Shutdown,
    /// The link died; the supervisor decides whether backoff buys a
    /// repair.
    LinkDead,
}

/// Decode one subscription link's POSTERIOR-SYNC stream into its slice
/// [`Published`] until the run ends or the link dies — the replica twin
/// of the sharded worker's `pump_slice`.
fn pump_subscription(
    r: &mut TcpStream,
    addr: &str,
    shape: (u64, u64, u64, u64, u64, u64),
    slice_pub: &Published,
    pong_w: &Mutex<TcpStream>,
    heartbeat: Duration,
) -> SubEnd {
    let mut scratch = Vec::new();
    let _ = r.set_read_timeout(Some(heartbeat));
    let mut pinged = false;
    loop {
        let frame = match wire::read_frame_event(r, &mut scratch, MAX_FRAME_LEN) {
            Ok(ReadEvent::Frame(f)) => {
                pinged = false;
                f
            }
            Ok(ReadEvent::IdleTimeout) => {
                if pinged || send_frame(pong_w, &Frame::Ping).is_err() {
                    log_warn!(
                        "serve::replica: θ server {addr} silent through PING + grace — \
                         treating the subscription as dead"
                    );
                    return SubEnd::LinkDead;
                }
                pinged = true;
                continue;
            }
            Ok(ReadEvent::Eof) => return SubEnd::LinkDead,
            Err(e) => {
                log_warn!("serve::replica: subscription to {addr} ended: {e:#}");
                return SubEnd::LinkDead;
            }
        };
        match frame {
            Frame::PosteriorSync {
                m,
                d,
                slice_id,
                n_slices,
                start,
                end,
                version,
                meta,
                theta,
            } => {
                if (m, d, slice_id, n_slices, start, end) != shape || theta.is_empty() {
                    log_warn!(
                        "serve::replica: {addr} sent a sync disagreeing with its \
                         handshake (slice {slice_id}/{n_slices} @ [{start}, {end}))"
                    );
                    return SubEnd::LinkDead;
                }
                slice_pub.publish_meta(version, theta, meta);
            }
            Frame::Ping => {
                let _ = send_frame(pong_w, &Frame::Pong);
            }
            Frame::Pong => {}
            Frame::Shutdown => return SubEnd::Shutdown,
            Frame::Error { code, message } => {
                log_warn!(
                    "serve::replica: θ server {addr} answered ERROR {code} ({message})"
                );
                return SubEnd::LinkDead;
            }
            f => {
                log_warn!(
                    "serve::replica: unexpected frame kind {:#04x} from {addr}",
                    f.kind()
                );
                return SubEnd::LinkDead;
            }
        }
    }
}

pub(crate) fn send_frame(w: &Mutex<TcpStream>, f: &Frame) -> std::io::Result<()> {
    use std::io::Write;
    w.lock().unwrap().write_all(&f.encode())
}

/// Sleep in 20 ms polls, aborting when the replica is torn down.
pub(crate) fn sleep_poll(d: Duration, over: &AtomicBool) -> bool {
    let deadline = Instant::now() + d;
    while Instant::now() < deadline {
        if over.load(Ordering::SeqCst) {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    over.load(Ordering::SeqCst)
}

/// Shared state of the predict listener and its per-connection handlers.
struct PredictCtx {
    layout: ThetaLayout,
    cache: Arc<PosteriorCache>,
    /// Handle into the shared [`BatchServer`]; taken at teardown so the
    /// serve loop can drain and exit (a clone held here forever would
    /// deadlock `BatchServer::join`).
    client: Mutex<Option<ServeClient>>,
    health: Arc<LinkHealth>,
    over: Arc<AtomicBool>,
    inflight: AtomicUsize,
    rejects: RejectCounters,
    cfg: ReplicaConfig,
    /// Live sockets (subscriptions + predict sessions) torn down with
    /// the replica so no pump outlives it.
    conns: Mutex<Vec<Arc<Mutex<TcpStream>>>>,
}

impl PredictCtx {
    fn register(&self, s: &TcpStream) -> Option<Arc<Mutex<TcpStream>>> {
        let w = Arc::new(Mutex::new(s.try_clone().ok()?));
        self.conns.lock().unwrap().push(Arc::clone(&w));
        Some(w)
    }
}

/// A running serving replica.  `start` subscribes, assembles, and
/// listens; `shutdown` tears every thread down and returns the serving
/// report.
pub struct Replica {
    addr: SocketAddr,
    cache: Arc<PosteriorCache>,
    assembled: Arc<Published>,
    ctx: Arc<PredictCtx>,
    server: BatchServer,
    /// Current socket of each subscription link (supervisors swap in
    /// the reconnected stream) — severed at teardown so no pump waits
    /// out a heartbeat window.
    sub_writers: Vec<Arc<Mutex<TcpStream>>>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Replica {
    /// Subscribe to the slice servers at `subscribe_addrs` (one per θ
    /// slice, any order), validate that their announced slices tile θ,
    /// and start serving PREDICT sessions on `listen` (port 0 for an
    /// ephemeral port — read it back from [`Replica::predict_addr`]).
    pub fn start(listen: &str, subscribe_addrs: &[String], cfg: ReplicaConfig) -> Result<Self> {
        ensure!(!subscribe_addrs.is_empty(), "no slice servers to subscribe to");
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("bind replica predict listener on {listen}"))?;
        let addr = listener.local_addr().context("replica listener address")?;

        // ---- subscribe to every slice and validate the tiling ----
        let mut subs: Vec<(String, Subscription)> = Vec::with_capacity(subscribe_addrs.len());
        for a in subscribe_addrs {
            subs.push((a.clone(), connect_subscribe(a, &cfg.retry)?));
        }
        let (m, d) = (subs[0].1.m, subs[0].1.d);
        let layout = ThetaLayout::new(m as usize, d as usize);
        for (a, s) in &subs {
            ensure!(
                (s.m, s.d) == (m, d),
                "{a} announces layout ({}, {}) but {} announced ({m}, {d})",
                s.m,
                s.d,
                subscribe_addrs[0]
            );
            ensure!(
                s.n_slices as usize == subs.len(),
                "{a} is slice {}/{} but {} servers were given",
                s.slice_id,
                s.n_slices,
                subs.len()
            );
        }
        // Sort by slice id; ids must be exactly 0..S and the ranges
        // must tile θ — the same agreement checks the sharded worker
        // runs on its WELCOME2s.
        subs.sort_by_key(|(_, s)| s.slice_id);
        let mut ranges = Vec::with_capacity(subs.len());
        let mut cursor = 0u64;
        for (i, (a, s)) in subs.iter().enumerate() {
            ensure!(
                s.slice_id == i as u64,
                "duplicate or missing slice id: {a} is slice {} (expected {i})",
                s.slice_id
            );
            ensure!(
                s.start == cursor && s.end > s.start,
                "{a}: slice {} is [{}, {}) but the tiling cursor is at {cursor}",
                i,
                s.start,
                s.end
            );
            cursor = s.end;
            ranges.push(s.start as usize..s.end as usize);
        }
        ensure!(
            cursor as usize == layout.len(),
            "slices tile only {cursor} of {} θ coordinates",
            layout.len()
        );
        let topology = Topology { dim: layout.len(), ranges };

        // ---- assemble: slice views → version-vector-floor view ----
        let mut theta0 = vec![0.0f64; layout.len()];
        for (_, s) in &subs {
            theta0[s.start as usize..s.end as usize].copy_from_slice(&s.theta);
        }
        let assembled = Published::new(theta0.clone());
        let sharded =
            Arc::new(ShardedPublished::new(topology, &theta0, Arc::clone(&assembled)));
        let floor = subs.iter().map(|(_, s)| s.version).min().unwrap_or(0);
        let floor_meta = subs
            .iter()
            .map(|(_, s)| (s.version, s.meta))
            .min_by_key(|(v, _)| *v)
            .map(|(_, m)| m)
            .unwrap_or_default();
        for ((_, s), p) in subs.iter().zip(&sharded.slices) {
            if s.version > 0 {
                p.publish_meta(s.version, s.theta.clone(), s.meta);
            }
        }
        if floor > 0 {
            assembled.publish_meta(floor, theta0.clone(), floor_meta);
        }
        let cache = Arc::new(PosteriorCache::new(layout));
        cache.install(floor, &theta0);

        let health = Arc::new(LinkHealth::new(subs.len()));
        let over = Arc::new(AtomicBool::new(false));
        let mut threads = Vec::new();

        // ---- per-link pump + reconnect supervisor threads ----
        let mut sub_writers = Vec::with_capacity(subs.len());
        for (i, (a, sub)) in subs.into_iter().enumerate() {
            let slice_pub = Arc::clone(&sharded.slices[i]);
            let health = Arc::clone(&health);
            let over = Arc::clone(&over);
            let retry = cfg.retry;
            let writer = Arc::new(Mutex::new(
                sub.stream.try_clone().context("clone subscription stream")?,
            ));
            sub_writers.push(Arc::clone(&writer));
            threads.push(
                std::thread::Builder::new()
                    .name(format!("advgp-sub-{i}"))
                    .spawn(move || {
                        supervise_subscription(
                            sub, i, a, slice_pub, writer, health, over, retry,
                        )
                    })
                    .context("spawn subscription supervisor")?,
            );
        }

        // ---- assembler thread (draining — final version survives) ----
        {
            let sharded = Arc::clone(&sharded);
            threads.push(
                std::thread::Builder::new()
                    .name("advgp-assemble".into())
                    .spawn(move || run_assembler_draining(&sharded))
                    .context("spawn assembler")?,
            );
        }

        // ---- posterior refresher: keep the cache hot while idle ----
        // The batch server also syncs before every flush; this thread
        // covers the idle case (no traffic) and moves the O(m³) build
        // off the serve thread's critical path.  Draining wait, so the
        // final version is installed even when it races shutdown.
        {
            let cache = Arc::clone(&cache);
            let a = Arc::clone(&assembled);
            threads.push(
                std::thread::Builder::new()
                    .name("advgp-refresh".into())
                    .spawn(move || {
                        let mut seen = 0u64;
                        while let Some((v, th, _)) = a.wait_newer_draining(seen) {
                            cache.install(v, &th);
                            seen = v;
                        }
                    })
                    .context("spawn posterior refresher")?,
            );
        }

        // ---- batch server + predict listener ----
        let (server, client) = BatchServer::start(
            Arc::clone(&cache),
            Some(Arc::clone(&assembled)),
            cfg.batch.clone(),
        );
        let ctx = Arc::new(PredictCtx {
            layout,
            cache: Arc::clone(&cache),
            client: Mutex::new(Some(client)),
            health: Arc::clone(&health),
            over: Arc::clone(&over),
            inflight: AtomicUsize::new(0),
            rejects: RejectCounters::default(),
            cfg,
            conns: Mutex::new(Vec::new()),
        });
        {
            let ctx = Arc::clone(&ctx);
            threads.push(
                std::thread::Builder::new()
                    .name("advgp-predict-accept".into())
                    .spawn(move || accept_predicts(listener, ctx))
                    .context("spawn predict accept loop")?,
            );
        }
        log_info!(
            "serve::replica: serving predicts on {addr} (θ v{floor}, {} slices)",
            sharded.topology.n_slices()
        );
        Ok(Self { addr, cache, assembled, ctx, server, sub_writers, threads })
    }

    /// Where PREDICT sessions connect.
    pub fn predict_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The replica's posterior cache — the τ=0 parity tests compare
    /// this against the trainer-side cache bitwise.
    pub fn cache(&self) -> Arc<PosteriorCache> {
        Arc::clone(&self.cache)
    }

    /// θ version of the currently-served posterior.
    pub fn version(&self) -> Option<u64> {
        self.cache.version()
    }

    /// Poll (20 ms) until the served posterior reaches version `v` or
    /// `timeout` elapses; true on success.  Test/benchmark helper.
    pub fn wait_version(&self, v: u64, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while Instant::now() < deadline {
            if self.cache.version().is_some_and(|got| got >= v) {
                return true;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        self.cache.version().is_some_and(|got| got >= v)
    }

    /// REJECT tallies so far (typed-degradation evidence).
    pub fn rejects(&self) -> &RejectCounters {
        &self.ctx.rejects
    }

    /// Block until the training fleet announced a clean end (true) or
    /// `timeout` elapsed (false).  The replica keeps serving its final
    /// posterior either way — this is how `advgp serve-replica` knows
    /// when its `--linger-secs` clock may start.
    pub fn wait_trainer_end(&self, timeout: Duration) -> bool {
        self.assembled.shutdown_or_timeout(timeout)
    }

    /// Tear the replica down: stop accepting, sever every session and
    /// subscription, and return the batch server's report.
    pub fn shutdown(mut self) -> ServeReport {
        self.ctx.over.store(true, Ordering::SeqCst);
        // End the assembled view so the refresher unwinds even if the
        // assembler is already gone.
        self.assembled.shutdown();
        // Sever the subscription sockets (unblocks the pump reads) and
        // every predict session (unblocks the handlers, which then drop
        // their ServeClient clones).
        for w in &self.sub_writers {
            let _ = w.lock().unwrap().shutdown(std::net::Shutdown::Both);
        }
        for w in self.ctx.conns.lock().unwrap().iter() {
            let _ = w.lock().unwrap().shutdown(std::net::Shutdown::Both);
        }
        drop(self.ctx.client.lock().unwrap().take());
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
        self.server.join()
    }
}

/// One subscription link's lifetime: pump until the run ends, repairing
/// the link with jittered backoff through transient outages.  On a dead
/// budget the link stays down (staleness clock running) — the replica
/// keeps serving its last posterior, degrading typed, instead of dying.
#[allow(clippy::too_many_arguments)]
fn supervise_subscription(
    sub: Subscription,
    i: usize,
    addr: String,
    slice_pub: Arc<Published>,
    writer: Arc<Mutex<TcpStream>>,
    health: Arc<LinkHealth>,
    over: Arc<AtomicBool>,
    retry: RetryPolicy,
) {
    let shape = sub.shape();
    // Deterministic per-(address, slice) jitter stream, mirroring the
    // sharded worker's seeding.
    let mut rng =
        Pcg64::seeded(fnv1a64(FNV1A64_INIT, addr.as_bytes()) ^ sub.slice_id);
    let mut reader = sub.stream;
    'session: loop {
        match pump_subscription(
            &mut reader,
            &addr,
            shape,
            &slice_pub,
            &writer,
            retry.heartbeat,
        ) {
            SubEnd::Shutdown => {
                health.mark_clean();
                log_info!(
                    "serve::replica: θ server {addr} announced SHUTDOWN — \
                     serving the final posterior from here on"
                );
                break 'session;
            }
            SubEnd::LinkDead => {}
        }
        health.mark_down(i);
        if over.load(Ordering::SeqCst) {
            break 'session;
        }
        let mut attempt = 0u32;
        reader = loop {
            if attempt >= retry.reconnect.max_retries {
                log_warn!(
                    "serve::replica: subscription to {addr} lost and the reconnect \
                     budget is exhausted — serving stale until the staleness budget \
                     runs out"
                );
                break 'session;
            }
            let delay = retry.reconnect.delay(attempt, &mut rng);
            attempt += 1;
            if sleep_poll(delay, &over) {
                break 'session;
            }
            let s = match connect_subscribe(&addr, &retry) {
                Ok(s) => s,
                Err(e) => {
                    log_warn!("serve::replica: resubscribe to {addr} failed: {e:#}");
                    continue;
                }
            };
            if s.shape() != shape {
                log_warn!(
                    "serve::replica: {addr} no longer matches the fleet \
                     (layout/slice/topology changed) — abandoning the subscription"
                );
                break 'session;
            }
            let Ok(w) = s.stream.try_clone() else { continue };
            // Resume at the newest θ the server holds — the handshake
            // sync carries it, so the assembled floor can advance past
            // the outage without waiting for the next training update.
            if s.version > 0 {
                slice_pub.publish_meta(s.version, s.theta, s.meta);
            }
            *writer.lock().unwrap() = w;
            health.mark_up(i);
            log_info!(
                "serve::replica: subscription to {addr} re-established (θ v{})",
                s.version
            );
            break s.stream;
        };
    }
    // Session over for this slice: end its view so the (draining)
    // assembler unwinds once every slice is done.
    slice_pub.shutdown();
}

/// Accept PREDICT sessions until teardown (non-blocking accept with a
/// 50 ms poll, like the parameter server's accept loop).
fn accept_predicts(listener: TcpListener, ctx: Arc<PredictCtx>) {
    let nonblocking = listener.set_nonblocking(true).is_ok();
    loop {
        match listener.accept() {
            Ok((s, _peer)) => {
                if ctx.over.load(Ordering::SeqCst) {
                    break;
                }
                if s.set_nonblocking(false).is_err() {
                    continue;
                }
                let ctx = Arc::clone(&ctx);
                std::thread::spawn(move || handle_predict_conn(s, ctx));
            }
            Err(e) if nonblocking && e.kind() == std::io::ErrorKind::WouldBlock => {
                if ctx.over.load(Ordering::SeqCst) {
                    break;
                }
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(e) => {
                if ctx.over.load(Ordering::SeqCst) {
                    break;
                }
                log_warn!("serve::replica: accept failed: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// One PREDICT session, replica side: SUBSCRIBE(predict) → header-only
/// POSTERIOR-SYNC ack, then answer each PREDICT with a PREDICTION or a
/// typed REJECT.  REJECTs are per-request: the session survives them.
fn handle_predict_conn(stream: TcpStream, ctx: Arc<PredictCtx>) {
    // Clone the batch-server handle up front; `None` means the replica
    // is already tearing down.
    let Some(client) = ctx.client.lock().unwrap().clone() else { return };
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(ctx.cfg.retry.write_timeout));
    let _ = stream.set_read_timeout(Some(ctx.cfg.retry.handshake_timeout));
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into());
    let Some(writer) = ctx.register(&stream) else { return };
    let mut reader = stream;
    let mut scratch = Vec::new();
    let first = wire::read_frame_capped(&mut reader, &mut scratch, MAX_HANDSHAKE_FRAME_LEN);
    match first {
        Ok(Frame::Subscribe { proto, scope }) if proto >= PROTO_NT2 => {
            if scope != SUBSCRIBE_PREDICT {
                // A replica holds an assembled posterior, not a θ-slice
                // publish stream: posterior subscriptions belong on the
                // slice servers.
                let _ = send_frame(
                    &writer,
                    &Frame::Reject {
                        id: 0,
                        code: REJ_BAD_SCOPE,
                        message: "replicas serve predict sessions; subscribe to the \
                                  θ-slice servers for posterior streams"
                            .into(),
                    },
                );
                return;
            }
        }
        Ok(Frame::Subscribe { .. }) => {
            let msg = format!("predict sessions require rev {PROTO_NT2}");
            let _ = send_frame(&writer, &Frame::Error { code: ERR_PROTO, message: msg });
            return;
        }
        Ok(f) => {
            let msg = format!("expected SUBSCRIBE, got kind {:#04x}", f.kind());
            let _ = send_frame(&writer, &Frame::Error { code: ERR_MALFORMED, message: msg });
            return;
        }
        Err(e) => {
            let msg = format!("bad SUBSCRIBE: {e:#}");
            let _ = send_frame(&writer, &Frame::Error { code: ERR_MALFORMED, message: msg });
            return;
        }
    }
    // Handshake ack: a header-only sync carrying (m, d, version) — the
    // client learns the feature dimension without shipping θ.
    let (m, d) = (ctx.layout.m as u64, ctx.layout.d as u64);
    let ack = Frame::PosteriorSync {
        m,
        d,
        slice_id: 0,
        n_slices: 1,
        start: 0,
        end: ctx.layout.len() as u64,
        version: ctx.cache.version().unwrap_or(0),
        meta: PublishMeta::default(),
        theta: vec![],
    };
    if send_frame(&writer, &ack).is_err() {
        return;
    }
    let _ = reader.set_read_timeout(Some(ctx.cfg.retry.heartbeat));
    let mut pinged = false;
    let reject = |id: u64, code: u16, message: String| {
        ctx.rejects.bump(code);
        send_frame(&writer, &Frame::Reject { id, code, message })
    };
    loop {
        let frame = match wire::read_frame_event(&mut reader, &mut scratch, MAX_FRAME_LEN) {
            Ok(ReadEvent::Frame(f)) => {
                pinged = false;
                f
            }
            Ok(ReadEvent::IdleTimeout) => {
                if pinged || send_frame(&writer, &Frame::Ping).is_err() {
                    log_warn!(
                        "serve::replica: predict client {peer} silent through PING + \
                         grace — dropping the session"
                    );
                    break;
                }
                pinged = true;
                continue;
            }
            Ok(ReadEvent::Eof) => break,
            Err(e) => {
                let msg = format!("malformed stream: {e:#}");
                let _ = send_frame(&writer, &Frame::Error { code: ERR_MALFORMED, message: msg });
                break;
            }
        };
        match frame {
            Frame::Predict { id, d: want_d, rows } => {
                let k = rows.len() / want_d.max(1) as usize;
                // ---- admission control: typed per-request verdicts ----
                if want_d != d {
                    let _ = reject(
                        id,
                        REJ_BAD_DIM,
                        format!("inputs are {want_d}-dimensional but the model takes {d}"),
                    );
                    continue;
                }
                if let Some(stale) = ctx.health.stale_for() {
                    if stale > ctx.cfg.staleness_budget {
                        let _ = reject(
                            id,
                            REJ_STALE,
                            format!(
                                "posterior stale for {:.1}s (budget {:.1}s) — \
                                 subscription down",
                                stale.as_secs_f64(),
                                ctx.cfg.staleness_budget.as_secs_f64()
                            ),
                        );
                        continue;
                    }
                }
                if ctx.cache.get().is_none() {
                    let _ = reject(id, REJ_NOT_READY, "no posterior installed yet".into());
                    continue;
                }
                let admitted = ctx.inflight.fetch_add(k, Ordering::SeqCst) + k;
                if admitted > ctx.cfg.max_inflight_rows {
                    ctx.inflight.fetch_sub(k, Ordering::SeqCst);
                    let _ = reject(
                        id,
                        REJ_OVERLOAD,
                        format!(
                            "{admitted} rows in flight exceeds the admission ceiling {}",
                            ctx.cfg.max_inflight_rows
                        ),
                    );
                    continue;
                }
                // ---- admitted: microbatch through the shared server ----
                let receivers: Option<Vec<_>> =
                    rows.chunks_exact(d as usize).map(|row| client.submit(row)).collect();
                let Some(receivers) = receivers else {
                    ctx.inflight.fetch_sub(k, Ordering::SeqCst);
                    break; // batch server gone: the replica is tearing down
                };
                let mut mean = Vec::with_capacity(k);
                let mut var = Vec::with_capacity(k);
                let mut version = u64::MAX;
                let mut dead = false;
                for rx in receivers {
                    match rx.recv() {
                        Ok(p) => {
                            mean.push(p.mean);
                            var.push(p.var);
                            // A batch can straddle an install; report
                            // the floor so the client never overclaims
                            // freshness.
                            version = version.min(p.version);
                        }
                        Err(_) => {
                            dead = true;
                            break;
                        }
                    }
                }
                ctx.inflight.fetch_sub(k, Ordering::SeqCst);
                if dead {
                    break;
                }
                let answer = Frame::Prediction { id, version, mean, var };
                if send_frame(&writer, &answer).is_err() {
                    break;
                }
            }
            Frame::Ping => {
                let _ = send_frame(&writer, &Frame::Pong);
            }
            Frame::Pong => {}
            Frame::Error { code, message } => {
                log_warn!(
                    "serve::replica: predict client {peer} sent error {code}: {message}"
                );
                break;
            }
            f => {
                let msg = format!("unexpected kind {:#04x} on a predict session", f.kind());
                let _ = send_frame(&writer, &Frame::Error { code: ERR_MALFORMED, message: msg });
                break;
            }
        }
    }
    let _ = reader.shutdown(std::net::Shutdown::Both);
}

/// One answered PREDICT, client side.
#[derive(Clone, Debug)]
pub enum PredictAnswer {
    /// The posterior answer: θ version, predictive means, predictive
    /// variances (one per input row).
    Prediction { version: u64, mean: Vec<f64>, var: Vec<f64> },
    /// Admission control said no (typed, non-fatal).
    Rejected { code: u16, message: String },
}

/// The client half of a PREDICT session — used by `advgp loadgen`, the
/// chaos suite, and any external caller.  [`PredictClient::predict`] is
/// the simple lock-step form; [`PredictClient::into_split`] yields
/// independently-owned send/receive halves for pipelined open-loop
/// traffic.
pub struct PredictClient {
    reader: TcpStream,
    writer: TcpStream,
    scratch: Vec<u8>,
    next_id: u64,
    /// Model layout announced in the handshake ack.
    pub m: usize,
    pub d: usize,
    /// θ version at handshake time.
    pub version: u64,
    /// Latest ROUTE-STATUS absorbed: `(fleet_version, replicas)`.
    /// Routers (ADVGPRT1) send these unsolicited; direct replicas
    /// never do, so `None` means "talking straight to a replica".
    pub route_status: Option<(u64, Vec<wire::ReplicaStatus>)>,
}

impl PredictClient {
    /// Dial a replica and run the SUBSCRIBE(predict) handshake.
    pub fn connect(addr: &str) -> Result<Self> {
        let mut reader = TcpStream::connect(addr)
            .with_context(|| format!("connect predict session to {addr}"))?;
        let _ = reader.set_nodelay(true);
        let _ = reader.set_read_timeout(Some(Duration::from_secs(10)));
        wire::write_frame(
            &mut reader,
            &Frame::Subscribe { proto: PROTO_VERSION, scope: SUBSCRIBE_PREDICT },
        )
        .context("send SUBSCRIBE")?;
        let mut scratch = Vec::new();
        let ack = wire::read_frame_capped(&mut reader, &mut scratch, MAX_HANDSHAKE_FRAME_LEN)
            .with_context(|| format!("read predict handshake ack from {addr}"))?;
        let (m, d, version) = match ack {
            Frame::PosteriorSync { m, d, version, theta, .. } => {
                ensure!(theta.is_empty(), "predict ack carried θ");
                (m, d, version)
            }
            Frame::Error { code, message } => {
                return Err(Rejected { code, message })
                    .with_context(|| format!("{addr} rejected the predict session"))
            }
            Frame::Reject { code, message, .. } => {
                bail!("{addr} rejected the predict session (code {code}: {message})")
            }
            f => bail!("{addr}: expected a sync ack, got kind {:#04x}", f.kind()),
        };
        let _ = reader.set_read_timeout(None);
        let writer = reader.try_clone().context("clone predict stream")?;
        Ok(Self {
            reader,
            writer,
            scratch,
            next_id: 0,
            m: m as usize,
            d: d as usize,
            version,
            route_status: None,
        })
    }

    /// Arm (or clear) a read timeout on answers.  With a timeout armed,
    /// a peer that goes silent mid-request turns into an `Err` from
    /// [`PredictClient::recv`] instead of a hung thread — the router
    /// uses this to bound every hop before failing over to a sibling.
    pub fn set_answer_timeout(&self, timeout: Option<Duration>) -> std::io::Result<()> {
        self.reader.set_read_timeout(timeout)
    }

    /// Clone the underlying stream handle, so a supervisor can sever a
    /// read this client's owner is blocked in from another thread at
    /// teardown.
    pub fn try_clone_stream(&self) -> std::io::Result<TcpStream> {
        self.reader.try_clone()
    }

    /// Liveness probe: send PING and wait for the PONG, absorbing
    /// whatever arrives first (peer PINGs are answered, ROUTE-STATUS is
    /// recorded).  Arm [`PredictClient::set_answer_timeout`] first so a
    /// wedged peer fails the probe instead of blocking it forever.
    pub fn ping(&mut self) -> Result<()> {
        wire::write_frame(&mut self.writer, &Frame::Ping).context("send PING")?;
        loop {
            let frame = wire::read_frame(&mut self.reader, &mut self.scratch)
                .context("await PONG")?;
            match frame {
                Frame::Pong => return Ok(()),
                Frame::Ping => {
                    wire::write_frame(&mut self.writer, &Frame::Pong)
                        .context("answer PING")?;
                }
                Frame::RouteStatus { fleet_version, replicas } => {
                    self.route_status = Some((fleet_version, replicas));
                }
                Frame::Error { code, message } => {
                    bail!("peer answered ERROR {code}: {message}")
                }
                Frame::Shutdown => bail!("peer shut the session down"),
                // Stray answers (e.g. from a prior timed-out request)
                // are stale here — drop them and keep waiting.
                Frame::Prediction { .. } | Frame::Reject { .. } => {}
                f => bail!("unexpected kind {:#04x} on a predict session", f.kind()),
            }
        }
    }

    /// Send one PREDICT (rows row-major, `rows.len() % d == 0`) without
    /// waiting; returns the request id to correlate the answer.
    pub fn send(&mut self, rows: &[f64]) -> Result<u64> {
        ensure!(
            !rows.is_empty() && rows.len() % self.d == 0,
            "{} values is not a whole number of {}-dim rows",
            rows.len(),
            self.d
        );
        let id = self.next_id;
        self.next_id += 1;
        wire::write_frame(
            &mut self.writer,
            &Frame::Predict { id, d: self.d as u64, rows: rows.to_vec() },
        )
        .context("send PREDICT")?;
        Ok(id)
    }

    /// Receive the next answer (answers arrive in request order on a
    /// session — the replica handler is sequential per connection).
    pub fn recv(&mut self) -> Result<(u64, PredictAnswer)> {
        loop {
            let frame = wire::read_frame(&mut self.reader, &mut self.scratch)
                .context("read prediction")?;
            match frame {
                Frame::Prediction { id, version, mean, var } => {
                    return Ok((id, PredictAnswer::Prediction { version, mean, var }))
                }
                Frame::Reject { id, code, message } => {
                    return Ok((id, PredictAnswer::Rejected { code, message }))
                }
                Frame::Ping => {
                    wire::write_frame(&mut self.writer, &Frame::Pong)
                        .context("answer PING")?;
                }
                Frame::Pong => {}
                Frame::RouteStatus { fleet_version, replicas } => {
                    // Fleet observability from a router — record and
                    // keep waiting for the answer (ADVGPRT1: clients
                    // must absorb ROUTE-STATUS at any point after the
                    // handshake).
                    self.route_status = Some((fleet_version, replicas));
                }
                Frame::Error { code, message } => {
                    bail!("replica answered ERROR {code}: {message}")
                }
                Frame::Shutdown => bail!("replica shut the session down"),
                f => bail!("unexpected kind {:#04x} on a predict session", f.kind()),
            }
        }
    }

    /// Lock-step predict: send one batch, wait for its answer.
    pub fn predict(&mut self, rows: &[f64]) -> Result<PredictAnswer> {
        let want = self.send(rows)?;
        let (id, answer) = self.recv()?;
        ensure!(id == want, "answer for request {id}, expected {want}");
        Ok(answer)
    }

    /// Split into independently-owned halves for pipelined traffic
    /// (sender thread + receiver thread, correlated by request id).
    pub fn into_split(self) -> (PredictSender, PredictReceiver) {
        (
            PredictSender { writer: self.writer, d: self.d, next_id: self.next_id },
            PredictReceiver {
                reader: self.reader,
                scratch: self.scratch,
                route_status: self.route_status,
            },
        )
    }
}

/// The send half of a split [`PredictClient`].
pub struct PredictSender {
    writer: TcpStream,
    d: usize,
    next_id: u64,
}

impl PredictSender {
    pub fn send(&mut self, rows: &[f64]) -> Result<u64> {
        ensure!(
            !rows.is_empty() && rows.len() % self.d == 0,
            "{} values is not a whole number of {}-dim rows",
            rows.len(),
            self.d
        );
        let id = self.next_id;
        self.next_id += 1;
        wire::write_frame(
            &mut self.writer,
            &Frame::Predict { id, d: self.d as u64, rows: rows.to_vec() },
        )
        .context("send PREDICT")?;
        Ok(id)
    }

    /// Half-close the send direction: the replica sees EOF after the
    /// in-flight answers drain, ending the session cleanly.
    pub fn finish(self) {
        let _ = self.writer.shutdown(std::net::Shutdown::Write);
    }
}

/// The receive half of a split [`PredictClient`].
pub struct PredictReceiver {
    reader: TcpStream,
    scratch: Vec<u8>,
    /// Latest ROUTE-STATUS absorbed on this half (see
    /// [`PredictClient::route_status`]).
    pub route_status: Option<(u64, Vec<wire::ReplicaStatus>)>,
}

impl PredictReceiver {
    /// Next answer, or `None` on a clean end-of-session.
    pub fn recv(&mut self) -> Result<Option<(u64, PredictAnswer)>> {
        loop {
            let frame =
                match wire::read_frame_opt(&mut self.reader, &mut self.scratch)? {
                    Some(f) => f,
                    None => return Ok(None),
                };
            match frame {
                Frame::Prediction { id, version, mean, var } => {
                    return Ok(Some((id, PredictAnswer::Prediction { version, mean, var })))
                }
                Frame::Reject { id, code, message } => {
                    return Ok(Some((id, PredictAnswer::Rejected { code, message })))
                }
                Frame::Ping | Frame::Pong => {} // receive half can't answer; harmless
                Frame::RouteStatus { fleet_version, replicas } => {
                    self.route_status = Some((fleet_version, replicas));
                }
                Frame::Error { code, message } => {
                    bail!("replica answered ERROR {code}: {message}")
                }
                Frame::Shutdown => return Ok(None),
                f => bail!("unexpected kind {:#04x} on a predict session", f.kind()),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The staleness clock: starts on the first down link, survives
    /// partial repair, clears on full repair, and is permanently
    /// silenced by a clean shutdown.
    #[test]
    fn link_health_staleness_clock() {
        let h = LinkHealth::new(2);
        assert!(h.stale_for().is_none(), "healthy fleet is not stale");
        h.mark_down(0);
        assert!(h.stale_for().is_some());
        h.mark_down(1);
        h.mark_up(0);
        assert!(h.stale_for().is_some(), "one link still down");
        h.mark_up(1);
        assert!(h.stale_for().is_none(), "full repair clears the clock");
        h.mark_down(0);
        h.mark_clean();
        assert!(h.stale_for().is_none(), "a finished model is final, not stale");
        h.mark_down(1);
        assert!(h.stale_for().is_none(), "post-shutdown link loss is expected");
    }

    /// REJECT tallies land on their own counters.
    #[test]
    fn reject_counters_tally_by_code() {
        let c = RejectCounters::default();
        c.bump(REJ_STALE);
        c.bump(REJ_STALE);
        c.bump(REJ_OVERLOAD);
        c.bump(REJ_BAD_DIM);
        c.bump(999); // unknown codes are ignored, not miscounted
        assert_eq!(c.stale.load(Ordering::Relaxed), 2);
        assert_eq!(c.overload.load(Ordering::Relaxed), 1);
        assert_eq!(c.bad_dim.load(Ordering::Relaxed), 1);
        assert_eq!(c.not_ready.load(Ordering::Relaxed), 0);
        assert_eq!(c.total(), 4);
    }
}
