//! The predict-side routing tier (ADVGPRT1, ISSUE 9): one address in
//! front of the ADVGPSV1 replica fleet.
//!
//! A [`Router`] accepts PREDICT sessions on the same rev-2 wire a
//! replica does — [`super::PredictClient`] cannot tell the difference
//! except for the extra ROUTE-STATUS frame pushed after the handshake —
//! and spreads per-request work over N replicas:
//!
//! * **Balancing** is power-of-two-choices on in-flight rows: each
//!   request draws two distinct live legs from a per-session seeded
//!   [`Pcg64`] stream and keeps the emptier one (first draw wins ties).
//!   Same seed + same session order ⇒ same leg choices, which is what
//!   makes routed fault traces replayable in the chaos suite.
//! * **Retry** is transparent for *replica-state* verdicts: a
//!   `REJECT(REJ_OVERLOAD)`/`REJECT(REJ_STALE)` or a dead leg link is
//!   absorbed and the request re-sent to an untried sibling, up to
//!   [`RouterConfig::retry_hops`] extra attempts.  *Request/fleet*
//!   verdicts (`REJ_BAD_DIM`, `REJ_NOT_READY`, `REJ_BAD_SCOPE`) are
//!   surfaced immediately — a sibling would say the same
//!   ([`crate::ps::wire::reject_is_retryable`] is the normative split).
//! * **Caching**: each leg owns a bounded [`AnswerCache`] keyed by
//!   `(posterior version, FNV-1a(row bytes))`.  A request whose rows
//!   *all* hit at the leg's newest observed version is answered without
//!   touching the replica; any newer version observed on the leg
//!   (handshake, answer, or probe re-handshake) purges every stale
//!   entry, so a cached `(mean, var)` can never be served across a
//!   posterior install.
//! * **Health**: one probe thread per leg holds a PING/PONG session at
//!   the configured heartbeat cadence.  A failed probe retires the leg
//!   (P2C stops drawing it); the probe keeps redialing with jittered
//!   backoff forever and revives the leg on the next good handshake.
//!
//! Answer-preservation contract (pinned by `rust/tests/serve_router.rs`):
//! at a settled posterior version, a routed answer is **bitwise equal**
//! to the direct-replica answer — cache hit or miss, batched or solo —
//! because [`crate::gp::SparseGp`] is a deterministic function of
//! (layout, θ) and the cache stores the replica's own answers under a
//! version-exact key.

use super::replica::{send_frame, sleep_poll, PredictAnswer, PredictClient, RejectCounters};
use crate::gp::ThetaLayout;
use crate::ps::net::RetryPolicy;
use crate::ps::wire::{
    self, reject_is_retryable, Frame, ReadEvent, ReplicaStatus, ERR_MALFORMED, ERR_PROTO,
    MAX_FRAME_LEN, MAX_HANDSHAKE_FRAME_LEN, MAX_ROUTE_REPLICAS, PROTO_NT2, REJ_BAD_DIM,
    REJ_BAD_SCOPE, REJ_NOT_READY, ROUTE_RETIRED, SUBSCRIBE_PREDICT,
};
use crate::ps::PublishMeta;
use crate::util::rng::Pcg64;
use crate::util::{fnv1a64, FNV1A64_INIT};
use crate::{log_info, log_warn};
use anyhow::{ensure, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Answer cache
// ---------------------------------------------------------------------------

/// One cached answer: the full row (compared bitwise on lookup, so a
/// hash collision can never cross-serve another row's answer) and the
/// replica's `(mean, var)` for it.
struct CacheSlot {
    row: Vec<f64>,
    mean: f64,
    var: f64,
}

struct CacheInner {
    /// Newest posterior version observed; every stored slot was
    /// answered at exactly this version.
    version: u64,
    /// Hash → slots (a chain holds colliding rows).
    map: HashMap<u64, Vec<CacheSlot>>,
    /// Insertion order of hashes — FIFO eviction.
    fifo: VecDeque<u64>,
    len: usize,
}

/// Bounded, version-gated answer cache keyed by
/// `(posterior version, hash(row bytes))`.
///
/// Semantics (the satellite property suite in
/// `rust/tests/serve_properties.rs` pins each clause):
/// * a lookup hits **iff** the cache's current version matches the
///   version the row was answered at *and* the stored row is bitwise
///   equal to the queried one (`f64::to_bits`, so `-0.0 ≠ 0.0` and
///   NaN payloads are distinct keys);
/// * inserting (or [`AnswerCache::advance`]-ing to) a **newer** version
///   purges every older entry — stale answers become unreachable, not
///   merely deprioritized; inserts at an **older** version are dropped;
/// * capacity is enforced by FIFO eviction, so the cache can forget an
///   answer but never serve one from the wrong version or the wrong
///   row.
///
/// The production hasher is FNV-1a over the row's little-endian f64
/// bytes; [`AnswerCache::with_hasher`] lets tests inject deliberately
/// colliding hash functions (real 64-bit FNV collisions being
/// infeasible to construct) to exercise the chain + bitwise-compare
/// path.
pub struct AnswerCache {
    cap: usize,
    hasher: fn(&[u8]) -> u64,
    inner: Mutex<CacheInner>,
}

fn fnv_row_hasher(bytes: &[u8]) -> u64 {
    fnv1a64(FNV1A64_INIT, bytes)
}

fn same_row(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

impl AnswerCache {
    /// Cache holding at most `cap` rows (0 disables caching entirely).
    pub fn new(cap: usize) -> Self {
        Self::with_hasher(cap, fnv_row_hasher)
    }

    /// [`AnswerCache::new`] with an injected row-bytes hasher — test
    /// hook for forcing collisions.
    pub fn with_hasher(cap: usize, hasher: fn(&[u8]) -> u64) -> Self {
        Self {
            cap,
            hasher,
            inner: Mutex::new(CacheInner {
                version: 0,
                map: HashMap::new(),
                fifo: VecDeque::new(),
                len: 0,
            }),
        }
    }

    fn key(&self, row: &[f64]) -> u64 {
        let mut bytes = Vec::with_capacity(row.len() * 8);
        for v in row {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        (self.hasher)(&bytes)
    }

    /// Newest posterior version this cache has seen.
    pub fn version(&self) -> u64 {
        self.inner.lock().unwrap().version
    }

    /// Rows currently cached.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Make every entry older than `version` unreachable.  Called when
    /// a newer posterior is observed anywhere on the leg; a no-op for
    /// `version` at or below the current one.
    pub fn advance(&self, version: u64) {
        let mut inner = self.inner.lock().unwrap();
        advance_locked(&mut inner, version);
    }

    /// Exact-match lookup: `Some((version, mean, var))` iff `row` was
    /// answered at the cache's **current** version and is stored
    /// bitwise-equal.
    pub fn get(&self, row: &[f64]) -> Option<(u64, f64, f64)> {
        let h = self.key(row);
        let inner = self.inner.lock().unwrap();
        let slot = inner.map.get(&h)?.iter().find(|s| same_row(&s.row, row))?;
        Some((inner.version, slot.mean, slot.var))
    }

    /// All-or-nothing multi-row lookup under one lock: every row of the
    /// request must hit at a single version or the whole request is a
    /// miss (a half-cached answer would mix versions).
    pub fn get_batch(&self, rows: &[f64], d: usize) -> Option<(u64, Vec<f64>, Vec<f64>)> {
        assert!(d > 0 && rows.len() % d == 0, "ragged rows reached the answer cache");
        let keys: Vec<u64> = rows.chunks_exact(d).map(|r| self.key(r)).collect();
        let inner = self.inner.lock().unwrap();
        let mut mean = Vec::with_capacity(keys.len());
        let mut var = Vec::with_capacity(keys.len());
        for (row, h) in rows.chunks_exact(d).zip(&keys) {
            let slot = inner.map.get(h)?.iter().find(|s| same_row(&s.row, row))?;
            mean.push(slot.mean);
            var.push(slot.var);
        }
        Some((inner.version, mean, var))
    }

    /// Record one answered row.  An insert at a newer version first
    /// purges everything older; an insert at an older version is
    /// dropped (the answer is already stale); a duplicate of a stored
    /// row is a no-op.
    pub fn insert(&self, version: u64, row: &[f64], mean: f64, var: f64) {
        if self.cap == 0 {
            return;
        }
        let h = self.key(row);
        let mut inner = self.inner.lock().unwrap();
        if version < inner.version {
            return;
        }
        advance_locked(&mut inner, version);
        insert_locked(&mut inner, self.cap, h, row, mean, var);
    }

    /// [`AnswerCache::insert`] for a whole answered request.
    pub fn insert_batch(&self, version: u64, rows: &[f64], d: usize, mean: &[f64], var: &[f64]) {
        assert!(d > 0 && rows.len() % d == 0, "ragged rows reached the answer cache");
        assert_eq!(rows.len() / d, mean.len());
        assert_eq!(mean.len(), var.len());
        for (i, row) in rows.chunks_exact(d).enumerate() {
            self.insert(version, row, mean[i], var[i]);
        }
    }
}

fn advance_locked(inner: &mut CacheInner, version: u64) {
    if version > inner.version {
        inner.map.clear();
        inner.fifo.clear();
        inner.len = 0;
        inner.version = version;
    }
}

fn insert_locked(inner: &mut CacheInner, cap: usize, h: u64, row: &[f64], mean: f64, var: f64) {
    let chain = inner.map.entry(h).or_default();
    if chain.iter().any(|s| same_row(&s.row, row)) {
        return;
    }
    chain.push(CacheSlot { row: row.to_vec(), mean, var });
    inner.fifo.push_back(h);
    inner.len += 1;
    while inner.len > cap {
        let Some(old) = inner.fifo.pop_front() else { break };
        if let Some(chain) = inner.map.get_mut(&old) {
            if !chain.is_empty() {
                // chains push to the back, so the front slot is the
                // oldest insert under this hash — FIFO holds even
                // through collisions
                chain.remove(0);
            }
            if chain.is_empty() {
                inner.map.remove(&old);
            }
        }
        inner.len -= 1;
    }
}

// ---------------------------------------------------------------------------
// Router
// ---------------------------------------------------------------------------

/// Router policy knobs.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Timeouts + probe cadence: `heartbeat` paces the per-leg PING
    /// probes (a leg silent through `2×heartbeat` is retired),
    /// `handshake_timeout` bounds every forwarded hop, and `reconnect`
    /// shapes the probe's redial backoff.
    pub retry: RetryPolicy,
    /// Answer-cache capacity per replica leg, in rows (0 disables).
    pub cache_rows: usize,
    /// Extra sibling attempts after the first hop's retryable failure
    /// (retryable REJECT or a dead leg link).
    pub retry_hops: usize,
    /// Seed for the per-session P2C draw streams (session `k` draws
    /// from `Pcg64::seeded(seed ^ k)`).
    pub seed: u64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            retry: RetryPolicy::default(),
            cache_rows: 4096,
            retry_hops: 1,
            seed: 0x5254_0001, // "RT", revision 1
        }
    }
}

/// One replica behind the router.
struct Leg {
    addr: String,
    /// Rows currently forwarded and unanswered — the P2C load signal.
    inflight: AtomicUsize,
    /// Set by the probe on heartbeat failure, cleared on revival; a
    /// retired leg is never drawn for new hops.
    retired: AtomicBool,
    /// Newest θ version observed on this leg (handshakes + answers).
    version: AtomicU64,
    /// Requests this leg answered (cache hits included).
    answered: AtomicU64,
    cache: AnswerCache,
}

impl Leg {
    fn observe(&self, version: u64) {
        self.version.fetch_max(version, Ordering::SeqCst);
        self.cache.advance(version);
    }
}

/// Counter snapshot from a running (or finished) [`Router`].
#[derive(Clone, Debug, Default)]
pub struct RouteStats {
    /// PREDICT sessions accepted.
    pub sessions: u64,
    /// Requests answered with a PREDICTION (cache hits included).
    pub routed: u64,
    /// Requests answered straight from a leg's [`AnswerCache`].
    pub cache_hits: u64,
    /// Per-hop cache lookups that missed (a retried request can miss
    /// on more than one leg, so this can exceed the request count).
    pub cache_misses: u64,
    /// Retryable REJECTs absorbed from replicas (each one either moved
    /// the request to a sibling or, with the budget spent, surfaced).
    pub retries: u64,
    /// Dead-link hops absorbed (connect failure or mid-request error).
    pub failovers: u64,
    /// Per-code REJECTs absorbed from replica hops — the per-hop
    /// accounting `BENCH_serve.json` reports for routed runs.
    pub hop_rejects: Vec<(u16, u64)>,
    /// Per-code REJECTs actually surfaced to clients.
    pub surfaced_rejects: Vec<(u16, u64)>,
    /// Requests answered per leg, fleet order.
    pub answered_per_leg: Vec<u64>,
    /// Retirement flag per leg, fleet order.
    pub retired: Vec<bool>,
    /// Newest θ version observed per leg, fleet order.
    pub leg_versions: Vec<u64>,
}

struct RouteCtx {
    legs: Vec<Arc<Leg>>,
    m: u64,
    d: u64,
    layout_len: u64,
    cfg: RouterConfig,
    over: AtomicBool,
    sessions: AtomicU64,
    routed: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    retries: AtomicU64,
    failovers: AtomicU64,
    hop_rejects: RejectCounters,
    surfaced: RejectCounters,
    /// Every stream the router holds (client sessions, leg sessions,
    /// probes) — severed at shutdown so no thread stays blocked in a
    /// read.
    conns: Mutex<Vec<Arc<Mutex<TcpStream>>>>,
}

impl RouteCtx {
    fn register(&self, s: &TcpStream) -> Option<Arc<Mutex<TcpStream>>> {
        let w = Arc::new(Mutex::new(s.try_clone().ok()?));
        self.conns.lock().unwrap().push(w.clone());
        Some(w)
    }

    fn register_raw(&self, s: TcpStream) {
        self.conns.lock().unwrap().push(Arc::new(Mutex::new(s)));
    }

    /// Newest version over the live legs (over all legs when every one
    /// is retired — a frozen fleet still reports what it last saw).
    fn fleet_version(&self) -> u64 {
        let live = self
            .legs
            .iter()
            .filter(|l| !l.retired.load(Ordering::SeqCst))
            .map(|l| l.version.load(Ordering::SeqCst))
            .max();
        live.unwrap_or_else(|| {
            self.legs.iter().map(|l| l.version.load(Ordering::SeqCst)).max().unwrap_or(0)
        })
    }

    fn statuses(&self) -> Vec<ReplicaStatus> {
        self.legs
            .iter()
            .map(|l| ReplicaStatus {
                version: l.version.load(Ordering::SeqCst),
                inflight: l.inflight.load(Ordering::SeqCst).min(u32::MAX as usize) as u32,
                flags: if l.retired.load(Ordering::SeqCst) { ROUTE_RETIRED } else { 0 },
            })
            .collect()
    }
}

/// The routing tier: one listener, N replica legs, per-leg answer
/// caches and health probes.  See the module doc for semantics.
pub struct Router {
    addr: SocketAddr,
    ctx: Arc<RouteCtx>,
    threads: Vec<JoinHandle<()>>,
}

impl Router {
    /// Bind `listen`, dial every replica (failing fast on an
    /// unreachable or mismatched fleet), and start serving routed
    /// PREDICT sessions.
    pub fn start(listen: &str, replicas: &[String], cfg: RouterConfig) -> Result<Self> {
        ensure!(!replicas.is_empty(), "a router needs at least one replica");
        ensure!(
            replicas.len() <= MAX_ROUTE_REPLICAS,
            "{} replicas exceeds the ROUTE-STATUS ceiling {MAX_ROUTE_REPLICAS}",
            replicas.len()
        );
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("bind router listener on {listen}"))?;
        let addr = listener.local_addr().context("router listener address")?;

        // Dial the whole fleet up front: a typo'd or down replica fails
        // start() instead of silently serving a smaller fleet, and the
        // handshakes teach us (m, d, version) for the session acks.
        let mut first = Vec::with_capacity(replicas.len());
        let mut md: Option<(usize, usize)> = None;
        for (i, a) in replicas.iter().enumerate() {
            let c = PredictClient::connect(a)
                .with_context(|| format!("router leg {i}: dial replica {a}"))?;
            match md {
                None => md = Some((c.m, c.d)),
                Some((m, d)) => ensure!(
                    (c.m, c.d) == (m, d),
                    "router leg {i} ({a}) announces m={}, d={} but leg 0 announced m={m}, d={d}",
                    c.m,
                    c.d
                ),
            }
            first.push(c);
        }
        let (m, d) = md.unwrap();
        let layout_len = ThetaLayout::new(m, d).len() as u64;

        let legs: Vec<Arc<Leg>> = replicas
            .iter()
            .zip(&first)
            .map(|(a, c)| {
                Arc::new(Leg {
                    addr: a.clone(),
                    inflight: AtomicUsize::new(0),
                    retired: AtomicBool::new(false),
                    version: AtomicU64::new(c.version),
                    answered: AtomicU64::new(0),
                    cache: AnswerCache::new(cfg.cache_rows),
                })
            })
            .collect();

        let n = legs.len();
        let ctx = Arc::new(RouteCtx {
            legs,
            m: m as u64,
            d: d as u64,
            layout_len,
            cfg,
            over: AtomicBool::new(false),
            sessions: AtomicU64::new(0),
            routed: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
            hop_rejects: RejectCounters::default(),
            surfaced: RejectCounters::default(),
            conns: Mutex::new(Vec::new()),
        });

        let mut threads = Vec::with_capacity(n + 1);
        for (i, c) in first.into_iter().enumerate() {
            let ctx = ctx.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("advgp-route-probe-{i}"))
                    .spawn(move || probe_leg(ctx, i, Some(c)))
                    .context("spawn probe thread")?,
            );
        }
        {
            let ctx = ctx.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("advgp-route-accept".into())
                    .spawn(move || accept_sessions(listener, ctx))
                    .context("spawn router accept thread")?,
            );
        }
        log_info!("serve::router: routing {addr} over {n} replicas (m={m}, d={d})");
        Ok(Self { addr, ctx, threads })
    }

    /// The client-facing listen address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live counter snapshot.
    pub fn stats(&self) -> RouteStats {
        stats_of(&self.ctx)
    }

    /// Whether leg `i`'s probe currently has it retired.
    pub fn leg_retired(&self, i: usize) -> bool {
        self.ctx.legs[i].retired.load(Ordering::SeqCst)
    }

    /// Poll until leg `i` is retired (true) or `timeout` passes.
    pub fn wait_leg_retired(&self, i: usize, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while Instant::now() < deadline {
            if self.leg_retired(i) {
                return true;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        self.leg_retired(i)
    }

    /// Stop accepting, sever every held stream, join all threads, and
    /// return the final counters.
    pub fn shutdown(self) -> RouteStats {
        let Router { ctx, threads, .. } = self;
        ctx.over.store(true, Ordering::SeqCst);
        for c in ctx.conns.lock().unwrap().iter() {
            let _ = c.lock().unwrap().shutdown(std::net::Shutdown::Both);
        }
        for t in threads {
            let _ = t.join();
        }
        stats_of(&ctx)
    }
}

fn stats_of(ctx: &RouteCtx) -> RouteStats {
    RouteStats {
        sessions: ctx.sessions.load(Ordering::Relaxed),
        routed: ctx.routed.load(Ordering::Relaxed),
        cache_hits: ctx.cache_hits.load(Ordering::Relaxed),
        cache_misses: ctx.cache_misses.load(Ordering::Relaxed),
        retries: ctx.retries.load(Ordering::Relaxed),
        failovers: ctx.failovers.load(Ordering::Relaxed),
        hop_rejects: ctx.hop_rejects.by_code().to_vec(),
        surfaced_rejects: ctx.surfaced.by_code().to_vec(),
        answered_per_leg: ctx.legs.iter().map(|l| l.answered.load(Ordering::Relaxed)).collect(),
        retired: ctx.legs.iter().map(|l| l.retired.load(Ordering::SeqCst)).collect(),
        leg_versions: ctx.legs.iter().map(|l| l.version.load(Ordering::SeqCst)).collect(),
    }
}

// ---------------------------------------------------------------------------
// Health probes
// ---------------------------------------------------------------------------

/// Arm the probe's PONG grace window, learn the handshake version, and
/// make the stream severable at shutdown.
fn adopt_probe(ctx: &RouteCtx, leg: &Leg, c: &PredictClient) {
    let hb = ctx.cfg.retry.heartbeat;
    let _ = c.set_answer_timeout(Some(hb * 2));
    leg.observe(c.version);
    if let Ok(s) = c.try_clone_stream() {
        ctx.register_raw(s);
    }
}

/// Per-leg health loop: PING at heartbeat cadence; a failed probe
/// retires the leg, then redials with jittered backoff **forever**
/// (unlike the budgeted training-side reconnects, retirement is the
/// steady state while a replica is unreachable and revival costs one
/// good handshake).
fn probe_leg(ctx: Arc<RouteCtx>, idx: usize, mut client: Option<PredictClient>) {
    let leg = ctx.legs[idx].clone();
    let hb = ctx.cfg.retry.heartbeat;
    let mut rng =
        Pcg64::seeded(ctx.cfg.seed ^ fnv1a64(FNV1A64_INIT, leg.addr.as_bytes()));
    let mut attempt = 0u32;
    if let Some(c) = &client {
        adopt_probe(&ctx, &leg, c);
    }
    loop {
        if ctx.over.load(Ordering::SeqCst) {
            return;
        }
        let Some(c) = client.as_mut() else {
            match PredictClient::connect(&leg.addr) {
                Ok(c) => {
                    adopt_probe(&ctx, &leg, &c);
                    if leg.retired.swap(false, Ordering::SeqCst) {
                        log_info!(
                            "serve::router: leg {idx} ({}) revived at θ v{}",
                            leg.addr,
                            c.version
                        );
                    }
                    attempt = 0;
                    client = Some(c);
                }
                Err(_) => {
                    if !leg.retired.swap(true, Ordering::SeqCst) {
                        log_warn!(
                            "serve::router: leg {idx} ({}) unreachable — retired",
                            leg.addr
                        );
                    }
                    let delay = ctx.cfg.retry.reconnect.delay(attempt, &mut rng);
                    attempt = attempt.saturating_add(1);
                    if sleep_poll(delay, &ctx.over) {
                        return;
                    }
                }
            }
            continue;
        };
        if sleep_poll(hb, &ctx.over) {
            return;
        }
        if c.ping().is_ok() {
            continue;
        }
        if !leg.retired.swap(true, Ordering::SeqCst) {
            log_warn!(
                "serve::router: leg {idx} ({}) failed its heartbeat probe — retired",
                leg.addr
            );
        }
        client = None;
    }
}

// ---------------------------------------------------------------------------
// Client sessions
// ---------------------------------------------------------------------------

fn accept_sessions(listener: TcpListener, ctx: Arc<RouteCtx>) {
    let nonblocking = listener.set_nonblocking(true).is_ok();
    loop {
        match listener.accept() {
            Ok((s, _peer)) => {
                if ctx.over.load(Ordering::SeqCst) {
                    return;
                }
                let _ = s.set_nonblocking(false);
                let ctx = ctx.clone();
                let _ = std::thread::Builder::new()
                    .name("advgp-route-conn".into())
                    .spawn(move || handle_route_conn(s, ctx));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if ctx.over.load(Ordering::SeqCst) || !nonblocking {
                    return;
                }
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(_) => {
                if ctx.over.load(Ordering::SeqCst) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// A session's lazily-dialed connection to one leg.  The replica drops
/// a predict session silent through `2×heartbeat` (its PING goes
/// unanswered while this handler blocks on the *client* socket), so a
/// connection idle past one heartbeat window is discarded and redialed
/// rather than trusted.
struct LegConn {
    client: PredictClient,
    last_used: Instant,
}

fn handle_route_conn(stream: TcpStream, ctx: Arc<RouteCtx>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(ctx.cfg.retry.write_timeout));
    let _ = stream.set_read_timeout(Some(ctx.cfg.retry.handshake_timeout));
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into());
    let Some(writer) = ctx.register(&stream) else { return };
    let mut reader = stream;
    let mut scratch = Vec::new();
    let first = wire::read_frame_capped(&mut reader, &mut scratch, MAX_HANDSHAKE_FRAME_LEN);
    match first {
        Ok(Frame::Subscribe { proto, scope }) if proto >= PROTO_NT2 => {
            if scope != SUBSCRIBE_PREDICT {
                let _ = send_frame(
                    &writer,
                    &Frame::Reject {
                        id: 0,
                        code: REJ_BAD_SCOPE,
                        message: "routers front predict sessions; subscribe to the \
                                  θ-slice servers for posterior streams"
                            .into(),
                    },
                );
                return;
            }
        }
        Ok(Frame::Subscribe { .. }) => {
            let msg = format!("predict sessions require rev {PROTO_NT2}");
            let _ = send_frame(&writer, &Frame::Error { code: ERR_PROTO, message: msg });
            return;
        }
        Ok(f) => {
            let msg = format!("expected SUBSCRIBE, got kind {:#04x}", f.kind());
            let _ = send_frame(&writer, &Frame::Error { code: ERR_MALFORMED, message: msg });
            return;
        }
        Err(e) => {
            let msg = format!("bad SUBSCRIBE: {e:#}");
            let _ = send_frame(&writer, &Frame::Error { code: ERR_MALFORMED, message: msg });
            return;
        }
    }
    // The ack mirrors a replica's exactly — same header-only sync, with
    // the newest live-leg version as the fleet version — so existing
    // predict clients work against a router unchanged.
    let ack = Frame::PosteriorSync {
        m: ctx.m,
        d: ctx.d,
        slice_id: 0,
        n_slices: 1,
        start: 0,
        end: ctx.layout_len,
        version: ctx.fleet_version(),
        meta: PublishMeta::default(),
        theta: vec![],
    };
    if send_frame(&writer, &ack).is_err() {
        return;
    }
    // What a replica never sends: fleet observability, pushed once per
    // session right after the handshake.
    let status =
        Frame::RouteStatus { fleet_version: ctx.fleet_version(), replicas: ctx.statuses() };
    if send_frame(&writer, &status).is_err() {
        return;
    }
    // Per-session draw stream: seed ^ session-ordinal makes leg choices
    // a pure function of (config seed, session order, request order) —
    // the chaos suite replays routed fault traces on exactly this.
    let ordinal = ctx.sessions.fetch_add(1, Ordering::SeqCst);
    let mut rng = Pcg64::seeded(ctx.cfg.seed ^ ordinal);
    let mut legs_conn: Vec<Option<LegConn>> = ctx.legs.iter().map(|_| None).collect();
    let _ = reader.set_read_timeout(Some(ctx.cfg.retry.heartbeat));
    let mut pinged = false;
    loop {
        let frame = match wire::read_frame_event(&mut reader, &mut scratch, MAX_FRAME_LEN) {
            Ok(ReadEvent::Frame(f)) => {
                pinged = false;
                f
            }
            Ok(ReadEvent::IdleTimeout) => {
                if pinged || send_frame(&writer, &Frame::Ping).is_err() {
                    log_warn!(
                        "serve::router: client {peer} silent through PING + grace — \
                         dropping the session"
                    );
                    break;
                }
                pinged = true;
                continue;
            }
            Ok(ReadEvent::Eof) => break,
            Err(e) => {
                let msg = format!("malformed stream: {e:#}");
                let _ = send_frame(&writer, &Frame::Error { code: ERR_MALFORMED, message: msg });
                break;
            }
        };
        match frame {
            Frame::Predict { id, d: want_d, rows } => {
                if !route_request(&ctx, &mut rng, &mut legs_conn, &writer, id, want_d, rows) {
                    break;
                }
            }
            Frame::Ping => {
                let _ = send_frame(&writer, &Frame::Pong);
            }
            Frame::Pong => {}
            Frame::Error { code, message } => {
                log_warn!("serve::router: client {peer} sent error {code}: {message}");
                break;
            }
            f => {
                let msg = format!("unexpected kind {:#04x} on a predict session", f.kind());
                let _ = send_frame(&writer, &Frame::Error { code: ERR_MALFORMED, message: msg });
                break;
            }
        }
    }
    let _ = reader.shutdown(std::net::Shutdown::Both);
}

/// Draw one untried live leg — power of two choices on in-flight rows,
/// first draw winning ties so a quiet fleet still spreads by the rng
/// stream alone.
fn pick_leg(ctx: &RouteCtx, rng: &mut Pcg64, tried: &[bool]) -> Option<usize> {
    let live: Vec<usize> = ctx
        .legs
        .iter()
        .enumerate()
        .filter(|(i, l)| !tried[*i] && !l.retired.load(Ordering::SeqCst))
        .map(|(i, _)| i)
        .collect();
    match live.len() {
        0 => None,
        1 => Some(live[0]),
        n => {
            let ia = rng.next_below(n as u64) as usize;
            let mut ib = rng.next_below(n as u64 - 1) as usize;
            if ib >= ia {
                ib += 1;
            }
            let (a, b) = (live[ia], live[ib]);
            let load_a = ctx.legs[a].inflight.load(Ordering::SeqCst);
            let load_b = ctx.legs[b].inflight.load(Ordering::SeqCst);
            Some(if load_b < load_a { b } else { a })
        }
    }
}

/// Route one PREDICT: cache → forward → (maybe) retry on a sibling.
/// Returns false when the client link is dead and the session should
/// end.
fn route_request(
    ctx: &RouteCtx,
    rng: &mut Pcg64,
    legs_conn: &mut [Option<LegConn>],
    writer: &Mutex<TcpStream>,
    id: u64,
    want_d: u64,
    rows: Vec<f64>,
) -> bool {
    let d = ctx.d as usize;
    let surface = |code: u16, message: String| {
        ctx.surfaced.bump(code);
        send_frame(writer, &Frame::Reject { id, code, message }).is_ok()
    };
    if want_d != ctx.d {
        return surface(
            REJ_BAD_DIM,
            format!("inputs are {want_d}-dimensional but the model takes {}", ctx.d),
        );
    }
    if rows.is_empty() || rows.len() % d != 0 {
        return surface(
            REJ_BAD_DIM,
            format!("{} values is not a whole number of {d}-dim rows", rows.len()),
        );
    }
    let k = rows.len() / d;
    let mut tried = vec![false; ctx.legs.len()];
    let mut attempts = ctx.cfg.retry_hops + 1;
    let mut last_reject: Option<(u16, String)> = None;
    while attempts > 0 {
        let Some(idx) = pick_leg(ctx, rng, &tried) else { break };
        tried[idx] = true;
        attempts -= 1;
        let leg = &ctx.legs[idx];
        // Cache first: every row must hit at the leg's newest observed
        // version or the whole request goes upstream.
        if ctx.cfg.cache_rows > 0 {
            if let Some((version, mean, var)) = leg.cache.get_batch(&rows, d) {
                ctx.cache_hits.fetch_add(1, Ordering::Relaxed);
                ctx.routed.fetch_add(1, Ordering::Relaxed);
                leg.answered.fetch_add(1, Ordering::Relaxed);
                return send_frame(writer, &Frame::Prediction { id, version, mean, var })
                    .is_ok();
            }
            ctx.cache_misses.fetch_add(1, Ordering::Relaxed);
        }
        // A leg connection idle past one heartbeat window may already
        // be dropped replica-side — redial instead of trusting it.
        if let Some(lc) = &legs_conn[idx] {
            if lc.last_used.elapsed() >= ctx.cfg.retry.heartbeat {
                legs_conn[idx] = None;
            }
        }
        if legs_conn[idx].is_none() {
            match PredictClient::connect(&leg.addr) {
                Ok(c) => {
                    let _ = c.set_answer_timeout(Some(ctx.cfg.retry.handshake_timeout));
                    if let Ok(s) = c.try_clone_stream() {
                        ctx.register_raw(s);
                    }
                    leg.observe(c.version);
                    legs_conn[idx] = Some(LegConn { client: c, last_used: Instant::now() });
                }
                Err(_) => {
                    ctx.failovers.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
            }
        }
        let lc = legs_conn[idx].as_mut().unwrap();
        leg.inflight.fetch_add(k, Ordering::SeqCst);
        let outcome = lc.client.predict(&rows);
        leg.inflight.fetch_sub(k, Ordering::SeqCst);
        match outcome {
            Ok(PredictAnswer::Prediction { version, mean, var }) => {
                lc.last_used = Instant::now();
                leg.observe(version);
                if ctx.cfg.cache_rows > 0 {
                    leg.cache.insert_batch(version, &rows, d, &mean, &var);
                }
                ctx.routed.fetch_add(1, Ordering::Relaxed);
                leg.answered.fetch_add(1, Ordering::Relaxed);
                return send_frame(writer, &Frame::Prediction { id, version, mean, var })
                    .is_ok();
            }
            Ok(PredictAnswer::Rejected { code, message }) => {
                lc.last_used = Instant::now();
                ctx.hop_rejects.bump(code);
                if reject_is_retryable(code) {
                    // Replica-state verdict: a sibling may well say
                    // yes — absorb and keep going.
                    ctx.retries.fetch_add(1, Ordering::Relaxed);
                    last_reject = Some((code, message));
                    continue;
                }
                // Request/fleet verdict: every sibling would repeat it.
                return surface(code, message);
            }
            Err(_) => {
                // Dead link mid-request: drop the connection (the next
                // request redials) and fail over.
                legs_conn[idx] = None;
                ctx.failovers.fetch_add(1, Ordering::Relaxed);
                continue;
            }
        }
    }
    let (code, message) = last_reject
        .unwrap_or_else(|| (REJ_NOT_READY, "no live replica could answer".into()));
    surface(code, message)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn colliding(_bytes: &[u8]) -> u64 {
        42
    }

    #[test]
    fn answer_cache_hits_only_on_exact_version_and_row() {
        let cache = AnswerCache::new(8);
        cache.insert(3, &[1.0, 2.0], 0.5, 0.25);
        assert_eq!(cache.get(&[1.0, 2.0]), Some((3, 0.5, 0.25)));
        // one-ulp difference in the row is a different key
        assert_eq!(cache.get(&[1.0, 2.0 + f64::EPSILON]), None);
        // a newer version makes the entry unreachable
        cache.advance(4);
        assert_eq!(cache.get(&[1.0, 2.0]), None);
        assert_eq!(cache.len(), 0);
        // inserts at an older version are dropped, not resurrected
        cache.insert(3, &[1.0, 2.0], 0.5, 0.25);
        assert_eq!(cache.len(), 0);
    }

    #[test]
    fn answer_cache_collisions_never_cross_serve() {
        let cache = AnswerCache::with_hasher(8, colliding);
        cache.insert(1, &[1.0], 10.0, 0.1);
        cache.insert(1, &[2.0], 20.0, 0.2);
        // both rows live under one hash; lookups stay row-exact
        assert_eq!(cache.get(&[1.0]), Some((1, 10.0, 0.1)));
        assert_eq!(cache.get(&[2.0]), Some((1, 20.0, 0.2)));
        assert_eq!(cache.get(&[3.0]), None);
    }

    #[test]
    fn answer_cache_eviction_is_fifo_and_bounded() {
        let cache = AnswerCache::new(2);
        cache.insert(1, &[1.0], 10.0, 0.1);
        cache.insert(1, &[2.0], 20.0, 0.2);
        cache.insert(1, &[3.0], 30.0, 0.3);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.get(&[1.0]), None, "oldest entry evicted first");
        assert_eq!(cache.get(&[2.0]), Some((1, 20.0, 0.2)));
        assert_eq!(cache.get(&[3.0]), Some((1, 30.0, 0.3)));
    }

    #[test]
    fn get_batch_is_all_or_nothing() {
        let cache = AnswerCache::new(8);
        cache.insert(5, &[1.0, 2.0], 0.5, 0.25);
        cache.insert(5, &[3.0, 4.0], 0.7, 0.35);
        let (v, mean, var) = cache.get_batch(&[1.0, 2.0, 3.0, 4.0], 2).unwrap();
        assert_eq!((v, mean, var), (5, vec![0.5, 0.7], vec![0.25, 0.35]));
        // one uncached row fails the whole request
        assert!(cache.get_batch(&[1.0, 2.0, 9.0, 9.0], 2).is_none());
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let cache = AnswerCache::new(0);
        cache.insert(1, &[1.0], 10.0, 0.1);
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.get(&[1.0]), None);
    }
}
