//! The prediction/serving subsystem (ISSUE 2): versioned posterior
//! snapshots + a microbatching batch server over the blocked posterior
//! math of [`crate::gp`].
//!
//! Design:
//!
//! * [`PosteriorCache`] — an atomically-swapped, **immutable** posterior
//!   snapshot per published θ version.  Building a [`Posterior`] costs
//!   O(m³) (the `InducingChol` factor), so it happens once per version
//!   *outside* the swap lock; readers clone an `Arc` and can never
//!   observe factors from two different θ versions — a snapshot is
//!   frozen at construction.  Installs are version-gated: stale writers
//!   (a slow rebuild racing a newer one) are dropped, so the cache is
//!   monotone in version.
//! * [`BatchServer`] — microbatches incoming single-row predict
//!   requests (flush at `max_rows` or a deadline) and answers each
//!   batch with one blocked `predict_into` call through a reusable
//!   [`crate::gp::PredictWorkspace`], reporting rows/sec and latency
//!   percentiles.
//!
//! The mid-training evaluator (`ps::coordinator::native_eval_factory`)
//! runs on the same cache + workspaces, so cadenced evaluation shares
//! the per-version factor build and allocates nothing per snapshot
//! beyond it.
//!
//! The **read-path fleet** (ADVGPSV1, ISSUE 8) scales this horizontally:
//! [`replica::Replica`] subscribes to the training fleet's per-slice
//! publish streams over the wire, mirrors them through the same
//! assembler/cache machinery, and serves PREDICT sessions on its own
//! listener; [`loadgen`] is the open-loop load generator + scoreboard
//! that measures such a fleet (`advgp loadgen` → `BENCH_serve.json`).
//!
//! The **routing tier** (ADVGPRT1, ISSUE 9) puts one address in front
//! of the fleet: [`router::Router`] spreads PREDICT sessions with
//! power-of-two-choices balancing, retries replica-state REJECTs on a
//! sibling, and short-circuits repeated rows through per-leg
//! version-gated [`router::AnswerCache`]s — answer-preserving by
//! construction, pinned bitwise by `rust/tests/serve_router.rs`.

pub mod batch;
pub mod loadgen;
pub mod replica;
pub mod router;

pub use batch::{BatchConfig, BatchServer, Prediction, ServeClient, ServeReport};
pub use loadgen::{LoadgenConfig, Scoreboard};
pub use replica::{PredictAnswer, PredictClient, Replica, ReplicaConfig};
pub use router::{AnswerCache, RouteStats, Router, RouterConfig};

use crate::gp::{SparseGp, Theta, ThetaLayout};
use crate::ps::Published;
use std::sync::{Arc, RwLock};

/// One immutable posterior snapshot: the θ version it was built from
/// and the fully-factored predictive model.
pub struct Posterior {
    pub version: u64,
    pub gp: SparseGp,
}

/// Versioned, atomically-swapped posterior state.  `install` is called
/// by whoever observes a new published θ (evaluator, batch server,
/// refresher thread); `get` is wait-free apart from a brief read lock
/// and returns a snapshot that stays valid for as long as the caller
/// holds the `Arc`, even across later installs.
///
/// ```
/// use advgp::gp::{Theta, ThetaLayout};
/// use advgp::linalg::Mat;
/// use advgp::serve::PosteriorCache;
///
/// let layout = ThetaLayout::new(2, 1);
/// let theta = Theta::init(layout, &Mat::from_vec(2, 1, vec![-1.0, 1.0]));
/// let cache = PosteriorCache::new(layout);
/// assert!(cache.get().is_none()); // nothing installed yet
///
/// assert!(cache.install(1, &theta.data)); // O(m³) build, then swap
/// assert!(!cache.install(1, &theta.data)); // same version: no rebuild
/// assert!(!cache.install(0, &theta.data)); // stale writer: dropped
///
/// let post = cache.get().unwrap(); // snapshot outlives later installs
/// assert_eq!(post.version, 1);
/// let (mean, var) = post.gp.predict(&Mat::from_vec(1, 1, vec![0.2]));
/// assert_eq!((mean.len(), var.len()), (1, 1));
/// ```
pub struct PosteriorCache {
    layout: ThetaLayout,
    slot: RwLock<Option<Arc<Posterior>>>,
}

impl PosteriorCache {
    pub fn new(layout: ThetaLayout) -> Self {
        Self { layout, slot: RwLock::new(None) }
    }

    pub fn layout(&self) -> ThetaLayout {
        self.layout
    }

    /// Version of the currently-installed posterior (None before the
    /// first install).
    pub fn version(&self) -> Option<u64> {
        self.slot.read().unwrap().as_ref().map(|p| p.version)
    }

    /// Current posterior snapshot.
    pub fn get(&self) -> Option<Arc<Posterior>> {
        self.slot.read().unwrap().clone()
    }

    /// Build and install the posterior for `(version, θ)` if it is
    /// newer than the installed one.  The O(m³) factor build runs
    /// outside the lock; the swap re-checks the version so concurrent
    /// installs resolve in version order (a stale build is discarded).
    /// Returns true if the snapshot was installed.
    pub fn install(&self, version: u64, theta: &[f64]) -> bool {
        if self.version().is_some_and(|v| v >= version) {
            return false; // stale or already current — skip the O(m³) rebuild
        }
        let gp = SparseGp::new(Theta { layout: self.layout, data: theta.to_vec() });
        let post = Arc::new(Posterior { version, gp });
        let mut slot = self.slot.write().unwrap();
        match slot.as_ref() {
            Some(cur) if cur.version >= version => false,
            _ => {
                *slot = Some(post);
                true
            }
        }
    }

    /// Install from the parameter server's published state if it has
    /// advanced.  Returns true if a new posterior was installed.
    pub fn sync(&self, published: &Published) -> bool {
        let (version, theta, _shutdown) = published.snapshot();
        if self.version() == Some(version) {
            return false;
        }
        self.install(version, &theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::rng::Pcg64;
    use std::sync::atomic::{AtomicBool, Ordering};

    fn theta_for_version(layout: ThetaLayout, v: u64) -> Vec<f64> {
        let mut rng = Pcg64::seeded(100);
        let z = Mat::from_vec(
            layout.m,
            layout.d,
            (0..layout.m * layout.d).map(|_| rng.normal()).collect(),
        );
        let mut th = Theta::init(layout, &z);
        // Every version gets a distinct amplitude AND mean, so both the
        // feature-map factor and the variational state are version-tagged.
        th.data[layout.log_a0_idx()] = 0.05 * v as f64;
        for mu in th.mu_mut() {
            *mu = v as f64;
        }
        th.data
    }

    #[test]
    fn install_is_version_monotone() {
        let layout = ThetaLayout::new(4, 2);
        let cache = PosteriorCache::new(layout);
        assert!(cache.get().is_none());
        assert!(cache.install(3, &theta_for_version(layout, 3)));
        assert_eq!(cache.version(), Some(3));
        // Same version: no rebuild; older version: dropped.
        assert!(!cache.install(3, &theta_for_version(layout, 3)));
        assert!(!cache.install(2, &theta_for_version(layout, 2)));
        assert_eq!(cache.version(), Some(3));
        assert!(cache.install(7, &theta_for_version(layout, 7)));
        assert_eq!(cache.version(), Some(7));
    }

    /// Readers racing a writer must never observe a posterior mixing
    /// factors from two θ versions: predictions from any snapshot must
    /// equal a fresh model built from that snapshot's exact θ.
    #[test]
    fn stale_reads_never_mix_versions() {
        let layout = ThetaLayout::new(4, 2);
        let versions: u64 = 40;
        let mut rng = Pcg64::seeded(200);
        let probe = Mat::from_vec(3, 2, (0..6).map(|_| rng.normal()).collect());
        // Expected predictions per version, from independently-built models.
        let expected: Vec<(Vec<f64>, Vec<f64>)> = (0..=versions)
            .map(|v| {
                let gp = SparseGp::new(Theta {
                    layout,
                    data: theta_for_version(layout, v),
                });
                gp.predict(&probe)
            })
            .collect();
        let cache = Arc::new(PosteriorCache::new(layout));
        cache.install(0, &theta_for_version(layout, 0));
        let done = Arc::new(AtomicBool::new(false));
        std::thread::scope(|scope| {
            for _ in 0..3 {
                let cache = Arc::clone(&cache);
                let done = Arc::clone(&done);
                let probe = probe.clone();
                let expected = &expected;
                scope.spawn(move || {
                    let mut last = 0u64;
                    while !done.load(Ordering::Relaxed) {
                        let post = cache.get().expect("seeded");
                        let v = post.version;
                        // Monotone: a reader never goes back in time.
                        assert!(v >= last, "version regressed {last} -> {v}");
                        last = v;
                        // θ is internally consistent with the version tag…
                        for mu in post.gp.theta.mu() {
                            assert_eq!(*mu, v as f64, "torn θ at version {v}");
                        }
                        // …and the *factors* match that exact θ: same
                        // deterministic build ⇒ bitwise-equal predictions.
                        let (mean, var) = post.gp.predict(&probe);
                        let (em, ev) = &expected[v as usize];
                        assert_eq!(&mean, em, "mean mixes factors at version {v}");
                        assert_eq!(&var, ev, "var mixes factors at version {v}");
                    }
                });
            }
            for v in 1..=versions {
                cache.install(v, &theta_for_version(layout, v));
            }
            done.store(true, Ordering::Relaxed);
        });
        assert_eq!(cache.version(), Some(versions));
    }
}
