//! Microbatching prediction server: single-row requests are staged and
//! answered in blocked batches (flush at `max_rows` rows or when the
//! oldest staged row's `latency_budget` runs out), amortizing the
//! O(B·m²) posterior math and the pool dispatch across concurrent
//! clients.
//!
//! The ingress queue is *shared*: [`ServeClient`] is a cheap clone, so
//! every predict session on a replica feeds the same staging buffer and
//! rows from different sessions fuse into one batch (cross-session
//! batching, ADVGPRT1 ISSUE 9).  The `latency_budget` is therefore a
//! per-*row* promise, not a per-batch one — the flush deadline is
//! anchored at the oldest staged row's enqueue instant (time spent in
//! the ingress queue while the server was busy counts against the
//! budget), so no session's row waits past its budget for stragglers
//! from another session.
//!
//! One serving thread owns a reusable [`PredictWorkspace`] and a staged
//! row buffer, so the steady-state serve loop allocates nothing on the
//! prediction path; the only per-request allocations are client-side
//! (the row copy and the one-shot reply channel).  The server follows
//! the live published θ: before every flush it syncs its
//! [`PosteriorCache`] against the parameter server's [`Published`]
//! state, rebuilding the posterior only when the version advanced.

use super::PosteriorCache;
use crate::gp::PredictWorkspace;
use crate::linalg::Mat;
use crate::ps::Published;
use crate::util::{Stats, Stopwatch};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Microbatching policy.
#[derive(Clone, Debug)]
pub struct BatchConfig {
    /// Flush when this many rows are staged — a full batch
    /// short-circuits the latency budget.
    pub max_rows: usize,
    /// …or when the *oldest staged row* (across every session feeding
    /// the shared ingress queue) has waited this long since it was
    /// enqueued.  Ingress-queue time counts: a row that sat behind a
    /// long compute has already burned budget, so its batch closes
    /// correspondingly sooner.
    pub latency_budget: Duration,
}

impl BatchConfig {
    /// The CLI/bench-facing constructor: a flush size plus the latency
    /// budget in milliseconds (`--latency-budget-ms`).
    pub fn with_budget_ms(max_rows: usize, budget_ms: u64) -> Self {
        Self { max_rows, latency_budget: Duration::from_millis(budget_ms) }
    }
}

impl Default for BatchConfig {
    fn default() -> Self {
        Self { max_rows: 256, latency_budget: Duration::from_millis(2) }
    }
}

/// One answered prediction.
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    pub mean: f64,
    /// Predictive variance of y (noise included).
    pub var: f64,
    /// θ version of the posterior that served this row.
    pub version: u64,
}

struct Request {
    row: Vec<f64>,
    enqueued: Stopwatch,
    reply: Sender<Prediction>,
}

/// Cheap cloneable handle for submitting predict requests.  Dropping
/// every client (and any clones) shuts the server down.
#[derive(Clone)]
pub struct ServeClient {
    tx: Sender<Request>,
    d: usize,
}

impl ServeClient {
    /// Enqueue one row; the answer arrives on the returned channel once
    /// its microbatch is flushed.  None if the server has shut down.
    pub fn submit(&self, row: &[f64]) -> Option<Receiver<Prediction>> {
        assert_eq!(row.len(), self.d, "feature dimension mismatch");
        let (rtx, rrx) = channel();
        let req = Request { row: row.to_vec(), enqueued: Stopwatch::start(), reply: rtx };
        self.tx.send(req).ok()?;
        Some(rrx)
    }

    /// Blocking single-row predict.
    pub fn predict(&self, row: &[f64]) -> Option<Prediction> {
        self.submit(row)?.recv().ok()
    }
}

/// Throughput/latency report for one server lifetime.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Rows answered.
    pub rows: u64,
    /// Blocked predict calls issued.
    pub batches: u64,
    /// Serving-thread lifetime.
    pub wall_secs: f64,
    pub rows_per_sec: f64,
    /// Rows-per-batch distribution.
    pub batch_rows: Stats,
    /// Per-request latency (enqueue → reply), seconds.  Use
    /// `latency.quantile(0.5 / 0.95 / 0.99)` for percentiles.
    pub latency: Stats,
    /// θ versions served (first, last) — how live the posterior was.
    pub first_version: u64,
    pub last_version: u64,
}

impl ServeReport {
    /// One-line human summary (used by the example/bench output).
    pub fn summary(&self) -> String {
        format!(
            "{} rows in {} batches ({:.0} rows/s, mean batch {:.1}); latency p50 {:.3}ms p95 {:.3}ms p99 {:.3}ms; θ v{}..v{}",
            self.rows,
            self.batches,
            self.rows_per_sec,
            self.batch_rows.mean(),
            self.latency.quantile(0.5) * 1e3,
            self.latency.quantile(0.95) * 1e3,
            self.latency.quantile(0.99) * 1e3,
            self.first_version,
            self.last_version,
        )
    }
}

/// The microbatching server.  `start` spawns the serving thread and
/// hands back a client; `join` collects the report after every client
/// handle has been dropped.
pub struct BatchServer {
    handle: std::thread::JoinHandle<ServeReport>,
}

impl BatchServer {
    /// Spawn the serving thread.  The cache must either already hold a
    /// posterior or `published` must be given (the server seeds the
    /// cache from it before serving).
    pub fn start(
        cache: Arc<PosteriorCache>,
        published: Option<Arc<Published>>,
        cfg: BatchConfig,
    ) -> (Self, ServeClient) {
        assert!(cfg.max_rows >= 1, "max_rows must be >= 1");
        if let Some(p) = &published {
            cache.sync(p);
        }
        assert!(
            cache.get().is_some(),
            "BatchServer needs a seeded PosteriorCache or a Published source"
        );
        let d = cache.layout().d;
        let (tx, rx) = channel::<Request>();
        let handle = std::thread::Builder::new()
            .name("advgp-serve".into())
            .spawn(move || serve_loop(cache, published, cfg, rx))
            .expect("spawn serve thread");
        (Self { handle }, ServeClient { tx, d })
    }

    /// Wait for shutdown (all clients dropped) and return the report.
    pub fn join(self) -> ServeReport {
        self.handle.join().expect("serve thread panicked")
    }
}

fn serve_loop(
    cache: Arc<PosteriorCache>,
    published: Option<Arc<Published>>,
    cfg: BatchConfig,
    rx: Receiver<Request>,
) -> ServeReport {
    let d = cache.layout().d;
    let clock = Stopwatch::start();
    let mut ws = PredictWorkspace::new();
    let mut xbuf = Mat::empty();
    let mut mean: Vec<f64> = Vec::new();
    let mut var: Vec<f64> = Vec::new();
    let mut pending: Vec<Request> = Vec::with_capacity(cfg.max_rows);
    let mut rows = 0u64;
    let mut batches = 0u64;
    let mut batch_rows = Stats::new();
    let mut latency = Stats::new();
    let mut first_version: Option<u64> = None;
    let mut last_version = 0u64;

    'serve: loop {
        // Block for the batch's first request; disconnect = shutdown.
        match rx.recv() {
            Ok(r) => pending.push(r),
            Err(_) => break 'serve,
        }
        // Stage more until the flush threshold or the deadline.  The
        // deadline is anchored at the first row's *enqueue* instant —
        // time it already spent waiting in the shared ingress queue is
        // budget spent, not budget reset.
        let waited = Duration::from_secs_f64(pending[0].enqueued.secs());
        let deadline = Instant::now() + cfg.latency_budget.saturating_sub(waited);
        while pending.len() < cfg.max_rows {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                // Serve what's staged, then shut down.
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // Follow the live θ: rebuild the posterior only on a version bump.
        if let Some(p) = &published {
            cache.sync(p);
        }
        let post = cache.get().expect("cache seeded before start");
        let b = pending.len();
        xbuf.resize(b, d);
        for (i, r) in pending.iter().enumerate() {
            xbuf.row_mut(i).copy_from_slice(&r.row);
        }
        post.gp.predict_into(&xbuf, &mut ws, &mut mean, &mut var);
        batches += 1;
        rows += b as u64;
        batch_rows.push(b as f64);
        first_version.get_or_insert(post.version);
        last_version = post.version;
        for (i, r) in pending.drain(..).enumerate() {
            latency.push(r.enqueued.secs());
            // A client that gave up on its reply is not an error.
            let _ = r.reply.send(Prediction {
                mean: mean[i],
                var: var[i],
                version: post.version,
            });
        }
    }

    let wall_secs = clock.secs();
    ServeReport {
        rows,
        batches,
        wall_secs,
        rows_per_sec: rows as f64 / wall_secs.max(1e-12),
        batch_rows,
        latency,
        first_version: first_version.unwrap_or(0),
        last_version,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::{SparseGp, Theta, ThetaLayout};
    use crate::util::rng::Pcg64;

    fn seeded_cache(m: usize, d: usize) -> (Arc<PosteriorCache>, Theta) {
        let layout = ThetaLayout::new(m, d);
        let mut rng = Pcg64::seeded(77);
        let z = Mat::from_vec(m, d, (0..m * d).map(|_| rng.normal()).collect());
        let mut th = Theta::init(layout, &z);
        for v in th.mu_mut() {
            *v = rng.normal();
        }
        let cache = Arc::new(PosteriorCache::new(layout));
        cache.install(1, &th.data);
        (cache, th)
    }

    #[test]
    fn batched_answers_match_direct_predict_exactly() {
        let (cache, th) = seeded_cache(6, 3);
        let gp = SparseGp::new(th);
        let cfg = BatchConfig { max_rows: 8, latency_budget: Duration::from_millis(5) };
        let (server, client) = BatchServer::start(Arc::clone(&cache), None, cfg);
        let mut rng = Pcg64::seeded(78);
        let rows: Vec<Vec<f64>> = (0..40)
            .map(|_| (0..3).map(|_| rng.normal()).collect())
            .collect();
        // Concurrent clients so batches actually mix rows.
        std::thread::scope(|scope| {
            for chunk in rows.chunks(10) {
                let client = client.clone();
                let gp = &gp;
                scope.spawn(move || {
                    for row in chunk {
                        let p = client.predict(row).expect("server alive");
                        let x = Mat::from_vec(1, 3, row.clone());
                        let (em, ev) = gp.predict(&x);
                        // Per-row math is independent of batch shape:
                        // bitwise equality, not tolerance.
                        assert_eq!(p.mean, em[0]);
                        assert_eq!(p.var, ev[0]);
                        assert_eq!(p.version, 1);
                    }
                });
            }
        });
        drop(client);
        let report = server.join();
        assert_eq!(report.rows, 40);
        assert_eq!(report.latency.n, 40);
        assert!(report.batches <= 40);
        assert!(report.rows_per_sec > 0.0);
        assert_eq!((report.first_version, report.last_version), (1, 1));
        assert!(report.latency.quantile(0.99) >= report.latency.quantile(0.5));
    }

    /// A burst submitted before the server can drain must be coalesced
    /// into few blocked calls (the whole point of microbatching).
    #[test]
    fn burst_is_microbatched() {
        let (cache, _th) = seeded_cache(4, 2);
        let cfg = BatchConfig { max_rows: 64, latency_budget: Duration::from_millis(100) };
        let (server, client) = BatchServer::start(cache, None, cfg);
        let row = [0.3, -0.7];
        let receivers: Vec<_> = (0..256)
            .map(|_| client.submit(&row).expect("server alive"))
            .collect();
        for r in receivers {
            r.recv().expect("reply");
        }
        drop(client);
        let report = server.join();
        assert_eq!(report.rows, 256);
        // 256 rows at flush size 64: a handful of batches even with an
        // early partial flush — far fewer than one call per row.
        assert!(
            report.batches <= 16,
            "burst not batched: {} batches for {} rows",
            report.batches,
            report.rows
        );
        assert!(report.batch_rows.max <= 64.0);
    }
}
