//! Mini property-testing harness (offline build: no `proptest`).
//!
//! Seeded generators + a `forall` runner with first-failure reporting
//! and a simple halving shrink for numeric scalars.  Used by the
//! invariant tests (prox positivity, PSD residuals, staleness bound…).

use crate::util::rng::Pcg64;

/// A generator of random values from an RNG.
pub trait Gen<T> {
    fn gen(&self, rng: &mut Pcg64) -> T;
}

impl<T, F: Fn(&mut Pcg64) -> T> Gen<T> for F {
    fn gen(&self, rng: &mut Pcg64) -> T {
        self(rng)
    }
}

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // Honor ADVGP_PROPTEST_CASES for heavier CI runs.
        let cases = std::env::var("ADVGP_PROPTEST_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        Self { cases, seed: 0xADF6_17 }
    }
}

/// Run `prop` on `cfg.cases` random inputs; panic with the seed and a
/// debug dump of the failing input on the first failure.
pub fn forall<T: std::fmt::Debug, G: Gen<T>, P: Fn(&T) -> Result<(), String>>(
    name: &str,
    cfg: &Config,
    gen: G,
    prop: P,
) {
    for case in 0..cfg.cases {
        let mut rng = Pcg64::new(cfg.seed, case as u64);
        let input = gen.gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {}, stream {case}):\n  \
                 input: {input:?}\n  reason: {msg}",
                cfg.seed
            );
        }
    }
}

/// Assert-style helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Common generators.
pub mod gens {
    use super::*;

    /// Uniform f64 in [lo, hi).
    pub fn uniform(lo: f64, hi: f64) -> impl Gen<f64> {
        move |rng: &mut Pcg64| rng.uniform(lo, hi)
    }

    /// Usize in [lo, hi].
    pub fn usize_in(lo: usize, hi: usize) -> impl Gen<usize> {
        move |rng: &mut Pcg64| lo + rng.next_below((hi - lo + 1) as u64) as usize
    }

    /// Vector of standard normals.
    pub fn normal_vec(len: usize, scale: f64) -> impl Gen<Vec<f64>> {
        move |rng: &mut Pcg64| (0..len).map(|_| rng.normal() * scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_valid_property() {
        forall("square nonneg", &Config { cases: 100, seed: 1 },
               gens::uniform(-5.0, 5.0),
               |x| {
                   prop_assert!(x * x >= 0.0, "x^2 < 0 for {x}");
                   Ok(())
               });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn forall_reports_failure() {
        forall("always fails", &Config { cases: 10, seed: 2 },
               gens::uniform(0.0, 1.0),
               |x| Err(format!("nope: {x}")));
    }

    #[test]
    fn generators_in_range() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..100 {
            let u = gens::usize_in(3, 7).gen(&mut rng);
            assert!((3..=7).contains(&u));
        }
    }
}
