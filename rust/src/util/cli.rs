//! Tiny CLI argument parser substrate (offline build: no `clap`).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! arguments, and typed lookups with defaults.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (usually `std::env::args().skip(1)`).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Self {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flags.get(key).map(|s| s.as_str()).unwrap_or(default)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.flags
            .get(key)
            .map(|v| matches!(v.as_str(), "true" | "1" | "yes"))
            .unwrap_or(default)
    }

    /// Optional list: `--taus 0,5,10`.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.flags.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{key}: bad entry {s:?}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&["train", "--m", "100", "--tau=32", "--verbose", "--out", "x.csv"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize_or("m", 0), 100);
        assert_eq!(a.usize_or("tau", 0), 32);
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.str_or("out", ""), "x.csv");
        assert_eq!(a.usize_or("missing", 7), 7);
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse(&["--lr=-0.5", "--x", "2"]);
        assert_eq!(a.f64_or("lr", 0.0), -0.5);
        assert_eq!(a.f64_or("x", 0.0), 2.0);
    }

    #[test]
    fn lists() {
        let a = parse(&["--taus", "0,5,10,20"]);
        assert_eq!(a.usize_list_or("taus", &[]), vec![0, 5, 10, 20]);
        assert_eq!(a.usize_list_or("other", &[1]), vec![1]);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["--sync"]);
        assert!(a.bool_or("sync", false));
    }
}
