//! Deterministic RNG substrate (offline build: no `rand` crate).
//!
//! `Pcg64` is the PCG-XSL-RR 128/64 generator — small state, excellent
//! statistical quality, splittable via `SplitMix64`-derived streams.
//! Normal variates use the Box–Muller transform with caching.

/// SplitMix64: used to seed/derive independent streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSL-RR 128/64.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Seeded generator; `stream` selects an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ (stream.wrapping_mul(0xA3EC_647_659_359_409)));
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let i0 = sm.next_u64() as u128;
        let i1 = sm.next_u64() as u128;
        let mut rng = Self {
            state: (s0 << 64) | s1,
            inc: (((i0 << 64) | i1) << 1) | 1,
        };
        rng.next_u64();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard normal via Box–Muller (no caching: keeps state simple
    /// and reproducible across call sites).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// N(mean, std^2).
    #[inline]
    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_independent() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 0);
        let mut c = Pcg64::new(42, 1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Pcg64::seeded(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Pcg64::seeded(7);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_f64()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let skew = xs.iter().map(|x| (x - mean).powi(3)).sum::<f64>()
            / (n as f64 * var.powf(1.5));
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
        assert!(skew.abs() < 0.03, "skew={skew}");
    }

    #[test]
    fn next_below_unbiased_ish() {
        let mut r = Pcg64::seeded(11);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.next_below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::seeded(4);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut u = s.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 20);
        assert!(u.iter().all(|&i| i < 50));
    }
}
