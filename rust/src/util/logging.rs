//! Leveled stderr logger substrate (offline build: no `log`/`env_logger`).
//!
//! Level comes from `ADVGP_LOG` (error|warn|info|debug|trace), default
//! `info`.  Messages carry elapsed wall-clock since process start.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();

fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

/// Initialize from the environment; safe to call many times.
pub fn init() {
    start();
    if let Ok(v) = std::env::var("ADVGP_LOG") {
        let lv = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        };
        LEVEL.store(lv as u8, Ordering::Relaxed);
    }
}

pub fn set_level(lv: Level) {
    LEVEL.store(lv as u8, Ordering::Relaxed);
}

pub fn enabled(lv: Level) -> bool {
    lv as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(lv: Level, module: &str, msg: std::fmt::Arguments) {
    if enabled(lv) {
        let t = start().elapsed().as_secs_f64();
        let tag = match lv {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {tag} {module}] {msg}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info,
                                   module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn,
                                   module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug,
                                   module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error,
                                   module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
