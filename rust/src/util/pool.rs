//! Persistent work-sharing thread pool for the dense-linalg hot paths.
//!
//! std-only (the build is offline — no `rayon`).  Design:
//!
//! * One **global pool**, sized by `ADVGP_THREADS` (default: available
//!   parallelism), spawned lazily on first parallel dispatch.  A size of
//!   1 means "no helper threads": every dispatch runs inline, so
//!   `ADVGP_THREADS=1` reproduces the old single-threaded behaviour
//!   with zero queueing overhead.
//! * **Work-sharing**: the *calling* thread always participates in its
//!   own task set, so progress never depends on free pool workers —
//!   several parameter-server workers can dispatch concurrently without
//!   risk of deadlock (a caller whose helpers are busy simply does all
//!   the work itself).
//! * **Nested dispatch** from inside a pool job runs inline (serial):
//!   no recursive fan-out, no oversubscription.
//! * A thread-local **budget** ([`with_budget`]) caps the parallelism
//!   of a region, letting the parameter server split the machine across
//!   its worker threads (`ps::TrainConfig::worker_threads`).
//!
//! Determinism: the pool only distributes *which thread* computes a
//! block; every block's internal arithmetic order is fixed by the
//! kernel, so per-row results are bitwise identical at any thread
//! count (see `linalg`).

use std::cell::Cell;
use std::marker::PhantomData;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// The pool: `threads - 1` helper threads plus the calling thread.
pub struct ThreadPool {
    tx: Mutex<Sender<Job>>,
    workers: usize,
}

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

thread_local! {
    /// True on pool helper threads: nested dispatch runs inline there.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
    /// Per-thread parallelism cap (see [`with_budget`]).
    static BUDGET: Cell<usize> = const { Cell::new(usize::MAX) };
}

fn configured_threads() -> usize {
    let auto = || std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    match std::env::var("ADVGP_THREADS") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                // A typo'd/zero value must not silently serialize the
                // whole process: warn and fall back to the default.
                eprintln!(
                    "warning: invalid ADVGP_THREADS={v:?}; using available parallelism"
                );
                auto()
            }
        },
        Err(_) => auto(),
    }
}

/// The global pool (created on first use).
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| ThreadPool::new(configured_threads()))
}

/// Total thread budget of the global pool (helpers + caller).
pub fn threads() -> usize {
    global().workers + 1
}

/// Parallelism available to the *current* thread right now: 1 on pool
/// helpers and under `with_budget(1)`, otherwise min(pool, budget).
pub fn effective_parallelism() -> usize {
    if IN_POOL.with(|f| f.get()) {
        1
    } else {
        threads().min(BUDGET.with(|b| b.get())).max(1)
    }
}

struct BudgetGuard {
    prev: usize,
}

impl Drop for BudgetGuard {
    fn drop(&mut self) {
        BUDGET.with(|b| b.set(self.prev));
    }
}

/// Run `f` with this thread's parallel dispatches capped at `n` lanes
/// (n = 1 forces fully serial execution).  Restored on exit, including
/// on panic.
pub fn with_budget<R>(n: usize, f: impl FnOnce() -> R) -> R {
    let _guard = BUDGET.with(|b| {
        let prev = b.get();
        b.set(n.max(1));
        BudgetGuard { prev }
    });
    f()
}

impl ThreadPool {
    /// Pool with `threads` total lanes (spawns `threads - 1` helpers).
    fn new(threads: usize) -> Self {
        let workers = threads.saturating_sub(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..workers {
            let rx = Arc::clone(&rx);
            std::thread::Builder::new()
                .name(format!("advgp-pool-{i}"))
                .spawn(move || {
                    IN_POOL.with(|f| f.set(true));
                    loop {
                        // Hold the lock only for the blocking recv; jobs
                        // run outside it so helpers execute in parallel.
                        let job = match rx.lock().unwrap().recv() {
                            Ok(j) => j,
                            Err(_) => return,
                        };
                        job();
                    }
                })
                .expect("spawn pool worker");
        }
        Self { tx: Mutex::new(tx), workers }
    }
}

/// Shared state of one `parallel_tasks` call, reference-counted so
/// queued-but-stale helper jobs stay sound after the caller returns.
struct JobState {
    /// Lifetime-erased task body, kept as a *raw* pointer: a stale
    /// queued job may hold this state after the `parallel_tasks` frame
    /// (and the closure it points at) is gone, and a raw pointer —
    /// unlike a reference — is allowed to dangle while unused.  It is
    /// re-bound to a reference only for task indices claimed from
    /// `next`, and the caller blocks until every claimed index has
    /// finished, so the pointee is always alive at dereference time.
    f: *const (dyn Fn(usize) + Sync),
    next: AtomicUsize,
    tasks: usize,
    done: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
    /// First panic payload, re-thrown on the calling thread so the
    /// original message/location survives the pool boundary.
    payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

// Safety: `f` points at a `Sync` closure (so shared cross-thread calls
// are fine) and is only dereferenced under the claimed-task protocol
// documented on the field; all other fields are Sync.
unsafe impl Send for JobState {}
unsafe impl Sync for JobState {}

impl JobState {
    /// Claim-and-run until the cursor is exhausted.  After a failure,
    /// remaining claims are skipped (no wasted work) but still counted
    /// done, so waiters cannot hang; the first payload is kept.
    fn drain(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.tasks {
                return;
            }
            if !self.panicked.load(Ordering::Relaxed) {
                // Safety: `i < tasks` was claimed, so the caller frame
                // (owning the closure) is still blocked in `wait_all`.
                let f = unsafe { &*self.f };
                if let Err(p) = catch_unwind(AssertUnwindSafe(|| f(i))) {
                    let mut slot = self.payload.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(p);
                    }
                    self.panicked.store(true, Ordering::Relaxed);
                }
            }
            let mut d = self.done.lock().unwrap();
            *d += 1;
            if *d == self.tasks {
                self.cv.notify_all();
            }
        }
    }

    /// Block until every task (not every helper job) has completed —
    /// a caller whose tasks were all claimed returns immediately even
    /// if its queued helper jobs are still waiting behind another
    /// caller's work in the shared queue.
    fn wait_all(&self) {
        let mut d = self.done.lock().unwrap();
        while *d < self.tasks {
            d = self.cv.wait(d).unwrap();
        }
    }
}

/// Run `f(i)` for every `i in 0..tasks` across the pool, blocking until
/// all tasks finish.  Tasks are claimed dynamically (an atomic cursor),
/// the caller participates, and each task runs exactly once.  Tasks
/// must be independent; use [`DisjointMut`] for split output buffers.
pub fn parallel_tasks(tasks: usize, f: &(dyn Fn(usize) + Sync)) {
    if tasks == 0 {
        return;
    }
    let par = effective_parallelism();
    let pool = global();
    let helpers = pool.workers.min(par.saturating_sub(1)).min(tasks - 1);
    if helpers == 0 {
        // Fast path: no state, no unwind shims.
        for i in 0..tasks {
            f(i);
        }
        return;
    }
    // Lifetime erasure (see `JobState::f`): jobs that find the cursor
    // exhausted exit without ever touching `f`; jobs that claim a task
    // finish it before `wait_all` lets this frame return.  The Arc
    // keeps the state itself alive for stale queued jobs.
    // (transmute, not `as`: an `as`-cast may not widen the trait
    // object's lifetime bound to the pointer type's `'static` default)
    let f_ptr: *const (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
    let state = Arc::new(JobState {
        f: f_ptr,
        next: AtomicUsize::new(0),
        tasks,
        done: Mutex::new(0),
        cv: Condvar::new(),
        panicked: AtomicBool::new(false),
        payload: Mutex::new(None),
    });
    {
        let tx = pool.tx.lock().unwrap();
        for _ in 0..helpers {
            let s = Arc::clone(&state);
            tx.send(Box::new(move || s.drain())).expect("pool alive");
        }
    }
    state.drain(); // the caller always participates
    state.wait_all();
    if let Some(p) = state.payload.lock().unwrap().take() {
        resume_unwind(p);
    }
}

/// Split `0..total` into contiguous blocks of (up to) `block` items and
/// run them on the pool.
pub fn parallel_blocks(total: usize, block: usize, f: &(dyn Fn(Range<usize>) + Sync)) {
    if total == 0 {
        return;
    }
    let block = block.max(1);
    let n = (total + block - 1) / block;
    parallel_tasks(n, &|i| {
        let lo = i * block;
        f(lo..(lo + block).min(total))
    });
}

/// Block size giving each available lane a few blocks (load balance
/// without excessive dispatch overhead).  For kernels whose per-block
/// work streams only the block itself.
pub fn block_size(total: usize) -> usize {
    let lanes = effective_parallelism() * 4;
    ((total + lanes - 1) / lanes).max(1)
}

/// Block size for kernels whose *every block* re-streams a whole input
/// operand (transpose-side reductions: tr_matmul/gram/col_sums): one
/// block per lane, since extra blocks multiply memory traffic, not
/// balance.
pub fn block_size_full_pass(total: usize) -> usize {
    let lanes = effective_parallelism();
    ((total + lanes - 1) / lanes).max(1)
}

/// Hands out non-overlapping `&mut` windows of one slice to parallel
/// tasks.  The exclusive borrow on `data` pins the slice for the
/// wrapper's lifetime.
pub struct DisjointMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Sync for DisjointMut<'_, T> {}
unsafe impl<T: Send> Send for DisjointMut<'_, T> {}

impl<'a, T> DisjointMut<'a, T> {
    pub fn new(data: &'a mut [T]) -> Self {
        Self { ptr: data.as_mut_ptr(), len: data.len(), _marker: PhantomData }
    }

    /// # Safety
    /// Ranges taken by concurrently-live calls must be disjoint.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range(&self, r: Range<usize>) -> &mut [T] {
        debug_assert!(r.start <= r.end && r.end <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(r.start), r.end - r.start)
    }
}

/// Parallel map over disjoint row blocks of a row-major buffer:
/// `f(first_row, block_slice)` with `block_slice` covering whole rows.
pub fn parallel_rows_mut<T: Send>(
    out: &mut [T],
    row_len: usize,
    rows: usize,
    rows_per_block: usize,
    f: &(dyn Fn(usize, &mut [T]) + Sync),
) {
    assert!(rows * row_len <= out.len(), "row blocks exceed buffer");
    let cells = DisjointMut::new(out);
    parallel_blocks(rows, rows_per_block, &|r: Range<usize>| {
        // Safety: blocks from `parallel_blocks` are disjoint row ranges.
        let s = unsafe { cells.range(r.start * row_len..r.end * row_len) };
        f(r.start, s)
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tasks_run_exactly_once() {
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        parallel_tasks(97, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn blocks_cover_range() {
        for total in [0usize, 1, 2, 7, 64, 129] {
            for block in [1usize, 3, 64] {
                let seen: Vec<AtomicUsize> =
                    (0..total).map(|_| AtomicUsize::new(0)).collect();
                parallel_blocks(total, block, &|r| {
                    for i in r {
                        seen[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert!(seen.iter().all(|s| s.load(Ordering::Relaxed) == 1));
            }
        }
    }

    #[test]
    fn rows_mut_writes_disjoint() {
        let mut out = vec![0.0f64; 7 * 5];
        parallel_rows_mut(&mut out, 5, 7, 2, &|r0, blk| {
            for (i, v) in blk.iter_mut().enumerate() {
                *v = (r0 * 5 + i) as f64;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f64);
        }
    }

    #[test]
    fn nested_dispatch_is_serial_and_correct() {
        let total = AtomicUsize::new(0);
        parallel_tasks(8, &|_| {
            // Inner dispatch: inline on pool helpers, still correct.
            parallel_tasks(16, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 8 * 16);
    }

    #[test]
    fn budget_one_is_inline() {
        with_budget(1, || {
            assert_eq!(effective_parallelism(), 1);
            let n = AtomicUsize::new(0);
            parallel_tasks(32, &|_| {
                n.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(n.load(Ordering::Relaxed), 32);
        });
        assert!(effective_parallelism() >= 1);
    }

    #[test]
    fn budget_restored_after_panic() {
        let before = BUDGET.with(|b| b.get());
        let r = catch_unwind(AssertUnwindSafe(|| {
            with_budget(1, || panic!("boom"));
        }));
        assert!(r.is_err());
        assert_eq!(BUDGET.with(|b| b.get()), before);
    }

    #[test]
    fn task_panic_propagates() {
        let r = catch_unwind(AssertUnwindSafe(|| {
            parallel_tasks(64, &|i| {
                if i == 13 {
                    panic!("task 13");
                }
            });
        }));
        // The original payload must cross the pool boundary intact.
        let p = r.expect_err("must panic");
        let msg = p.downcast_ref::<&str>().copied().unwrap_or_default();
        assert_eq!(msg, "task 13");
        // Pool must stay usable after a panicked dispatch.
        let n = AtomicUsize::new(0);
        parallel_tasks(8, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 8);
    }
}
