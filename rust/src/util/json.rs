//! Minimal JSON substrate (offline build: no `serde`).
//!
//! Supports the full JSON data model; used for the artifact manifest,
//! run configs, and metrics dumps.  Not performance-critical.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at {}", p.i));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad utf8")?,
                                16,
                            )
                            .map_err(|_| "bad hex")?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "bad utf8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "s": "x\n\"y\""}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x\n\"y\""));
    }

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{"version": 1, "artifacts": [{"kind": "grad", "m": 50, "d": 8, "b": 1024, "file": "g.hlo.txt"}]}"#;
        let v = Json::parse(src).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("m").unwrap().as_usize(), Some(50));
        assert_eq!(arts[0].get("kind").unwrap().as_str(), Some("grad"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn integers_serialize_clean() {
        assert_eq!(Json::Num(50.0).to_string(), "50");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
