//! Shared substrates: RNG, JSON, CLI parsing, logging, timing, the
//! global thread pool, and streaming statistics.
//!
//! The build environment is offline (only `xla` + `anyhow` resolve), so
//! these replace the usual crates (`rand`, `serde_json`, `clap`, `log`).
//!
//! Key invariants:
//! * [`rng::Pcg64`] streams are deterministic per seed — every
//!   experiment, shuffle, and worker offset is reproducible.
//! * [`Stats`] memory is bounded (Welford summaries + a 512-slot
//!   quantile reservoir) for any stream length, so server metrics
//!   never grow with run length.
//! * [`pool`] is work-*sharing*: dispatchers execute part of their own
//!   task set and nested dispatch runs inline, so concurrent
//!   parameter-server workers can never deadlock the pool.

pub mod cli;
pub mod json;
pub mod logging;
pub mod pool;
pub mod rng;

use std::time::Instant;

/// Monotonic stopwatch used across metrics and traces.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Capacity of the [`Stats`] quantile reservoir: memory stays bounded
/// no matter how many samples are pushed (ISSUE 2 satellite — server
/// stats on long runs must not grow linearly).
const RESERVOIR_CAP: usize = 512;

/// Streaming mean/variance/min/max accumulator (Welford) plus a
/// **bounded reservoir sample** (Vitter's Algorithm R, deterministic
/// internal RNG) for quantile estimates.  O(RESERVOIR_CAP) memory for
/// any stream length.
#[derive(Clone, Debug)]
pub struct Stats {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
    /// Uniform sample of the stream, ≤ RESERVOIR_CAP entries.
    reservoir: Vec<f64>,
    /// xorshift64* state for reservoir replacement (fixed seed: stats
    /// are reproducible for a fixed push sequence).
    rng: u64,
}

impl Default for Stats {
    fn default() -> Self {
        Self::new()
    }
}

impl Stats {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            reservoir: Vec::new(),
            rng: 0x9E37_79B9_7F4A_7C15,
        }
    }

    fn next_u64(&mut self) -> u64 {
        let mut x = self.rng;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.rng = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.reservoir.len() < RESERVOIR_CAP {
            self.reservoir.push(x);
        } else {
            // Algorithm R: keep each of the n samples with prob CAP/n.
            let j = (self.next_u64() % self.n) as usize;
            if j < RESERVOIR_CAP {
                self.reservoir[j] = x;
            }
        }
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Quantile estimate (q in [0, 1]) from the bounded reservoir —
    /// exact while n ≤ RESERVOIR_CAP, a uniform-sample estimate beyond.
    /// NaN when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.reservoir.is_empty() {
            return f64::NAN;
        }
        let mut s = self.reservoir.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let idx = ((s.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
        s[idx]
    }
}

/// Write `bytes` to `path` durably: create `<path>.tmp` beside it,
/// write, fsync, atomically rename into place, then best-effort fsync
/// the parent directory so the rename itself survives a crash.  A
/// failure can never leave a partial file at `path`.  The single
/// durability-policy point shared by checkpoints and store manifests
/// (the streaming shard writer follows the same discipline inline).
pub fn atomic_write(path: &std::path::Path, bytes: &[u8]) -> anyhow::Result<()> {
    use anyhow::Context;
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    let tmp = std::path::PathBuf::from(os);
    let mut f = std::fs::File::create(&tmp)
        .with_context(|| format!("create {}", tmp.display()))?;
    std::io::Write::write_all(&mut f, bytes)
        .with_context(|| format!("write {}", tmp.display()))?;
    f.sync_all().with_context(|| format!("fsync {}", tmp.display()))?;
    drop(f);
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))?;
    if let Some(parent) = path.parent() {
        if let Ok(d) = std::fs::File::open(parent) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// FNV-1a 64-bit offset basis — seed for [`fnv1a64`].
pub const FNV1A64_INIT: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold `bytes` into an FNV-1a 64-bit state (seed with
/// [`FNV1A64_INIT`]; chain calls to hash incrementally).  Integrity
/// hashing only — not cryptographic.  Shared by the checkpoint
/// checksum and the shard-store data fingerprint.
pub fn fnv1a64(mut h: u64, bytes: &[u8]) -> u64 {
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Root-mean-square error between two slices.
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    assert!(!pred.is_empty());
    let sse: f64 = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (p - t) * (p - t))
        .sum();
    (sse / pred.len() as f64).sqrt()
}

/// Mean negative log predictive likelihood for Gaussian predictions
/// (Appendix D's MNLP): mean of -log N(y | mean_i, var_i).
pub fn mnlp(mean: &[f64], var: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(mean.len(), truth.len());
    assert_eq!(var.len(), truth.len());
    let n = mean.len() as f64;
    let s: f64 = mean
        .iter()
        .zip(var)
        .zip(truth)
        .map(|((m, v), t)| {
            let v = v.max(1e-12);
            0.5 * ((2.0 * std::f64::consts::PI * v).ln() + (t - m) * (t - m) / v)
        })
        .sum();
    s / n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_welford() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    /// Reservoir memory stays bounded for arbitrarily long streams and
    /// quantiles remain sane estimates.
    #[test]
    fn stats_reservoir_bounded_and_quantiles_sane() {
        let mut s = Stats::new();
        // Exact regime: n ≤ cap.
        for i in 0..100 {
            s.push(i as f64);
        }
        assert_eq!(s.quantile(0.0), 0.0);
        assert_eq!(s.quantile(1.0), 99.0);
        assert!((s.quantile(0.5) - 49.5).abs() <= 0.5);
        // Long-stream regime: memory bounded, estimates in-range.
        for i in 100..200_000 {
            s.push((i % 1000) as f64);
        }
        assert!(s.reservoir.len() <= RESERVOIR_CAP, "reservoir grew unbounded");
        assert_eq!(s.n, 200_000);
        let p50 = s.quantile(0.5);
        assert!((0.0..=999.0).contains(&p50));
        // Uniform 0..999 stream: the sampled median lands near 500.
        assert!((p50 - 500.0).abs() < 120.0, "p50 estimate {p50}");
        // Welford summaries are unaffected by the reservoir.
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 999.0);
        // Empty stats: quantile is NaN, min/max are sentinels.
        let e = Stats::default();
        assert!(e.quantile(0.5).is_nan());
        assert!(e.min.is_infinite() && e.max.is_infinite());
    }

    #[test]
    fn rmse_known() {
        assert!((rmse(&[1.0, 2.0], &[0.0, 4.0]) - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(rmse(&[3.0], &[3.0]), 0.0);
    }

    #[test]
    fn mnlp_standard_normal() {
        // -log N(0 | 0, 1) = 0.5 ln(2 pi)
        let v = mnlp(&[0.0], &[1.0], &[0.0]);
        assert!((v - 0.5 * (2.0 * std::f64::consts::PI).ln()).abs() < 1e-12);
    }

    #[test]
    fn mnlp_penalizes_overconfidence() {
        // Same error, smaller variance -> larger MNLP.
        let tight = mnlp(&[0.0], &[0.01], &[1.0]);
        let loose = mnlp(&[0.0], &[1.0], &[1.0]);
        assert!(tight > loose);
    }
}
