//! Shared substrates: RNG, JSON, CLI parsing, logging, timing.
//!
//! The build environment is offline (only `xla` + `anyhow` resolve), so
//! these replace the usual crates (`rand`, `serde_json`, `clap`, `log`).

pub mod cli;
pub mod json;
pub mod logging;
pub mod pool;
pub mod rng;

use std::time::Instant;

/// Monotonic stopwatch used across metrics and traces.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Simple online mean/variance/min/max accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Stats {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Root-mean-square error between two slices.
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    assert!(!pred.is_empty());
    let sse: f64 = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (p - t) * (p - t))
        .sum();
    (sse / pred.len() as f64).sqrt()
}

/// Mean negative log predictive likelihood for Gaussian predictions
/// (Appendix D's MNLP): mean of -log N(y | mean_i, var_i).
pub fn mnlp(mean: &[f64], var: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(mean.len(), truth.len());
    assert_eq!(var.len(), truth.len());
    let n = mean.len() as f64;
    let s: f64 = mean
        .iter()
        .zip(var)
        .zip(truth)
        .map(|((m, v), t)| {
            let v = v.max(1e-12);
            0.5 * ((2.0 * std::f64::consts::PI * v).ln() + (t - m) * (t - m) / v)
        })
        .sum();
    s / n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_welford() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn rmse_known() {
        assert!((rmse(&[1.0, 2.0], &[0.0, 4.0]) - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(rmse(&[3.0], &[3.0]), 0.0);
    }

    #[test]
    fn mnlp_standard_normal() {
        // -log N(0 | 0, 1) = 0.5 ln(2 pi)
        let v = mnlp(&[0.0], &[1.0], &[0.0]);
        assert!((v - 0.5 * (2.0 * std::f64::consts::PI).ln()).abs() < 1e-12);
    }

    #[test]
    fn mnlp_penalizes_overconfidence() {
        // Same error, smaller variance -> larger MNLP.
        let tight = mnlp(&[0.0], &[0.01], &[1.0]);
        let loose = mnlp(&[0.0], &[1.0], &[1.0]);
        assert!(tight > loose);
    }
}
