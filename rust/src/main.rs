//! `advgp` — the command-line launcher for the ADVGP system.
//!
//! Subcommands:
//!   train      train a GP regression model (ADVGP / baselines) on CSV
//!              or synthetic data and report RMSE/MNLP
//!   serve-ps   run the parameter server over the ADVGPNT1 networked
//!              transport; `advgp worker` processes connect to it
//!   worker     join a serve-ps run as a remote worker, streaming its
//!              shard from an on-disk store
//!   store      offline shard-store tools (ISSUE 7): verify (full
//!              scrub), migrate (ADVGPSH1 → SH2 in place), repartition
//!              (remap chunk ranges to a new worker count)
//!   serve-replica  stateless read-path replica (ADVGPSV1): subscribe
//!              to a serve-ps fleet's publish streams, rebuild the
//!              posterior locally, answer PREDICT sessions
//!   loadgen    open-loop load generator + scoreboard against one or
//!              more replicas; merge-writes BENCH_serve.json
//!   route      predict-side routing tier (ADVGPRT1): one address in
//!              front of a replica fleet — P2C balancing, sibling
//!              retry, per-leg answer caches, heartbeat retirement
//!   datagen    write a synthetic dataset (flight|taxi|friedman) as CSV
//!   artifacts  list the AOT artifact manifest
//!   smoke      PJRT round-trip smoke test on an HLO text file

use advgp::baselines::BaselineResult;
use advgp::data::store::ShardSet;
use advgp::data::{csv, synth, Dataset};
use advgp::experiments::methods::*;
use advgp::experiments::{make_problem, print_table, Problem};
use advgp::grad::native_factory;
use advgp::opt::StepSchedule;
use advgp::ps::coordinator::native_eval_factory;
use advgp::ps::{train_remote, Checkpoint, TrainConfig};
use advgp::runtime::{engine::xla_factory, ArtifactKind, Manifest};
use advgp::util::cli::Args;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

fn main() -> Result<()> {
    advgp::util::logging::init();
    let args = Args::from_env();
    // Install the compute backend process-wide before any subcommand
    // builds an engine (ISSUE 10): `--backend` beats `ADVGP_BACKEND`
    // beats the scalar default.  An unknown name or an unavailable
    // backend is a typed error here, not a panic mid-run.
    advgp::runtime::backend::set_active(backend_arg(&args)?)?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("serve-ps") => cmd_serve_ps(&args),
        Some("worker") => cmd_worker(&args),
        Some("serve-replica") => cmd_serve_replica(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("route") => cmd_route(&args),
        Some("store") => cmd_store(&args),
        Some("datagen") => cmd_datagen(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some("smoke") => cmd_smoke(&args),
        _ => {
            eprintln!(
                "usage: advgp <train|serve-ps|worker|serve-replica|loadgen|route|store|\
                 datagen|artifacts|smoke> [--flags]\n\
                 \n\
                 train:    --data <csv|flight|taxi|friedman> [--n 50000] [--m 100]\n\
                 \x20         [--method advgp|svigp|distgp-gd|distgp-lbfgs|linear]\n\
                 \x20         [--workers 4] [--servers 1] [--tau 32] [--budget 30]\n\
                 \x20         [--engine native|xla] [--backend scalar|simd|auto|xla]\n\
                 \x20         [--store dir] [--chunk-rows 4096]\n\
                 \x20         [--checkpoint-every 0] [--checkpoint-dir dir]\n\
                 \x20         [--keep-last K] [--resume] [--out-trace trace.csv]\n\
                 serve-ps: --addr 127.0.0.1:7171 --workers 2 --data <...> [--n 50000]\n\
                 \x20         [--m 100] [--tau 32] [--budget 60] [--max-updates N]\n\
                 \x20         [--servers S | --slice i/S]   (partitioned θ, ADVGPNT2)\n\
                 \x20         [--store dir] [--chunk-rows 4096] [--checkpoint-every N]\n\
                 \x20         [--checkpoint-dir dir] [--keep-last K] [--resume]\n\
                 worker:   --connect host:port[,host:port…] --store dir --shard K\n\
                 \x20         (one address per slice server of a partitioned fleet)\n\
                 \x20         [--worker-id id] [--chunk-rows n] [--max-rows n]\n\
                 \x20         [--threads n] [--straggle-ms n]\n\
                 serve-replica: --connect host:port[,host:port…] (the serve-ps fleet)\n\
                 \x20         [--listen 127.0.0.1:0] [--staleness-secs 10]\n\
                 \x20         [--max-inflight-rows 4096] [--batch-rows 256]\n\
                 \x20         [--latency-budget-ms 2] [--linger-secs 0]\n\
                 loadgen:  --replicas host:port[,host:port…] [--qps 500]\n\
                 \x20         [--requests 2000] [--rows 8] [--seed 42]\n\
                 \x20         [--bench-out BENCH_serve.json] [--name serve/replicas=N]\n\
                 route:    --replicas host:port[,host:port…] (replica predict addrs)\n\
                 \x20         [--listen 127.0.0.1:0] [--cache-rows 4096]\n\
                 \x20         [--retry-hops 1] [--seed …] [--secs 0 (forever)]\n\
                 store:    <verify|migrate|repartition> --store dir [--workers W]\n\
                 \x20         verify: scrub every chunk checksum, per-chunk report\n\
                 \x20         migrate: upgrade ADVGPSH1 shards to SH2 in place\n\
                 \x20         repartition: remap chunks to W workers, bytes untouched\n\
                 datagen:  --kind flight|taxi|friedman --n 10000 --out data.csv [--seed 0]\n\
                 artifacts: [--dir artifacts]\n\
                 smoke:    [--hlo /tmp/fn_hlo.txt]"
            );
            std::process::exit(2);
        }
    }
}

/// Resolve this invocation's compute backend: the `--backend` flag
/// wins, else the `ADVGP_BACKEND` env selection (scalar when unset;
/// an unknown env value warns and falls back to scalar, but an unknown
/// *flag* value is an error — the user explicitly asked for it).
fn backend_arg(args: &Args) -> Result<advgp::runtime::Backend> {
    match args.get("backend") {
        Some(v) => Ok(advgp::runtime::Backend::parse(v)?),
        None => Ok(advgp::runtime::Backend::from_env()),
    }
}

fn load_data(args: &Args) -> Result<Dataset> {
    let spec = args.str_or("data", "flight");
    let n = args.usize_or("n", 50_000);
    let seed = args.u64_or("seed", 0);
    Ok(match spec {
        "flight" => synth::flight_like(n, seed),
        "taxi" => synth::taxi_like(n, seed),
        "friedman" => synth::friedman(n, 4, 0.4, seed),
        path => csv::read_dataset(Path::new(path))
            .with_context(|| format!("loading CSV {path}"))?,
    })
}

/// Reuse a shard store if `dir` holds one (validating shape, content
/// fingerprint, and that explicit flags don't contradict the frozen
/// partition), or partition the standardized train set into one.
/// Shared by `train --store` and `serve-ps --store`.
fn open_or_create_store(
    dir: &Path,
    train: &Dataset,
    workers: usize,
    args: &Args,
) -> Result<ShardSet> {
    if ShardSet::exists(dir) {
        let s = ShardSet::open(dir)?;
        anyhow::ensure!(
            s.n() == train.n() && s.d() == train.d(),
            "store {} holds n={} d={} but this run has n={} d={} \
             (delete the dir or match --data/--n/--seed)",
            dir.display(),
            s.n(),
            s.d(),
            train.n(),
            train.d()
        );
        // Shape can collide across seeds/regenerated files; the content
        // fingerprint cannot.
        anyhow::ensure!(
            s.fingerprint() == advgp::data::store::dataset_fingerprint(train),
            "store {} was built from different data than this run \
             (same shape, different contents — check --data/--seed \
             or delete the store)",
            dir.display()
        );
        // A reused store fixes the partition: explicit flags that
        // contradict it are an error, not a silent override.  The
        // *logical* worker count is authoritative — `advgp store
        // repartition` can remap chunks to more or fewer workers than
        // there are shard files (ISSUE 7).
        anyhow::ensure!(
            args.get("workers").is_none() || workers == s.logical_workers(),
            "--workers {workers} contradicts store {} ({} logical worker(s) \
             over {} file(s)); drop the flag, recreate the store, or run \
             `advgp store repartition --workers {workers}`",
            dir.display(),
            s.logical_workers(),
            s.r()
        );
        anyhow::ensure!(
            args.get("chunk-rows").is_none()
                || args.usize_or("chunk-rows", 0) == s.chunk_rows(),
            "--chunk-rows {} contradicts store {} (chunk {}); drop \
             the flag or recreate the store",
            args.usize_or("chunk-rows", 0),
            dir.display(),
            s.chunk_rows()
        );
        println!(
            "store: reusing {} ({} file(s), {} logical worker(s), chunk {})",
            dir.display(),
            s.r(),
            s.logical_workers(),
            s.chunk_rows()
        );
        Ok(s)
    } else {
        let chunk = args.usize_or("chunk-rows", 4096);
        let s = ShardSet::create(dir, train, workers, chunk)?;
        println!(
            "store: wrote {} shards ({} rows, chunk {chunk}) to {}",
            s.r(),
            s.n(),
            dir.display()
        );
        Ok(s)
    }
}

/// Parse the durability flags shared by `train` and `serve-ps`:
/// `--checkpoint-every N`, `--checkpoint-dir`, `--keep-last K`,
/// `--resume`.  Returns (cadence, dir, resume checkpoint, keep-last).
fn checkpoint_flags(
    args: &Args,
    store_dir: Option<&PathBuf>,
) -> Result<(u64, PathBuf, Option<Checkpoint>, Option<usize>)> {
    let checkpoint_every = args.u64_or("checkpoint-every", 0);
    anyhow::ensure!(
        args.get("checkpoint-dir").is_none()
            || checkpoint_every > 0
            || args.bool_or("resume", false),
        "--checkpoint-dir does nothing on its own: add --checkpoint-every N \
         (to write checkpoints) or --resume (to restore from them)"
    );
    anyhow::ensure!(
        args.get("keep-last").is_none() || checkpoint_every > 0,
        "--keep-last does nothing without --checkpoint-every N"
    );
    let keep_last = match args.get("keep-last") {
        None => None,
        Some(v) => {
            let k: usize = v
                .parse()
                .map_err(|_| anyhow::anyhow!("--keep-last wants an integer, got {v:?}"))?;
            anyhow::ensure!(k >= 1, "--keep-last wants K ≥ 1 (the seal must survive)");
            Some(k)
        }
    };
    let checkpoint_dir = args
        .get("checkpoint-dir")
        .map(PathBuf::from)
        .or_else(|| store_dir.map(|d| d.join("checkpoints")))
        .unwrap_or_else(|| PathBuf::from("checkpoints"));
    let resume_from = if args.bool_or("resume", false) {
        // `load_latest_any` handles both directory shapes: flat
        // single-server files and sharded (topology manifest +
        // per-slice subdirectories, reassembled bitwise).
        let ck = Checkpoint::load_latest_any(&checkpoint_dir)?.with_context(|| {
            format!("--resume: no checkpoint in {}", checkpoint_dir.display())
        })?;
        println!(
            "resuming from version {} ({})",
            ck.version,
            checkpoint_dir.display()
        );
        // Provenance across resumes, from the lineage manifest.
        match advgp::ps::checkpoint::provenance(&checkpoint_dir) {
            Ok(p) if !p.is_empty() => print!("lineage:\n{p}"),
            Ok(_) => {}
            Err(e) => eprintln!("lineage manifest unreadable: {e:#}"),
        }
        Some(ck)
    } else {
        None
    };
    Ok((checkpoint_every, checkpoint_dir, resume_from, keep_last))
}

/// Final RMSE/MNLP table (original target units) + optional trace CSV.
fn report_result(
    method: &str,
    p: &Problem,
    result: &BaselineResult,
    args: &Args,
) -> Result<()> {
    if let Some(out) = args.get("out-trace") {
        advgp::ps::metrics::write_trace_csv(Path::new(out), &result.trace)?;
        println!("trace -> {out}");
    }
    let y_std = p.standardizer.y_std;
    let mean = run_mean_method(p);
    print_table(
        "results (original target units)",
        &["Method", "RMSE", "MNLP", "wall (s)"],
        &[
            vec![
                method.to_string(),
                format!("{:.4}", final_rmse(result) * y_std),
                format!("{:.4}", final_mnlp(result)),
                format!("{:.1}", result.wall_secs),
            ],
            vec![
                "mean".into(),
                format!("{:.4}", final_rmse(&mean) * y_std),
                "-".into(),
                "0.0".into(),
            ],
        ],
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let raw = load_data(args)?;
    let m = args.usize_or("m", 100);
    let n_test = args.usize_or("n-test", (raw.n() / 10).clamp(100, 100_000));
    let method = args.str_or("method", "advgp").to_string();
    let engine = args.str_or("engine", "native").to_string();
    // Durability flags (ISSUE 3): --checkpoint-every N writes versioned
    // server checkpoints; --resume continues from the newest one.  Only
    // the advgp parameter-server path implements them — reject rather
    // than silently ignore elsewhere.
    if method != "advgp" {
        anyhow::ensure!(
            args.get("store").is_none()
                && args.get("servers").is_none()
                && args.get("checkpoint-every").is_none()
                && args.get("checkpoint-dir").is_none()
                && args.get("keep-last").is_none()
                && !args.bool_or("resume", false),
            "--store/--servers/--checkpoint-every/--checkpoint-dir/--keep-last/\
             --resume only apply to --method advgp (got --method {method})"
        );
    }
    let store_dir = args.get("store").map(PathBuf::from);
    let (checkpoint_every, checkpoint_dir, resume_from, keep_last) =
        checkpoint_flags(args, store_dir.as_ref())?;
    let servers = args.usize_or("servers", 1);
    anyhow::ensure!(
        (1..=advgp::ps::sharded::MAX_SLICES).contains(&servers),
        "--servers wants 1..={}, got {servers}",
        advgp::ps::sharded::MAX_SLICES
    );
    let opts = MethodOpts {
        workers: args.usize_or("workers", 4),
        servers,
        tau: args.u64_or("tau", 32),
        budget_secs: args.f64_or("budget", 30.0),
        eval_every_secs: args.f64_or("eval-every", 0.5),
        lr: args.f64_or("lr", 1.0),
        prox_c: args.f64_or("prox-c", 0.05),
        prox_t0: args.f64_or("prox-t0", 200.0),
        max_rows: args.usize_or("max-rows", 0),
        checkpoint_every,
        checkpoint_dir: (checkpoint_every > 0 || resume_from.is_some())
            .then(|| checkpoint_dir.clone()),
        keep_last,
        resume_from,
        backend: backend_arg(args)?,
        ..Default::default()
    };
    let p = make_problem(raw, n_test, m, 20_000, args.u64_or("seed", 0));
    anyhow::ensure!(
        opts.servers <= p.layout.len(),
        "--servers {} exceeds the θ dimension {} — nothing left to slice",
        opts.servers,
        p.layout.len()
    );
    println!(
        "training {method} on n={} (test {}), d={}, m={m}, θ dim {}",
        p.train.n(), p.test.n(), p.train.d(), p.layout.len()
    );

    let result = match method.as_str() {
        "advgp" => {
            let factory = if engine == "xla" {
                let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
                let man = Manifest::load(&dir)?;
                man.find(ArtifactKind::Grad, m, p.train.d())?;
                Some(xla_factory(man, m, p.train.d()))
            } else {
                None
            };
            if let Some(dir) = &store_dir {
                // Out-of-core path: partition the (standardized) train
                // set to disk once, then every worker streams minibatch
                // chunks from its shard file instead of holding a clone.
                let store = open_or_create_store(dir, &p.train, opts.workers, args)?;
                let f = factory.unwrap_or_else(|| native_factory(p.layout));
                run_advgp_store(&p, &opts, &store, f)?
            } else {
                match factory {
                    Some(f) => run_advgp_with(&p, &opts, f),
                    None => run_advgp(&p, &opts),
                }
            }
        }
        "svigp" => run_svigp_method(&p, &opts),
        "distgp-gd" => run_distgp_gd_method(&p, &opts),
        "distgp-lbfgs" => run_distgp_lbfgs_method(&p, &opts),
        "linear" => run_linear_method(&p, &opts),
        other => bail!("unknown method {other}"),
    };
    report_result(&method, &p, &result, args)
}

/// `advgp serve-ps`: run the θ-server side of a distributed training
/// run over the ADVGPNT1 transport (see docs/PROTOCOL.md).  The server
/// owns the problem definition (data standardization, θ layout, θ₀,
/// evaluation set); workers bring only compute and their shard.  With
/// `--store`, the standardized train set is partitioned to disk so
/// local `advgp worker --store` processes can stream it.
fn cmd_serve_ps(args: &Args) -> Result<()> {
    let raw = load_data(args)?;
    let m = args.usize_or("m", 100);
    let n_test = args.usize_or("n-test", (raw.n() / 10).clamp(100, 100_000));
    let mut workers = args.usize_or("workers", 2);
    let addr = args.str_or("addr", "127.0.0.1:7171");
    let store_dir = args.get("store").map(PathBuf::from);
    let (checkpoint_every, checkpoint_dir, resume_from, keep_last) =
        checkpoint_flags(args, store_dir.as_ref())?;
    let p = make_problem(raw, n_test, m, 20_000, args.u64_or("seed", 0));
    if let Some(dir) = &store_dir {
        let store = open_or_create_store(dir, &p.train, workers, args)?;
        // The store's partition is authoritative: a fresh store was just
        // written with `workers` shards, an explicit contradicting
        // --workers already errored inside open_or_create_store, and a
        // reused store without the flag adopts its frozen (possibly
        // repartitioned) worker count (mirrors `train --store`) instead
        // of failing against the default.
        workers = store.logical_workers();
    }
    let mut cfg = TrainConfig::new(p.layout);
    cfg.tau = args.u64_or("tau", 32);
    cfg.max_updates = args.u64_or("max-updates", u64::MAX / 2);
    cfg.time_limit_secs = Some(args.f64_or("budget", 60.0));
    cfg.eval_every_secs = args.f64_or("eval-every", 0.5);
    cfg.lr = args.f64_or("lr", 1.0);
    cfg.prox = StepSchedule::new(
        args.f64_or("prox-c", 0.05),
        args.f64_or("prox-t0", 200.0),
    );
    cfg.checkpoint_every = checkpoint_every;
    cfg.checkpoint_dir = (checkpoint_every > 0 || resume_from.is_some())
        .then(|| checkpoint_dir.clone());
    cfg.keep_last = keep_last;
    cfg.resume_from = resume_from;
    cfg.backend = backend_arg(args)?;

    // ---- partitioned-θ modes (ISSUE 5) ----
    if let Some(slice_arg) = args.get("slice") {
        // One slice server in this process; the other S−1 run elsewhere
        // (`--slice j/S` each).  Workers connect to all of them.
        anyhow::ensure!(
            args.get("servers").is_none(),
            "--slice i/S and --servers S are mutually exclusive \
             (--servers runs every slice in this process)"
        );
        let (slice_id, n_slices) = parse_slice_arg(slice_arg)?;
        anyhow::ensure!(
            n_slices <= p.layout.len(),
            "--slice {slice_id}/{n_slices}: {n_slices} slices exceed the θ \
             dimension {} — nothing left to slice",
            p.layout.len()
        );
        let net = advgp::ps::NetServer::bind(addr)?;
        println!(
            "serve-ps: ADVGPNT2 rev {} on {} — θ slice {slice_id}/{n_slices}, \
             expecting {workers} worker(s), n={} d={} m={m} (θ dim {}), τ={}",
            advgp::ps::wire::PROTO_VERSION,
            net.local_addr(),
            p.train.n(),
            p.train.d(),
            p.layout.len(),
            cfg.tau
        );
        let res = advgp::ps::train_remote_slice(
            &cfg,
            p.theta0.data.clone(),
            net,
            workers,
            slice_id,
            n_slices,
        );
        // This process never holds the full θ, so there is no final
        // RMSE table — just the slice server's own account of the run.
        println!(
            "serve-ps (slice {slice_id}/{n_slices}): done — {} updates, \
             {} pushes, {} join(s), {} leave(s), {} transport fault(s), \
             {} coordinate(s) owned",
            res.stats.updates,
            res.stats.pushes,
            res.stats.joins,
            res.stats.leaves,
            res.stats.faults,
            res.theta.len()
        );
        return Ok(());
    }

    let servers = args.usize_or("servers", 1);
    anyhow::ensure!(
        (1..=advgp::ps::sharded::MAX_SLICES).contains(&servers)
            && servers <= p.layout.len(),
        "--servers wants 1..={} (and at most the θ dimension {}), got {servers}",
        advgp::ps::sharded::MAX_SLICES,
        p.layout.len()
    );
    let eval = Some(native_eval_factory(p.layout, p.test.clone(), None));
    let res = if servers > 1 {
        let nets = bind_slice_listeners(addr, servers)?;
        let addrs: Vec<String> =
            nets.iter().map(|n| n.local_addr().to_string()).collect();
        println!(
            "serve-ps: ADVGPNT2 rev {} — θ partitioned over {servers} slice \
             server(s) on [{}], expecting {workers} worker(s) connecting to \
             ALL of them (--connect {}), n={} d={} m={m} (θ dim {}), τ={}",
            advgp::ps::wire::PROTO_VERSION,
            addrs.join(", "),
            addrs.join(","),
            p.train.n(),
            p.train.d(),
            p.layout.len(),
            cfg.tau
        );
        advgp::ps::train_remote_sharded(&cfg, p.theta0.data.clone(), nets, workers, eval)
    } else {
        let net = advgp::ps::NetServer::bind(addr)?;
        println!(
            "serve-ps: ADVGPNT rev {} on {} — expecting {workers} worker(s), \
             n={} d={} m={m} (θ dim {}), τ={}",
            advgp::ps::wire::PROTO_VERSION,
            net.local_addr(),
            p.train.n(),
            p.train.d(),
            p.layout.len(),
            cfg.tau
        );
        train_remote(&cfg, p.theta0.data.clone(), net, workers, eval)
    };
    println!(
        "serve-ps: done — {} updates, {} pushes, {} join(s), {} leave(s), \
         {} transport fault(s), {} quarantined chunk(s)",
        res.stats.updates,
        res.stats.pushes,
        res.stats.joins,
        res.stats.leaves,
        res.stats.faults,
        res.stats.store_quarantines
    );
    let result = BaselineResult {
        theta: res.theta,
        trace: res.trace,
        wall_secs: res.wall_secs,
    };
    report_result("advgp (networked)", &p, &result, args)
}

/// Parse `--slice i/S`.
fn parse_slice_arg(arg: &str) -> Result<(usize, usize)> {
    let (i, s) = arg
        .split_once('/')
        .with_context(|| format!("--slice wants i/S (e.g. 0/2), got {arg:?}"))?;
    let i: usize = i.parse().map_err(|_| anyhow::anyhow!("--slice: bad index {i:?}"))?;
    let s: usize = s.parse().map_err(|_| anyhow::anyhow!("--slice: bad count {s:?}"))?;
    anyhow::ensure!(s >= 1 && i < s, "--slice {i}/{s}: index out of range");
    anyhow::ensure!(
        s <= advgp::ps::sharded::MAX_SLICES,
        "--slice {i}/{s}: at most {} slices supported",
        advgp::ps::sharded::MAX_SLICES
    );
    Ok((i, s))
}

/// Bind `s` slice listeners from a base `host:port` — consecutive ports
/// (port, port+1, …), or all-ephemeral when the base port is 0.
fn bind_slice_listeners(addr: &str, s: usize) -> Result<Vec<advgp::ps::NetServer>> {
    let (host, port) = addr
        .rsplit_once(':')
        .with_context(|| format!("--addr wants host:port, got {addr:?}"))?;
    let port: u16 = port
        .parse()
        .map_err(|_| anyhow::anyhow!("--addr: bad port in {addr:?}"))?;
    (0..s)
        .map(|i| {
            let p = if port == 0 {
                0
            } else {
                port.checked_add(i as u16).with_context(|| {
                    format!("--servers {s}: port range {port}+{i} overflows")
                })?
            };
            advgp::ps::NetServer::bind(&format!("{host}:{p}"))
        })
        .collect()
}

/// `advgp worker`: join a `serve-ps` run as a remote worker.  The θ
/// layout arrives in the WELCOME frame, so the only local inputs are
/// the connection address and the shard to stream.
fn cmd_worker(args: &Args) -> Result<()> {
    use advgp::ps::{
        remote_worker_loop, NetWorkerHandle, ShardedWorkerHandle, WorkerProfile,
        WorkerSource,
    };
    let connect = args.get("connect").context(
        "--connect host:port (or a comma-separated list, one address per \
         slice server of a partitioned fleet) required",
    )?;
    let addrs: Vec<String> = connect
        .split(',')
        .map(|a| a.trim().to_string())
        .filter(|a| !a.is_empty())
        .collect();
    anyhow::ensure!(!addrs.is_empty(), "--connect: no addresses given");
    let store = args.get("store").context(
        "--store dir required (the shard store written by \
         `advgp serve-ps --store` or `advgp train --store`)",
    )?;
    let set = ShardSet::open(Path::new(store))?;
    let shard: usize = args
        .get("shard")
        .context("--shard K required (which shard of the store this worker owns)")?
        .parse()
        .map_err(|_| anyhow::anyhow!("--shard wants an integer"))?;
    let mut reader = set.reader(shard)?;
    if let Some(chunk) = args.get("chunk-rows") {
        let chunk: usize = chunk
            .parse()
            .map_err(|_| anyhow::anyhow!("--chunk-rows wants an integer"))?;
        reader.set_chunk_rows(chunk);
    }
    let claim = Some(args.usize_or("worker-id", shard));
    let profile = WorkerProfile {
        max_rows: args.usize_or("max-rows", 0),
        // A standalone worker process owns its whole machine: default
        // to the full pool (in-process runs split it across workers).
        threads: args.usize_or("threads", advgp::util::pool::threads()),
        straggle: std::time::Duration::from_millis(args.u64_or("straggle-ms", 0)),
        ..Default::default()
    };
    let shard_rows = reader.n();
    let source = WorkerSource::Store(reader);
    // Fail a bad store pairing before any gradient work — one contract,
    // applied to whichever handle shape the address list produced.
    let check_store = |layout: advgp::gp::ThetaLayout| -> Result<()> {
        anyhow::ensure!(
            layout.d == set.d(),
            "server layout has d={} but store {store} holds d={} features",
            layout.d,
            set.d()
        );
        Ok(())
    };

    let worker_id = if addrs.len() > 1 {
        // Partitioned fleet: one connection per slice server, θ
        // assembled worker-side, gradients split per slice (ADVGPNT2).
        let handle = ShardedWorkerHandle::connect(&addrs, claim)?;
        check_store(handle.layout)?;
        println!(
            "worker {}: connected to {} slice server(s) [{}] (m={} d={} τ={}, \
             θ versions {:?}) — streaming shard {shard}/{}",
            handle.worker,
            addrs.len(),
            addrs.join(", "),
            handle.layout.m,
            handle.layout.d,
            handle.tau,
            handle.version_vector(),
            set.r(),
        );
        let factory = native_factory(handle.layout);
        let id = handle.worker;
        let mut source = source;
        // Lost slice links are re-established in place under the
        // session's outage budget (ISSUE 6); ConnectionLost means that
        // budget ran dry or the fleet changed identity underneath us.
        // Library callers get the same flow via
        // `ps::sharded_worker_loop`.
        match handle.run(&mut source, factory, profile)? {
            advgp::ps::net::RunEnd::ConnectionLost => anyhow::bail!(
                "worker {id}: a slice-server link was lost and the session's \
                 outage budget is exhausted; rerun this command to rejoin \
                 the fleet"
            ),
            _ => id,
        }
    } else {
        // Single server: probe once for the layout (so a bad store
        // pairing fails before any gradient work), then run with
        // reconnect-with-backoff through transient link losses.
        let probe = NetWorkerHandle::connect(&addrs[0], claim)?;
        check_store(probe.layout)?;
        println!(
            "worker {}: connected to {} (rev {}, m={} d={} τ={}, θ v{}) — \
             streaming shard {shard}/{} ({} rows)",
            probe.worker,
            addrs[0],
            probe.proto,
            probe.layout.m,
            probe.layout.d,
            probe.tau,
            probe.version(),
            set.r(),
            shard_rows,
        );
        let factory = native_factory(probe.layout);
        let claim = Some(probe.worker);
        let mut source = source;
        // Run on the probe connection; a lost link falls back to the
        // reconnect loop, which re-claims the same id.
        match probe.run(&mut source, factory.clone(), profile.clone())? {
            advgp::ps::net::RunEnd::ConnectionLost => {
                println!("worker: link lost — reconnecting with backoff");
                remote_worker_loop(&addrs[0], claim, source, factory, profile)?
            }
            _ => claim.unwrap(),
        }
    };
    println!("worker {worker_id}: run complete (server shut down or this worker departed)");
    Ok(())
}

/// `advgp serve-replica`: a stateless read-path replica (ADVGPSV1).
/// Subscribes to every slice server of a `serve-ps` fleet, mirrors the
/// publish streams into a local posterior cache, and answers PREDICT
/// sessions on `--listen`.  Exits `--linger-secs` after the training
/// fleet announces a clean end (so a scripted smoke terminates); kill
/// the process to stop earlier.
fn cmd_serve_replica(args: &Args) -> Result<()> {
    use advgp::serve::{Replica, ReplicaConfig};
    let connect = args.get("connect").context(
        "--connect host:port (or a comma-separated list, one address per \
         slice server of the training fleet) required",
    )?;
    let addrs: Vec<String> = connect
        .split(',')
        .map(|a| a.trim().to_string())
        .filter(|a| !a.is_empty())
        .collect();
    anyhow::ensure!(!addrs.is_empty(), "--connect: no addresses given");
    let mut cfg = ReplicaConfig::default();
    cfg.staleness_budget =
        std::time::Duration::from_secs_f64(args.f64_or("staleness-secs", 10.0));
    cfg.max_inflight_rows = args.usize_or("max-inflight-rows", cfg.max_inflight_rows);
    cfg.batch.max_rows = args.usize_or("batch-rows", cfg.batch.max_rows);
    // --batch-delay-ms is the pre-ISSUE-9 spelling, kept as a fallback.
    cfg.batch.latency_budget = std::time::Duration::from_millis(
        args.u64_or("latency-budget-ms", args.u64_or("batch-delay-ms", 2)),
    );
    let listen = args.str_or("listen", "127.0.0.1:0");
    let replica = Replica::start(listen, &addrs, cfg)?;
    println!(
        "serve-replica: predicts on {} — subscribed to {} slice server(s) \
         [{}], θ v{}",
        replica.predict_addr(),
        addrs.len(),
        addrs.join(", "),
        replica.version().unwrap_or(0)
    );
    // Serve until the trainer ends cleanly, then linger for stragglers.
    while !replica.wait_trainer_end(std::time::Duration::from_secs(3600)) {}
    let linger = args.f64_or("linger-secs", 0.0);
    println!(
        "serve-replica: training fleet finished (θ v{}) — serving the final \
         posterior for {linger:.0}s more",
        replica.version().unwrap_or(0)
    );
    std::thread::sleep(std::time::Duration::from_secs_f64(linger));
    let report = replica.shutdown();
    println!("serve-replica: done — {}", report.summary());
    Ok(())
}

/// `advgp loadgen`: offered-load measurement of a replica fleet.  Open
/// loop (latency is measured from each request's *scheduled* instant),
/// exact p50/p99/p999, optional merge-write into `BENCH_serve.json`.
fn cmd_loadgen(args: &Args) -> Result<()> {
    use advgp::serve::{loadgen, LoadgenConfig};
    let replicas = args.get("replicas").context(
        "--replicas host:port (or a comma-separated list of replica \
         predict addresses) required",
    )?;
    let addrs: Vec<String> = replicas
        .split(',')
        .map(|a| a.trim().to_string())
        .filter(|a| !a.is_empty())
        .collect();
    anyhow::ensure!(!addrs.is_empty(), "--replicas: no addresses given");
    let cfg = LoadgenConfig {
        qps: args.f64_or("qps", 500.0),
        requests: args.usize_or("requests", 2000),
        rows_per_request: args.usize_or("rows", 8),
        seed: args.u64_or("seed", 42),
    };
    println!(
        "loadgen: offering {} request(s) ({} row(s) each) at {} req/s across \
         {} replica(s)",
        cfg.requests,
        cfg.rows_per_request,
        cfg.qps,
        addrs.len()
    );
    let sb = loadgen::run(&addrs, &cfg)?;
    println!("loadgen: {}", sb.summary());
    if let Some(out) = args.get("bench-out") {
        let default_name = format!("serve/replicas={}", addrs.len());
        let name = args.str_or("name", &default_name);
        sb.write_bench(out, name, &cfg, addrs.len())?;
        println!("loadgen: wrote entry {name:?} to {out}");
    }
    Ok(())
}

/// `advgp route`: the predict-side routing tier (ADVGPRT1).  One
/// address in front of a replica fleet — power-of-two-choices
/// balancing on in-flight rows, transparent sibling retry on retryable
/// REJECTs, bounded per-leg answer caches with version-gated
/// invalidation, and heartbeat retirement of unreachable replicas.
fn cmd_route(args: &Args) -> Result<()> {
    use advgp::serve::{Router, RouterConfig};
    let replicas = args.get("replicas").context(
        "--replicas host:port (or a comma-separated list of replica \
         predict addresses) required",
    )?;
    let addrs: Vec<String> = replicas
        .split(',')
        .map(|a| a.trim().to_string())
        .filter(|a| !a.is_empty())
        .collect();
    anyhow::ensure!(!addrs.is_empty(), "--replicas: no addresses given");
    let mut cfg = RouterConfig::default();
    cfg.cache_rows = args.usize_or("cache-rows", cfg.cache_rows);
    cfg.retry_hops = args.usize_or("retry-hops", cfg.retry_hops);
    cfg.seed = args.u64_or("seed", cfg.seed);
    let listen = args.str_or("listen", "127.0.0.1:0");
    let router = Router::start(listen, &addrs, cfg)?;
    println!(
        "route: predicts on {} — fronting {} replica(s) [{}]",
        router.addr(),
        addrs.len(),
        addrs.join(", ")
    );
    // Serve for --secs (0 = forever; kill the process to stop).
    let secs = args.f64_or("secs", 0.0);
    if secs > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(secs));
    } else {
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    let stats = router.shutdown();
    println!(
        "route: done — {} session(s), {} routed, {} cache hit(s), {} retry(ies), \
         {} failover(s)",
        stats.sessions, stats.routed, stats.cache_hits, stats.retries, stats.failovers
    );
    Ok(())
}

/// `advgp store`: offline tools over an on-disk shard store (ISSUE 7).
/// `verify` scrubs every chunk checksum and prints a per-chunk report
/// (exit 1 on any fault, so CI can gate on it); `migrate` upgrades
/// ADVGPSH1 shards to the checksummed ADVGPSH2 format in place with
/// bitwise row parity checked before any original is replaced;
/// `repartition --workers W` remaps chunk ranges to a new worker count
/// without rewriting shard bytes.
fn cmd_store(args: &Args) -> Result<()> {
    use advgp::data::store::{migrate_store, repartition_store, verify_store};
    let action = args.positional.get(1).map(|s| s.as_str()).context(
        "usage: advgp store <verify|migrate|repartition> --store dir [--workers W]",
    )?;
    let dir = PathBuf::from(
        args.get("store")
            .context("--store dir required (the shard store directory)")?,
    );
    match action {
        "verify" => {
            let report = verify_store(&dir)?;
            println!("{report}");
            anyhow::ensure!(
                report.clean(),
                "store {} failed verification ({} fault(s))",
                dir.display(),
                report.total_corrupt()
            );
            Ok(())
        }
        "migrate" => {
            let migrated = migrate_store(&dir)?;
            let s = ShardSet::open(&dir)?;
            println!(
                "store {}: {} file(s) migrated to ADVGPSH2 ({} already v2), \
                 {} rows, chunk {}",
                dir.display(),
                migrated,
                s.r() - migrated,
                s.n(),
                s.chunk_rows()
            );
            Ok(())
        }
        "repartition" => {
            let workers: usize = args
                .get("workers")
                .context("--workers W required (the new logical worker count)")?
                .parse()
                .map_err(|_| anyhow::anyhow!("--workers wants an integer"))?;
            repartition_store(&dir, workers)?;
            let s = ShardSet::open(&dir)?;
            println!(
                "store {}: {} chunk(s) across {} file(s) remapped to {} logical \
                 worker(s) — shard bytes untouched",
                dir.display(),
                s.total_chunks(),
                s.r(),
                s.logical_workers()
            );
            Ok(())
        }
        other => bail!("unknown store action {other} (verify|migrate|repartition)"),
    }
}

fn cmd_datagen(args: &Args) -> Result<()> {
    let kind = args.str_or("kind", "flight");
    let n = args.usize_or("n", 10_000);
    let seed = args.u64_or("seed", 0);
    let out = args.get("out").context("--out <file.csv> required")?;
    let ds = match kind {
        "flight" => synth::flight_like(n, seed),
        "taxi" => synth::taxi_like(n, seed),
        "friedman" => synth::friedman(n, 4, 0.4, seed),
        other => bail!("unknown kind {other}"),
    };
    csv::write_dataset(Path::new(out), &ds)?;
    println!("wrote {n} rows ({} features) to {out}", ds.d());
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.str_or("dir", "artifacts"));
    let man = Manifest::load(&dir)?;
    println!("{} artifacts in {}:", man.artifacts.len(), dir.display());
    for a in &man.artifacts {
        println!(
            "  {:<8} m={:<4} d={:<2} b={:<5} {}",
            format!("{:?}", a.kind).to_lowercase(),
            a.m, a.d, a.b,
            a.path.file_name().unwrap().to_string_lossy()
        );
    }
    println!("complete (grad+predict+elbo) configs: {:?}", man.complete_configs());
    Ok(())
}

fn cmd_smoke(args: &Args) -> Result<()> {
    let path = args.str_or("hlo", "/tmp/fn_hlo.txt");
    let vals = advgp::runtime::smoke(path)?;
    println!("smoke result: {vals:?}");
    anyhow::ensure!(vals == vec![5.0, 5.0, 9.0, 9.0], "unexpected values");
    println!("smoke OK");
    Ok(())
}
