//! `advgp` — the command-line launcher for the ADVGP system.
//!
//! Subcommands:
//!   train      train a GP regression model (ADVGP / baselines) on CSV
//!              or synthetic data and report RMSE/MNLP
//!   datagen    write a synthetic dataset (flight|taxi|friedman) as CSV
//!   artifacts  list the AOT artifact manifest
//!   smoke      PJRT round-trip smoke test on an HLO text file

use advgp::data::{csv, synth, Dataset};
use advgp::experiments::methods::*;
use advgp::experiments::{make_problem, print_table};
use advgp::runtime::{engine::xla_factory, ArtifactKind, Manifest};
use advgp::util::cli::Args;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

fn main() -> Result<()> {
    advgp::util::logging::init();
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("datagen") => cmd_datagen(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some("smoke") => cmd_smoke(&args),
        _ => {
            eprintln!(
                "usage: advgp <train|datagen|artifacts|smoke> [--flags]\n\
                 \n\
                 train:    --data <csv|flight|taxi|friedman> [--n 50000] [--m 100]\n\
                 \x20         [--method advgp|svigp|distgp-gd|distgp-lbfgs|linear]\n\
                 \x20         [--workers 4] [--tau 32] [--budget 30] [--engine native|xla]\n\
                 \x20         [--out-trace trace.csv]\n\
                 datagen:  --kind flight|taxi|friedman --n 10000 --out data.csv [--seed 0]\n\
                 artifacts: [--dir artifacts]\n\
                 smoke:    [--hlo /tmp/fn_hlo.txt]"
            );
            std::process::exit(2);
        }
    }
}

fn load_data(args: &Args) -> Result<Dataset> {
    let spec = args.str_or("data", "flight");
    let n = args.usize_or("n", 50_000);
    let seed = args.u64_or("seed", 0);
    Ok(match spec {
        "flight" => synth::flight_like(n, seed),
        "taxi" => synth::taxi_like(n, seed),
        "friedman" => synth::friedman(n, 4, 0.4, seed),
        path => csv::read_dataset(Path::new(path))
            .with_context(|| format!("loading CSV {path}"))?,
    })
}

fn cmd_train(args: &Args) -> Result<()> {
    let raw = load_data(args)?;
    let m = args.usize_or("m", 100);
    let n_test = args.usize_or("n-test", (raw.n() / 10).clamp(100, 100_000));
    let method = args.str_or("method", "advgp").to_string();
    let engine = args.str_or("engine", "native").to_string();
    let opts = MethodOpts {
        workers: args.usize_or("workers", 4),
        tau: args.u64_or("tau", 32),
        budget_secs: args.f64_or("budget", 30.0),
        eval_every_secs: args.f64_or("eval-every", 0.5),
        lr: args.f64_or("lr", 1.0),
        prox_c: args.f64_or("prox-c", 0.05),
        prox_t0: args.f64_or("prox-t0", 200.0),
        max_rows: args.usize_or("max-rows", 0),
        ..Default::default()
    };
    let p = make_problem(raw, n_test, m, 20_000, args.u64_or("seed", 0));
    let y_std = p.standardizer.y_std;
    println!(
        "training {method} on n={} (test {}), d={}, m={m}, θ dim {}",
        p.train.n(), p.test.n(), p.train.d(), p.layout.len()
    );

    let result = match method.as_str() {
        "advgp" => {
            if engine == "xla" {
                let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
                let man = Manifest::load(&dir)?;
                man.find(ArtifactKind::Grad, m, p.train.d())?;
                run_advgp_with(&p, &opts, xla_factory(man, m, p.train.d()))
            } else {
                run_advgp(&p, &opts)
            }
        }
        "svigp" => run_svigp_method(&p, &opts),
        "distgp-gd" => run_distgp_gd_method(&p, &opts),
        "distgp-lbfgs" => run_distgp_lbfgs_method(&p, &opts),
        "linear" => run_linear_method(&p, &opts),
        other => bail!("unknown method {other}"),
    };

    if let Some(out) = args.get("out-trace") {
        advgp::ps::metrics::write_trace_csv(Path::new(out), &result.trace)?;
        println!("trace -> {out}");
    }
    let mean = run_mean_method(&p);
    print_table(
        "results (original target units)",
        &["Method", "RMSE", "MNLP", "wall (s)"],
        &[
            vec![method, format!("{:.4}", final_rmse(&result) * y_std),
                 format!("{:.4}", final_mnlp(&result)),
                 format!("{:.1}", result.wall_secs)],
            vec!["mean".into(), format!("{:.4}", final_rmse(&mean) * y_std),
                 "-".into(), "0.0".into()],
        ],
    );
    Ok(())
}

fn cmd_datagen(args: &Args) -> Result<()> {
    let kind = args.str_or("kind", "flight");
    let n = args.usize_or("n", 10_000);
    let seed = args.u64_or("seed", 0);
    let out = args.get("out").context("--out <file.csv> required")?;
    let ds = match kind {
        "flight" => synth::flight_like(n, seed),
        "taxi" => synth::taxi_like(n, seed),
        "friedman" => synth::friedman(n, 4, 0.4, seed),
        other => bail!("unknown kind {other}"),
    };
    csv::write_dataset(Path::new(out), &ds)?;
    println!("wrote {n} rows ({} features) to {out}", ds.d());
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.str_or("dir", "artifacts"));
    let man = Manifest::load(&dir)?;
    println!("{} artifacts in {}:", man.artifacts.len(), dir.display());
    for a in &man.artifacts {
        println!(
            "  {:<8} m={:<4} d={:<2} b={:<5} {}",
            format!("{:?}", a.kind).to_lowercase(),
            a.m, a.d, a.b,
            a.path.file_name().unwrap().to_string_lossy()
        );
    }
    println!("complete (grad+predict+elbo) configs: {:?}", man.complete_configs());
    Ok(())
}

fn cmd_smoke(args: &Args) -> Result<()> {
    let path = args.str_or("hlo", "/tmp/fn_hlo.txt");
    let vals = advgp::runtime::smoke(path)?;
    println!("smoke result: {vals:?}");
    anyhow::ensure!(vals == vec![5.0, 5.0, 9.0, 9.0], "unexpected values");
    println!("smoke OK");
    Ok(())
}
