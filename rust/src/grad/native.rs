//! Pure-Rust gradient engine: batched analytic gradients of the local
//! data term G (paper appendix A, eqs. 16–17 and 26–32).
//!
//! The per-sample forms of the appendix are folded into matrix products
//! (DESIGN.md §6): with Φ [B,m] and P [B,m] (rows p_i of eq. 29), the
//! direct K_bm path uses `A1 = (P Lᵀ) ∘ K_bm` and the L path chains the
//! cotangent `dL̄ = β K_bmᵀ P` through [`super::chain::LChain`] — the
//! mechanical equivalent of the appendix's Ψ/T_i operator.  Correctness
//! is pinned by central finite differences over every θ coordinate
//! (tests below) and against the JAX/Pallas artifact (integration test).

use super::chain::LChain;
use super::{GradEngine, GradResult};
use crate::gp::{Theta, ThetaLayout};
use crate::kernel::cross;
use crate::linalg::{dot, Mat};

/// Max rows processed per chunk (bounds the [chunk, m] temporaries).
const CHUNK: usize = 2048;

pub struct NativeEngine {
    layout: ThetaLayout,
}

impl NativeEngine {
    pub fn new(layout: ThetaLayout) -> Self {
        Self { layout }
    }
}

/// Per-θ precomputation shared across chunks.
struct Factorization {
    lchain: LChain,
    u: Mat,
    mu: Vec<f64>,
    beta: f64,
    log_sigma: f64,
}

impl Factorization {
    fn build(layout: ThetaLayout, theta: &[f64]) -> Option<Self> {
        let th = Theta { layout, data: theta.to_vec() };
        let lchain = LChain::try_build(th.ard(), th.z_mat())?;
        let mut u = th.u_mat();
        u.triu_inplace();
        Some(Self {
            lchain,
            u,
            mu: th.mu().to_vec(),
            beta: th.beta(),
            log_sigma: th.log_sigma(),
        })
    }
}

impl GradEngine for NativeEngine {
    fn layout(&self) -> ThetaLayout {
        self.layout
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn grad(&mut self, theta: &[f64], x: &Mat, y: &[f64]) -> GradResult {
        assert_eq!(theta.len(), self.layout.len());
        assert_eq!(x.cols, self.layout.d);
        assert_eq!(x.rows, y.len());
        // Line searches probe infeasible θ (non-SPD K_mm): report +∞ so
        // the caller backtracks instead of crashing.
        let Some(f) = Factorization::build(self.layout, theta) else {
            return GradResult {
                value: f64::INFINITY,
                grad: vec![0.0; self.layout.len()],
            };
        };
        let mut value = 0.0;
        let mut grad = vec![0.0; self.layout.len()];
        // dL̄ accumulates across chunks; the O(m³) chain runs once.
        let m = self.layout.m;
        let mut l_cot = Mat::zeros(m, m);
        let mut start = 0;
        while start < x.rows {
            let len = CHUNK.min(x.rows - start);
            let xc = Mat::from_vec(len, x.cols,
                                   x.data[start * x.cols..(start + len) * x.cols].to_vec());
            let yc = &y[start..start + len];
            value += accumulate_chunk(&self.layout, &f, &xc, yc, &mut grad, &mut l_cot);
            start += len;
        }
        // L path: Z and lnη contributions (ln a0 is covered exactly by
        // the analytic eq. 27 inside the chunk loop — see note there).
        let lg = f.lchain.chain(&l_cot);
        let zr = self.layout.z_range();
        for (slot, v) in grad[zr].iter_mut().zip(&lg.dz.data) {
            *slot += v;
        }
        let er = self.layout.log_eta_range();
        for (slot, v) in grad[er].iter_mut().zip(&lg.dlog_eta) {
            *slot += v;
        }
        GradResult { value, grad }
    }
}

/// Process one chunk; returns its contribution to G, adds the direct
/// paths to `grad`, and accumulates the L cotangent into `l_cot`.
fn accumulate_chunk(
    layout: &ThetaLayout,
    f: &Factorization,
    x: &Mat,
    y: &[f64],
    grad: &mut [f64],
    l_cot: &mut Mat,
) -> f64 {
    let (b, m, d) = (x.rows, layout.m, layout.d);
    let a0_sq = f.lchain.params.a0_sq();
    let eta = f.lchain.params.eta();
    let beta = f.beta;
    let z = &f.lchain.z;

    // ---- forward (the Pallas kernel's job on the XLA path) ----
    let k_bm = cross(&f.lchain.params, x, z); // [B, m]
    let phi = k_bm.matmul(&f.lchain.chol_l); // [B, m]
    let mut e = vec![0.0; b];
    let mut quad = vec![0.0; b];
    let mut ktilde = vec![0.0; b];
    // uphi rows: U φ_i; sphi rows: Σ φ_i = U^T (U φ_i).
    let uphi = phi.matmul(&f.u.transpose()); // rows: (U φ_i)^T
    let sphi = uphi.matmul(&f.u); // rows: φ_i^T U^T U = (Σ φ_i)^T
    for i in 0..b {
        let phi_i = phi.row(i);
        e[i] = dot(phi_i, &f.mu) - y[i];
        quad[i] = dot(uphi.row(i), uphi.row(i));
        ktilde[i] = a0_sq - dot(phi_i, phi_i);
    }
    let mut g_val = 0.0;
    for i in 0..b {
        g_val += 0.5 * (2.0 * std::f64::consts::PI).ln() + f.log_sigma
            + 0.5 * beta * (e[i] * e[i] + quad[i] + ktilde[i]);
    }

    // ---- dμ (eq. 16): β Φ^T e ----
    {
        let dmu = phi.tr_matvec(&e);
        let r = layout.mu_range();
        for (gslot, v) in grad[r].iter_mut().zip(dmu) {
            *gslot += beta * v;
        }
    }

    // ---- dU (eq. 17): β triu(U Φ^T Φ) ----
    {
        let gram = phi.gram(); // Φ^T Φ
        let mut du = f.u.matmul(&gram);
        du.triu_inplace();
        let r = layout.u_range();
        for (gslot, v) in grad[r].iter_mut().zip(&du.data) {
            *gslot += beta * v;
        }
    }

    // ---- dlnσ (eq. 26) ----
    {
        let mut s = 0.0;
        for i in 0..b {
            s += 1.0 - beta * (e[i] * e[i] + quad[i] + ktilde[i]);
        }
        grad[layout.log_sigma_idx()] += s;
    }

    // ---- dln a0 (eq. 27) — exact for ALL paths: Φ ∝ a0 identically
    // (K_bm ∝ a0², L ∝ a0^{-1} incl. the a0²-scaled jitter), so the
    // closed form needs no chain contribution. ----
    {
        let mut s = 0.0;
        for i in 0..b {
            let phim = e[i] + y[i]; // φ_i^T μ
            let phi_sq = a0_sq - ktilde[i]; // ‖φ_i‖²
            s += -y[i] * phim + quad[i] + phim * phim + a0_sq - phi_sq;
        }
        grad[layout.log_a0_idx()] += beta * s;
    }

    // ---- P (eq. 29): p_i = e_i μ + Σ φ_i − φ_i (= ∂g_i/∂φ_i / β) ----
    let mut p = Mat::zeros(b, m);
    for i in 0..b {
        let prow = p.row_mut(i);
        let phii = phi.row(i);
        let sphii = sphi.row(i);
        for j in 0..m {
            prow[j] = e[i] * f.mu[j] + sphii[j] - phii[j];
        }
    }

    // ---- direct K_bm path: A1 = (P Lᵀ) ∘ K_bm ----
    let mut a1 = p.matmul(&f.lchain.chol_l.transpose());
    for (v, k) in a1.data.iter_mut().zip(&k_bm.data) {
        *v *= k;
    }
    let ones_b = vec![1.0; b];
    let s_col = a1.tr_matvec(&ones_b); // s_j = Σ_i A1[i,j]
    let mut row_sum = vec![0.0; b];
    for i in 0..b {
        row_sum[i] = a1.row(i).iter().sum();
    }
    let a1t_x = a1.tr_matmul(x); // [m, d]

    // dZ direct: β η_k [ (A1ᵀX)[j,k] − s_j z_jk ].
    {
        let r = layout.z_range();
        let gz = &mut grad[r];
        for j in 0..m {
            for k in 0..d {
                gz[j * d + k] +=
                    beta * eta[k] * (a1t_x[(j, k)] - s_col[j] * z[(j, k)]);
            }
        }
    }

    // dlnη direct: −½ β η_k Σ_ij A1[i,j] (x_ik − z_jk)².
    {
        let r = layout.log_eta_range();
        let geta = &mut grad[r];
        for k in 0..d {
            let mut q = 0.0;
            for i in 0..b {
                let xik = x[(i, k)];
                q += row_sum[i] * xik * xik;
            }
            for j in 0..m {
                let zjk = z[(j, k)];
                q += -2.0 * zjk * a1t_x[(j, k)] + s_col[j] * zjk * zjk;
            }
            geta[k] += -0.5 * beta * eta[k] * q;
        }
    }

    // ---- accumulate the true L cotangent: dL̄ += β K_bmᵀ P ----
    {
        let d_mat = k_bm.tr_matmul(&p);
        l_cot.axpy(beta, &d_mat);
    }

    g_val
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn test_theta(layout: ThetaLayout, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::seeded(seed);
        let z = Mat::from_vec(layout.m, layout.d,
                              (0..layout.m * layout.d).map(|_| rng.normal() * 0.8).collect());
        let mut th = Theta::init(layout, &z);
        for v in th.mu_mut() {
            *v = rng.normal() * 0.3;
        }
        let m = layout.m;
        let mut u = Mat::eye(m);
        for i in 0..m {
            u[(i, i)] = 0.7 + 0.3 * rng.next_f64();
            for j in i + 1..m {
                u[(i, j)] = rng.normal() * 0.05;
            }
        }
        th.set_u_mat(&u);
        th.data[layout.log_a0_idx()] = 0.2;
        for (k, v) in th.data[layout.log_eta_range()].iter_mut().enumerate() {
            *v = 0.1 * (k as f64 - 1.0);
        }
        th.data[layout.log_sigma_idx()] = -0.3;
        th.data
    }

    fn value_at(layout: ThetaLayout, theta: &[f64], x: &Mat, y: &[f64]) -> f64 {
        NativeEngine::new(layout).grad(theta, x, y).value
    }

    fn rand_data(n: usize, d: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Pcg64::seeded(seed);
        let x = Mat::from_vec(n, d, (0..n * d).map(|_| rng.normal()).collect());
        let y = (0..n).map(|_| rng.normal()).collect();
        (x, y)
    }

    /// Central finite differences over EVERY θ coordinate.
    #[test]
    fn gradient_matches_finite_differences() {
        let layout = ThetaLayout::new(5, 3);
        let theta = test_theta(layout, 1);
        let (x, y) = rand_data(24, 3, 2);
        let mut engine = NativeEngine::new(layout);
        let res = engine.grad(&theta, &x, &y);
        let eps = 1e-5;
        let mut max_rel = 0.0f64;
        for i in 0..layout.len() {
            let mut tp = theta.clone();
            tp[i] += eps;
            let mut tm = theta.clone();
            tm[i] -= eps;
            let fd = (value_at(layout, &tp, &x, &y) - value_at(layout, &tm, &x, &y))
                / (2.0 * eps);
            let an = res.grad[i];
            let denom = fd.abs().max(an.abs()).max(1e-4);
            let rel = (fd - an).abs() / denom;
            max_rel = max_rel.max(rel);
            assert!(
                rel < 2e-3,
                "coord {i}: analytic {an:.8} vs fd {fd:.8} (rel {rel:.2e})"
            );
        }
        assert!(max_rel < 2e-3, "max rel err {max_rel:.2e}");
    }

    #[test]
    fn strictly_lower_u_gradient_is_zero() {
        let layout = ThetaLayout::new(4, 2);
        let theta = test_theta(layout, 3);
        let (x, y) = rand_data(32, 2, 4);
        let res = NativeEngine::new(layout).grad(&theta, &x, &y);
        let ur = layout.u_range();
        let m = 4;
        for i in 0..m {
            for j in 0..i {
                assert_eq!(res.grad[ur.start + i * m + j], 0.0);
            }
        }
    }

    #[test]
    fn value_matches_sparse_gp_data_term() {
        let layout = ThetaLayout::new(6, 3);
        let theta = test_theta(layout, 5);
        let (x, y) = rand_data(50, 3, 6);
        let res = NativeEngine::new(layout).grad(&theta, &x, &y);
        let gp = crate::gp::SparseGp::new(Theta { layout, data: theta.clone() });
        let want = gp.data_term(&x, &y);
        assert!((res.value - want).abs() < 1e-8 * want.abs().max(1.0),
                "{} vs {}", res.value, want);
    }

    #[test]
    fn additive_over_shards() {
        let layout = ThetaLayout::new(5, 3);
        let theta = test_theta(layout, 7);
        let (x, y) = rand_data(64, 3, 8);
        let ds = crate::data::Dataset { x, y };
        let mut eng = NativeEngine::new(layout);
        let whole = eng.grad(&theta, &ds.x, &ds.y);
        let shards = ds.shard(4);
        let mut sum_val = 0.0;
        let mut sum_grad = vec![0.0; layout.len()];
        for s in &shards {
            let r = eng.grad(&theta, &s.x, &s.y);
            sum_val += r.value;
            for (a, b) in sum_grad.iter_mut().zip(&r.grad) {
                *a += b;
            }
        }
        assert!((whole.value - sum_val).abs() < 1e-8);
        for (a, b) in whole.grad.iter().zip(&sum_grad) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn chunking_is_transparent() {
        let layout = ThetaLayout::new(4, 2);
        let theta = test_theta(layout, 9);
        let n = CHUNK + 513;
        let (x, y) = rand_data(n, 2, 10);
        let mut eng = NativeEngine::new(layout);
        let whole = eng.grad(&theta, &x, &y);
        let x1 = Mat::from_vec(CHUNK, 2, x.data[..CHUNK * 2].to_vec());
        let x2 = Mat::from_vec(513, 2, x.data[CHUNK * 2..].to_vec());
        let r1 = eng.grad(&theta, &x1, &y[..CHUNK]);
        let r2 = eng.grad(&theta, &x2, &y[CHUNK..]);
        assert!((whole.value - r1.value - r2.value).abs() < 1e-6);
        for i in 0..layout.len() {
            assert!((whole.grad[i] - r1.grad[i] - r2.grad[i]).abs() < 1e-6);
        }
    }
}
