//! Pure-Rust gradient engine: batched analytic gradients of the local
//! data term G (paper appendix A, eqs. 16–17 and 26–32).
//!
//! The per-sample forms of the appendix are folded into matrix products
//! (DESIGN.md §6): with Φ [B,m] and P [B,m] (rows p_i of eq. 29), the
//! direct K_bm path uses `A1 = (P Lᵀ) ∘ K_bm` and the L path chains the
//! cotangent `dL̄ = β K_bmᵀ P` through [`super::chain::LChain`] — the
//! mechanical equivalent of the appendix's Ψ/T_i operator.  Correctness
//! is pinned by central finite differences over every θ coordinate
//! (tests below) and against the JAX/Pallas artifact (integration test).
//!
//! # Hot-path execution (ISSUE 1)
//!
//! The engine owns reusable **lane workspaces** holding every [B, m]
//! temporary (K_bm, Φ, UΦ, ΣΦ, P, A1, …), so the per-block gradient
//! path performs **zero heap allocation in steady state** — buffers are
//! resized in place and hold their capacity across calls.  Shards wider
//! than one chunk are split across the thread pool: each lane owns a
//! static round-robin subset of chunks (deterministic assignment) and
//! its own workspace/accumulators, reduced in lane order at the end.
//! Single-chunk batches instead parallelize *inside* the linalg/kernel
//! ops (row blocks), so both regimes use the whole machine.

use super::chain::LChain;
use super::{GradEngine, GradResult};
use crate::gp::{Theta, ThetaLayout};
use crate::kernel::CrossScratch;
use crate::linalg::Mat;
use crate::runtime::backend::{self, ComputeBackend};
use crate::util::pool;

/// Max rows processed per chunk (bounds the [chunk, m] temporaries).
const CHUNK: usize = 2048;

pub struct NativeEngine {
    layout: ThetaLayout,
    /// Kernel set the per-chunk math executes on (ISSUE 10).  The
    /// O(m³)-once-per-θ factorization (`Factorization::build`) stays
    /// on the scalar reference path for every backend.
    be: &'static dyn ComputeBackend,
    /// Lane workspaces, grown on demand and reused across `grad` calls.
    lanes: Vec<LaneWs>,
}

impl NativeEngine {
    /// Engine on the process-wide active backend
    /// ([`crate::runtime::backend::active`]) — scalar unless training
    /// config / `ADVGP_BACKEND` installed something else.
    pub fn new(layout: ThetaLayout) -> Self {
        Self::with_backend(layout, backend::active())
    }

    /// Engine pinned to an explicit backend, regardless of global
    /// selection (used by the tolerance-contract tests and benches).
    pub fn with_backend(layout: ThetaLayout, be: &'static dyn ComputeBackend) -> Self {
        Self { layout, be, lanes: Vec::new() }
    }
}

/// Per-lane scratch: every per-chunk temporary plus the lane's private
/// gradient accumulators.  All buffers are `resize`d in place, so after
/// the first chunk of the first call nothing here allocates.
struct LaneWs {
    xc: Mat,
    k_bm: Mat,
    phi: Mat,
    uphi: Mat,
    sphi: Mat,
    p: Mat,
    a1: Mat,
    a1t_x: Mat,
    gram: Mat,
    du: Mat,
    dmat: Mat,
    e: Vec<f64>,
    quad: Vec<f64>,
    ktilde: Vec<f64>,
    row_sum: Vec<f64>,
    s_col: Vec<f64>,
    dmu: Vec<f64>,
    cross: CrossScratch,
    // Lane accumulators, reduced in lane order after the fan-out.
    grad: Vec<f64>,
    l_cot: Mat,
    value: f64,
}

impl LaneWs {
    fn new() -> Self {
        Self {
            xc: Mat::empty(),
            k_bm: Mat::empty(),
            phi: Mat::empty(),
            uphi: Mat::empty(),
            sphi: Mat::empty(),
            p: Mat::empty(),
            a1: Mat::empty(),
            a1t_x: Mat::empty(),
            gram: Mat::empty(),
            du: Mat::empty(),
            dmat: Mat::empty(),
            e: Vec::new(),
            quad: Vec::new(),
            ktilde: Vec::new(),
            row_sum: Vec::new(),
            s_col: Vec::new(),
            dmu: Vec::new(),
            cross: CrossScratch::new(),
            grad: Vec::new(),
            l_cot: Mat::empty(),
            value: 0.0,
        }
    }

    fn reset(&mut self, theta_len: usize, m: usize) {
        self.grad.resize(theta_len, 0.0);
        for v in &mut self.grad {
            *v = 0.0;
        }
        self.l_cot.resize(m, m);
        for v in &mut self.l_cot.data {
            *v = 0.0;
        }
        self.value = 0.0;
    }
}

/// Per-θ precomputation shared across chunks.
struct Factorization {
    lchain: LChain,
    u: Mat,
    mu: Vec<f64>,
    beta: f64,
    log_sigma: f64,
}

impl Factorization {
    fn build(layout: ThetaLayout, theta: &[f64]) -> Option<Self> {
        let th = Theta { layout, data: theta.to_vec() };
        let lchain = LChain::try_build(th.ard(), th.z_mat())?;
        let mut u = th.u_mat();
        u.triu_inplace();
        Some(Self {
            lchain,
            u,
            mu: th.mu().to_vec(),
            beta: th.beta(),
            log_sigma: th.log_sigma(),
        })
    }
}

impl GradEngine for NativeEngine {
    fn layout(&self) -> ThetaLayout {
        self.layout
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn grad(&mut self, theta: &[f64], x: &Mat, y: &[f64]) -> GradResult {
        assert_eq!(theta.len(), self.layout.len());
        assert_eq!(x.cols, self.layout.d);
        assert_eq!(x.rows, y.len());
        // Line searches probe infeasible θ (non-SPD K_mm): report +∞ so
        // the caller backtracks instead of crashing.
        let Some(f) = Factorization::build(self.layout, theta) else {
            return GradResult {
                value: f64::INFINITY,
                grad: vec![0.0; self.layout.len()],
            };
        };
        let m = self.layout.m;
        let n_chunks = (x.rows + CHUNK - 1) / CHUNK;
        // Many chunks → one lane per pool thread, serial math inside
        // each lane (lowest dispatch overhead, perfect balance).  Few
        // chunks → a single lane whose linalg/kernel ops row-parallelize
        // internally.
        let par = pool::effective_parallelism();
        let lanes = if par > 1 && n_chunks >= 2 * par { par } else { 1 };
        if self.lanes.len() < lanes {
            self.lanes.resize_with(lanes, LaneWs::new);
        }
        for ws in self.lanes[..lanes].iter_mut() {
            ws.reset(self.layout.len(), m);
        }
        let layout = self.layout;
        let be = self.be;
        if lanes == 1 {
            let ws = &mut self.lanes[0];
            for chunk in 0..n_chunks {
                accumulate_chunk(&layout, be, &f, x, y, chunk, ws);
            }
        } else {
            let fref = &f;
            // One task per lane; `parallel_rows_mut` hands each task an
            // exclusive &mut over its own workspace.
            pool::parallel_rows_mut(
                &mut self.lanes[..lanes],
                1,
                lanes,
                1,
                &|lane, blk: &mut [LaneWs]| {
                    let ws = &mut blk[0];
                    // Lanes already occupy the pool: keep their inner
                    // linalg serial rather than queueing nested row blocks.
                    pool::with_budget(1, || {
                        let mut chunk = lane;
                        while chunk < n_chunks {
                            accumulate_chunk(&layout, be, fref, x, y, chunk, ws);
                            chunk += lanes;
                        }
                    });
                },
            );
        }
        // Deterministic lane-order reduction (chunk→lane assignment is
        // static, so results are reproducible run to run).
        let mut value = 0.0;
        let mut grad = vec![0.0; self.layout.len()];
        let mut l_cot = Mat::zeros(m, m);
        for ws in &self.lanes[..lanes] {
            value += ws.value;
            for (a, b) in grad.iter_mut().zip(&ws.grad) {
                *a += b;
            }
            l_cot.add_assign(&ws.l_cot);
        }
        // L path: Z and lnη contributions (ln a0 is covered exactly by
        // the analytic eq. 27 inside the chunk loop — see note there).
        let lg = f.lchain.chain(&l_cot);
        let zr = self.layout.z_range();
        for (slot, v) in grad[zr].iter_mut().zip(&lg.dz.data) {
            *slot += v;
        }
        let er = self.layout.log_eta_range();
        for (slot, v) in grad[er].iter_mut().zip(&lg.dlog_eta) {
            *slot += v;
        }
        GradResult { value, grad }
    }
}

/// Process chunk `chunk` of `x` into the lane workspace: adds the chunk
/// value to `ws.value`, the direct gradient paths to `ws.grad`, and the
/// L cotangent to `ws.l_cot`.  Allocation-free once `ws` is warm.  All
/// O(B·m) / O(B·m²) products run on `be`; the scalar bookkeeping loops
/// (row sums, per-coordinate gradient folds) stay backend-independent.
fn accumulate_chunk(
    layout: &ThetaLayout,
    be: &dyn ComputeBackend,
    f: &Factorization,
    x: &Mat,
    y: &[f64],
    chunk: usize,
    ws: &mut LaneWs,
) {
    let (m, d) = (layout.m, layout.d);
    let start = chunk * CHUNK;
    let b = CHUNK.min(x.rows - start);
    let a0_sq = f.lchain.params.a0_sq();
    let eta = f.lchain.params.eta();
    let beta = f.beta;
    let z = &f.lchain.z;

    // Chunk rows, memcpy'd into the reusable buffer (no view type in
    // this substrate; the copy is noise next to the O(B·m²) products).
    ws.xc.resize(b, x.cols);
    ws.xc
        .data
        .copy_from_slice(&x.data[start * x.cols..(start + b) * x.cols]);
    let yc = &y[start..start + b];

    // ---- forward (the Pallas kernel's job on the XLA path) ----
    be.cross_into_ws(&f.lchain.params, &ws.xc, z, &mut ws.k_bm, &mut ws.cross); // [B, m]
    be.mul_tril_into(&ws.k_bm, &f.lchain.chol_l, &mut ws.phi); // [B, m]
    // uphi rows: (U φ_i)ᵀ = φᵀ Uᵀ; sphi rows: (Σ φ_i)ᵀ = (U φ)ᵀ U.
    be.mul_triu_t_into(&ws.phi, &f.u, &mut ws.uphi);
    be.mul_triu_into(&ws.uphi, &f.u, &mut ws.sphi);
    ws.e.resize(b, 0.0);
    ws.quad.resize(b, 0.0);
    ws.ktilde.resize(b, 0.0);
    for i in 0..b {
        let phi_i = ws.phi.row(i);
        ws.e[i] = be.dot(phi_i, &f.mu) - yc[i];
        ws.quad[i] = be.sumsq(ws.uphi.row(i));
        ws.ktilde[i] = a0_sq - be.sumsq(phi_i);
    }
    let mut g_val = 0.0;
    for i in 0..b {
        g_val += 0.5 * (2.0 * std::f64::consts::PI).ln() + f.log_sigma
            + 0.5 * beta * (ws.e[i] * ws.e[i] + ws.quad[i] + ws.ktilde[i]);
    }
    ws.value += g_val;

    // ---- dμ (eq. 16): β Φ^T e ----
    {
        be.tr_matvec_into(&ws.phi, &ws.e, &mut ws.dmu);
        let r = layout.mu_range();
        for (gslot, v) in ws.grad[r].iter_mut().zip(&ws.dmu) {
            *gslot += beta * v;
        }
    }

    // ---- dU (eq. 17): β triu(U Φ^T Φ) ----
    {
        be.gram_into(&ws.phi, &mut ws.gram); // Φ^T Φ
        be.triu_matmul_into(&f.u, &ws.gram, &mut ws.du);
        ws.du.triu_inplace();
        let r = layout.u_range();
        for (gslot, v) in ws.grad[r].iter_mut().zip(&ws.du.data) {
            *gslot += beta * v;
        }
    }

    // ---- dlnσ (eq. 26) ----
    {
        let mut s = 0.0;
        for i in 0..b {
            s += 1.0 - beta * (ws.e[i] * ws.e[i] + ws.quad[i] + ws.ktilde[i]);
        }
        ws.grad[layout.log_sigma_idx()] += s;
    }

    // ---- dln a0 (eq. 27) — exact for ALL paths: Φ ∝ a0 identically
    // (K_bm ∝ a0², L ∝ a0^{-1} incl. the a0²-scaled jitter), so the
    // closed form needs no chain contribution. ----
    {
        let mut s = 0.0;
        for i in 0..b {
            let phim = ws.e[i] + yc[i]; // φ_i^T μ
            let phi_sq = a0_sq - ws.ktilde[i]; // ‖φ_i‖²
            s += -yc[i] * phim + ws.quad[i] + phim * phim + a0_sq - phi_sq;
        }
        ws.grad[layout.log_a0_idx()] += beta * s;
    }

    // ---- P (eq. 29): p_i = e_i μ + Σ φ_i − φ_i (= ∂g_i/∂φ_i / β) ----
    ws.p.resize(b, m);
    for i in 0..b {
        let ei = ws.e[i];
        let prow = ws.p.row_mut(i);
        let phii = &ws.phi.data[i * m..(i + 1) * m];
        let sphii = &ws.sphi.data[i * m..(i + 1) * m];
        for j in 0..m {
            prow[j] = ei * f.mu[j] + sphii[j] - phii[j];
        }
    }

    // ---- direct K_bm path: A1 = (P Lᵀ) ∘ K_bm ----
    be.mul_tril_t_into(&ws.p, &f.lchain.chol_l, &mut ws.a1);
    for (v, k) in ws.a1.data.iter_mut().zip(&ws.k_bm.data) {
        *v *= k;
    }
    be.col_sums_into(&ws.a1, &mut ws.s_col); // s_j = Σ_i A1[i,j]
    ws.row_sum.resize(b, 0.0);
    for i in 0..b {
        ws.row_sum[i] = ws.a1.row(i).iter().sum();
    }
    be.tr_matmul_into(&ws.a1, &ws.xc, &mut ws.a1t_x); // [m, d]

    // dZ direct: β η_k [ (A1ᵀX)[j,k] − s_j z_jk ].
    {
        let r = layout.z_range();
        let gz = &mut ws.grad[r];
        for j in 0..m {
            for k in 0..d {
                gz[j * d + k] +=
                    beta * eta[k] * (ws.a1t_x[(j, k)] - ws.s_col[j] * z[(j, k)]);
            }
        }
    }

    // dlnη direct: −½ β η_k Σ_ij A1[i,j] (x_ik − z_jk)².
    {
        let r = layout.log_eta_range();
        let geta = &mut ws.grad[r];
        for k in 0..d {
            let mut q = 0.0;
            for i in 0..b {
                let xik = ws.xc[(i, k)];
                q += ws.row_sum[i] * xik * xik;
            }
            for j in 0..m {
                let zjk = z[(j, k)];
                q += -2.0 * zjk * ws.a1t_x[(j, k)] + ws.s_col[j] * zjk * zjk;
            }
            geta[k] += -0.5 * beta * eta[k] * q;
        }
    }

    // ---- accumulate the true L cotangent: dL̄ += β K_bmᵀ P ----
    {
        be.tr_matmul_into(&ws.k_bm, &ws.p, &mut ws.dmat);
        ws.l_cot.axpy(beta, &ws.dmat);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn test_theta(layout: ThetaLayout, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::seeded(seed);
        let z = Mat::from_vec(layout.m, layout.d,
                              (0..layout.m * layout.d).map(|_| rng.normal() * 0.8).collect());
        let mut th = Theta::init(layout, &z);
        for v in th.mu_mut() {
            *v = rng.normal() * 0.3;
        }
        let m = layout.m;
        let mut u = Mat::eye(m);
        for i in 0..m {
            u[(i, i)] = 0.7 + 0.3 * rng.next_f64();
            for j in i + 1..m {
                u[(i, j)] = rng.normal() * 0.05;
            }
        }
        th.set_u_mat(&u);
        th.data[layout.log_a0_idx()] = 0.2;
        for (k, v) in th.data[layout.log_eta_range()].iter_mut().enumerate() {
            *v = 0.1 * (k as f64 - 1.0);
        }
        th.data[layout.log_sigma_idx()] = -0.3;
        th.data
    }

    fn value_at(layout: ThetaLayout, theta: &[f64], x: &Mat, y: &[f64]) -> f64 {
        NativeEngine::new(layout).grad(theta, x, y).value
    }

    fn rand_data(n: usize, d: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Pcg64::seeded(seed);
        let x = Mat::from_vec(n, d, (0..n * d).map(|_| rng.normal()).collect());
        let y = (0..n).map(|_| rng.normal()).collect();
        (x, y)
    }

    /// Central finite differences over EVERY θ coordinate.
    #[test]
    fn gradient_matches_finite_differences() {
        let layout = ThetaLayout::new(5, 3);
        let theta = test_theta(layout, 1);
        let (x, y) = rand_data(24, 3, 2);
        let mut engine = NativeEngine::new(layout);
        let res = engine.grad(&theta, &x, &y);
        let eps = 1e-5;
        let mut max_rel = 0.0f64;
        for i in 0..layout.len() {
            let mut tp = theta.clone();
            tp[i] += eps;
            let mut tm = theta.clone();
            tm[i] -= eps;
            let fd = (value_at(layout, &tp, &x, &y) - value_at(layout, &tm, &x, &y))
                / (2.0 * eps);
            let an = res.grad[i];
            let denom = fd.abs().max(an.abs()).max(1e-4);
            let rel = (fd - an).abs() / denom;
            max_rel = max_rel.max(rel);
            assert!(
                rel < 2e-3,
                "coord {i}: analytic {an:.8} vs fd {fd:.8} (rel {rel:.2e})"
            );
        }
        assert!(max_rel < 2e-3, "max rel err {max_rel:.2e}");
    }

    #[test]
    fn strictly_lower_u_gradient_is_zero() {
        let layout = ThetaLayout::new(4, 2);
        let theta = test_theta(layout, 3);
        let (x, y) = rand_data(32, 2, 4);
        let res = NativeEngine::new(layout).grad(&theta, &x, &y);
        let ur = layout.u_range();
        let m = 4;
        for i in 0..m {
            for j in 0..i {
                assert_eq!(res.grad[ur.start + i * m + j], 0.0);
            }
        }
    }

    #[test]
    fn value_matches_sparse_gp_data_term() {
        let layout = ThetaLayout::new(6, 3);
        let theta = test_theta(layout, 5);
        let (x, y) = rand_data(50, 3, 6);
        let res = NativeEngine::new(layout).grad(&theta, &x, &y);
        let gp = crate::gp::SparseGp::new(Theta { layout, data: theta.clone() });
        let want = gp.data_term(&x, &y);
        assert!((res.value - want).abs() < 1e-8 * want.abs().max(1.0),
                "{} vs {}", res.value, want);
    }

    #[test]
    fn additive_over_shards() {
        let layout = ThetaLayout::new(5, 3);
        let theta = test_theta(layout, 7);
        let (x, y) = rand_data(64, 3, 8);
        let ds = crate::data::Dataset { x, y };
        let mut eng = NativeEngine::new(layout);
        let whole = eng.grad(&theta, &ds.x, &ds.y);
        let shards = ds.shard(4);
        let mut sum_val = 0.0;
        let mut sum_grad = vec![0.0; layout.len()];
        for s in &shards {
            let r = eng.grad(&theta, &s.x, &s.y);
            sum_val += r.value;
            for (a, b) in sum_grad.iter_mut().zip(&r.grad) {
                *a += b;
            }
        }
        assert!((whole.value - sum_val).abs() < 1e-8);
        for (a, b) in whole.grad.iter().zip(&sum_grad) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn chunking_is_transparent() {
        let layout = ThetaLayout::new(4, 2);
        let theta = test_theta(layout, 9);
        let n = CHUNK + 513;
        let (x, y) = rand_data(n, 2, 10);
        let mut eng = NativeEngine::new(layout);
        let whole = eng.grad(&theta, &x, &y);
        let x1 = Mat::from_vec(CHUNK, 2, x.data[..CHUNK * 2].to_vec());
        let x2 = Mat::from_vec(513, 2, x.data[CHUNK * 2..].to_vec());
        let r1 = eng.grad(&theta, &x1, &y[..CHUNK]);
        let r2 = eng.grad(&theta, &x2, &y[CHUNK..]);
        assert!((whole.value - r1.value - r2.value).abs() < 1e-6);
        for i in 0..layout.len() {
            assert!((whole.grad[i] - r1.grad[i] - r2.grad[i]).abs() < 1e-6);
        }
    }

    /// Workspace reuse across calls of *different* shapes must not
    /// change results: a warm engine and a fresh engine agree exactly.
    #[test]
    fn workspace_reuse_is_transparent() {
        let layout = ThetaLayout::new(5, 3);
        let theta = test_theta(layout, 11);
        let mut warm = NativeEngine::new(layout);
        // Warm the workspace on shapes larger and smaller than the probe.
        let (xa, ya) = rand_data(96, 3, 12);
        let (xb, yb) = rand_data(7, 3, 13);
        warm.grad(&theta, &xa, &ya);
        warm.grad(&theta, &xb, &yb);
        let (x, y) = rand_data(41, 3, 14);
        let from_warm = warm.grad(&theta, &x, &y);
        let from_fresh = NativeEngine::new(layout).grad(&theta, &x, &y);
        assert_eq!(from_warm.value, from_fresh.value);
        assert_eq!(from_warm.grad, from_fresh.grad);
    }

    /// The lane fan-out (pool budget > 1) must match the fully serial
    /// path to reduction-order precision on a multi-chunk shard.
    ///
    /// Budgets are pinned so the lane path actually engages (it needs
    /// `n_chunks >= 2 * par`): with 6 chunks, budgets 2 and 3 qualify
    /// on any multi-core host; an unbudgeted run on a many-core host
    /// would silently take the single-lane path instead.
    #[test]
    fn lane_parallel_matches_serial() {
        let layout = ThetaLayout::new(4, 2);
        let theta = test_theta(layout, 15);
        let n = 5 * CHUNK + 137; // 6 chunks
        let (x, y) = rand_data(n, 2, 16);
        let mut eng = NativeEngine::new(layout);
        let serial = crate::util::pool::with_budget(1, || eng.grad(&theta, &x, &y));
        for budget in [2usize, 3] {
            let par = crate::util::pool::with_budget(budget, || eng.grad(&theta, &x, &y));
            let scale = serial.value.abs().max(1.0);
            assert!((serial.value - par.value).abs() < 1e-9 * scale);
            for (a, b) in serial.grad.iter().zip(&par.grad) {
                assert!((a - b).abs() < 1e-8 * a.abs().max(1.0) + 1e-9,
                        "budget {budget}: {a} vs {b}");
            }
        }
    }
}
