//! Gradient engines: compute `(G, ∇G)` of the local data term for a
//! worker's shard.
//!
//! Two interchangeable implementations:
//! * [`native::NativeEngine`] — pure Rust, analytic appendix-A formulas
//!   (eqs. 16–17, 26–32 batched).  Used by baselines, tests, and the
//!   high-worker-count scaling benches.
//! * [`crate::runtime::XlaEngine`] — executes the AOT JAX/Pallas
//!   artifact through PJRT (the production hot path).
//!
//! Both implement [`GradEngine`] over the same flat θ layout, and an
//! integration test pins them against each other.

pub mod chain;
pub mod native;

use crate::gp::ThetaLayout;
use crate::linalg::Mat;

/// Result of one local-gradient computation.
#[derive(Clone, Debug)]
pub struct GradResult {
    /// The local data term G_k(θ) (eq. 15, summed over the shard).
    pub value: f64,
    /// ∇G_k in the flat θ layout.
    pub grad: Vec<f64>,
}

/// Computes the data-term gradient over a worker's shard.
///
/// Engines are created per worker thread by an [`EngineFactory`]
/// (PJRT clients are not `Send`, so they can never cross threads).
pub trait GradEngine {
    fn layout(&self) -> ThetaLayout;

    /// Full-shard gradient at θ (chunks the shard internally if needed).
    fn grad(&mut self, theta: &[f64], x: &Mat, y: &[f64]) -> GradResult;

    /// Name for logs/metrics.
    fn name(&self) -> &'static str;
}

/// Per-thread engine constructor (worker id → engine).
pub type EngineFactory = std::sync::Arc<dyn Fn(usize) -> Box<dyn GradEngine> + Send + Sync>;

/// Convenience: factory for the pure-Rust engine.
pub fn native_factory(layout: ThetaLayout) -> EngineFactory {
    std::sync::Arc::new(move |_worker| Box::new(native::NativeEngine::new(layout)))
}
