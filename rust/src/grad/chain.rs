//! The L-path chain rule shared by both gradient engines.
//!
//! The feature map uses `L = chol(K_mm^{-1})` (eq. 11).  Given the true
//! cotangent `dL̄ = ∂G/∂L`, this module back-propagates it through
//!
//!   L = cholesky(K_inv)      (reverse-mode Cholesky, half-diag mask)
//!   K_inv = K_mm⁻¹           (reverse-mode inverse)
//!   K_mm  = k(Z, Z) + jitter·a0²·I   (ARD kernel VJP)
//!
//! yielding the (Z, lnη, ln a0) contributions.  This is exactly the
//! content of the paper's appendix eqs. 28–32 (their Ψ/T_i operator is
//! the per-sample form of the Cholesky differential); we keep the
//! mechanical form because every step is independently testable.
//!
//! Used by: `NativeEngine` (which also computes dL̄ itself) and
//! `XlaEngine` (whose artifact returns dL̄ — jax's CPU linalg lowers to
//! typed-FFI custom-calls that xla_extension 0.5.1 cannot execute, so
//! the O(m³) factor lives on the Rust side of the ABI).

use crate::kernel::{cross_pairwise, kmm, ArdParams, DEFAULT_JITTER};
use crate::linalg::{cholesky_lower, solve_lower, spd_inverse, Mat};

/// Factorization context for one θ.
pub struct LChain {
    pub params: ArdParams,
    pub z: Mat,
    /// Lower L with K_mm^{-1} = L L^T (jittered K_mm).
    pub chol_l: Mat,
    /// L^{-1} (lower).
    pub chol_l_inv: Mat,
    /// K_mm^{-1} (jittered).
    pub kinv: Mat,
    /// Jittered K_mm.
    pub kmm_jit: Mat,
    /// Raw (unjittered) kernel matrix k(Z, Z).
    pub kmm_raw: Mat,
}

/// Gradient contributions flowing through L.
pub struct LChainGrads {
    pub dz: Mat,
    pub dlog_eta: Vec<f64>,
    pub dlog_a0: f64,
}

impl LChain {
    pub fn build(params: ArdParams, z: Mat) -> Self {
        Self::try_build(params, z).expect("K_mm SPD")
    }

    /// Fallible build: returns `None` when K_mm (or its inverse) is not
    /// SPD at this θ — line searches probe such points and must see a
    /// +∞ objective rather than a panic.
    pub fn try_build(params: ArdParams, z: Mat) -> Option<Self> {
        let m = z.rows;
        let kmm_jit = kmm(&params, &z, DEFAULT_JITTER);
        let kinv = spd_inverse(&kmm_jit).ok()?;
        let chol_l = cholesky_lower(&kinv).ok()?;
        let mut chol_l_inv = Mat::zeros(m, m);
        for col in 0..m {
            let mut e = vec![0.0; m];
            e[col] = 1.0;
            let xcol = solve_lower(&chol_l, &e);
            for r in col..m {
                chol_l_inv[(r, col)] = xcol[r];
            }
        }
        let kmm_raw = cross_pairwise(&params, &z, &z);
        Some(Self { params, z, chol_l, chol_l_inv, kinv, kmm_jit, kmm_raw })
    }

    /// Back-propagate the true cotangent `l_cot = ∂G/∂L` to (Z, lnη, ln a0).
    pub fn chain(&self, l_cot: &Mat) -> LChainGrads {
        let m = self.z.rows;
        let d = self.z.cols;
        let eta = self.params.eta();
        // Cholesky reverse-mode for K_inv = L Lᵀ:
        //   K̄inv = ½ L^{-T} (Φ(Lᵀ dL̄) + Φ(Lᵀ dL̄)ᵀ) L^{-1},
        // Φ = take-lower with halved diagonal.
        let lt_d = self.chol_l.transpose().matmul(l_cot);
        let mut philow = Mat::zeros(m, m);
        for i in 0..m {
            for j in 0..=i {
                philow[(i, j)] = lt_d[(i, j)] * if i == j { 0.5 } else { 1.0 };
            }
        }
        let mut sym = philow.clone();
        let pt = philow.transpose();
        sym.add_assign(&pt);
        let linv = &self.chol_l_inv;
        let mut kinv_cot = linv.transpose().matmul(&sym).matmul(linv);
        kinv_cot.scale(0.5);
        // Inverse reverse-mode: K̄mm = −K_inv K̄inv K_inv.
        let mut kmm_cot = self.kinv.matmul(&kinv_cot).matmul(&self.kinv);
        kmm_cot.scale(-1.0);

        // Kernel VJP.  G2 = (K̄mm + K̄mmᵀ) ∘ K_raw for dZ;
        // G3 = K̄mm ∘ K_raw for dlnη; dln a0 = 2 Σ K̄mm ∘ K_jit
        // (the jitter ridge scales with a0², hence K_jit).
        let mut g2 = kmm_cot.clone();
        let kt = kmm_cot.transpose();
        g2.add_assign(&kt);
        for (v, k) in g2.data.iter_mut().zip(&self.kmm_raw.data) {
            *v *= k;
        }
        let g2_z = g2.matmul(&self.z);
        let g2_rowsum: Vec<f64> = (0..m).map(|j| g2.row(j).iter().sum()).collect();
        let mut dz = Mat::zeros(m, d);
        for j in 0..m {
            for k in 0..d {
                dz[(j, k)] =
                    eta[k] * (g2_z[(j, k)] - g2_rowsum[j] * self.z[(j, k)]);
            }
        }

        let mut g3 = kmm_cot.clone();
        for (v, k) in g3.data.iter_mut().zip(&self.kmm_raw.data) {
            *v *= k;
        }
        let g3_z = g3.matmul(&self.z);
        let g3_rowsum: Vec<f64> = (0..m).map(|j| g3.row(j).iter().sum()).collect();
        let g3_colsum = g3.col_sums();
        let mut dlog_eta = vec![0.0; d];
        for k in 0..d {
            let mut q = 0.0;
            for j in 0..m {
                let zjk = self.z[(j, k)];
                q += g3_rowsum[j] * zjk * zjk - 2.0 * zjk * g3_z[(j, k)]
                    + g3_colsum[j] * zjk * zjk;
            }
            dlog_eta[k] = -0.5 * eta[k] * q;
        }

        let mut dlog_a0 = 0.0;
        for (c, k) in kmm_cot.data.iter().zip(&self.kmm_jit.data) {
            dlog_a0 += 2.0 * c * k;
        }

        LChainGrads { dz, dlog_eta, dlog_a0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// FD check of the full chain: scalar s(L(Z, η, a0)) = Σ W ∘ L.
    #[test]
    fn chain_matches_finite_differences() {
        let (m, d) = (5, 3);
        let mut rng = Pcg64::seeded(77);
        let z0 = Mat::from_vec(m, d, (0..m * d).map(|_| rng.normal()).collect());
        let w = Mat::from_vec(m, m, (0..m * m).map(|_| rng.normal()).collect());
        let params0 = ArdParams { log_a0: 0.15, log_eta: vec![0.1, -0.2, 0.05] };

        let scalar = |params: &ArdParams, z: &Mat| -> f64 {
            let c = LChain::build(params.clone(), z.clone());
            c.chol_l.data.iter().zip(&w.data).map(|(a, b)| a * b).sum()
        };

        let chain = LChain::build(params0.clone(), z0.clone());
        let grads = chain.chain(&w);
        let eps = 1e-6;

        // Z coordinates.
        for j in 0..m {
            for k in 0..d {
                let mut zp = z0.clone();
                zp[(j, k)] += eps;
                let mut zm = z0.clone();
                zm[(j, k)] -= eps;
                let fd = (scalar(&params0, &zp) - scalar(&params0, &zm)) / (2.0 * eps);
                let an = grads.dz[(j, k)];
                assert!(
                    (fd - an).abs() < 1e-4 * fd.abs().max(an.abs()).max(1.0),
                    "dz[{j},{k}] fd {fd} vs {an}"
                );
            }
        }
        // lnη.
        for k in 0..d {
            let mut pp = params0.clone();
            pp.log_eta[k] += eps;
            let mut pm = params0.clone();
            pm.log_eta[k] -= eps;
            let fd = (scalar(&pp, &z0) - scalar(&pm, &z0)) / (2.0 * eps);
            let an = grads.dlog_eta[k];
            assert!((fd - an).abs() < 1e-4 * fd.abs().max(an.abs()).max(1.0),
                    "dleta[{k}] fd {fd} vs {an}");
        }
        // ln a0.
        let mut pp = params0.clone();
        pp.log_a0 += eps;
        let mut pm = params0.clone();
        pm.log_a0 -= eps;
        let fd = (scalar(&pp, &z0) - scalar(&pm, &z0)) / (2.0 * eps);
        assert!((fd - grads.dlog_a0).abs() < 1e-4 * fd.abs().max(1.0),
                "dla0 fd {fd} vs {}", grads.dlog_a0);
    }
}
