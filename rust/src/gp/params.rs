//! The flat parameter vector θ shared by the server, both gradient
//! engines, and the AOT artifacts.
//!
//! Layout (f64 host-side; converted to f32 at the PJRT boundary), in the
//! exact positional order of `python/compile/model.py`:
//!
//! ```text
//! mu        [m]        variational mean of q(w)
//! u         [m*m]      row-major upper-tri Cholesky factor of Σ
//! z         [m*d]      row-major inducing inputs
//! log_a0    [1]
//! log_eta   [d]
//! log_sigma [1]
//! ```

use crate::kernel::ArdParams;
use crate::linalg::Mat;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ThetaLayout {
    pub m: usize,
    pub d: usize,
}

impl ThetaLayout {
    pub fn new(m: usize, d: usize) -> Self {
        Self { m, d }
    }

    pub fn len(&self) -> usize {
        self.m + self.m * self.m + self.m * self.d + 1 + self.d + 1
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn mu_range(&self) -> std::ops::Range<usize> {
        0..self.m
    }

    pub fn u_range(&self) -> std::ops::Range<usize> {
        let s = self.m;
        s..s + self.m * self.m
    }

    pub fn z_range(&self) -> std::ops::Range<usize> {
        let s = self.m + self.m * self.m;
        s..s + self.m * self.d
    }

    pub fn log_a0_idx(&self) -> usize {
        self.m + self.m * self.m + self.m * self.d
    }

    pub fn log_eta_range(&self) -> std::ops::Range<usize> {
        let s = self.log_a0_idx() + 1;
        s..s + self.d
    }

    pub fn log_sigma_idx(&self) -> usize {
        self.log_eta_range().end
    }

    /// Is index `i` part of the variational block (μ or U)?  The server
    /// applies the proximal operator only there (Algorithm 1 line 3).
    pub fn is_variational(&self, i: usize) -> bool {
        i < self.m + self.m * self.m
    }

    /// Is index `i` a *diagonal* element of U (special prox, eq. 20)?
    pub fn is_u_diag(&self, i: usize) -> bool {
        let ur = self.u_range();
        if !ur.contains(&i) {
            return false;
        }
        let off = i - ur.start;
        off % self.m == off / self.m
    }
}

/// Owned parameter vector with typed accessors.
#[derive(Clone, Debug)]
pub struct Theta {
    pub layout: ThetaLayout,
    pub data: Vec<f64>,
}

impl Theta {
    /// Paper §6.1 init: μ = 0, U = I, unit kernel, given inducing points.
    pub fn init(layout: ThetaLayout, z_init: &Mat) -> Self {
        assert_eq!(z_init.rows, layout.m);
        assert_eq!(z_init.cols, layout.d);
        let mut data = vec![0.0; layout.len()];
        let m = layout.m;
        for i in 0..m {
            data[layout.u_range().start + i * m + i] = 1.0;
        }
        data[layout.z_range()].copy_from_slice(&z_init.data);
        // log_a0 = 0, log_sigma = 0.  Lengthscales use the standard
        // heuristic for standardized features: eta_k = 1/d, so that the
        // expected scaled distance E[eta * ||x - x'||^2] = 2 stays inside
        // the kernel's responsive range for any input dimension.
        let log_eta0 = -(layout.d as f64).ln();
        for v in &mut data[layout.log_eta_range()] {
            *v = log_eta0;
        }
        Self { layout, data }
    }

    pub fn mu(&self) -> &[f64] {
        &self.data[self.layout.mu_range()]
    }

    pub fn mu_mut(&mut self) -> &mut [f64] {
        let r = self.layout.mu_range();
        &mut self.data[r]
    }

    pub fn u_mat(&self) -> Mat {
        Mat::from_vec(self.layout.m, self.layout.m,
                      self.data[self.layout.u_range()].to_vec())
    }

    pub fn set_u_mat(&mut self, u: &Mat) {
        assert_eq!((u.rows, u.cols), (self.layout.m, self.layout.m));
        let r = self.layout.u_range();
        self.data[r].copy_from_slice(&u.data);
    }

    pub fn z_mat(&self) -> Mat {
        Mat::from_vec(self.layout.m, self.layout.d,
                      self.data[self.layout.z_range()].to_vec())
    }

    pub fn set_z_mat(&mut self, z: &Mat) {
        let r = self.layout.z_range();
        self.data[r].copy_from_slice(&z.data);
    }

    pub fn log_a0(&self) -> f64 {
        self.data[self.layout.log_a0_idx()]
    }

    pub fn log_eta(&self) -> &[f64] {
        &self.data[self.layout.log_eta_range()]
    }

    pub fn log_sigma(&self) -> f64 {
        self.data[self.layout.log_sigma_idx()]
    }

    pub fn beta(&self) -> f64 {
        (-2.0 * self.log_sigma()).exp()
    }

    pub fn ard(&self) -> ArdParams {
        ArdParams { log_a0: self.log_a0(), log_eta: self.log_eta().to_vec() }
    }

    /// KL term h(μ, U) of eq. (24): ½(−ln|Σ| − m + tr Σ + μᵀμ), with
    /// Σ = UᵀU so ln|Σ| = 2 Σ_i ln|U_ii| and tr Σ = ΣᵢⱼU²ᵢⱼ.
    pub fn kl(&self) -> f64 {
        let m = self.layout.m;
        let u = &self.data[self.layout.u_range()];
        let mut logdet = 0.0;
        let mut tr = 0.0;
        for i in 0..m {
            for j in i..m {
                let v = u[i * m + j];
                tr += v * v;
            }
            logdet += u[i * m + i].abs().max(1e-300).ln();
        }
        let mu_sq: f64 = self.mu().iter().map(|x| x * x).sum();
        0.5 * (-2.0 * logdet - m as f64 + tr + mu_sq)
    }

    /// Enforce the upper-triangular structure of U (zero strict lower).
    pub fn enforce_triu(&mut self) {
        let m = self.layout.m;
        let r = self.layout.u_range();
        let u = &mut self.data[r];
        for i in 0..m {
            for j in 0..i {
                u[i * m + j] = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_ranges_partition() {
        let l = ThetaLayout::new(5, 3);
        assert_eq!(l.len(), 5 + 25 + 15 + 1 + 3 + 1);
        assert_eq!(l.mu_range().end, l.u_range().start);
        assert_eq!(l.u_range().end, l.z_range().start);
        assert_eq!(l.z_range().end, l.log_a0_idx());
        assert_eq!(l.log_a0_idx() + 1, l.log_eta_range().start);
        assert_eq!(l.log_eta_range().end, l.log_sigma_idx());
        assert_eq!(l.log_sigma_idx() + 1, l.len());
    }

    #[test]
    fn variational_and_diag_classification() {
        let l = ThetaLayout::new(3, 2);
        for i in 0..l.len() {
            let expect = i < 3 + 9;
            assert_eq!(l.is_variational(i), expect, "i={i}");
        }
        // U diag offsets: u starts at 3; diag at local 0, 4, 8.
        let diags: Vec<usize> = (0..l.len()).filter(|&i| l.is_u_diag(i)).collect();
        assert_eq!(diags, vec![3, 7, 11]);
    }

    #[test]
    fn init_is_paper_init() {
        let l = ThetaLayout::new(4, 2);
        let z = Mat::from_vec(4, 2, (0..8).map(|i| i as f64).collect());
        let th = Theta::init(l, &z);
        assert!(th.mu().iter().all(|&x| x == 0.0));
        let u = th.u_mat();
        assert!(u.max_abs_diff(&Mat::eye(4)) < 1e-15);
        assert_eq!(th.z_mat().data, z.data);
        assert_eq!(th.log_a0(), 0.0);
        assert_eq!(th.log_sigma(), 0.0);
        // KL at the prior is exactly 0.
        assert!(th.kl().abs() < 1e-12);
    }

    #[test]
    fn kl_matches_dense_formula() {
        let l = ThetaLayout::new(3, 1);
        let z = Mat::zeros(3, 1);
        let mut th = Theta::init(l, &z);
        th.mu_mut().copy_from_slice(&[0.5, -1.0, 2.0]);
        let u = Mat::from_rows(vec![
            vec![0.9, 0.2, -0.1],
            vec![0.0, 1.1, 0.3],
            vec![0.0, 0.0, 0.7],
        ]);
        th.set_u_mat(&u);
        let sigma = u.transpose().matmul(&u);
        let (w, _) = crate::linalg::sym_eig(&sigma);
        let logdet: f64 = w.iter().map(|x| x.ln()).sum();
        let want = 0.5 * (-logdet - 3.0 + sigma.trace() + 0.25 + 1.0 + 4.0);
        assert!((th.kl() - want).abs() < 1e-9, "{} vs {}", th.kl(), want);
    }
}
