//! GP core: parameter layout, feature maps, sparse predictive model,
//! ELBO evaluation, exact-GP oracle.

pub mod exact;
pub mod featuremap;
pub mod params;

pub use params::{Theta, ThetaLayout};

use crate::gp::featuremap::{FeatureMap, InducingChol};
use crate::linalg::Mat;

/// Sparse-GP predictive model bound to a parameter vector θ.
///
/// Wraps the eq. (11) feature map; prediction follows §3's augmented
/// model: q(f*) = N(φ(x*)^T μ, ktilde + φ^T Σ φ), plus σ² for y*.
pub struct SparseGp {
    pub theta: Theta,
    map: InducingChol,
}

impl SparseGp {
    pub fn new(theta: Theta) -> Self {
        let map = InducingChol::build(&theta.ard(), theta.z_mat());
        Self { theta, map }
    }

    /// Refresh the cached feature-map factor after θ changed.
    pub fn update(&mut self, theta: Theta) {
        self.map = InducingChol::build(&theta.ard(), theta.z_mat());
        self.theta = theta;
    }

    /// Predictive mean and variance (of y, noise included) for a batch.
    pub fn predict(&self, x: &Mat) -> (Vec<f64>, Vec<f64>) {
        let pb = self.map.phi(&self.theta.ard(), x);
        let mu = self.theta.mu();
        let u = self.theta.u_mat(); // upper-tri
        let mean = pb.phi.matvec(mu);
        let noise = (2.0 * self.theta.log_sigma()).exp();
        let mut var = Vec::with_capacity(x.rows);
        for i in 0..x.rows {
            let phi_i = pb.phi.row(i);
            // ‖U φ‖² = φ^T Σ φ.
            let uphi = u.matvec(phi_i);
            let quad: f64 = uphi.iter().map(|v| v * v).sum();
            var.push((pb.ktilde[i] + quad).max(1e-12) + noise);
        }
        (mean, var)
    }

    /// The batch data term Σ_i g_i of the negative ELBO (eq. 23) —
    /// pure-Rust twin of `model.elbo_fn`'s first output.
    pub fn data_term(&self, x: &Mat, y: &[f64]) -> f64 {
        let pb = self.map.phi(&self.theta.ard(), x);
        let mu = self.theta.mu();
        let u = self.theta.u_mat();
        let beta = self.theta.beta();
        let log_sigma = self.theta.log_sigma();
        let mut g = 0.0;
        for i in 0..x.rows {
            let phi_i = pb.phi.row(i);
            let e = crate::linalg::dot(phi_i, mu) - y[i];
            let uphi = u.matvec(phi_i);
            let quad: f64 = uphi.iter().map(|v| v * v).sum();
            g += 0.5 * (2.0 * std::f64::consts::PI).ln() + log_sigma
                + 0.5 * beta * (e * e + quad + pb.ktilde[i]);
        }
        g
    }

    /// Full negative ELBO −L = Σ g_i + h (eq. 14) over a dataset.
    pub fn neg_elbo(&self, x: &Mat, y: &[f64]) -> f64 {
        self.data_term(x, y) + self.theta.kl()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::gp::exact::ExactGp;
    use crate::kernel::ArdParams;
    use crate::util::rmse;

    fn optimal_q(theta: &mut Theta, x: &Mat, y: &[f64]) {
        // Closed-form optimum: Σ* = (I + β Φ^T Φ)^{-1}, μ* = β Σ* Φ^T y.
        let map = InducingChol::build(&theta.ard(), theta.z_mat());
        let pb = map.phi(&theta.ard(), x);
        let beta = theta.beta();
        let m = theta.layout.m;
        let mut prec = pb.phi.gram();
        prec.scale(beta);
        for i in 0..m {
            prec[(i, i)] += 1.0;
        }
        let sigma = crate::linalg::spd_inverse(&prec).unwrap();
        let phity = pb.phi.tr_matvec(y);
        let mut mu = sigma.matvec(&phity);
        for v in &mut mu {
            *v *= beta;
        }
        theta.mu_mut().copy_from_slice(&mu);
        // U = chol(Σ)^T (upper).
        let l = crate::linalg::cholesky_lower(&sigma).unwrap();
        theta.set_u_mat(&l.transpose());
    }

    #[test]
    fn elbo_lower_bounds_exact_evidence() {
        let ds = synth::gp_draw(60, 2, 0.3, 7);
        let exact = ExactGp::fit(ArdParams::unit(2), (0.3f64).ln(), ds.x.clone(), &ds.y);
        let layout = ThetaLayout::new(12, 2);
        let mut rng = crate::util::rng::Pcg64::seeded(8);
        let z = crate::data::kmeans::kmeans(&ds.x, 12, 25, &mut rng);
        let mut theta = Theta::init(layout, &z);
        theta.data[layout.log_sigma_idx()] = (0.3f64).ln();
        // At the init q.
        let gp = SparseGp::new(theta.clone());
        let elbo_init = -gp.neg_elbo(&ds.x, &ds.y);
        assert!(elbo_init <= exact.log_evidence() + 1e-6);
        // At the optimal q: tighter but still a lower bound.
        optimal_q(&mut theta, &ds.x, &ds.y);
        let gp2 = SparseGp::new(theta);
        let elbo_opt = -gp2.neg_elbo(&ds.x, &ds.y);
        assert!(elbo_opt <= exact.log_evidence() + 1e-6);
        assert!(elbo_opt > elbo_init);
    }

    #[test]
    fn m_equals_n_predictions_match_exact() {
        let ds = synth::gp_draw(50, 2, 0.2, 9);
        let layout = ThetaLayout::new(50, 2);
        let mut theta = Theta::init(layout, &ds.x); // Z = X
        theta.data[layout.log_sigma_idx()] = (0.2f64).ln();
        // Match the exact GP's unit lengthscales (init uses η = 1/d).
        for v in &mut theta.data[layout.log_eta_range()] {
            *v = 0.0;
        }
        optimal_q(&mut theta, &ds.x, &ds.y);
        let sparse = SparseGp::new(theta);
        let exact = ExactGp::fit(ArdParams::unit(2), (0.2f64).ln(), ds.x.clone(), &ds.y);
        let test = synth::gp_draw(20, 2, 0.2, 10).x;
        let (ms, vs) = sparse.predict(&test);
        let (me, ve) = exact.predict(&test);
        assert!(rmse(&ms, &me) < 2e-2, "mean gap {}", rmse(&ms, &me));
        for (a, b) in vs.iter().zip(&ve) {
            assert!((a - b).abs() < 5e-2, "var gap {a} vs {b}");
        }
    }

    #[test]
    fn data_term_matches_manual_sum() {
        let ds = synth::friedman(64, 4, 0.3, 11);
        let layout = ThetaLayout::new(8, 4);
        let mut rng = crate::util::rng::Pcg64::seeded(12);
        let z = crate::data::kmeans::kmeans(&ds.x, 8, 10, &mut rng);
        let theta = Theta::init(layout, &z);
        let gp = SparseGp::new(theta);
        // Additivity: sum over two halves equals the whole.
        let h1 = ds.head(32);
        let x2 = Mat::from_vec(32, 4, ds.x.data[32 * 4..].to_vec());
        let y2 = ds.y[32..].to_vec();
        let whole = gp.data_term(&ds.x, &ds.y);
        let parts = gp.data_term(&h1.x, &h1.y) + gp.data_term(&x2, &y2);
        assert!((whole - parts).abs() < 1e-8);
    }
}
