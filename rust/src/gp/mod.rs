//! GP core: parameter layout, feature maps, sparse predictive model,
//! ELBO evaluation, exact-GP oracle.
//!
//! # Blocked posterior math (ISSUE 2)
//!
//! `SparseGp` no longer walks test rows one at a time.  Prediction and
//! the ELBO data term are computed in row chunks through the blocked,
//! pool-parallel linalg kernels of `linalg`:
//!
//! * `V = Φ Uᵀ` via the structural [`Mat::mul_triu_t_into`] kernel
//!   (suffix dots — half the multiplies of a dense product), so the
//!   per-row quadratic `φᵀ Σ φ = ‖U φ‖²` becomes a row sum-of-squares
//!   of `V`;
//! * the predictive mean `Φ μ` via the row-parallel matvec.
//!
//! All `[chunk, m]` temporaries live in a reusable [`PredictWorkspace`]
//! (mirroring `grad::native::NativeEngine`'s lane design): buffers are
//! resized in place and keep their capacity across calls, so the
//! steady-state predict path performs **zero heap allocation**.  Shards
//! wider than a few chunks fan out chunk→lane over the thread pool with
//! a static round-robin assignment and deterministic lane-order
//! reduction; smaller batches parallelize *inside* the kernels instead.

pub mod exact;
pub mod featuremap;
pub mod params;

pub use params::{Theta, ThetaLayout};

use crate::gp::featuremap::{FeatureMap, InducingChol, PhiBatch, PhiWorkspace};
use crate::kernel::ArdParams;
use crate::linalg::Mat;
use crate::runtime::backend::{self, ComputeBackend};
use crate::util::pool;

/// Max rows per prediction chunk (bounds the `[chunk, m]` temporaries;
/// same granularity as the gradient engine's chunking).
const PRED_CHUNK: usize = 2048;

/// Reusable buffers for the blocked posterior math.  One lane per
/// concurrently-processed chunk; lanes are grown on demand and keep
/// their capacity, so repeated `predict_into`/`data_term_ws` calls at a
/// fixed shape allocate nothing.
pub struct PredictWorkspace {
    lanes: Vec<PredictLane>,
}

struct PredictLane {
    /// Staged chunk rows `[b, d]` (no view type in this substrate; the
    /// memcpy is noise next to the O(b·m²) products).
    xc: Mat,
    phi_ws: PhiWorkspace,
    pb: PhiBatch,
    /// V = Φ Uᵀ rows: v_i = (U φ_i)ᵀ, shape [b, m].
    v: Mat,
    /// Φ μ for the chunk.
    mv: Vec<f64>,
    /// Lane-private data-term accumulator, reduced in lane order.
    g: f64,
}

impl PredictLane {
    fn new() -> Self {
        Self {
            xc: Mat::empty(),
            phi_ws: PhiWorkspace::new(),
            pb: PhiBatch::empty(),
            v: Mat::empty(),
            mv: Vec::new(),
            g: 0.0,
        }
    }
}

impl PredictWorkspace {
    pub fn new() -> Self {
        Self { lanes: Vec::new() }
    }

    fn ensure_lanes(&mut self, n: usize) {
        if self.lanes.len() < n {
            self.lanes.resize_with(n, PredictLane::new);
        }
    }
}

impl Default for PredictWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

/// Sparse-GP predictive model bound to a parameter vector θ.
///
/// Wraps the eq. (11) feature map; prediction follows §3's augmented
/// model: q(f*) = N(φ(x*)^T μ, ktilde + φ^T Σ φ), plus σ² for y*.
///
/// The kernel params, the (triu-enforced) variational factor U and the
/// feature-map factor are cached at construction so the per-batch
/// posterior math touches no allocating accessor.
pub struct SparseGp {
    pub theta: Theta,
    map: InducingChol,
    /// Cached kernel parameters (θ accessors allocate).
    ard: ArdParams,
    /// Cached U with the strict lower triangle zeroed — the posterior
    /// math (like the gradient engine) treats U as structurally
    /// upper-triangular.
    u: Mat,
    /// Kernel set the blocked posterior math executes on (ISSUE 10).
    /// The O(m³) feature-map build stays on the scalar reference path.
    be: &'static dyn ComputeBackend,
}

impl SparseGp {
    /// Model on the process-wide active backend
    /// ([`crate::runtime::backend::active`]) — scalar unless training
    /// config / `ADVGP_BACKEND` installed something else.
    pub fn new(theta: Theta) -> Self {
        Self::with_backend(theta, backend::active())
    }

    /// Model pinned to an explicit backend, regardless of global
    /// selection (used by the tolerance-contract tests and benches).
    pub fn with_backend(theta: Theta, be: &'static dyn ComputeBackend) -> Self {
        let ard = theta.ard();
        let map = InducingChol::build(&ard, theta.z_mat());
        let mut u = theta.u_mat();
        u.triu_inplace();
        Self { theta, map, ard, u, be }
    }

    /// Refresh the cached feature-map factor after θ changed.
    pub fn update(&mut self, theta: Theta) {
        self.ard = theta.ard();
        self.map = InducingChol::build(&self.ard, theta.z_mat());
        let mut u = theta.u_mat();
        u.triu_inplace();
        self.u = u;
        self.theta = theta;
    }

    /// Predictive mean and variance (of y, noise included) for a batch
    /// (allocating convenience wrapper around [`SparseGp::predict_into`]).
    pub fn predict(&self, x: &Mat) -> (Vec<f64>, Vec<f64>) {
        let mut ws = PredictWorkspace::new();
        let mut mean = Vec::new();
        let mut var = Vec::new();
        self.predict_into(x, &mut ws, &mut mean, &mut var);
        (mean, var)
    }

    /// Blocked predictive mean/variance into caller-owned buffers —
    /// allocation-free once `ws`/`mean`/`var` are warm.
    pub fn predict_into(
        &self,
        x: &Mat,
        ws: &mut PredictWorkspace,
        mean: &mut Vec<f64>,
        var: &mut Vec<f64>,
    ) {
        let n = x.rows;
        mean.resize(n, 0.0);
        var.resize(n, 0.0);
        if n == 0 {
            return;
        }
        let noise = (2.0 * self.theta.log_sigma()).exp();
        let meanw = pool::DisjointMut::new(&mut mean[..]);
        let varw = pool::DisjointMut::new(&mut var[..]);
        self.for_each_chunk(n, ws, &|lane, start, b| {
            // Safety: chunk row ranges are disjoint and statically
            // assigned (`for_each_chunk` hands each chunk out once).
            let ms = unsafe { meanw.range(start..start + b) };
            let vs = unsafe { varw.range(start..start + b) };
            self.predict_chunk(x, start, b, noise, lane, ms, vs);
        });
    }

    /// Shared chunk→lane dispatch for the blocked posterior paths: run
    /// `body(lane, start, b)` over every [`PRED_CHUNK`] chunk of `n`
    /// rows.  Many chunks → one lane per pool thread (static
    /// round-robin, serial inner linalg — see `NativeEngine`); few →
    /// a single lane whose kernels row-parallelize internally.  Lane
    /// `g` accumulators are zeroed for the lanes used; returns that
    /// lane count so callers can reduce in lane order.
    fn for_each_chunk(
        &self,
        n: usize,
        ws: &mut PredictWorkspace,
        body: &(dyn Fn(&mut PredictLane, usize, usize) + Sync),
    ) -> usize {
        let n_chunks = (n + PRED_CHUNK - 1) / PRED_CHUNK;
        let lanes = self.lane_count(n_chunks);
        ws.ensure_lanes(lanes);
        for lane in ws.lanes[..lanes].iter_mut() {
            lane.g = 0.0;
        }
        if lanes == 1 {
            let lane = &mut ws.lanes[0];
            for c in 0..n_chunks {
                let start = c * PRED_CHUNK;
                body(lane, start, PRED_CHUNK.min(n - start));
            }
        } else {
            pool::parallel_rows_mut(
                &mut ws.lanes[..lanes],
                1,
                lanes,
                1,
                &|lane_i, blk: &mut [PredictLane]| {
                    let lane = &mut blk[0];
                    pool::with_budget(1, || {
                        let mut c = lane_i;
                        while c < n_chunks {
                            let start = c * PRED_CHUNK;
                            body(lane, start, PRED_CHUNK.min(n - start));
                            c += lanes;
                        }
                    });
                },
            );
        }
        lanes
    }

    /// One chunk of the blocked posterior: Φ → mean slice, V = Φ Uᵀ →
    /// row sums-of-squares → variance slice.
    fn predict_chunk(
        &self,
        x: &Mat,
        start: usize,
        b: usize,
        noise: f64,
        lane: &mut PredictLane,
        mean: &mut [f64],
        var: &mut [f64],
    ) {
        self.chunk_forward(x, start, b, lane);
        mean.copy_from_slice(&lane.mv);
        for i in 0..b {
            let vi = lane.v.row(i);
            var[i] = (lane.pb.ktilde[i] + self.be.sumsq(vi)).max(1e-12) + noise;
        }
    }

    /// Shared forward pass for a chunk: stage rows, evaluate the
    /// feature map, Φ μ into `lane.mv`, V = Φ Uᵀ into `lane.v`.
    fn chunk_forward(&self, x: &Mat, start: usize, b: usize, lane: &mut PredictLane) {
        let d = x.cols;
        lane.xc.resize(b, d);
        lane.xc
            .data
            .copy_from_slice(&x.data[start * d..(start + b) * d]);
        self.map
            .phi_into_be(self.be, &self.ard, &lane.xc, &mut lane.phi_ws, &mut lane.pb);
        self.be.matvec_into(&lane.pb.phi, self.theta.mu(), &mut lane.mv);
        self.be.mul_triu_t_into(&lane.pb.phi, &self.u, &mut lane.v);
    }

    /// Decide the chunk→lane fan-out (same policy as the gradient
    /// engine): many chunks → one lane per pool thread with serial math
    /// inside; few chunks → a single lane whose kernels row-parallelize
    /// internally.
    fn lane_count(&self, n_chunks: usize) -> usize {
        let par = pool::effective_parallelism();
        if par > 1 && n_chunks >= 2 * par {
            par
        } else {
            1
        }
    }

    /// The batch data term Σ_i g_i of the negative ELBO (eq. 23) —
    /// pure-Rust twin of `model.elbo_fn`'s first output (allocating
    /// convenience wrapper around [`SparseGp::data_term_ws`]).
    pub fn data_term(&self, x: &Mat, y: &[f64]) -> f64 {
        let mut ws = PredictWorkspace::new();
        self.data_term_ws(x, y, &mut ws)
    }

    /// Blocked data term through a reusable workspace (allocation-free
    /// once `ws` is warm).
    pub fn data_term_ws(&self, x: &Mat, y: &[f64], ws: &mut PredictWorkspace) -> f64 {
        assert_eq!(x.rows, y.len());
        let n = x.rows;
        if n == 0 {
            return 0.0;
        }
        let lanes = self.for_each_chunk(n, ws, &|lane, start, b| {
            self.data_term_chunk(x, y, start, b, lane)
        });
        // Deterministic lane-order reduction.
        ws.lanes[..lanes].iter().map(|l| l.g).sum()
    }

    /// One chunk of the blocked data term (eq. 23), accumulated into
    /// the lane.
    fn data_term_chunk(&self, x: &Mat, y: &[f64], start: usize, b: usize, lane: &mut PredictLane) {
        self.chunk_forward(x, start, b, lane);
        let beta = self.theta.beta();
        let log_sigma = self.theta.log_sigma();
        let mut g = 0.0;
        for i in 0..b {
            let e = lane.mv[i] - y[start + i];
            let vi = lane.v.row(i);
            let quad = self.be.sumsq(vi);
            g += 0.5 * (2.0 * std::f64::consts::PI).ln() + log_sigma
                + 0.5 * beta * (e * e + quad + lane.pb.ktilde[i]);
        }
        lane.g += g;
    }

    /// Full negative ELBO −L = Σ g_i + h (eq. 14) over a dataset.
    pub fn neg_elbo(&self, x: &Mat, y: &[f64]) -> f64 {
        self.data_term(x, y) + self.theta.kl()
    }

    /// Negative ELBO through a reusable workspace.
    pub fn neg_elbo_ws(&self, x: &Mat, y: &[f64], ws: &mut PredictWorkspace) -> f64 {
        self.data_term_ws(x, y, ws) + self.theta.kl()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::gp::exact::ExactGp;
    use crate::kernel::ArdParams;
    use crate::util::rmse;

    fn optimal_q(theta: &mut Theta, x: &Mat, y: &[f64]) {
        // Closed-form optimum: Σ* = (I + β Φ^T Φ)^{-1}, μ* = β Σ* Φ^T y.
        let map = InducingChol::build(&theta.ard(), theta.z_mat());
        let pb = map.phi(&theta.ard(), x);
        let beta = theta.beta();
        let m = theta.layout.m;
        let mut prec = pb.phi.gram();
        prec.scale(beta);
        for i in 0..m {
            prec[(i, i)] += 1.0;
        }
        let sigma = crate::linalg::spd_inverse(&prec).unwrap();
        let phity = pb.phi.tr_matvec(y);
        let mut mu = sigma.matvec(&phity);
        for v in &mut mu {
            *v *= beta;
        }
        theta.mu_mut().copy_from_slice(&mu);
        // U = chol(Σ)^T (upper).
        let l = crate::linalg::cholesky_lower(&sigma).unwrap();
        theta.set_u_mat(&l.transpose());
    }

    #[test]
    fn elbo_lower_bounds_exact_evidence() {
        let ds = synth::gp_draw(60, 2, 0.3, 7);
        let exact = ExactGp::fit(ArdParams::unit(2), (0.3f64).ln(), ds.x.clone(), &ds.y);
        let layout = ThetaLayout::new(12, 2);
        let mut rng = crate::util::rng::Pcg64::seeded(8);
        let z = crate::data::kmeans::kmeans(&ds.x, 12, 25, &mut rng);
        let mut theta = Theta::init(layout, &z);
        theta.data[layout.log_sigma_idx()] = (0.3f64).ln();
        // At the init q.
        let gp = SparseGp::new(theta.clone());
        let elbo_init = -gp.neg_elbo(&ds.x, &ds.y);
        assert!(elbo_init <= exact.log_evidence() + 1e-6);
        // At the optimal q: tighter but still a lower bound.
        optimal_q(&mut theta, &ds.x, &ds.y);
        let gp2 = SparseGp::new(theta);
        let elbo_opt = -gp2.neg_elbo(&ds.x, &ds.y);
        assert!(elbo_opt <= exact.log_evidence() + 1e-6);
        assert!(elbo_opt > elbo_init);
    }

    #[test]
    fn m_equals_n_predictions_match_exact() {
        let ds = synth::gp_draw(50, 2, 0.2, 9);
        let layout = ThetaLayout::new(50, 2);
        let mut theta = Theta::init(layout, &ds.x); // Z = X
        theta.data[layout.log_sigma_idx()] = (0.2f64).ln();
        // Match the exact GP's unit lengthscales (init uses η = 1/d).
        for v in &mut theta.data[layout.log_eta_range()] {
            *v = 0.0;
        }
        optimal_q(&mut theta, &ds.x, &ds.y);
        let sparse = SparseGp::new(theta);
        let exact = ExactGp::fit(ArdParams::unit(2), (0.2f64).ln(), ds.x.clone(), &ds.y);
        let test = synth::gp_draw(20, 2, 0.2, 10).x;
        let (ms, vs) = sparse.predict(&test);
        let (me, ve) = exact.predict(&test);
        assert!(rmse(&ms, &me) < 2e-2, "mean gap {}", rmse(&ms, &me));
        for (a, b) in vs.iter().zip(&ve) {
            assert!((a - b).abs() < 5e-2, "var gap {a} vs {b}");
        }
    }

    #[test]
    fn data_term_matches_manual_sum() {
        let ds = synth::friedman(64, 4, 0.3, 11);
        let layout = ThetaLayout::new(8, 4);
        let mut rng = crate::util::rng::Pcg64::seeded(12);
        let z = crate::data::kmeans::kmeans(&ds.x, 8, 10, &mut rng);
        let theta = Theta::init(layout, &z);
        let gp = SparseGp::new(theta);
        // Additivity: sum over two halves equals the whole.
        let h1 = ds.head(32);
        let x2 = Mat::from_vec(32, 4, ds.x.data[32 * 4..].to_vec());
        let y2 = ds.y[32..].to_vec();
        let whole = gp.data_term(&ds.x, &ds.y);
        let parts = gp.data_term(&h1.x, &h1.y) + gp.data_term(&x2, &y2);
        assert!((whole - parts).abs() < 1e-8);
    }

    /// Per-row reference predict (the pre-ISSUE-2 implementation): one
    /// `u.matvec(φ_i)` per test row.
    fn predict_reference(gp: &SparseGp, x: &Mat) -> (Vec<f64>, Vec<f64>) {
        let pb = gp.map.phi(&gp.theta.ard(), x);
        let mu = gp.theta.mu();
        let u = gp.theta.u_mat();
        let mean = pb.phi.matvec(mu);
        let noise = (2.0 * gp.theta.log_sigma()).exp();
        let mut var = Vec::with_capacity(x.rows);
        for i in 0..x.rows {
            let uphi = u.matvec(pb.phi.row(i));
            let quad: f64 = uphi.iter().map(|v| v * v).sum();
            var.push((pb.ktilde[i] + quad).max(1e-12) + noise);
        }
        (mean, var)
    }

    fn random_gp(m: usize, d: usize, seed: u64) -> SparseGp {
        let mut rng = crate::util::rng::Pcg64::seeded(seed);
        let z = Mat::from_vec(m, d, (0..m * d).map(|_| rng.normal()).collect());
        let mut th = Theta::init(ThetaLayout::new(m, d), &z);
        for v in th.mu_mut() {
            *v = rng.normal() * 0.4;
        }
        let mut u = Mat::zeros(m, m);
        for i in 0..m {
            u[(i, i)] = 0.6 + rng.next_f64();
            for j in i + 1..m {
                u[(i, j)] = rng.normal() * 0.1;
            }
        }
        th.set_u_mat(&u);
        th.data[th.layout.log_sigma_idx()] = -0.4;
        SparseGp::new(th)
    }

    #[test]
    fn blocked_predict_matches_per_row_reference() {
        let gp = random_gp(7, 3, 21);
        let mut rng = crate::util::rng::Pcg64::seeded(22);
        for n in [1usize, 2, 33, 257] {
            let x = Mat::from_vec(n, 3, (0..n * 3).map(|_| rng.normal()).collect());
            let (mean, var) = gp.predict(&x);
            let (mr, vr) = predict_reference(&gp, &x);
            assert_eq!(mean, mr, "n={n}: blocked mean must be bitwise");
            for i in 0..n {
                let scale = vr[i].abs().max(1.0);
                assert!(
                    (var[i] - vr[i]).abs() <= 1e-12 * scale,
                    "n={n} row {i}: {} vs {}",
                    var[i],
                    vr[i]
                );
            }
        }
    }

    /// The predict/data-term hot path must not allocate in steady
    /// state: capacities of every reusable buffer are unchanged across
    /// repeated calls, including after warming on a different shape.
    #[test]
    fn predict_workspace_zero_steady_state_allocation() {
        let gp = random_gp(6, 2, 31);
        let mut rng = crate::util::rng::Pcg64::seeded(32);
        let xa = Mat::from_vec(97, 2, (0..97 * 2).map(|_| rng.normal()).collect());
        let xb = Mat::from_vec(40, 2, (0..40 * 2).map(|_| rng.normal()).collect());
        let yb: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let mut ws = PredictWorkspace::new();
        let mut mean = Vec::new();
        let mut var = Vec::new();
        // Warm on a larger shape, then settle on the steady shape.
        gp.predict_into(&xa, &mut ws, &mut mean, &mut var);
        gp.predict_into(&xb, &mut ws, &mut mean, &mut var);
        gp.data_term_ws(&xb, &yb, &mut ws);
        let sig = |ws: &PredictWorkspace, mean: &Vec<f64>, var: &Vec<f64>| {
            let mut caps = vec![ws.lanes.capacity(), mean.capacity(), var.capacity()];
            for l in &ws.lanes {
                caps.extend_from_slice(&[
                    l.xc.data.capacity(),
                    l.pb.phi.data.capacity(),
                    l.pb.ktilde.capacity(),
                    l.v.data.capacity(),
                    l.mv.capacity(),
                ]);
            }
            caps
        };
        let before = sig(&ws, &mean, &var);
        let (m0, v0) = (mean.clone(), var.clone());
        for _ in 0..4 {
            gp.predict_into(&xb, &mut ws, &mut mean, &mut var);
            gp.data_term_ws(&xb, &yb, &mut ws);
        }
        assert_eq!(sig(&ws, &mean, &var), before, "steady-state predict reallocated");
        assert_eq!(mean, m0);
        assert_eq!(var, v0);
    }

    /// The chunk→lane fan-out must be transparent: a multi-chunk batch
    /// predicted under different pool budgets matches the serial path
    /// exactly (per-row values depend only on their own row).
    #[test]
    fn lane_parallel_predict_matches_serial() {
        let gp = random_gp(5, 2, 41);
        let n = 5 * PRED_CHUNK + 137; // 6 chunks
        let mut rng = crate::util::rng::Pcg64::seeded(42);
        let x = Mat::from_vec(n, 2, (0..n * 2).map(|_| rng.normal()).collect());
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut ws = PredictWorkspace::new();
        let mut mean = Vec::new();
        let mut var = Vec::new();
        let (m0, v0, g0) = pool::with_budget(1, || {
            gp.predict_into(&x, &mut ws, &mut mean, &mut var);
            (mean.clone(), var.clone(), gp.data_term_ws(&x, &y, &mut ws))
        });
        for budget in [2usize, 3] {
            let g = pool::with_budget(budget, || {
                gp.predict_into(&x, &mut ws, &mut mean, &mut var);
                gp.data_term_ws(&x, &y, &mut ws)
            });
            assert_eq!(mean, m0, "mean differs at budget {budget}");
            assert_eq!(var, v0, "var differs at budget {budget}");
            // Lane reduction reorders the chunk partial sums.
            assert!(
                (g - g0).abs() < 1e-9 * g0.abs().max(1.0),
                "data term differs at budget {budget}: {g} vs {g0}"
            );
        }
    }
}
