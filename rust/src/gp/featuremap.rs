//! Feature maps φ(·) of the weight-space augmentation (paper §3, §5).
//!
//! Every map here has the form `phi(x) = W^T k_m(x)` for a projection
//! matrix `W` built from the inducing covariance K_mm, so a batch is
//! `Phi = K_bm W` and the eq. (6) residual diagonal is
//! `ktilde_i = k(x_i, x_i) - ||phi_i||^2`.  The paper's variants:
//!
//! * [`InducingChol`] — eq. (11): `W = L`, `K_mm^{-1} = L L^T`.  This is
//!   the Titsias/SVI parameterization and ADVGP's default.
//! * [`Nystrom`] — eq. (21): `W = Q diag(λ)^{-1/2}` (variational EigenGP).
//!   Spans the same subspace as `InducingChol` (Φ Φ^T identical), letting
//!   tests cross-validate both.
//! * [`EnsembleNystrom`] — eq. (22): q Nyström maps over q groups of
//!   inducing points, concatenated with 1/√q scaling so that
//!   `Φ Φ^T = (1/q) Σ_l Φ_l Φ_l^T ⪯ K_nn` (each term is the Schur-PSD
//!   single-group map).
//! * [`Rvm`] — §5's RVM-style map `phi(x) = diag(α)^{1/2} k_m(x)`, with α
//!   clamped to `α_i ≤ 1/λ_max(K_mm)` so `diag(α) ⪯ K_mm^{-1}` keeps
//!   K_nn − ΦΦ^T ⪰ 0.

use crate::kernel::{cross_into_ws, kmm, ArdParams, CrossScratch, DEFAULT_JITTER};
use crate::linalg::{cholesky_lower, spd_inverse, sym_eig, Mat};
use crate::runtime::ComputeBackend;

/// Batch output of a feature map.
pub struct PhiBatch {
    /// Φ rows: φ(x_i)^T, shape [B, p] (p = feature dimension).
    pub phi: Mat,
    /// ktilde_i = k(x_i, x_i) − ‖φ(x_i)‖², shape `[B]`.
    pub ktilde: Vec<f64>,
}

impl PhiBatch {
    /// Empty batch for use as a reusable `phi_into` target.
    pub fn empty() -> Self {
        Self { phi: Mat::empty(), ktilde: Vec::new() }
    }
}

/// Reusable scratch for [`FeatureMap::phi_into`] — holds the K_bm
/// buffer plus kernel scratch so callers that keep a workspace across
/// batches run the forward pass with no steady-state heap allocation.
/// Both the gradient engine (`grad::native::LaneWs`) and the blocked
/// posterior path (`gp::PredictWorkspace`, one per predict lane) embed
/// one; the allocating [`FeatureMap::phi`] remains as a convenience
/// for one-shot callers and tests.
pub struct PhiWorkspace {
    k_bm: Mat,
    cross: CrossScratch,
    /// Per-group staging buffer (ensembles only).
    tmp: Mat,
}

impl PhiWorkspace {
    pub fn new() -> Self {
        Self { k_bm: Mat::empty(), cross: CrossScratch::new(), tmp: Mat::empty() }
    }
}

impl Default for PhiWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

/// A feature map bound to (kernel params, inducing inputs).
pub trait FeatureMap {
    /// Feature dimension p (rows of w; = m except for ensembles).
    fn dim(&self) -> usize;

    /// Evaluate the map on a batch X [B, d] into caller-owned buffers
    /// (allocation-free once `ws`/`out` are warm).
    fn phi_into(&self, params: &ArdParams, x: &Mat, ws: &mut PhiWorkspace, out: &mut PhiBatch);

    /// [`FeatureMap::phi_into`] on an explicit compute backend
    /// (ISSUE 10).  The default ignores `be` and runs the scalar
    /// `phi_into` — correct for any map, so exotic maps need no SIMD
    /// plumbing; the hot maps ([`InducingChol`], [`Nystrom`]) override
    /// it to route their O(B·m·d) / O(B·m²) products through `be`.
    /// `ktilde_into` stays scalar under every backend: it is O(B·m)
    /// and keeping it common pins the eq. (6) diagonal bitwise across
    /// backends' shared portion.
    fn phi_into_be(
        &self,
        be: &dyn ComputeBackend,
        params: &ArdParams,
        x: &Mat,
        ws: &mut PhiWorkspace,
        out: &mut PhiBatch,
    ) {
        let _ = be;
        self.phi_into(params, x, ws, out);
    }

    /// Evaluate the map on a batch X [B, d] (allocating convenience
    /// wrapper around [`FeatureMap::phi_into`]).
    fn phi(&self, params: &ArdParams, x: &Mat) -> PhiBatch {
        let mut ws = PhiWorkspace::new();
        let mut out = PhiBatch::empty();
        self.phi_into(params, x, &mut ws, &mut out);
        out
    }
}

fn ktilde_into(phi: &Mat, a0_sq: f64, out: &mut Vec<f64>) {
    out.resize(phi.rows, 0.0);
    for (i, slot) in out.iter_mut().enumerate() {
        *slot = a0_sq - phi.row(i).iter().map(|v| v * v).sum::<f64>();
    }
}

/// eq. (11): φ(x) = L^T k_m(x), K_mm^{-1} = L L^T.
pub struct InducingChol {
    pub z: Mat,
    /// Lower-triangular L.
    pub chol_l: Mat,
}

impl InducingChol {
    pub fn build(params: &ArdParams, z: Mat) -> Self {
        let k = kmm(params, &z, DEFAULT_JITTER);
        let kinv = spd_inverse(&k).expect("K_mm SPD");
        let chol_l = cholesky_lower(&kinv).expect("K_mm^{-1} SPD");
        Self { z, chol_l }
    }
}

impl FeatureMap for InducingChol {
    fn dim(&self) -> usize {
        self.z.rows
    }

    fn phi_into(&self, params: &ArdParams, x: &Mat, ws: &mut PhiWorkspace, out: &mut PhiBatch) {
        cross_into_ws(params, x, &self.z, &mut ws.k_bm, &mut ws.cross);
        // L = chol(K_mm^{-1}) is lower triangular: structural kernel.
        ws.k_bm.mul_tril_into(&self.chol_l, &mut out.phi);
        ktilde_into(&out.phi, params.a0_sq(), &mut out.ktilde);
    }

    fn phi_into_be(
        &self,
        be: &dyn ComputeBackend,
        params: &ArdParams,
        x: &Mat,
        ws: &mut PhiWorkspace,
        out: &mut PhiBatch,
    ) {
        be.cross_into_ws(params, x, &self.z, &mut ws.k_bm, &mut ws.cross);
        be.mul_tril_into(&ws.k_bm, &self.chol_l, &mut out.phi);
        ktilde_into(&out.phi, params.a0_sq(), &mut out.ktilde);
    }
}

/// eq. (21): φ(x) = diag(λ)^{-1/2} Q^T k_m(x) — scaled Nyström/EigenGP.
pub struct Nystrom {
    pub z: Mat,
    /// W = Q diag(λ)^{-1/2} (columns scaled eigenvectors of K_mm).
    pub w: Mat,
}

impl Nystrom {
    pub fn build(params: &ArdParams, z: Mat) -> Self {
        let k = kmm(params, &z, DEFAULT_JITTER);
        let (lam, q) = sym_eig(&k);
        let m = z.rows;
        let mut w = q;
        for c in 0..m {
            let s = 1.0 / lam[c].max(1e-12).sqrt();
            for r in 0..m {
                w[(r, c)] *= s;
            }
        }
        Self { z, w }
    }
}

impl FeatureMap for Nystrom {
    fn dim(&self) -> usize {
        self.z.rows
    }

    fn phi_into(&self, params: &ArdParams, x: &Mat, ws: &mut PhiWorkspace, out: &mut PhiBatch) {
        cross_into_ws(params, x, &self.z, &mut ws.k_bm, &mut ws.cross);
        ws.k_bm.matmul_into(&self.w, &mut out.phi);
        ktilde_into(&out.phi, params.a0_sq(), &mut out.ktilde);
    }

    fn phi_into_be(
        &self,
        be: &dyn ComputeBackend,
        params: &ArdParams,
        x: &Mat,
        ws: &mut PhiWorkspace,
        out: &mut PhiBatch,
    ) {
        be.cross_into_ws(params, x, &self.z, &mut ws.k_bm, &mut ws.cross);
        be.matmul_into(&ws.k_bm, &self.w, &mut out.phi);
        ktilde_into(&out.phi, params.a0_sq(), &mut out.ktilde);
    }
}

/// eq. (22): concatenation of q Nyström maps with 1/sqrt(q) scaling.
pub struct EnsembleNystrom {
    pub groups: Vec<Nystrom>,
}

impl EnsembleNystrom {
    pub fn build(params: &ArdParams, groups: Vec<Mat>) -> Self {
        Self {
            groups: groups
                .into_iter()
                .map(|z| Nystrom::build(params, z))
                .collect(),
        }
    }
}

impl FeatureMap for EnsembleNystrom {
    fn dim(&self) -> usize {
        self.groups.iter().map(|g| g.dim()).sum()
    }

    fn phi_into(&self, params: &ArdParams, x: &Mat, ws: &mut PhiWorkspace, out: &mut PhiBatch) {
        let q = self.groups.len();
        let scale = 1.0 / (q as f64).sqrt();
        let b = x.rows;
        let p = self.dim();
        out.phi.resize(b, p);
        let mut col0 = 0;
        for g in &self.groups {
            let gd = g.dim();
            cross_into_ws(params, x, &g.z, &mut ws.k_bm, &mut ws.cross);
            ws.k_bm.matmul_into(&g.w, &mut ws.tmp);
            for r in 0..b {
                let src = ws.tmp.row(r);
                let dst = &mut out.phi.row_mut(r)[col0..col0 + gd];
                for (d, s) in dst.iter_mut().zip(src) {
                    *d = scale * s;
                }
            }
            col0 += gd;
        }
        ktilde_into(&out.phi, params.a0_sq(), &mut out.ktilde);
    }
}

/// §5 RVM-style: φ(x) = diag(α)^{1/2} k_m(x), α clamped for PSD.
pub struct Rvm {
    pub z: Mat,
    pub sqrt_alpha: Vec<f64>,
}

impl Rvm {
    /// Clamp each α_i to 1/(m λ_max(K_mm)) … guarantees
    /// diag(α) ⪯ (1/λ_max) I ⪯ K_mm^{-1}.
    pub fn build(params: &ArdParams, z: Mat, alpha: &[f64]) -> Self {
        assert_eq!(alpha.len(), z.rows);
        let k = kmm(params, &z, DEFAULT_JITTER);
        let (lam, _) = sym_eig(&k);
        let cap = 1.0 / lam[0].max(1e-12);
        let sqrt_alpha = alpha
            .iter()
            .map(|&a| a.clamp(0.0, cap).sqrt())
            .collect();
        Self { z, sqrt_alpha }
    }
}

impl FeatureMap for Rvm {
    fn dim(&self) -> usize {
        self.z.rows
    }

    fn phi_into(&self, params: &ArdParams, x: &Mat, ws: &mut PhiWorkspace, out: &mut PhiBatch) {
        cross_into_ws(params, x, &self.z, &mut out.phi, &mut ws.cross);
        for r in 0..out.phi.rows {
            let row = out.phi.row_mut(r);
            for (c, v) in row.iter_mut().enumerate() {
                *v *= self.sqrt_alpha[c];
            }
        }
        ktilde_into(&out.phi, params.a0_sq(), &mut out.ktilde);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel;
    use crate::util::rng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    /// K_nn − Φ Φ^T must be PSD (eq. 6's covariance): check via
    /// eigenvalues on a modest batch.
    fn assert_residual_psd(map: &dyn FeatureMap, params: &ArdParams, x: &Mat) {
        let knn = kernel::cross(params, x, x);
        let pb = map.phi(params, x);
        let ppt = pb.phi.matmul(&pb.phi.transpose());
        let mut resid = knn.clone();
        resid.axpy(-1.0, &ppt);
        let (w, _) = sym_eig(&resid);
        let min = w.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(min > -1e-6 * params.a0_sq(), "min eig {min}");
        // And ktilde is its diagonal.
        for i in 0..x.rows {
            assert!((pb.ktilde[i] - resid[(i, i)]).abs() < 1e-8);
        }
    }

    #[test]
    fn inducing_chol_psd_and_ktilde() {
        let mut rng = Pcg64::seeded(41);
        let params = ArdParams { log_a0: 0.2, log_eta: vec![0.1, -0.2, 0.0] };
        let z = rand_mat(&mut rng, 12, 3);
        let x = rand_mat(&mut rng, 25, 3);
        let map = InducingChol::build(&params, z);
        assert_residual_psd(&map, &params, &x);
    }

    #[test]
    fn nystrom_spans_same_subspace_as_chol() {
        let mut rng = Pcg64::seeded(42);
        let params = ArdParams::unit(2);
        let z = rand_mat(&mut rng, 8, 2);
        let x = rand_mat(&mut rng, 15, 2);
        let chol = InducingChol::build(&params, z.clone());
        let nys = Nystrom::build(&params, z);
        let p1 = chol.phi(&params, &x);
        let p2 = nys.phi(&params, &x);
        // Different bases but identical Gram matrices Φ Φ^T.
        let g1 = p1.phi.matmul(&p1.phi.transpose());
        let g2 = p2.phi.matmul(&p2.phi.transpose());
        assert!(g1.max_abs_diff(&g2) < 1e-6);
        for (a, b) in p1.ktilde.iter().zip(&p2.ktilde) {
            assert!((a - b).abs() < 1e-6);
        }
        assert_residual_psd(&nys, &params, &x);
    }

    #[test]
    fn ensemble_psd_and_dim() {
        let mut rng = Pcg64::seeded(43);
        let params = ArdParams::unit(2);
        let g1 = rand_mat(&mut rng, 5, 2);
        let g2 = rand_mat(&mut rng, 7, 2);
        let x = rand_mat(&mut rng, 20, 2);
        let ens = EnsembleNystrom::build(&params, vec![g1, g2]);
        assert_eq!(ens.dim(), 12);
        assert_residual_psd(&ens, &params, &x);
    }

    #[test]
    fn rvm_clamps_to_psd() {
        let mut rng = Pcg64::seeded(44);
        let params = ArdParams::unit(2);
        let z = rand_mat(&mut rng, 6, 2);
        let x = rand_mat(&mut rng, 18, 2);
        // Intentionally huge alphas: must be clamped.
        let alpha = vec![1e6; 6];
        let map = Rvm::build(&params, z, &alpha);
        assert_residual_psd(&map, &params, &x);
    }

    #[test]
    fn phi_into_matches_phi_and_reuses_buffers() {
        let mut rng = Pcg64::seeded(46);
        let params = ArdParams { log_a0: 0.1, log_eta: vec![0.2, -0.1] };
        let z = rand_mat(&mut rng, 6, 2);
        let g2 = rand_mat(&mut rng, 4, 2);
        let maps: Vec<Box<dyn FeatureMap>> = vec![
            Box::new(InducingChol::build(&params, z.clone())),
            Box::new(Nystrom::build(&params, z.clone())),
            Box::new(EnsembleNystrom::build(&params, vec![z.clone(), g2])),
            Box::new(Rvm::build(&params, z, &vec![0.3; 6])),
        ];
        let xa = rand_mat(&mut rng, 17, 2);
        let xb = rand_mat(&mut rng, 5, 2);
        for map in &maps {
            let mut ws = PhiWorkspace::new();
            let mut out = PhiBatch::empty();
            // Warm on one shape, then evaluate another: results must
            // match the allocating path exactly.
            map.phi_into(&params, &xa, &mut ws, &mut out);
            map.phi_into(&params, &xb, &mut ws, &mut out);
            let want = map.phi(&params, &xb);
            assert_eq!(out.phi.data, want.phi.data);
            assert_eq!(out.ktilde, want.ktilde);
            let cap = out.phi.data.capacity();
            map.phi_into(&params, &xb, &mut ws, &mut out);
            assert_eq!(out.phi.data.capacity(), cap, "phi_into reallocated");
        }
    }

    #[test]
    fn phi_into_be_scalar_is_bitwise_phi_into() {
        let mut rng = Pcg64::seeded(47);
        let params = ArdParams { log_a0: 0.1, log_eta: vec![0.2, -0.1] };
        let z = rand_mat(&mut rng, 6, 2);
        let x = rand_mat(&mut rng, 13, 2);
        let maps: Vec<Box<dyn FeatureMap>> = vec![
            Box::new(InducingChol::build(&params, z.clone())),
            Box::new(Nystrom::build(&params, z)),
        ];
        let be = crate::runtime::Backend::Scalar.resolve().unwrap();
        for map in &maps {
            let mut ws = PhiWorkspace::new();
            let mut out = PhiBatch::empty();
            map.phi_into_be(be, &params, &x, &mut ws, &mut out);
            let want = map.phi(&params, &x);
            assert_eq!(out.phi.data, want.phi.data);
            assert_eq!(out.ktilde, want.ktilde);
        }
    }

    #[test]
    fn ktilde_vanishes_on_inducing_points() {
        // φ at x = z_j reconstructs k exactly: ktilde(z_j) ≈ jitter-scale.
        let mut rng = Pcg64::seeded(45);
        let params = ArdParams::unit(3);
        let z = rand_mat(&mut rng, 10, 3);
        let map = InducingChol::build(&params, z.clone());
        let pb = map.phi(&params, &z);
        for &kt in &pb.ktilde {
            assert!(kt.abs() < 5e-4, "ktilde at inducing point: {kt}");
        }
    }
}
