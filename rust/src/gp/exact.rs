//! Exact GP regression (paper §2) — the O(n³) oracle.
//!
//! Used for small-n validation of the variational machinery: the ELBO
//! must lower-bound `log_evidence`, and sparse predictions must approach
//! exact ones as m → n.

use crate::kernel::{cross, ArdParams};
use crate::linalg::{cholesky_lower, solve_lower, solve_upper, Mat};

pub struct ExactGp {
    params: ArdParams,
    noise_var: f64,
    x: Mat,
    /// Lower Cholesky of K_nn + σ² I.
    chol: Mat,
    /// α = (K_nn + σ² I)^{-1} y.
    alpha: Vec<f64>,
    log_evidence: f64,
}

impl ExactGp {
    pub fn fit(params: ArdParams, log_sigma: f64, x: Mat, y: &[f64]) -> Self {
        let n = x.rows;
        assert_eq!(y.len(), n);
        let noise_var = (2.0 * log_sigma).exp();
        let mut c = cross(&params, &x, &x);
        for i in 0..n {
            c[(i, i)] += noise_var + 1e-10;
        }
        let chol = cholesky_lower(&c).expect("K + σ²I SPD");
        // α via two triangular solves.
        let tmp = solve_lower(&chol, y);
        let alpha = solve_upper(&chol.transpose(), &tmp);
        let logdet: f64 = chol.diag().iter().map(|v| 2.0 * v.ln()).sum();
        let fit: f64 = y.iter().zip(&alpha).map(|(a, b)| a * b).sum();
        let log_evidence =
            -0.5 * (n as f64 * (2.0 * std::f64::consts::PI).ln() + logdet + fit);
        Self { params, noise_var, x, chol, alpha, log_evidence }
    }

    /// Marginal log evidence log N(y | 0, K_nn + σ² I) (eq. 2).
    pub fn log_evidence(&self) -> f64 {
        self.log_evidence
    }

    /// Predictive mean/variance (of y*, noise included) — eqs. (3)–(5).
    pub fn predict(&self, xs: &Mat) -> (Vec<f64>, Vec<f64>) {
        let k_star = cross(&self.params, xs, &self.x); // [B, n]
        let mean = k_star.matvec(&self.alpha);
        let mut var = Vec::with_capacity(xs.rows);
        for i in 0..xs.rows {
            // v = L^{-1} k_*; var_f = k** − v^T v.
            let v = solve_lower(&self.chol, k_star.row(i));
            let kss = self.params.a0_sq();
            let vf = kss - v.iter().map(|x| x * x).sum::<f64>();
            var.push(vf.max(1e-12) + self.noise_var);
        }
        (mean, var)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::{mnlp, rmse};

    #[test]
    fn interpolates_noiseless_training_points() {
        let ds = synth::gp_draw(40, 2, 1e-3, 1);
        let gp = ExactGp::fit(ArdParams::unit(2), (1e-3f64).ln(), ds.x.clone(), &ds.y);
        let (mean, _) = gp.predict(&ds.x);
        assert!(rmse(&mean, &ds.y) < 5e-2);
    }

    #[test]
    fn beats_mean_predictor_on_gp_data() {
        let tr = synth::gp_draw(150, 2, 0.1, 2);
        let te = synth::gp_draw(50, 2, 0.1, 3); // independent draw: same prior
        let gp = ExactGp::fit(ArdParams::unit(2), (0.1f64).ln(), tr.x.clone(), &tr.y);
        let (mean, _var) = gp.predict(&tr.x);
        // In-sample must beat the mean predictor decisively.
        let gp_rmse = rmse(&mean, &tr.y);
        let ybar = tr.y.iter().sum::<f64>() / tr.n() as f64;
        let mean_rmse = rmse(&vec![ybar; tr.n()], &tr.y);
        assert!(gp_rmse < 0.6 * mean_rmse, "{gp_rmse} vs {mean_rmse}");
        // MNLP should be finite and sane on held-out (different function,
        // so just sanity: no NaN, variance positive).
        let (m2, v2) = gp.predict(&te.x);
        assert!(mnlp(&m2, &v2, &te.y).is_finite());
        assert!(v2.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn evidence_decreases_with_model_mismatch() {
        let ds = synth::gp_draw(60, 2, 0.1, 4);
        let good = ExactGp::fit(ArdParams::unit(2), (0.1f64).ln(), ds.x.clone(), &ds.y);
        let bad = ExactGp::fit(
            ArdParams { log_a0: 3.0, log_eta: vec![4.0, 4.0] },
            (0.1f64).ln(),
            ds.x.clone(),
            &ds.y,
        );
        assert!(good.log_evidence() > bad.log_evidence());
    }

    #[test]
    fn far_extrapolation_reverts_to_prior() {
        let ds = synth::gp_draw(30, 2, 0.1, 5);
        let gp = ExactGp::fit(ArdParams::unit(2), (0.1f64).ln(), ds.x.clone(), &ds.y);
        let far = Mat::from_vec(1, 2, vec![100.0, -100.0]);
        let (mean, var) = gp.predict(&far);
        assert!(mean[0].abs() < 1e-6);
        assert!((var[0] - (1.0 + 0.01)).abs() < 1e-6);
    }
}
