//! The bounded-staleness gate of Algorithm 1, with elastic membership.
//!
//! The server may advance from iteration `t` to `t+1` only when every
//! **live** worker's freshest gradient was computed at a version `t_k`
//! with `t − τ ≤ t_k` (and every live worker has pushed at least once).
//! τ = 0 is bulk-synchronous; τ = `u64::MAX` is fully asynchronous.
//!
//! Membership is elastic (ISSUE 3): a departed worker is **retired** —
//! its clock leaves the `min_k t_k` so the run proceeds without it —
//! and a joiner is **admitted** on its first push (there is no separate
//! hello: the first gradient both registers the worker and stamps its
//! clock, so a slow joiner can never stall the gate before it has work
//! to contribute).

/// Per-worker clock state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Clock {
    /// Registered but never pushed — blocks every update (Algorithm 1
    /// aggregates one gradient from every live worker).
    Pending,
    /// Freshest pushed version t_k.
    Active(u64),
    /// Departed (or an id gap left by sparse joins): excluded from the
    /// gate entirely.
    Retired,
}

/// Tracks per-worker freshest-push versions and answers the gate query.
#[derive(Clone, Debug)]
pub struct DelayGate {
    tau: u64,
    clocks: Vec<Clock>,
}

impl DelayGate {
    pub fn new(workers: usize, tau: u64) -> Self {
        Self { tau, clocks: vec![Clock::Pending; workers] }
    }

    pub fn tau(&self) -> u64 {
        self.tau
    }

    /// Record a push from `worker` computed at `version`.  Unknown ids
    /// are admitted (the gate grows); a retired id that pushes again is
    /// re-activated.  Returns true when this push *admitted* the worker
    /// into the live set (an unknown id, or a retired id coming back) —
    /// a `Pending` initial worker's first push is not an admission, it
    /// was already a member.
    pub fn record(&mut self, worker: usize, version: u64) -> bool {
        if worker >= self.clocks.len() {
            // Ids between the old frontier and the joiner never pushed:
            // they stay out of the gate until their own first push.
            self.clocks.resize(worker + 1, Clock::Retired);
        }
        let slot = &mut self.clocks[worker];
        let admitted = *slot == Clock::Retired;
        // Versions may arrive out of order under heavy async; keep max.
        *slot = match *slot {
            Clock::Active(v) => Clock::Active(v.max(version)),
            _ => Clock::Active(version),
        };
        admitted
    }

    /// Retire a departed worker: its clock no longer gates updates and
    /// its id may be re-admitted later by a fresh push.
    pub fn retire(&mut self, worker: usize) {
        if worker < self.clocks.len() {
            self.clocks[worker] = Clock::Retired;
        }
    }

    /// Is this id currently excluded from the gate?
    pub fn is_retired(&self, worker: usize) -> bool {
        self.clocks.get(worker).is_none_or(|c| *c == Clock::Retired)
    }

    /// Live (non-retired) workers currently gating updates.
    pub fn live(&self) -> usize {
        self.clocks.iter().filter(|c| **c != Clock::Retired).count()
    }

    /// May the server perform update `t` (producing version t+1)?
    /// False while any live worker is yet to push, or when no live
    /// worker remains at all.
    pub fn permits(&self, t: u64) -> bool {
        let mut any_live = false;
        for c in &self.clocks {
            match c {
                Clock::Retired => {}
                Clock::Pending => return false,
                Clock::Active(tk) => {
                    any_live = true;
                    if tk.saturating_add(self.tau) < t {
                        return false;
                    }
                }
            }
        }
        any_live
    }

    /// Current staleness bound observed: t − min over live clocks
    /// (None if some live worker never pushed, or none are live).
    pub fn staleness(&self, t: u64) -> Option<u64> {
        let mut min: Option<u64> = None;
        for c in &self.clocks {
            match c {
                Clock::Retired => {}
                Clock::Pending => return None,
                Clock::Active(tk) => min = Some(min.map_or(*tk, |m| m.min(*tk))),
            }
        }
        min.map(|m| t.saturating_sub(m))
    }

    /// Per-worker clocks for checkpointing: `Some(t_k)` for active
    /// workers, `None` for pending/retired slots.
    pub fn clocks(&self) -> Vec<Option<u64>> {
        self.clocks
            .iter()
            .map(|c| match c {
                Clock::Active(tk) => Some(*tk),
                _ => None,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requires_first_push_from_everyone() {
        let mut g = DelayGate::new(3, 100);
        assert!(!g.permits(0));
        g.record(0, 0);
        g.record(1, 0);
        assert!(!g.permits(0));
        g.record(2, 0);
        assert!(g.permits(0));
    }

    #[test]
    fn tau_zero_is_synchronous() {
        let mut g = DelayGate::new(2, 0);
        g.record(0, 0);
        g.record(1, 0);
        assert!(g.permits(0));
        // After update to t=1, old gradients (t_k=0) no longer qualify.
        assert!(!g.permits(1));
        g.record(0, 1);
        assert!(!g.permits(1));
        g.record(1, 1);
        assert!(g.permits(1));
    }

    #[test]
    fn tau_bounds_staleness_exactly() {
        let mut g = DelayGate::new(2, 3);
        g.record(0, 0);
        g.record(1, 0);
        for t in 0..=3 {
            assert!(g.permits(t), "t={t} within tau");
        }
        assert!(!g.permits(4), "t=4 exceeds tau=3 for t_k=0");
        g.record(1, 4);
        assert!(!g.permits(4), "worker 0 still stale");
        g.record(0, 2);
        assert!(g.permits(4), "t−τ=1 ≤ min t_k=2");
        assert_eq!(g.staleness(4), Some(2));
    }

    #[test]
    fn out_of_order_pushes_keep_max() {
        let mut g = DelayGate::new(1, 0);
        g.record(0, 5);
        g.record(0, 3); // late arrival of an older push
        assert!(g.permits(5));
        assert_eq!(g.staleness(5), Some(0));
    }

    #[test]
    fn huge_tau_is_fully_async() {
        let mut g = DelayGate::new(2, u64::MAX);
        g.record(0, 0);
        g.record(1, 3); // saturating add: no overflow at tau = MAX
        assert!(g.permits(1_000_000_000));
    }

    /// ISSUE 3: a departed worker's frozen clock must stop gating
    /// progress the moment it is retired.
    #[test]
    fn retired_clock_leaves_the_gate() {
        let mut g = DelayGate::new(3, 2);
        g.record(0, 10);
        g.record(1, 10);
        g.record(2, 0); // stale straggler
        assert!(!g.permits(10), "straggler's clock gates");
        assert_eq!(g.staleness(10), Some(10));
        g.retire(2);
        assert_eq!(g.live(), 2);
        assert!(g.permits(10), "retired clock must not gate");
        assert_eq!(g.staleness(10), Some(0));
        assert!(g.is_retired(2));
        // Rejoin: a fresh push re-admits the id (and reports it).
        assert!(g.record(2, 11), "re-admission must be reported");
        assert!(!g.is_retired(2));
        assert_eq!(g.live(), 3);
    }

    /// A joiner with an unseen id is admitted on first push; id gaps
    /// stay out of the gate.
    #[test]
    fn join_admits_on_first_push() {
        let mut g = DelayGate::new(2, 1);
        assert!(!g.record(0, 4), "initial member: not an admission");
        g.record(1, 4);
        assert!(g.permits(4));
        assert!(g.record(5, 4), "joiner admitted"); // ids 2..5 stay gaps
        assert!(!g.record(5, 5), "second push is not a second admission");
        assert_eq!(g.live(), 3);
        assert!(g.permits(4), "gap ids must not gate");
        assert_eq!(g.clocks(), vec![Some(4), Some(4), None, None, None, Some(5)]);
    }

    /// With every worker retired the gate closes (the server stops via
    /// its live-worker count, but permits must not go vacuously true).
    #[test]
    fn all_retired_never_permits() {
        let mut g = DelayGate::new(2, u64::MAX);
        g.record(0, 0);
        g.record(1, 0);
        g.retire(0);
        g.retire(1);
        assert_eq!(g.live(), 0);
        assert!(!g.permits(0));
        assert_eq!(g.staleness(0), None);
    }
}
