//! The bounded-staleness gate of Algorithm 1.
//!
//! The server may advance from iteration `t` to `t+1` only when every
//! worker's freshest gradient was computed at a version `t_k` with
//! `t − τ ≤ t_k` (and every worker has pushed at least once).  τ = 0 is
//! bulk-synchronous; τ = `u64::MAX` is fully asynchronous.

/// Tracks per-worker freshest-push versions and answers the gate query.
#[derive(Clone, Debug)]
pub struct DelayGate {
    tau: u64,
    /// Freshest pushed version per worker; `None` until the first push.
    latest: Vec<Option<u64>>,
}

impl DelayGate {
    pub fn new(workers: usize, tau: u64) -> Self {
        Self { tau, latest: vec![None; workers] }
    }

    pub fn tau(&self) -> u64 {
        self.tau
    }

    /// Record a push from `worker` computed at `version`.
    pub fn record(&mut self, worker: usize, version: u64) {
        let slot = &mut self.latest[worker];
        // Versions may arrive out of order under heavy async; keep max.
        *slot = Some(slot.map_or(version, |v| v.max(version)));
    }

    /// May the server perform update `t` (producing version t+1)?
    pub fn permits(&self, t: u64) -> bool {
        self.latest.iter().all(|slot| match slot {
            None => false,
            Some(tk) => *tk + self.tau >= t,
        })
    }

    /// Current staleness bound observed: t − min_k t_k (None if some
    /// worker never pushed).
    pub fn staleness(&self, t: u64) -> Option<u64> {
        let min = self
            .latest
            .iter()
            .map(|s| (*s)?.into())
            .collect::<Option<Vec<u64>>>()?
            .into_iter()
            .min()?;
        Some(t.saturating_sub(min))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requires_first_push_from_everyone() {
        let mut g = DelayGate::new(3, 100);
        assert!(!g.permits(0));
        g.record(0, 0);
        g.record(1, 0);
        assert!(!g.permits(0));
        g.record(2, 0);
        assert!(g.permits(0));
    }

    #[test]
    fn tau_zero_is_synchronous() {
        let mut g = DelayGate::new(2, 0);
        g.record(0, 0);
        g.record(1, 0);
        assert!(g.permits(0));
        // After update to t=1, old gradients (t_k=0) no longer qualify.
        assert!(!g.permits(1));
        g.record(0, 1);
        assert!(!g.permits(1));
        g.record(1, 1);
        assert!(g.permits(1));
    }

    #[test]
    fn tau_bounds_staleness_exactly() {
        let mut g = DelayGate::new(2, 3);
        g.record(0, 0);
        g.record(1, 0);
        for t in 0..=3 {
            assert!(g.permits(t), "t={t} within tau");
        }
        assert!(!g.permits(4), "t=4 exceeds tau=3 for t_k=0");
        g.record(1, 4);
        assert!(!g.permits(4), "worker 0 still stale");
        g.record(0, 2);
        assert!(g.permits(4), "t−τ=1 ≤ min t_k=2");
        assert_eq!(g.staleness(4), Some(2));
    }

    #[test]
    fn out_of_order_pushes_keep_max() {
        let mut g = DelayGate::new(1, 0);
        g.record(0, 5);
        g.record(0, 3); // late arrival of an older push
        assert!(g.permits(5));
        assert_eq!(g.staleness(5), Some(0));
    }

    #[test]
    fn huge_tau_is_fully_async() {
        let mut g = DelayGate::new(2, u64::MAX);
        g.record(0, 0);
        g.record(1, 0);
        assert!(g.permits(1_000_000_000));
    }
}
