//! The length-prefixed binary wire codec for the networked parameter
//! server: protocol revisions `ADVGPNT1` (ISSUE 4) and `ADVGPNT2`
//! (ISSUE 5 — partitioned θ: WELCOME2/PUBLISH2/PUSH2 carry a
//! `(slice_id, range)` plus a topology map, and PING/PONG add the WAN
//! heartbeat).  The two revisions share the stream magic and framing;
//! HELLO's `proto` field negotiates which one a connection speaks (a
//! revision-1 peer keeps working against a single-slice server).
//! The serving path (`ADVGPSV1`, ISSUE 8) rides the same rev-2 framing:
//! SUBSCRIBE opens a read-only session (a posterior stream on a θ-slice
//! server, or a predict session on a serving replica), POSTERIOR-SYNC
//! fans θ out to subscribers, and PREDICT/PREDICTION/REJECT carry the
//! batched prediction traffic with per-request admission control.
//! The routing tier (`ADVGPRT1`, ISSUE 9) adds ROUTE-STATUS — a
//! router → client fleet-observability frame any predict client must
//! absorb — and the normative retry-on-REJECT rule ([`reject_is_retryable`]).
//!
//! This module is pure codec: [`Frame`] ⇄ bytes, plus blocking
//! [`read_frame`]/[`write_frame`] helpers over any `Read`/`Write`.  All
//! socket handling, threading, and protocol *sequencing* (who sends
//! what when) lives in [`super::net`]; the byte-level contract is
//! specified normatively in `docs/PROTOCOL.md` — a reader should be
//! able to reimplement this file from that document alone.
//!
//! # Frame layout
//!
//! Every frame on the stream, both directions, little-endian:
//!
//! ```text
//! [0..4)       len       u32 — byte length of body ∥ checksum (≥ 9)
//! [4..4+len−8) body      kind u8, then the kind-specific payload
//! last 8       checksum  u64 FNV-1a over body (same rules as ADVGPCK1)
//! ```
//!
//! The checksum covers the body only; a corrupted length prefix
//! misframes the stream and surfaces as a checksum mismatch, an unknown
//! kind, or an out-of-range length — all hard errors (the connection is
//! dropped, never resynchronized).
//!
//! # Example: encode → decode roundtrip
//!
//! ```
//! use advgp::ps::messages::{Push, PublishMeta};
//! use advgp::ps::wire::Frame;
//!
//! let frame = Frame::Push(Push {
//!     worker: 1,
//!     version: 7,
//!     value: -3.25,
//!     grad: vec![0.5, -1.0],
//!     compute_secs: 0.125,
//! });
//! let bytes = frame.encode();
//! // Strip the 4-byte length prefix (a stream reader has already
//! // consumed it) and decode the rest.
//! let back = Frame::decode(&bytes[4..]).unwrap();
//! assert_eq!(back, frame);
//! ```

use super::messages::{FromServer, Push, PublishMeta, ToServer};
use super::sharded::MAX_SLICES;
use crate::util::{fnv1a64, FNV1A64_INIT};
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};

/// Magic bytes carried inside HELLO and WELCOME/WELCOME2.  This names
/// the protocol *family* (the framing and handshake shape) and is
/// shared by every revision — the `proto` field, not the magic, is what
/// negotiation keys on — so a revision-1 peer's first-frame magic check
/// keeps passing against a revision-2 implementation.
pub const WIRE_MAGIC: [u8; 8] = *b"ADVGPNT1";

/// Revision 1 — `ADVGPNT1`: single-server θ, full-vector PUBLISH/PUSH.
pub const PROTO_NT1: u32 = 1;

/// Revision 2 — `ADVGPNT2`: partitioned θ (WELCOME2/PUBLISH2/PUSH2
/// carry `(slice_id, range)` + the topology map) and PING/PONG
/// heartbeats.
pub const PROTO_NT2: u32 = 2;

/// Highest protocol revision spoken by this build.  HELLO carries the
/// highest revision the client speaks; the server answers with the
/// revision the connection will use — `min(offer, PROTO_VERSION)`,
/// downgraded to revision 1 only when the server owns all of θ (a
/// revision-1 frame cannot address a slice), else an `ERR_PROTO` error.
pub const PROTO_VERSION: u32 = PROTO_NT2;

/// Hard ceiling on the `len` field: frames larger than this are treated
/// as stream corruption, not as gigantic messages.  1 GiB comfortably
/// holds any realistic θ (m = 10⁴, d = 10² is ≈ 400 MB).
pub const MAX_FRAME_LEN: usize = 1 << 30;

/// Length ceiling for *handshake* frames (HELLO, WELCOME, and the
/// ERROR replies they can draw).  Until a peer has passed the
/// handshake it is fully untrusted, so the first read must not let a
/// length prefix alone commit the receiver to a MAX_FRAME_LEN
/// allocation — 4 KiB is orders of magnitude above any legal
/// handshake frame.
pub const MAX_HANDSHAKE_FRAME_LEN: usize = 4096;

/// HELLO `worker` value requesting server-side id assignment.
pub const WORKER_ID_ANY: u64 = u64::MAX;

/// Largest claimable worker id.  The server's gate clocks and gradient
/// slots are dense arrays indexed by id, so an unbounded id claim would
/// let one misconfigured client allocate gigabytes of bookkeeping on
/// the shared θ-server; 2¹⁶ workers is far beyond any realistic run.
pub const MAX_WORKER_ID: u64 = 1 << 16;

/// Frame kind bytes (first byte of every body).
pub const KIND_HELLO: u8 = 0x01;
pub const KIND_WELCOME: u8 = 0x02;
pub const KIND_PUBLISH: u8 = 0x03;
pub const KIND_PUSH: u8 = 0x04;
pub const KIND_EXIT: u8 = 0x05;
pub const KIND_SHUTDOWN: u8 = 0x06;
pub const KIND_ERROR: u8 = 0x07;
/// Revision-2 kinds (never sent on a revision-1 connection).
pub const KIND_PING: u8 = 0x08;
pub const KIND_PONG: u8 = 0x09;
pub const KIND_WELCOME2: u8 = 0x0A;
pub const KIND_PUBLISH2: u8 = 0x0B;
pub const KIND_PUSH2: u8 = 0x0C;
/// Serving-path kinds (ADVGPSV1, ISSUE 8) — spoken only on rev ≥ 2
/// connections opened with SUBSCRIBE instead of HELLO.
pub const KIND_SUBSCRIBE: u8 = 0x0D;
pub const KIND_POSTERIOR_SYNC: u8 = 0x0E;
pub const KIND_PREDICT: u8 = 0x0F;
pub const KIND_PREDICTION: u8 = 0x10;
pub const KIND_REJECT: u8 = 0x11;
/// Routing-tier kind (ADVGPRT1, ISSUE 9) — router → client only.
pub const KIND_ROUTE_STATUS: u8 = 0x12;

/// Ceiling on the replica count a ROUTE-STATUS frame may carry.  A
/// router fronts a handful-to-hundreds of replicas; a four-digit count
/// in a status frame is corruption, not a fleet.
pub const MAX_ROUTE_REPLICAS: usize = 1 << 10;

/// ROUTE-STATUS per-replica flag bit: the router has retired this
/// replica (heartbeat death or connect failure) and power-of-two-choices
/// no longer selects it.  All other bits are reserved and must be zero.
pub const ROUTE_RETIRED: u8 = 0x01;

/// ERROR frame codes.
pub const ERR_BAD_MAGIC: u16 = 1;
pub const ERR_PROTO: u16 = 2;
pub const ERR_ID_IN_USE: u16 = 3;
pub const ERR_MALFORMED: u16 = 4;
pub const ERR_DIM: u16 = 5;
pub const ERR_ID_MISMATCH: u16 = 6;

/// SUBSCRIBE scope: a θ-slice posterior stream (server → subscriber
/// POSTERIOR-SYNC fan-out; the read-path twin of a worker's PUBLISH2
/// stream).
pub const SUBSCRIBE_POSTERIOR: u8 = 0;
/// SUBSCRIBE scope: a predict session against a serving replica
/// (PREDICT/PREDICTION/REJECT traffic).
pub const SUBSCRIBE_PREDICT: u8 = 1;

/// REJECT codes — per-request admission-control verdicts (ADVGPSV1).
/// Unlike ERROR, a REJECT is *not* fatal: the session stays open and
/// the next PREDICT is admitted on its own merits.
pub const REJ_NOT_READY: u16 = 1;
pub const REJ_STALE: u16 = 2;
pub const REJ_OVERLOAD: u16 = 3;
pub const REJ_BAD_DIM: u16 = 4;
pub const REJ_BAD_SCOPE: u16 = 5;

/// The normative ADVGPRT1 retry rule: a REJECT that reflects *replica
/// state* (overload, staleness) may be transparently retried on a
/// sibling replica, because a sibling can hold a healthier queue or a
/// fresher posterior.  A REJECT that reflects the *request* (bad
/// dimension, bad scope) or the *fleet* (nothing ready anywhere) would
/// draw the same verdict from every sibling and must be surfaced as-is.
pub fn reject_is_retryable(code: u16) -> bool {
    matches!(code, REJ_OVERLOAD | REJ_STALE)
}

/// One replica's row in a ROUTE-STATUS frame: the newest posterior
/// version the router has observed from it, the rows currently in
/// flight to it, and its flag bits ([`ROUTE_RETIRED`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReplicaStatus {
    pub version: u64,
    pub inflight: u32,
    pub flags: u8,
}

impl ReplicaStatus {
    /// Is the [`ROUTE_RETIRED`] bit set?
    pub fn retired(&self) -> bool {
        self.flags & ROUTE_RETIRED != 0
    }
}

/// One ADVGPNT1 frame — see the module docs for the byte layout and
/// `docs/PROTOCOL.md` §"Frame table" for the per-kind payloads.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Client → server, first frame on every connection: magic,
    /// highest protocol revision spoken, and the worker id claimed
    /// ([`WORKER_ID_ANY`] = assign me one).
    Hello { proto: u32, worker: u64 },
    /// Server → client handshake reply: negotiated revision, the id the
    /// connection runs as, the θ layout (m, d), and the staleness bound.
    Welcome { proto: u32, worker: u64, m: u64, d: u64, tau: u64 },
    /// Server → client: one published θ snapshot (version, gate-clock
    /// metadata, full θ).
    Publish { version: u64, meta: PublishMeta, theta: Vec<f64> },
    /// Client → server: a local gradient ([`super::messages::Push`]).
    Push(Push),
    /// Client → server: permanent departure (retires the gate clock).
    WorkerExit { worker: u64 },
    /// Server → client: the run is over; close after reading this.
    Shutdown,
    /// Either direction: fatal protocol error; the sender closes the
    /// connection after writing it.
    Error { code: u16, message: String },
    /// Either direction, revision ≥ 2: liveness probe after read
    /// silence.  The receiver answers PONG promptly; no reply within
    /// the sender's grace window means the peer is wedged and is
    /// retired like a disconnect.
    Ping,
    /// Revision ≥ 2: the answer to PING.
    Pong,
    /// Server → client handshake reply, revision ≥ 2: WELCOME plus the
    /// θ slice this server owns (`slice_id`, `[start, end)`) and the
    /// full topology map, so a worker can validate that the servers it
    /// connected to tile θ exactly.
    Welcome2 {
        proto: u32,
        worker: u64,
        m: u64,
        d: u64,
        tau: u64,
        slice_id: u64,
        n_slices: u64,
        start: u64,
        end: u64,
        /// `(start, end)` per slice, in slice-id order — the topology
        /// map every participant must agree on.
        topology: Vec<(u64, u64)>,
    },
    /// Server → client, revision ≥ 2: one published snapshot of this
    /// server's θ slice (`theta.len() == end − start` of the WELCOME2
    /// range; `start` repeats the range origin as a consistency check).
    Publish2 { version: u64, meta: PublishMeta, slice_id: u64, start: u64, theta: Vec<f64> },
    /// Client → server, revision ≥ 2: the slice fragment of a local
    /// gradient — `push.grad` is restricted to the server's range.
    Push2 { slice_id: u64, start: u64, push: Push },
    /// Subscriber → server, first frame on a *read-only* connection
    /// (ADVGPSV1): magic, highest revision spoken, and the session
    /// scope ([`SUBSCRIBE_POSTERIOR`] against a θ-slice server,
    /// [`SUBSCRIBE_PREDICT`] against a serving replica).  A SUBSCRIBE
    /// connection never claims a worker id and never pushes.
    Subscribe { proto: u32, scope: u8 },
    /// Server → subscriber (ADVGPSV1): the handshake reply *and* every
    /// subsequent θ update on a posterior stream — layout, slice
    /// coordinates, topology range, version, gate-clock metadata, and
    /// the slice's θ values.  On a predict session the replica answers
    /// the handshake with a header-only sync (`theta` empty): the
    /// client learns `(m, d, version)` without shipping θ.
    PosteriorSync {
        m: u64,
        d: u64,
        slice_id: u64,
        n_slices: u64,
        start: u64,
        end: u64,
        version: u64,
        meta: PublishMeta,
        theta: Vec<f64>,
    },
    /// Client → replica (ADVGPSV1): one batch of prediction inputs —
    /// `rows` is row-major, `rows.len() == k·d` for some k ≥ 1.  `id`
    /// correlates the answer (PREDICTION or REJECT) on a pipelined
    /// session.
    Predict { id: u64, d: u64, rows: Vec<f64> },
    /// Replica → client (ADVGPSV1): the posterior answer for PREDICT
    /// `id` — predictive mean and variance per input row, plus the θ
    /// version the posterior was built from.
    Prediction { id: u64, version: u64, mean: Vec<f64>, var: Vec<f64> },
    /// Replica → client (ADVGPSV1): PREDICT `id` was refused by
    /// admission control (`REJ_*`).  Non-fatal: the session continues.
    Reject { id: u64, code: u16, message: String },
    /// Router → client (ADVGPRT1): fleet observability — the maximum
    /// posterior version across live replicas plus one
    /// [`ReplicaStatus`] per replica, in stable replica-index order.
    /// Sent after the predict handshake ack and whenever the router
    /// chooses to refresh it; a predict client must absorb it at any
    /// point after the handshake (direct replicas never send it).
    RouteStatus { fleet_version: u64, replicas: Vec<ReplicaStatus> },
}

impl Frame {
    /// The kind byte this frame encodes as.
    pub fn kind(&self) -> u8 {
        match self {
            Frame::Hello { .. } => KIND_HELLO,
            Frame::Welcome { .. } => KIND_WELCOME,
            Frame::Publish { .. } => KIND_PUBLISH,
            Frame::Push(_) => KIND_PUSH,
            Frame::WorkerExit { .. } => KIND_EXIT,
            Frame::Shutdown => KIND_SHUTDOWN,
            Frame::Error { .. } => KIND_ERROR,
            Frame::Ping => KIND_PING,
            Frame::Pong => KIND_PONG,
            Frame::Welcome2 { .. } => KIND_WELCOME2,
            Frame::Publish2 { .. } => KIND_PUBLISH2,
            Frame::Push2 { .. } => KIND_PUSH2,
            Frame::Subscribe { .. } => KIND_SUBSCRIBE,
            Frame::PosteriorSync { .. } => KIND_POSTERIOR_SYNC,
            Frame::Predict { .. } => KIND_PREDICT,
            Frame::Prediction { .. } => KIND_PREDICTION,
            Frame::Reject { .. } => KIND_REJECT,
            Frame::RouteStatus { .. } => KIND_ROUTE_STATUS,
        }
    }

    /// Serialize to the full on-stream form: length prefix, body,
    /// checksum.  The result is written with a single `write_all`, so
    /// concurrent writers serialized by a lock never interleave frames.
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::with_capacity(64);
        body.push(self.kind());
        match self {
            Frame::Hello { proto, worker } => {
                body.extend_from_slice(&WIRE_MAGIC);
                body.extend_from_slice(&proto.to_le_bytes());
                body.extend_from_slice(&worker.to_le_bytes());
            }
            Frame::Welcome { proto, worker, m, d, tau } => {
                body.extend_from_slice(&WIRE_MAGIC);
                body.extend_from_slice(&proto.to_le_bytes());
                body.extend_from_slice(&worker.to_le_bytes());
                body.extend_from_slice(&m.to_le_bytes());
                body.extend_from_slice(&d.to_le_bytes());
                body.extend_from_slice(&tau.to_le_bytes());
            }
            Frame::Publish { version, meta, theta } => {
                // One copy of the PUBLISH layout: the slice-based
                // encoder below is the normative implementation.
                return publish_frame_bytes(*version, *meta, theta);
            }
            Frame::Push(p) => {
                body.extend_from_slice(&(p.worker as u64).to_le_bytes());
                body.extend_from_slice(&p.version.to_le_bytes());
                body.extend_from_slice(&p.value.to_le_bytes());
                body.extend_from_slice(&p.compute_secs.to_le_bytes());
                body.extend_from_slice(&(p.grad.len() as u64).to_le_bytes());
                for v in &p.grad {
                    body.extend_from_slice(&v.to_le_bytes());
                }
            }
            Frame::WorkerExit { worker } => {
                body.extend_from_slice(&worker.to_le_bytes());
            }
            Frame::Shutdown | Frame::Ping | Frame::Pong => {}
            Frame::Error { code, message } => {
                body.extend_from_slice(&code.to_le_bytes());
                let msg = message.as_bytes();
                body.extend_from_slice(&(msg.len() as u32).to_le_bytes());
                body.extend_from_slice(msg);
            }
            Frame::Welcome2 {
                proto,
                worker,
                m,
                d,
                tau,
                slice_id,
                n_slices,
                start,
                end,
                topology,
            } => {
                body.extend_from_slice(&WIRE_MAGIC);
                body.extend_from_slice(&proto.to_le_bytes());
                body.extend_from_slice(&worker.to_le_bytes());
                body.extend_from_slice(&m.to_le_bytes());
                body.extend_from_slice(&d.to_le_bytes());
                body.extend_from_slice(&tau.to_le_bytes());
                body.extend_from_slice(&slice_id.to_le_bytes());
                body.extend_from_slice(&n_slices.to_le_bytes());
                body.extend_from_slice(&start.to_le_bytes());
                body.extend_from_slice(&end.to_le_bytes());
                assert_eq!(
                    topology.len() as u64,
                    *n_slices,
                    "WELCOME2: topology map must list every slice"
                );
                for (a, b) in topology {
                    body.extend_from_slice(&a.to_le_bytes());
                    body.extend_from_slice(&b.to_le_bytes());
                }
            }
            Frame::Publish2 { version, meta, slice_id, start, theta } => {
                // One copy of the layout: the slice-based encoder below
                // is the normative implementation.
                return publish2_frame_bytes(*version, *meta, *slice_id, *start, theta);
            }
            Frame::Push2 { slice_id, start, push: p } => {
                body.extend_from_slice(&(p.worker as u64).to_le_bytes());
                body.extend_from_slice(&p.version.to_le_bytes());
                body.extend_from_slice(&p.value.to_le_bytes());
                body.extend_from_slice(&p.compute_secs.to_le_bytes());
                body.extend_from_slice(&slice_id.to_le_bytes());
                body.extend_from_slice(&start.to_le_bytes());
                body.extend_from_slice(&(p.grad.len() as u64).to_le_bytes());
                for v in &p.grad {
                    body.extend_from_slice(&v.to_le_bytes());
                }
            }
            Frame::Subscribe { proto, scope } => {
                body.extend_from_slice(&WIRE_MAGIC);
                body.extend_from_slice(&proto.to_le_bytes());
                body.push(*scope);
            }
            Frame::PosteriorSync {
                m,
                d,
                slice_id,
                n_slices,
                start,
                end,
                version,
                meta,
                theta,
            } => {
                // One copy of the layout: the slice-based encoder below
                // is the normative implementation.
                return posterior_sync_frame_bytes(
                    *m, *d, *slice_id, *n_slices, *start, *end, *version, *meta, theta,
                );
            }
            Frame::Predict { id, d, rows } => {
                body.extend_from_slice(&id.to_le_bytes());
                body.extend_from_slice(&d.to_le_bytes());
                body.extend_from_slice(&(rows.len() as u64).to_le_bytes());
                for v in rows {
                    body.extend_from_slice(&v.to_le_bytes());
                }
            }
            Frame::Prediction { id, version, mean, var } => {
                body.extend_from_slice(&id.to_le_bytes());
                body.extend_from_slice(&version.to_le_bytes());
                assert_eq!(
                    mean.len(),
                    var.len(),
                    "PREDICTION: one variance per mean"
                );
                body.extend_from_slice(&(mean.len() as u64).to_le_bytes());
                for v in mean {
                    body.extend_from_slice(&v.to_le_bytes());
                }
                for v in var {
                    body.extend_from_slice(&v.to_le_bytes());
                }
            }
            Frame::Reject { id, code, message } => {
                body.extend_from_slice(&id.to_le_bytes());
                body.extend_from_slice(&code.to_le_bytes());
                let msg = message.as_bytes();
                body.extend_from_slice(&(msg.len() as u32).to_le_bytes());
                body.extend_from_slice(msg);
            }
            Frame::RouteStatus { fleet_version, replicas } => {
                assert!(
                    !replicas.is_empty() && replicas.len() <= MAX_ROUTE_REPLICAS,
                    "ROUTE-STATUS: {} replicas outside [1, {MAX_ROUTE_REPLICAS}]",
                    replicas.len()
                );
                body.extend_from_slice(&fleet_version.to_le_bytes());
                body.extend_from_slice(&(replicas.len() as u16).to_le_bytes());
                for r in replicas {
                    body.extend_from_slice(&r.version.to_le_bytes());
                    body.extend_from_slice(&r.inflight.to_le_bytes());
                    body.push(r.flags);
                }
            }
        }
        seal_frame(body)
    }

    /// Decode one frame from `bytes` = body ∥ checksum (the 4-byte
    /// length prefix already consumed by the stream reader).  Rejects
    /// checksum mismatches, unknown kinds, truncated payloads, trailing
    /// bytes, bad magic (HELLO/WELCOME), and invalid UTF-8 (ERROR).
    pub fn decode(bytes: &[u8]) -> Result<Frame> {
        ensure!(bytes.len() >= 9, "frame shorter than kind + checksum");
        let (body, sum) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(sum.try_into().unwrap());
        let actual = fnv1a64(FNV1A64_INIT, body);
        ensure!(
            stored == actual,
            "frame checksum mismatch (stored {stored:#018x}, computed \
             {actual:#018x}) — corrupt or misframed stream"
        );
        let kind = body[0];
        let mut r = Cursor { b: &body[1..], i: 0 };
        let frame = match kind {
            KIND_HELLO => {
                ensure!(r.take(8)? == WIRE_MAGIC, "HELLO: bad magic (want ADVGPNT1)");
                Frame::Hello { proto: r.u32()?, worker: r.u64()? }
            }
            KIND_WELCOME => {
                ensure!(r.take(8)? == WIRE_MAGIC, "WELCOME: bad magic (want ADVGPNT1)");
                Frame::Welcome {
                    proto: r.u32()?,
                    worker: r.u64()?,
                    m: r.u64()?,
                    d: r.u64()?,
                    tau: r.u64()?,
                }
            }
            KIND_PUBLISH => {
                let version = r.u64()?;
                let meta = PublishMeta { live: r.u64()?, staleness: r.u64()? };
                let dim = r.u64()? as usize;
                Frame::Publish { version, meta, theta: r.f64_vec(dim)? }
            }
            KIND_PUSH => {
                let worker = r.u64()?;
                ensure!(
                    worker <= MAX_WORKER_ID,
                    "PUSH: implausible worker id {worker} (max {MAX_WORKER_ID})"
                );
                let version = r.u64()?;
                let value = r.f64()?;
                let compute_secs = r.f64()?;
                let dim = r.u64()? as usize;
                Frame::Push(Push {
                    worker: worker as usize,
                    version,
                    value,
                    grad: r.f64_vec(dim)?,
                    compute_secs,
                })
            }
            KIND_EXIT => Frame::WorkerExit { worker: r.u64()? },
            KIND_SHUTDOWN => Frame::Shutdown,
            KIND_PING => Frame::Ping,
            KIND_PONG => Frame::Pong,
            KIND_WELCOME2 => {
                ensure!(r.take(8)? == WIRE_MAGIC, "WELCOME2: bad magic (want ADVGPNT1)");
                let proto = r.u32()?;
                let worker = r.u64()?;
                let m = r.u64()?;
                let d = r.u64()?;
                let tau = r.u64()?;
                let slice_id = r.u64()?;
                let n_slices = r.u64()?;
                let start = r.u64()?;
                let end = r.u64()?;
                ensure!(
                    (1..=MAX_SLICES as u64).contains(&n_slices),
                    "WELCOME2: implausible slice count {n_slices} (max {MAX_SLICES})"
                );
                ensure!(
                    slice_id < n_slices && start < end,
                    "WELCOME2: slice {slice_id}/{n_slices} with range [{start}, {end})"
                );
                let mut topology = Vec::with_capacity(n_slices as usize);
                for _ in 0..n_slices {
                    topology.push((r.u64()?, r.u64()?));
                }
                ensure!(
                    topology[slice_id as usize] == (start, end),
                    "WELCOME2: slice range disagrees with its topology entry"
                );
                Frame::Welcome2 {
                    proto,
                    worker,
                    m,
                    d,
                    tau,
                    slice_id,
                    n_slices,
                    start,
                    end,
                    topology,
                }
            }
            KIND_PUBLISH2 => {
                let version = r.u64()?;
                let meta = PublishMeta { live: r.u64()?, staleness: r.u64()? };
                let slice_id = r.u64()?;
                let start = r.u64()?;
                let dim = r.u64()? as usize;
                Frame::Publish2 { version, meta, slice_id, start, theta: r.f64_vec(dim)? }
            }
            KIND_PUSH2 => {
                let worker = r.u64()?;
                ensure!(
                    worker <= MAX_WORKER_ID,
                    "PUSH2: implausible worker id {worker} (max {MAX_WORKER_ID})"
                );
                let version = r.u64()?;
                let value = r.f64()?;
                let compute_secs = r.f64()?;
                let slice_id = r.u64()?;
                let start = r.u64()?;
                let dim = r.u64()? as usize;
                Frame::Push2 {
                    slice_id,
                    start,
                    push: Push {
                        worker: worker as usize,
                        version,
                        value,
                        grad: r.f64_vec(dim)?,
                        compute_secs,
                    },
                }
            }
            KIND_SUBSCRIBE => {
                ensure!(r.take(8)? == WIRE_MAGIC, "SUBSCRIBE: bad magic (want ADVGPNT1)");
                let proto = r.u32()?;
                let scope = r.take(1)?[0];
                ensure!(
                    scope == SUBSCRIBE_POSTERIOR || scope == SUBSCRIBE_PREDICT,
                    "SUBSCRIBE: unknown scope {scope}"
                );
                Frame::Subscribe { proto, scope }
            }
            KIND_POSTERIOR_SYNC => {
                let m = r.u64()?;
                let d = r.u64()?;
                let slice_id = r.u64()?;
                let n_slices = r.u64()?;
                let start = r.u64()?;
                let end = r.u64()?;
                let version = r.u64()?;
                let meta = PublishMeta { live: r.u64()?, staleness: r.u64()? };
                ensure!(
                    (1..=MAX_SLICES as u64).contains(&n_slices),
                    "POSTERIOR-SYNC: implausible slice count {n_slices} (max {MAX_SLICES})"
                );
                ensure!(
                    slice_id < n_slices && start < end,
                    "POSTERIOR-SYNC: slice {slice_id}/{n_slices} with range [{start}, {end})"
                );
                let dim = r.u64()? as usize;
                ensure!(
                    dim == 0 || dim as u64 == end - start,
                    "POSTERIOR-SYNC: {dim} θ values for range [{start}, {end}) \
                     (want 0 — a header-only sync — or the full slice)"
                );
                Frame::PosteriorSync {
                    m,
                    d,
                    slice_id,
                    n_slices,
                    start,
                    end,
                    version,
                    meta,
                    theta: r.f64_vec(dim)?,
                }
            }
            KIND_PREDICT => {
                let id = r.u64()?;
                let d = r.u64()?;
                let len = r.u64()? as usize;
                ensure!(d >= 1, "PREDICT: zero-dimensional inputs");
                ensure!(
                    len >= 1 && len as u64 % d == 0,
                    "PREDICT: {len} values is not a whole number of {d}-dim rows"
                );
                Frame::Predict { id, d, rows: r.f64_vec(len)? }
            }
            KIND_PREDICTION => {
                let id = r.u64()?;
                let version = r.u64()?;
                let len = r.u64()? as usize;
                let mean = r.f64_vec(len)?;
                let var = r.f64_vec(len)?;
                Frame::Prediction { id, version, mean, var }
            }
            KIND_REJECT => {
                let id = r.u64()?;
                let code = r.u16()?;
                let len = r.u32()? as usize;
                let message = String::from_utf8(r.take(len)?.to_vec())
                    .context("REJECT frame: message is not UTF-8")?;
                Frame::Reject { id, code, message }
            }
            KIND_ROUTE_STATUS => {
                let fleet_version = r.u64()?;
                let n = r.u16()? as usize;
                ensure!(
                    (1..=MAX_ROUTE_REPLICAS).contains(&n),
                    "ROUTE-STATUS: implausible replica count {n} \
                     (max {MAX_ROUTE_REPLICAS})"
                );
                let mut replicas = Vec::with_capacity(n);
                for _ in 0..n {
                    let version = r.u64()?;
                    let inflight = r.u32()?;
                    let flags = r.take(1)?[0];
                    ensure!(
                        flags & !ROUTE_RETIRED == 0,
                        "ROUTE-STATUS: unknown flag bits {flags:#04x}"
                    );
                    replicas.push(ReplicaStatus { version, inflight, flags });
                }
                Frame::RouteStatus { fleet_version, replicas }
            }
            KIND_ERROR => {
                let code = r.u16()?;
                let len = r.u32()? as usize;
                let message = String::from_utf8(r.take(len)?.to_vec())
                    .context("ERROR frame: message is not UTF-8")?;
                Frame::Error { code, message }
            }
            k => bail!("unknown frame kind {k:#04x}"),
        };
        ensure!(
            r.i == body.len() - 1,
            "frame kind {kind:#04x}: {} trailing payload bytes",
            body.len() - 1 - r.i
        );
        Ok(frame)
    }

    /// The worker→server message this frame carries, if it is one.
    pub fn into_to_server(self) -> Option<ToServer> {
        match self {
            Frame::Push(p) => Some(ToServer::Push(p)),
            Frame::WorkerExit { worker } => {
                Some(ToServer::WorkerExit { worker: worker as usize })
            }
            _ => None,
        }
    }

    /// The server→worker message this frame carries, if it is one.
    pub fn into_from_server(self) -> Option<FromServer> {
        match self {
            Frame::Publish { version, meta, theta } => {
                Some(FromServer::Publish { version, meta, theta })
            }
            Frame::Shutdown => Some(FromServer::Shutdown),
            _ => None,
        }
    }
}

impl From<FromServer> for Frame {
    fn from(m: FromServer) -> Frame {
        match m {
            FromServer::Publish { version, meta, theta } => {
                Frame::Publish { version, meta, theta }
            }
            FromServer::Shutdown => Frame::Shutdown,
        }
    }
}

impl From<ToServer> for Frame {
    fn from(m: ToServer) -> Frame {
        match m {
            ToServer::Push(p) => Frame::Push(p),
            ToServer::WorkerExit { worker } => {
                Frame::WorkerExit { worker: worker as u64 }
            }
        }
    }
}

/// Encode a PUBLISH frame straight from a θ slice — the server's
/// publish fan-out path, which would otherwise clone θ into a [`Frame`]
/// once per connection per version just to serialize it.
pub fn publish_frame_bytes(version: u64, meta: PublishMeta, theta: &[f64]) -> Vec<u8> {
    let mut body = Vec::with_capacity(1 + 32 + theta.len() * 8);
    body.push(KIND_PUBLISH);
    body.extend_from_slice(&version.to_le_bytes());
    body.extend_from_slice(&meta.live.to_le_bytes());
    body.extend_from_slice(&meta.staleness.to_le_bytes());
    body.extend_from_slice(&(theta.len() as u64).to_le_bytes());
    for v in theta {
        body.extend_from_slice(&v.to_le_bytes());
    }
    seal_frame(body)
}

/// Encode a PUBLISH2 frame straight from a θ-slice — the revision-2
/// twin of [`publish_frame_bytes`], used by the per-slice publish
/// fan-out (and its frame cache) so θ is encoded once per version, not
/// once per connection.
pub fn publish2_frame_bytes(
    version: u64,
    meta: PublishMeta,
    slice_id: u64,
    start: u64,
    theta: &[f64],
) -> Vec<u8> {
    let mut body = Vec::with_capacity(1 + 48 + theta.len() * 8);
    body.push(KIND_PUBLISH2);
    body.extend_from_slice(&version.to_le_bytes());
    body.extend_from_slice(&meta.live.to_le_bytes());
    body.extend_from_slice(&meta.staleness.to_le_bytes());
    body.extend_from_slice(&slice_id.to_le_bytes());
    body.extend_from_slice(&start.to_le_bytes());
    body.extend_from_slice(&(theta.len() as u64).to_le_bytes());
    for v in theta {
        body.extend_from_slice(&v.to_le_bytes());
    }
    seal_frame(body)
}

/// Encode a POSTERIOR-SYNC frame straight from a θ-slice — the
/// serving-path twin of [`publish2_frame_bytes`], used by the
/// subscriber fan-out so θ is encoded once per version, not once per
/// subscriber.  `theta` may be empty (a header-only sync: the predict
/// handshake's `(m, d, version)` ack).
#[allow(clippy::too_many_arguments)]
pub fn posterior_sync_frame_bytes(
    m: u64,
    d: u64,
    slice_id: u64,
    n_slices: u64,
    start: u64,
    end: u64,
    version: u64,
    meta: PublishMeta,
    theta: &[f64],
) -> Vec<u8> {
    let mut body = Vec::with_capacity(1 + 80 + theta.len() * 8);
    body.push(KIND_POSTERIOR_SYNC);
    body.extend_from_slice(&m.to_le_bytes());
    body.extend_from_slice(&d.to_le_bytes());
    body.extend_from_slice(&slice_id.to_le_bytes());
    body.extend_from_slice(&n_slices.to_le_bytes());
    body.extend_from_slice(&start.to_le_bytes());
    body.extend_from_slice(&end.to_le_bytes());
    body.extend_from_slice(&version.to_le_bytes());
    body.extend_from_slice(&meta.live.to_le_bytes());
    body.extend_from_slice(&meta.staleness.to_le_bytes());
    body.extend_from_slice(&(theta.len() as u64).to_le_bytes());
    for v in theta {
        body.extend_from_slice(&v.to_le_bytes());
    }
    seal_frame(body)
}

/// Checksum a body and prepend the length prefix — the single sealing
/// point for every encoder.  Panics on a frame over [`MAX_FRAME_LEN`]:
/// the receiver would reject it anyway, and a silent `as u32` wrap
/// would misframe the stream and blame the network for a local sizing
/// bug.
fn seal_frame(body: Vec<u8>) -> Vec<u8> {
    let sum = fnv1a64(FNV1A64_INIT, &body);
    let total = body.len() + 8;
    assert!(
        total <= MAX_FRAME_LEN,
        "frame of {total} bytes exceeds MAX_FRAME_LEN ({MAX_FRAME_LEN}) — \
         θ too large for one ADVGPNT1 frame"
    );
    let mut out = Vec::with_capacity(4 + total);
    out.extend_from_slice(&(total as u32).to_le_bytes());
    out.extend_from_slice(&body);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Write one frame (a single `write_all` of the encoded bytes).
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> std::io::Result<()> {
    w.write_all(&frame.encode())
}

/// Read one frame, reusing `scratch` across calls (no steady-state
/// allocation once the buffer has grown to the largest frame seen).
/// EOF anywhere — including cleanly between frames — is an error; use
/// [`read_frame_opt`] where a peer hanging up is an expected event.
pub fn read_frame(r: &mut impl Read, scratch: &mut Vec<u8>) -> Result<Frame> {
    read_frame_opt(r, scratch)?.context("connection closed mid-stream")
}

/// [`read_frame`] with a caller-chosen length ceiling.  Handshake
/// reads pass [`MAX_HANDSHAKE_FRAME_LEN`] so an unauthenticated peer's
/// length prefix can never commit the receiver to a gigabyte
/// allocation before HELLO/WELCOME validation has run.
pub fn read_frame_capped(
    r: &mut impl Read,
    scratch: &mut Vec<u8>,
    max_len: usize,
) -> Result<Frame> {
    read_frame_opt_capped(r, scratch, max_len)?.context("connection closed mid-stream")
}

/// Like [`read_frame`], but a clean EOF *at a frame boundary* returns
/// `Ok(None)`; EOF inside a frame is still an error (torn frame).
pub fn read_frame_opt(r: &mut impl Read, scratch: &mut Vec<u8>) -> Result<Option<Frame>> {
    read_frame_opt_capped(r, scratch, MAX_FRAME_LEN)
}

/// What [`read_frame_event`] observed on the stream.
#[derive(Debug)]
pub enum ReadEvent {
    /// One complete, validated frame.
    Frame(Frame),
    /// Clean hang-up at a frame boundary.
    Eof,
    /// A read timeout fired **before any byte of a frame arrived** —
    /// the peer is idle, not torn.  Only possible when the caller has
    /// armed a socket read timeout; the heartbeat loop in
    /// [`super::net`] answers this with a PING.  A timeout *inside* a
    /// frame is still an error (a peer trickling a torn frame must not
    /// look idle forever).
    IdleTimeout,
}

/// The core reader: length prefix (bounded by `max_len`), body,
/// checksum, decode — with idle-timeout detection for heartbeat loops.
pub fn read_frame_event(
    r: &mut impl Read,
    scratch: &mut Vec<u8>,
    max_len: usize,
) -> Result<ReadEvent> {
    let max_len = max_len.min(MAX_FRAME_LEN);
    let mut len4 = [0u8; 4];
    let mut got = 0usize;
    while got == 0 {
        match r.read(&mut len4) {
            Ok(0) => return Ok(ReadEvent::Eof), // peer hung up between frames
            Ok(k) => got = k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Zero bytes consumed: the stream is still at a frame
                // boundary, so this is pure idleness.
                return Ok(ReadEvent::IdleTimeout);
            }
            Err(e) => return Err(e).context("read frame length"),
        }
    }
    r.read_exact(&mut len4[got..]).context("read frame length (torn)")?;
    let len = u32::from_le_bytes(len4) as usize;
    ensure!(
        (9..=max_len).contains(&len),
        "frame length {len} outside [9, {max_len}] — corrupt or hostile stream"
    );
    scratch.resize(len, 0);
    r.read_exact(scratch).context("read frame body (torn)")?;
    Frame::decode(scratch).map(ReadEvent::Frame)
}

/// [`read_frame_event`] for callers without a heartbeat: an idle
/// timeout is an error here (these callers armed a timeout as a hard
/// bound, e.g. the handshake reads).
pub fn read_frame_opt_capped(
    r: &mut impl Read,
    scratch: &mut Vec<u8>,
    max_len: usize,
) -> Result<Option<Frame>> {
    match read_frame_event(r, scratch, max_len)? {
        ReadEvent::Frame(f) => Ok(Some(f)),
        ReadEvent::Eof => Ok(None),
        ReadEvent::IdleTimeout => bail!("timed out waiting for a frame"),
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, len: usize) -> Result<&'a [u8]> {
        ensure!(
            self.i + len <= self.b.len(),
            "frame payload truncated at byte {}",
            self.i
        );
        let s = &self.b[self.i..self.i + len];
        self.i += len;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64_vec(&mut self, len: usize) -> Result<Vec<f64>> {
        let raw = self.take(len.checked_mul(8).context("frame: length overflow")?)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_frames() -> Vec<Frame> {
        vec![
            Frame::Hello { proto: PROTO_VERSION, worker: WORKER_ID_ANY },
            Frame::Hello { proto: 1, worker: 3 },
            Frame::Welcome { proto: 1, worker: 3, m: 100, d: 8, tau: 32 },
            Frame::Publish {
                version: 41,
                meta: PublishMeta { live: 4, staleness: 2 },
                theta: vec![1.5, -2.25, 0.0, f64::MIN_POSITIVE],
            },
            Frame::Push(Push {
                worker: 2,
                version: 40,
                value: -1234.5,
                grad: vec![0.125; 7],
                compute_secs: 0.03125,
            }),
            Frame::WorkerExit { worker: 2 },
            Frame::Shutdown,
            Frame::Error { code: ERR_ID_IN_USE, message: "worker id 3 in use".into() },
            Frame::Ping,
            Frame::Pong,
            Frame::Welcome2 {
                proto: PROTO_NT2,
                worker: 1,
                m: 100,
                d: 8,
                tau: 32,
                slice_id: 1,
                n_slices: 3,
                start: 40,
                end: 80,
                topology: vec![(0, 40), (40, 80), (80, 120)],
            },
            Frame::Publish2 {
                version: 41,
                meta: PublishMeta { live: 4, staleness: 2 },
                slice_id: 2,
                start: 80,
                theta: vec![0.5, -0.25, 3.0],
            },
            Frame::Push2 {
                slice_id: 0,
                start: 0,
                push: Push {
                    worker: 2,
                    version: 40,
                    value: -9.5,
                    grad: vec![0.25; 5],
                    compute_secs: 0.0625,
                },
            },
            Frame::Subscribe { proto: PROTO_NT2, scope: SUBSCRIBE_POSTERIOR },
            Frame::Subscribe { proto: PROTO_NT2, scope: SUBSCRIBE_PREDICT },
            Frame::PosteriorSync {
                m: 100,
                d: 8,
                slice_id: 1,
                n_slices: 2,
                start: 40,
                end: 80,
                version: 17,
                meta: PublishMeta { live: 4, staleness: 1 },
                theta: vec![0.5; 40],
            },
            Frame::PosteriorSync {
                // Header-only sync: the predict handshake ack.
                m: 100,
                d: 8,
                slice_id: 0,
                n_slices: 1,
                start: 0,
                end: 120,
                version: 17,
                meta: PublishMeta { live: 4, staleness: 1 },
                theta: vec![],
            },
            Frame::Predict { id: 9, d: 3, rows: vec![1.0, -2.0, 0.5, 4.0, 0.0, -0.125] },
            Frame::Prediction {
                id: 9,
                version: 17,
                mean: vec![0.25, -1.5],
                var: vec![0.0625, 0.125],
            },
            Frame::Reject { id: 10, code: REJ_STALE, message: "stale".into() },
            Frame::RouteStatus {
                fleet_version: 17,
                replicas: vec![ReplicaStatus { version: 17, inflight: 5, flags: 0 }],
            },
            Frame::RouteStatus {
                fleet_version: 17,
                replicas: vec![
                    ReplicaStatus { version: 17, inflight: 0, flags: 0 },
                    ReplicaStatus { version: 12, inflight: 0, flags: ROUTE_RETIRED },
                ],
            },
        ]
    }

    #[test]
    fn roundtrip_every_kind() {
        for f in all_frames() {
            let bytes = f.encode();
            let len = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
            assert_eq!(len, bytes.len() - 4, "{f:?}: length prefix");
            let back = Frame::decode(&bytes[4..]).unwrap();
            assert_eq!(back, f);
        }
    }

    #[test]
    fn f64_payloads_roundtrip_bitwise() {
        // PartialEq can't see the difference between 0.0 and -0.0 (and
        // would reject NaN): check the raw bit patterns explicitly.
        let theta = vec![0.0, -0.0, f64::NAN, f64::INFINITY, -1e-308];
        let f = Frame::Publish { version: 1, meta: PublishMeta::default(), theta: theta.clone() };
        let bytes = f.encode();
        match Frame::decode(&bytes[4..]).unwrap() {
            Frame::Publish { theta: back, .. } => {
                for (a, b) in theta.iter().zip(&back) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("wrong kind back: {other:?}"),
        }
    }

    #[test]
    fn corruption_is_rejected() {
        for f in all_frames() {
            let clean = f.encode();
            // Flip every body/checksum byte one at a time: decode must
            // never silently accept (a kind-byte flip may decode as a
            // *checksum* error — either way it's an Err).
            for i in 4..clean.len() {
                let mut bytes = clean.clone();
                bytes[i] ^= 0x01;
                assert!(
                    Frame::decode(&bytes[4..]).is_err(),
                    "{f:?}: accepted a flipped byte at {i}"
                );
            }
            // Truncation at every boundary.
            for cut in 4..clean.len() {
                assert!(
                    Frame::decode(&clean[4..cut]).is_err(),
                    "{f:?}: accepted truncation at {cut}"
                );
            }
            // Trailing garbage (appended before the checksum slot moves:
            // simplest is appending a byte — checksum now misaligned).
            let mut bytes = clean.clone();
            bytes.push(0xAB);
            assert!(Frame::decode(&bytes[4..]).is_err(), "{f:?}: trailing byte");
        }
    }

    #[test]
    fn stream_read_write_roundtrip_and_eof_semantics() {
        let mut buf: Vec<u8> = Vec::new();
        for f in all_frames() {
            write_frame(&mut buf, &f).unwrap();
        }
        let mut cur = std::io::Cursor::new(buf.clone());
        let mut scratch = Vec::new();
        for f in all_frames() {
            assert_eq!(read_frame(&mut cur, &mut scratch).unwrap(), f);
        }
        // Clean EOF at a frame boundary: None, not an error.
        assert!(read_frame_opt(&mut cur, &mut scratch).unwrap().is_none());
        // ... but read_frame treats it as an error.
        assert!(read_frame(&mut cur, &mut scratch).is_err());
        // Torn frame: cut the stream mid-frame.
        let mut cur = std::io::Cursor::new(buf[..buf.len() - 3].to_vec());
        loop {
            match read_frame_opt(&mut cur, &mut scratch) {
                Ok(Some(_)) => continue,
                Ok(None) => panic!("torn frame read as clean EOF"),
                Err(_) => break,
            }
        }
    }

    #[test]
    fn length_prefix_and_handshake_cap_are_enforced() {
        // len < 9.
        let mut bytes = vec![];
        bytes.extend_from_slice(&5u32.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 5]);
        let mut cur = std::io::Cursor::new(bytes);
        assert!(read_frame(&mut cur, &mut Vec::new()).is_err());
        // len > MAX_FRAME_LEN.
        let mut bytes = vec![];
        bytes.extend_from_slice(&(MAX_FRAME_LEN as u32 + 1).to_le_bytes());
        let mut cur = std::io::Cursor::new(bytes);
        assert!(read_frame(&mut cur, &mut Vec::new()).is_err());
        // Handshake cap: a legal-for-the-stream length is still
        // rejected before the body is read (or allocated) when it
        // exceeds the handshake ceiling.
        let big = Frame::Publish {
            version: 0,
            meta: PublishMeta::default(),
            theta: vec![0.0; MAX_HANDSHAKE_FRAME_LEN / 8],
        }
        .encode();
        let mut scratch = Vec::new();
        let mut cur = std::io::Cursor::new(big.clone());
        assert!(
            read_frame_capped(&mut cur, &mut scratch, MAX_HANDSHAKE_FRAME_LEN).is_err(),
            "oversized frame accepted during handshake"
        );
        assert!(scratch.is_empty(), "handshake cap allocated the body anyway");
        // The same bytes are fine through the normal reader.
        let mut cur = std::io::Cursor::new(big);
        assert!(read_frame(&mut cur, &mut scratch).is_ok());
        // HELLO itself fits the cap with room to spare.
        let hello = Frame::Hello { proto: PROTO_VERSION, worker: WORKER_ID_ANY }.encode();
        let mut cur = std::io::Cursor::new(hello);
        assert!(read_frame_capped(&mut cur, &mut scratch, MAX_HANDSHAKE_FRAME_LEN).is_ok());
    }

    /// Pins the worked example in docs/PROTOCOL.md: if this breaks,
    /// the codec and its normative spec have drifted apart.
    #[test]
    fn shutdown_frame_matches_the_protocol_doc() {
        assert_eq!(
            Frame::Shutdown.encode(),
            vec![0x09, 0, 0, 0, 0x06, 0x79, 0xb4, 0x01, 0x86, 0x4c, 0xbb, 0x63, 0xaf]
        );
    }

    /// Pins the ADVGPNT2 worked example (PING) the same way.
    #[test]
    fn ping_frame_matches_the_protocol_doc() {
        assert_eq!(
            Frame::Ping.encode(),
            vec![0x09, 0, 0, 0, 0x08, 0x77, 0xc5, 0x01, 0x86, 0x4c, 0xc5, 0x63, 0xaf]
        );
    }

    #[test]
    fn publish_frame_bytes_matches_frame_encode() {
        let meta = PublishMeta { live: 3, staleness: 1 };
        let theta = vec![1.0, 2.5, -3.75];
        let via_frame =
            Frame::Publish { version: 9, meta, theta: theta.clone() }.encode();
        assert_eq!(publish_frame_bytes(9, meta, &theta), via_frame);
    }

    #[test]
    fn publish2_frame_bytes_matches_frame_encode() {
        let meta = PublishMeta { live: 2, staleness: 0 };
        let theta = vec![-1.5, 0.125];
        let via_frame = Frame::Publish2 {
            version: 7,
            meta,
            slice_id: 1,
            start: 10,
            theta: theta.clone(),
        }
        .encode();
        assert_eq!(publish2_frame_bytes(7, meta, 1, 10, &theta), via_frame);
    }

    /// Pins the ADVGPSV1 worked example (SUBSCRIBE, posterior scope) in
    /// docs/PROTOCOL.md the same way SHUTDOWN and PING pin theirs.
    #[test]
    fn subscribe_frame_matches_the_protocol_doc() {
        assert_eq!(
            Frame::Subscribe { proto: PROTO_NT2, scope: SUBSCRIBE_POSTERIOR }.encode(),
            vec![
                0x16, 0x00, 0x00, 0x00, // len = 22
                0x0d, // kind SUBSCRIBE
                0x41, 0x44, 0x56, 0x47, 0x50, 0x4e, 0x54, 0x31, // "ADVGPNT1"
                0x02, 0x00, 0x00, 0x00, // proto = 2
                0x00, // scope = posterior
                0xe7, 0x10, 0xda, 0x89, 0x7b, 0x08, 0xaa, 0xa3, // fnv1a64(body)
            ]
        );
    }

    /// Pins the ADVGPSV1 REJECT worked example in docs/PROTOCOL.md.
    #[test]
    fn reject_frame_matches_the_protocol_doc() {
        assert_eq!(
            Frame::Reject { id: 7, code: REJ_STALE, message: "stale".into() }.encode(),
            vec![
                0x1c, 0x00, 0x00, 0x00, // len = 28
                0x11, // kind REJECT
                0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // id = 7
                0x02, 0x00, // code = REJ_STALE
                0x05, 0x00, 0x00, 0x00, // message length
                0x73, 0x74, 0x61, 0x6c, 0x65, // "stale"
                0xf1, 0x7f, 0x58, 0xbc, 0x19, 0xbb, 0xf5, 0x43, // fnv1a64(body)
            ]
        );
    }

    /// Pins the ADVGPRT1 ROUTE-STATUS worked example in
    /// docs/PROTOCOL.md: fleet at v7, replica 0 live with 3 rows in
    /// flight, replica 1 retired at v6.
    #[test]
    fn route_status_frame_matches_the_protocol_doc() {
        let frame = Frame::RouteStatus {
            fleet_version: 7,
            replicas: vec![
                ReplicaStatus { version: 7, inflight: 3, flags: 0 },
                ReplicaStatus { version: 6, inflight: 0, flags: ROUTE_RETIRED },
            ],
        };
        assert_eq!(
            frame.encode(),
            vec![
                0x2d, 0x00, 0x00, 0x00, // len = 45
                0x12, // kind ROUTE-STATUS
                0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // fleet_version = 7
                0x02, 0x00, // n = 2
                0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // r0 version = 7
                0x03, 0x00, 0x00, 0x00, // r0 inflight = 3
                0x00, // r0 flags = live
                0x06, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, // r1 version = 6
                0x00, 0x00, 0x00, 0x00, // r1 inflight = 0
                0x01, // r1 flags = retired
                0x61, 0x9d, 0x99, 0xfb, 0x29, 0x1e, 0x9f, 0x93, // fnv1a64(body)
            ]
        );
    }

    /// ROUTE-STATUS semantic validation: an empty replica list, an
    /// implausible count, and unknown flag bits are all rejected at
    /// decode (craft the bodies by hand — encode asserts the bounds).
    #[test]
    fn route_status_semantic_validation() {
        let status = |n: u16, flags: u8| {
            let mut body = vec![KIND_ROUTE_STATUS];
            body.extend_from_slice(&7u64.to_le_bytes());
            body.extend_from_slice(&n.to_le_bytes());
            for _ in 0..n {
                body.extend_from_slice(&7u64.to_le_bytes());
                body.extend_from_slice(&0u32.to_le_bytes());
                body.push(flags);
            }
            seal_frame(body)
        };
        assert!(Frame::decode(&status(0, 0)[4..]).is_err(), "empty replica list");
        assert!(Frame::decode(&status(1, 0x02)[4..]).is_err(), "unknown flag bit");
        assert!(Frame::decode(&status(1, 0x81)[4..]).is_err(), "reserved high bit");
        assert!(Frame::decode(&status(1, ROUTE_RETIRED)[4..]).is_ok());
        // A count over the cap is rejected before its rows are read:
        // claim MAX+1 rows but ship only one — the count check must
        // fire, not the truncation error.
        let mut body = vec![KIND_ROUTE_STATUS];
        body.extend_from_slice(&7u64.to_le_bytes());
        body.extend_from_slice(&(MAX_ROUTE_REPLICAS as u16 + 1).to_le_bytes());
        body.extend_from_slice(&7u64.to_le_bytes());
        body.extend_from_slice(&0u32.to_le_bytes());
        body.push(0);
        let bytes = seal_frame(body);
        let err = Frame::decode(&bytes[4..]).unwrap_err();
        assert!(
            format!("{err:#}").contains("implausible replica count"),
            "{err:#}"
        );
    }

    /// The normative retry-on-REJECT table: state-reflecting verdicts
    /// retry on a sibling, request/fleet-reflecting ones surface.
    #[test]
    fn reject_retryability_follows_the_protocol_doc() {
        assert!(reject_is_retryable(REJ_OVERLOAD));
        assert!(reject_is_retryable(REJ_STALE));
        assert!(!reject_is_retryable(REJ_NOT_READY));
        assert!(!reject_is_retryable(REJ_BAD_DIM));
        assert!(!reject_is_retryable(REJ_BAD_SCOPE));
        assert!(!reject_is_retryable(0));
    }

    #[test]
    fn posterior_sync_frame_bytes_matches_frame_encode() {
        let meta = PublishMeta { live: 2, staleness: 3 };
        let theta = vec![1.0, -0.5, 0.25];
        let via_frame = Frame::PosteriorSync {
            m: 10,
            d: 4,
            slice_id: 1,
            n_slices: 2,
            start: 7,
            end: 10,
            version: 5,
            meta,
            theta: theta.clone(),
        }
        .encode();
        assert_eq!(
            posterior_sync_frame_bytes(10, 4, 1, 2, 7, 10, 5, meta, &theta),
            via_frame
        );
    }

    /// ADVGPSV1 semantic validation: SUBSCRIBE scope bytes, the
    /// POSTERIOR-SYNC slice/θ-length rules (header-only or the whole
    /// slice, nothing in between), and PREDICT's whole-rows rule.
    #[test]
    fn serving_frame_semantic_validation() {
        // SUBSCRIBE: an unknown scope is rejected (craft the body by
        // hand — encode can only produce legal scopes).
        let mut body = vec![KIND_SUBSCRIBE];
        body.extend_from_slice(&WIRE_MAGIC);
        body.extend_from_slice(&PROTO_NT2.to_le_bytes());
        body.push(2); // not a scope
        let bytes = seal_frame(body);
        assert!(Frame::decode(&bytes[4..]).is_err());
        // POSTERIOR-SYNC: a partial slice is rejected; empty (header
        // only) and exactly end − start both pass.
        let sync = |theta: Vec<f64>| Frame::PosteriorSync {
            m: 4,
            d: 2,
            slice_id: 0,
            n_slices: 1,
            start: 3,
            end: 6,
            version: 1,
            meta: PublishMeta::default(),
            theta,
        };
        assert!(Frame::decode(&sync(vec![]).encode()[4..]).is_ok());
        assert!(Frame::decode(&sync(vec![0.0; 3]).encode()[4..]).is_ok());
        assert!(Frame::decode(&sync(vec![0.0; 2]).encode()[4..]).is_err());
        // POSTERIOR-SYNC: slice coordinates obey the WELCOME2 rules.
        let bad = Frame::PosteriorSync {
            m: 4,
            d: 2,
            slice_id: 1,
            n_slices: 1, // slice_id ≥ n_slices
            start: 0,
            end: 3,
            version: 1,
            meta: PublishMeta::default(),
            theta: vec![],
        };
        assert!(Frame::decode(&bad.encode()[4..]).is_err());
        // PREDICT: a ragged batch (7 values, d = 3) is rejected, as is
        // an empty one.
        let ragged = Frame::Predict { id: 1, d: 3, rows: vec![0.0; 7] };
        assert!(Frame::decode(&ragged.encode()[4..]).is_err());
        let empty = Frame::Predict { id: 1, d: 3, rows: vec![] };
        assert!(Frame::decode(&empty.encode()[4..]).is_err());
        let whole = Frame::Predict { id: 1, d: 3, rows: vec![0.0; 6] };
        assert!(Frame::decode(&whole.encode()[4..]).is_ok());
    }

    /// WELCOME2's internal consistency rules: the slice must sit inside
    /// a plausible topology map that agrees with the slice fields.
    #[test]
    fn welcome2_semantic_validation() {
        let good = Frame::Welcome2 {
            proto: PROTO_NT2,
            worker: 0,
            m: 4,
            d: 2,
            tau: 0,
            slice_id: 0,
            n_slices: 2,
            start: 0,
            end: 10,
            topology: vec![(0, 10), (10, 20)],
        };
        let bytes = good.encode();
        assert_eq!(Frame::decode(&bytes[4..]).unwrap(), good);
        // Disagreeing topology entry: rebuild the frame bytes by hand
        // (encode asserts, so corrupt post-encode — but any flip trips
        // the checksum; instead re-encode a frame whose map disagrees
        // via a raw body).  Simplest: decode must reject slice_id ≥
        // n_slices and start ≥ end, which we exercise through crafted
        // frames below.
        let bad_range = Frame::Welcome2 {
            proto: PROTO_NT2,
            worker: 0,
            m: 4,
            d: 2,
            tau: 0,
            slice_id: 0,
            n_slices: 1,
            start: 5,
            end: 5, // empty range
            topology: vec![(5, 5)],
        };
        assert!(Frame::decode(&bad_range.encode()[4..]).is_err());
        let too_many = Frame::Welcome2 {
            proto: PROTO_NT2,
            worker: 0,
            m: 4,
            d: 2,
            tau: 0,
            slice_id: 0,
            n_slices: (MAX_SLICES + 1) as u64,
            start: 0,
            end: 1,
            topology: vec![(0, 1); MAX_SLICES + 1],
        };
        assert!(Frame::decode(&too_many.encode()[4..]).is_err());
    }

    /// Idle timeouts surface as `ReadEvent::IdleTimeout` only at a
    /// frame boundary; mid-frame they are torn-stream errors.
    #[test]
    fn idle_timeout_is_only_clean_at_a_frame_boundary() {
        struct TimeoutReader {
            data: std::io::Cursor<Vec<u8>>,
            then_timeout: bool,
        }
        impl Read for TimeoutReader {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                let n = self.data.read(buf)?;
                if n == 0 && self.then_timeout {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WouldBlock,
                        "simulated read timeout",
                    ));
                }
                Ok(n)
            }
        }
        // Timeout at the boundary after one whole frame: Frame then Idle.
        let mut r = TimeoutReader {
            data: std::io::Cursor::new(Frame::Ping.encode()),
            then_timeout: true,
        };
        let mut scratch = Vec::new();
        assert!(matches!(
            read_frame_event(&mut r, &mut scratch, MAX_FRAME_LEN).unwrap(),
            ReadEvent::Frame(Frame::Ping)
        ));
        assert!(matches!(
            read_frame_event(&mut r, &mut scratch, MAX_FRAME_LEN).unwrap(),
            ReadEvent::IdleTimeout
        ));
        // Timeout mid-frame (after a partial length prefix): an error.
        let mut r = TimeoutReader {
            data: std::io::Cursor::new(Frame::Ping.encode()[..2].to_vec()),
            then_timeout: true,
        };
        assert!(read_frame_event(&mut r, &mut scratch, MAX_FRAME_LEN).is_err());
    }

    #[test]
    fn to_server_conversions() {
        let push = Push {
            worker: 5,
            version: 2,
            value: 0.5,
            grad: vec![1.0],
            compute_secs: 0.01,
        };
        let f: Frame = ToServer::Push(push.clone()).into();
        assert_eq!(f.clone().into_to_server(), Some(ToServer::Push(push)));
        let f: Frame = ToServer::WorkerExit { worker: 5 }.into();
        assert_eq!(f.into_to_server(), Some(ToServer::WorkerExit { worker: 5 }));
        assert_eq!(Frame::Shutdown.into_to_server(), None);
    }

    #[test]
    fn from_server_conversions() {
        let msg = FromServer::Publish {
            version: 4,
            meta: PublishMeta { live: 2, staleness: 0 },
            theta: vec![1.0, 2.0],
        };
        let f: Frame = msg.clone().into();
        assert_eq!(f.into_from_server(), Some(msg));
        let f: Frame = FromServer::Shutdown.into();
        assert_eq!(f.clone().into_from_server(), Some(FromServer::Shutdown));
        assert_eq!(Frame::Shutdown.into_to_server(), None);
        assert_eq!(
            Frame::Hello { proto: 1, worker: 0 }.into_from_server(),
            None
        );
    }

    /// Torn streams at every frame position are *errors* (ISSUE 6),
    /// never hangs or silent EOFs — the fault injector's TruncateMid
    /// lands exactly here.
    #[test]
    fn torn_length_prefix_at_eof_is_an_error() {
        let mut scratch = Vec::new();
        let bytes = Frame::Ping.encode();
        // 2 of the 4 length bytes, then EOF: the stream died mid-frame.
        let mut r = std::io::Cursor::new(bytes[..2].to_vec());
        let err = read_frame_event(&mut r, &mut scratch, MAX_FRAME_LEN).unwrap_err();
        assert!(format!("{err:#}").contains("read frame length (torn)"), "{err:#}");
        // A torn *body* (full prefix, partial payload) is equally fatal.
        let mut r = std::io::Cursor::new(bytes[..6].to_vec());
        let err = read_frame_event(&mut r, &mut scratch, MAX_FRAME_LEN).unwrap_err();
        assert!(format!("{err:#}").contains("read frame body (torn)"), "{err:#}");
    }

    /// A length prefix outside `[9, max_len]` is rejected before any
    /// body allocation — both the hostile-giant end and the
    /// impossible-small end (a frame is at least kind + checksum).
    #[test]
    fn length_prefix_bounds_are_enforced() {
        let mut scratch = Vec::new();
        for len in [0u32, 1, 8, MAX_HANDSHAKE_FRAME_LEN as u32 + 1] {
            let mut bytes = len.to_le_bytes().to_vec();
            bytes.extend_from_slice(&[0u8; 16]);
            let mut r = std::io::Cursor::new(bytes);
            let err = read_frame_event(&mut r, &mut scratch, MAX_HANDSHAKE_FRAME_LEN)
                .unwrap_err();
            assert!(
                format!("{err:#}").contains("corrupt or hostile stream"),
                "len={len}: {err:#}"
            );
        }
        // The floor itself (9 = kind + checksum, zero payload) passes
        // framing and reaches the decoder.
        let ok = Frame::Shutdown.encode();
        assert_eq!(u32::from_le_bytes(ok[..4].try_into().unwrap()), 9);
        let mut r = std::io::Cursor::new(ok);
        assert!(matches!(
            read_frame_event(&mut r, &mut scratch, MAX_HANDSHAKE_FRAME_LEN).unwrap(),
            ReadEvent::Frame(Frame::Shutdown)
        ));
    }

    /// Zero-payload frames are exactly 13 bytes on the wire —
    /// `[len=9][kind][fnv1a64(kind)]` — and round-trip.  Pins the
    /// minimal wire image the chaos suite corrupts byte-by-byte.
    #[test]
    fn zero_payload_frames_pin_the_minimal_wire_image() {
        for (frame, kind) in [
            (Frame::Shutdown, KIND_SHUTDOWN),
            (Frame::Ping, KIND_PING),
            (Frame::Pong, KIND_PONG),
        ] {
            let bytes = frame.encode();
            assert_eq!(bytes.len(), 13, "{frame:?}");
            assert_eq!(&bytes[..4], &9u32.to_le_bytes(), "{frame:?}");
            assert_eq!(bytes[4], kind, "{frame:?}");
            let sum = fnv1a64(FNV1A64_INIT, &[kind]);
            assert_eq!(&bytes[5..], &sum.to_le_bytes(), "{frame:?}");
            assert_eq!(Frame::decode(&bytes[4..]).unwrap(), frame);
        }
    }
}
