//! Worker → server messages.  (Server → worker travels through
//! [`super::Published`], matching ParameterServer's pull semantics.)

/// A local gradient pushed by a worker (Algorithm 1, worker line 4).
pub struct Push {
    pub worker: usize,
    /// The version t_k of θ the gradient was computed at.
    pub version: u64,
    /// Local data-term value G_k(θ^(t_k)).
    pub value: f64,
    /// ∇G_k in the flat θ layout.
    pub grad: Vec<f64>,
    /// Wall-clock seconds the worker spent computing (for metrics).
    pub compute_secs: f64,
}

/// Everything a worker can tell the server.
pub enum ToServer {
    Push(Push),
    /// Worker exited (failure injection / shutdown).
    WorkerExit { worker: usize },
}
