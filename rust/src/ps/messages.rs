//! Worker → server messages.  (Server → worker travels through
//! [`super::Published`], matching ParameterServer's pull semantics.)
//!
//! Membership is implicit in the message stream (ISSUE 3): a worker is
//! **admitted** by its first [`Push`] — there is no separate hello, so
//! a joiner can never stall the bounded-staleness gate before it has a
//! gradient to contribute — and **retired** by [`ToServer::WorkerExit`],
//! which removes both its clock and its latest gradient from the
//! aggregation.

/// A local gradient pushed by a worker (Algorithm 1, worker line 4).
pub struct Push {
    pub worker: usize,
    /// The version t_k of θ the gradient was computed at.
    pub version: u64,
    /// Local data-term value G_k(θ^(t_k)).
    pub value: f64,
    /// ∇G_k in the flat θ layout.
    pub grad: Vec<f64>,
    /// Wall-clock seconds the worker spent computing (for metrics).
    pub compute_secs: f64,
}

/// Everything a worker can tell the server.
pub enum ToServer {
    Push(Push),
    /// Worker departed (permanent leave, store failure, or shutdown).
    /// Mid-run, the server retires the worker's clock so the gate
    /// `min_k t_k ≥ t − τ` ranges over live workers only.
    WorkerExit { worker: usize },
}
