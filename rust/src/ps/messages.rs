//! Transport-agnostic message types: everything a worker and the server
//! can say to each other, independent of how the bytes travel.
//!
//! Worker → server messages are [`ToServer`]; server → worker traffic is
//! [`FromServer`] (in-process it travels through [`super::Published`],
//! matching ParameterServer's pull semantics; over the network both
//! directions are framed by [`super::wire`] — the `ADVGPNT1` codec —
//! and pumped by [`super::net`]).
//!
//! Membership is implicit in the message stream (ISSUE 3): a worker is
//! **admitted** by its first [`Push`] — there is no separate hello at
//! this layer, so a joiner can never stall the bounded-staleness gate
//! before it has a gradient to contribute — and **retired** by
//! [`ToServer::WorkerExit`], which removes both its clock and its
//! latest gradient from the aggregation.  (The wire protocol's
//! HELLO/WELCOME exchange is *connection* setup — id assignment and
//! version negotiation — not gate membership; see `docs/PROTOCOL.md`.)

/// A local gradient pushed by a worker (Algorithm 1, worker line 4).
#[derive(Clone, Debug, PartialEq)]
pub struct Push {
    pub worker: usize,
    /// The version t_k of θ the gradient was computed at.
    pub version: u64,
    /// Local data-term value G_k(θ^(t_k)).
    pub value: f64,
    /// ∇G_k in the flat θ layout.
    pub grad: Vec<f64>,
    /// Wall-clock seconds the worker spent computing (for metrics).
    pub compute_secs: f64,
}

/// Everything a worker can tell the server.
#[derive(Clone, Debug, PartialEq)]
pub enum ToServer {
    Push(Push),
    /// Worker departed (permanent leave, store failure, or shutdown).
    /// Mid-run, the server retires the worker's clock so the gate
    /// `min_k t_k ≥ t − τ` ranges over live workers only.
    WorkerExit { worker: usize },
}

/// `staleness` value in [`PublishMeta`] meaning "not measured" (no
/// update has landed yet, e.g. the initial θ₀ publish or a resume
/// republish before any post-resume push).
pub const STALENESS_UNKNOWN: u64 = u64::MAX;

/// Gate-clock metadata riding along with every published θ snapshot —
/// what a remote worker can know about the staleness regime it is
/// participating in without seeing the server's [`super::DelayGate`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PublishMeta {
    /// Live (non-retired) workers gating updates when this version was
    /// produced.
    pub live: u64,
    /// Observed staleness `t − min_k t_k` at the aggregation that
    /// produced this version ([`STALENESS_UNKNOWN`] when the snapshot
    /// was not produced by an aggregation).
    pub staleness: u64,
}

impl Default for PublishMeta {
    fn default() -> Self {
        Self { live: 0, staleness: STALENESS_UNKNOWN }
    }
}

/// Everything the server can tell a worker — the pull-side dual of
/// [`ToServer`].  In-process this is implicit in [`super::Published`]
/// (`Publish` = a condvar wakeup with a newer version, `Shutdown` = the
/// shutdown flag); on the wire each variant is an explicit frame.
#[derive(Clone, Debug, PartialEq)]
pub enum FromServer {
    /// A new θ version (the publish stream).
    Publish { version: u64, meta: PublishMeta, theta: Vec<f64> },
    /// The run is over; workers should exit.
    Shutdown,
}
