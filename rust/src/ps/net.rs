//! Networked parameter-server transport: the TCP half that turns the
//! in-process `mpsc` + condvar topology into a distributed one,
//! speaking `ADVGPNT1`/`ADVGPNT2` ([`super::wire`] is the codec;
//! `docs/PROTOCOL.md` the normative spec).
//!
//! Design: the server loop ([`super::server::run_server`]), the
//! [`super::DelayGate`], checkpointing, and the worker loop
//! ([`super::worker::run_worker`]) are reused **unchanged** — this
//! module only pumps bytes:
//!
//! * **Server side** — [`NetServer`] + the accept loop: one *reader*
//!   thread per connection decodes PUSH/PUSH2/EXIT frames into the same
//!   `Sender<ToServer>` the in-process workers would use, and one
//!   *publisher* thread per connection follows
//!   [`super::Published::wait_newer_meta`] and writes PUBLISH(2) frames
//!   drawn from a shared per-version [`PublishFrameCache`] — θ is
//!   encoded **once per version**, however many connections fan it out.
//!   Backpressure is per-connection: a slow link blocks only its own
//!   publisher, which then skips straight to the newest version (the
//!   same catch-up semantics an in-process worker gets from the
//!   condvar).  A connection that dies without an EXIT frame has its
//!   clock retired via a synthesized `WorkerExit`; on revision-2
//!   connections a **heartbeat** closes the remaining gap: after
//!   `heartbeat` of read silence the reader sends PING, and a peer that
//!   answers nothing within another such window — wedged-but-connected,
//!   the failure TCP alone cannot observe — is retired exactly like a
//!   disconnect.
//! * **Worker side** — [`NetWorkerHandle`] connects and handshakes
//!   (HELLO → WELCOME/WELCOME2 + initial PUBLISH), then
//!   [`NetWorkerHandle::run`] bridges the socket onto a local
//!   [`super::Published`] and an `mpsc` channel and calls `run_worker`
//!   on them.  Against a **partitioned** server fleet (ISSUE 5),
//!   [`ShardedWorkerHandle`] opens one connection per slice server,
//!   validates that the announced slices tile θ, and assembles the
//!   slice publish streams into one full-θ view (the version-vector
//!   floor) while splitting each gradient into per-slice PUSH2 frames —
//!   `run_worker` never learns the topology existed.
//! * [`remote_worker_loop`] adds WAN resilience: bounded,
//!   jitter-backed-off reconnects ([`ReconnectPolicy`], the budget knob
//!   inside the unified [`RetryPolicy`] timeout bundle) both for the
//!   initial connect and after a mid-run link loss — the worker
//!   reclaims its id, re-adopts the live θ, and is re-admitted by its
//!   first push, so a transient partition costs staleness, not the
//!   worker.  The sharded twin hardens the half-lost fleet session
//!   (ISSUE 6): a [`ShardedWorkerHandle`] that loses a *subset* of its
//!   S links re-establishes only the lost ones, under one shared
//!   outage budget, while held-back gradient fragments queue behind
//!   the repair instead of being lost.
//!
//! * **Serving side** (ADVGPSV1, ISSUE 8) — a connection whose first
//!   frame is SUBSCRIBE instead of HELLO is a *read-only* posterior
//!   subscription: no worker id, no gate clock, no registry entry.  The
//!   server answers with a full POSTERIOR-SYNC of its θ slice and fans
//!   out every later version through
//!   [`super::Published::wait_newer_draining`] — draining, so the final
//!   publish of a run reaches subscribers even when it races SHUTDOWN.
//!   [`crate::serve::replica`] is the client: it assembles the slice
//!   streams exactly like [`ShardedWorkerHandle`] and serves PREDICT
//!   traffic from the rebuilt posterior.
//!
//! Fault semantics (ISSUE 6): corrupt or truncated frames make the
//! server answer `ERROR` and drop that one connection — never panic
//! the slice loop — counted into
//! [`ServerStats::faults`](super::metrics::ServerStats) via
//! [`NetServeOpts::faults`].  The deterministic injection harness that
//! proves this lives in [`super::fault`]; the seeded chaos matrix is
//! `rust/tests/chaos_ps.rs`.
//!
//! Determinism: the transport moves exactly the same messages the
//! in-process channel would, and every slice server aggregates gradient
//! slots in worker-id order — so a τ=0 loopback-TCP run (sharded or
//! not) reproduces the in-process θ trajectory **bitwise** (pinned by
//! `rust/tests/net_transport.rs` and `rust/tests/sharded_ps.rs`).
//!
//! # Example: join a run as a remote worker
//!
//! ```no_run
//! # fn main() -> anyhow::Result<()> {
//! use advgp::data::synth;
//! use advgp::grad::native_factory;
//! use advgp::ps::{NetWorkerHandle, WorkerProfile, WorkerSource};
//!
//! // Connect to `advgp serve-ps` on :7171, claiming worker id 0.  The
//! // WELCOME frame carries the θ layout, so the engine needs no local
//! // configuration beyond the data shard.
//! let shard = synth::friedman(1000, 4, 0.4, 0);
//! let handle = NetWorkerHandle::connect("127.0.0.1:7171", Some(0))?;
//! let factory = native_factory(handle.layout);
//! let mut source = WorkerSource::Memory(shard);
//! handle.run(&mut source, factory, WorkerProfile::default())?;
//! # Ok(()) }
//! ```

use super::messages::ToServer;
use super::sharded::{run_assembler, ShardedPublished, SliceSpec, Topology};
use super::wire::{
    self, Frame, ReadEvent, ERR_BAD_MAGIC, ERR_DIM, ERR_ID_IN_USE, ERR_ID_MISMATCH,
    ERR_MALFORMED, ERR_PROTO, MAX_FRAME_LEN, MAX_HANDSHAKE_FRAME_LEN, MAX_WORKER_ID,
    PROTO_NT1, PROTO_NT2, PROTO_VERSION, WORKER_ID_ANY,
};
use super::worker::{run_worker, WorkerProfile, WorkerSource};
use super::{Published, PublishMeta};
use crate::gp::ThetaLayout;
use crate::grad::EngineFactory;
use crate::util::rng::Pcg64;
use crate::util::{fnv1a64, Stopwatch, FNV1A64_INIT};
use crate::{log_debug, log_info, log_warn};
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashSet;
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A bound listener, handed to
/// [`super::coordinator::train_remote`] (or one per slice to
/// [`super::coordinator::train_remote_sharded`]) to serve a run.
/// Binding is split from serving so callers (tests, the CLI) can bind
/// port 0 and learn the real port before any worker needs it.
pub struct NetServer {
    listener: TcpListener,
}

impl NetServer {
    /// Bind the listener (e.g. `"0.0.0.0:7171"`, or `"127.0.0.1:0"` for
    /// an ephemeral loopback port).
    pub fn bind(addr: &str) -> Result<Self> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("bind ADVGPNT server on {addr}"))?;
        Ok(Self { listener })
    }

    /// The address actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has a local address")
    }
}

/// Everything one slice server's accept loop needs to know about the
/// run it serves: the θ layout and staleness bound (WELCOME fields),
/// the declared worker count (id-assignment floor), the slice this
/// server owns plus the full topology (WELCOME2 fields), and the
/// heartbeat idle window (`None` disables the wedged-peer probe).
pub struct NetServeOpts {
    pub layout: ThetaLayout,
    pub tau: u64,
    pub declared_workers: usize,
    pub slice: SliceSpec,
    pub topology: Topology,
    pub heartbeat: Option<Duration>,
    /// Server-side timeout budgets (handshake read, frame write) — the
    /// reconnect half is worker-side and unused here.
    pub retry: RetryPolicy,
    /// Transport-fault counter: incremented once per connection the
    /// server drops for a protocol violation or a corrupt/truncated
    /// stream (every `ERROR`-answer path).  The coordinator samples it
    /// into [`ServerStats::faults`](super::metrics::ServerStats) via
    /// [`super::server::ServerConfig::transport_faults`].
    pub faults: Arc<AtomicU64>,
}

impl NetServeOpts {
    /// Classic single-server options (full slice).
    pub fn single(
        layout: ThetaLayout,
        tau: u64,
        declared_workers: usize,
        heartbeat: Option<Duration>,
    ) -> Self {
        let dim = layout.len();
        Self {
            layout,
            tau,
            declared_workers,
            slice: SliceSpec::full(dim),
            topology: Topology::partition(dim, 1),
            heartbeat,
            retry: RetryPolicy::default(),
            faults: Arc::new(AtomicU64::new(0)),
        }
    }
}

/// Worker ids currently holding a live connection.  An id frees up on
/// disconnect, so a crashed worker can reconnect as itself and be
/// re-admitted by the gate on its next push.
struct Registry {
    /// Declared gate members (ids `0..declared`).  Reserved for
    /// explicit claims: auto-assignment starts above this range, so a
    /// read-only or elastic `ANY` connection can never squat the id an
    /// expected `advgp worker --shard k` is about to claim (which
    /// would stall the gate on a clock that never pushes).
    declared: u64,
    connected: Mutex<HashSet<u64>>,
}

impl Registry {
    fn new(declared: usize) -> Self {
        Self { declared: declared as u64, connected: Mutex::new(HashSet::new()) }
    }

    fn claim(&self, want: u64) -> std::result::Result<u64, (u16, String)> {
        let mut c = self.connected.lock().unwrap();
        let id = if want == WORKER_ID_ANY {
            let mut i = self.declared;
            while c.contains(&i) {
                i += 1;
            }
            i
        } else if want > MAX_WORKER_ID {
            // The gate clocks and gradient slots are id-indexed dense
            // arrays: an unbounded claim would let one client OOM the
            // shared server.
            return Err((
                ERR_MALFORMED,
                format!("worker id {want} exceeds the maximum {MAX_WORKER_ID}"),
            ));
        } else if c.contains(&want) {
            return Err((ERR_ID_IN_USE, format!("worker id {want} already connected")));
        } else {
            want
        };
        c.insert(id);
        Ok(id)
    }

    fn release(&self, id: u64) {
        self.connected.lock().unwrap().remove(&id);
    }
}

/// Per-version PUBLISH frame cache (ROADMAP "WAN hardening"): the
/// publish fan-out used to re-encode θ once per connection per version;
/// this shares one `(version, Arc<bytes>)` encoded frame across every
/// publisher thread of a slice server — exactly **one encode per
/// version per wire revision**, asserted by
/// `frame_cache_encodes_each_version_once`.
///
/// Two slots, one per protocol revision a single server can be speaking
/// simultaneously (rev-1 PUBLISH and rev-2 PUBLISH2 frame the same θ
/// differently).  The encode happens under the slot lock: publishers
/// asking for the same version serialize briefly instead of encoding
/// redundantly, which is the cheaper side of the trade for frames that
/// are O(dim) to build and written to sockets anyway.
pub struct PublishFrameCache {
    slice: SliceSpec,
    slots: Mutex<[Option<(u64, Arc<Vec<u8>>)>; 2]>,
    encodes: AtomicU64,
}

impl PublishFrameCache {
    pub fn new(slice: SliceSpec) -> Self {
        Self { slice, slots: Mutex::new([None, None]), encodes: AtomicU64::new(0) }
    }

    /// The encoded PUBLISH (rev 1) or PUBLISH2 (rev ≥ 2) frame for
    /// `version`, encoding only if this `(version, revision)` has not
    /// been encoded yet.
    pub fn get(
        &self,
        proto: u32,
        version: u64,
        meta: PublishMeta,
        theta: &[f64],
    ) -> Arc<Vec<u8>> {
        let idx = usize::from(proto != PROTO_NT1);
        let mut slots = self.slots.lock().unwrap();
        if let Some((v, bytes)) = &slots[idx] {
            if *v == version {
                return Arc::clone(bytes);
            }
        }
        self.encodes.fetch_add(1, Ordering::Relaxed);
        let bytes = Arc::new(if proto == PROTO_NT1 {
            wire::publish_frame_bytes(version, meta, theta)
        } else {
            wire::publish2_frame_bytes(
                version,
                meta,
                self.slice.id as u64,
                self.slice.range.start as u64,
                theta,
            )
        });
        slots[idx] = Some((version, Arc::clone(&bytes)));
        bytes
    }

    /// Total encodes performed (tests pin one per version per revision).
    pub fn encodes(&self) -> u64 {
        self.encodes.load(Ordering::Relaxed)
    }
}

/// Accept connections until shutdown, spawning a handler per worker.
/// Runs on a dedicated thread inside the coordinator's scope; per-
/// connection reader/publisher threads are detached (they hold only
/// `Arc`s and channel clones, and unwind on socket close).
///
/// The listener runs non-blocking with a 50 ms shutdown poll, so the
/// loop terminates deterministically even if the post-shutdown
/// [`wake`] connection (which exists only to end the wait early) is
/// dropped by a firewall.  If non-blocking mode is unavailable the
/// loop falls back to blocking accepts and relies on the wake.
pub(crate) fn accept_loop(
    net: NetServer,
    published: Arc<Published>,
    tx: Sender<ToServer>,
    opts: NetServeOpts,
) {
    let opts = Arc::new(opts);
    let registry = Arc::new(Registry::new(opts.declared_workers));
    let cache = Arc::new(PublishFrameCache::new(opts.slice.clone()));
    let nonblocking = net.listener.set_nonblocking(true).is_ok();
    loop {
        let stream = match net.listener.accept() {
            Ok((s, _peer)) => s,
            Err(e) if nonblocking && e.kind() == std::io::ErrorKind::WouldBlock => {
                if published.snapshot().2 {
                    break;
                }
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
            Err(e) => {
                if published.snapshot().2 {
                    break;
                }
                log_warn!("ps::net: accept failed: {e}");
                // EMFILE and friends are persistent: without a backoff
                // this arm busy-spins the accept thread at 100% CPU
                // (the queued connection keeps failing instantly).
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
        };
        if published.snapshot().2 {
            break; // the post-shutdown wake connection (or a stray late joiner)
        }
        // Handlers expect blocking I/O regardless of the listener mode.
        if stream.set_nonblocking(false).is_err() {
            continue;
        }
        let published = Arc::clone(&published);
        let tx = tx.clone();
        let registry = Arc::clone(&registry);
        let opts = Arc::clone(&opts);
        let cache = Arc::clone(&cache);
        std::thread::spawn(move || handle_conn(stream, published, tx, opts, registry, cache));
    }
}

/// Unblock an [`accept_loop`] stuck in `accept()` after shutdown was
/// signalled, by poking one throwaway connection at it.
pub(crate) fn wake(addr: SocketAddr) {
    let mut a = addr;
    if a.ip().is_unspecified() {
        // Can't connect *to* a wildcard bind address; the listener is
        // reachable on loopback.
        a.set_ip(match a {
            SocketAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
            SocketAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
        });
    }
    let _ = TcpStream::connect_timeout(&a, Duration::from_millis(500));
}

fn send_bytes(w: &Mutex<TcpStream>, bytes: &[u8]) -> std::io::Result<()> {
    use std::io::Write;
    // One locked write_all per frame: frames never interleave even with
    // the publisher thread and the reader's error path sharing a socket.
    w.lock().unwrap().write_all(bytes)
}

fn send_error(w: &Mutex<TcpStream>, code: u16, message: &str) {
    let f = Frame::Error { code, message: message.into() };
    let _ = send_bytes(w, &f.encode());
}

/// [`send_error`] on the graceful-degradation path (ISSUE 6): every
/// `ERROR`-answered-and-dropped connection is one transport fault,
/// visible in [`ServerStats::faults`](super::metrics::ServerStats) —
/// the slice loop itself never even notices, let alone panics.
fn send_error_counted(w: &Mutex<TcpStream>, faults: &AtomicU64, code: u16, message: &str) {
    faults.fetch_add(1, Ordering::Relaxed);
    send_error(w, code, message);
}

/// One connection, server side: handshake (with protocol-revision
/// negotiation), then this thread reads worker→server frames — probing
/// idle revision-2 peers with PING — while a spawned twin fans out
/// publishes from the shared frame cache.
fn handle_conn(
    stream: TcpStream,
    published: Arc<Published>,
    tx: Sender<ToServer>,
    opts: Arc<NetServeOpts>,
    registry: Arc<Registry>,
    cache: Arc<PublishFrameCache>,
) {
    let layout = opts.layout;
    let slice = &opts.slice;
    let _ = stream.set_nodelay(true);
    // Bound every write: a peer that stops draining its publish stream
    // would otherwise block the publisher thread inside write_all while
    // it holds the writer mutex — and then an error-path send_error on
    // the reader thread would deadlock behind it, leaving the worker's
    // clock in the gate forever.  With the timeout the wedged write
    // fails, the mutex frees, and teardown proceeds.
    let _ = stream.set_write_timeout(Some(opts.retry.write_timeout));
    // Bound the handshake read too: an idle pre-HELLO connection (port
    // scanner, slowloris) must not pin this thread + FD for the life of
    // the process.  Re-armed after the handshake only as the heartbeat
    // window — a healthy worker may legitimately compute for minutes
    // between pushes, and the PING/PONG probe (not a hard timeout) is
    // what distinguishes "slow" from "wedged".
    let _ = stream.set_read_timeout(Some(opts.retry.handshake_timeout));
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "?".into());
    let writer = match stream.try_clone() {
        Ok(s) => Arc::new(Mutex::new(s)),
        Err(e) => {
            log_warn!("ps::net: {peer}: stream clone failed: {e}");
            return;
        }
    };
    let mut reader = stream;
    let mut scratch = Vec::new();

    // ---- handshake: HELLO → WELCOME(2) + initial PUBLISH(2) ----
    // The peer is untrusted until HELLO validates: the capped read
    // keeps a hostile length prefix from allocating MAX_FRAME_LEN.
    let hello = wire::read_frame_capped(&mut reader, &mut scratch, MAX_HANDSHAKE_FRAME_LEN);
    let (offered, want) = match hello {
        Ok(Frame::Hello { proto, worker }) => (proto, worker),
        Ok(Frame::Subscribe { proto, scope }) => {
            // ADVGPSV1: a read-only posterior subscription — no worker
            // id, no registry entry, no gate clock.  Handled on this
            // thread until the stream ends.
            handle_subscriber(reader, writer, published, opts, proto, scope, &peer, scratch);
            return;
        }
        Ok(f) => {
            let msg = format!("expected HELLO, got kind {:#04x}", f.kind());
            send_error_counted(&writer, &opts.faults, ERR_MALFORMED, &msg);
            return;
        }
        Err(e) => {
            let msg = format!("bad HELLO: {e:#}");
            send_error_counted(&writer, &opts.faults, ERR_BAD_MAGIC, &msg);
            return;
        }
    };
    // Version negotiation: the connection speaks min(offer, ours).  A
    // revision-1 peer can only address a server owning all of θ — its
    // frames have nowhere to put a slice.
    let proto = if offered >= PROTO_NT2 {
        PROTO_NT2
    } else if offered == PROTO_NT1 {
        if slice.covers(layout.len()) {
            PROTO_NT1
        } else {
            send_error_counted(
                &writer,
                &opts.faults,
                ERR_PROTO,
                &format!(
                    "this server owns θ slice {}/{}; ADVGPNT1 (rev 1) cannot \
                     address a partitioned server — speak rev {PROTO_NT2}",
                    slice.id, slice.n_slices
                ),
            );
            return;
        }
    } else {
        send_error_counted(
            &writer,
            &opts.faults,
            ERR_PROTO,
            &format!(
                "server speaks ADVGPNT revisions 1..={PROTO_VERSION}, \
                 client offered {offered}"
            ),
        );
        return;
    };
    let id = match registry.claim(want) {
        Ok(id) => id,
        Err((code, msg)) => {
            send_error_counted(&writer, &opts.faults, code, &msg);
            return;
        }
    };
    let welcome = if proto == PROTO_NT1 {
        Frame::Welcome {
            proto,
            worker: id,
            m: layout.m as u64,
            d: layout.d as u64,
            tau: opts.tau,
        }
    } else {
        Frame::Welcome2 {
            proto,
            worker: id,
            m: layout.m as u64,
            d: layout.d as u64,
            tau: opts.tau,
            slice_id: slice.id as u64,
            n_slices: slice.n_slices as u64,
            start: slice.range.start as u64,
            end: slice.range.end as u64,
            topology: opts.topology.to_wire(),
        }
    };
    let (version, theta, meta, shutdown) = published.snapshot_meta();
    let hand = send_bytes(&writer, &welcome.encode()).and_then(|_| {
        if shutdown {
            send_bytes(&writer, &Frame::Shutdown.encode())
        } else {
            send_bytes(&writer, &cache.get(proto, version, meta, &theta))
        }
    });
    if hand.is_err() || shutdown {
        registry.release(id);
        return;
    }
    // Handshake passed: the read timeout becomes the heartbeat idle
    // window (rev ≥ 2 with heartbeats on) or is cleared (rev 1 — an
    // old peer would not answer PING, so silence must stay legal).
    let heartbeat = (proto >= PROTO_NT2).then_some(opts.heartbeat).flatten();
    let _ = reader.set_read_timeout(heartbeat);
    log_info!("ps::net: worker {id} joined from {peer} (rev {proto}, θ v{version})");

    // ---- publish fan-out: one detached thread per connection ----
    let pub_w = Arc::clone(&writer);
    let pub_published = Arc::clone(&published);
    let pub_cache = Arc::clone(&cache);
    std::thread::spawn(move || {
        let mut seen = version;
        loop {
            match pub_published.wait_newer_meta(seen) {
                Some((v, th, meta)) => {
                    let bytes = pub_cache.get(proto, v, meta, &th);
                    if send_bytes(&pub_w, &bytes).is_err() {
                        // Link gone (or write-timeout on a wedged peer):
                        // kill the socket so the reader side unblocks
                        // promptly and retires the clock, instead of
                        // waiting for the peer's FIN that may never come.
                        let _ = pub_w.lock().unwrap().shutdown(std::net::Shutdown::Both);
                        return;
                    }
                    seen = v;
                }
                None => {
                    let _ = send_bytes(&pub_w, &Frame::Shutdown.encode());
                    return;
                }
            }
        }
    });

    // ---- worker → server pump (this thread) ----
    let mut exited = false;
    // One outstanding PING at a time: a second idle window with no
    // traffic at all (not even PONG) is the wedged-peer verdict.
    let mut pinged = false;
    loop {
        let event = wire::read_frame_event(&mut reader, &mut scratch, MAX_FRAME_LEN);
        let frame = match event {
            Ok(ReadEvent::Frame(f)) => {
                pinged = false; // any traffic proves liveness
                f
            }
            Ok(ReadEvent::IdleTimeout) => {
                if heartbeat.is_none() {
                    // No heartbeat configured but a timeout fired (e.g.
                    // platform quirk): treat as a transient and retry.
                    continue;
                }
                if pinged {
                    log_warn!(
                        "ps::net: worker {id} ({peer}) silent through PING + \
                         grace — wedged; retiring its clock"
                    );
                    break;
                }
                if send_bytes(&writer, &Frame::Ping.encode()).is_err() {
                    break;
                }
                pinged = true;
                continue;
            }
            Ok(ReadEvent::Eof) => break, // clean close
            Err(e) => {
                // Corrupt or truncated stream (ISSUE 6): answer ERROR,
                // count the fault, drop the connection — the slice
                // loop is untouched, graceful degradation by design.
                log_warn!("ps::net: worker {id} ({peer}) stream error: {e:#}");
                let msg = format!("malformed stream: {e:#}");
                send_error_counted(&writer, &opts.faults, ERR_MALFORMED, &msg);
                break;
            }
        };
        // Normalize the two push encodings into one (worker, grad) pair
        // after revision- and slice-validation; everything downstream is
        // revision-agnostic.
        let push = match frame {
            Frame::Push(p) => {
                if proto != PROTO_NT1 {
                    let msg = "rev-2 connections push PUSH2";
                    send_error_counted(&writer, &opts.faults, ERR_MALFORMED, msg);
                    break;
                }
                if p.grad.len() != layout.len() {
                    send_error_counted(
                        &writer,
                        &opts.faults,
                        ERR_DIM,
                        &format!("gradient dim {} but θ dim is {}", p.grad.len(), layout.len()),
                    );
                    break;
                }
                p
            }
            Frame::Push2 { slice_id, start, push } => {
                if proto == PROTO_NT1 {
                    let msg = "PUSH2 on a rev-1 connection";
                    send_error_counted(&writer, &opts.faults, ERR_MALFORMED, msg);
                    break;
                }
                if slice_id != slice.id as u64 || start != slice.range.start as u64 {
                    send_error_counted(
                        &writer,
                        &opts.faults,
                        ERR_DIM,
                        &format!(
                            "PUSH2 for slice {slice_id} @ {start} but this server owns \
                             slice {} @ {}",
                            slice.id, slice.range.start
                        ),
                    );
                    break;
                }
                if push.grad.len() != slice.len() {
                    send_error_counted(
                        &writer,
                        &opts.faults,
                        ERR_DIM,
                        &format!(
                            "gradient fragment dim {} but slice [{}, {}) holds {}",
                            push.grad.len(),
                            slice.range.start,
                            slice.range.end,
                            slice.len()
                        ),
                    );
                    break;
                }
                push
            }
            Frame::Ping => {
                let _ = send_bytes(&writer, &Frame::Pong.encode());
                continue;
            }
            Frame::Pong => continue,
            Frame::WorkerExit { worker } => {
                if worker != id {
                    // Same contract as PUSH (and docs/PROTOCOL.md
                    // code 6): the id field must match the connection.
                    send_error_counted(
                        &writer,
                        &opts.faults,
                        ERR_ID_MISMATCH,
                        &format!("exit for worker {worker} on worker-{id} connection"),
                    );
                    break;
                }
                exited = true;
                let _ = tx.send(ToServer::WorkerExit { worker: id as usize });
                // Keep draining until the client closes its end.
                continue;
            }
            Frame::Error { code, message } => {
                // The peer declared the connection broken: a transport
                // fault on our books too (no ERROR answer — the sender
                // is already closing).
                opts.faults.fetch_add(1, Ordering::Relaxed);
                log_warn!("ps::net: worker {id} sent error {code}: {message}");
                break;
            }
            f => {
                let msg = format!("unexpected kind {:#04x}", f.kind());
                send_error_counted(&writer, &opts.faults, ERR_MALFORMED, &msg);
                break;
            }
        };
        if exited {
            // A push after EXIT would re-admit the retired clock — and
            // with `exited` already true, no WorkerExit would be
            // synthesized on disconnect, leaving a ghost clock that
            // stalls the gate forever.  Protocol-state violation: drop
            // the connection (its clock stays retired).
            send_error_counted(&writer, &opts.faults, ERR_MALFORMED, "PUSH after EXIT");
            break;
        }
        if push.worker as u64 != id {
            send_error_counted(
                &writer,
                &opts.faults,
                ERR_ID_MISMATCH,
                &format!("push for worker {} on worker-{id} connection", push.worker),
            );
            break;
        }
        if tx.send(ToServer::Push(push)).is_err() {
            break; // server loop already returned
        }
    }
    if !exited {
        // Mid-stream disconnect (crash, kill -9, partition) or a wedged
        // peer: retire the clock so the gate ranges over live workers
        // only — the networked twin of the in-process kill-worker path.
        let _ = tx.send(ToServer::WorkerExit { worker: id as usize });
    }
    // Enforce the "ERROR (or EXIT) then close" contract for every exit
    // from the loop: killing the socket makes the publisher thread's
    // next write fail so it exits too — otherwise it would stream
    // publishes to a dead connection (one pinned thread + FD per
    // erroring client) for the rest of the run.
    let _ = reader.shutdown(std::net::Shutdown::Both);
    registry.release(id);
    log_info!(
        "ps::net: worker {id} ({peer}) disconnected{}",
        if exited { "" } else { " without EXIT — clock retired" }
    );
}

/// One read-only subscriber connection, server side (ADVGPSV1): answer
/// the SUBSCRIBE handshake with a full POSTERIOR-SYNC of the current θ
/// slice, then fan out every subsequent version from a publisher thread
/// while this thread polices the (PING/PONG-only) return stream.
///
/// Two deliberate differences from the worker path:
/// * **No registry claim** — a subscriber has no gate clock, so its
///   arrival, departure, or death changes nothing about the run; no
///   `WorkerExit` is ever synthesized for it.
/// * **Draining publish wait** — the fan-out uses
///   [`Published::wait_newer_draining`], so a final publish that races
///   shutdown still reaches every subscriber *before* the SHUTDOWN
///   frame.  Workers deliberately drop that version (a gradient against
///   a finished run is waste); a replica must not (its posterior would
///   end one version behind the trainer, breaking bitwise parity).
fn handle_subscriber(
    mut reader: TcpStream,
    writer: Arc<Mutex<TcpStream>>,
    published: Arc<Published>,
    opts: Arc<NetServeOpts>,
    offered: u32,
    scope: u8,
    peer: &str,
    mut scratch: Vec<u8>,
) {
    let layout = opts.layout;
    let slice = &opts.slice;
    if offered < PROTO_NT2 {
        send_error_counted(
            &writer,
            &opts.faults,
            ERR_PROTO,
            &format!(
                "ADVGPSV1 subscriptions require rev {PROTO_NT2}, client offered {offered}"
            ),
        );
        return;
    }
    if scope != wire::SUBSCRIBE_POSTERIOR {
        // Wrong endpoint, not a broken stream: a typed REJECT lets the
        // client tell "dialed a θ server for predicts" from corruption.
        let f = Frame::Reject {
            id: 0,
            code: wire::REJ_BAD_SCOPE,
            message: "θ-slice servers serve posterior streams; dial a serving \
                      replica for predict sessions"
                .into(),
        };
        let _ = send_bytes(&writer, &f.encode());
        return;
    }
    // Handshake reply: the current slice state, θ included — even if
    // the run already shut down, this is the final posterior the
    // subscriber came for (the SHUTDOWN frame follows right behind).
    let (version, theta, meta, _) = published.snapshot_meta();
    let (m, d) = (layout.m as u64, layout.d as u64);
    let sync = wire::posterior_sync_frame_bytes(
        m,
        d,
        slice.id as u64,
        slice.n_slices as u64,
        slice.range.start as u64,
        slice.range.end as u64,
        version,
        meta,
        &theta,
    );
    if send_bytes(&writer, &sync).is_err() {
        return;
    }
    let heartbeat = opts.heartbeat;
    let _ = reader.set_read_timeout(heartbeat);
    log_info!(
        "ps::net: subscriber joined from {peer} (slice {}, θ v{version})",
        slice.id
    );

    // ---- posterior fan-out: one detached thread per subscription ----
    let pub_w = Arc::clone(&writer);
    let pub_published = Arc::clone(&published);
    let pub_slice = slice.clone();
    std::thread::spawn(move || {
        let mut seen = version;
        loop {
            match pub_published.wait_newer_draining(seen) {
                Some((v, th, meta)) => {
                    let bytes = wire::posterior_sync_frame_bytes(
                        m,
                        d,
                        pub_slice.id as u64,
                        pub_slice.n_slices as u64,
                        pub_slice.range.start as u64,
                        pub_slice.range.end as u64,
                        v,
                        meta,
                        &th,
                    );
                    if send_bytes(&pub_w, &bytes).is_err() {
                        // Link gone (or write-timeout on a wedged
                        // subscriber): kill the socket so the reader
                        // side unblocks promptly.
                        let _ = pub_w.lock().unwrap().shutdown(std::net::Shutdown::Both);
                        return;
                    }
                    seen = v;
                }
                None => {
                    let _ = send_bytes(&pub_w, &Frame::Shutdown.encode());
                    return;
                }
            }
        }
    });

    // ---- subscriber → server pump (this thread): PING/PONG only ----
    // Capped reads: a subscriber's only legal frames are tiny, so its
    // length prefix must never commit this server to a big allocation.
    let mut pinged = false;
    loop {
        match wire::read_frame_event(&mut reader, &mut scratch, MAX_HANDSHAKE_FRAME_LEN) {
            Ok(ReadEvent::Frame(Frame::Ping)) => {
                pinged = false;
                let _ = send_bytes(&writer, &Frame::Pong.encode());
            }
            Ok(ReadEvent::Frame(Frame::Pong)) => pinged = false,
            Ok(ReadEvent::Frame(f)) => {
                let msg =
                    format!("unexpected kind {:#04x} on a posterior subscription", f.kind());
                send_error_counted(&writer, &opts.faults, ERR_MALFORMED, &msg);
                break;
            }
            Ok(ReadEvent::IdleTimeout) => {
                if heartbeat.is_none() {
                    continue;
                }
                if pinged || send_bytes(&writer, &Frame::Ping.encode()).is_err() {
                    log_warn!(
                        "ps::net: subscriber {peer} silent through PING + grace — \
                         dropping the stream"
                    );
                    break;
                }
                pinged = true;
            }
            Ok(ReadEvent::Eof) => break, // clean close
            Err(e) => {
                log_warn!("ps::net: subscriber {peer} stream error: {e:#}");
                let msg = format!("malformed stream: {e:#}");
                send_error_counted(&writer, &opts.faults, ERR_MALFORMED, &msg);
                break;
            }
        }
    }
    // Nothing to retire — a subscriber is read-only.  Kill the socket
    // so the fan-out thread unwinds with it.
    let _ = reader.shutdown(std::net::Shutdown::Both);
    log_info!("ps::net: subscriber {peer} disconnected");
}

/// Worker-side heartbeat window: after this much publish-stream
/// silence on a revision-2 connection the worker PINGs its server, and
/// a server silent through a second window is treated as a dead link
/// ([`RunEnd::ConnectionLost`] → the reconnect loop engages) — the
/// mirror of the server-side probe, per the spec's bidirectional
/// heartbeat.  Matches `TrainConfig::heartbeat_secs`'s default.
/// Revision-1 servers do not speak PING, so rev-1 links keep the
/// pre-heartbeat behavior (block until FIN).
pub const WORKER_HEARTBEAT: Duration = Duration::from_secs(30);

/// Every retry/timeout budget of the transport in one bundle (ISSUE 6),
/// replacing the ad-hoc per-call-site constants: the reconnect backoff,
/// the pre-handshake read bound, the per-frame write bound, and the
/// heartbeat idle window.  `Default` reproduces the historical budgets;
/// the chaos suite (`rust/tests/chaos_ps.rs`) shrinks them so injected
/// outages resolve in milliseconds instead of minutes.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Reconnect budget — per outage for [`remote_worker_loop`], per
    /// *session* for the sharded fleet (one shared pool however many
    /// links an outage takes down), refilled by any successful
    /// re-handshake.
    pub reconnect: ReconnectPolicy,
    /// How long an unvalidated peer may take over the
    /// HELLO → WELCOME → initial-PUBLISH handshake before the
    /// connection is abandoned.
    pub handshake_timeout: Duration,
    /// Per-frame write bound: a peer that stops draining fails the
    /// write instead of pinning a pump thread inside `write_all`.
    pub write_timeout: Duration,
    /// Read-silence window before a PING probe on rev ≥ 2 links (a
    /// peer silent through a second window is wedged).
    pub heartbeat: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            reconnect: ReconnectPolicy::default(),
            handshake_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(30),
            heartbeat: WORKER_HEARTBEAT,
        }
    }
}

impl From<ReconnectPolicy> for RetryPolicy {
    /// Adopt a bare reconnect budget, keeping the default timeouts —
    /// the bridge for callers holding the pre-ISSUE-6 policy struct.
    fn from(reconnect: ReconnectPolicy) -> Self {
        Self { reconnect, ..Self::default() }
    }
}

/// How [`NetWorkerHandle::run`] (and the sharded twin) ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunEnd {
    /// The server announced SHUTDOWN — the run is over.
    Shutdown,
    /// The link died (read/write error or EOF without SHUTDOWN) while
    /// the run may still be live: [`remote_worker_loop`] answers this
    /// with a reconnect.
    ConnectionLost,
    /// The worker departed voluntarily (profile `leave_at`, store
    /// failure) over a healthy connection.
    Left,
}

/// A handshake rejection the server spelled out in an ERROR frame —
/// deliberate, not transient, so [`remote_worker_loop`] does **not**
/// retry it (retrying an `ERR_ID_IN_USE` or `ERR_PROTO` answer would
/// hammer a server that has already said no).
#[derive(Debug)]
pub struct Rejected {
    pub code: u16,
    pub message: String,
}

impl Rejected {
    /// Whether a *sibling* peer might answer differently — REJ_OVERLOAD
    /// and REJ_STALE are verdicts about one replica's state, everything
    /// else about the request or the fleet.  Delegates to the normative
    /// split in [`super::wire::reject_is_retryable`] (ADVGPRT1 routers
    /// retry exactly these on another leg before surfacing).
    pub fn retryable(&self) -> bool {
        super::wire::reject_is_retryable(self.code)
    }
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server rejected the connection (code {}): {}", self.code, self.message)
    }
}

impl std::error::Error for Rejected {}

/// A handshaken worker-side connection: holds the assigned id, the θ
/// layout, staleness bound, and slice this server announced, and the
/// initial θ snapshot.  [`NetWorkerHandle::run`] turns it into a full
/// worker (single-server topologies); [`ShardedWorkerHandle`] composes
/// one of these per slice server.
pub struct NetWorkerHandle {
    stream: TcpStream,
    /// The address this connection dialed — re-dialed by the sharded
    /// link supervisors, named in worker-side ERROR logs.
    pub addr: String,
    /// Worker id this connection runs as (claimed or server-assigned).
    pub worker: usize,
    /// θ layout announced by WELCOME — build the engine from this.
    pub layout: ThetaLayout,
    /// Staleness bound τ announced by WELCOME (informational).
    pub tau: u64,
    /// Negotiated protocol revision for this connection.
    pub proto: u32,
    /// The θ slice the server at the other end owns ([`SliceSpec::full`]
    /// on revision-1 connections and unsharded revision-2 servers).
    pub slice: SliceSpec,
    /// The server's announced topology (single-slice unless sharded).
    pub topology: Topology,
    version: u64,
    meta: PublishMeta,
    theta: Vec<f64>,
}

impl NetWorkerHandle {
    /// Connect and handshake.  `claim = Some(k)` asks to run as worker
    /// k (the id owning shard k); `None` lets the server assign the
    /// lowest free id.  Offers revision [`PROTO_VERSION`] and accepts
    /// whatever ≤ that the server negotiates.
    pub fn connect(addr: &str, claim: Option<usize>) -> Result<Self> {
        Self::connect_with(addr, claim, &RetryPolicy::default())
    }

    /// [`NetWorkerHandle::connect`] with explicit timeout budgets.
    pub fn connect_with(addr: &str, claim: Option<usize>, retry: &RetryPolicy) -> Result<Self> {
        let mut stream = TcpStream::connect(addr)
            .with_context(|| format!("connect to ADVGPNT server {addr}"))?;
        let _ = stream.set_nodelay(true);
        // Bound every write: a wedged server must surface as a push
        // failure (→ ConnectionLost → reconnect), not pin the push pump
        // in write_all forever.
        let _ = stream.set_write_timeout(Some(retry.write_timeout));
        // Bound the handshake so a silent listener can't hang the
        // worker forever; re-armed by `run` as the worker-side
        // heartbeat window (pulls can legitimately wait a long time
        // between publishes — the PING probe, not a hard timeout, is
        // what distinguishes a quiet server from a dead one).
        let _ = stream.set_read_timeout(Some(retry.handshake_timeout));
        let hello = Frame::Hello {
            proto: PROTO_VERSION,
            worker: claim.map_or(WORKER_ID_ANY, |c| c as u64),
        };
        wire::write_frame(&mut stream, &hello).context("send HELLO")?;
        let mut scratch = Vec::new();
        // The server is unvalidated until WELCOME arrives: cap the read
        // so a rogue listener can't make us allocate MAX_FRAME_LEN.
        let welcome =
            wire::read_frame_capped(&mut stream, &mut scratch, MAX_HANDSHAKE_FRAME_LEN)?;
        let check_layout = |m: u64, d: u64| -> Result<ThetaLayout> {
            ensure!(
                (1..=1 << 20).contains(&m) && (1..=1 << 20).contains(&d),
                "WELCOME: implausible layout m={m} d={d}"
            );
            Ok(ThetaLayout::new(m as usize, d as usize))
        };
        let (proto, worker, layout, tau, slice, topology) = match welcome {
            Frame::Welcome { proto, worker, m, d, tau } => {
                ensure!(
                    proto == PROTO_NT1,
                    "rev-1 WELCOME announcing revision {proto} — confused server"
                );
                let layout = check_layout(m, d)?;
                let dim = layout.len();
                (
                    proto,
                    worker as usize,
                    layout,
                    tau,
                    SliceSpec::full(dim),
                    Topology::partition(dim, 1),
                )
            }
            Frame::Welcome2 {
                proto,
                worker,
                m,
                d,
                tau,
                slice_id,
                n_slices,
                start: _,
                end: _,
                topology,
            } => {
                ensure!(
                    (PROTO_NT2..=PROTO_VERSION).contains(&proto),
                    "server negotiated unsupported ADVGPNT revision {proto}"
                );
                let layout = check_layout(m, d)?;
                let topo = Topology::from_wire(layout.len(), &topology)
                    .context("WELCOME2 topology map")?;
                ensure!(
                    (slice_id as usize) < topo.n_slices() && n_slices as usize == topo.n_slices(),
                    "WELCOME2: slice {slice_id}/{n_slices} outside its own topology"
                );
                let slice = topo.slice(slice_id as usize);
                (proto, worker as usize, layout, tau, slice, topo)
            }
            Frame::Error { code, message } => {
                return Err(anyhow::Error::new(Rejected { code, message }))
            }
            f => bail!("expected WELCOME, got frame kind {:#04x}", f.kind()),
        };
        let (version, meta, theta) = match wire::read_frame(&mut stream, &mut scratch)? {
            Frame::Publish { version, meta, theta } => {
                ensure!(proto == PROTO_NT1, "rev-1 PUBLISH on a rev-{proto} connection");
                ensure!(
                    theta.len() == layout.len(),
                    "initial PUBLISH carries dim {} but layout m={} d={} needs {}",
                    theta.len(),
                    layout.m,
                    layout.d,
                    layout.len()
                );
                (version, meta, theta)
            }
            Frame::Publish2 { version, meta, slice_id, start, theta } => {
                ensure!(proto >= PROTO_NT2, "PUBLISH2 on a rev-1 connection");
                ensure!(
                    slice_id == slice.id as u64
                        && start == slice.range.start as u64
                        && theta.len() == slice.len(),
                    "initial PUBLISH2 (slice {slice_id} @ {start}, {} values) does \
                     not match the announced slice {} @ {} ({} values)",
                    theta.len(),
                    slice.id,
                    slice.range.start,
                    slice.len()
                );
                (version, meta, theta)
            }
            Frame::Shutdown => bail!("server is shutting down; nothing to join"),
            Frame::Error { code, message } => {
                return Err(anyhow::Error::new(Rejected { code, message }))
            }
            f => bail!("expected the initial PUBLISH, got frame kind {:#04x}", f.kind()),
        };
        let _ = stream.set_read_timeout(None);
        Ok(Self {
            stream,
            addr: addr.to_string(),
            worker,
            layout,
            tau,
            proto,
            slice,
            topology,
            version,
            meta,
            theta,
        })
    }

    /// θ version the server was at when this connection handshook.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Run the worker loop over this connection until the server shuts
    /// down, the link dies, or the profile makes the worker leave —
    /// the [`RunEnd`] says which.  Internally this bridges the socket
    /// onto a local [`Published`] + `mpsc` pair and calls the ordinary
    /// [`run_worker`] — straggler/crash/leave profiles, windowed
    /// streaming, and [`WorkerSource::Store`] all behave exactly as
    /// they do in-process.  Answers server PINGs with PONG.
    ///
    /// Only valid against a server owning **all** of θ; against a slice
    /// server use [`ShardedWorkerHandle`] (one connection per slice).
    pub fn run(
        self,
        source: &mut WorkerSource,
        factory: EngineFactory,
        profile: WorkerProfile,
    ) -> Result<RunEnd> {
        self.run_with(source, factory, profile, &RetryPolicy::default())
    }

    /// [`NetWorkerHandle::run`] with explicit timeout budgets (the
    /// chaos suite shrinks the heartbeat so injected wedges resolve in
    /// milliseconds).
    pub fn run_with(
        self,
        source: &mut WorkerSource,
        factory: EngineFactory,
        profile: WorkerProfile,
        retry: &RetryPolicy,
    ) -> Result<RunEnd> {
        let Self {
            stream,
            addr,
            worker,
            layout,
            tau: _,
            proto,
            slice,
            topology: _,
            version,
            meta,
            theta,
        } = self;
        let heartbeat = retry.heartbeat;
        ensure!(
            slice.covers(layout.len()),
            "server owns θ slice {}/{} — a single connection cannot train \
             against a partitioned fleet; connect to every slice server \
             (ShardedWorkerHandle / --connect addr0,addr1,…)",
            slice.id,
            slice.n_slices
        );
        ensure!(
            source.d() == layout.d,
            "shard has d={} features but the server's layout has d={}",
            source.d(),
            layout.d
        );
        // Seed a local Published with the server's snapshot so the
        // worker's first pull adopts the live version (a late joiner
        // whose first push claimed version 0 would stall a tight gate).
        let published = Published::new(theta.clone());
        if version > 0 {
            published.publish_meta(version, theta, meta);
        }
        let reader = stream.try_clone().context("clone stream for the publish pump")?;
        let ctrl = stream.try_clone().context("clone stream for teardown")?;
        // Writes are shared between the push pump and the publish
        // pump's PONG replies: one mutex, one write_all per frame.
        let writer = Arc::new(Mutex::new(stream));
        let (tx, rx) = std::sync::mpsc::channel::<ToServer>();
        let dim = layout.len();
        let saw_shutdown = Arc::new(AtomicBool::new(false));
        let conn_err = Arc::new(AtomicBool::new(false));
        let end = std::thread::scope(|s| {
            // Publish pump: server → local Published (+ PONG replies).
            let pub_r = Arc::clone(&published);
            let pong_w = Arc::clone(&writer);
            let sd = Arc::clone(&saw_shutdown);
            let ce = Arc::clone(&conn_err);
            s.spawn(move || {
                let mut r = reader;
                let mut scratch = Vec::new();
                // Worker-side heartbeat (rev ≥ 2 only: a rev-1 server
                // would treat PING as a protocol error).
                if proto >= PROTO_NT2 {
                    let _ = r.set_read_timeout(Some(heartbeat));
                } else {
                    let _ = r.set_read_timeout(None);
                }
                let mut pinged = false;
                loop {
                    let frame = match wire::read_frame_event(&mut r, &mut scratch, MAX_FRAME_LEN)
                    {
                        Ok(ReadEvent::Frame(f)) => {
                            pinged = false; // any traffic proves liveness
                            f
                        }
                        Ok(ReadEvent::IdleTimeout) => {
                            if proto == PROTO_NT1 {
                                continue; // no timeout armed; platform quirk
                            }
                            if pinged
                                || send_bytes(&pong_w, &Frame::Ping.encode()).is_err()
                            {
                                log_warn!(
                                    "worker {worker}: server silent through PING + \
                                     grace — treating the link as dead"
                                );
                                ce.store(true, Ordering::Relaxed);
                                break;
                            }
                            pinged = true;
                            continue;
                        }
                        Ok(ReadEvent::Eof) => {
                            // EOF without SHUTDOWN: the server vanished.
                            ce.store(true, Ordering::Relaxed);
                            break;
                        }
                        Err(e) => {
                            // Server died mid-frame, or our own teardown
                            // half-close raced a publish: either way the
                            // run is over for this worker.
                            log_debug!("worker {worker}: publish stream ended: {e:#}");
                            ce.store(true, Ordering::Relaxed);
                            break;
                        }
                    };
                    match frame {
                        Frame::Publish { version, meta, theta } => {
                            if proto != PROTO_NT1 || theta.len() != dim {
                                log_warn!(
                                    "worker {worker}: bad PUBLISH (dim {} on a rev-{proto} \
                                     link, layout dim {dim})",
                                    theta.len()
                                );
                                ce.store(true, Ordering::Relaxed);
                                break;
                            }
                            pub_r.publish_meta(version, theta, meta);
                        }
                        Frame::Publish2 { version, meta, slice_id, start, theta } => {
                            if proto == PROTO_NT1
                                || slice_id != 0
                                || start != 0
                                || theta.len() != dim
                            {
                                log_warn!(
                                    "worker {worker}: bad PUBLISH2 (slice {slice_id} @ \
                                     {start}, {} values, rev {proto})",
                                    theta.len()
                                );
                                ce.store(true, Ordering::Relaxed);
                                break;
                            }
                            pub_r.publish_meta(version, theta, meta);
                        }
                        Frame::Ping => {
                            let _ = send_bytes(&pong_w, &Frame::Pong.encode());
                        }
                        Frame::Pong => {}
                        Frame::Shutdown => {
                            sd.store(true, Ordering::Relaxed);
                            break;
                        }
                        Frame::Error { code, message } => {
                            // Surface the peer and the decision, not
                            // just the code (ISSUE 6): the operator
                            // sees *which* server refused and what
                            // happens next.
                            log_warn!(
                                "worker {worker}: server {addr} answered ERROR {code} \
                                 ({message}) — dropping the link; the reconnect loop \
                                 decides whether to retry"
                            );
                            ce.store(true, Ordering::Relaxed);
                            break;
                        }
                        f => {
                            log_warn!("worker {worker}: unexpected frame kind {:#04x}", f.kind());
                            ce.store(true, Ordering::Relaxed);
                            break;
                        }
                    }
                }
                pub_r.shutdown();
            });
            // Push pump: local channel → server (PUSH on rev 1, PUSH2
            // on rev 2 — same slice-full payload either way).
            let pub_w = Arc::clone(&published);
            let push_w = Arc::clone(&writer);
            let push_slice = slice.clone();
            let wh = s.spawn(move || -> std::io::Result<()> {
                while let Ok(msg) = rx.recv() {
                    let frame: Frame = if proto == PROTO_NT1 {
                        msg.into()
                    } else {
                        match msg {
                            ToServer::Push(p) => Frame::Push2 {
                                slice_id: push_slice.id as u64,
                                start: push_slice.range.start as u64,
                                push: p,
                            },
                            ToServer::WorkerExit { worker } => {
                                Frame::WorkerExit { worker: worker as u64 }
                            }
                        }
                    };
                    if let Err(e) = send_bytes(&push_w, &frame.encode()) {
                        // Server unreachable: stop the local loop too.
                        pub_w.shutdown();
                        return Err(e);
                    }
                }
                let _ = push_w.lock().unwrap().shutdown(std::net::Shutdown::Write);
                Ok(())
            });
            // The worker loop itself, unchanged from the in-process path.
            run_worker(worker, source, factory, Arc::clone(&published), tx, profile);
            let push_res = wh
                .join()
                .unwrap_or_else(|_| Err(std::io::Error::other("push pump panicked")));
            // Decide how the run ended *before* teardown: the control
            // shutdown below makes the publish pump error out, which
            // must not be mistaken for a lost link.
            let end = if saw_shutdown.load(Ordering::Relaxed) {
                RunEnd::Shutdown
            } else if conn_err.load(Ordering::Relaxed) || push_res.is_err() {
                RunEnd::ConnectionLost
            } else {
                RunEnd::Left
            };
            if let Err(e) = &push_res {
                log_warn!("worker {worker}: push stream failed: {e}");
            }
            // Unblock the publish pump if it is still mid-read (early
            // departure: the server keeps publishing to others).
            let _ = ctrl.shutdown(std::net::Shutdown::Both);
            end
        });
        Ok(end)
    }
}

/// A worker-side bundle of connections to a **partitioned** server
/// fleet (ISSUE 5): one [`NetWorkerHandle`] per slice server, validated
/// to agree on layout/τ/topology, to report the same worker id, and to
/// tile θ exactly.  [`ShardedWorkerHandle::run`] assembles the slice
/// publish streams into one full-θ view and splits each gradient into
/// per-slice PUSH2 frames — `run_worker` (the math, windowing, and
/// profiles) is reused verbatim on the assembled view.
pub struct ShardedWorkerHandle {
    conns: Vec<NetWorkerHandle>,
    pub worker: usize,
    pub layout: ThetaLayout,
    pub tau: u64,
    pub topology: Topology,
}

impl ShardedWorkerHandle {
    /// Connect to every slice server (`addrs` in any order; the slices
    /// they announce decide their role).  The first connection may let
    /// the server assign an id (`claim = None`); every subsequent
    /// connection claims that same id, so the worker is one identity
    /// across the fleet.  Prefer explicit claims in multi-worker
    /// deployments — concurrent `ANY` assignments on different servers
    /// are not coordinated.
    pub fn connect(addrs: &[String], claim: Option<usize>) -> Result<Self> {
        ensure!(!addrs.is_empty(), "need at least one server address");
        let mut conns: Vec<NetWorkerHandle> = Vec::with_capacity(addrs.len());
        let mut claim = claim;
        for addr in addrs {
            let h = NetWorkerHandle::connect(addr, claim)
                .with_context(|| format!("slice server {addr}"))?;
            ensure!(
                h.proto >= PROTO_NT2,
                "{addr} negotiated revision {} — a sharded worker needs ADVGPNT2",
                h.proto
            );
            claim = Some(h.worker); // one identity across the fleet
            conns.push(h);
        }
        let first = &conns[0];
        let (worker, layout, tau, topology) =
            (first.worker, first.layout, first.tau, first.topology.clone());
        ensure!(
            topology.n_slices() == addrs.len(),
            "servers announce a {}-slice topology but {} address(es) were given \
             — connect to every slice server exactly once",
            topology.n_slices(),
            addrs.len()
        );
        let mut seen = vec![false; topology.n_slices()];
        for (addr, h) in addrs.iter().zip(&conns) {
            ensure!(
                h.worker == worker && h.layout == layout && h.tau == tau,
                "{addr} disagrees on worker id / layout / τ with the first server"
            );
            ensure!(
                h.topology == topology,
                "{addr} announces a different topology — the fleet is inconsistent"
            );
            ensure!(
                !std::mem::replace(&mut seen[h.slice.id], true),
                "{addr} announces slice {} which another address already covers",
                h.slice.id
            );
        }
        conns.sort_by_key(|c| c.slice.id);
        Ok(Self { conns, worker, layout, tau, topology })
    }

    /// The per-slice θ versions at handshake time (the assembled start
    /// version is this vector's minimum).
    pub fn version_vector(&self) -> Vec<u64> {
        self.conns.iter().map(|c| c.version).collect()
    }

    /// Run the worker loop against the fleet until the servers shut
    /// down, the session's outage budget runs dry, or the profile makes
    /// the worker leave.
    pub fn run(
        self,
        source: &mut WorkerSource,
        factory: EngineFactory,
        profile: WorkerProfile,
    ) -> Result<RunEnd> {
        self.run_with(source, factory, profile, &RetryPolicy::default())
    }

    /// [`ShardedWorkerHandle::run`] with explicit retry/timeout budgets.
    ///
    /// Hardening (ISSUE 6): a link that dies mid-run no longer ends the
    /// session.  Each slice link has a *supervisor*: when the pump
    /// reports the link dead, the supervisor marks it down (the push
    /// splitter then **holds** that slice's fragments instead of
    /// erroring out), draws an attempt from the session-wide
    /// [`OutageBudget`] — one pool however many of the S links an
    /// outage takes down — backs off, and re-handshakes that one
    /// address, validating the new WELCOME2 still matches the fleet
    /// (same id, layout, τ, topology, slice).  A successful
    /// re-handshake refills the budget, republishes the slice's live θ,
    /// swaps the shared writer, and the pump resumes; an exhausted
    /// budget (or a changed fleet) ends the session with
    /// [`RunEnd::ConnectionLost`].
    pub fn run_with(
        self,
        source: &mut WorkerSource,
        factory: EngineFactory,
        profile: WorkerProfile,
        retry: &RetryPolicy,
    ) -> Result<RunEnd> {
        let Self { conns, worker, layout, tau, topology } = self;
        ensure!(
            source.d() == layout.d,
            "shard has d={} features but the server's layout has d={}",
            source.d(),
            layout.d
        );
        // Assemble the initial view at the handshake version floor.
        let floor = conns.iter().map(|c| c.version).min().unwrap_or(0);
        let mut theta0 = vec![0.0f64; topology.dim];
        for c in &conns {
            theta0[c.slice.range.clone()].copy_from_slice(&c.theta);
        }
        let assembled = Published::new(theta0.clone());
        let sharded = ShardedPublished::new(topology.clone(), &theta0, Arc::clone(&assembled));
        for (c, p) in conns.iter().zip(&sharded.slices) {
            if c.version > 0 {
                p.publish_meta(c.version, c.theta.clone(), c.meta);
            }
        }
        if floor > 0 {
            assembled.publish(floor, theta0);
        }
        let saw_shutdown = Arc::new(AtomicBool::new(false));
        let conn_err = Arc::new(AtomicBool::new(false));
        // Teardown flag: supervisors check it before re-establishing,
        // the splitter before holding a fragment — so a run that is
        // over cannot be resurrected by a racing repair.
        let session_over = Arc::new(AtomicBool::new(false));
        // Which links are currently down (splitter holds fragments for
        // them; their supervisors repair them).
        let link_down: Arc<Vec<AtomicBool>> =
            Arc::new((0..conns.len()).map(|_| AtomicBool::new(false)).collect());
        let budget = Arc::new(OutageBudget {
            max: retry.reconnect.max_retries,
            used: AtomicU32::new(0),
        });
        let (tx, rx) = std::sync::mpsc::channel::<ToServer>();
        // Per-connection plumbing: a reader for the publish pump, a
        // control clone for teardown (behind a mutex so a repair can
        // swap in the replacement socket), a shared writer for pushes +
        // PONGs, and the dialed address for re-establishment.
        let mut addrs = Vec::with_capacity(conns.len());
        let mut readers = Vec::with_capacity(conns.len());
        let mut ctrls: Vec<Arc<Mutex<TcpStream>>> = Vec::with_capacity(conns.len());
        let mut writers = Vec::with_capacity(conns.len());
        for c in &conns {
            addrs.push(c.addr.clone());
            readers.push(c.stream.try_clone().context("clone stream for the publish pump")?);
            ctrls.push(Arc::new(Mutex::new(
                c.stream.try_clone().context("clone stream for teardown")?,
            )));
        }
        for c in conns {
            writers.push(Arc::new(Mutex::new(c.stream)));
        }
        let end = std::thread::scope(|s| {
            // One supervised publish pump per slice link: the pump runs
            // until SHUTDOWN or link death; the supervisor loop around
            // it decides whether the outage budget buys a repair.
            for (i, mut reader) in readers.into_iter().enumerate() {
                let slice = topology.slice(i);
                let topo = topology.clone();
                let addr = addrs[i].clone();
                let slice_pub = Arc::clone(&sharded.slices[i]);
                let writer = Arc::clone(&writers[i]);
                let ctrl = Arc::clone(&ctrls[i]);
                let sd = Arc::clone(&saw_shutdown);
                let ce = Arc::clone(&conn_err);
                let over = Arc::clone(&session_over);
                let down = Arc::clone(&link_down);
                let budget = Arc::clone(&budget);
                let retry = *retry;
                // Deterministic per-(worker, address, slice) jitter
                // stream, mirroring remote_worker_loop's seeding.
                let mut rng = Pcg64::seeded(
                    fnv1a64(FNV1A64_INIT, addr.as_bytes())
                        ^ worker as u64
                        ^ slice.id as u64,
                );
                s.spawn(move || {
                    'session: loop {
                        match pump_slice(
                            &mut reader,
                            worker,
                            &addr,
                            &slice,
                            &slice_pub,
                            &writer,
                            retry.heartbeat,
                        ) {
                            PumpEnd::Shutdown => {
                                sd.store(true, Ordering::SeqCst);
                                break 'session;
                            }
                            PumpEnd::LinkDead => {}
                        }
                        down[i].store(true, Ordering::SeqCst);
                        if over.load(Ordering::SeqCst) {
                            break 'session;
                        }
                        // Re-establish this one link under the shared
                        // outage budget; the other S−1 links keep
                        // training meanwhile.
                        reader = loop {
                            let Some(attempt) = budget.take() else {
                                log_warn!(
                                    "worker {worker}: slice {} link to {addr} lost and \
                                     the session outage budget is exhausted — abandoning \
                                     the session",
                                    slice.id
                                );
                                ce.store(true, Ordering::SeqCst);
                                break 'session;
                            };
                            let delay = retry.reconnect.delay(attempt, &mut rng);
                            log_warn!(
                                "worker {worker}: slice {} link to {addr} lost; \
                                 re-establishing ({}/{} outage retries used) in {:.1}s",
                                slice.id,
                                attempt + 1,
                                retry.reconnect.max_retries,
                                delay.as_secs_f64()
                            );
                            if sleep_poll(delay, &over) {
                                break 'session;
                            }
                            let h = match NetWorkerHandle::connect_with(
                                &addr,
                                Some(worker),
                                &retry,
                            ) {
                                Ok(h) => h,
                                Err(e) => {
                                    // Same contract as remote_worker_loop:
                                    // deliberate rejections are fatal,
                                    // except ERR_ID_IN_USE, which a
                                    // half-dead old connection answers
                                    // until the server's heartbeat
                                    // retires it.
                                    let fatal = e
                                        .downcast_ref::<Rejected>()
                                        .is_some_and(|r| r.code != ERR_ID_IN_USE);
                                    if fatal {
                                        log_warn!(
                                            "worker {worker}: slice {} server {addr} \
                                             rejected the reconnect ({e:#}) — not \
                                             retrying",
                                            slice.id
                                        );
                                        ce.store(true, Ordering::SeqCst);
                                        break 'session;
                                    }
                                    log_warn!(
                                        "worker {worker}: slice {} reconnect to {addr} \
                                         failed: {e:#}",
                                        slice.id
                                    );
                                    continue;
                                }
                            };
                            if h.proto < PROTO_NT2
                                || h.worker != worker
                                || h.layout != layout
                                || h.tau != tau
                                || h.topology != topo
                                || h.slice.id != slice.id
                            {
                                log_warn!(
                                    "worker {worker}: slice {} server {addr} no longer \
                                     matches the fleet (id/layout/τ/topology/slice \
                                     changed) — abandoning the session",
                                    slice.id
                                );
                                ce.store(true, Ordering::SeqCst);
                                break 'session;
                            }
                            let (Ok(new_reader), Ok(new_ctrl)) =
                                (h.stream.try_clone(), h.stream.try_clone())
                            else {
                                continue;
                            };
                            budget.refill();
                            let NetWorkerHandle { stream, version, theta, meta, .. } = h;
                            *ctrl.lock().unwrap() = new_ctrl;
                            // Re-seed the slice view with the live θ so
                            // the assembled floor can advance past the
                            // outage without waiting for the next
                            // server-side update.
                            if version > 0 {
                                slice_pub.publish_meta(version, theta, meta);
                            }
                            // Swap the writer *before* clearing `down`:
                            // the splitter must never see a live link
                            // with a dead socket behind it.
                            *writer.lock().unwrap() = stream;
                            down[i].store(false, Ordering::SeqCst);
                            log_info!(
                                "worker {worker}: slice {} link to {addr} \
                                 re-established (θ v{version})",
                                slice.id
                            );
                            if over.load(Ordering::SeqCst) {
                                break 'session;
                            }
                            break new_reader;
                        };
                    }
                    // The session is over for this slice (SHUTDOWN, an
                    // exhausted budget, a changed fleet, or teardown):
                    // end its view so the assembler — and run_worker
                    // blocked behind it — unwinds too.
                    slice_pub.shutdown();
                });
            }
            // The assembler: slice views → assembled full-θ view.
            {
                let sharded_ref = &sharded;
                s.spawn(move || run_assembler(sharded_ref));
            }
            // The push splitter: local channel → one PUSH2 per slice.
            // A fragment bound for a down link is **held** (20 ms
            // polls) until its supervisor repairs the link — dropping
            // it instead would wedge the run: the slice gate would wait
            // forever on a push that never arrives while the worker
            // waits on a publish that never comes.
            let split_writers: Vec<Arc<Mutex<TcpStream>>> =
                writers.iter().map(Arc::clone).collect();
            let topo = topology.clone();
            let view = Arc::clone(&assembled);
            let over = Arc::clone(&session_over);
            let down = Arc::clone(&link_down);
            let wh = s.spawn(move || {
                while let Ok(msg) = rx.recv() {
                    for (i, part) in
                        super::sharded::split_message(&topo, &msg).into_iter().enumerate()
                    {
                        let frame: Frame = match part {
                            ToServer::Push(p) => Frame::Push2 {
                                slice_id: i as u64,
                                start: topo.ranges[i].start as u64,
                                push: p,
                            },
                            ToServer::WorkerExit { worker } => {
                                Frame::WorkerExit { worker: worker as u64 }
                            }
                        };
                        let bytes = frame.encode();
                        loop {
                            if over.load(Ordering::SeqCst) || view.snapshot().2 {
                                return; // session over: the fragment is moot
                            }
                            if down[i].load(Ordering::SeqCst) {
                                std::thread::sleep(Duration::from_millis(20));
                                continue; // hold for the link supervisor
                            }
                            match send_bytes(&split_writers[i], &bytes) {
                                Ok(()) => break,
                                Err(e) => {
                                    // First to notice the dead socket:
                                    // flag it and hold the fragment; the
                                    // supervisor's pump errors out next
                                    // read and repairs the link.
                                    down[i].store(true, Ordering::SeqCst);
                                    log_warn!(
                                        "worker {worker}: slice {i} push failed ({e}); \
                                         holding the fragment for the link supervisor"
                                    );
                                }
                            }
                        }
                    }
                }
                for w in &split_writers {
                    let _ = w.lock().unwrap().shutdown(std::net::Shutdown::Write);
                }
            });
            // The worker loop, verbatim, on the assembled view.
            run_worker(worker, source, factory, Arc::clone(&assembled), tx, profile);
            let _ = wh.join();
            // Decide how the run ended *before* teardown: the control
            // shutdowns below make the pumps error out, which must not
            // be mistaken for a lost link.
            let end = if saw_shutdown.load(Ordering::SeqCst) {
                RunEnd::Shutdown
            } else if conn_err.load(Ordering::SeqCst) {
                RunEnd::ConnectionLost
            } else {
                RunEnd::Left
            };
            // Tear every socket down so the per-slice pumps (and the
            // assembler behind them) unwind; `session_over` stops the
            // supervisors from re-establishing what we just tore down.
            session_over.store(true, Ordering::SeqCst);
            for c in ctrls.iter() {
                let _ = c.lock().unwrap().shutdown(std::net::Shutdown::Both);
            }
            sharded.shutdown_all();
            end
        });
        Ok(end)
    }
}

/// How one slice link's publish pump ended: the whole session is over,
/// or just this link.
enum PumpEnd {
    /// The server announced SHUTDOWN — the run is complete everywhere.
    Shutdown,
    /// This link died (EOF, stream error, heartbeat verdict, ERROR
    /// answer, protocol violation); the supervisor decides whether the
    /// outage budget buys a repair.
    LinkDead,
}

/// One outage budget shared by every slice link of a sharded session
/// (ISSUE 6): however many links a partition takes down, attempts are
/// drawn from a single pool, refilled by any successful re-handshake —
/// so a flapping fleet cannot retry forever, but an S-link outage
/// costs the same budget a 1-link outage would.
struct OutageBudget {
    max: u32,
    used: AtomicU32,
}

impl OutageBudget {
    /// Draw one attempt; `Some(n)` is the 0-based attempt index (feeds
    /// the backoff curve), `None` means the budget is exhausted.
    fn take(&self) -> Option<u32> {
        self.used
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |u| {
                (u < self.max).then_some(u + 1)
            })
            .ok()
    }

    fn refill(&self) {
        self.used.store(0, Ordering::SeqCst);
    }
}

/// Sleep `d` in 20 ms polls, aborting early when the session ends;
/// returns true if it ended — a supervisor's backoff must never
/// outlive the run it would be repairing.
fn sleep_poll(d: Duration, over: &AtomicBool) -> bool {
    let sw = Stopwatch::start();
    while sw.secs() < d.as_secs_f64() {
        if over.load(Ordering::SeqCst) {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    over.load(Ordering::SeqCst)
}

/// One slice link's publish pump (the hardened sharded worker side):
/// decode PUBLISH2/PING/SHUTDOWN until the run ends or the link dies —
/// the caller's supervisor loop owns what happens next.
fn pump_slice(
    r: &mut TcpStream,
    worker: usize,
    addr: &str,
    slice: &SliceSpec,
    slice_pub: &Published,
    pong_w: &Mutex<TcpStream>,
    heartbeat: Duration,
) -> PumpEnd {
    let mut scratch = Vec::new();
    // Sharded links are always rev ≥ 2: the worker-side heartbeat
    // probes every slice server independently.
    let _ = r.set_read_timeout(Some(heartbeat));
    let mut pinged = false;
    loop {
        let frame = match wire::read_frame_event(r, &mut scratch, MAX_FRAME_LEN) {
            Ok(ReadEvent::Frame(f)) => {
                pinged = false;
                f
            }
            Ok(ReadEvent::IdleTimeout) => {
                if pinged || send_bytes(pong_w, &Frame::Ping.encode()).is_err() {
                    log_warn!(
                        "worker {worker}: slice {} server {addr} silent through \
                         PING + grace — treating the link as dead",
                        slice.id
                    );
                    return PumpEnd::LinkDead;
                }
                pinged = true;
                continue;
            }
            Ok(ReadEvent::Eof) => return PumpEnd::LinkDead,
            Err(e) => {
                log_debug!(
                    "worker {worker}: slice {} publish stream ended: {e:#}",
                    slice.id
                );
                return PumpEnd::LinkDead;
            }
        };
        match frame {
            Frame::Publish2 { version, meta, slice_id, start, theta } => {
                if slice_id != slice.id as u64
                    || start != slice.range.start as u64
                    || theta.len() != slice.len()
                {
                    log_warn!(
                        "worker {worker}: slice {} sent a mismatched PUBLISH2 \
                         (slice {slice_id} @ {start}, {} values)",
                        slice.id,
                        theta.len()
                    );
                    return PumpEnd::LinkDead;
                }
                slice_pub.publish_meta(version, theta, meta);
            }
            Frame::Ping => {
                let _ = send_bytes(pong_w, &Frame::Pong.encode());
            }
            Frame::Pong => {}
            Frame::Shutdown => return PumpEnd::Shutdown,
            Frame::Error { code, message } => {
                // Surface the peer and the decision taken (ISSUE 6).
                log_warn!(
                    "worker {worker}: slice {} server {addr} answered ERROR {code} \
                     ({message}) — dropping the link; the outage budget decides \
                     whether to re-establish",
                    slice.id
                );
                return PumpEnd::LinkDead;
            }
            f => {
                log_warn!("worker {worker}: unexpected frame kind {:#04x}", f.kind());
                return PumpEnd::LinkDead;
            }
        }
    }
}

/// Reconnect policy for [`remote_worker_loop`] (ROADMAP "WAN
/// hardening"): bounded retries with exponentially growing, jittered
/// delays.  The retry budget refills after every successful handshake,
/// so it bounds each *outage*, not the worker's lifetime; handshake
/// *rejections* ([`Rejected`] — wrong revision, id in use) are never
/// retried.
#[derive(Clone, Copy, Debug)]
pub struct ReconnectPolicy {
    /// Retries per outage (0 = fail on the first error).
    pub max_retries: u32,
    /// First retry delay; doubles each attempt.
    pub base: Duration,
    /// Ceiling on the (pre-jitter) delay.
    pub cap: Duration,
}

impl Default for ReconnectPolicy {
    fn default() -> Self {
        Self { max_retries: 5, base: Duration::from_millis(200), cap: Duration::from_secs(10) }
    }
}

impl ReconnectPolicy {
    /// The delay before retry `attempt` (0-based): `base · 2^attempt`,
    /// capped, then jittered by a uniform factor in `[0.5, 1.5)` so a
    /// fleet of workers dropped by one partition does not reconnect as
    /// a thundering herd.
    pub fn delay(&self, attempt: u32, rng: &mut Pcg64) -> Duration {
        let exp = self.base.as_secs_f64() * 2f64.powi(attempt.min(20) as i32);
        let capped = exp.min(self.cap.as_secs_f64());
        Duration::from_secs_f64(capped * (0.5 + rng.next_f64()))
    }
}

/// Connect to `addr`, handshake (claiming `claim` if given), and run
/// the worker loop to completion, reconnecting with the default
/// [`ReconnectPolicy`] through transient connect failures and mid-run
/// link losses.  Returns the worker id the run used.  This is the whole
/// body of `advgp worker --connect` (single-server form).
pub fn remote_worker_loop(
    addr: &str,
    claim: Option<usize>,
    source: WorkerSource,
    factory: EngineFactory,
    profile: WorkerProfile,
) -> Result<usize> {
    remote_worker_loop_with(addr, claim, source, factory, profile, ReconnectPolicy::default())
}

/// [`remote_worker_loop`] with an explicit [`ReconnectPolicy`].
pub fn remote_worker_loop_with(
    addr: &str,
    claim: Option<usize>,
    mut source: WorkerSource,
    factory: EngineFactory,
    profile: WorkerProfile,
    policy: ReconnectPolicy,
) -> Result<usize> {
    let mut claim = claim;
    let retry = RetryPolicy::from(policy);
    // Deterministic per-(worker, address) jitter stream.
    let seed = fnv1a64(FNV1A64_INIT, addr.as_bytes())
        ^ claim.map_or(u64::MAX, |c| c as u64);
    let mut rng = Pcg64::seeded(seed);
    let mut attempt: u32 = 0;
    loop {
        let handle = match NetWorkerHandle::connect_with(addr, claim, &retry) {
            Ok(h) => h,
            Err(e) => {
                // Deliberate rejections are fatal — EXCEPT "id in use",
                // which is transient by construction on a reconnect:
                // after a link loss the server frees the id only once
                // its reader observes the dead connection (up to a
                // heartbeat window later), so the very scenario the
                // retry budget exists for answers ERR_ID_IN_USE first.
                let fatal_rejection = e
                    .downcast_ref::<Rejected>()
                    .is_some_and(|r| r.code != ERR_ID_IN_USE);
                if fatal_rejection || attempt >= policy.max_retries {
                    // Surface the server's stated reason and our
                    // decision before erroring out (ISSUE 6): the
                    // operator should not have to unwrap an error
                    // chain to learn *why* the worker gave up.
                    if let Some(r) = e.downcast_ref::<Rejected>() {
                        log_warn!(
                            "worker: server {addr} rejected the connection \
                             (ERROR {}: {}) — not retrying",
                            r.code,
                            r.message
                        );
                    }
                    return Err(e).with_context(|| {
                        format!("connect to {addr} (after {attempt} retries)")
                    });
                }
                let delay = policy.delay(attempt, &mut rng);
                attempt += 1;
                log_warn!(
                    "worker: connect to {addr} failed ({e:#}); retry {attempt}/{} in {:.1}s",
                    policy.max_retries,
                    delay.as_secs_f64()
                );
                std::thread::sleep(delay);
                continue;
            }
        };
        // A successful handshake refills the budget and pins the id, so
        // a reconnect resumes the same identity — and the jitter stream
        // is reseeded with that id: a fleet started with ANY claims
        // shares one pre-assignment seed, and identical backoff
        // sequences would reconnect it as exactly the thundering herd
        // the jitter exists to spread.
        attempt = 0;
        let id = handle.worker;
        if claim != Some(id) {
            rng = Pcg64::seeded(seed ^ id as u64);
        }
        claim = Some(id);
        match handle.run_with(&mut source, factory.clone(), profile.clone(), &retry)? {
            RunEnd::Shutdown | RunEnd::Left => return Ok(id),
            RunEnd::ConnectionLost => {
                if attempt >= policy.max_retries {
                    bail!("worker {id}: link to {addr} lost and retry budget exhausted");
                }
                let delay = policy.delay(attempt, &mut rng);
                attempt += 1;
                log_warn!(
                    "worker {id}: link to {addr} lost; reconnect {attempt}/{} in {:.1}s",
                    policy.max_retries,
                    delay.as_secs_f64()
                );
                std::thread::sleep(delay);
            }
        }
    }
}

/// Connect to every slice server of a partitioned fleet, handshake, and
/// run the worker loop to completion, surviving partial link loss: the
/// hardened [`ShardedWorkerHandle::run`] re-establishes lost links one
/// by one under a single session-wide outage budget (ISSUE 6), so a
/// half-lost fleet session costs staleness, not the worker.  Returns
/// the worker id.  This is the body of
/// `advgp worker --connect addr0,addr1,…`.
pub fn sharded_worker_loop(
    addrs: &[String],
    claim: Option<usize>,
    source: WorkerSource,
    factory: EngineFactory,
    profile: WorkerProfile,
) -> Result<usize> {
    sharded_worker_loop_with(addrs, claim, source, factory, profile, RetryPolicy::default())
}

/// [`sharded_worker_loop`] with explicit retry/timeout budgets.
pub fn sharded_worker_loop_with(
    addrs: &[String],
    claim: Option<usize>,
    mut source: WorkerSource,
    factory: EngineFactory,
    profile: WorkerProfile,
    retry: RetryPolicy,
) -> Result<usize> {
    let handle = ShardedWorkerHandle::connect(addrs, claim)?;
    let id = handle.worker;
    match handle.run_with(&mut source, factory, profile, &retry)? {
        // The session outage budget ran dry (or the fleet changed under
        // us) — a failure the caller (or its supervisor) must see:
        // exiting 0 would read as "run complete" while the fleet is
        // still training without us.
        RunEnd::ConnectionLost => bail!(
            "worker {id}: a slice-server link was lost and the session's \
             outage budget is exhausted; restart the worker to rejoin \
             the fleet"
        ),
        RunEnd::Shutdown | RunEnd::Left => Ok(id),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The satellite guarantee: however many connections fan a version
    /// out (and from however many threads), each (version, revision) is
    /// encoded exactly once.
    #[test]
    fn frame_cache_encodes_each_version_once() {
        let cache = Arc::new(PublishFrameCache::new(SliceSpec::full(4)));
        let theta = Arc::new(vec![1.0, 2.0, 3.0, 4.0]);
        let meta = PublishMeta::default();
        for version in 1..=3u64 {
            let mut handles = Vec::new();
            for _ in 0..8 {
                let cache = Arc::clone(&cache);
                let theta = Arc::clone(&theta);
                handles.push(std::thread::spawn(move || {
                    cache.get(PROTO_NT2, version, meta, &theta)
                }));
            }
            let frames: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
            // All connections got byte-identical frames.
            for f in &frames[1..] {
                assert_eq!(**f, *frames[0]);
            }
            assert_eq!(
                cache.encodes(),
                version,
                "one encode per version, not per connection"
            );
        }
        // A rev-1 connection needs its own framing: one more encode,
        // still shared across rev-1 readers.
        let a = cache.get(PROTO_NT1, 3, meta, &theta);
        let b = cache.get(PROTO_NT1, 3, meta, &theta);
        assert_eq!(*a, *b);
        assert_eq!(cache.encodes(), 4);
        assert_eq!(*a, wire::publish_frame_bytes(3, meta, &theta));
    }

    /// Backoff grows, caps, and jitters within [0.5, 1.5)× —
    /// deterministic for a seeded stream.
    #[test]
    fn reconnect_backoff_grows_caps_and_jitters() {
        let policy = ReconnectPolicy {
            max_retries: 10,
            base: Duration::from_millis(100),
            cap: Duration::from_secs(2),
        };
        let mut rng = Pcg64::seeded(7);
        let mut prev_nominal = 0.0f64;
        for attempt in 0..10 {
            let d = policy.delay(attempt, &mut rng).as_secs_f64();
            let nominal = (0.1 * 2f64.powi(attempt as i32)).min(2.0);
            assert!(
                d >= nominal * 0.5 && d < nominal * 1.5,
                "attempt {attempt}: {d} outside jitter band around {nominal}"
            );
            assert!(nominal >= prev_nominal, "nominal delay must be monotone");
            prev_nominal = nominal;
        }
        // Capped: far attempts never exceed 1.5 × cap.
        let d = policy.delay(30, &mut rng).as_secs_f64();
        assert!(d < 2.0 * 1.5 + 1e-9);
    }

    /// The unified budget bundle reproduces the historical constants,
    /// and adopting a bare [`ReconnectPolicy`] keeps the default
    /// timeouts — existing call sites see no behavior change.
    #[test]
    fn retry_policy_defaults_pin_the_historical_budgets() {
        let r = RetryPolicy::default();
        assert_eq!(r.heartbeat, WORKER_HEARTBEAT);
        assert_eq!(r.write_timeout, Duration::from_secs(30));
        assert_eq!(r.handshake_timeout, Duration::from_secs(10));
        assert_eq!(r.reconnect.max_retries, 5);
        assert_eq!(r.reconnect.base, Duration::from_millis(200));
        assert_eq!(r.reconnect.cap, Duration::from_secs(10));
        let tight = ReconnectPolicy {
            max_retries: 2,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(50),
        };
        let from = RetryPolicy::from(tight);
        assert_eq!(from.reconnect.max_retries, 2);
        assert_eq!(from.heartbeat, WORKER_HEARTBEAT);
    }

    /// The session-wide outage budget: attempts draw from one pool,
    /// exhaust exactly at `max`, and any successful re-handshake
    /// refills the whole pool.
    #[test]
    fn outage_budget_draws_exhausts_and_refills() {
        let b = OutageBudget { max: 3, used: AtomicU32::new(0) };
        assert_eq!(b.take(), Some(0));
        assert_eq!(b.take(), Some(1));
        assert_eq!(b.take(), Some(2));
        assert_eq!(b.take(), None);
        assert_eq!(b.take(), None, "exhaustion is stable");
        b.refill();
        assert_eq!(b.take(), Some(0));
    }
}
