//! Networked parameter-server transport (ISSUE 4): the TCP half that
//! turns the in-process `mpsc` + condvar topology into a distributed
//! one, speaking the `ADVGPNT1` protocol ([`super::wire`] is the codec;
//! `docs/PROTOCOL.md` the normative spec).
//!
//! Design: the server loop ([`super::server::run_server`]), the
//! [`super::DelayGate`], checkpointing, and the worker loop
//! ([`super::worker::run_worker`]) are reused **unchanged** — this
//! module only pumps bytes:
//!
//! * **Server side** — [`NetServer`] + the accept loop: one *reader*
//!   thread per connection decodes PUSH/EXIT frames into the same
//!   `Sender<ToServer>` the in-process workers would use, and one
//!   *publisher* thread per connection follows
//!   [`super::Published::wait_newer_meta`] and writes PUBLISH frames.
//!   Backpressure is per-connection: a slow link blocks only its own
//!   publisher, which then skips straight to the newest version (the
//!   same catch-up semantics an in-process worker gets from the
//!   condvar).  A connection that dies without an EXIT frame has its
//!   clock retired via a synthesized `WorkerExit`, so a killed remote
//!   worker (any death the TCP stack can observe — process kill, RST,
//!   FIN) cannot stall the bounded-staleness gate.  A *silently* wedged
//!   peer — powered off mid-run, no FIN ever — is the documented gap:
//!   like a hung in-process worker it stalls a bounded-τ gate until the
//!   wall-clock watchdog (see ROADMAP "WAN hardening" for the
//!   heartbeat plan).
//! * **Worker side** — [`NetWorkerHandle`] connects and handshakes
//!   (HELLO → WELCOME + initial PUBLISH), then [`NetWorkerHandle::run`]
//!   bridges the socket onto a local [`super::Published`] and an `mpsc`
//!   channel and calls `run_worker` on them.
//!
//! Determinism: the transport moves exactly the same messages the
//! in-process channel would, and the server aggregates gradient slots
//! in worker-id order — so a τ=0 loopback-TCP run reproduces the
//! in-process θ trajectory **bitwise** (pinned by
//! `rust/tests/net_transport.rs`).
//!
//! # Example: join a run as a remote worker
//!
//! ```no_run
//! # fn main() -> anyhow::Result<()> {
//! use advgp::data::synth;
//! use advgp::grad::native_factory;
//! use advgp::ps::{NetWorkerHandle, WorkerProfile, WorkerSource};
//!
//! // Connect to `advgp serve-ps` on :7171, claiming worker id 0.  The
//! // WELCOME frame carries the θ layout, so the engine needs no local
//! // configuration beyond the data shard.
//! let shard = synth::friedman(1000, 4, 0.4, 0);
//! let handle = NetWorkerHandle::connect("127.0.0.1:7171", Some(0))?;
//! let factory = native_factory(handle.layout);
//! handle.run(WorkerSource::Memory(shard), factory, WorkerProfile::default())?;
//! # Ok(()) }
//! ```

use super::messages::ToServer;
use super::wire::{
    self, Frame, ERR_BAD_MAGIC, ERR_DIM, ERR_ID_IN_USE, ERR_ID_MISMATCH,
    ERR_MALFORMED, ERR_PROTO, MAX_HANDSHAKE_FRAME_LEN, MAX_WORKER_ID,
    PROTO_VERSION, WORKER_ID_ANY,
};
use super::worker::{run_worker, WorkerProfile, WorkerSource};
use super::{Published, PublishMeta};
use crate::gp::ThetaLayout;
use crate::grad::EngineFactory;
use crate::{log_debug, log_info, log_warn};
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashSet;
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A bound ADVGPNT1 listener, handed to
/// [`super::coordinator::train_remote`] to serve a run.  Binding is
/// split from serving so callers (tests, the CLI) can bind port 0 and
/// learn the real port before any worker needs it.
pub struct NetServer {
    listener: TcpListener,
}

impl NetServer {
    /// Bind the listener (e.g. `"0.0.0.0:7171"`, or `"127.0.0.1:0"` for
    /// an ephemeral loopback port).
    pub fn bind(addr: &str) -> Result<Self> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("bind ADVGPNT1 server on {addr}"))?;
        Ok(Self { listener })
    }

    /// The address actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has a local address")
    }
}

/// Worker ids currently holding a live connection.  An id frees up on
/// disconnect, so a crashed worker can reconnect as itself and be
/// re-admitted by the gate on its next push.
struct Registry {
    /// Declared gate members (ids `0..declared`).  Reserved for
    /// explicit claims: auto-assignment starts above this range, so a
    /// read-only or elastic `ANY` connection can never squat the id an
    /// expected `advgp worker --shard k` is about to claim (which
    /// would stall the gate on a clock that never pushes).
    declared: u64,
    connected: Mutex<HashSet<u64>>,
}

impl Registry {
    fn new(declared: usize) -> Self {
        Self { declared: declared as u64, connected: Mutex::new(HashSet::new()) }
    }

    fn claim(&self, want: u64) -> std::result::Result<u64, (u16, String)> {
        let mut c = self.connected.lock().unwrap();
        let id = if want == WORKER_ID_ANY {
            let mut i = self.declared;
            while c.contains(&i) {
                i += 1;
            }
            i
        } else if want > MAX_WORKER_ID {
            // The gate clocks and gradient slots are id-indexed dense
            // arrays: an unbounded claim would let one client OOM the
            // shared server.
            return Err((
                ERR_MALFORMED,
                format!("worker id {want} exceeds the maximum {MAX_WORKER_ID}"),
            ));
        } else if c.contains(&want) {
            return Err((ERR_ID_IN_USE, format!("worker id {want} already connected")));
        } else {
            want
        };
        c.insert(id);
        Ok(id)
    }

    fn release(&self, id: u64) {
        self.connected.lock().unwrap().remove(&id);
    }
}

/// Accept connections until shutdown, spawning a handler per worker.
/// Runs on a dedicated thread inside `train_remote`'s scope; per-
/// connection reader/publisher threads are detached (they hold only
/// `Arc`s and channel clones, and unwind on socket close).
///
/// The listener runs non-blocking with a 50 ms shutdown poll, so the
/// loop terminates deterministically even if the post-shutdown
/// [`wake`] connection (which exists only to end the wait early) is
/// dropped by a firewall.  If non-blocking mode is unavailable the
/// loop falls back to blocking accepts and relies on the wake.
pub(crate) fn accept_loop(
    net: NetServer,
    published: Arc<Published>,
    tx: Sender<ToServer>,
    layout: ThetaLayout,
    tau: u64,
    declared_workers: usize,
) {
    let registry = Arc::new(Registry::new(declared_workers));
    let nonblocking = net.listener.set_nonblocking(true).is_ok();
    loop {
        let stream = match net.listener.accept() {
            Ok((s, _peer)) => s,
            Err(e) if nonblocking && e.kind() == std::io::ErrorKind::WouldBlock => {
                if published.snapshot().2 {
                    break;
                }
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
            Err(e) => {
                if published.snapshot().2 {
                    break;
                }
                log_warn!("ps::net: accept failed: {e}");
                // EMFILE and friends are persistent: without a backoff
                // this arm busy-spins the accept thread at 100% CPU
                // (the queued connection keeps failing instantly).
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
        };
        if published.snapshot().2 {
            break; // the post-shutdown wake connection (or a stray late joiner)
        }
        // Handlers expect blocking I/O regardless of the listener mode.
        if stream.set_nonblocking(false).is_err() {
            continue;
        }
        let published = Arc::clone(&published);
        let tx = tx.clone();
        let registry = Arc::clone(&registry);
        std::thread::spawn(move || handle_conn(stream, published, tx, layout, tau, registry));
    }
}

/// Unblock an [`accept_loop`] stuck in `accept()` after shutdown was
/// signalled, by poking one throwaway connection at it.
pub(crate) fn wake(addr: SocketAddr) {
    let mut a = addr;
    if a.ip().is_unspecified() {
        // Can't connect *to* a wildcard bind address; the listener is
        // reachable on loopback.
        a.set_ip(match a {
            SocketAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
            SocketAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
        });
    }
    let _ = TcpStream::connect_timeout(&a, Duration::from_millis(500));
}

fn send_bytes(w: &Mutex<TcpStream>, bytes: &[u8]) -> std::io::Result<()> {
    use std::io::Write;
    // One locked write_all per frame: frames never interleave even with
    // the publisher thread and the reader's error path sharing a socket.
    w.lock().unwrap().write_all(bytes)
}

fn send_error(w: &Mutex<TcpStream>, code: u16, message: &str) {
    let f = Frame::Error { code, message: message.into() };
    let _ = send_bytes(w, &f.encode());
}

/// One connection, server side: handshake, then this thread reads
/// worker→server frames while a spawned twin fans out publishes.
fn handle_conn(
    stream: TcpStream,
    published: Arc<Published>,
    tx: Sender<ToServer>,
    layout: ThetaLayout,
    tau: u64,
    registry: Arc<Registry>,
) {
    let _ = stream.set_nodelay(true);
    // Bound every write: a peer that stops draining its publish stream
    // would otherwise block the publisher thread inside write_all while
    // it holds the writer mutex — and then an error-path send_error on
    // the reader thread would deadlock behind it, leaving the worker's
    // clock in the gate forever.  With the timeout the wedged write
    // fails, the mutex frees, and teardown proceeds.
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    // Bound the handshake read too: an idle pre-HELLO connection (port
    // scanner, slowloris) must not pin this thread + FD for the life of
    // the process.  Cleared after the handshake — a healthy worker may
    // legitimately compute for minutes between pushes.
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "?".into());
    let writer = match stream.try_clone() {
        Ok(s) => Arc::new(Mutex::new(s)),
        Err(e) => {
            log_warn!("ps::net: {peer}: stream clone failed: {e}");
            return;
        }
    };
    let mut reader = stream;
    let mut scratch = Vec::new();

    // ---- handshake: HELLO → WELCOME + initial PUBLISH ----
    // The peer is untrusted until HELLO validates: the capped read
    // keeps a hostile length prefix from allocating MAX_FRAME_LEN.
    let hello = wire::read_frame_capped(&mut reader, &mut scratch, MAX_HANDSHAKE_FRAME_LEN);
    let (proto, want) = match hello {
        Ok(Frame::Hello { proto, worker }) => (proto, worker),
        Ok(f) => {
            let msg = format!("expected HELLO, got kind {:#04x}", f.kind());
            send_error(&writer, ERR_MALFORMED, &msg);
            return;
        }
        Err(e) => {
            send_error(&writer, ERR_BAD_MAGIC, &format!("bad HELLO: {e:#}"));
            return;
        }
    };
    if proto != PROTO_VERSION {
        send_error(
            &writer,
            ERR_PROTO,
            &format!("server speaks ADVGPNT1 rev {PROTO_VERSION}, client offered {proto}"),
        );
        return;
    }
    let id = match registry.claim(want) {
        Ok(id) => id,
        Err((code, msg)) => {
            send_error(&writer, code, &msg);
            return;
        }
    };
    let welcome = Frame::Welcome {
        proto: PROTO_VERSION,
        worker: id,
        m: layout.m as u64,
        d: layout.d as u64,
        tau,
    };
    let (version, theta, meta, shutdown) = published.snapshot_meta();
    let hand = send_bytes(&writer, &welcome.encode()).and_then(|_| {
        if shutdown {
            send_bytes(&writer, &Frame::Shutdown.encode())
        } else {
            send_bytes(&writer, &wire::publish_frame_bytes(version, meta, &theta))
        }
    });
    if hand.is_err() || shutdown {
        registry.release(id);
        return;
    }
    // Handshake passed: back to blocking reads (see above).
    let _ = reader.set_read_timeout(None);
    log_info!("ps::net: worker {id} joined from {peer} (θ v{version})");

    // ---- publish fan-out: one detached thread per connection ----
    let pub_w = Arc::clone(&writer);
    let pub_published = Arc::clone(&published);
    std::thread::spawn(move || {
        let mut seen = version;
        loop {
            match pub_published.wait_newer_meta(seen) {
                Some((v, th, meta)) => {
                    if send_bytes(&pub_w, &wire::publish_frame_bytes(v, meta, &th)).is_err() {
                        // Link gone (or write-timeout on a wedged peer):
                        // kill the socket so the reader side unblocks
                        // promptly and retires the clock, instead of
                        // waiting for the peer's FIN that may never come.
                        let _ = pub_w.lock().unwrap().shutdown(std::net::Shutdown::Both);
                        return;
                    }
                    seen = v;
                }
                None => {
                    let _ = send_bytes(&pub_w, &Frame::Shutdown.encode());
                    return;
                }
            }
        }
    });

    // ---- worker → server pump (this thread) ----
    let mut exited = false;
    loop {
        match wire::read_frame_opt(&mut reader, &mut scratch) {
            Ok(Some(Frame::Push(p))) => {
                if exited {
                    // A push after EXIT would re-admit the retired
                    // clock — and with `exited` already true, no
                    // WorkerExit would be synthesized on disconnect,
                    // leaving a ghost clock that stalls the gate
                    // forever.  Protocol-state violation: drop the
                    // connection (its clock stays retired).
                    send_error(&writer, ERR_MALFORMED, "PUSH after EXIT");
                    break;
                }
                if p.worker as u64 != id {
                    send_error(
                        &writer,
                        ERR_ID_MISMATCH,
                        &format!("push for worker {} on worker-{id} connection", p.worker),
                    );
                    break;
                }
                if p.grad.len() != layout.len() {
                    send_error(
                        &writer,
                        ERR_DIM,
                        &format!("gradient dim {} but θ dim is {}", p.grad.len(), layout.len()),
                    );
                    break;
                }
                if tx.send(ToServer::Push(p)).is_err() {
                    break; // server loop already returned
                }
            }
            Ok(Some(Frame::WorkerExit { worker })) => {
                if worker != id {
                    // Same contract as PUSH (and docs/PROTOCOL.md
                    // code 6): the id field must match the connection.
                    send_error(
                        &writer,
                        ERR_ID_MISMATCH,
                        &format!("exit for worker {worker} on worker-{id} connection"),
                    );
                    break;
                }
                exited = true;
                let _ = tx.send(ToServer::WorkerExit { worker: id as usize });
                // Keep draining until the client closes its end.
            }
            Ok(Some(Frame::Error { code, message })) => {
                log_warn!("ps::net: worker {id} sent error {code}: {message}");
                break;
            }
            Ok(Some(f)) => {
                send_error(&writer, ERR_MALFORMED, &format!("unexpected kind {:#04x}", f.kind()));
                break;
            }
            Ok(None) => break, // clean close
            Err(e) => {
                log_warn!("ps::net: worker {id} ({peer}) stream error: {e:#}");
                break;
            }
        }
    }
    if !exited {
        // Mid-stream disconnect (crash, kill -9, partition): retire the
        // clock so the gate ranges over live workers only — the
        // networked twin of the in-process kill-worker path.
        let _ = tx.send(ToServer::WorkerExit { worker: id as usize });
    }
    // Enforce the "ERROR (or EXIT) then close" contract for every exit
    // from the loop: killing the socket makes the publisher thread's
    // next write fail so it exits too — otherwise it would stream
    // publishes to a dead connection (one pinned thread + FD per
    // erroring client) for the rest of the run.
    let _ = reader.shutdown(std::net::Shutdown::Both);
    registry.release(id);
    log_info!(
        "ps::net: worker {id} ({peer}) disconnected{}",
        if exited { "" } else { " without EXIT — clock retired" }
    );
}

/// A handshaken worker-side connection: holds the assigned id, the θ
/// layout and staleness bound the server announced, and the initial θ
/// snapshot.  [`NetWorkerHandle::run`] turns it into a full worker.
pub struct NetWorkerHandle {
    stream: TcpStream,
    /// Worker id this connection runs as (claimed or server-assigned).
    pub worker: usize,
    /// θ layout announced by WELCOME — build the engine from this.
    pub layout: ThetaLayout,
    /// Staleness bound τ announced by WELCOME (informational).
    pub tau: u64,
    version: u64,
    meta: PublishMeta,
    theta: Vec<f64>,
}

impl NetWorkerHandle {
    /// Connect and handshake.  `claim = Some(k)` asks to run as worker
    /// k (the id owning shard k); `None` lets the server assign the
    /// lowest free id.
    pub fn connect(addr: &str, claim: Option<usize>) -> Result<Self> {
        let mut stream = TcpStream::connect(addr)
            .with_context(|| format!("connect to ADVGPNT1 server {addr}"))?;
        let _ = stream.set_nodelay(true);
        // Bound the handshake so a silent listener can't hang the
        // worker forever; cleared below once WELCOME validates (pulls
        // can legitimately wait a long time between publishes).
        let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
        let hello = Frame::Hello {
            proto: PROTO_VERSION,
            worker: claim.map_or(WORKER_ID_ANY, |c| c as u64),
        };
        wire::write_frame(&mut stream, &hello).context("send HELLO")?;
        let mut scratch = Vec::new();
        // The server is unvalidated until WELCOME arrives: cap the read
        // so a rogue listener can't make us allocate MAX_FRAME_LEN.
        let welcome =
            wire::read_frame_capped(&mut stream, &mut scratch, MAX_HANDSHAKE_FRAME_LEN)?;
        let (worker, layout, tau) = match welcome {
            Frame::Welcome { proto, worker, m, d, tau } => {
                ensure!(
                    proto == PROTO_VERSION,
                    "server negotiated unsupported ADVGPNT1 rev {proto}"
                );
                ensure!(
                    (1..=1 << 20).contains(&m) && (1..=1 << 20).contains(&d),
                    "WELCOME: implausible layout m={m} d={d}"
                );
                (worker as usize, ThetaLayout::new(m as usize, d as usize), tau)
            }
            Frame::Error { code, message } => {
                bail!("server rejected the connection (code {code}): {message}")
            }
            f => bail!("expected WELCOME, got frame kind {:#04x}", f.kind()),
        };
        let (version, meta, theta) = match wire::read_frame(&mut stream, &mut scratch)? {
            Frame::Publish { version, meta, theta } => {
                ensure!(
                    theta.len() == layout.len(),
                    "initial PUBLISH carries dim {} but layout m={} d={} needs {}",
                    theta.len(),
                    layout.m,
                    layout.d,
                    layout.len()
                );
                (version, meta, theta)
            }
            Frame::Shutdown => bail!("server is shutting down; nothing to join"),
            Frame::Error { code, message } => {
                bail!("server rejected the connection (code {code}): {message}")
            }
            f => bail!("expected the initial PUBLISH, got frame kind {:#04x}", f.kind()),
        };
        let _ = stream.set_read_timeout(None);
        Ok(Self { stream, worker, layout, tau, version, meta, theta })
    }

    /// θ version the server was at when this connection handshook.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Run the worker loop over this connection until the server shuts
    /// down or the profile makes the worker leave.  Internally this
    /// bridges the socket onto a local [`Published`] + `mpsc` pair and
    /// calls the ordinary [`run_worker`] — straggler/crash/leave
    /// profiles, windowed streaming, and [`WorkerSource::Store`] all
    /// behave exactly as they do in-process.
    pub fn run(
        self,
        source: WorkerSource,
        factory: EngineFactory,
        profile: WorkerProfile,
    ) -> Result<()> {
        let Self { stream, worker, layout, tau: _, version, meta, theta } = self;
        ensure!(
            source.d() == layout.d,
            "shard has d={} features but the server's layout has d={}",
            source.d(),
            layout.d
        );
        // Seed a local Published with the server's snapshot so the
        // worker's first pull adopts the live version (a late joiner
        // whose first push claimed version 0 would stall a tight gate).
        let published = Published::new(theta.clone());
        if version > 0 {
            published.publish_meta(version, theta, meta);
        }
        let reader = stream.try_clone().context("clone stream for the publish pump")?;
        let ctrl = stream.try_clone().context("clone stream for teardown")?;
        let (tx, rx) = std::sync::mpsc::channel::<ToServer>();
        let dim = layout.len();
        std::thread::scope(|s| {
            // Publish pump: server → local Published.
            let pub_r = Arc::clone(&published);
            s.spawn(move || {
                let mut r = reader;
                let mut scratch = Vec::new();
                loop {
                    match wire::read_frame_opt(&mut r, &mut scratch) {
                        Ok(Some(Frame::Publish { version, meta, theta })) => {
                            if theta.len() != dim {
                                // Protocol violation; don't hand the
                                // engine a mis-sized θ.
                                log_warn!(
                                    "worker {worker}: PUBLISH dim {} ≠ layout dim {dim}",
                                    theta.len()
                                );
                                break;
                            }
                            pub_r.publish_meta(version, theta, meta);
                        }
                        Ok(Some(Frame::Shutdown)) | Ok(None) => break,
                        Ok(Some(Frame::Error { code, message })) => {
                            log_warn!("worker {worker}: server error {code}: {message}");
                            break;
                        }
                        Ok(Some(f)) => {
                            log_warn!("worker {worker}: unexpected frame kind {:#04x}", f.kind());
                            break;
                        }
                        Err(e) => {
                            // Server died mid-frame, or our own teardown
                            // half-close raced a publish: either way the
                            // run is over for this worker.
                            log_debug!("worker {worker}: publish stream ended: {e:#}");
                            break;
                        }
                    }
                }
                pub_r.shutdown();
            });
            // Push pump: local channel → server.
            let pub_w = Arc::clone(&published);
            let wh = s.spawn(move || {
                let mut w = stream;
                while let Ok(msg) = rx.recv() {
                    let frame: Frame = msg.into();
                    if let Err(e) = wire::write_frame(&mut w, &frame) {
                        // Server unreachable: stop the local loop too.
                        pub_w.shutdown();
                        return Err(e);
                    }
                }
                let _ = w.shutdown(std::net::Shutdown::Write);
                Ok(())
            });
            // The worker loop itself, unchanged from the in-process path.
            run_worker(worker, source, factory, Arc::clone(&published), tx, profile);
            if let Ok(Err(e)) = wh.join().map_err(|_| "push pump panicked") {
                log_warn!("worker {worker}: push stream failed: {e}");
            }
            // Unblock the publish pump if it is still mid-read (early
            // departure: the server keeps publishing to others).
            let _ = ctrl.shutdown(std::net::Shutdown::Both);
        });
        Ok(())
    }
}

/// Connect to `addr`, handshake (claiming `claim` if given), and run
/// the worker loop to completion.  Returns the worker id the run used.
/// This is the whole body of `advgp worker --connect`.
pub fn remote_worker_loop(
    addr: &str,
    claim: Option<usize>,
    source: WorkerSource,
    factory: EngineFactory,
    profile: WorkerProfile,
) -> Result<usize> {
    let handle = NetWorkerHandle::connect(addr, claim)?;
    let id = handle.worker;
    handle.run(source, factory, profile)?;
    Ok(id)
}
