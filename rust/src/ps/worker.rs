//! The worker loop (Algorithm 1, worker side) with straggler and
//! crash/restart injection.

use super::messages::{Push, ToServer};
use super::Published;
use crate::data::Dataset;
use crate::grad::EngineFactory;
use crate::linalg::Mat;
use crate::util::rng::Pcg64;
use crate::util::{pool, Stopwatch};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Duration;

/// Per-worker behaviour knobs (used by Fig. 2's straggler experiment and
/// the failure-injection tests).
#[derive(Clone, Debug, Default)]
pub struct WorkerProfile {
    /// Sleep this long before *every* iteration (the paper's simulated
    /// slow workers: "a random sleep time of 0, 10 or 20 seconds").
    pub straggle: Duration,
    /// Simulate a crash at local iteration N: the worker drops its
    /// engine, sleeps `restart_after`, rebuilds, and rejoins.
    pub crash_at: Option<u64>,
    pub restart_after: Duration,
    /// Cap rows per iteration (0 = full shard, the paper's setting).
    /// Capped workers rotate a cyclic window through the shard so the
    /// cap subsamples *all* of their data over time, not a fixed head.
    pub max_rows: usize,
    /// Thread-pool budget for this worker's gradient computation
    /// (0 = auto: the coordinator splits `pool::threads()` across
    /// workers).  See `util::pool::with_budget`.
    pub threads: usize,
}

/// Run one worker until the server shuts down.
pub fn run_worker(
    worker_id: usize,
    shard: Dataset,
    factory: EngineFactory,
    published: Arc<Published>,
    tx: Sender<ToServer>,
    profile: WorkerProfile,
) {
    let mut engine = factory(worker_id);
    let mut seen: u64 = 0;
    let mut local_iter: u64 = 0;
    let mut crashed = false;
    // Capped workers rotate a cyclic window through the shard (seeded
    // starting offset, advanced by the cap each iteration) so every row
    // is visited within ⌈n/cap⌉ iterations — the old `shard.head(cap)`
    // resampled the *same* rows forever.  The window buffer is reused
    // across iterations; uncapped workers borrow the shard directly
    // (the old path cloned the whole dataset every step).
    let capped = profile.max_rows > 0 && profile.max_rows < shard.n();
    let mut window = Dataset { x: Mat::empty(), y: Vec::new() };
    let mut offset = if capped {
        Pcg64::seeded(worker_id as u64 ^ 0x5EED).next_below(shard.n() as u64) as usize
    } else {
        0
    };
    // First pull uses version 0 (initial θ) — workers must each push one
    // gradient before the server can make update 0, so don't wait for a
    // newer version on the first iteration.
    let (mut version, mut theta) = {
        let (v, th, _sd) = published.snapshot();
        (v, th)
    };
    loop {
        if !profile.straggle.is_zero() {
            std::thread::sleep(profile.straggle);
        }
        if !crashed && profile.crash_at == Some(local_iter) {
            // Crash: lose the engine, stay dark, then rebuild and rejoin.
            crashed = true;
            drop(engine);
            std::thread::sleep(profile.restart_after);
            engine = factory(worker_id);
        }

        let (x, y): (&Mat, &[f64]) = if capped {
            shard.copy_cyclic_window(offset, profile.max_rows, &mut window);
            offset = (offset + profile.max_rows) % shard.n();
            (&window.x, &window.y)
        } else {
            (&shard.x, &shard.y)
        };
        let sw = Stopwatch::start();
        // Cap this worker's parallel linalg at its share of the pool so
        // concurrent workers don't oversubscribe the machine.
        let res = pool::with_budget(profile.threads.max(1), || engine.grad(&theta, x, y));
        let push = Push {
            worker: worker_id,
            version,
            value: res.value,
            grad: res.grad,
            compute_secs: sw.secs(),
        };
        if tx.send(ToServer::Push(push)).is_err() {
            break; // server gone
        }
        local_iter += 1;

        // Block until a strictly newer version (Algorithm 1, line 1).
        match published.wait_newer(seen.max(version)) {
            None => break,
            Some((v, th)) => {
                seen = v;
                version = v;
                theta = th;
            }
        }
    }
    let _ = tx.send(ToServer::WorkerExit { worker: worker_id });
}
