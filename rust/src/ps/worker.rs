//! The worker loop (Algorithm 1, worker side) with straggler,
//! crash/restart, and permanent-departure injection, over in-memory or
//! out-of-core data sources.
//!
//! The loop is transport-agnostic: it talks to the server only through
//! a [`Published`] handle (pull) and a `Sender<ToServer>` (push).
//! In-process those are the coordinator's shared handle and channel;
//! over the network [`super::net::NetWorkerHandle::run`] hands the
//! *same function* a socket-backed pair, so profiles, windowing, and
//! store streaming behave identically on both transports.

use super::messages::{Push, ToServer};
use super::Published;
use crate::data::store::{QuarantinePolicy, ShardReader, StoreFault};
use crate::data::Dataset;
use crate::grad::EngineFactory;
use crate::linalg::Mat;
use crate::util::rng::Pcg64;
use crate::util::{pool, Stopwatch};
use crate::{log_info, log_warn};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Shared per-worker stream-cursor registry (ISSUE 7): worker id →
/// `(initial offset, completed local iterations)`.  Each worker records
/// its entry *before* every push (so the channel's happens-before makes
/// the entry visible to whoever absorbed the push), and the server
/// snapshots the whole map into each checkpoint.  At τ=0 the snapshot
/// is exact: every worker has pushed for update `t` and is blocked
/// waiting for `t+1`, so every entry reads `t+1` consumed windows.
/// In-process transports only — networked workers keep their own
/// cursors and resume from the stream head (documented limitation).
pub type CursorRegistry = Arc<Mutex<BTreeMap<u64, (u64, u64)>>>;

/// Where a worker's shard lives (ISSUE 3).
///
/// * `Memory` — the original path: the shard is resident and borrowed
///   every iteration (capped workers rotate a cyclic window through it).
/// * `Store` — out-of-core: the worker streams fixed-size minibatch
///   chunks from a shard file through one reusable buffer; peak
///   resident data is one chunk, never the shard.
/// * `Pool` — a [`StorePool`]: out-of-core like `Store`, plus shard
///   adoption (ISSUE 6) — when a worker departs, its shards go back to
///   a coordinator-shared inbox and the survivors pick them up, so
///   data coverage survives departures.
pub enum WorkerSource {
    Memory(Dataset),
    Store(ShardReader),
    Pool(StorePool),
}

impl WorkerSource {
    /// Rows in the underlying shard(s).
    pub fn n(&self) -> usize {
        match self {
            WorkerSource::Memory(ds) => ds.n(),
            WorkerSource::Store(r) => r.n(),
            WorkerSource::Pool(p) => p.n(),
        }
    }

    /// Feature count of the underlying shard(s).
    pub fn d(&self) -> usize {
        match self {
            WorkerSource::Memory(ds) => ds.d(),
            WorkerSource::Store(r) => r.d(),
            WorkerSource::Pool(p) => p.d(),
        }
    }

    /// Install a corruption-quarantine policy (ISSUE 7) on every
    /// underlying [`ShardReader`] — store reads then degrade (skip
    /// quarantined chunks under the budget) instead of failing strict.
    /// No-op for in-memory sources.
    pub fn set_fault_policy(&mut self, policy: QuarantinePolicy) {
        match self {
            WorkerSource::Memory(_) => {}
            WorkerSource::Store(r) => r.set_fault_policy(policy),
            WorkerSource::Pool(p) => p.set_fault_policy(policy),
        }
    }

    /// Advance the stream cursor as `windows` iterations would
    /// (arithmetic only — no I/O).  Memory sources are a no-op: their
    /// cursor lives in [`run_worker`]'s own offset arithmetic.
    pub fn fast_forward(&mut self, windows: u64) {
        match self {
            WorkerSource::Memory(_) => {}
            WorkerSource::Store(r) => r.fast_forward(windows),
            WorkerSource::Pool(p) => p.fast_forward(windows),
        }
    }
}

/// The shared shard-adoption inbox (ISSUE 6): departed workers'
/// [`StorePool`]s surrender their readers here; survivors adopt them
/// on their next iteration.  One per elastic run, created by the
/// coordinator.
pub type ShardInbox = Arc<Mutex<Vec<ShardReader>>>;

/// One worker's rotation of out-of-core shards, wired to a shared
/// adoption inbox (ISSUE 6).  Starts with the worker's own shard;
/// every window first drains the inbox (adopting whatever departed
/// workers surrendered, stream cursors intact), then reads round-robin
/// across the held shards.  A shard that fails to read is dropped from
/// the rotation — the pool only errors (and the worker leaves) when
/// *no* readable shard remains.
pub struct StorePool {
    worker_id: usize,
    readers: Vec<ShardReader>,
    inbox: ShardInbox,
    /// Round-robin cursor into `readers`.
    next: usize,
    /// Window size applied to every adopted reader (the owner's
    /// `window_rows`), set by `configure`.
    chunk_rows: usize,
    d: usize,
    /// Quarantine policy applied to every held *and adopted* reader
    /// (ISSUE 7), so degraded mode survives shard adoption.
    policy: Option<QuarantinePolicy>,
}

impl StorePool {
    pub fn new(worker_id: usize, reader: ShardReader, inbox: ShardInbox) -> Self {
        Self::from_readers(worker_id, vec![reader], inbox)
    }

    /// Pool over an explicit reader group — a logically-repartitioned
    /// worker streams several chunk-restricted readers round-robin
    /// (ISSUE 7, [`crate::data::store::ShardSet::reader_group`]).
    pub fn from_readers(
        worker_id: usize,
        readers: Vec<ShardReader>,
        inbox: ShardInbox,
    ) -> Self {
        assert!(!readers.is_empty(), "a store pool needs at least one reader");
        let d = readers[0].d();
        let chunk_rows = readers[0].chunk_rows();
        Self { worker_id, readers, inbox, next: 0, chunk_rows, d, policy: None }
    }

    /// Re-home the pool onto a run's shared shard inbox.  The
    /// coordinator does this to pools built before the run existed
    /// (pre-grouped repartition sources), so surrender/adopt spans
    /// every pool worker of the run instead of a private dead-letter
    /// inbox.
    pub fn rehome(&mut self, inbox: ShardInbox) {
        self.inbox = inbox;
    }

    /// Install a quarantine policy on every held reader and remember it
    /// for readers adopted later.
    pub fn set_fault_policy(&mut self, policy: QuarantinePolicy) {
        for r in &mut self.readers {
            r.set_fault_policy(policy.clone());
        }
        self.policy = Some(policy);
    }

    /// Advance the round-robin stream as `windows` iterations would
    /// (arithmetic only): each held reader is forwarded by its share of
    /// the windows, in rotation order.  Exact when the membership never
    /// changed (the resume case: a freshly built pool holds exactly its
    /// own shard); adoption and quarantine void the bitwise promise.
    pub fn fast_forward(&mut self, windows: u64) {
        if self.readers.is_empty() || windows == 0 {
            return;
        }
        let k = self.readers.len() as u64;
        for i in 0..self.readers.len() {
            let idx = (self.next + i) % self.readers.len();
            let share = windows / k + u64::from((i as u64) < windows % k);
            self.readers[idx].fast_forward(share);
        }
        self.next = ((self.next as u64 + windows) % k) as usize;
    }

    /// Rows across the currently held shards (grows on adoption).
    pub fn n(&self) -> usize {
        self.readers.iter().map(|r| r.n()).sum()
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Default window of the primary shard (mirrors
    /// [`ShardReader::chunk_rows`] for the `window_rows` decision).
    fn primary_chunk_rows(&self) -> usize {
        self.readers.first().map_or(1, |r| r.chunk_rows())
    }

    /// Apply the owner's window size and starting offset (the pool twin
    /// of `set_chunk_rows` + `seek_to` on a bare reader).
    fn configure(&mut self, window_rows: usize, offset: usize) {
        self.chunk_rows = window_rows.max(1);
        for r in &mut self.readers {
            r.set_chunk_rows(self.chunk_rows);
        }
        if let Some(r) = self.readers.first_mut() {
            r.seek_to(offset);
        }
    }

    /// Drain the adoption inbox into this pool's rotation.
    fn adopt(&mut self) {
        let mut inbox = self.inbox.lock().unwrap();
        while let Some(mut r) = inbox.pop() {
            r.set_chunk_rows(self.chunk_rows);
            if let Some(p) = &self.policy {
                r.set_fault_policy(p.clone());
            }
            log_info!(
                "worker {}: adopted surrendered shard {} ({} rows) — \
                 rotation now holds {} shard(s)",
                self.worker_id,
                r.path().display(),
                r.n(),
                self.readers.len() + 1
            );
            self.readers.push(r);
        }
    }

    /// The next window, round-robin across held shards (adopting first).
    ///
    /// Error triage (ISSUE 7): a dry corruption budget or a strict
    /// [`StoreFault::ChunkCorrupt`] propagates typed — corrupt data must
    /// never be silently dropped from the rotation without accounting.
    /// Everything else (a fully quarantined shard, plain I/O death)
    /// keeps the pre-SH2 behavior: drop the shard, try the others.
    fn next_window(&mut self, out: &mut Dataset) -> Result<usize> {
        self.adopt();
        while !self.readers.is_empty() {
            self.next %= self.readers.len();
            match self.readers[self.next].next_window(out) {
                Ok(k) => {
                    self.next += 1;
                    return Ok(k);
                }
                Err(e) => {
                    if matches!(
                        e.downcast_ref::<StoreFault>(),
                        Some(StoreFault::BudgetDry { .. } | StoreFault::ChunkCorrupt { .. })
                    ) {
                        return Err(e);
                    }
                    let r = self.readers.remove(self.next);
                    log_warn!(
                        "worker {}: shard {} read failed ({e:#}); dropped from \
                         the rotation",
                        self.worker_id,
                        r.path().display()
                    );
                }
            }
        }
        bail!("no readable shard left in the pool")
    }

    /// Surrender every held shard to the inbox (the departure path:
    /// stream cursors ride along, so adopters continue mid-rotation).
    /// Returns how many shards were given up.
    pub fn surrender(self) -> usize {
        let Self { readers, inbox, worker_id, .. } = self;
        let k = readers.len();
        if k > 0 {
            log_info!("worker {worker_id}: surrendering {k} shard(s) for adoption");
            inbox.lock().unwrap().extend(readers);
        }
        k
    }
}

impl From<Dataset> for WorkerSource {
    fn from(ds: Dataset) -> Self {
        WorkerSource::Memory(ds)
    }
}

impl From<ShardReader> for WorkerSource {
    fn from(r: ShardReader) -> Self {
        WorkerSource::Store(r)
    }
}

/// Per-worker behaviour knobs (used by Fig. 2's straggler experiment and
/// the failure-injection/elasticity tests).
#[derive(Clone, Debug, Default)]
pub struct WorkerProfile {
    /// Sleep this long before *every* iteration (the paper's simulated
    /// slow workers: "a random sleep time of 0, 10 or 20 seconds").
    pub straggle: Duration,
    /// Simulate a crash at local iteration N: the worker drops its
    /// engine, sleeps `restart_after`, rebuilds, and rejoins.
    pub crash_at: Option<u64>,
    pub restart_after: Duration,
    /// Depart permanently at local iteration N (ISSUE 3): the worker
    /// sends `WorkerExit` and the server retires its clock from the
    /// bounded-staleness gate, so the run proceeds without it.
    pub leave_at: Option<u64>,
    /// Cap rows per iteration (0 = full shard, the paper's setting).
    /// Capped workers rotate a cyclic window through the shard so the
    /// cap subsamples *all* of their data over time, not a fixed head.
    /// For `Store` sources this also overrides the store's chunk size.
    pub max_rows: usize,
    /// Thread-pool budget for this worker's gradient computation
    /// (0 = auto: the coordinator splits `pool::threads()` across
    /// workers).  See `util::pool::with_budget`.
    pub threads: usize,
    /// Shared stream-cursor registry (ISSUE 7): when set, the worker
    /// records `(initial offset, consumed windows)` here before every
    /// push, so checkpoints capture exact stream positions.
    pub cursors: Option<CursorRegistry>,
    /// Resume cursor from a checkpoint (ISSUE 7): `(initial offset,
    /// consumed windows)`.  The worker re-seeds its stream from the
    /// original offset and fast-forwards, instead of drawing a fresh
    /// seeded start — the streamed half of bitwise τ=0 resume.
    pub resume_cursor: Option<(u64, u64)>,
}

/// Run one worker until the server shuts down (or the profile makes it
/// leave).  The worker pulls θ from `published`, computes its local
/// gradient over `source`, and pushes to `tx` — Algorithm 1, worker
/// side.
///
/// `source` is borrowed, not consumed: a transport that reconnects
/// after a dropped link ([`super::net::remote_worker_loop`]'s bounded
/// retry) hands the *same* source — stream cursor and all — to the
/// next `run_worker` call.
pub fn run_worker(
    worker_id: usize,
    source: &mut WorkerSource,
    factory: EngineFactory,
    published: Arc<Published>,
    tx: Sender<ToServer>,
    profile: WorkerProfile,
) {
    let mut engine = factory(worker_id);
    let mut seen: u64 = 0;
    let mut crashed = false;
    let n = source.n();
    // Windowed iteration: store sources always stream chunks; memory
    // sources window only when capped.  Windows rotate cyclically from
    // a seeded offset (advanced by the window size each iteration) so
    // every row is visited within ⌈n/window⌉ iterations — see
    // `Dataset::copy_cyclic_window`.  The window buffer is reused
    // across iterations; uncapped memory workers borrow the shard
    // directly (the pre-ISSUE-2 path cloned the whole dataset every
    // step).
    let window_rows = match &*source {
        WorkerSource::Memory(_) => {
            if profile.max_rows > 0 && profile.max_rows < n {
                profile.max_rows
            } else {
                0 // borrow the whole shard
            }
        }
        WorkerSource::Store(r) => {
            if profile.max_rows > 0 {
                profile.max_rows.min(n)
            } else {
                r.chunk_rows()
            }
        }
        WorkerSource::Pool(p) => {
            if profile.max_rows > 0 {
                profile.max_rows.min(n)
            } else {
                p.primary_chunk_rows()
            }
        }
    };
    let mut window = Dataset { x: Mat::empty(), y: Vec::new() };
    // Seed the cyclic start only for windows smaller than the shard:
    // rotating a full-shard window is a no-op for coverage, and offset
    // 0 keeps a whole-shard store stream bitwise-identical to the
    // resident borrow (pinned by `tests/store_checkpoint.rs`).
    //
    // A resume cursor (ISSUE 7) overrides the fresh draw: the worker
    // re-seeds from the checkpointed *initial* offset and fast-forwards
    // by the consumed-window count, so the resumed stream serves
    // exactly the windows the uninterrupted run would have.
    let fresh_offset = if window_rows > 0 && window_rows < n {
        Pcg64::seeded(worker_id as u64 ^ 0x5EED).next_below(n as u64) as usize
    } else {
        0
    };
    let (init_offset, start_iter) = match profile.resume_cursor {
        Some((off, consumed)) => (off as usize, consumed),
        None => (fresh_offset, 0),
    };
    let mut local_iter: u64 = start_iter;
    // Memory sources keep their cursor here; store sources keep it in
    // the reader (one copy of the cyclic arithmetic, in `data::store`).
    let mut offset = if window_rows > 0 && n > 0 {
        ((init_offset as u128 + start_iter as u128 * window_rows as u128) % n as u128) as usize
    } else {
        init_offset
    };
    match &mut *source {
        WorkerSource::Store(reader) => {
            reader.set_chunk_rows(window_rows);
            reader.seek_to(init_offset);
            reader.fast_forward(start_iter);
        }
        WorkerSource::Pool(pool) => {
            pool.configure(window_rows, init_offset);
            pool.fast_forward(start_iter);
        }
        WorkerSource::Memory(_) => {}
    }
    // First pull uses version 0 (initial θ) — workers must each push one
    // gradient before the server can make update 0, so don't wait for a
    // newer version on the first iteration.  A late joiner lands here
    // too: its first snapshot *adopts* whatever version is live.
    let (mut version, mut theta) = {
        let (v, th, _sd) = published.snapshot();
        (v, th)
    };
    loop {
        if profile.leave_at == Some(local_iter) {
            break; // permanent departure — WorkerExit below retires us
        }
        if !profile.straggle.is_zero() {
            std::thread::sleep(profile.straggle);
        }
        if !crashed && profile.crash_at == Some(local_iter) {
            // Crash: lose the engine, stay dark, then rebuild and rejoin.
            crashed = true;
            drop(engine);
            std::thread::sleep(profile.restart_after);
            engine = factory(worker_id);
        }

        let (x, y): (&Mat, &[f64]) = match &mut *source {
            WorkerSource::Memory(ds) => {
                if window_rows > 0 {
                    ds.copy_cyclic_window(offset, window_rows, &mut window);
                    offset = (offset + window_rows) % n;
                    (&window.x, &window.y)
                } else {
                    (&ds.x, &ds.y)
                }
            }
            WorkerSource::Store(reader) => {
                if let Err(e) = reader.next_window(&mut window) {
                    // A dead store is a dead worker: depart and let the
                    // gate retire our clock.
                    log_warn!("worker {worker_id}: shard read failed, leaving: {e:#}");
                    break;
                }
                (&window.x, &window.y)
            }
            WorkerSource::Pool(pool) => {
                // The pool drops individual bad shards itself; only a
                // pool with nothing left to read ends the worker.
                if let Err(e) = pool.next_window(&mut window) {
                    log_warn!("worker {worker_id}: shard pool exhausted, leaving: {e:#}");
                    break;
                }
                (&window.x, &window.y)
            }
        };
        let sw = Stopwatch::start();
        // Cap this worker's parallel linalg at its share of the pool so
        // concurrent workers don't oversubscribe the machine.
        let res = pool::with_budget(profile.threads.max(1), || engine.grad(&theta, x, y));
        let push = Push {
            worker: worker_id,
            version,
            value: res.value,
            grad: res.grad,
            compute_secs: sw.secs(),
        };
        // Record the stream cursor *before* the push: the channel's
        // happens-before then guarantees the server sees a registry in
        // which this worker has consumed `local_iter + 1` windows
        // whenever it has absorbed this push (ISSUE 7).
        if let Some(reg) = &profile.cursors {
            reg.lock().unwrap().insert(worker_id as u64, (init_offset as u64, local_iter + 1));
        }
        if tx.send(ToServer::Push(push)).is_err() {
            break; // server gone
        }
        local_iter += 1;

        // Block until a strictly newer version (Algorithm 1, line 1).
        match published.wait_newer(seen.max(version)) {
            None => break,
            Some((v, th)) => {
                seen = v;
                version = v;
                theta = th;
            }
        }
    }
    let _ = tx.send(ToServer::WorkerExit { worker: worker_id });
}
