//! Run metrics: evaluation snapshots and per-iteration statistics —
//! the raw material for every figure in the paper.

use crate::util::Stats;
use anyhow::Result;
use std::path::Path;

/// One evaluator snapshot.
#[derive(Clone, Debug)]
pub struct TraceRow {
    /// Seconds since training start.
    pub t_secs: f64,
    /// Server version at snapshot time.
    pub version: u64,
    pub rmse: f64,
    pub mnlp: f64,
    /// Negative ELBO (−L = Σg + h) over the elbo-eval subset, if tracked.
    pub neg_elbo: Option<f64>,
}

/// Metrics produced by one evaluation pass.
#[derive(Clone, Copy, Debug)]
pub struct EvalMetrics {
    pub rmse: f64,
    pub mnlp: f64,
    pub neg_elbo: Option<f64>,
}

/// Aggregated run statistics from the server loop.
///
/// Every series is a streaming [`Stats`] summary (count/mean/min/max +
/// a bounded quantile reservoir), so server memory stays O(1) in the
/// number of updates — long runs never grow these linearly.  Use
/// `Stats::quantile` for percentiles (e.g. p95 iteration time).
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    /// Wall time between consecutive server updates.
    pub iter_secs: Stats,
    /// Observed staleness t − min_k t_k at each update.
    pub staleness: Stats,
    /// Worker compute seconds (from push messages).
    pub worker_compute_secs: Stats,
    /// Published version after the last update.  Equals the number of
    /// updates for a fresh run; on a resumed run the count continues
    /// from the checkpoint version (cumulative across resumes).
    pub updates: u64,
    /// Total pushes received.
    pub pushes: u64,
    /// Workers admitted mid-run: first push from a previously-unknown
    /// worker id (ISSUE 3 elasticity).
    pub joins: u64,
    /// Worker departures the server observed: mid-run exits (the
    /// elasticity signal) plus whatever shutdown-driven exits had
    /// reached the channel by teardown — exits still in flight when
    /// the server returns are not counted, so treat this as a floor.
    /// Only *members* count: an exit for an id that never pushed and
    /// was never declared (e.g. a read-only networked observer
    /// disconnecting) is not a leave.
    pub leaves: u64,
    /// Transport faults absorbed gracefully (ISSUE 6): connections the
    /// networked server answered `ERROR` and dropped — corrupt or
    /// truncated frames, protocol violations, dimension mismatches —
    /// plus `ERROR` frames peers sent us.  Always 0 for in-process
    /// runs; on sharded runs, summed across slices.  The slice loop
    /// itself never sees these (graceful degradation by design).
    pub faults: u64,
    /// Store chunks quarantined during the run (ISSUE 7): reads that
    /// failed ADVGPSH2 chunk verification, were isolated, and were
    /// survived in degraded mode under the corruption budget.  0 for
    /// in-memory or intact-store runs; on sharded runs the counter is
    /// shared across workers and tallied once (not per slice).
    pub store_quarantines: u64,
}

/// Write a trace as CSV (t_secs,version,rmse,mnlp,neg_elbo).
pub fn write_trace_csv(path: &Path, rows: &[TraceRow]) -> Result<()> {
    let mut out = String::from("t_secs,version,rmse,mnlp,neg_elbo\n");
    for r in rows {
        out.push_str(&format!(
            "{},{},{},{},{}\n",
            r.t_secs,
            r.version,
            r.rmse,
            r.mnlp,
            r.neg_elbo.map(|v| v.to_string()).unwrap_or_default()
        ));
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_shape() {
        let rows = vec![
            TraceRow { t_secs: 0.5, version: 3, rmse: 1.2, mnlp: 0.9, neg_elbo: Some(10.0) },
            TraceRow { t_secs: 1.0, version: 7, rmse: 1.0, mnlp: 0.8, neg_elbo: None },
        ];
        let dir = std::env::temp_dir().join("advgp_metrics_test");
        let p = dir.join("trace.csv");
        write_trace_csv(&p, &rows).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[1].starts_with("0.5,3,1.2,0.9,10"));
        assert!(lines[2].ends_with(','));
    }
}
